"""Batched LM serving: prefill a batch of prompts, stream greedy tokens from
the KV-cache decode path (per-family caches: KV / SSM states / hybrid).

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b --gen 24
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, list_archs, smoke_config
from repro.configs.base import ShapeConfig
from repro.models import lm
from repro.models.params import init_params
from repro.serving.decode import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_config(get_arch(args.arch))
    params = init_params(jax.random.key(0), lm.model_schema(cfg), cfg.param_dtype)
    shape = ShapeConfig("serve", "prefill", args.prompt_len, args.batch)
    batch = lm.make_batch(jax.random.key(1), cfg, shape)

    t0 = time.time()
    toks = greedy_generate(params, batch, cfg, args.gen)
    dt = time.time() - t0
    n = toks.shape[0] * toks.shape[1]
    print(f"{args.arch} ({cfg.family}): {n} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s incl. compile)")
    for b in range(min(2, args.batch)):
        print(f"  stream[{b}]:", np.asarray(toks[b]).tolist())


if __name__ == "__main__":
    main()
