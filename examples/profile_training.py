"""Per-phase profiling walkthrough (DESIGN.md §13).

    PYTHONPATH=src python examples/profile_training.py

Trains a GBT under the tracer, prints the phase breakdown, and writes
`profile_trace.json` — open it in chrome://tracing or ui.perfetto.dev
to see the span tree on a timeline (the screenshot-able artifact).
"""
import json

from repro.core import GradientBoostedTreesLearner
from repro.data.tabular import adult_like, train_test_split
from repro.obs import trace
from repro.obs.export import phase_summary, write_chrome_trace

train, test = train_test_split(adult_like(4000), 0.3, seed=1)

# 1. Any code run inside trace.capture() is profiled; outside a capture
#    the same instrumentation is a near-zero no-op (gated at <=1% of a
#    50-tree train in tier-1), so nothing here needed a special flag.
with trace.capture() as tracer:
    model = GradientBoostedTreesLearner(
        label="income", num_trees=30).train(train)

# 2. Per-phase aggregates: where did training time go?  self_ms is the
#    phase's own time, excluding its child spans.
print(f"{'phase':<28} {'count':>6} {'total_ms':>9} {'self_ms':>9}")
for name, d in sorted(phase_summary(tracer).items(),
                      key=lambda kv: -kv[1]["self_s"]):
    print(f"{name:<28} {d['count']:>6} {d['total_s'] * 1e3:>9.1f} "
          f"{d['self_s'] * 1e3:>9.1f}")
print()

# 3. The same breakdown rides on the model itself: training_logs carries
#    a schema-versioned "profile" section whenever a capture was active.
prof = model.training_logs["profile"]
print(f"training_logs profile: {prof['span_count']} spans, "
      f"{len(prof['phases'])} distinct phases")
print(json.dumps({k: round(v["total_s"] * 1e3, 1)
                  for k, v in prof["phases"].items()}, indent=1))
print()

# 4. Chrome trace-event export: the timeline view.  Load the file in
#    chrome://tracing (or ui.perfetto.dev) — one lane per thread, each
#    grower phase a nested block with its args (level, frontier, ...).
write_chrome_trace("profile_trace.json", tracer)
print("wrote profile_trace.json -- open in chrome://tracing")

# 5. Inference profiles the same way: spans from the engine dispatch
#    (engines/compile, engines/dispatch) land in the same capture.
with trace.capture() as tracer:
    model.predict({k: v for k, v in test.items() if k != "income"})
for name, d in phase_summary(tracer).items():
    print(f"inference: {name:<20} x{d['count']} "
          f"{d['total_s'] * 1e3:.1f} ms")

# Equivalent CLI (writes the same artifacts from a dataset on disk):
#   python -m repro.cli profile train --dataset=csv:train.csv \
#       --label=income --trace=trace.json --hparam num_trees=30
