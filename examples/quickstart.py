"""Quickstart: the paper's §4 flow in a few lines of Python.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import GradientBoostedTreesLearner, RandomForestLearner
from repro.core.engines import benchmark_inference
from repro.data.tabular import adult_like, train_test_split

# 1. data (Adult/Census-shaped fixture: mixed semantics, missing values)
train, test = train_test_split(adult_like(4000), 0.3, seed=1)

# 2. train — semantics are inferred automatically (§3.4); five lines total
learner = GradientBoostedTreesLearner(label="income", num_trees=60)
model = learner.train(train)

# 3. inspect (show_model analogue)
print(model.summary())
print()

# 4. evaluate with confidence intervals (App. B.3 style report)
print(model.evaluate(test).report())
print()

# 5. compare against another learner, fairly (same folds; §5.2 protocol)
rf = RandomForestLearner(label="income", num_trees=60).train(train)
print("GBT vs RF accuracy:",
      model.evaluate(test)["accuracy"], "vs", rf.evaluate(test)["accuracy"])
print("RF out-of-bag self-evaluation:", rf.self_evaluation.metrics["accuracy"])
print()

# 5b. growth engines (DESIGN.md §6): "batched" is the host fast path (for RF
#     it grows tree_parallelism trees in lockstep); "device" runs the whole
#     level loop as one compiled XLA program (the TPU training path — on CPU
#     hosts it is the portability/correctness path). Unsupported configs fall
#     back to "batched" and say why. histogram_backend picks the histogram
#     accumulator for the batched engine ("auto" is hardware-aware: pallas on
#     TPU, numpy elsewhere — forcing "pallas" without a TPU raises).
rf_dev = RandomForestLearner(label="income", num_trees=8, max_depth=6,
                             compute_oob=False, growth_engine="device",
                             histogram_backend="auto").train(train)
logs = rf_dev.training_logs
print(f"requested growth_engine='device' -> ran {logs['growth_engine']!r}"
      + (f" (fallback: {logs['engine_fallback']})"
         if logs["engine_fallback"] else
         f", {logs['tree_parallelism']} trees per lockstep block"))
print()

# 6. deploy: engine compilation + inference benchmark (App. B.4)
print(benchmark_inference(model, test))

# 7. ship it
model.save("/tmp/quickstart_model")
from repro.core import Model
print("\nreloaded prediction head:",
      Model.load("/tmp/quickstart_model").predict(test)[:3])

# 8. production serving — compiled predictors, micro-batching, BENCH_infer:
#    see examples/serve_forest.py (DESIGN.md §5)
