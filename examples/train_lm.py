"""End-to-end LM training driver: trains an assigned architecture (reduced to
~CPU size by default, full-size on real hardware) for a few hundred steps with
checkpointing/resume, on the deterministic synthetic token stream.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-1.5b --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch qwen2-1.5b --steps 200 \
        --width 256 --layers 8   # ~15M params: "small but real"

Kill it mid-run and re-run: it resumes from the last checkpoint and the loss
curve continues exactly (pure-function-of-step data pipeline).
"""
import argparse

from repro.configs import get_arch, smoke_config
from repro.configs.base import ShapeConfig
from repro.train.loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = smoke_config(get_arch(args.arch)).replace(
        d_model=args.width, n_heads=max(4, args.width // 32),
        head_dim=32, n_kv_heads=max(1, args.width // 64),
        d_ff=args.width * 4, n_layers=args.layers, vocab_size=2048,
        learning_rate=1e-3)
    shape = ShapeConfig("example", "train", args.seq, args.batch)
    out = train_loop(cfg, shape, f"{args.ckpt}_{args.arch}",
                     LoopConfig(total_steps=args.steps, ckpt_every=50,
                                log_every=10))
    first = out["losses"][0][1] if out["losses"] else float("nan")
    last = out["losses"][-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {out['final_step']} steps "
          f"(ckpt: {out['ckpt']})")


if __name__ == "__main__":
    main()
