"""Decision forests x neural networks (paper §2.4 composability): train a GBT
Learner on FROZEN transformer activations — the library-integration story the
paper motivates (hybrid DF+NN research needs libraries that compose).

A small LM embeds token sequences; a GBT classifies sequences by whether the
(hidden) Markov-chain seed that generated them is "A" or "B". The LEARNER
never sees the LM internals — only a feature dict, like any tabular dataset.

    PYTHONPATH=src python examples/forest_on_lm_features.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core import GradientBoostedTreesLearner, LinearLearner
from repro.data.tabular import train_test_split
from repro.models import lm
from repro.models.layers import Ctx
from repro.models.params import init_params

# -- an LM (frozen, random init is fine for a feature extractor demo)
cfg = smoke_config(get_arch("qwen2-1.5b")).replace(vocab_size=256)
params = init_params(jax.random.key(0), lm.model_schema(cfg), cfg.param_dtype)
ctx = Ctx(cfg)


@jax.jit
def embed_sequences(tokens):
    h, _, _ = lm.forward(params, {"tokens": tokens}, ctx)
    return h.mean(axis=1)  # (B, D) mean-pooled features


# -- two token distributions (class A vs class B)
rng = np.random.default_rng(0)
N, S = 1200, 32


def sample(cls, n):
    base = rng.integers(0, 128, (n, S)) if cls == "A" else rng.integers(64, 192, (n, S))
    drift = (np.arange(S) * (2 if cls == "A" else 3)) % 17
    return (base + drift) % 256


toks = np.concatenate([sample("A", N // 2), sample("B", N // 2)])
labels = np.array(["A"] * (N // 2) + ["B"] * (N // 2), dtype=object)
feats = np.asarray(embed_sequences(jnp.asarray(toks, jnp.int32)))

data = {f"lm_feat_{i}": feats[:, i].astype(object) for i in range(feats.shape[1])}
data["cls"] = labels
train, test = train_test_split(data, 0.3, seed=2)

gbt = GradientBoostedTreesLearner(label="cls", num_trees=40).train(train)
lin = LinearLearner(label="cls").train(train)
print("GBT on frozen LM features:", gbt.evaluate(test)["accuracy"])
print("Linear probe baseline:   ", lin.evaluate(test)["accuracy"])
print("\ntop LM features by GBT importance:")
vi = gbt.variable_importances()["NUM_NODES"]
for name, v in sorted(vi.items(), key=lambda kv: -kv[1])[:5]:
    print(f"  {name}: {v:.0f} nodes")
