"""Distributed GBT training on a (data x model) device grid (paper §3.9):
example-parallel histogram psums + feature-parallel split exchange with
bit-packed partition broadcast, plus the single-process simulation backend
with a mid-training worker failure.

    PYTHONPATH=src python examples/distributed_forest.py
(spawns its own 8 placeholder devices; run unchanged on a real 256-chip pod)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core.distributed import DistGBTConfig, DistributedGBT, SimulatedCluster

rng = np.random.default_rng(0)
N, F = 4096, 16
codes = rng.integers(0, 64, (N, F)).astype(np.uint8)
logit = (0.9 * (codes[:, 0] > 30) - 1.1 * (codes[:, 3] > 45)
         + 0.6 * (codes[:, 5] > 10) * (codes[:, 8] > 20) - 0.2)
y = (rng.random(N) < 1 / (1 + np.exp(-logit))).astype(np.float64)

cfg = DistGBTConfig(max_depth=5, n_bins=64, num_trees=20)

print("== 2-D grid training (2 'data' x 4 'model' workers) ==")
mesh = jax.make_mesh((2, 4), ("data", "model"))
model = DistributedGBT(cfg, mesh).fit(codes, y)
acc = ((model.predict_scores(codes) > 0) == y).mean()
print(f"train accuracy: {acc:.4f} over {len(model.trees)} trees")

print("\n== equivalence with a single-worker run ==")
m1 = DistributedGBT(cfg, jax.make_mesh((1, 1), ("data", "model"))).fit(codes, y)
print("max |score diff|:",
      np.abs(m1.predict_scores(codes) - model.predict_scores(codes)).max())

print("\n== fault tolerance: checkpoint + resume mid-forest ==")
half = DistributedGBT(DistGBTConfig(max_depth=5, n_bins=64, num_trees=10),
                      mesh).fit(codes, y)
state = half.state_dict()
state["pred"] = half.predict_scores(codes)
resumed = DistributedGBT(cfg, mesh).fit(codes, y, resume_state=state)
print("resume == straight run:",
      np.allclose(resumed.predict_scores(codes), model.predict_scores(codes),
                  atol=1e-5))

print("\n== simulation backend (paper's third backend) + worker death ==")
sim = SimulatedCluster(codes, n_workers=8, cfg=cfg)
g = 0.5 - y
stats = np.stack([g, np.full(N, 0.25), np.ones(N)], 1)
t0 = sim.grow_tree(stats)
sim.kill_worker(3)  # features reassigned round-robin
t1 = sim.grow_tree(stats)
print("tree unchanged after worker death:", np.allclose(t0["leaf"], t1["leaf"]))
print(f"communication: {sim.traffic_bytes} bytes "
      f"(candidates + 32x bit-packed partitions)")

print("\n== serve through the engine stack ==")
forest = model.to_forest([f"f{i}" for i in range(F)])
from repro.core.tree import aggregate_gbt, predict_raw
scores = aggregate_gbt(predict_raw(forest, codes[:8].astype(np.float32)), forest)
print("first scores:", np.round(scores[:, 0], 3))
