"""Distributed GBT training on a (data x model) device grid (paper §3.9):
example-parallel histogram psums + feature-parallel split exchange with
bit-packed partition broadcast, plus the single-process simulation backend
with a mid-training worker failure.

    PYTHONPATH=src python examples/distributed_forest.py
(spawns its own 8 placeholder devices; run unchanged on a real 256-chip pod)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core.distributed import DistGBTConfig, DistributedGBT, SimulatedCluster

rng = np.random.default_rng(0)
N, F = 4096, 16
codes = rng.integers(0, 64, (N, F)).astype(np.uint8)
logit = (0.9 * (codes[:, 0] > 30) - 1.1 * (codes[:, 3] > 45)
         + 0.6 * (codes[:, 5] > 10) * (codes[:, 8] > 20) - 0.2)
y = (rng.random(N) < 1 / (1 + np.exp(-logit))).astype(np.float64)

cfg = DistGBTConfig(max_depth=5, n_bins=64, num_trees=20)

print("== 2-D grid training (2 'data' x 4 'model' workers) ==")
mesh = jax.make_mesh((2, 4), ("data", "model"))
model = DistributedGBT(cfg, mesh).fit(codes, y)
acc = ((model.predict_scores(codes) > 0) == y).mean()
print(f"train accuracy: {acc:.4f} over {len(model.trees)} trees")

print("\n== equivalence with a single-worker run ==")
m1 = DistributedGBT(cfg, jax.make_mesh((1, 1), ("data", "model"))).fit(codes, y)
print("max |score diff|:",
      np.abs(m1.predict_scores(codes) - model.predict_scores(codes)).max())

print("\n== fault tolerance: checkpoint, interrupt mid-forest, resume ==")
import tempfile

from repro.train.checkpoint import CheckpointPolicy

ckdir = tempfile.mkdtemp()
calls = {"n": 0}
def _cancel():                      # simulate an interruption after 10 trees
    calls["n"] += 1
    return calls["n"] >= 10
half = DistributedGBT(cfg, mesh).fit(
    codes, y, checkpoint=CheckpointPolicy(ckdir, every_n_trees=5, cancel=_cancel))
print(f"interrupted at {len(half.trees)} trees "
      f"(servable: acc={((half.predict_scores(codes) > 0) == y).mean():.4f})")
resumed = DistributedGBT(cfg, mesh).fit(codes, y,
                                        checkpoint=CheckpointPolicy(ckdir))
print("resume == straight run:",
      np.allclose(resumed.predict_scores(codes), model.predict_scores(codes),
                  atol=1e-5))

print("\n== simulation backend (paper's third backend) + worker deaths ==")
from repro.core.distributed import WorkerFaultPlan

sim_clean = SimulatedCluster(codes, n_workers=8, cfg=cfg, seed=0).fit(y)
plan = WorkerFaultPlan(deaths=((2, 1, 3), (7, 0, 5)))  # die mid-level
sim_fault = SimulatedCluster(codes, n_workers=8, cfg=cfg, seed=0,
                             fault_plan=plan).fit(y)
same = all(np.array_equal(a[k], b[k])
           for a, b in zip(sim_clean.trees, sim_fault.trees) for k in a)
print("forest bit-identical despite 2 mid-level deaths:", same)
for ev in sim_fault.training_logs["resilience"]:
    print("  recovery event:", ev)
print(f"communication: {sim_fault.traffic_bytes} bytes "
      f"(candidates + 32x bit-packed partitions)")

print("\n== serve through the engine stack ==")
forest = model.to_forest([f"f{i}" for i in range(F)])
from repro.core.tree import aggregate_gbt, predict_raw
scores = aggregate_gbt(predict_raw(forest, codes[:8].astype(np.float32)), forest)
print("first scores:", np.round(scores[:, 0], 3))
