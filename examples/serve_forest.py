"""Serving walkthrough: train -> compile -> benchmark -> serve
(DESIGN.md §5; runs on CPU — the pallas engine uses interpret mode there).

    PYTHONPATH=src python examples/serve_forest.py
"""
import time

import numpy as np

from repro.core import GradientBoostedTreesLearner
from repro.core.engines import benchmark_inference
from repro.data.tabular import adult_like, train_test_split
from repro.serving.forest import MicroBatcher, make_forest_server

# 1. train (the serving story starts where quickstart.py ends)
train, test = train_test_split(adult_like(6000), 0.3, seed=1)
model = GradientBoostedTreesLearner(label="income", num_trees=60).train(train)
print(f"trained: {model.forest.n_trees} trees, "
      f"{model.forest.node_counts()['total_nodes']} nodes\n")

# 2. compile — one-time cost, then predict(batch) is end-to-end reusable:
#    encode tables (§5.1) + traversal closure + output head. Model.predict
#    builds and caches exactly this object on first call.
predictor = model.predictor()
print(f"compiled predictor: engine={predictor.name!r} "
      f"(compile {predictor.compile_s * 1e3:.0f} ms)")

# serving requests carry features only — no label column needed
request = {k: v for k, v in test.items() if k != "income"}
t0 = time.perf_counter()
probs = predictor.predict(request)
print(f"predict({len(probs)} rows) -> {(time.perf_counter() - t0) * 1e3:.1f} ms, "
      f"p(>50K)[:3] = {np.round(probs[:3, 1], 3)}\n")

# 3. benchmark every compatible engine at the serving shape; compile time is
#    reported separately because production pays it once (§5.1)
print(benchmark_inference(model, test, repetitions=3))
print()

# 4. serve: micro-batched request loop (§5.4) — accumulate ragged requests,
#    pad to a bucket, dispatch once, scatter results back per ticket
bundle = make_forest_server(model, buckets=(32, 128, 512))
batcher = MicroBatcher(bundle, max_batch=256)
tickets = []
for lo in range(0, 300, 17):  # 18 ragged requests of 17 rows
    req = {k: v[lo:lo + 17] for k, v in request.items()}
    tickets.append((batcher.submit(req), req))
batcher.flush()
ok = all(np.allclose(batcher.result(t), model.predict(r)) for t, r in tickets)
print(f"micro-batcher: {len(tickets)} requests -> {batcher.dispatches} "
      f"dispatch(es), {batcher.rows_dispatched} rows "
      f"(+{batcher.rows_padded} pad), per-request results correct: {ok}")
