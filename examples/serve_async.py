"""Fault-tolerant serving walkthrough: deadlines, admission control, engine
degradation, and the deterministic fault harness (DESIGN.md §9; CPU-runnable).

    PYTHONPATH=src python examples/serve_async.py
"""
import asyncio

import numpy as np

from repro.core import GradientBoostedTreesLearner, RandomForestLearner, Task
from repro.data.tabular import adult_like, train_test_split
from repro.serving import (
    AsyncForestServer,
    FakeClock,
    FaultPlan,
    ForestServer,
    RequestShed,
    RetryPolicy,
)

# 1. train two models — the server routes requests between them by name
train, test = train_test_split(adult_like(4000), 0.3, seed=1)
income = GradientBoostedTreesLearner(label="income", num_trees=30).train(train)
age = RandomForestLearner(label="age", task=Task.REGRESSION, num_trees=10,
                          max_depth=8).train(train)
request = {k: v for k, v in test.items() if k != "income"}

# 2. a ForestServer compiles a DEGRADATION CHAIN per model (primary engine
#    first, simpler fallbacks behind circuit breakers) and serves requests
#    under per-request deadlines with EWMA admission control
server = ForestServer({"income": income, "age": age},
                      buckets=(32, 128, 512), default_deadline_s=0.25,
                      retry=RetryPolicy(max_attempts=3, base_s=1e-3, seed=0),
                      failure_threshold=3, cooldown_s=0.1, warmup=True)
print("engine chains:",
      {m: [e["engine"] for e in server.engine_status(m)]
       for m in server.models()})

probs = server.predict({k: v[:5] for k, v in request.items()}, model="income")
years = server.predict({k: v[:5] for k, v in test.items()}, model="age")
print(f"routed: p(>50K)[:3]={np.round(probs[:3, 1], 3)}, "
      f"age[:3]={np.round(years[:3], 1)}\n")

# 3. async front-end: concurrent awaiters micro-batch into shared padded
#    dispatches; sheds and timeouts surface as typed exceptions per future
async def fan_in():
    async with AsyncForestServer(server, flush_interval_s=0.002) as aserver:
        jobs = [aserver.predict({k: v[i:i + 8] for k, v in request.items()},
                                model="income") for i in range(0, 160, 8)]
        return await asyncio.gather(*jobs, return_exceptions=True)

results = asyncio.run(fan_in())
ok = sum(isinstance(r, np.ndarray) for r in results)
print(f"async fan-in: {ok}/{len(results)} requests served "
      f"({server.metrics.dispatches} padded dispatches total)\n")

# 4. the deterministic fault harness: a seeded FaultPlan kills the primary
#    engine for a while. Watch the circuit open (traffic degrades to the
#    fallback engine — SAME bits), then a half-open probe restore it.
clock = FakeClock()
faulty = ForestServer(income, buckets=(32,), default_deadline_s=None,
                      failure_threshold=2, cooldown_s=1.0,
                      clock=clock.now, sleep=clock.sleep)
wrapper = faulty.inject_faults(FaultPlan(dead_from=0, dead_until=3))
req8 = {k: v[:8] for k, v in request.items()}
clean = income.predict(req8)
for step in range(5):
    out = faulty.predict(req8)
    assert np.array_equal(out, clean)      # degradation is invisible in bits
    state = faulty.engine_status()[0]["circuit"]
    print(f"  dispatch {step}: primary circuit={state:9s} "
          f"(primary calls so far: {wrapper.calls})")
    if state == "open":
        clock.advance(1.5)                 # cooldown -> half-open probe next
print()

# 5. overload: a slow engine (injected latency teaches the EWMA estimator a
#    real service rate) + deadlines the queue cannot meet -> requests are
#    SHED at admission (loud, cheap), not timed out after wasted work
faulty.inject_faults(FaultPlan(latency_rate=1.0, latency_s=0.05))
faulty.predict(req8)                       # EWMA learns ~50 ms / dispatch
shed = 0
for i in range(50):
    try:
        faulty.submit(req8, deadline_s=0.02, pump=False)
    except RequestShed:
        shed += 1
faulty.pump()
print(f"overload: {shed}/50 tight-deadline requests shed at admission\n")
print(faulty.metrics.summary())
