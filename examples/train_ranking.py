"""Learning-to-rank walkthrough: LambdaMART through the stock GBT grower
(DESIGN.md §12; the RANKING task is a loss, not a new engine).

    PYTHONPATH=src python examples/train_ranking.py
"""
import numpy as np

from repro.core import GradientBoostedTreesLearner, Task
from repro.core.evaluation import ndcg_at_k
from repro.data.tabular import grouped_relevance
from repro.serving.forest import MicroBatcher, make_forest_server
from repro.tasks import group_aware_split

# 1. a ranking dataset is a tabular dataset plus a "group" column (the
#    query id). grouped_relevance() plants a group-constant bias in the
#    graded labels that is NOT observable as a feature — pointwise
#    regression must fit through it; pairwise lambdas cancel it.
ds = grouped_relevance(n_groups=150, seed=7)
gid = np.asarray([int(v) for v in ds["group"]], np.int64)
rel = np.array([float(v) for v in ds["rel"]])

# 2. split by GROUP, never by row — a query straddling train/test leaks
tr_idx, te_idx = group_aware_split(gid, ratio=0.3, seed=99)
train = {k: v[tr_idx] for k, v in ds.items()}
test = {k: v[te_idx] for k, v in ds.items()}

# 3. task=RANKING routes the stock GBT grower through LambdaMARTLoss:
#    pairwise |delta-NDCG@k|-weighted gradients computed as ONE padded
#    (groups, max, max) pass (benchmarks/rank_bench.py measures it)
model = GradientBoostedTreesLearner(label="rel", task=Task.RANKING,
                                    num_trees=80, seed=1).train(train)
print(model.summary())

# 4. evaluate: NDCG@{1,5,10} through the task-aware evaluator, and the
#    same number recomputed directly to show there is no magic
ev = model.evaluate(test)
print(ev.report())
nd5 = ndcg_at_k(rel[te_idx], np.asarray(model.predict(test)),
                gid[te_idx], k=5)
assert abs(ev.metrics["ndcg@5"] - nd5) < 1e-12

# the pin from tests/test_tasks.py: the same trees trained pointwise
# (task=REGRESSION, group column dropped) rank measurably worse
reg = GradientBoostedTreesLearner(
    label="rel", task=Task.REGRESSION, num_trees=80, seed=1).train(
    {k: v for k, v in train.items() if k != "group"})
nd5_reg = ndcg_at_k(rel[te_idx], np.asarray(reg.predict(test)),
                    gid[te_idx], k=5)
print(f"\nNDCG@5: lambdamart={ev.metrics['ndcg@5']:.4f} "
      f"pointwise-regression={nd5_reg:.4f} "
      f"(gap {ev.metrics['ndcg@5'] - nd5_reg:+.4f})\n")

# 5. serve scores through the micro-batching front-end (§5.4): requests
#    carry features only; scores come back bit-identical to predict()
bundle = make_forest_server(model)
batcher = MicroBatcher(bundle, max_batch=256)
features = {k: v for k, v in test.items() if k not in ("rel", "group")}
tickets = [batcher.submit({k: v[i:i + 1] for k, v in features.items()})
           for i in range(32)]
batcher.flush()
served = np.concatenate([batcher.result(t) for t in tickets])
assert np.array_equal(served, np.asarray(model.predict(test))[:32])
print(f"served 32 single-row requests in {batcher.dispatches} padded "
      f"dispatch(es), bit-identical to predict()\n")

# 6. which features drive the ranking? permutation importances run the
#    squared-error scalar proxy over the ranking scores (§12.2)
report = model.analyze(test, permutation_repetitions=2)
top = report.importance("MEAN_INCREASE_RMSE").top(3)
print("top features by permutation importance:",
      [(e.feature, round(e.importance, 4)) for e in top])
