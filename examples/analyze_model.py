"""Analysis walkthrough: train -> evaluate -> analyze -> inspect
(DESIGN.md §8; the paper's third pillar — interpretation — served by the
compiled inference stack).

    PYTHONPATH=src python examples/analyze_model.py
"""
import time

from repro.analysis import permutation_importances
from repro.core import RandomForestLearner
from repro.data.tabular import adult_like, train_test_split

# 1. train a Random Forest; out-of-bag self-evaluation is on by default and
#    now surfaced in training_logs + summary() (previously unreachable)
train, test = train_test_split(adult_like(4000), 0.3, seed=1)
model = RandomForestLearner(label="income", num_trees=60,
                            max_depth=10).train(train)
oob = model.training_logs["oob"]
print(f"trained: {model.forest.n_trees} trees; out-of-bag "
      f"accuracy={oob['metrics']['accuracy']:.3f} over "
      f"{oob['n_examples']} examples "
      f"({oob['coverage']:.0%} coverage)\n")

# 2. evaluate through the cached CompiledPredictor; the report is kept so
#    model.save() writes evaluation.txt/.json beside summary.txt
evaluation = model.evaluate(test)
print(evaluation.report(), "\n")

# 3. analyze: structural importances (one vectorized SoA pass), permutation
#    importances (all permuted replicas stacked through the compiled
#    serving path), the OOB variant (bags regenerated from model.bag_info),
#    and partial-dependence sparklines — one report, text + JSON
t0 = time.perf_counter()
report = model.analyze(train, permutation_repetitions=3, grid_size=12)
print(f"analyze(train) in {time.perf_counter() - t0:.1f}s")
print(report.report(), "\n")

# the same report as a JSON-serializable dict (CLI: analyze --json)
payload = report.to_dict()
print("JSON payload keys:", sorted(payload))
top = report.importance("MEAN_DECREASE_ACCURACY").top(3)
print("top-3 by permutation importance:",
      [(e.feature, round(e.importance, 4)) for e in top], "\n")

# 4. the engines compose with the serving layer: route the same sweep
#    through a ForestServeBundle's padded buckets (§5.4 + §8.3)
from repro.serving.forest import make_forest_server
bundle = make_forest_server(model)
table, _ = permutation_importances(model, test, repetitions=2, bundle=bundle)
print("held-out permutation ranking via serving bundle:",
      table.ranking(), "\n")

# 5. interpretation meets the typed tree API (§7): the most important
#    feature, then the first levels of tree #0
print(model.summary(verbose=2))
