"""Interop walkthrough: train in scikit-learn -> import -> serve here
(DESIGN.md §7). The point of the typed tree API's import seam: any sklearn
forest gets this library's compiled serving stack — encode tables, the
vectorized/pallas engines, micro-batched dispatch — without retraining.

    PYTHONPATH=src python examples/interop_sklearn.py

Requires scikit-learn (optional dependency; the example explains and exits
cleanly when it is absent).
"""
import time

import numpy as np

try:
    from sklearn.ensemble import RandomForestClassifier
except ImportError:
    raise SystemExit("This example needs scikit-learn: pip install scikit-learn")

from repro.interop import from_sklearn
from repro.serving.forest import MicroBatcher, make_forest_server

# 1. train in sklearn — any existing pipeline, unchanged
rng = np.random.default_rng(0)
X = rng.normal(size=(4000, 8)).astype(np.float32)
y = (X[:, 0] + np.square(X[:, 1]) - 0.5 * X[:, 2] > 0.4).astype(int)
est = RandomForestClassifier(n_estimators=100, random_state=0).fit(X, y)
print(f"sklearn model: {type(est).__name__}, {len(est.estimators_)} trees")

# 2. import: typed trees -> Forest SoA + synthesized DataSpec. The model
#    predicts from raw feature dicts exactly like a natively-trained one.
model = from_sklearn(est, label="y")
print(f"imported -> {type(model).__name__}: "
      f"{model.forest.node_counts()['total_nodes']} nodes, "
      f"features {model.features}\n")

# 3. inspect it through the typed API
insp = model.inspect()
print("structure:", insp.stats_summary())
print("tree #0, first 3 levels:")
print(insp.plot_tree(0, max_depth=3), "\n")

# 4. prediction equivalence with the source estimator
X_test = rng.normal(size=(2000, 8)).astype(np.float32)
request = {f"f{i}": X_test[:, i] for i in range(8)}
ours = model.predict(request)
ref = est.predict_proba(X_test)
print(f"max |ours - sklearn.predict_proba| = {np.abs(ours - ref).max():.2e}")

# 5. serve through the compiled stack: bundle + micro-batcher (§5.4)
bundle = make_forest_server(model, "vectorized")
mb = MicroBatcher(bundle=bundle, max_batch=512)
t0 = time.perf_counter()
tickets = [mb.submit({k: v[i:i + 250] for k, v in request.items()})
           for i in range(0, 2000, 250)]
outs = np.concatenate([mb.result(t) for t in tickets])
dt = time.perf_counter() - t0
print(f"micro-batched serve: {len(outs)} rows in {dt * 1e3:.1f} ms "
      f"({mb.dispatches} dispatches, {mb.rows_padded} padded rows), "
      f"allclose={np.allclose(outs, ref, atol=1e-5)}")

# 6. sklearn's own batch predict, for scale
t0 = time.perf_counter()
est.predict_proba(X_test)
print(f"sklearn.predict_proba: {(time.perf_counter() - t0) * 1e3:.1f} ms "
      "(same rows, in-process)")
