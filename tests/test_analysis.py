"""Model-analysis subsystem (DESIGN.md §8): structural / permutation / OOB
variable importances, partial dependence, report objects, and the
batched-replica dispatch contract (stacked replicas through the compiled
serving path == a naive per-feature loop, bit for bit).
"""
import json
import os

import numpy as np
import pytest

from repro.analysis import (
    analyze_model,
    oob_permutation_importances,
    partial_dependence,
    permutation_importances,
    structural_importances,
)
from repro.analysis.importance import _permutation
from repro.analysis.report import sparkline
from repro.core import (
    CartLearner,
    GradientBoostedTreesLearner,
    RandomForestLearner,
    Task,
    YdfError,
)
from repro.core.dataspec import label_values
from repro.core.tree import node_depths

LEARNERS = {
    # ALL candidate attributes: per-node sqrt-sampling would randomize which
    # feature reaches the roots of a 10-tree forest, muddying min-depth ranks
    "rf": lambda label, task: RandomForestLearner(
        label=label, task=task, num_trees=10, max_depth=8,
        num_candidate_attributes="ALL"),
    "gbt": lambda label, task: GradientBoostedTreesLearner(
        label=label, task=task, num_trees=20, max_depth=4),
    "cart": lambda label, task: CartLearner(label=label, task=task),
}


def planted_dataset(n=700, noise_feats=4, task=Task.CLASSIFICATION, seed=0):
    """One informative feature (x0) + pure-noise features: every importance
    engine must put x0 first."""
    rng = np.random.default_rng(seed)
    x0 = rng.normal(size=n)
    data = {"x0": x0.astype(object)}
    for j in range(noise_feats):
        data[f"noise{j}"] = rng.normal(size=n).astype(object)
    if task == Task.CLASSIFICATION:
        y = np.where(x0 + 0.2 * rng.normal(size=n) > 0, "pos", "neg")
        data["label"] = y.astype(object)
    else:
        data["label"] = (3.0 * x0 + 0.1 * rng.normal(size=n)).astype(object)
    return data


@pytest.fixture(scope="module")
def planted_cls():
    return planted_dataset(task=Task.CLASSIFICATION)


@pytest.fixture(scope="module")
def planted_reg():
    return planted_dataset(task=Task.REGRESSION, seed=1)


@pytest.fixture(scope="module")
def rf_cls(planted_cls):
    return LEARNERS["rf"]("label", Task.CLASSIFICATION).train(planted_cls)


# ----------------------------------------------------- structural importances

@pytest.mark.parametrize("learner", ["rf", "gbt", "cart"])
@pytest.mark.parametrize("task", [Task.CLASSIFICATION, Task.REGRESSION])
def test_structural_planted_signal(learner, task, planted_cls, planted_reg):
    data = planted_cls if task == Task.CLASSIFICATION else planted_reg
    model = LEARNERS[learner]("label", task).train(data)
    vi = model.variable_importances()
    for kind in ("NUM_NODES", "SUM_SCORE", "INV_MEAN_MIN_DEPTH"):
        assert kind in vi, (learner, task, sorted(vi))
        best = max(vi[kind], key=vi[kind].get)
        assert best == "x0", (learner, task, kind, vi[kind])


def test_structural_matches_inspector_oracle(rf_cls):
    """The single vectorized SoA pass vs a typed-tree traversal oracle."""
    feats = rf_cls.features
    num_nodes = {f: 0.0 for f in feats}
    num_root = {f: 0.0 for f in feats}
    min_depth_sum = {f: 0.0 for f in feats}
    trees = rf_cls.inspect().trees()
    for tr in trees:
        tree_min = {}
        for node, d in tr.iter_nodes():
            if node.is_leaf:
                continue
            name = feats[node.condition.feature]
            num_nodes[name] += 1
            if d == 0:
                num_root[name] += 1
            tree_min[name] = min(tree_min.get(name, tr.depth), d)
        for f in feats:
            min_depth_sum[f] += tree_min.get(f, tr.depth)
    vi = rf_cls.variable_importances()
    assert vi["NUM_NODES"] == num_nodes
    assert vi["NUM_AS_ROOT"] == num_root
    for f in feats:
        inv = 1.0 / (1.0 + min_depth_sum[f] / len(trees))
        assert vi["INV_MEAN_MIN_DEPTH"][f] == pytest.approx(inv)


def test_split_gain_recorded_on_internal_nodes_only(rf_cls):
    forest = rf_cls.forest
    depth = node_depths(forest)
    internal = (forest.left_child >= 0) & (depth >= 0)
    assert (forest.split_gain[internal] > 0).any()
    assert not forest.split_gain[~internal].any()
    # truncation slices the gain table with the rest of the SoA
    assert forest.truncated(3).split_gain.shape[0] == 3


def test_structural_importances_with_oblique_splits(planted_cls):
    m = GradientBoostedTreesLearner(label="label", num_trees=4,
                                    template="benchmark_rank1").train(planted_cls)
    vi = m.variable_importances()
    assert sum(vi["NUM_NODES"].values()) > 0  # oblique nodes count features
    # oblique ROOTS credit their projected features too (table consistency)
    assert sum(vi["NUM_AS_ROOT"].values()) > 0


def test_node_depths_terminates_on_corrupt_back_edge():
    """A child back-edge (only py_tree validates DAGs) must terminate the
    structural pass, not loop forever like an unbounded frontier would."""
    from repro.core.tree import empty_forest
    f = empty_forest(1, 8, 1)
    f.feature[0, 0] = 0
    f.left_child[0, 0] = 1
    f.feature[0, 1] = 0
    f.left_child[0, 1] = 0          # points back at the root
    f.n_nodes[0] = 3
    f.depth = 2
    d = node_depths(f)
    assert d[0, 0] == 0 and d[0, 1] == 1 and d[0, 2] == 1
    f.node_counts()                  # must not hang either


# ---------------------------------------------------- permutation importances

@pytest.mark.parametrize("learner", ["rf", "gbt", "cart"])
@pytest.mark.parametrize("task", [Task.CLASSIFICATION, Task.REGRESSION])
def test_permutation_planted_signal(learner, task, planted_cls, planted_reg):
    data = planted_cls if task == Task.CLASSIFICATION else planted_reg
    model = LEARNERS[learner]("label", task).train(data)
    table, baseline = permutation_importances(model, data, repetitions=2)
    assert table.ranking()[0] == "x0"
    e = table.entries[0]
    assert e.importance > 0
    assert e.ci95[0] <= e.importance <= e.ci95[1]
    assert baseline.n_examples == len(data["label"])


def test_batched_replicas_equal_naive_per_feature_loop(rf_cls, planted_cls):
    """The stacked-replica dispatch must reproduce a naive python loop that
    predicts one permuted copy at a time — same permutations, same engine,
    identical scores."""
    model, data = rf_cls, planted_cls
    reps = 2
    table, baseline = permutation_importances(model, data, repetitions=reps,
                                              row_budget=1500)  # forces chunking
    pred = model.predictor()
    X = pred.encode(data)
    y = label_values(model, data)
    N = len(y)
    base_acc = float((np.asarray(pred.predict_encoded(X)).argmax(1) == y).mean())
    assert baseline["accuracy"] == pytest.approx(base_acc)
    for j, name in enumerate(model.features):
        drops = []
        for r in range(reps):
            Xp = X.copy()
            Xp[:, j] = X[_permutation(42, j, r, N), j]
            acc = float((np.asarray(pred.predict_encoded(Xp)).argmax(1) == y).mean())
            drops.append(base_acc - acc)
        assert table[name] == pytest.approx(np.mean(drops), abs=1e-12), name


def test_permutation_through_serving_bundle(rf_cls, planted_cls):
    from repro.serving.forest import make_forest_server
    bundle = make_forest_server(rf_cls, buckets=(64, 256))
    t_direct, _ = permutation_importances(rf_cls, planted_cls, repetitions=1)
    t_bundle, _ = permutation_importances(rf_cls, planted_cls, repetitions=1,
                                          bundle=bundle)
    for e in t_direct.entries:
        assert t_bundle[e.feature] == pytest.approx(e.importance, abs=1e-12)


def test_bundle_bulk_dispatch_matches_predictor(rf_cls, planted_cls):
    from repro.serving.forest import make_forest_server
    bundle = make_forest_server(rf_cls, buckets=(32, 128))
    X = rf_cls.predictor().encode(planted_cls)
    big = np.tile(X, (3, 1))  # > top bucket: chunked dispatch
    np.testing.assert_array_equal(
        bundle.predict_encoded_bulk(big),
        np.asarray(rf_cls.predictor().predict_encoded(big)))


# ------------------------------------------------------------ OOB importances

def test_oob_baseline_reproduces_training_self_evaluation(rf_cls, planted_cls):
    table, baseline = oob_permutation_importances(rf_cls, planted_cls)
    se = rf_cls.self_evaluation
    assert se is not None and se.source == "out-of-bag"
    assert baseline.n_examples == se.n_examples
    assert baseline["accuracy"] == pytest.approx(se["accuracy"])
    assert table.ranking()[0] == "x0"
    assert table.baseline == pytest.approx(se["accuracy"])


def test_oob_regression_planted_signal(planted_reg):
    m = RandomForestLearner(label="label", task=Task.REGRESSION,
                            num_trees=10, max_depth=8).train(planted_reg)
    table, baseline = oob_permutation_importances(m, planted_reg)
    assert table.ranking()[0] == "x0"
    assert baseline["rmse"] == pytest.approx(m.self_evaluation["rmse"])


def test_oob_requires_exact_training_dataset(rf_cls, planted_cls):
    small = {k: v[:100] for k, v in planted_cls.items()}
    with pytest.raises(YdfError, match="exact training dataset"):
        oob_permutation_importances(rf_cls, small)


def test_oob_rejects_same_size_different_content(rf_cls):
    """The content fingerprint catches what a row-count check cannot: a
    non-training dataset of exactly the training size."""
    other = planted_dataset(n=700, task=Task.CLASSIFICATION, seed=77)
    with pytest.raises(YdfError, match="different content"):
        oob_permutation_importances(rf_cls, other)
    rep = rf_cls.analyze(other, permutation_repetitions=1, sample_rows=32)
    assert all(t.kind != "OOB_MEAN_DECREASE_ACCURACY"
               for t in rep.importances)
    assert any("skipped" in n for n in rep.notes)


def test_analyze_oob_true_requires_labeled_dataset(rf_cls, planted_cls):
    with pytest.raises(YdfError, match="oob=True"):
        rf_cls.analyze(oob=True)
    feats_only = {k: v for k, v in planted_cls.items() if k != "label"}
    with pytest.raises(YdfError, match="absent"):
        rf_cls.analyze(feats_only, oob=True)


def test_analyze_forwards_repetitions_to_oob(rf_cls, planted_cls):
    rep = rf_cls.analyze(planted_cls, permutation_repetitions=2,
                         sample_rows=32, grid_size=4)
    assert rep.importance("OOB_MEAN_DECREASE_ACCURACY").repetitions == 2


def test_compile_predict_raw_empty_forest():
    from repro.core.tree import compile_predict_raw, empty_forest
    run = compile_predict_raw(empty_forest(3, 8, 1).truncated(0))
    assert run(np.zeros((5, 2), np.float32)).shape == (5, 0, 1)


def test_oob_requires_bag_info(planted_cls):
    m = RandomForestLearner(label="label", num_trees=4,
                            bootstrap=False).train(planted_cls)
    with pytest.raises(YdfError, match="bootstrap"):
        oob_permutation_importances(m, planted_cls)


# --------------------------------------------------------- partial dependence

def test_pdp_monotone_on_monotone_target():
    rng = np.random.default_rng(3)
    n = 800
    x0 = rng.uniform(-2, 2, n)
    data = {"x0": x0.astype(object),
            "noise0": rng.normal(size=n).astype(object),
            "label": (2.0 * x0).astype(object)}
    m = GradientBoostedTreesLearner(label="label", task=Task.REGRESSION,
                                    num_trees=60).train(data)
    [curve] = partial_dependence(m, data, features=["x0"], grid_size=12)
    c = curve.curve()
    span = c.max() - c.min()
    assert c[-1] > c[0] and span > 1.0
    assert (np.diff(c) >= -0.02 * span).all()  # monotone up to fit noise


def test_pdp_categorical_uses_vocab_labels(tiny_adult):
    m = RandomForestLearner(label="income", num_trees=5,
                            max_depth=6).train(tiny_adult)
    [curve] = partial_dependence(m, tiny_adult, features=["workclass"],
                                 grid_size=8, sample_rows=50)
    assert curve.semantic == "CATEGORICAL"
    vocab = m.spec["workclass"].vocab
    assert curve.labels and all(l in vocab for l in curve.labels)
    assert curve.mean.shape == (len(curve.grid), len(m.classes))
    assert curve.n_sample == 50


def test_pdp_ice_shapes(rf_cls, planted_cls):
    [curve] = partial_dependence(rf_cls, planted_cls, features=["x0"],
                                 grid_size=6, sample_rows=40, ice=True)
    g = len(curve.grid)
    assert curve.ice.shape == (g, 40, 2)
    np.testing.assert_allclose(curve.ice.mean(axis=1), curve.mean)


# ------------------------------------------------------------ report / API

def test_analyze_report_text_and_json(rf_cls, planted_cls):
    rep = rf_cls.analyze(planted_cls, permutation_repetitions=1,
                         sample_rows=64, grid_size=6)
    txt = rep.report()
    assert "MEAN_DECREASE_ACCURACY" in txt and "Partial dependence" in txt
    assert str(rep) == txt
    payload = json.loads(json.dumps(rep.to_dict()))
    kinds = [t["kind"] for t in payload["variable_importances"]]
    assert "NUM_NODES" in kinds and "OOB_MEAN_DECREASE_ACCURACY" in kinds
    assert payload["evaluation"]["metrics"]["accuracy"] > 0.5
    assert len(payload["partial_dependence"]) == len(rf_cls.features)
    # accessors
    assert rep.importance("NUM_NODES").ranking()[0] == "x0"
    assert rep.pdp_curve("x0").feature == "x0"


def test_analyze_structure_only(rf_cls):
    rep = rf_cls.analyze()
    assert rep.evaluation is None and not rep.pdp
    assert {t.kind for t in rep.importances} >= {"NUM_NODES", "SUM_SCORE"}


def test_analyze_without_label_skips_permutation(rf_cls, planted_cls):
    feats_only = {k: v for k, v in planted_cls.items() if k != "label"}
    rep = rf_cls.analyze(feats_only, sample_rows=32)
    assert rep.evaluation is None
    assert all(t.source == "structure" for t in rep.importances)
    assert rep.pdp and any("label" in n for n in rep.notes)


def test_evaluate_caches_and_save_writes_report(rf_cls, planted_cls, tmp_path):
    path = str(tmp_path / "m")
    rf_cls.save(path)
    assert not os.path.exists(os.path.join(path, "evaluation.txt"))
    ev = rf_cls.evaluate(planted_cls)
    rf_cls.save(path)
    with open(os.path.join(path, "evaluation.txt")) as f:
        assert f"accuracy: {ev['accuracy']:.6g}" in f.read()
    with open(os.path.join(path, "evaluation.json")) as f:
        assert json.load(f)["metrics"]["accuracy"] == ev["accuracy"]


def test_cli_analyze_and_evaluate_json(rf_cls, planted_cls, tmp_path, capsys):
    from repro.cli import main
    from repro.data.io import write_dataset
    mdir = str(tmp_path / "model")
    rf_cls.save(mdir)
    csv = "csv:" + str(tmp_path / "d.csv")
    write_dataset(planted_cls, csv)
    out_json = str(tmp_path / "report.json")
    main(["analyze", "--model", mdir, "--dataset", csv, "--repetitions", "1",
          "--sample", "32", "--output", out_json])
    with open(out_json) as f:
        payload = json.load(f)
    assert payload["label"] == "label"
    assert any(t["kind"] == "MEAN_DECREASE_ACCURACY"
               for t in payload["variable_importances"])
    main(["analyze", "--model", mdir])  # structural-only, text
    assert "NUM_NODES" in capsys.readouterr().out
    main(["evaluate", "--model", mdir, "--dataset", csv, "--json"])
    assert json.loads(capsys.readouterr().out)["metrics"]["accuracy"] > 0.5


def test_sparkline():
    assert sparkline([0, 1]) == "▁█"
    assert sparkline([1, 1, 1]) == "▁▁▁"
    assert sparkline([]) == ""
    assert len(sparkline(np.arange(10))) == 10


# --------------------------------------------------------------- slow matrix

@pytest.mark.slow
@pytest.mark.parametrize("engine", ["vectorized", "naive"])
def test_permutation_engine_agnostic(rf_cls, planted_cls, engine):
    """Importance scores are an engine-independent model property."""
    rf_cls.compile(engine)
    table, _ = permutation_importances(rf_cls, planted_cls, repetitions=1)
    rf_cls.compile("vectorized")
    ref, _ = permutation_importances(rf_cls, planted_cls, repetitions=1)
    for e in ref.entries:
        assert table[e.feature] == pytest.approx(e.importance, abs=1e-6)
