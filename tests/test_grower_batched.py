"""Batched-frontier training engine vs the seed-equivalent oracle (§2.3).

The "oracle" growth engine is the simple module — per-node partition loops,
full-N histogram rebuilds, example-major histogram accumulation. The
"batched" engine (vectorized apply_split, flattened-bincount leaf stats,
parent-minus-sibling histogram subtraction, pluggable histogram backend)
must produce bit-identical forests at equal seeds.
"""
import numpy as np
import pytest

from repro.core import GradientBoostedTreesLearner, RandomForestLearner, YdfError
from repro.core.api import Task
from repro.core.cart import CartLearner
from repro.core.hist_backend import (
    NumpyHistogramBackend,
    PallasHistogramBackend,
    SimpleHistogramBackend,
    resolve_backend,
)
from repro.data.tabular import SUITE, adult_like, make_dataset, train_test_split

FOREST_KEYS = ["feature", "threshold", "split_bin", "cat_mask", "left_child",
               "leaf_value", "n_nodes"]


def _assert_forests_identical(a, b, msg=""):
    for k in FOREST_KEYS:
        np.testing.assert_array_equal(getattr(a, k), getattr(b, k),
                                      err_msg=f"{msg}: forest.{k} differs")
    if a.obl_weights is not None and (a.feature == -2).any():
        np.testing.assert_array_equal(a.obl_weights, b.obl_weights, err_msg=msg)
        np.testing.assert_array_equal(a.obl_features, b.obl_features, err_msg=msg)


@pytest.fixture(scope="module")
def adult():
    return train_test_split(adult_like(900), 0.3, 1)[0]


# ---------------------------------------------------------------- engines

@pytest.mark.parametrize("hp", [
    dict(),                                               # LOCAL, CART cats
    dict(growing_strategy="BEST_FIRST_GLOBAL"),           # subtraction trick
    dict(categorical_algorithm="ONE_HOT"),
    dict(subsample=0.7, use_hessian_gain=True),           # bagging + dup stats
    dict(template="benchmark_rank1"),                     # oblique + RANDOM + bf
    # deep RANDOM cats: rng drift regression (pruning must stay disabled when
    # the splitter draws per-level randomness the oracle would still consume)
    dict(categorical_algorithm="RANDOM", max_depth=8),
])
def test_gbt_batched_bit_identical_to_oracle(adult, hp):
    kw = dict(label="income", num_trees=6)
    tmpl = hp.pop("template", None)
    mo = GradientBoostedTreesLearner(**kw, template=tmpl, growth_engine="oracle",
                                     **hp).train(adult)
    mb = GradientBoostedTreesLearner(**kw, template=tmpl, growth_engine="batched",
                                     **hp).train(adult)
    _assert_forests_identical(mo.forest, mb.forest, str(hp))


def test_rf_and_cart_batched_bit_identical_to_oracle(adult):
    for hp in (dict(num_trees=4, max_depth=10),           # sqrt feature mask
               dict(num_trees=3, growing_strategy="BEST_FIRST_GLOBAL",
                    max_num_nodes=128)):
        mo = RandomForestLearner(label="income", growth_engine="oracle",
                                 **hp).train(adult)
        mb = RandomForestLearner(label="income", growth_engine="batched",
                                 **hp).train(adult)
        _assert_forests_identical(mo.forest, mb.forest, str(hp))
    mo = CartLearner(label="income", growth_engine="oracle").train(adult)
    mb = CartLearner(label="income", growth_engine="batched").train(adult)
    _assert_forests_identical(mo.forest, mb.forest, "cart")


def test_rf_regression_batched_bit_identical(adult):
    train, _ = train_test_split(make_dataset(SUITE[7]), 0.3, SUITE[7].seed)
    mo = RandomForestLearner(label="label", task=Task.REGRESSION, num_trees=4,
                             max_depth=9, growth_engine="oracle").train(train)
    mb = RandomForestLearner(label="label", task=Task.REGRESSION, num_trees=4,
                             max_depth=9, growth_engine="batched").train(train)
    _assert_forests_identical(mo.forest, mb.forest, "rf_reg")


def test_unknown_engine_and_backend_raise(adult):
    with pytest.raises(YdfError, match="growth engine"):
        GradientBoostedTreesLearner(label="income", num_trees=1,
                                    growth_engine="warp").train(adult)
    with pytest.raises(YdfError, match="histogram_backend"):
        resolve_backend("cuda")


# ---------------------------------------------------------------- backends

def _random_mixed(seed, n=400, f_num=3, f_cat=3, s=4):
    """Mixed numerical/categorical codes with inactive (-1) examples and a
    duplicated stat column (the GBT hessian-gain-off layout)."""
    rng = np.random.default_rng(seed)
    codes = np.concatenate(
        [rng.integers(0, 256, (n, f_num)).astype(np.uint8),
         rng.integers(0, 9, (n, f_cat)).astype(np.uint8)], axis=1)
    g = rng.normal(size=n)
    w = rng.integers(0, 3, n).astype(np.float64)
    stats = np.stack([g * w, w, np.abs(g) * w, w], 1)[:, :s]
    node_of = rng.integers(-1, 5, n).astype(np.int32)
    return codes, stats, node_of


def test_numpy_backend_matches_simple_bitwise():
    """The vectorized feature-major bincount == the seed example-major pass."""
    for seed in range(5):
        codes, stats, node_of = _random_mixed(seed)
        a = SimpleHistogramBackend().build(codes, stats, node_of, 5)
        b = NumpyHistogramBackend().build(codes, stats, node_of, 5)
        np.testing.assert_array_equal(a, b)


def test_subtraction_trick_matches_direct_build():
    """parent - smaller child == directly-built sibling histogram."""
    codes, stats, node_of = _random_mixed(7, n=600)
    be = NumpyHistogramBackend()
    act = node_of >= 0
    idx = np.where(act)[0]
    parent = be.build(codes[idx], stats[idx], np.zeros(len(idx), np.int32), 1)
    go = codes[idx, 0] >= 128
    small, big = idx[~go], idx[go]
    if len(small) > len(big):
        small, big = big, small
    h_small = be.build(codes[small], stats[small],
                       np.zeros(len(small), np.int32), 1)
    h_big = be.build(codes[big], stats[big], np.zeros(len(big), np.int32), 1)
    np.testing.assert_allclose(parent - h_small, h_big, rtol=1e-9, atol=1e-9)
    # float32 gain-scan inputs are bit-identical in practice
    np.testing.assert_array_equal((parent - h_small).astype(np.float32),
                                  h_big.astype(np.float32))


def test_pallas_backend_matches_numpy():
    """histogram_pallas (interpret mode on CPU) == numpy backend on mixed
    data with inactive examples, including the n_nodes padding path."""
    codes, stats, node_of = _random_mixed(11, n=300)
    ref = NumpyHistogramBackend().build(codes, stats, node_of, 5)
    pal = PallasHistogramBackend(interpret=True).build(codes, stats, node_of, 5)
    assert pal.shape == ref.shape
    np.testing.assert_allclose(pal, ref, atol=1e-3, rtol=1e-4)


def test_backend_auto_resolution_is_hardware_aware():
    import jax
    be = resolve_backend("auto")
    want = "pallas" if jax.default_backend() == "tpu" else "numpy"
    assert be.name == want
    assert resolve_backend(be) is be  # instances pass through


@pytest.mark.slow
def test_training_with_pallas_backend_matches_numpy(adult):
    """End-to-end wiring: histogram_backend="pallas_interpret" (the explicit
    CPU opt-in) grows the same trees as the numpy backend up to f32
    accumulation. Plain "pallas" on a CPU host raises instead (tested in
    test_grower_device.py)."""
    small = {k: np.asarray(v)[:150] for k, v in adult.items()}
    kw = dict(label="income", num_trees=2, max_depth=3, validation_ratio=0.0,
              early_stopping="NONE")
    m_np = GradientBoostedTreesLearner(**kw, histogram_backend="numpy").train(small)
    m_pl = GradientBoostedTreesLearner(
        **kw, histogram_backend="pallas_interpret").train(small)
    f_np, f_pl = m_np.forest, m_pl.forest
    np.testing.assert_array_equal(f_np.feature, f_pl.feature)
    np.testing.assert_array_equal(f_np.split_bin, f_pl.split_bin)
    np.testing.assert_allclose(f_np.leaf_value, f_pl.leaf_value, atol=1e-5)
