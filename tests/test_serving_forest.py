"""The compiled serving stack (DESIGN.md §5): encode tables, predictor
lifecycle, depth-packing, micro-batching, and the inference benchmark."""
import pickle

import numpy as np
import pytest

import repro.core.models as M
from repro.core import GradientBoostedTreesLearner, RandomForestLearner, YdfError
from repro.core.dataspec import BatchEncoder
from repro.data.tabular import adult_like, train_test_split
from repro.serving.forest import ForestServeBundle, MicroBatcher, make_forest_server


@pytest.fixture(scope="module")
def trained():
    train, test = train_test_split(adult_like(900), 0.3, 1)
    gbt = GradientBoostedTreesLearner(label="income", num_trees=6).train(train)
    rf = RandomForestLearner(label="income", num_trees=4, max_depth=6).train(train)
    return gbt, rf, test


# ------------------------------------------------------------- encode (§5.1)

def test_batch_encoder_matches_per_call_path(trained):
    gbt, _, test = trained
    enc = BatchEncoder(gbt.spec, gbt.features)
    # inject unseen categories and missing values into a feature-only batch
    batch = {k: v.copy() for k, v in test.items() if k != "income"}
    batch["occupation"][3] = "Astronaut"     # out-of-dictionary -> code 0
    batch["occupation"][4] = None            # missing -> most-frequent code
    batch["age"][5] = None                   # missing numerical -> mean
    batch["age"][6] = "nan"
    ref_input = dict(batch)
    ref_input["income"] = test["income"]     # seed path needs all columns
    want = M.raw_matrix(M._as_vertical(ref_input, gbt.spec), gbt.features)
    got = enc.encode(batch)
    np.testing.assert_array_equal(got, want)
    # VerticalDataset input routes through raw_matrix unchanged
    ds = M._as_vertical(ref_input, gbt.spec)
    np.testing.assert_array_equal(enc.encode(ds), want)


def test_batch_encoder_reports_missing_columns(trained):
    gbt, _, test = trained
    enc = BatchEncoder(gbt.spec, gbt.features)
    with pytest.raises(YdfError, match="age"):
        enc.encode({k: v for k, v in test.items() if k not in ("age", "income")})


# -------------------------------------------------- predictor lifecycle (§5.1)

def test_predictor_is_cached_and_matches_predict(trained):
    for model in trained[:2]:
        test = trained[2]
        p = model.predictor()
        assert model.predictor() is p          # cached and reused
        direct = model.predict(test)
        np.testing.assert_allclose(p.predict(test), direct, atol=0)
        # label-free serving batches work (the per-call path required it)
        features_only = {k: v for k, v in test.items() if k != "income"}
        np.testing.assert_allclose(model.predict(features_only), direct, atol=0)


def test_predictor_engine_switch_and_equivalence(trained):
    gbt, _, test = trained
    base = gbt.predictor("vectorized").predict(test)
    pal = gbt.predictor("pallas")
    assert pal.name == "pallas"
    np.testing.assert_allclose(pal.predict(test), base, atol=1e-5)


def test_predictor_not_pickled(trained):
    gbt, _, test = trained
    gbt.predict(test)  # force-compile
    clone = pickle.loads(pickle.dumps(gbt))
    assert clone._predictor is None and clone._engine is None
    np.testing.assert_allclose(clone.predict(test), gbt.predict(test), atol=0)


# ---------------------------------------------------------- depth-pack (§5.3)

def test_pack_by_depth_invariants(random_forest_factory):
    from repro.core.tree import pack_by_depth, tree_depths
    forest = random_forest_factory(7, [2, 30, 150], 6, out_dim=2, seed=11)
    p = pack_by_depth(forest)
    assert p.max_nodes % 128 == 0
    assert p.n_blocks * p.trees_per_block >= forest.n_trees
    assert sorted(p.inv_order.tolist()) == list(range(forest.n_trees))
    # packed slots are depth-sorted: each block's bound covers its trees
    d = tree_depths(forest)
    slot_depth = np.zeros(p.n_blocks * p.trees_per_block, np.int32)
    slot_depth[p.inv_order] = d
    per_block = slot_depth.reshape(p.n_blocks, p.trees_per_block).max(1)
    assert (per_block <= p.block_depth[:, 0]).all()


# -------------------------------------------------------- micro-batch (§5.4)

def test_bundle_bucket_padding(trained):
    gbt, _, test = trained
    bundle = make_forest_server(gbt, buckets=(8, 32), warmup=False)
    assert bundle.bucket_for(3) == 8
    assert bundle.bucket_for(33) == 64   # multiples of the top bucket
    sub = {k: v[:13] for k, v in test.items()}
    np.testing.assert_allclose(bundle.predict(sub), gbt.predict(sub), atol=0)


def test_micro_batcher_accumulates_pads_dispatches(trained):
    gbt, _, test = trained
    bundle = make_forest_server(gbt, buckets=(16, 64), warmup=False)
    mb = MicroBatcher(bundle, max_batch=16)
    sizes = [5, 7, 20]
    reqs = [{k: v[sum(sizes[:i]):sum(sizes[:i + 1])] for k, v in test.items()
             if k != "income"} for i in range(len(sizes))]
    t0 = mb.submit(reqs[0])
    t1 = mb.submit(reqs[1])
    assert mb.dispatches == 0 and mb.pending_rows() == 12
    t2 = mb.submit(reqs[2])                 # 32 rows >= max_batch -> flush
    assert mb.dispatches == 1 and mb.pending_rows() == 0
    assert mb.rows_dispatched == 32 and mb.rows_padded == 32  # bucket 64
    for t, req in zip((t0, t1, t2), reqs):
        np.testing.assert_allclose(mb.result(t), gbt.predict(req), atol=0)
    # result() on a pending ticket flushes on demand (no deadlock)
    t3 = mb.submit(reqs[0])
    np.testing.assert_allclose(mb.result(t3), gbt.predict(reqs[0]), atol=0)
    assert mb.dispatches == 2
    with pytest.raises(KeyError):
        mb.result(t3)


def test_micro_batcher_evicts_abandoned_results(trained):
    gbt, _, test = trained
    bundle = make_forest_server(gbt, buckets=(16,), warmup=False)
    mb = MicroBatcher(bundle, max_batch=4, max_results=3)
    req = {k: v[:2] for k, v in test.items() if k != "income"}
    tickets = [mb.submit(req) for _ in range(6)]  # auto-flushes every 2 reqs
    mb.flush()
    # only the newest max_results survive; the oldest were abandoned
    assert len(mb._results) == 3
    with pytest.raises(KeyError):
        mb.result(tickets[0])
    np.testing.assert_allclose(mb.result(tickets[-1]), gbt.predict(req), atol=0)


def test_micro_batcher_bad_ticket_never_flushes(trained):
    """A never-issued or already-claimed ticket is the CALLER's bug: it must
    raise KeyError immediately, not force everyone else's pending work
    through a premature padded dispatch."""
    gbt, _, test = trained
    bundle = make_forest_server(gbt, buckets=(16,), warmup=False)
    mb = MicroBatcher(bundle, max_batch=64)
    req = {k: v[:3] for k, v in test.items() if k != "income"}
    t = mb.submit(req)
    for bad in (999, -1, "nope"):
        with pytest.raises(KeyError):
            mb.result(bad)
    assert mb.dispatches == 0 and mb.pending_rows() == 3   # queue untouched
    np.testing.assert_allclose(mb.result(t), gbt.predict(req), atol=0)
    assert mb.dispatches == 1
    with pytest.raises(KeyError):
        mb.result(t)                                       # already consumed
    assert mb.dispatches == 1                              # ...and no reflush


def test_zero_row_dispatch_returns_empty_shapes(trained):
    """An empty batch is a legal request: no phantom padding row, just a
    correctly-shaped (0, out_dim) — or (0,) for regression — result."""
    from repro.core import Task
    gbt, _, test = trained
    bundle = make_forest_server(gbt, buckets=(16,), warmup=False)
    assert bundle.padded_size(0) == 0
    empty = {k: v[:0] for k, v in test.items() if k != "income"}
    out = bundle.predict(empty)
    assert out.shape == (0, 2) and out.dtype == np.float32
    # regression head: trailing shape is scalar
    train, _ = train_test_split(adult_like(300), 0.3, 1)
    reg = RandomForestLearner(label="age", task=Task.REGRESSION, num_trees=3,
                              max_depth=5).train(train)
    reg_bundle = make_forest_server(reg, buckets=(16,), warmup=False)
    empty_reg = {k: v[:0] for k, v in train.items() if k != "age"}
    assert reg_bundle.predict(empty_reg).shape == (0,)
    # and a MicroBatcher ticket for an empty request resolves, shape intact
    mb = MicroBatcher(bundle, max_batch=64)
    t = mb.submit(empty)
    assert mb.result(t).shape == (0, 2)


# -------------------------------------------------------------- bench smoke

def test_infer_bench_smoke():
    from benchmarks import infer_bench
    res = infer_bench.run(rows=400, num_trees=3, reps=1, verbose=False,
                          sklearn_trees=5)
    assert res["benchmark"] == "infer_bench"
    # sklearn_import is recorded when scikit-learn is installed (optional)
    assert set(res["configs"]) - {"sklearn_import"} == {"gbt_adult",
                                                        "rf_adult"}
    for name in ("gbt_adult", "rf_adult"):
        cfg = res["configs"][name]
        a = cfg["after"]["vectorized"]
        assert a["allclose"] is True
        assert a["us_example"] > 0 and cfg["us_example_before"] > 0
        assert "compile_s" in a
    sk = res["configs"].get("sklearn_import")
    if sk is not None:
        assert sk["allclose"] is True
        assert sk["n_trees"] == 5 and sk["us_example_compiled"] > 0
    assert res["headline_speedup"] > 0
