import os

# Tests must see the single real CPU device (the 512-device override is
# dryrun.py-local, never global).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
