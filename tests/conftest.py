import os

# Tests must see the single real CPU device (the 512-device override is
# dryrun.py-local, never global).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def _make_random_forest(n_trees, n_splits_list, n_features, out_dim=1,
                        seed=0, cat_feats=(), chain=False):
    """Synthetic valid Forest (random leaf-splitting order): n_splits_list
    cycles per tree, so mixed entries build ragged-depth forests; entries
    over 2048 build >4096-node trees. cat_feats get random category masks.
    A 0-splits entry yields a single-leaf stump (random root leaf value).
    ``chain=True`` always splits the DEEPEST open leaf, so a tree with k
    splits has depth exactly k — deterministic-depth forests for the
    depth-bucketing tests (tree.plan_depth_buckets)."""
    from repro.core.tree import empty_forest

    M = 2 * max(n_splits_list) + 1
    f = empty_forest(n_trees, M, out_dim)
    rng = np.random.default_rng(seed)
    maxd = 0
    for t in range(n_trees):
        f.leaf_value[t, 0] = rng.normal(size=out_dim)  # stump fallback
        leaves = [(0, 0)]
        n_nodes = 1
        for _ in range(n_splits_list[t % len(n_splits_list)]):
            pick = (max(range(len(leaves)), key=lambda i: leaves[i][1])
                    if chain else int(rng.integers(len(leaves))))
            node, d = leaves.pop(pick)
            j = int(rng.integers(n_features))
            f.feature[t, node] = j
            if j in cat_feats:
                mask = rng.integers(0, 2 ** 32, size=f.cat_mask.shape[-1],
                                    dtype=np.uint64).astype(np.uint32)
                mask[0] |= 1  # never empty: empty mask means numerical
                f.cat_mask[t, node] = mask
            else:
                f.threshold[t, node] = rng.normal()
            f.left_child[t, node] = n_nodes
            f.leaf_value[t, n_nodes] = rng.normal(size=out_dim)
            f.leaf_value[t, n_nodes + 1] = rng.normal(size=out_dim)
            leaves += [(n_nodes, d + 1), (n_nodes + 1, d + 1)]
            n_nodes += 2
            maxd = max(maxd, d + 1)
        f.n_nodes[t] = n_nodes
    f.depth = maxd
    f.feature_names = [f"f{j}" for j in range(n_features)]
    return f


@pytest.fixture(scope="session")
def random_forest_factory():
    return _make_random_forest


# ----------------------------- forest zoo (traversal-strategy differentials)

@pytest.fixture(scope="session")
def depth_skewed_forest():
    """Mixed depth-2 / depth-12 chains: the shape the depth-bucketed engine
    exists for — shallow trees must stop early, deep trees must not."""
    return _make_random_forest(24, [2, 12], 6, seed=21, chain=True)


@pytest.fixture(scope="session")
def stump_forest():
    """Single-node trees only (boosted-stump shape): depth 0, the root IS
    the leaf. Exercises the scan's sentinel self-loop and leaf_path's
    empty-path scoring."""
    return _make_random_forest(17, [0], 4, seed=22)


@pytest.fixture(scope="session")
def all_categorical_forest():
    """Every split is a category-mask bit test (no numerical thresholds):
    the cat-code cast path with nothing to hide behind."""
    return _make_random_forest(12, [1, 3, 5], 4, seed=23,
                               cat_feats=(0, 1, 2, 3))


@pytest.fixture(scope="session")
def tiny_adult():
    """A small mixed-semantics training set shared by model-layer tests."""
    from repro.data.tabular import adult_like
    return adult_like(400, seed=3)
