"""Checkpointed, interruption-safe training (DESIGN.md §11).

The invariant under test is BIT-IDENTICAL RESUME: a run interrupted at any
tree boundary and resumed produces np.array_equal forest arrays and
byte-stable predictions vs an uninterrupted run — across {GBT, RF} x
{classification, regression} x {host batched, device} engines, plus CART's
grown/pruned two-stage boundary. The store itself is exercised adversarially:
corrupt/truncated checkpoints roll back to the previous good one, resuming
against the wrong dataset or config is rejected, retention honors keep_last.
The distributed simulation backend must survive seeded multi-death fault
plans with a forest bit-identical to the clean run.
"""
import os
import signal

import numpy as np
import pytest

from repro.core import GradientBoostedTreesLearner, RandomForestLearner
from repro.core.api import Task, YdfError
from repro.core.cart import CartLearner
from repro.data.tabular import adult_like
from repro.train.checkpoint import (
    CheckpointPolicy,
    CheckpointSession,
    checkpoint_name,
    latest_checkpoint,
    resume_training,
)

pytestmark = pytest.mark.resilience


def _cls_data():
    return adult_like(300, seed=5)


def _reg_data():
    rng = np.random.default_rng(7)
    x = rng.uniform(-3, 3, 400)
    z = rng.normal(size=400)
    y = np.sin(x) * 2 + 0.5 * z + rng.normal(scale=0.1, size=400)
    return {"x": x.astype(object), "z": z.astype(object),
            "y": y.astype(object)}


def _learner(kind, task, engine, **over):
    label = "income" if task == Task.CLASSIFICATION else "y"
    kw = dict(label=label, task=task, seed=11, growth_engine=engine,
              max_depth=3, num_trees=6)
    kw.update(over)
    if kind == "gbt":
        return GradientBoostedTreesLearner(**kw)
    # block = 2 so the 6-tree run has interior lockstep boundaries to
    # checkpoint/interrupt at (RF only checkpoints between blocks)
    kw.setdefault("tree_parallelism", 2)
    return RandomForestLearner(**kw)


def _cancel_after(n):
    calls = {"n": 0}

    def cancel():
        calls["n"] += 1
        return calls["n"] >= n
    return cancel


FOREST_ARRAYS = ("feature", "threshold", "split_bin", "cat_mask",
                 "left_child", "leaf_value", "n_nodes", "split_gain")


def assert_forests_bit_identical(a, b):
    assert a.n_trees == b.n_trees
    for k in FOREST_ARRAYS:
        np.testing.assert_array_equal(getattr(a, k), getattr(b, k), err_msg=k)


# ------------------------------------------------------------ kill & resume

@pytest.mark.parametrize("engine", ["batched", "device"])
@pytest.mark.parametrize("task", [Task.CLASSIFICATION, Task.REGRESSION],
                         ids=["cls", "reg"])
@pytest.mark.parametrize("kind", ["gbt", "rf"])
def test_kill_and_resume_bit_identical(kind, task, engine, tmp_path):
    ds = _cls_data() if task == Task.CLASSIFICATION else _reg_data()
    clean = _learner(kind, task, engine).train(ds)

    ckdir = str(tmp_path / "ck")
    # 2nd poll: GBT stops after tree 2, RF (block=2) after tree 4 — both
    # interior boundaries of the 6-tree run
    policy = CheckpointPolicy(ckdir, every_n_trees=2, keep_last=2,
                              cancel=_cancel_after(2))
    part = _learner(kind, task, engine).train(ds, checkpoint=policy)
    assert part.training_logs["interrupted"]
    # the truncated model is servable and strictly shorter than the full run
    assert 0 < part.forest.n_trees < clean.forest.n_trees
    assert np.isfinite(part.predict(ds)).all()

    resumed = resume_training(ckdir, ds)
    assert not resumed.training_logs["interrupted"]
    assert any(e["event"] == "resume"
               for e in resumed.training_logs["resilience"])
    assert_forests_bit_identical(clean.forest, resumed.forest)
    assert clean.predict(ds).tobytes() == resumed.predict(ds).tobytes()


def test_cart_grown_stage_resume(tmp_path):
    ds = _cls_data()
    clean = CartLearner(label="income", seed=11, max_depth=4).train(ds)
    ckdir = str(tmp_path / "ck")
    part = CartLearner(label="income", seed=11, max_depth=4).train(
        ds, checkpoint=CheckpointPolicy(ckdir, cancel=lambda: True))
    # interrupted between growth and pruning: servable, pruning pending
    assert part.training_logs["interrupted"]
    assert np.isfinite(part.predict(ds)).all()
    resumed = resume_training(ckdir, ds)
    assert_forests_bit_identical(clean.forest, resumed.forest)
    assert clean.predict(ds).tobytes() == resumed.predict(ds).tobytes()


def test_sigint_becomes_cooperative_interruption(tmp_path):
    """A SIGINT mid-training must not raise KeyboardInterrupt: the session
    captures it, training stops at the next tree boundary with a final
    checkpoint, and the resumed run is bit-identical to a clean one."""
    ds = _cls_data()
    clean = _learner("gbt", Task.CLASSIFICATION, "batched").train(ds)
    ckdir = str(tmp_path / "ck")
    before = signal.getsignal(signal.SIGINT)
    calls = {"n": 0}

    def fire_sigint():                       # delivered between boundaries
        calls["n"] += 1
        if calls["n"] == 2:
            os.kill(os.getpid(), signal.SIGINT)
        return False

    policy = CheckpointPolicy(ckdir, every_n_trees=2, cancel=fire_sigint)
    part = _learner("gbt", Task.CLASSIFICATION, "batched").train(
        ds, checkpoint=policy)             # must NOT raise
    assert part.training_logs["interrupted"]
    assert any(e["event"] == "signal"
               for e in part.training_logs["resilience"])
    # the pre-training handler is restored after the session
    assert signal.getsignal(signal.SIGINT) is before
    resumed = resume_training(ckdir, ds)
    assert_forests_bit_identical(clean.forest, resumed.forest)


def test_gbt_early_stopping_survives_resume(tmp_path):
    """Early-stopping bookkeeping (best_loss/best_t, the validation
    predictions) is part of the checkpoint closure: resuming mid-run must
    reproduce the clean run's best_t truncation exactly."""
    ds = _cls_data()
    kw = dict(label="income", seed=3, num_trees=40, max_depth=2,
              early_stopping="LOSS_INCREASE", early_stopping_patience=3,
              validation_ratio=0.2)
    clean = GradientBoostedTreesLearner(**kw).train(ds)
    ckdir = str(tmp_path / "ck")
    policy = CheckpointPolicy(ckdir, every_n_trees=3, cancel=_cancel_after(5))
    part = GradientBoostedTreesLearner(**kw).train(ds, checkpoint=policy)
    assert part.training_logs["interrupted"]
    resumed = resume_training(ckdir, ds)
    assert_forests_bit_identical(clean.forest, resumed.forest)
    assert clean.training_logs["valid_loss"] == resumed.training_logs["valid_loss"]


def test_resume_of_finished_run_returns_same_model(tmp_path):
    ds = _reg_data()
    ckdir = str(tmp_path / "ck")
    policy = CheckpointPolicy(ckdir, every_n_trees=2)
    first = _learner("rf", Task.REGRESSION, "batched").train(
        ds, checkpoint=policy)
    _, manifest, _ = latest_checkpoint(ckdir)
    assert manifest["done"]
    again = resume_training(ckdir, ds)     # grows nothing, rebuilds the model
    assert_forests_bit_identical(first.forest, again.forest)


# ------------------------------------------------------------ wall clock

class FakeClock:
    """Injectable monotonic clock: time advances only when told to."""

    def __init__(self):
        self.t = 0.0

    def advance(self, seconds):
        self.t += seconds

    def __call__(self):
        return self.t


def test_wall_clock_cadence_fires_at_boundaries(tmp_path):
    """every_seconds makes a save due by elapsed wall clock even when the
    tree cadence is far away; the timer resets AT the save, and nothing
    fires between boundaries (save() is only ever called at them)."""
    clk = FakeClock()
    pol = CheckpointPolicy(str(tmp_path / "ck"), every_n_trees=10**9,
                           every_seconds=5.0, clock=clk)
    sess = CheckpointSession(pol, config={"learner": "X"}, fingerprint="f")
    payload = {"trees": np.arange(3)}
    assert not sess.save(1, payload)          # 0.0s elapsed
    clk.advance(4.9)
    assert not sess.save(2, payload)          # 4.9s < 5.0s
    clk.advance(0.2)
    assert sess.save(3, payload)              # 5.1s since session open
    assert not sess.save(4, payload)          # timer reset by the save
    clk.advance(5.0)
    assert sess.save(5, payload)
    names = sorted(n for n in os.listdir(pol.directory) if "." not in n)
    assert names == [checkpoint_name(3), checkpoint_name(5)]


def test_wall_clock_and_tree_cadence_compose(tmp_path):
    """Either cadence being due triggers the save: trees without elapsed
    time, and elapsed time without trees."""
    clk = FakeClock()
    pol = CheckpointPolicy(str(tmp_path / "ck"), every_n_trees=3,
                           every_seconds=100.0, keep_last=10, clock=clk)
    sess = CheckpointSession(pol, config={"learner": "X"}, fingerprint="f")
    assert not sess.save(2, {})               # neither cadence due
    assert sess.save(3, {})                   # tree cadence
    clk.advance(100.0)
    assert sess.save(4, {})                   # wall clock, only 1 tree later
    assert not sess.save(5, {})


def test_wall_clock_policy_round_trips_through_manifest(tmp_path):
    """every_seconds survives the manifest so resume_training continues
    under the same wall-clock cadence — and the resumed run is still
    bit-identical to a clean one."""
    ds = _cls_data()
    clean = _learner("gbt", Task.CLASSIFICATION, "batched").train(ds)
    ckdir = str(tmp_path / "ck")
    policy = CheckpointPolicy(ckdir, every_n_trees=2, every_seconds=900.0,
                              cancel=_cancel_after(2))
    part = _learner("gbt", Task.CLASSIFICATION, "batched").train(
        ds, checkpoint=policy)
    assert part.training_logs["interrupted"]
    _, manifest, _ = latest_checkpoint(ckdir)
    assert manifest["policy"]["every_seconds"] == 900.0
    resumed = resume_training(ckdir, ds)
    assert_forests_bit_identical(clean.forest, resumed.forest)


def test_wall_clock_only_cadence_checkpoints_during_training(tmp_path):
    """Integration: tree cadence effectively off, FakeClock advanced via
    the cancel probe (polled at every boundary) — intermediate checkpoints
    appear purely from elapsed wall clock."""
    ds = _cls_data()
    clk = FakeClock()

    def tick():                                # one boundary ~= 0.6s
        clk.advance(0.6)
        return False

    ckdir = str(tmp_path / "ck")
    policy = CheckpointPolicy(ckdir, every_n_trees=10**9, every_seconds=1.0,
                              keep_last=10, cancel=tick, clock=clk)
    model = _learner("gbt", Task.CLASSIFICATION, "batched").train(
        ds, checkpoint=policy)
    saves = [e for e in model.training_logs["resilience"]
             if e["event"] == "checkpoint"]
    # 6 trees x 0.6s/boundary with a 1s cadence: interior saves happened
    # before the forced final one
    assert len(saves) >= 2
    assert any(not e["done"] for e in saves)


# ------------------------------------------------------------ store hardening

def test_corrupt_checkpoint_rolls_back_to_previous_good(tmp_path):
    ds = _cls_data()
    clean = _learner("gbt", Task.CLASSIFICATION, "batched").train(ds)
    ckdir = str(tmp_path / "ck")
    policy = CheckpointPolicy(ckdir, every_n_trees=1, keep_last=3,
                              cancel=_cancel_after(4))
    _learner("gbt", Task.CLASSIFICATION, "batched").train(
        ds, checkpoint=policy)
    names = sorted(n for n in os.listdir(ckdir) if "." not in n)
    assert len(names) == 3
    # truncate the newest state file mid-byte: sha1 mismatch on read
    newest = os.path.join(ckdir, names[-1], "state.pkl")
    with open(newest, "rb") as f:
        blob = f.read()
    with open(newest, "wb") as f:
        f.write(blob[: len(blob) // 2])

    resumed = resume_training(ckdir, ds)
    events = resumed.training_logs["resilience"]
    assert any(e["event"] == "rollback" and e["checkpoint"] == names[-1]
               for e in events)
    # evidence quarantined, never re-trusted
    assert os.path.isdir(os.path.join(ckdir, names[-1] + ".corrupt"))
    # ... and the run still finishes bit-identical from the previous good one
    assert_forests_bit_identical(clean.forest, resumed.forest)


def test_all_checkpoints_corrupt_is_a_clear_error(tmp_path):
    ds = _cls_data()
    ckdir = str(tmp_path / "ck")
    policy = CheckpointPolicy(ckdir, every_n_trees=2, cancel=_cancel_after(3))
    _learner("gbt", Task.CLASSIFICATION, "batched").train(
        ds, checkpoint=policy)
    for name in list(os.listdir(ckdir)):
        if "." in name:
            continue
        with open(os.path.join(ckdir, name, "manifest.json"), "w") as f:
            f.write("{ not json")
    with pytest.raises(YdfError, match="No valid checkpoint"):
        resume_training(ckdir, ds)


def test_wrong_dataset_is_rejected(tmp_path):
    ds = _cls_data()
    ckdir = str(tmp_path / "ck")
    policy = CheckpointPolicy(ckdir, every_n_trees=2, cancel=_cancel_after(3))
    _learner("gbt", Task.CLASSIFICATION, "batched").train(
        ds, checkpoint=policy)
    other = adult_like(300, seed=99)       # same shape, different rows
    with pytest.raises(YdfError, match="DIFFERENT dataset"):
        resume_training(ckdir, other)


def test_changed_config_is_rejected(tmp_path):
    ds = _cls_data()
    ckdir = str(tmp_path / "ck")
    policy = CheckpointPolicy(ckdir, every_n_trees=2, cancel=_cancel_after(3))
    _learner("gbt", Task.CLASSIFICATION, "batched").train(
        ds, checkpoint=policy)
    with pytest.raises(YdfError, match="different training configuration"):
        _learner("gbt", Task.CLASSIFICATION, "batched", num_trees=9).train(
            ds, checkpoint=CheckpointPolicy(ckdir))


def test_retention_keeps_last_k(tmp_path):
    ds = _reg_data()
    ckdir = str(tmp_path / "ck")
    policy = CheckpointPolicy(ckdir, every_n_trees=1, keep_last=2)
    _learner("rf", Task.REGRESSION, "batched", tree_parallelism=1).train(
        ds, checkpoint=policy)
    names = sorted(n for n in os.listdir(ckdir) if "." not in n)
    assert names == [checkpoint_name(5), checkpoint_name(6)]


# ------------------------------------------------------------ atomic save

def test_model_save_is_atomic_under_mid_write_crash(tmp_path, monkeypatch):
    ds = _cls_data()
    m1 = _learner("gbt", Task.CLASSIFICATION, "batched").train(ds)
    m2 = _learner("gbt", Task.CLASSIFICATION, "batched", num_trees=3).train(ds)
    target = str(tmp_path / "model")
    m1.save(target)

    from repro.core.api import Model
    orig = Model._write_model_dir

    def crash_mid_write(self, path):
        orig(self, path)
        os.remove(os.path.join(path, "model.pkl"))   # torn state in the tmp
        raise RuntimeError("simulated crash mid-save")

    monkeypatch.setattr(Model, "_write_model_dir", crash_mid_write)
    with pytest.raises(RuntimeError):
        m2.save(target)
    monkeypatch.undo()
    # the target still holds the COMPLETE previous model, and no tmp junk
    loaded = Model.load(target)
    assert loaded.forest.n_trees == m1.forest.n_trees
    assert not [n for n in os.listdir(tmp_path) if ".tmp-" in n]


def test_model_save_refuses_to_clobber_foreign_directory(tmp_path):
    ds = _cls_data()
    m = _learner("gbt", Task.CLASSIFICATION, "batched", num_trees=2).train(ds)
    victim = tmp_path / "precious"
    victim.mkdir()
    (victim / "thesis.txt").write_text("years of work")
    with pytest.raises(YdfError, match="Refusing to overwrite"):
        m.save(str(victim))
    assert (victim / "thesis.txt").read_text() == "years of work"


def test_model_save_overwrites_previous_model_in_place(tmp_path):
    ds = _cls_data()
    m1 = _learner("gbt", Task.CLASSIFICATION, "batched", num_trees=2).train(ds)
    m2 = _learner("gbt", Task.CLASSIFICATION, "batched").train(ds)
    from repro.core.api import Model
    target = str(tmp_path / "model")
    m1.save(target)
    m2.save(target)                        # replacing a model dir is allowed
    assert Model.load(target).forest.n_trees == m2.forest.n_trees


# ------------------------------------------------------------ distributed

def _sim_setup(num_trees=8):
    from repro.core.distributed import DistGBTConfig
    rng = np.random.default_rng(1)
    N, F = 512, 6
    codes = rng.integers(0, 32, (N, F)).astype(np.uint8)
    y = (codes[:, 1] > 15).astype(np.float64)
    cfg = DistGBTConfig(max_depth=3, n_bins=32, num_trees=num_trees)
    return codes, y, cfg


def _trees_equal(a, b):
    return len(a) == len(b) and all(
        np.array_equal(ta[k], tb[k]) for ta, tb in zip(a, b) for k in ta)


def test_simulated_cluster_multi_death_soak_bit_identical():
    """Seeded FaultPlan soak: scheduled + Bernoulli worker deaths across the
    run (>= 2 of them, some mid-level) must leave the forest bit-identical
    to the clean run — deaths only cost a level restart, never accuracy."""
    from repro.core.distributed import SimulatedCluster, WorkerFaultPlan
    codes, y, cfg = _sim_setup()
    clean = SimulatedCluster(codes, 6, cfg, seed=0).fit(y)

    plan = WorkerFaultPlan(seed=5, deaths=((1, 1, 0), (4, 2, 3)),
                           death_rate=0.02)
    faulted = SimulatedCluster(codes, 6, cfg, seed=0, fault_plan=plan).fit(y)
    deaths = [e for e in faulted.training_logs["resilience"]
              if e["event"] == "worker_death"]
    restarts = [e for e in faulted.training_logs["resilience"]
                if e["event"] == "level_restart"]
    assert len(deaths) >= 2 and restarts
    assert _trees_equal(clean.trees, faulted.trees)
    assert clean.predict_scores(codes).tobytes() == \
        faulted.predict_scores(codes).tobytes()


def test_simulated_cluster_checkpoint_resume(tmp_path):
    from repro.core.distributed import SimulatedCluster
    codes, y, cfg = _sim_setup()
    clean = SimulatedCluster(codes, 4, cfg, seed=0).fit(y)
    ckdir = str(tmp_path / "ck")
    part = SimulatedCluster(codes, 4, cfg, seed=0).fit(
        y, checkpoint=CheckpointPolicy(ckdir, every_n_trees=2,
                                       cancel=_cancel_after(3)))
    assert part.training_logs["interrupted"]
    assert 0 < len(part.trees) < cfg.num_trees
    resumed = SimulatedCluster(codes, 4, cfg, seed=0).fit(
        y, checkpoint=CheckpointPolicy(ckdir))
    assert _trees_equal(clean.trees, resumed.trees)


def test_simulated_cluster_wrong_data_rejected(tmp_path):
    from repro.core.distributed import SimulatedCluster
    codes, y, cfg = _sim_setup()
    ckdir = str(tmp_path / "ck")
    SimulatedCluster(codes, 4, cfg, seed=0).fit(
        y, checkpoint=CheckpointPolicy(ckdir, every_n_trees=2,
                                       cancel=_cancel_after(3)))
    with pytest.raises(YdfError, match="DIFFERENT dataset"):
        SimulatedCluster(codes, 4, cfg, seed=0).fit(
            1.0 - y, checkpoint=CheckpointPolicy(ckdir))


def test_learner_resume_refuses_trainer_checkpoint(tmp_path):
    """A SimulatedCluster checkpoint has no 'learner' key: the generic
    resume_training entry point must reject it with directions instead of
    crashing into make_learner."""
    from repro.core.distributed import SimulatedCluster
    codes, y, cfg = _sim_setup()
    ckdir = str(tmp_path / "ck")
    SimulatedCluster(codes, 4, cfg, seed=0).fit(
        y, checkpoint=CheckpointPolicy(ckdir, every_n_trees=2,
                                       cancel=_cancel_after(3)))
    with pytest.raises(YdfError, match="not written by a Learner"):
        resume_training(ckdir, _cls_data())


# ------------------------------------------------------------ CLI

def test_cli_train_checkpoint_and_resume(tmp_path, capsys):
    from repro.cli import main
    from repro.data.io import write_dataset
    ds = _cls_data()
    csv_path = f"csv:{tmp_path}/train.csv"
    write_dataset(ds, csv_path)

    ckdir = str(tmp_path / "ck")
    out1 = str(tmp_path / "m1")
    main(["train", "--dataset", csv_path, "--label", "income",
          "--learner", "GRADIENT_BOOSTED_TREES", "--seed", "11",
          "--hparam", "num_trees=4", "--hparam", "max_depth=3",
          "--output", out1, "--checkpoint-dir", ckdir,
          "--checkpoint-every", "2"])
    assert os.path.isdir(ckdir) and os.listdir(ckdir)

    out2 = str(tmp_path / "m2")
    main(["train", "--dataset", csv_path, "--label", "income",
          "--resume", ckdir, "--output", out2])
    assert "resumed from" in capsys.readouterr().out
    from repro.core import Model
    m1, m2 = Model.load(out1), Model.load(out2)
    assert_forests_bit_identical(m1.forest, m2.forest)
