"""End-to-end system behaviour: the paper's §4 usage flow (dataspec -> train
-> evaluate -> predict -> benchmark) through the public API, CSV round-trip
included, plus the cross-API training-config path (§3.10)."""
import numpy as np

from repro.core import (
    GradientBoostedTreesLearner,
    Model,
    Task,
    get_learner,
    list_learners,
    make_learner,
)
from repro.core.dataspec import infer_dataspec
from repro.core.engines import benchmark_inference
from repro.data.io import read_dataset, write_dataset
from repro.data.tabular import adult_like, train_test_split


def test_cli_like_flow(tmp_path):
    """Mirrors the paper's §4.1 CLI sequence end to end."""
    train, test = train_test_split(adult_like(1500), 0.3, 3)
    write_dataset(train, f"csv:{tmp_path}/train.csv")
    write_dataset(test, f"csv:{tmp_path}/test.csv")

    # infer_dataspec + show_dataspec
    train_csv = read_dataset(f"csv:{tmp_path}/train.csv")
    spec = infer_dataspec(train_csv)
    rep = spec.report()
    assert "income" in rep and "NUMERICAL" in rep

    # train
    learner = GradientBoostedTreesLearner(label="income", num_trees=20)
    model = learner.train(train_csv)

    # show_model
    summary = model.summary()
    assert "GRADIENT" in summary.upper() and "Variable Importance" in summary

    # evaluate (report with CI, App. B.3 style)
    test_csv = read_dataset(f"csv:{tmp_path}/test.csv")
    ev = model.evaluate(test_csv)
    assert ev["accuracy"] > 0.75
    assert "CI95" in ev.report()

    # predict -> csv
    pred = model.predict(test_csv)
    write_dataset({"p_le50k": pred[:, 0], "p_gt50k": pred[:, 1]},
                  f"csv:{tmp_path}/predictions.csv")
    back = read_dataset(f"csv:{tmp_path}/predictions.csv")
    assert len(back["p_gt50k"]) == len(test_csv["income"])

    # benchmark_inference (App. B.4)
    rep = benchmark_inference(model, test_csv, repetitions=1)
    assert "us/example" in rep

    # save / load roundtrip through the Model registry
    model.save(str(tmp_path / "model"))
    m2 = Model.load(str(tmp_path / "model"))
    np.testing.assert_array_equal(model.predict(test_csv), m2.predict(test_csv))


def test_learner_registry_and_cross_api_config():
    assert {"GRADIENT_BOOSTED_TREES", "RANDOM_FOREST", "CART",
            "LINEAR"} <= set(list_learners())
    cfg = {"learner": "GRADIENT_BOOSTED_TREES", "label": "income",
           "task": "CLASSIFICATION", "seed": 7, "hparams": {"num_trees": 5}}
    learner = make_learner(cfg)
    assert learner.hparams.num_trees == 5
    # train_config roundtrip (cross-API compatibility, §3.10)
    cfg2 = learner.train_config()
    learner2 = make_learner(cfg2)
    train, test = train_test_split(adult_like(500), 0.3, 1)
    m1, m2 = learner.train(train), learner2.train(train)
    np.testing.assert_array_equal(m1.predict(test), m2.predict(test))
