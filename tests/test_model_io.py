"""Model persistence satellites (ISSUE 4): save→load→predict round-trip
matrix, inspectable save artefacts, robust load errors, fail-fast
predict_class, and hyper-parameter template wiring."""
import json
import os

import numpy as np
import pytest

from repro.core import (
    CartLearner,
    GradientBoostedTreesLearner,
    Model,
    RandomForestLearner,
    Task,
    YdfError,
    make_learner,
)


def _learners():
    return [
        ("rf_cls", RandomForestLearner, Task.CLASSIFICATION,
         dict(num_trees=4, max_depth=4, compute_oob=False)),
        ("rf_reg", RandomForestLearner, Task.REGRESSION,
         dict(num_trees=4, max_depth=4, compute_oob=False)),
        ("gbt_cls", GradientBoostedTreesLearner, Task.CLASSIFICATION,
         dict(num_trees=4, max_depth=3)),
        ("gbt_reg", GradientBoostedTreesLearner, Task.REGRESSION,
         dict(num_trees=4, max_depth=3)),
        ("cart_cls", CartLearner, Task.CLASSIFICATION, dict(max_depth=4)),
        ("cart_reg", CartLearner, Task.REGRESSION, dict(max_depth=4)),
    ]


@pytest.fixture(scope="module")
def reg_data(tiny_adult):
    data = dict(tiny_adult)
    rng = np.random.default_rng(5)
    data["target"] = rng.normal(size=len(data["age"])).astype(object)
    return data


@pytest.mark.parametrize("name,cls,task,hp", _learners(),
                         ids=[l[0] for l in _learners()])
def test_save_load_predict_roundtrip_matrix(tmp_path, tiny_adult, reg_data,
                                            name, cls, task, hp):
    data = tiny_adult if task == Task.CLASSIFICATION else reg_data
    label = "income" if task == Task.CLASSIFICATION else "target"
    model = cls(label=label, task=task, **hp).train(data)
    before = np.asarray(model.predict(data))
    path = str(tmp_path / name)
    model.save(path)
    loaded = Model.load(path)
    # predictors are runtime artifacts: the load starts cold and recompiles
    assert loaded._predictor is None
    after = np.asarray(loaded.predict(data))
    assert loaded._predictor is not None
    np.testing.assert_array_equal(before, after)  # byte-stable predictions


def test_save_writes_inspectable_artifacts(tmp_path, tiny_adult):
    from repro.core.dataspec import spec_from_dict
    model = CartLearner(label="income", max_depth=3).train(tiny_adult)
    path = str(tmp_path / "m")
    model.save(path)
    assert sorted(os.listdir(path)) == ["dataspec.json", "header.json",
                                        "model.pkl", "summary.txt"]
    text = open(os.path.join(path, "summary.txt")).read()
    assert "CartModel" in text and '"income"' in text
    with open(os.path.join(path, "dataspec.json")) as f:
        spec = spec_from_dict(json.load(f))
    assert set(spec.columns) == set(model.spec.columns)
    assert spec["income"].vocab == model.spec["income"].vocab


def test_load_missing_and_corrupt_headers_raise_ydf_errors(tmp_path):
    with pytest.raises(YdfError, match="missing 'header.json'"):
        Model.load(str(tmp_path / "nowhere"))
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "header.json").write_text("{not json")
    with pytest.raises(YdfError, match="corrupt"):
        Model.load(str(bad))
    keyless = tmp_path / "keyless"
    keyless.mkdir()
    (keyless / "header.json").write_text('{"class": "X"}')
    with pytest.raises(YdfError, match="format_version"):
        Model.load(str(keyless))
    nopkl = tmp_path / "nopkl"
    nopkl.mkdir()
    (nopkl / "header.json").write_text('{"format_version": 1}')
    with pytest.raises(YdfError, match="model.pkl"):
        Model.load(str(nopkl))


def test_predict_class_checks_task_before_predicting(tiny_adult, reg_data):
    model = CartLearner(label="target", task=Task.REGRESSION,
                        max_depth=3).train(reg_data)

    calls = []
    original = type(model).predict

    def spy(self, dataset):
        calls.append(1)
        return original(self, dataset)

    type(model).predict = spy
    try:
        with pytest.raises(YdfError, match="classification"):
            model.predict_class(reg_data)
    finally:
        type(model).predict = original
    assert not calls  # the task check must fire BEFORE any inference


# ------------------------------------------------------------- templates

def test_template_applies_before_explicit_overrides():
    l = GradientBoostedTreesLearner(label="y", template="benchmark_rank1",
                                    split_axis="AXIS_ALIGNED", num_trees=7)
    # template sets BEST_FIRST_GLOBAL+SPARSE_OBLIQUE; explicit override wins
    assert l.hparams.growing_strategy == "BEST_FIRST_GLOBAL"
    assert l.hparams.split_axis == "AXIS_ALIGNED"
    assert l.hparams.num_trees == 7
    assert l.template == "benchmark_rank1"


def test_template_round_trips_through_train_config():
    l = RandomForestLearner(label="y", template="benchmark_rank1",
                            num_trees=9)
    cfg = l.train_config()
    assert cfg["template"] == "benchmark_rank1"
    l2 = make_learner(cfg)
    assert l2.hparams == l.hparams
    assert l2.template == l.template
    # no template -> key absent, still round-trips
    l3 = RandomForestLearner(label="y", num_trees=9)
    cfg3 = l3.train_config()
    assert "template" not in cfg3
    assert make_learner(cfg3).hparams == l3.hparams


def test_unknown_template_raises():
    with pytest.raises(YdfError, match="Unknown hyper-parameter template"):
        CartLearner(label="y", template="benchmark_rank1")
