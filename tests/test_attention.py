"""Flash-chunked attention vs the O(S^2) oracle; decode vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.attention import (
    decode_attention,
    flash_attention,
    reference_attention,
)
from repro.models.layers import Ctx


def _qkv(key, B, S, H, KV, D, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, D), dtype)
    k = jax.random.normal(k2, (B, S, KV, D), dtype)
    v = jax.random.normal(k3, (B, S, KV, D), dtype)
    return q, k, v


@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_reference(H, KV, causal):
    B, S, D = 2, 64, 16
    cfg = ModelConfig(attn_chunk_q=16, attn_chunk_kv=16)
    ctx = Ctx(cfg)
    q, k, v = _qkv(jax.random.key(0), B, S, H, KV, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out = flash_attention(q, k, v, pos, pos, ctx, causal=causal)
    ref = reference_attention(q, k, v, pos, pos, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_prefix_lm_mask():
    B, S, H, KV, D = 1, 32, 2, 2, 8
    cfg = ModelConfig(attn_chunk_q=8, attn_chunk_kv=8)
    ctx = Ctx(cfg)
    q, k, v = _qkv(jax.random.key(1), B, S, H, KV, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out = flash_attention(q, k, v, pos, pos, ctx, causal=True, prefix_len=8)
    ref = reference_attention(q, k, v, pos, pos, causal=True, prefix_len=8)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_causal_skip_variant_matches_dense():
    B, S, H, KV, D = 2, 64, 4, 2, 16
    ctx_d = Ctx(ModelConfig(attn_chunk_q=16, attn_chunk_kv=16, attn_impl="chunked"))
    ctx_s = Ctx(ModelConfig(attn_chunk_q=16, attn_chunk_kv=16,
                            attn_impl="chunked_causal_skip"))
    q, k, v = _qkv(jax.random.key(2), B, S, H, KV, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    dense = flash_attention(q, k, v, pos, pos, ctx_d, causal=True)
    skip = flash_attention(q, k, v, pos, pos, ctx_s, causal=True)
    np.testing.assert_allclose(skip, dense, atol=2e-5, rtol=2e-5)


def test_non_divisible_chunking():
    """S=50 with chunk 16 -> divisor fallback must still be exact."""
    B, S, H, KV, D = 1, 50, 2, 1, 8
    ctx = Ctx(ModelConfig(attn_chunk_q=16, attn_chunk_kv=16))
    q, k, v = _qkv(jax.random.key(3), B, S, H, KV, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out = flash_attention(q, k, v, pos, pos, ctx, causal=True)
    ref = reference_attention(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_decode_matches_full_attention():
    """One-token decode over a cache == last row of full attention."""
    B, S, H, KV, D = 2, 24, 4, 2, 8
    ctx = Ctx(ModelConfig())
    q, k, v = _qkv(jax.random.key(4), B, S, H, KV, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = reference_attention(q, k, v, pos, pos, causal=True)
    # cache with padding beyond S
    Smax = 32
    kc = jnp.pad(k, ((0, 0), (0, Smax - S), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, Smax - S), (0, 0), (0, 0)))
    out = decode_attention(q[:, -1:], kc, vc, jnp.full((B,), S - 1), ctx)
    np.testing.assert_allclose(out[:, 0], full[:, -1], atol=2e-5, rtol=2e-5)
