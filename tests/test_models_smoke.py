"""Per-arch smoke tests (assignment requirement): reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs; decode path
consistency against prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, smoke_config
from repro.configs.base import ShapeConfig
from repro.models import lm
from repro.models.layers import Ctx
from repro.models.params import init_params
from repro.train import init_train_state, make_train_step

SHAPE = ShapeConfig("smoke", "train", 64, 2)
ARCHS = list_archs()


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCHS:
        cfg = smoke_config(get_arch(name))
        params = init_params(jax.random.key(0), lm.model_schema(cfg),
                             cfg.param_dtype)
        out[name] = (cfg, params)
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_shapes_and_finite(name, built):
    cfg, _ = built[name]
    state = init_train_state(jax.random.key(0), cfg)
    bundle = make_train_step(cfg, SHAPE)
    batch = lm.make_batch(jax.random.key(1), cfg, SHAPE)
    state2, m = jax.jit(bundle.step_fn)(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(state2["step"]) == 1
    # loss ~ ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(m["loss"]) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("name", ARCHS)
def test_loss_decreases(name, built):
    cfg, _ = built[name]
    state = init_train_state(jax.random.key(0), cfg)
    bundle = make_train_step(cfg, SHAPE)
    step = jax.jit(bundle.step_fn)
    batch = lm.make_batch(jax.random.key(1), cfg, SHAPE)
    first = None
    for _ in range(4):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first


@pytest.mark.parametrize("name", ARCHS)
def test_decode_consistent_with_prefill(name, built):
    """prefill(S) then decode_step == forward(S+1) last-token logits.

    MoE archs need ample capacity: with real capacity limits, token dropping
    is context-dependent (grouping differs between prefill and decode), so
    exact equality only holds when nothing is dropped."""
    cfg, params = built[name]
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=16.0)
    ctx = Ctx(cfg)
    B, S = 2, 32
    shape = ShapeConfig("p", "prefill", S, B)
    batch = lm.make_batch(jax.random.key(2), cfg, shape)
    logits_p, cache = lm.prefill(params, batch, ctx)
    next_tok = jnp.argmax(logits_p, -1).astype(jnp.int32)[:, None]

    # grow cache and decode one step
    total = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    full_cache = lm.init_cache(cfg, B, total + 8)
    from repro.serving.decode import _embed_cache
    cache = jax.tree.map(_embed_cache, full_cache, cache)
    logits_d, _ = lm.decode_step(params, {"token": next_tok}, cache, ctx)

    # reference: full forward over S+1 tokens
    batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], next_tok], 1))
    h, _, _ = lm.forward(params, batch2, ctx)
    from repro.models.layers import logits_last, unembed_matrix
    ref = logits_last(h[:, -1, :], unembed_matrix(params["embed"], ctx), ctx)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_greedy_generate_runs():
    cfg = smoke_config(get_arch("qwen2-1.5b"))
    params = init_params(jax.random.key(0), lm.model_schema(cfg), cfg.param_dtype)
    shape = ShapeConfig("p", "prefill", 16, 2)
    batch = lm.make_batch(jax.random.key(1), cfg, shape)
    from repro.serving.decode import greedy_generate
    toks = greedy_generate(params, batch, cfg, 4)
    assert toks.shape == (2, 4)
    assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < cfg.vocab_size).all()


def test_fp8_kv_cache_decode_close_to_bf16(built):
    """float8 KV cache (beyond-paper memory lever): same greedy tokens."""
    import jax.numpy as jnp
    name = "qwen2-1.5b"
    cfg, params = built[name]
    B, S = 2, 16
    shape = ShapeConfig("p", "prefill", S, B)
    batch = lm.make_batch(jax.random.key(2), cfg, shape)
    outs = {}
    for kvd in ("", "float8_e4m3fn"):
        c = cfg.replace(kv_cache_dtype=kvd)
        ctx = Ctx(c)
        _, cache = lm.prefill(params, batch, ctx)
        from repro.serving.decode import _embed_cache
        full = lm.init_cache(c, B, S + 4)
        cache = jax.tree.map(_embed_cache, full, cache)
        logits, _ = lm.decode_step(params, {"token": jnp.ones((B, 1), jnp.int32)},
                                   cache, ctx)
        outs[kvd] = np.asarray(logits)
    assert (outs[""].argmax(-1) == outs["float8_e4m3fn"].argmax(-1)).all()
    assert np.abs(outs[""] - outs["float8_e4m3fn"]).max() < 0.25
