"""sklearn import: prediction equivalence through the compiled stack.

The acceptance contract (ISSUE 4): imported models match the source
estimator's predict_proba/predict to 1e-5 on held-out data, through both
the compiled vectorized engine and the pallas engine (interpret mode on
CPU). sklearn is an optional dependency — the whole module skips cleanly
when it is absent.
"""
import numpy as np
import pytest

sklearn = pytest.importorskip("sklearn")

from sklearn.ensemble import (  # noqa: E402
    ExtraTreesClassifier,
    GradientBoostingClassifier,
    GradientBoostingRegressor,
    RandomForestClassifier,
    RandomForestRegressor,
)
from sklearn.tree import (  # noqa: E402
    DecisionTreeClassifier,
    DecisionTreeRegressor,
)

from repro.core.api import YdfError  # noqa: E402
from repro.core.models import (  # noqa: E402
    CartModel,
    GradientBoostedTreesModel,
    RandomForestModel,
)
from repro.interop import from_sklearn  # noqa: E402


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    X = rng.normal(size=(600, 5)).astype(np.float32)
    y_bin = (X[:, 0] + np.square(X[:, 1]) + rng.normal(0, 0.3, 600) > 0.7)
    y_multi = np.where(X[:, 2] > 0.4, 2, y_bin.astype(int))
    y_reg = (2 * X[:, 0] + np.sin(3 * X[:, 1])
             + rng.normal(0, 0.1, 600)).astype(np.float64)
    X_test = rng.normal(size=(200, 5)).astype(np.float32)
    return X, y_bin.astype(int), y_multi, y_reg, X_test


def _cols(A):
    return {f"f{i}": A[:, i] for i in range(A.shape[1])}


CASES = [
    ("dt_cls", lambda: DecisionTreeClassifier(max_depth=8, random_state=0),
     "bin", CartModel),
    ("dt_reg", lambda: DecisionTreeRegressor(max_depth=8, random_state=0),
     "reg", CartModel),
    ("rf_cls", lambda: RandomForestClassifier(n_estimators=20, random_state=0),
     "bin", RandomForestModel),
    ("rf_multi", lambda: RandomForestClassifier(n_estimators=15, random_state=0),
     "multi", RandomForestModel),
    ("rf_reg", lambda: RandomForestRegressor(n_estimators=15, random_state=0),
     "reg", RandomForestModel),
    ("extra_cls", lambda: ExtraTreesClassifier(n_estimators=10, random_state=0),
     "bin", RandomForestModel),
    ("gbt_cls", lambda: GradientBoostingClassifier(n_estimators=25, random_state=0),
     "bin", GradientBoostedTreesModel),
    ("gbt_multi", lambda: GradientBoostingClassifier(n_estimators=12, random_state=0),
     "multi", GradientBoostedTreesModel),
    ("gbt_reg", lambda: GradientBoostingRegressor(n_estimators=25, random_state=0),
     "reg", GradientBoostedTreesModel),
]


@pytest.mark.parametrize("name,make,target,model_cls",
                         CASES, ids=[c[0] for c in CASES])
def test_prediction_equivalence(data, name, make, target, model_cls):
    X, y_bin, y_multi, y_reg, X_test = data
    y = {"bin": y_bin, "multi": y_multi, "reg": y_reg}[target]
    est = make().fit(X, y)
    model = from_sklearn(est)
    assert isinstance(model, model_cls)
    ref = est.predict(X_test) if target == "reg" else est.predict_proba(X_test)
    ours = np.asarray(model.predict(_cols(X_test)))
    np.testing.assert_allclose(ours, ref, atol=1e-5)
    if target != "reg":
        assert model.classes == [str(c) for c in est.classes_]
        np.testing.assert_array_equal(model.predict_class(_cols(X_test)),
                                      est.predict(X_test))


@pytest.mark.parametrize("engine", ["vectorized", "pallas"])
def test_imported_models_through_compiled_engines(data, engine):
    X, y_bin, _, _, X_test = data
    est = RandomForestClassifier(n_estimators=12, max_depth=9,
                                 random_state=1).fit(X, y_bin)
    model = from_sklearn(est)
    model.compile(engine)  # pallas runs interpret-mode on CPU hosts
    assert model.predictor().name == engine
    np.testing.assert_allclose(model.predict(_cols(X_test)),
                               est.predict_proba(X_test), atol=1e-5)


def test_imported_model_through_serving_bundle_and_microbatcher(data):
    from repro.serving.forest import MicroBatcher, make_forest_server
    X, y_bin, _, _, X_test = data
    est = GradientBoostingClassifier(n_estimators=15, random_state=2)
    est.fit(X, y_bin)
    model = from_sklearn(est)
    bundle = make_forest_server(model, "vectorized")
    mb = MicroBatcher(bundle=bundle, max_batch=128)
    t1 = mb.submit(_cols(X_test[:70]))
    t2 = mb.submit(_cols(X_test[70:]))
    out = np.concatenate([mb.result(t1), mb.result(t2)])
    np.testing.assert_allclose(out, est.predict_proba(X_test), atol=1e-5)
    assert mb.dispatches >= 1


def test_threshold_ties_route_like_sklearn():
    # integer-valued feature: splits land at .5 midpoints, and exact-value
    # inputs must take sklearn's x <= t LEFT branch through our >= encoding
    X = np.repeat(np.arange(8, dtype=np.float32), 10)[:, None]
    y = (X[:, 0] >= 4).astype(int)
    est = DecisionTreeClassifier(random_state=0).fit(X, y)
    model = from_sklearn(est)
    probe = np.arange(8, dtype=np.float32)[:, None]
    np.testing.assert_allclose(model.predict({"f0": probe[:, 0]}),
                               est.predict_proba(probe), atol=1e-6)


def test_feature_names_from_override_and_errors(data):
    X, y_bin, _, _, X_test = data
    est = DecisionTreeClassifier(max_depth=4, random_state=0).fit(X, y_bin)
    names = ["a", "b", "c", "d", "e"]
    model = from_sklearn(est, label="income", feature_names=names)
    assert model.features == names and model.label == "income"
    model.predict({n: X_test[:8, i] for i, n in enumerate(names)})
    with pytest.raises(YdfError, match="one name per training column"):
        from_sklearn(est, feature_names=["too", "few"])


def test_unfitted_and_unsupported_estimators_raise(data):
    with pytest.raises(YdfError, match="not fitted"):
        from_sklearn(DecisionTreeClassifier())
    from sklearn.linear_model import LogisticRegression
    X, y_bin, _, _, _ = data
    with pytest.raises(YdfError, match="unsupported estimator"):
        from_sklearn(LogisticRegression().fit(X, y_bin))


def test_imported_model_save_load_roundtrip(tmp_path, data):
    from repro.core import Model
    X, y_bin, _, _, X_test = data
    est = RandomForestClassifier(n_estimators=8, random_state=3).fit(X, y_bin)
    model = from_sklearn(est)
    before = model.predict(_cols(X_test))
    model.save(str(tmp_path / "m"))
    loaded = Model.load(str(tmp_path / "m"))
    np.testing.assert_array_equal(loaded.predict(_cols(X_test)), before)
