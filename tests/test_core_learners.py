"""Learner end-to-end behaviour: learning power, determinism (§3.11),
serialization backwards compatibility, self-evaluation."""
import numpy as np
import pytest

from repro.core import (
    CartLearner,
    GradientBoostedTreesLearner,
    Model,
    RandomForestLearner,
    Task,
)
from repro.data.tabular import adult_like, train_test_split


def _xor_data(n=600, seed=0):
    rng = np.random.default_rng(seed)
    a, b = rng.normal(size=n), rng.normal(size=n)
    y = np.where((a > 0) ^ (b > 0), "pos", "neg")
    noise = rng.normal(size=n)
    return {"a": a.astype(object), "b": b.astype(object),
            "noise": noise.astype(object), "y": y.astype(object)}


@pytest.fixture(scope="module")
def adult():
    return train_test_split(adult_like(2000), 0.3, 1)


def test_gbt_learns_xor():
    train, test = train_test_split(_xor_data(), 0.3, 0)
    m = GradientBoostedTreesLearner(label="y", num_trees=40).train(train)
    assert m.evaluate(test)["accuracy"] > 0.9  # linear model can't beat 0.5


def test_rf_learns_xor_and_oob_close_to_test():
    train, test = train_test_split(_xor_data(), 0.3, 0)
    m = RandomForestLearner(label="y", num_trees=30).train(train)
    acc = m.evaluate(test)["accuracy"]
    assert acc > 0.85
    oob = m.self_evaluation
    assert oob is not None and oob.source == "out-of-bag"
    assert abs(oob["accuracy"] - acc) < 0.1


def test_gbt_regression():
    rng = np.random.default_rng(1)
    x = rng.uniform(-3, 3, 800)
    y = np.sin(x) * 2 + rng.normal(scale=0.1, size=800)
    data = {"x": x.astype(object), "y": y.astype(object)}
    train, test = train_test_split(data, 0.3, 0)
    m = GradientBoostedTreesLearner(label="y", task=Task.REGRESSION,
                                    num_trees=60).train(train)
    ev = m.evaluate(test)
    assert ev["rmse"] < 0.35 and ev["r2"] > 0.9


def test_gbt_multiclass():
    rng = np.random.default_rng(2)
    x1, x2 = rng.normal(size=900), rng.normal(size=900)
    y = np.select([x1 + x2 > 0.8, x1 - x2 > 0.8], ["a", "b"], default="c")
    data = {"x1": x1.astype(object), "x2": x2.astype(object),
            "y": y.astype(object)}
    train, test = train_test_split(data, 0.3, 0)
    m = GradientBoostedTreesLearner(label="y", num_trees=30).train(train)
    ev = m.evaluate(test)
    assert ev["accuracy"] > 0.85
    assert m.predict(test).shape[1] == 3
    np.testing.assert_allclose(m.predict(test).sum(1), 1.0, atol=1e-5)


def test_determinism_same_seed(adult):
    train, test = adult
    m1 = GradientBoostedTreesLearner(label="income", num_trees=10, seed=9).train(train)
    m2 = GradientBoostedTreesLearner(label="income", num_trees=10, seed=9).train(train)
    np.testing.assert_array_equal(m1.predict(test), m2.predict(test))
    m3 = RandomForestLearner(label="income", num_trees=5, seed=9).train(train)
    m4 = RandomForestLearner(label="income", num_trees=5, seed=9).train(train)
    np.testing.assert_array_equal(m3.predict(test), m4.predict(test))


def test_save_load_roundtrip(adult, tmp_path):
    train, test = adult
    m = GradientBoostedTreesLearner(label="income", num_trees=8).train(train)
    m.save(str(tmp_path / "model"))
    m2 = Model.load(str(tmp_path / "model"))
    np.testing.assert_array_equal(m.predict(test), m2.predict(test))


def test_early_stopping_truncates(adult):
    train, test = adult
    m = GradientBoostedTreesLearner(label="income", num_trees=150,
                                    shrinkage=0.4).train(train)
    # aggressive shrinkage overfits fast; early stopping must kick in
    assert m.training_logs["num_trees"] < 150


def test_best_first_global_growth(adult):
    train, test = adult
    m = GradientBoostedTreesLearner(
        label="income", num_trees=15, growing_strategy="BEST_FIRST_GLOBAL",
        max_num_nodes=32, max_depth=10).train(train)
    assert m.evaluate(test)["accuracy"] > 0.75
    c = m.forest.node_counts()
    assert c["nodes_per_tree_mean"] <= 33


def test_cart_prunes_and_predicts(adult):
    train, test = adult
    m = CartLearner(label="income").train(train)
    assert m.evaluate(test)["accuracy"] > 0.7
    assert m.forest.n_trees == 1


def test_variable_importance_finds_signal():
    train, _ = train_test_split(_xor_data(), 0.3, 0)
    m = GradientBoostedTreesLearner(label="y", num_trees=20).train(train)
    vi = m.variable_importances()["NUM_NODES"]
    assert vi["a"] > vi["noise"] and vi["b"] > vi["noise"]


def test_hessian_gain_variant(adult):
    train, test = adult
    m = GradientBoostedTreesLearner(label="income", num_trees=20,
                                    use_hessian_gain=True).train(train)
    assert m.evaluate(test)["accuracy"] > 0.75


def test_subsampling(adult):
    train, test = adult
    m = GradientBoostedTreesLearner(label="income", num_trees=20,
                                    subsample=0.7).train(train)
    assert m.evaluate(test)["accuracy"] > 0.75


def test_external_validation_set(adult):
    train, test = adult
    m = GradientBoostedTreesLearner(label="income", num_trees=15).train(
        train, valid=test)
    assert m.self_evaluation.source == "validation"
    assert m.self_evaluation.n_examples == len(test["income"])
