"""Distributed decision-forest training (§3.9) on 8 placeholder devices.

Run in a SUBPROCESS because the main pytest process must keep 1 CPU device
(jax locks device count at first init)."""
import os
import subprocess
import sys

import numpy as np
import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.core.distributed import DistGBTConfig, DistributedGBT

rng = np.random.default_rng(0)
N, F = 2048, 8
codes = rng.integers(0, 64, (N, F)).astype(np.uint8)
logit = 0.8*(codes[:,0] > 30) - 1.2*(codes[:,3] > 45) + 0.5*(codes[:,5] > 10)
y = (rng.random(N) < 1/(1+np.exp(-logit))).astype(np.float64)
cfg = DistGBTConfig(max_depth=4, n_bins=64, num_trees=8)

m_11 = DistributedGBT(cfg, jax.make_mesh((1, 1), ("data", "model"))).fit(codes, y)
m_24 = DistributedGBT(cfg, jax.make_mesh((2, 4), ("data", "model"))).fit(codes, y)
m_81 = DistributedGBT(cfg, jax.make_mesh((8, 1), ("data", "model"))).fit(codes, y)
m_18 = DistributedGBT(cfg, jax.make_mesh((1, 8), ("data", "model"))).fit(codes, y)
s = m_11.predict_scores(codes)
for name, m in [("2x4", m_24), ("8x1(example-par)", m_81), ("1x8(feature-par)", m_18)]:
    assert np.allclose(s, m.predict_scores(codes), atol=1e-4), name
acc = ((s > 0) == y).mean()
assert acc > 0.62, acc

# interrupt mid-forest via the §11 checkpoint layer, resume on a DIFFERENT
# mesh shape == straight run (checkpoints are mesh-placement-invariant)
import tempfile
from repro.train.checkpoint import CheckpointPolicy
ckdir = tempfile.mkdtemp()
calls = {"n": 0}
def cancel():
    calls["n"] += 1
    return calls["n"] >= 4
half = DistributedGBT(cfg, jax.make_mesh((2, 4), ("data", "model"))).fit(
    codes, y, checkpoint=CheckpointPolicy(ckdir, every_n_trees=2, cancel=cancel))
assert half.training_logs["interrupted"] and len(half.trees) < cfg.num_trees
m_res = DistributedGBT(cfg, jax.make_mesh((8, 1), ("data", "model"))).fit(
    codes, y, checkpoint=CheckpointPolicy(ckdir))
assert not m_res.training_logs["interrupted"]
assert np.allclose(s, m_res.predict_scores(codes), atol=1e-4)

# pointer-forest conversion serves identically
forest = m_24.to_forest([f"f{i}" for i in range(F)])
from repro.core.tree import predict_raw, aggregate_gbt
s3 = aggregate_gbt(predict_raw(forest, codes.astype(np.float32)), forest)[:, 0]
assert np.allclose(s, s3, atol=1e-4)
print("OK")
"""


@pytest.mark.slow
def test_distributed_gbt_mesh_equivalence_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=900,
                       env=dict(os.environ, PYTHONPATH="src",
                                JAX_PLATFORMS="cpu"))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "OK" in r.stdout


def test_simulated_cluster_fault_tolerance():
    """The paper's single-process simulation backend + worker death."""
    from repro.core.distributed import DistGBTConfig, SimulatedCluster
    rng = np.random.default_rng(1)
    N, F = 512, 6
    codes = rng.integers(0, 32, (N, F)).astype(np.uint8)
    y = (codes[:, 1] > 15).astype(np.float64)
    g = 0.5 - y
    stats = np.stack([g, np.full(N, 0.25), np.ones(N)], 1)
    cfg = DistGBTConfig(max_depth=3, n_bins=32)

    sim = SimulatedCluster(codes, 4, cfg, seed=0)
    t0 = sim.grow_tree(stats)
    traffic_before = sim.traffic_bytes
    sim.kill_worker(0)
    sim.kill_worker(2)
    t1 = sim.grow_tree(stats)
    # equivalent model despite losing half the workers (features reassigned):
    # gains and leaf values match exactly (feature ids / example routing may
    # tie-break differently when two features carry identical information)
    np.testing.assert_allclose(t0["leaf"], t1["leaf"])
    np.testing.assert_allclose(t0["gain"], t1["gain"], rtol=1e-6)
    assert sim.traffic_bytes > traffic_before  # it did communicate
    with pytest.raises(RuntimeError):
        sim.kill_worker(1), sim.kill_worker(3)


def test_traffic_is_independent_of_examples():
    """Guillame-Bert & Teytaud scaling: per-level candidate traffic depends on
    nodes/features, not N (partition bitmap scales N/8 bytes, 32x packed)."""
    from repro.core.distributed import DistGBTConfig, SimulatedCluster
    cfg = DistGBTConfig(max_depth=2, n_bins=16)
    traffics = []
    for N in (256, 1024):
        rng = np.random.default_rng(0)
        codes = rng.integers(0, 16, (N, 4)).astype(np.uint8)
        stats = np.stack([rng.normal(size=N), np.ones(N), np.ones(N)], 1)
        sim = SimulatedCluster(codes, 2, cfg, seed=0)
        sim.grow_tree(stats)
        traffics.append(sim.traffic_bytes)
    candidate_bytes = [t - n // 8 * cfg.max_depth for t, n in
                       zip(traffics, (256, 1024))]
    assert candidate_bytes[0] == candidate_bytes[1]
