"""MoE dispatch: capacity accounting, combine-weight normalization, and
equivalence with a dense (no-capacity) expert mixture when capacity is ample."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, _act
from repro.models.moe import _top_k_dispatch, moe_block, moe_schema
from repro.models.params import init_params


def _cfg(E=4, k=2, cap=8.0):
    return ModelConfig(d_model=16, n_experts=E, top_k=k, moe_d_ff=32,
                       act="swiglu", capacity_factor=cap, moe_group_size=16,
                       dtype="float32", param_dtype="float32")


def test_dispatch_capacity_and_weights():
    G, T, E, k, cap = 2, 16, 4, 2, 3
    gates = jax.nn.softmax(jax.random.normal(jax.random.key(0), (G, T, E)), -1)
    disp, comb = _top_k_dispatch(gates, k, cap)
    # each (expert, slot) holds at most one token
    assert float(disp.sum(axis=1).max()) <= 1.0 + 1e-6
    # capacity respected exactly
    assert disp.shape[-1] == cap
    # combine weights of surviving tokens sum to <= 1 (renormalized top-k)
    w = comb.sum(axis=(2, 3))
    assert float(w.max()) <= 1.0 + 1e-5
    # dispatched tokens' combine weight ratios match renormalized gates
    kept = disp.sum(axis=(2, 3)) == k  # tokens with both choices kept
    if bool(kept.any()):
        np.testing.assert_allclose(w[kept], 1.0, atol=1e-5)


def test_moe_matches_dense_reference_with_ample_capacity():
    cfg = _cfg(E=4, k=2, cap=16.0)
    p = init_params(jax.random.key(1), moe_schema(cfg), "float32")
    x = jax.random.normal(jax.random.key(2), (2, 8, 16)) * 0.5
    out, aux = moe_block(p, x, Ctx(cfg))

    # dense reference: every token through every expert, weighted by
    # renormalized top-k gates
    xt = x.reshape(-1, 16)
    logits = xt @ np.asarray(p["router"])
    gates = jax.nn.softmax(jnp.asarray(logits), -1)
    topv, topi = jax.lax.top_k(gates, 2)
    topv = topv / topv.sum(-1, keepdims=True)
    ref = np.zeros_like(xt)
    for e in range(4):
        h = xt @ np.asarray(p["w_in"][e])
        g = xt @ np.asarray(p["w_gate"][e])
        eo = (np.asarray(jax.nn.silu(jnp.asarray(g))) * h) @ np.asarray(p["w_out"][e])
        wsel = np.where(np.asarray(topi) == e, np.asarray(topv), 0).sum(-1)
        ref += wsel[:, None] * eo
    np.testing.assert_allclose(out.reshape(-1, 16), ref, atol=1e-4, rtol=1e-3)
    assert np.isfinite(float(aux))


def test_shared_experts_path():
    cfg = _cfg().replace(n_shared_experts=2)
    p = init_params(jax.random.key(3), moe_schema(cfg), "float32")
    x = jax.random.normal(jax.random.key(4), (2, 8, 16)) * 0.5
    out, aux = moe_block(p, x, Ctx(cfg))
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())


def test_aux_loss_balances():
    """Uniform router -> aux ~= router_aux_weight; collapsed -> larger."""
    cfg = _cfg(E=4, k=1, cap=16.0)
    p = init_params(jax.random.key(5), moe_schema(cfg), "float32")
    # positive inputs so a positive router column collapses routing for sure
    x = jnp.abs(jax.random.normal(jax.random.key(6), (2, 32, 16))) + 0.1
    p_balanced = dict(p, router=p["router"] * 0.01)  # near-uniform gates
    _, aux_u = moe_block(p_balanced, x, Ctx(cfg))
    p_collapsed = dict(p, router=jnp.zeros_like(p["router"]).at[:, 0].set(5.0))
    _, aux_c = moe_block(p_collapsed, x, Ctx(cfg))
    assert float(aux_c) > float(aux_u) * 1.5
