"""Assigned architectures x shapes: exact dims from the assignment table."""
import pytest

from repro.configs import SHAPES, applicable_shapes, get_arch, list_archs

# (name, family, L, d_model, H, KV, d_ff, vocab)
TABLE = [
    ("command-r-35b", "dense", 40, 8192, 64, 8, 22528, 256000),
    ("qwen2-1.5b", "dense", 28, 1536, 12, 2, 8960, 151936),
    ("qwen1.5-32b", "dense", 64, 5120, 40, 40, 27392, 152064),
    ("qwen3-8b", "dense", 36, 4096, 32, 8, 12288, 151936),
    ("grok-1-314b", "moe", 64, 6144, 48, 8, 32768, 131072),
    ("qwen2-moe-a2.7b", "moe", 24, 2048, 16, 16, 5632, 151936),
    ("paligemma-3b", "vlm", 18, 2048, 8, 1, 16384, 257216),
    ("whisper-large-v3", "audio", 32, 1280, 20, 20, 5120, 51866),
    ("zamba2-2.7b", "hybrid", 54, 2560, 32, 32, 10240, 32000),
    ("rwkv6-3b", "ssm", 32, 2560, 40, 40, 8960, 65536),
]


def test_all_archs_registered():
    assert sorted(list_archs()) == sorted(n for n, *_ in TABLE)


@pytest.mark.parametrize("name,family,L,d,H,KV,dff,V", TABLE)
def test_arch_dims(name, family, L, d, H, KV, dff, V):
    cfg = get_arch(name)
    assert cfg.family == family
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == H and cfg.n_kv_heads == KV
    assert cfg.d_ff == dff and cfg.vocab_size == V


def test_arch_specifics():
    assert get_arch("qwen3-8b").qk_norm
    assert get_arch("qwen2-1.5b").qkv_bias and get_arch("qwen1.5-32b").qkv_bias
    assert get_arch("command-r-35b").parallel_block
    g = get_arch("grok-1-314b")
    assert g.n_experts == 8 and g.top_k == 2
    q = get_arch("qwen2-moe-a2.7b")
    assert q.n_experts == 60 and q.top_k == 4 and q.n_shared_experts == 4
    assert q.moe_d_ff == 1408
    z = get_arch("zamba2-2.7b")
    assert z.ssm_state == 64 and z.attn_every == 6
    w = get_arch("whisper-large-v3")
    assert w.n_enc_layers == 32 and w.enc_seq == 1500
    assert get_arch("paligemma-3b").n_patches == 256
    assert get_arch("rwkv6-3b").rope_theta == 0.0  # attention-free


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1


def test_long_context_applicability():
    # sub-quadratic families only (assignment rule; skip documented in DESIGN.md)
    for name in list_archs():
        cfg = get_arch(name)
        shapes = applicable_shapes(cfg)
        if cfg.family in ("hybrid", "ssm"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
    # 40 assigned cells = 32 lowered + 8 documented long_500k skips
    total = sum(len(applicable_shapes(get_arch(a))) for a in list_archs())
    assert total == 32


def test_param_counts_close_to_nameplate():
    """Total params within tolerance of each arch's nameplate size."""
    from repro.launch.roofline import count_params
    expect = {"command-r-35b": 35e9, "qwen2-1.5b": 1.5e9, "qwen1.5-32b": 32e9,
              "qwen3-8b": 8e9, "grok-1-314b": 314e9, "qwen2-moe-a2.7b": 14e9,
              "paligemma-3b": 2.5e9, "whisper-large-v3": 1.5e9,
              "zamba2-2.7b": 2.7e9, "rwkv6-3b": 3e9}
    for name, nominal in expect.items():
        total, active = count_params(get_arch(name))
        assert 0.5 * nominal < total < 1.7 * nominal, (name, total)
        assert active <= total
