"""Mamba2 (SSD) and RWKV6 chunked-parallel forms vs naive recurrences; decode
steps vs chunked forms; chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.layers import Ctx
from repro.models.params import init_params


def _mamba_cfg(chunk):
    return ModelConfig(d_model=32, ssm_heads=4, ssm_head_dim=8, ssm_state=8,
                       ssm_chunk=chunk, d_conv=4, dtype="float32",
                       param_dtype="float32")


def _naive_mamba(p, x, cfg):
    """Token-by-token recurrence via mamba2_step (the O(1) decode form)."""
    ctx = Ctx(cfg)
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv = jnp.zeros((B, cfg.d_conv - 1, H * P + 2 * N), x.dtype)
    h = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for s in range(x.shape[1]):
        y, (conv, h) = ssm.mamba2_step(p, x[:, s:s + 1], ctx, conv, h)
        ys.append(y)
    return jnp.concatenate(ys, 1), h


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mamba2_chunked_matches_recurrence(chunk):
    cfg = _mamba_cfg(chunk)
    p = init_params(jax.random.key(0), ssm.mamba2_schema(cfg), "float32")
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.5
    y_chunk, (_, h_chunk) = ssm.mamba2_chunked(p, x, Ctx(cfg))
    y_naive, h_naive = _naive_mamba(p, x, cfg)
    np.testing.assert_allclose(y_chunk, y_naive, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h_chunk, h_naive, atol=1e-4, rtol=1e-4)


def test_mamba2_chunk_invariance():
    x = jax.random.normal(jax.random.key(2), (1, 24, 32)) * 0.5
    outs = []
    for chunk in (4, 12, 24):
        cfg = _mamba_cfg(chunk)
        p = init_params(jax.random.key(0), ssm.mamba2_schema(cfg), "float32")
        outs.append(ssm.mamba2_chunked(p, x, Ctx(cfg))[0])
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4)


def test_mamba2_state_carry():
    """Processing [a;b] == processing a then b with carried state."""
    cfg = _mamba_cfg(8)
    p = init_params(jax.random.key(0), ssm.mamba2_schema(cfg), "float32")
    x = jax.random.normal(jax.random.key(3), (2, 32, 32)) * 0.5
    full, _ = ssm.mamba2_chunked(p, x, Ctx(cfg))
    y1, (conv, h) = ssm.mamba2_chunked(p, x[:, :16], Ctx(cfg))
    y2, _ = ssm.mamba2_chunked(p, x[:, 16:], Ctx(cfg), conv_state=conv, ssm_state=h)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full, atol=1e-4)


# ---------------------------------------------------------------- rwkv6

def _rwkv_cfg(chunk):
    return ModelConfig(d_model=32, rwkv_head_dim=8, rwkv_chunk=chunk, d_ff=64,
                       dtype="float32", param_dtype="float32")


def test_rwkv6_chunked_matches_step_recurrence():
    cfg = _rwkv_cfg(8)
    sch = ssm.rwkv6_schema(cfg)["time"]
    p = init_params(jax.random.key(0), sch, "float32")
    x = jax.random.normal(jax.random.key(1), (2, 16, 32)) * 0.5
    y_chunk, (shift_c, s_chunk) = ssm.rwkv6_time_mix(p, x, Ctx(cfg))

    B, D = 2, 32
    H, C = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    shift = jnp.zeros((B, D))
    state = jnp.zeros((B, H, C, C), jnp.float32)
    ys = []
    for s in range(16):
        y, (shift, state) = ssm.rwkv6_time_step(p, x[:, s:s + 1], Ctx(cfg),
                                                shift, state)
        ys.append(y)
    y_naive = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(y_chunk, y_naive, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s_chunk, state, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(shift_c, shift, atol=1e-6)


def test_rwkv6_chunk_invariance():
    x = jax.random.normal(jax.random.key(5), (1, 24, 32)) * 0.5
    outs = []
    for chunk in (4, 8, 24):
        cfg = _rwkv_cfg(chunk)
        p = init_params(jax.random.key(0), ssm.rwkv6_schema(cfg)["time"], "float32")
        outs.append(ssm.rwkv6_time_mix(p, x, Ctx(cfg))[0])
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4)


def test_rwkv6_channel_mix_shift():
    cfg = _rwkv_cfg(8)
    p = init_params(jax.random.key(0), ssm.rwkv6_schema(cfg)["channel"], "float32")
    x = jax.random.normal(jax.random.key(6), (2, 8, 32)) * 0.5
    full, last = ssm.rwkv6_channel_mix(p, x, Ctx(cfg))
    np.testing.assert_allclose(last, x[:, -1, :])
    # step-by-step with carried shift state
    shift = jnp.zeros((2, 32))
    ys = []
    for s in range(8):
        y, shift = ssm.rwkv6_channel_mix(p, x[:, s:s + 1], Ctx(cfg), shift)
        ys.append(y)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), full, atol=1e-5)
