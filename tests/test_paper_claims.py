"""Paper-claim validation (EXPERIMENTS.md 'faithful baseline').

Absolute numbers are not 1:1 comparable (synthetic stand-ins for the 70
OpenML sets — no network), but the paper's ORDERINGS and protocol are
reproduced and asserted here. Everything is seeded => assertions are stable.

Claims covered (paper §5.4/5.5, Table 2, App. B.4):
  C1  GBT > linear baseline on rule-structured tabular data.
  C2  benchmark_rank1 template > defaults for GBT (mean rank over suite).
  C3  RF default is fast to train; GBT benchmark-hp is slower to train than
      GBT default (oblique splits cost — Table 2 ordering).
  C4  GBT models are smaller + faster at inference than RF (Table 2).
  C5  Engine compilation: vectorized engine >> naive python engine (B.4).
  C6  Tuned >= default on accuracy (Fig. 6 orderings, small-suite proxy).
"""
import time

import numpy as np
import pytest

from repro.core import (
    GradientBoostedTreesLearner,
    LinearLearner,
    RandomForestLearner,
)
from repro.data.tabular import SUITE, make_dataset, train_test_split


def _acc(learner, train, test):
    return learner.train(train).evaluate(test)["accuracy"]


@pytest.fixture(scope="module")
def small_suite():
    out = []
    for spec in SUITE[:4]:
        if spec.n_classes == 0:
            continue
        data = make_dataset(spec)
        out.append((spec.name, *train_test_split(data, 0.3, spec.seed)))
    return out


def test_c1_gbt_beats_linear_on_rule_data(small_suite):
    wins = 0
    for name, train, test in small_suite:
        gbt = _acc(GradientBoostedTreesLearner(label="label", num_trees=30), train, test)
        lin = _acc(LinearLearner(label="label"), train, test)
        wins += gbt > lin
    assert wins >= len(small_suite) - 1  # GBT wins (almost) everywhere


def test_c2_benchmark_template_mean_rank(small_suite):
    deltas = []
    for name, train, test in small_suite:
        d = _acc(GradientBoostedTreesLearner(label="label", num_trees=20,
                                             seed=5), train, test)
        b = _acc(GradientBoostedTreesLearner(label="label", num_trees=20,
                                             seed=5, template="benchmark_rank1"),
                 train, test)
        deltas.append(b - d)
    assert np.mean(deltas) > -0.01  # template >= default on average


def test_c3_training_time_ordering():
    data = make_dataset(SUITE[2])  # synth_adult
    train, _ = train_test_split(data, 0.3, 0)
    t0 = time.perf_counter()
    RandomForestLearner(label="label", num_trees=10, compute_oob=False).train(train)
    t_rf = time.perf_counter() - t0
    t0 = time.perf_counter()
    GradientBoostedTreesLearner(label="label", num_trees=10).train(train)
    t_gbt_default = time.perf_counter() - t0
    t0 = time.perf_counter()
    GradientBoostedTreesLearner(label="label", num_trees=10,
                                template="benchmark_rank1").train(train)
    t_gbt_bench = time.perf_counter() - t0
    # Table 2 ordering: oblique benchmark hp slower than default GBT
    assert t_gbt_bench > t_gbt_default
    assert t_rf > 0 and t_gbt_default > 0


def test_c4_gbt_smaller_and_faster_than_rf():
    data = make_dataset(SUITE[2])
    train, test = train_test_split(data, 0.3, 0)
    gbt = GradientBoostedTreesLearner(label="label", num_trees=20).train(train)
    rf = RandomForestLearner(label="label", num_trees=20).train(train)
    assert gbt.forest.node_counts()["total_nodes"] < \
        rf.forest.node_counts()["total_nodes"]
    import repro.core.models as M
    X = M.raw_matrix(M._as_vertical(test, gbt.spec), gbt.features)
    from repro.core.engines import compile_model
    for m in (gbt, rf):
        m.compile("vectorized")
    # interleaved best-of-N timing: a single sample each is a race against
    # scheduler noise in a full-suite run (flaked in PR 7); the best of
    # several alternated repetitions compares the engines' floors instead
    t_g = t_r = np.inf
    for _ in range(5):
        t0 = time.perf_counter(); gbt._scores(test)
        t_g = min(t_g, time.perf_counter() - t0)
        t0 = time.perf_counter(); rf._scores(test)
        t_r = min(t_r, time.perf_counter() - t0)
    assert t_g < t_r  # fewer+shallower trees infer faster


def test_c5_vectorized_engine_beats_naive():
    data = make_dataset(SUITE[1])
    train, test = train_test_split(data, 0.3, 0)
    m = GradientBoostedTreesLearner(label="label", num_trees=10).train(train)
    import repro.core.models as M
    from repro.core.engines import compile_model
    X = M.raw_matrix(M._as_vertical(test, m.spec), m.features)
    naive = compile_model(m, "naive")
    vect = compile_model(m, "vectorized")
    t0 = time.perf_counter(); naive.per_tree(X); t_n = time.perf_counter() - t0
    t0 = time.perf_counter(); vect.per_tree(X); t_v = time.perf_counter() - t0
    assert t_v < t_n  # QuickScorer-insight engine wins


def test_c6_tuned_geq_default(small_suite):
    from repro.core import HyperParameterTuner
    name, train, test = small_suite[0]
    default = _acc(GradientBoostedTreesLearner(label="label", num_trees=15), train, test)
    tuner = HyperParameterTuner(
        lambda **kw: GradientBoostedTreesLearner(num_trees=15, **kw),
        {"max_depth": [3, 6, 8], "shrinkage": [0.05, 0.1, 0.3]},
        label="label", n_trials=4, metric="accuracy", seed=1)
    tuned = tuner.train(train).evaluate(test)["accuracy"]
    assert tuned >= default - 0.02
