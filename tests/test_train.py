"""Training mechanics: grad-accum equivalence, checkpoint resume determinism,
optimizer behaviours, loss chunking."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.configs.base import ShapeConfig
from repro.data.lm_data import batch_at
from repro.models import lm
from repro.models.layers import Ctx, chunked_softmax_xent, unembed_matrix
from repro.models.params import init_params
from repro.train import init_train_state, make_train_step
from repro.train.loop import LoopConfig, train_loop

SHAPE = ShapeConfig("t", "train", 64, 4)
CFG = smoke_config(get_arch("qwen2-1.5b"))


def test_grad_accum_equivalence():
    """accum=2 gives (numerically) the same update as accum=1."""
    b1 = make_train_step(CFG.replace(grad_accum=1), SHAPE)
    b2 = make_train_step(CFG.replace(grad_accum=2), SHAPE)
    state = init_train_state(jax.random.key(0), CFG)
    batch = lm.make_batch(jax.random.key(1), CFG, SHAPE)
    s1, m1 = jax.jit(b1.step_fn)(state, batch)
    s2, m2 = jax.jit(b2.step_fn)(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    d1, d2 = jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])
    for a, b in zip(d1, d2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_chunked_xent_matches_dense():
    cfg = CFG.replace(loss_chunk=16)
    ctx = Ctx(cfg)
    params = init_params(jax.random.key(0), lm.model_schema(cfg), "float32")
    B, S, D, V = 2, 48, cfg.d_model, cfg.vocab_size
    h = jax.random.normal(jax.random.key(1), (B, S, D))
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, V)
    w = jnp.ones((B, S))
    un = unembed_matrix(params["embed"], ctx)
    sl, sw = chunked_softmax_xent(h, un, labels, w, ctx)
    logits = (h @ un).astype(jnp.float32)
    dense = (jax.nn.logsumexp(logits, -1)
             - jnp.take_along_axis(logits, labels[..., None], -1)[..., 0])
    np.testing.assert_allclose(float(sl), float(dense.sum()), rtol=1e-5)
    np.testing.assert_allclose(float(sw), B * S)


def test_train_loop_resume_determinism(tmp_path):
    """3+3 steps with restart == 6 straight steps (fault tolerance)."""
    loop6 = LoopConfig(total_steps=6, ckpt_every=3, log_every=100, seed=7)
    out_a = train_loop(CFG, SHAPE, os.path.join(tmp_path, "a"), loop6,
                       log=lambda *a: None)

    loop3 = LoopConfig(total_steps=3, ckpt_every=3, log_every=100, seed=7)
    train_loop(CFG, SHAPE, os.path.join(tmp_path, "b"), loop3,
               log=lambda *a: None)
    out_b = train_loop(CFG, SHAPE, os.path.join(tmp_path, "b"), loop6,
                       log=lambda *a: None)  # resumes at 3

    from repro.distributed.checkpoint import CheckpointManager
    sa, _ = CheckpointManager(os.path.join(tmp_path, "a")).restore(6)
    sb, _ = CheckpointManager(os.path.join(tmp_path, "b")).restore(6)
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_deadline_preemption(tmp_path):
    loop = LoopConfig(total_steps=10_000, ckpt_every=5, log_every=10_000,
                      deadline_s=1e-3)  # deadline hits right after step 1
    out = train_loop(CFG, SHAPE, str(tmp_path), loop, log=lambda *a: None)
    assert out["preempted"] and out["final_step"] >= 1


def test_adafactor_memory_shapes():
    """Adafactor slots are factored (vr+vc), not full (m+v)."""
    from repro.optim import make_optimizer, opt_slot_specs
    from repro.models.params import schema_shapes, schema_axes
    cfg = smoke_config(get_arch("grok-1-314b"))
    assert cfg.optimizer == "adafactor"
    sch = lm.model_schema(cfg)
    specs, axes = opt_slot_specs(cfg, schema_shapes(sch, "float32"),
                                 schema_axes(sch))
    import numpy as np
    slot_elems = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs))
    param_elems = sum(int(np.prod(s.shape))
                      for s in jax.tree.leaves(schema_shapes(sch, "float32")))
    assert slot_elems < 0.35 * param_elems  # AdamW would be 2.0x


def test_data_pipeline_determinism():
    b1 = batch_at(CFG, SHAPE, 5, seed=3)
    b2 = batch_at(CFG, SHAPE, 5, seed=3)
    b3 = batch_at(CFG, SHAPE, 6, seed=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
