"""Observability layer (DESIGN.md §13): tracer, metrics registry,
exporters, training_logs schema — plus the disabled-path overhead gate.

Span-tree tests run on ``serving.faults.FakeClock`` (§9.3 pattern):
every duration below is exact, no wall clock involved.
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np
import pytest

from repro.core.api import YdfError
from repro.obs import metrics as obs_metrics
from repro.obs import trace
from repro.obs.export import (chrome_trace, phase_summary, profile_dict,
                              validate_chrome_trace)
from repro.obs.logs import (REQUIRED_KEYS, build_training_logs,
                            summarize_training_logs, validate_training_logs)
from repro.serving.faults import FakeClock

pytestmark = pytest.mark.obs


# ------------------------------------------------------------------ tracer

def test_span_nesting_fake_clock():
    ck = FakeClock()
    with trace.capture(clock=ck.now) as tr:
        with trace.span("train/outer", trees=3):
            ck.advance(1.0)
            with trace.span("grower/inner"):
                ck.advance(0.25)
            ck.advance(0.5)
    assert len(tr.roots) == 1
    outer = tr.roots[0]
    assert outer.name == "train/outer"
    assert outer.args == {"trees": 3}
    assert outer.duration == pytest.approx(1.75)
    (inner,) = outer.children
    assert inner.name == "grower/inner"
    assert inner.t0 == pytest.approx(1.0)
    assert inner.duration == pytest.approx(0.25)
    assert tr.span_count() == 2
    assert tr.phase_names() == ["train/outer", "grower/inner"]


def test_span_exception_unwinding():
    ck = FakeClock()
    with trace.capture(clock=ck.now) as tr:
        with pytest.raises(RuntimeError):
            with trace.span("a"):
                ck.advance(1.0)
                with trace.span("b"):
                    ck.advance(1.0)
                    raise RuntimeError("boom")
    a = tr.roots[0]
    (b,) = a.children
    # both spans closed despite the exception, and the failing one is tagged
    assert b.args["error"] == "RuntimeError"
    assert a.args["error"] == "RuntimeError"
    assert a.t1 == b.t1 == pytest.approx(2.0)
    # the thread-local stack fully unwound: a new span is a fresh root
    with trace.capture(clock=ck.now) as tr2:
        with trace.span("c"):
            pass
    assert [r.name for r in tr2.roots] == ["c"]


def test_span_thread_isolation():
    ck = FakeClock()
    with trace.capture(clock=ck.now) as tr:
        def work(i: int):
            with trace.span("worker/block", i=i):
                with trace.span("worker/sub", i=i):
                    pass
        threads = [threading.Thread(target=work, args=(i,), name=f"w{i}")
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        with trace.span("main/own"):
            pass
    # each thread produced ITS OWN well-nested root; nothing leaked across
    assert len(tr.roots) == 5
    by_tid = {}
    for r in tr.roots:
        by_tid.setdefault(r.tid, []).append(r)
    for tid, roots in by_tid.items():
        if tid.startswith("w"):
            (r,) = roots
            assert r.name == "worker/block"
            assert [c.name for c in r.children] == ["worker/sub"]
            assert r.args["i"] == r.children[0].args["i"] == int(tid[1:])


def test_capture_nests_and_restores():
    ck = FakeClock()
    assert not trace.enabled()
    with trace.capture(clock=ck.now) as outer:
        with trace.span("outer/span"):
            with trace.capture(clock=ck.now) as inner:
                with trace.span("inner/span"):
                    pass
            assert trace.active() is outer
        assert [r.name for r in inner.roots] == ["inner/span"]
    assert not trace.enabled()
    assert [r.name for r in outer.roots] == ["outer/span"]
    # inner capture saw only its own spans
    assert all(s.name != "inner/span"
               for r in outer.roots for s in r.walk())


def test_events_and_disabled_noop():
    ck = FakeClock()
    with trace.capture(clock=ck.now) as tr:
        ck.advance(2.0)
        trace.event("distributed/worker_death", worker=3)
    assert tr.events[0]["name"] == "distributed/worker_death"
    assert tr.events[0]["ts"] == pytest.approx(2.0)
    assert tr.events[0]["args"] == {"worker": 3}
    # disabled: span() returns the shared no-op singleton, event() drops
    assert trace.span("x") is trace.span("y")
    trace.event("ignored")


# --------------------------------------------------------------- exporters

def _sample_tracer():
    ck = FakeClock()
    with trace.capture(clock=ck.now) as tr:
        with trace.span("gbt/tree", tree=0):
            ck.advance(0.5)
            with trace.span("grower/gain_scan", level=1):
                ck.advance(0.25)
        trace.event("checkpoint/rollback", tree=5)
    return tr


def test_chrome_trace_valid_and_normalized():
    tr = _sample_tracer()
    doc = chrome_trace(tr)
    validate_chrome_trace(doc)
    json.dumps(doc)                          # serializable end to end
    xs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    assert xs["gbt/tree"]["ts"] == 0.0       # normalized to t_origin
    assert xs["gbt/tree"]["dur"] == pytest.approx(0.75e6)
    assert xs["grower/gain_scan"]["cat"] == "grower"
    assert xs["grower/gain_scan"]["ts"] == pytest.approx(0.5e6)
    insts = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert insts[0]["name"] == "checkpoint/rollback"
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert metas and metas[0]["args"]["name"]     # thread lanes named
    with pytest.raises(ValueError):
        validate_chrome_trace({"nope": []})


def test_phase_summary_self_time():
    tr = _sample_tracer()
    ph = phase_summary(tr)
    assert ph["gbt/tree"]["count"] == 1
    assert ph["gbt/tree"]["total_s"] == pytest.approx(0.75)
    assert ph["gbt/tree"]["self_s"] == pytest.approx(0.5)   # minus child
    assert ph["grower/gain_scan"]["self_s"] == pytest.approx(0.25)
    prof = profile_dict(tr)
    assert prof["schema_version"] == 1
    assert prof["span_count"] == 2
    json.dumps(prof)


# ---------------------------------------------------------------- metrics

def test_metrics_counters_gauges_histograms():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("requests").inc()
    reg.counter("requests").inc(2)
    assert reg.counter("requests").value == 3
    reg.counter("requests", engine="pallas").inc(5)
    assert reg.labeled_values("requests", "engine") == {"pallas": 5}
    reg.gauge("queue_depth").set(7)
    assert reg.gauge("queue_depth").value == 7
    h = reg.histogram("latency_s")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count == 4
    assert h.mean == pytest.approx(2.5)
    assert h.percentile(50) in (2.0, 3.0)


def test_histogram_bounded_reservoir():
    h = obs_metrics.Histogram(cap=64)
    for i in range(1000):
        h.observe(float(i))
    assert h.count == 1000                   # exact count survives the cap
    assert h.total == pytest.approx(sum(range(1000)))
    assert len(h.values) <= 64


def test_registry_roundtrip_and_merge():
    a = obs_metrics.MetricsRegistry()
    a.counter("trees").inc(3)
    a.counter("dispatches", engine="numpy").inc(2)
    a.gauge("depth").set(5)
    a.histogram("lat", outcome="ok").observe(1.5)
    d = a.to_dict()
    assert d["schema_version"] == 1
    json.dumps(d)
    b = obs_metrics.MetricsRegistry.from_dict(d)
    assert b.to_dict() == d                  # lossless round-trip
    # merge: counters add, gauges last-write, histograms pool
    c = obs_metrics.MetricsRegistry()
    c.counter("trees").inc(3)
    c.gauge("depth").set(9)
    c.histogram("lat", outcome="ok").observe(2.5)
    b.merge(c)
    assert b.counter("trees").value == 6
    assert b.gauge("depth").value == 9
    h = b.histogram("lat", outcome="ok")
    assert h.count == 2 and h.mean == pytest.approx(2.0)
    assert b.counter("dispatches", engine="numpy").value == 2


# ----------------------------------------------------------- training logs

def test_build_training_logs_schema():
    logs = build_training_logs(learner="gbt", num_trees=10,
                               growth_engine="batched",
                               extra={"train_loss": [1.0], "skipme": None})
    assert all(k in logs for k in REQUIRED_KEYS)
    assert logs["schema_version"] == 1
    assert logs["train_loss"] == [1.0]
    assert "skipme" not in logs
    assert "profile" not in logs             # tracing was off
    validate_training_logs(logs)
    for bad in [{}, {**logs, "schema_version": 99},
                {**logs, "num_trees": -1},
                {**logs, "resilience": "nope"}]:
        with pytest.raises(YdfError):
            validate_training_logs(bad)


def test_training_logs_profile_attached_under_capture():
    ck = FakeClock()
    with trace.capture(clock=ck.now):
        with trace.span("grower/binning"):
            ck.advance(0.5)
        logs = build_training_logs(learner="gbt", num_trees=1)
    assert logs["profile"]["phases"]["grower/binning"]["count"] == 1
    lines = summarize_training_logs(logs)
    assert any("learner=gbt" in ln for ln in lines)
    assert any("profile" in ln for ln in lines)
    assert summarize_training_logs({"legacy": 1})[0].startswith(
        "Training logs (legacy)")


def test_learners_emit_schema_v1(tiny_adult):
    from repro.core import (CartLearner, GradientBoostedTreesLearner,
                            RandomForestLearner)
    for cls in (GradientBoostedTreesLearner, RandomForestLearner,
                CartLearner):
        kw = {"num_trees": 3} if cls is not CartLearner else {}
        model = cls(label="income", **kw).train(tiny_adult)
        logs = model.training_logs
        validate_training_logs(logs)
        assert logs["learner"] in ("gbt", "rf", "cart")
        assert any("Training logs (schema v1)" in ln
                   for ln in model.summary().splitlines())


def test_traced_train_covers_grower_phases(tiny_adult):
    from repro.core import GradientBoostedTreesLearner
    with trace.capture() as tr:
        model = GradientBoostedTreesLearner(
            label="income", num_trees=3).train(tiny_adult)
    names = set(tr.phase_names())
    assert {"grower/binning", "grower/hist_build", "grower/gain_scan",
            "grower/routing", "grower/leaf_stats"} <= names
    prof = model.training_logs["profile"]
    assert prof["phases"]["grower/gain_scan"]["count"] > 0
    validate_chrome_trace(chrome_trace(tr))


# ------------------------------------------------------------ CLI profile

def test_cli_profile_train_chrome_trace(tiny_adult, tmp_path, capsys):
    from repro.cli import main
    from repro.data.io import write_dataset
    csv = tmp_path / "train.csv"
    write_dataset(tiny_adult, f"csv:{csv}")
    out = tmp_path / "trace.json"
    main(["profile", "train", f"--dataset=csv:{csv}", "--label=income",
          f"--trace={out}", "--hparam", "num_trees=3"])
    doc = json.loads(out.read_text())
    validate_chrome_trace(doc)
    grower = {e["name"] for e in doc["traceEvents"]
              if e["ph"] == "X" and e["name"].startswith("grower/")}
    assert len(grower) >= 5, grower
    assert "phase" in capsys.readouterr().out


# ------------------------------------------------------- the overhead gate

def test_disabled_tracer_overhead_gate(tiny_adult):
    """The §13 acceptance gate: with no tracer installed, instrumentation
    must cost <= 1% of a 50-tree GBT train.

    Measured as (per-disabled-span cost) x (spans such a train emits)
    against the train's wall time, with the microbenchmark interleaved
    best-of-reps (the §11 checkpoint-gate protocol) so background load
    perturbs both sides equally. This scales the gate's sensitivity far
    beyond timing two trains (whose run-to-run jitter exceeds 1%).
    """
    from repro.core import GradientBoostedTreesLearner

    assert not trace.enabled()
    make = lambda: GradientBoostedTreesLearner(label="income", num_trees=50)

    # span count a 50-tree train emits, counted under a real capture
    with trace.capture() as tr:
        make().train(tiny_adult)
    n_spans = tr.span_count()

    # interleaved best-of: disabled-span loop vs empty loop
    N = 50_000
    def spans():
        for _ in range(N):
            with trace.span("grower/gain_scan", level=1):
                pass
    def baseline():
        for _ in range(N):
            pass
    best = [np.inf, np.inf]
    for _ in range(5):
        for i, fn in enumerate((spans, baseline)):
            t0 = time.perf_counter()
            fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    per_span = max(0.0, best[0] - best[1]) / N

    t0 = time.perf_counter()
    make().train(tiny_adult)
    train_s = time.perf_counter() - t0

    overhead = per_span * n_spans / train_s
    assert overhead <= 0.01, (
        f"disabled tracer costs {overhead:.2%} of a 50-tree train "
        f"({per_span * 1e9:.0f} ns/span x {n_spans} spans / {train_s:.2f}s)")
