"""Device-resident training engine (DESIGN.md §6) + tree-parallel lockstep.

Contracts under test:
  * the "device" engine (jitted level loop, fused hist+gain, tree axis)
    grows the SAME forests as the host "batched" engine at equal seeds —
    identical split structure, allclose leaf values/predictions;
  * RF lockstep blocks (tree_parallelism) are execution-only: bit-identical
    forests against the sequential oracle engine for any block size (keyed
    feature sampling makes the growth schedule semantics-free);
  * the fused kernel's f32-accumulated gain argmax agrees with the f64 numpy
    scan (property-style sweep over random frontiers);
  * exact_subtraction gating: backends that do not accumulate in f64 are
    never served the parent-minus-sibling subtraction;
  * hist_backend hardening: "auto" pins to numpy on CPU hosts, forcing
    "pallas" without a TPU raises, "pallas_interpret" is the explicit opt-in;
  * CI smoke: one tiny tree through the device engine with the Pallas kernel
    in interpret mode (JAX_PLATFORMS=cpu), so kernel regressions surface in
    tier-1.
"""
import numpy as np
import pytest

from repro.core import GradientBoostedTreesLearner, RandomForestLearner, YdfError
from repro.core.api import Task
from repro.core.binning import BinnedFeatures
from repro.core.grower import GrowthParams, grow_tree, resolve_engine
from repro.core.hist_backend import NumpyHistogramBackend, resolve_backend
from repro.core.splitters import SplitterParams, best_splits
from repro.core.tree import empty_forest
from repro.data.tabular import SUITE, adult_like, make_dataset, train_test_split

STRUCT_KEYS = ["feature", "split_bin", "cat_mask", "left_child", "n_nodes"]
ALL_KEYS = STRUCT_KEYS + ["threshold", "leaf_value"]


def _assert_struct_identical(a, b, msg=""):
    for k in STRUCT_KEYS:
        np.testing.assert_array_equal(getattr(a, k), getattr(b, k),
                                      err_msg=f"{msg}: forest.{k} differs")


def _assert_struct_close(a, b, min_frac=0.995, msg=""):
    """Near-identical structure: f32-vs-f64 rounding can flip the argmax at
    genuine gain ties (e.g. a category mirroring a numeric threshold, or
    adjacent near-equal bins), so a fraction of entries may differ — but only
    a tiny one, or something is actually broken."""
    for k in STRUCT_KEYS:
        x, y = getattr(a, k), getattr(b, k)
        frac = float((x == y).mean())
        assert frac >= min_frac, \
            f"{msg}: forest.{k} agrees on only {frac:.4f} of entries"


@pytest.fixture(scope="module")
def adult():
    return train_test_split(adult_like(900), 0.3, 1)


# ================================================================ device


@pytest.mark.parametrize("hp,strict", [
    (dict(), True),                                         # LOCAL, CART cats
    (dict(categorical_algorithm="ONE_HOT", max_depth=4), True),
    (dict(subsample=0.7, use_hessian_gain=True), False),    # bagging + dups
    (dict(l2_regularization=0.3, max_depth=3), True),
])
def test_gbt_device_matches_batched(adult, hp, strict):
    train, test = adult
    kw = dict(label="income", num_trees=4, validation_ratio=0.0,
              early_stopping="NONE", **hp)
    mb = GradientBoostedTreesLearner(**kw, growth_engine="batched").train(train)
    md = GradientBoostedTreesLearner(**kw, growth_engine="device").train(train)
    assert md.training_logs["growth_engine"] == "device"
    if strict:
        _assert_struct_identical(mb.forest, md.forest, str(hp))
        np.testing.assert_allclose(mb.forest.leaf_value, md.forest.leaf_value,
                                   atol=2e-5)
        np.testing.assert_allclose(mb.predict(test), md.predict(test),
                                   atol=1e-4)
    else:
        # a single f32 gain tie at a shallow node regrows that whole subtree
        # differently (equally good), so the contract here is predictive
        # equivalence, not node-for-node structure
        np.testing.assert_array_equal(mb.forest.n_nodes, md.forest.n_nodes)
        pb, pd = mb.predict(test), md.predict(test)
        assert np.abs(pb - pd).mean() < 2e-3
        assert ((pb > 0.5) == (pd > 0.5)).mean() > 0.99


def test_rf_device_matches_batched_including_sqrt_sampling(adult):
    """Keyed (hash-based) feature sampling is implemented identically in
    numpy and jnp, so the device engine reproduces the host engine's per-node
    feature subsets — SQRT sampling included."""
    train, test = adult
    kw = dict(label="income", num_trees=5, max_depth=7, compute_oob=False)
    mb = RandomForestLearner(**kw, growth_engine="batched").train(train)
    md = RandomForestLearner(**kw, growth_engine="device").train(train)
    assert md.training_logs["growth_engine"] == "device"
    _assert_struct_identical(mb.forest, md.forest, "rf sqrt")
    np.testing.assert_allclose(mb.predict(test), md.predict(test), atol=1e-4)


def test_rf_regression_device_matches_batched():
    train, test = train_test_split(make_dataset(SUITE[7]), 0.3, SUITE[7].seed)
    kw = dict(label="label", task=Task.REGRESSION, num_trees=3, max_depth=6,
              compute_oob=False)
    mb = RandomForestLearner(**kw, growth_engine="batched").train(train)
    md = RandomForestLearner(**kw, growth_engine="device").train(train)
    # moment scores (sum_y^2 / n) hit adjacent-bin f32 ties more often than
    # the other layouts — near-identical structure, close predictions
    _assert_struct_close(mb.forest, md.forest, msg="rf reg")
    pb, pd = mb.predict(test), md.predict(test)
    assert np.abs(pb - pd).mean() < 0.05 * max(1e-9, np.abs(pb).mean())


def test_multiclass_device_close_to_batched():
    """Multiclass switches categorical handling to one-hot (class stats with
    S > 3); entropy scores are more tie-prone under f32, so the contract here
    is allclose predictions rather than identical structure."""
    spec = SUITE[4]                                  # synth_vowel, 11 classes
    train, test = train_test_split(make_dataset(spec), 0.3, spec.seed)
    kw = dict(label="label", num_trees=4, max_depth=5, compute_oob=False)
    mb = RandomForestLearner(**kw, growth_engine="batched").train(train)
    md = RandomForestLearner(**kw, growth_engine="device").train(train)
    pb, pd = mb.predict(test), md.predict(test)
    assert (pb.argmax(1) == pd.argmax(1)).mean() > 0.97


def test_device_fallback_reasons(adult):
    train, _ = adult
    m = GradientBoostedTreesLearner(
        label="income", num_trees=2, growth_engine="device",
        growing_strategy="BEST_FIRST_GLOBAL").train(train)
    assert m.training_logs["growth_engine"] == "batched"
    assert "BEST_FIRST" in m.training_logs["engine_fallback"]
    m = RandomForestLearner(label="income", num_trees=2, compute_oob=False,
                            growth_engine="device",
                            categorical_algorithm="RANDOM").train(train)
    assert m.training_logs["growth_engine"] == "batched"
    assert "RANDOM" in m.training_logs["engine_fallback"]
    with pytest.raises(YdfError, match="growth engine"):
        GradientBoostedTreesLearner(label="income", num_trees=1,
                                    growth_engine="warp").train(train)


# ====================================================== lockstep blocks


def test_rf_lockstep_blocks_are_execution_only(adult):
    """tree_parallelism is semantics-free: any block size produces the same
    forest, and the lockstep batched engine matches the sequential oracle
    bit-for-bit (same keyed subsets, same f64 accumulation order)."""
    train, _ = adult
    kw = dict(label="income", num_trees=7, max_depth=8, compute_oob=False)
    ms = RandomForestLearner(**kw, growth_engine="oracle").train(train)
    for block in (1, 3, 8):
        mb = RandomForestLearner(**kw, growth_engine="batched",
                                 tree_parallelism=block).train(train)
        for k in ALL_KEYS:
            np.testing.assert_array_equal(
                getattr(ms.forest, k), getattr(mb.forest, k),
                err_msg=f"block={block}: forest.{k} differs")


# ==================================== fused kernel: f32 vs f64 argmax


def _random_frontier(seed, n=900, kf=4, n_slots=6, kind="gh"):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 256, (n, kf)).astype(np.uint8)
    g = rng.normal(size=n)
    w = rng.integers(0, 3, n).astype(np.float64)
    if kind == "gh":
        stats = np.stack([g * w, w, np.abs(g) * w, w], 1)
    elif kind == "class":
        c = rng.integers(0, 2, n)
        stats = np.stack([(c == 0) * w, (c == 1) * w, w], 1)
    else:
        stats = np.stack([g * w, np.square(g) * w, w], 1)
    slot = rng.integers(-1, n_slots, n).astype(np.int32)
    return codes, stats, slot


@pytest.mark.parametrize("kind", ["gh", "class", "moment"])
def test_fused_f32_argmax_matches_f64_scan(kind):
    """Property sweep: across random frontiers, the fused kernel's
    f32-accumulated gain argmax picks the same (feature, split_bin) as the
    f64 numpy histogram + f32 scan used by the host engines. Both fused
    implementations are swept — the jnp oracle on every seed and the actual
    Pallas kernel (interpret mode on CPU) on a subset, so a regression in
    the kernel's scoring/scan for any stat layout fails here, not just in
    the gh-kind end-to-end smoke."""
    from repro.kernels.histogram.ops import fused_best_split

    n_slots = 6
    for seed in range(8):
        codes, stats, slot = _random_frontier(100 * seed + 7, kind=kind)
        kf = codes.shape[1]
        hist64 = NumpyHistogramBackend().build(codes, stats, slot, n_slots)
        binned = BinnedFeatures(
            codes=codes, n_bins=np.full(kf, 256, np.int32),
            is_cat=np.zeros(kf, bool),
            boundaries=[np.arange(255, dtype=np.float32)] * kf,
            names=[f"f{j}" for j in range(kf)])
        sp = SplitterParams(stat_kind=kind, min_examples=5)
        ref = best_splits(hist64.astype(np.float32), binned, sp,
                          np.random.default_rng(0))
        impls = ("ref", "interpret") if seed < 3 else ("ref",)
        for impl in impls:
            gain, feat, sbin = map(np.asarray, fused_best_split(
                codes, stats.astype(np.float32), slot, n_slots,
                kind=kind, l2=0.0, min_examples=5, impl=impl))
            for i, s in enumerate(ref):
                if not s.valid:
                    assert gain[i] <= sp.min_gain or not np.isfinite(gain[i])
                    continue
                assert (feat[i], sbin[i]) == (s.feature, s.split_bin), \
                    (f"seed {seed} impl {impl} slot {i}: f32 argmax "
                     "diverged from f64 scan")


# ============================================ exact_subtraction gating


class _SpyBackend(NumpyHistogramBackend):
    """Numpy-exact accumulation with a configurable exact_subtraction flag
    and a log of how many (node, feature) histograms were built."""

    def __init__(self, exact: bool):
        self.exact_subtraction = exact
        self.built_nodes = 0

    def build(self, codes, stats, node_of, n_nodes, max_bins=256):
        self.built_nodes += int(n_nodes)
        return super().build(codes, stats, node_of, n_nodes, max_bins)


@pytest.mark.parametrize("strategy", ["LOCAL", "BEST_FIRST_GLOBAL"])
def test_exact_subtraction_gating_refuses_f32_backends(strategy):
    """A backend that does not accumulate in f64 must never be served the
    parent-minus-sibling subtraction: the grower rebuilds every histogram
    from scratch (strictly more built nodes), and forests stay identical
    because f64 subtraction is exact through the f32 cast."""
    train, _ = train_test_split(adult_like(3000), 0.3, 1)
    forests = {}
    spies = {}
    for exact in (True, False):
        spy = _SpyBackend(exact)
        m = GradientBoostedTreesLearner(
            label="income", num_trees=2, max_depth=4, validation_ratio=0.0,
            early_stopping="NONE", growing_strategy=strategy,
            histogram_backend=spy).train(train)
        forests[exact] = m.forest
        spies[exact] = spy
    assert spies[False].built_nodes > spies[True].built_nodes, \
        "f32 backend was served the subtraction trick"
    for k in ALL_KEYS:
        np.testing.assert_array_equal(getattr(forests[True], k),
                                      getattr(forests[False], k))


# ================================================= hist_backend guards


def test_auto_backend_pinned_to_numpy_on_cpu_hosts():
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("TPU host: auto resolves to pallas by design")
    assert resolve_backend("auto").name == "numpy"


def test_forced_pallas_without_device_raises():
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("TPU host: forced pallas is legitimate")
    with pytest.raises(YdfError, match="pallas_interpret"):
        resolve_backend("pallas")
    # the explicit opt-in still works (interpret-mode kernel)
    be = resolve_backend("pallas_interpret")
    assert be.name == "pallas" and be.interpret


# ========================================== interpret-mode CI smoke


def test_device_engine_interpret_smoke():
    """One tiny numerical-only tree through the device engine with the fused
    Pallas kernel in interpret mode — the tier-1 canary for kernel
    regressions on CPU-only CI (JAX_PLATFORMS=cpu)."""
    rng = np.random.default_rng(3)
    N, F = 200, 3
    X = rng.normal(size=(N, F))
    y = (X[:, 0] + 0.5 * X[:, 1] > 0).astype(np.float64)
    bounds = [np.sort(rng.choice(np.unique(X[:, j]), 31, replace=False))
              for j in range(F)]
    codes = np.stack([np.searchsorted(bounds[j], X[:, j], side="left")
                      for j in range(F)], 1).astype(np.uint8)
    binned = BinnedFeatures(
        codes=codes, n_bins=np.array([32] * F, np.int32),
        is_cat=np.zeros(F, bool),
        boundaries=[b.astype(np.float32) for b in bounds],
        names=[f"f{j}" for j in range(F)])
    stats = np.stack([y, np.ones(N), np.ones(N), np.ones(N)], 1)
    leaf_fn = lambda s: np.array([s[0] / max(s[-1], 1e-12)], np.float32)

    def grow(engine, impl="auto"):
        forest = empty_forest(1, 64, 1, feature_names=binned.names)
        gp = GrowthParams(max_depth=3, max_nodes=64,
                          splitter=SplitterParams(stat_kind="gh",
                                                  min_examples=5),
                          engine=engine, device_impl=impl)
        node_of = grow_tree(forest, 0, binned, X, stats, np.ones(N, bool),
                            leaf_fn, gp, np.random.default_rng(0))
        return forest, node_of

    fb, nb = grow("batched")
    fd, nd = grow("device", impl="interpret")
    for k in STRUCT_KEYS:
        np.testing.assert_array_equal(getattr(fb, k), getattr(fd, k),
                                      err_msg=f"forest.{k}")
    np.testing.assert_array_equal(nb, nd)
    np.testing.assert_allclose(fb.leaf_value, fd.leaf_value, atol=1e-5)
    assert fd.n_nodes[0] > 1, "smoke tree did not grow"


def test_resolve_engine_reports_fallback():
    gp = GrowthParams(engine="device",
                      splitter=SplitterParams(categorical_algorithm="RANDOM"))
    eng, reason = resolve_engine(gp)
    assert eng == "batched" and "RANDOM" in reason
    gp = GrowthParams(engine="device")
    assert resolve_engine(gp) == ("device", None)
