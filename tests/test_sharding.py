"""Logical-axis sharding resolution: divisibility fallback, axis reuse,
mesh-agnostic rules."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import (
    LONG_DECODE_RULES,
    TRAIN_RULES,
    resolve_spec,
    rules_for,
    tree_shardings,
)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_drops_non_dividing_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # shape-aware: kv_heads=2 can't shard over model-sized 1? use abstract test
    # via a fake mesh with axis sizes from mesh.shape — use the rule table.
    spec = resolve_spec(("embed", "kv_heads", None), mesh, TRAIN_RULES,
                        shape=(64, 2, 16))
    assert isinstance(spec, P)


def test_divisibility_logic_against_production_sizes():
    """Check the pure resolution logic against production axis sizes without
    building a 256-device mesh (device count is locked to 1 in tests)."""
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    # kv=8 doesn't divide 16 -> dropped; embed 8192 divides -> kept
    spec = resolve_spec(("embed", "kv_heads", "qkv"), m, TRAIN_RULES,
                        shape=(8192, 8, 128))
    assert spec == P("data", None, None)
    # heads=64 divides 16 -> kept
    spec = resolve_spec(("embed", "heads", "qkv"), m, TRAIN_RULES,
                        shape=(8192, 64, 128))
    assert spec == P("data", "model", None)
    # vocab 51866 (whisper) not divisible by 16 -> dropped
    spec = resolve_spec(("vocab", "embed"), m, TRAIN_RULES, shape=(51866, 1280))
    assert spec == P(None, "data")


def test_axis_used_once_per_spec():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    spec = resolve_spec(("batch", "seq", "embed"), FakeMesh(), TRAIN_RULES,
                        shape=(256, 4096, 1024))
    # batch takes pod+data; embed would also want data but it's used
    assert spec[0] == ("pod", "data")
    assert spec[2] is None


def test_long_decode_rules_shard_kv_len():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec = resolve_spec(("layers", "batch", "kv_len", "kv_heads", "qkv"),
                        FakeMesh(), LONG_DECODE_RULES,
                        shape=(54, 1, 524288, 32, 80))
    assert spec[2] == "data"   # flash-decoding style length sharding
    assert spec[1] is None     # batch=1 unshardable


def test_tree_shardings_with_shape_tree(mesh):
    specs = {"w": jax.ShapeDtypeStruct((8, 4), np.float32),
             "step": jax.ShapeDtypeStruct((), np.int32)}
    axes = {"w": ("embed", "mlp"), "step": ()}
    sh = tree_shardings(axes, mesh, TRAIN_RULES, specs)
    # size-1 axes divide everything -> named (but trivially replicated)
    assert sh["w"].spec == P("data", "model")
    assert sh["step"].spec == P()


def test_rules_for_modes():
    assert rules_for("train")["batch"] == ("pod", "data")
    assert rules_for("serve", long_context=True)["kv_len"] == ("pod", "data")
    # promoted default from §Perf hillclimb #2: decode caches shard their
    # length over 'model' (flash-decoding)
    assert rules_for("serve")["kv_len"] == ("model",)
    assert rules_for("train")["kv_len"] == ()
