"""Static policy check: all timing goes through ``repro.obs.clock``.

DESIGN.md §13.1: ad-hoc ``time.perf_counter()`` / ``time.time()`` call
sites are how profiling code rots — they cannot be faked in tests, and
their measurements never reach the tracer or the metrics registry. The
only sanctioned source of wall/perf time inside ``src/`` is
``repro.obs.clock`` (which owns the aliases) plus ``serving/faults.py``
(whose FakeClock/fault harness is itself a clock implementation).

``time.monotonic``/``time.sleep`` are NOT banned: monotonic deadlines and
actual sleeping are scheduling concerns, not measurements.
"""
from __future__ import annotations

import re
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parent.parent / "src"

# files allowed to touch the raw timers
ALLOWED = {
    "repro/obs/clock.py",       # the sanctioned aliases themselves
    "repro/serving/faults.py",  # clock implementations for fault injection
}

BANNED = re.compile(r"\btime\.(?:perf_counter|time)\s*\(")


@pytest.mark.obs
def test_no_stray_timers():
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        rel = path.relative_to(SRC).as_posix()
        if rel in ALLOWED:
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if BANNED.search(line):
                offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, (
        "raw time.perf_counter()/time.time() call sites found — use "
        "repro.obs.clock (perf/wall) so timing stays fakeable and "
        "observable:\n" + "\n".join(offenders))
