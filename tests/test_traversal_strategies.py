"""Differential traversal-strategy harness (DESIGN.md §10.5).

Every CPU traversal strategy — naive per-example loop, vectorized numpy,
depth-bucketed XLA scan, forced leaf-path matmul — must produce BIT-IDENTICAL
per-tree outputs on the same forest: they are four evaluation orders of one
function. The oracle is independent of the SoA engines entirely: typed
``py_tree`` trees (to_trees) walked by plain python conditions.

Covers: a forest zoo (depth-skewed, boosted stumps, all-categorical, ragged
mixed), every trained model family x task (RF/GBT/CART x cls/reg), the
engine-selection heuristic, CompiledPredictor pickling, and the
infer-bench ``--quick`` smoke on real data.
"""
import pickle

import numpy as np
import pytest

from repro.core import (
    CartLearner,
    GradientBoostedTreesLearner,
    RandomForestLearner,
    Task,
)
from repro.core.engines import (
    BUCKETED_MIN_WORK,
    available_engines,
    compile_predictor,
    select_cpu_engine,
)
from repro.core.py_tree import CategoricalIsIn, NumericalHigherThan
from repro.core.tree import (
    LEAF_PATH_BUDGET,
    compile_predict_raw,
    leaf_path_sizes,
    pack_depth_buckets,
    plan_depth_buckets,
    predict_naive,
    select_block_strategy,
    tree_depths,
)
from repro.kernels.forest_infer.ops import forest_predict_bucketed

from conftest import _make_random_forest

pytestmark = pytest.mark.traversal


# ------------------------------------------------------------ typed oracle

def _oracle_per_tree(forest, X):
    """Reference traversal over typed py_tree nodes — shares NO code with
    the SoA engines (different layout, different condition dispatch)."""
    trees = forest.to_trees()
    O = forest.leaf_value.shape[-1]
    out = np.zeros((len(X), forest.n_trees, O), np.float32)
    for t, tree in enumerate(trees):
        for n, x in enumerate(X):
            node = tree.root
            while not node.is_leaf:
                c = node.condition
                if isinstance(c, NumericalHigherThan):
                    go = bool(x[c.feature] >= np.float32(c.threshold))
                elif isinstance(c, CategoricalIsIn):
                    # numpy float->int semantics for garbage values (§10.2):
                    # NaN / +-inf / |x| >= 2^63 cast to INT64_MIN, THEN clip
                    # — so +inf lands on code 0, not 255
                    with np.errstate(invalid="ignore"):
                        code = int(np.clip(
                            np.float32(x[c.feature]).astype(np.int64), 0, 255))
                    go = code in c.categories
                else:  # pragma: no cover - zoo forests are axis-aligned
                    raise AssertionError(f"unexpected condition {c}")
                node = node.pos_child if go else node.neg_child
            out[n, t] = node.value.vector()
    return out


STRATEGIES = ("naive", "vectorized", "bucketed", "leaf_path")


def _per_tree(forest, X, strategy):
    if strategy == "naive":
        return predict_naive(forest, X)
    if strategy == "vectorized":
        return compile_predict_raw(forest)(X)
    if strategy == "bucketed":
        return forest_predict_bucketed(forest, X)
    return forest_predict_bucketed(forest, X, strategy="leaf_path")


def _assert_strategies_bit_identical(forest, X, oracle=True):
    X = np.ascontiguousarray(X, np.float32)
    want = _oracle_per_tree(forest, X) if oracle \
        else np.asarray(_per_tree(forest, X, "naive"))
    for strategy in STRATEGIES:
        if strategy == "leaf_path":
            i, l = leaf_path_sizes(forest)
            if i * l > LEAF_PATH_BUDGET:
                continue
        got = np.asarray(_per_tree(forest, X, strategy))
        assert got.shape == want.shape, strategy
        assert np.array_equal(got, want), \
            f"strategy {strategy!r} diverges from the typed-tree oracle"


def _inputs_for(forest, n, seed=5, cat_feats=(), n_cats=300):
    """Serving inputs including the hostile numerics: NaN / +-inf / huge on
    numerical AND categorical columns — every strategy and the oracle share
    numpy's float->int cast-then-clip semantics for garbage codes (§10.2),
    so hostile categorical values are part of the bit-identity contract."""
    rng = np.random.default_rng(seed)
    F = len(forest.feature_names)
    X = (rng.normal(size=(n, F)) * 2).astype(np.float32)
    for j in cat_feats:
        X[:, j] = rng.integers(-2, n_cats, size=n)
    num = [j for j in range(F) if j not in cat_feats]
    if num and n >= 8:
        X[0, num[0]] = np.nan
        X[1, num[0]] = np.inf
        X[2, num[0]] = -np.inf
        X[3, num[0]] = 3e38
    if cat_feats and n >= 8:
        X[4, cat_feats[0]] = np.nan
        X[5, cat_feats[0]] = np.inf
        X[6, cat_feats[0]] = -np.inf
        X[7, cat_feats[0]] = 3e38      # >= 2^63: cast-then-clip, not clip-255
    return X


# ---------------------------------------------------------------- forest zoo

def test_depth_skewed_forest_all_strategies(depth_skewed_forest):
    assert sorted(set(tree_depths(depth_skewed_forest))) == [2, 12]
    X = _inputs_for(depth_skewed_forest, 64)
    _assert_strategies_bit_identical(depth_skewed_forest, X)


def test_stump_forest_all_strategies(stump_forest):
    assert set(tree_depths(stump_forest)) == {0}
    X = _inputs_for(stump_forest, 32)
    _assert_strategies_bit_identical(stump_forest, X)
    # stumps must carry their root leaf value, not silent zeros
    assert np.abs(_oracle_per_tree(stump_forest, X[:1])).sum() > 0


def test_all_categorical_forest_all_strategies(all_categorical_forest):
    X = _inputs_for(all_categorical_forest, 64, cat_feats=(0, 1, 2, 3))
    _assert_strategies_bit_identical(all_categorical_forest, X)


def test_ragged_mixed_forest_all_strategies():
    forest = _make_random_forest(15, [0, 1, 4, 9, 6], 7, out_dim=3, seed=31,
                                 cat_feats=(2, 5))
    X = _inputs_for(forest, 48, cat_feats=(2, 5))
    _assert_strategies_bit_identical(forest, X)


def test_zero_and_one_row_batches(depth_skewed_forest):
    f = depth_skewed_forest
    for strategy in STRATEGIES:
        empty = np.asarray(_per_tree(f, np.zeros((0, 6), np.float32), strategy))
        assert empty.shape == (0, f.n_trees, 1)
    _assert_strategies_bit_identical(f, _inputs_for(f, 1))


# ------------------------------------------- trained models: family x task

def _trained_models(tiny_adult):
    reg = dict(tiny_adult)
    cls = dict(tiny_adult)
    models = []
    for fam, learner in (("rf", RandomForestLearner),
                         ("gbt", GradientBoostedTreesLearner),
                         ("cart", CartLearner)):
        kw = {} if fam == "cart" else {"num_trees": 6}
        models.append((f"{fam}_cls",
                       learner(label="income", **kw).train(cls)))
        models.append((f"{fam}_reg",
                       learner(label="age", task=Task.REGRESSION,
                               **kw).train(reg)))
    return models


def test_trained_model_matrix_bit_identical(tiny_adult):
    """RF/GBT/CART x classification/regression: every strategy bit-equals
    the typed-tree oracle on encoded real data, and the full predict()
    head agrees across engines."""
    for name, model in _trained_models(tiny_adult):
        pred = compile_predictor(model, "naive")
        X = pred.encode(tiny_adult)[:80]
        _assert_strategies_bit_identical(model.forest, X)
        base = compile_predictor(model, "vectorized").predict_encoded(X)
        for engine in ("bucketed", "naive"):
            got = compile_predictor(model, engine).predict_encoded(X)
            assert np.array_equal(np.asarray(got), np.asarray(base)), \
                (name, engine)


def test_task_model_matrix_bit_identical():
    """Ranking/uplift/anomaly models (DESIGN.md §12) serve bit-identically:
    every traversal strategy bit-equals the typed-tree oracle, and the full
    predict head agrees across compiled engines."""
    from repro.data.tabular import grouped_relevance, planted_anomaly, \
        randomized_treatment
    from repro.tasks import IsolationForestLearner, UpliftTreesLearner
    ds_r = grouped_relevance(n_groups=30, seed=7)
    ds_u = randomized_treatment(n=400, seed=11)
    ds_a = planted_anomaly(n_inlier=150, n_anomaly=8, seed=13)
    models = [
        ("ranking", GradientBoostedTreesLearner(
            label="rel", task=Task.RANKING, num_trees=6,
            seed=1).train(ds_r), ds_r),
        ("uplift", UpliftTreesLearner(
            label="outcome", num_trees=4, seed=2).train(ds_u), ds_u),
        ("anomaly", IsolationForestLearner(
            label="anomaly", num_trees=6, seed=3).train(ds_a), ds_a),
    ]
    for name, model, data in models:
        pred = compile_predictor(model, "naive")
        X = pred.encode(data)[:80]
        _assert_strategies_bit_identical(model.forest, X)
        base = compile_predictor(model, "vectorized").predict_encoded(X)
        for engine in ("bucketed", "naive"):
            got = compile_predictor(model, engine).predict_encoded(X)
            assert np.array_equal(np.asarray(got), np.asarray(base)), \
                (name, engine)


# ------------------------------------------------- selection heuristic (§10.3)

def test_select_cpu_engine_pins():
    shallow = _make_random_forest(8, [3], 4, seed=1, chain=True)     # work 24
    deep = _make_random_forest(40, [12], 4, seed=2, chain=True)      # work 480
    mixed = _make_random_forest(30, [2, 12], 4, seed=3, chain=True)  # work 360
    assert select_cpu_engine(shallow) == "vectorized"
    assert select_cpu_engine(deep) == "bucketed"
    assert select_cpu_engine(mixed) == "bucketed"
    # the boundary is n_trees * max depth, not forest.depth metadata
    assert 8 * 3 < BUCKETED_MIN_WORK <= 40 * 12


def test_select_block_strategy_pins():
    # CPU cost model: the scan wins at EVERY depth (measured, §10.3)
    for depth in (0, 1, 2, 6, 12):
        assert select_block_strategy(depth, 2 ** max(1, depth) - 1,
                                     2 ** max(1, depth)) == "scan"
    # an MXU-class backend flips shallow, small-table buckets to leaf_path
    assert select_block_strategy(2, 3, 4, matmul_cheap=True) == "leaf_path"
    assert select_block_strategy(6, 63, 64, matmul_cheap=True) == "leaf_path"
    assert select_block_strategy(12, 4095, 4096,
                                 matmul_cheap=True) == "scan"  # depth gate
    assert select_block_strategy(
        4, 200, 200, matmul_cheap=True) == "scan"  # budget gate: 40k > 2^14


def test_plan_depth_buckets_partition_and_bounds():
    depths = np.array([2] * 12 + [12] * 12 + [5] * 3 + [0] * 2)
    buckets = plan_depth_buckets(depths)
    assert 1 <= len(buckets) <= 4
    assert all(len(b) >= 8 for b in buckets)
    got = np.sort(np.concatenate(buckets))
    assert np.array_equal(got, np.arange(len(depths)))  # exact partition
    # bucket depth ceilings ascend: shallow trees never pay deep rounds
    ceilings = [depths[b].max() for b in buckets]
    assert ceilings == sorted(ceilings)
    assert plan_depth_buckets(np.zeros(0, np.int32)) == []


def test_pack_depth_buckets_layout(depth_skewed_forest):
    bf = pack_depth_buckets(depth_skewed_forest)
    assert len(bf.buckets) == 2
    assert [b.depth for b in bf.buckets] == [2, 12]  # per-bucket early exit
    assert all(b.strategy == "scan" for b in bf.buckets)  # CPU cost model
    # forcing leaf_path is the benchmark/TPU escape hatch
    bf_lp = pack_depth_buckets(depth_skewed_forest, strategy="leaf_path")
    assert all(b.strategy == "leaf_path" for b in bf_lp.buckets)
    # inv_order really restores original tree order
    order = np.concatenate([b.trees for b in bf.buckets])
    assert np.array_equal(order[bf.inv_order],
                          np.arange(depth_skewed_forest.n_trees))


def test_available_engines_gates():
    shallow = _make_random_forest(6, [2], 4, seed=7)
    assert available_engines(shallow) == [
        "pallas", "bucketed", "leaf_path", "vectorized", "naive"]
    big = _make_random_forest(2, [400], 4, seed=8)  # leaf-path table blowup
    engines = available_engines(big)
    assert "leaf_path" not in engines and "bucketed" in engines


# ------------------------------------------------------ predictor pickling

def test_compiled_predictor_pickle_round_trip(tiny_adult):
    model = RandomForestLearner(label="income", num_trees=5,
                                max_depth=6).train(tiny_adult)
    for engine in ("vectorized", "bucketed"):
        pred = compile_predictor(model, engine)
        clone = pickle.loads(pickle.dumps(pred))
        # the regression this pins: the CHOSEN engine survives the
        # round-trip instead of falling back to a fresh heuristic run
        assert clone.name == pred.name == engine
        X = pred.encode(tiny_adult)[:40]
        assert np.array_equal(np.asarray(clone.predict_encoded(X)),
                              np.asarray(pred.predict_encoded(X)))
        assert clone.out_shape == pred.out_shape


def test_engine_auto_pickle_keeps_choice(tiny_adult):
    model = GradientBoostedTreesLearner(label="income",
                                        num_trees=4).train(tiny_adult)
    pred = compile_predictor(model)  # heuristic picks (small model -> numpy)
    clone = pickle.loads(pickle.dumps(pred))
    assert clone.name == pred.name


# ------------------------------------------------------- bench quick smoke

def test_infer_bench_quick_smoke_strategies():
    """The ``--quick`` bench path on real data: every CPU strategy column
    present, timed, and allclose against the seed predict path."""
    from benchmarks import infer_bench
    res = infer_bench.run_smoke()
    for cfg_name in ("gbt_adult", "rf_adult"):
        after = res["configs"][cfg_name]["after"]
        assert "bucketed" in after and "vectorized" in after
        for ename, a in after.items():
            assert a["allclose"] is True, (cfg_name, ename)
            assert a["us_example"] > 0
    sk = res["configs"].get("sklearn_import")
    if sk is not None:
        assert "bucketed" in sk["strategies"]
        for ename, a in sk["strategies"].items():
            assert a["allclose"] is True, ename
        assert sk["speedup_vs_sklearn"] == max(
            a["speedup_vs_sklearn"] for a in sk["strategies"].values())
