"""Meta-learners (§3.2): tuner, ensembler, calibrator, feature selector —
including composition (Fig. 3)."""
import numpy as np
import pytest

from repro.core import (
    Calibrator,
    Ensembler,
    FeatureSelector,
    GradientBoostedTreesLearner,
    HyperParameterTuner,
    RandomForestLearner,
    cross_validate,
)
from repro.core.metalearners import kfold_indices
from repro.data.tabular import adult_like, train_test_split


@pytest.fixture(scope="module")
def adult():
    return train_test_split(adult_like(1200), 0.3, 1)


def _gbt_factory(**kw):
    kw.setdefault("num_trees", 12)
    return GradientBoostedTreesLearner(**kw)


def test_tuner_finds_depth_on_xor():
    """On XOR, depth-1 boosting cannot learn — the tuner must discover it."""
    rng = np.random.default_rng(0)
    a, b = rng.normal(size=700), rng.normal(size=700)
    y = np.where((a > 0) ^ (b > 0), "pos", "neg")
    data = {"a": a.astype(object), "b": b.astype(object), "y": y.astype(object)}
    train, test = train_test_split(data, 0.3, 0)
    bad = GradientBoostedTreesLearner(label="y", num_trees=12,
                                      max_depth=1).train(train)
    tuner = HyperParameterTuner(
        _gbt_factory, {"max_depth": [1, 4], "shrinkage": [0.1, 0.3]},
        label="y", n_trials=4, metric="accuracy", seed=3)
    tuned = tuner.train(train)
    assert tuned.tuning_logs["best"]["max_depth"] > 1
    assert tuned.evaluate(test)["accuracy"] > bad.evaluate(test)["accuracy"] + 0.2


def test_ensembler_averages(adult):
    train, test = adult
    ens = Ensembler([
        GradientBoostedTreesLearner(label="income", num_trees=8, seed=1),
        RandomForestLearner(label="income", num_trees=6, seed=2),
    ], label="income")
    model = ens.train(train)
    p = model.predict(test)
    a = model.models[0].predict(test)
    b = model.models[1].predict(test)
    np.testing.assert_allclose(p, (a + b) / 2, atol=1e-6)


def test_calibrator_improves_logloss_of_miscalibrated_model(adult):
    train, test = adult
    # winner-take-all RF with few trees gives hard 0/1-ish probabilities
    # -> badly miscalibrated logloss that Platt scaling must repair
    base = lambda **kw: RandomForestLearner(num_trees=5, winner_take_all=True,
                                            **kw)
    raw = base(label="income").train(train)
    cal = Calibrator(base(label="income"), label="income", seed=5).train(train)
    ll_raw = raw.evaluate(test)["logloss"]
    ll_cal = cal.evaluate(test)["logloss"]
    assert ll_cal < ll_raw


def test_feature_selector_drops_noise(adult):
    rng = np.random.default_rng(0)
    train, test = adult
    # a low-cardinality categorical noise column: a continuous one draws
    # hundreds of deep overfit splits in fully-grown RF trees (NUM_NODES
    # importance bias), which tests the importance heuristic, not selection
    train = dict(train, pure_noise=rng.choice(
        np.array(["a", "b", "c", "d"], object), size=len(train["income"])))
    # 16 trees for stable-ish OOB scores; 1% tolerance because single-removal
    # OOB deltas on ~800 rows move +-1% between refits — zero-tolerance
    # elimination stalls on that noise rather than on the features' value
    fs = FeatureSelector(lambda **kw: RandomForestLearner(num_trees=16, **kw),
                         label="income", tolerance=0.01)
    model = fs.train(train)
    assert "pure_noise" in model.removed_features or \
        "pure_noise" not in model.selected_features


def test_metalearner_composition(adult):
    """Fig. 3: calibrator(ensembler(tuner(GBT), RF))."""
    train, test = adult
    tuner = HyperParameterTuner(_gbt_factory, {"max_depth": [3, 6]},
                                label="income", n_trials=2, seed=1)
    ens = Ensembler([tuner, RandomForestLearner(label="income", num_trees=6)],
                    label="income")
    cal = Calibrator(ens, label="income")
    model = cal.train(train)
    ev = model.evaluate(test)
    assert ev["accuracy"] > 0.7


def test_cross_validation_folds_are_learner_independent():
    f1 = kfold_indices(100, 5, seed=7)
    f2 = kfold_indices(100, 5, seed=7)
    for (a, b), (c, d) in zip(f1, f2):
        np.testing.assert_array_equal(a, c)
        np.testing.assert_array_equal(b, d)
    # folds partition the data
    all_va = np.sort(np.concatenate([va for _, va in f1]))
    np.testing.assert_array_equal(all_va, np.arange(100))


def test_cross_validate_runs(adult):
    train, _ = adult
    evals = cross_validate(
        lambda: GradientBoostedTreesLearner(label="income", num_trees=5),
        train, k=3)
    assert len(evals) == 3
    assert all(0.5 < e["accuracy"] <= 1.0 for e in evals)
