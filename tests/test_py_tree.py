"""Typed tree API (DESIGN.md §7): SoA round-trips, validation, builder,
inspector."""
import numpy as np
import pytest

from repro.core.api import Task, YdfError
from repro.core.py_tree import (
    CartBuilder,
    CategoricalIsIn,
    GradientBoostedTreesBuilder,
    Leaf,
    LogitValue,
    NonLeaf,
    NumericalHigherThan,
    Oblique,
    ProbabilityValue,
    RandomForestBuilder,
    RegressionValue,
    Tree,
    forest_from_trees,
    forest_to_trees,
)
from repro.core.tree import Forest, predict_raw


def assert_forest_equal(a: Forest, b: Forest) -> None:
    for f in ("feature", "threshold", "split_bin", "cat_mask", "left_child",
              "leaf_value", "n_nodes"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.depth == b.depth
    assert a.out_dim == b.out_dim
    assert (a.tree_class is None) == (b.tree_class is None)
    if a.tree_class is not None:
        assert np.array_equal(a.tree_class, b.tree_class)
    assert np.array_equal(a.init_pred, b.init_pred)
    assert a.feature_names == b.feature_names
    for f in ("obl_weights", "obl_features"):
        x, y = getattr(a, f), getattr(b, f)
        assert (x is None) == (y is None), f
        if x is not None:
            assert np.array_equal(x, y), f


def roundtrip(forest: Forest) -> Forest:
    return Forest.from_trees(forest.to_trees(), like=forest)


# ------------------------------------------------------------- round-trips

def test_roundtrip_factory_forests_bit_identical(random_forest_factory):
    # random split orders exercise non-BFS split_order hints
    f = random_forest_factory(6, [9, 3, 17], 7, out_dim=3, seed=3,
                              cat_feats=(2, 5))
    assert_forest_equal(f, roundtrip(f))


@pytest.mark.parametrize("seed", range(5))
def test_roundtrip_property_sweep(random_forest_factory, seed):
    f = random_forest_factory(4, [1 + seed, 2 * seed + 3], 5,
                              out_dim=1 + seed % 3, seed=seed,
                              cat_feats=(0,) if seed % 2 else ())
    assert_forest_equal(f, roundtrip(f))


def test_roundtrip_single_leaf_tree(random_forest_factory):
    f = random_forest_factory(2, [0], 3)
    assert_forest_equal(f, roundtrip(f))


def test_roundtrip_trained_forests(tiny_adult):
    from repro.core import GradientBoostedTreesLearner, RandomForestLearner
    rf = RandomForestLearner(label="income", num_trees=5, max_depth=5,
                             compute_oob=False).train(tiny_adult)
    assert_forest_equal(rf.forest, roundtrip(rf.forest))
    gbt = GradientBoostedTreesLearner(label="income", num_trees=4,
                                      max_depth=4).train(tiny_adult)
    assert_forest_equal(gbt.forest, roundtrip(gbt.forest))


def test_roundtrip_oblique_forest(tiny_adult):
    from repro.core import RandomForestLearner
    m = RandomForestLearner(label="income", num_trees=4, max_depth=5,
                            split_axis="SPARSE_OBLIQUE",
                            compute_oob=False).train(tiny_adult)
    assert m.forest.has_oblique()
    f2 = roundtrip(m.forest)
    assert_forest_equal(m.forest, f2)
    trees = m.forest.to_trees()
    assert any(isinstance(n.condition, Oblique)
               for tr in trees for n, _ in tr.iter_nodes() if not n.is_leaf)


def test_pruned_cart_roundtrip_semantics_then_idempotent(tiny_adult):
    # reduced-error pruning leaves unreachable slots + stale condition
    # fields: the first round-trip COMPACTS (same predictions, canonical
    # allocation), after which round-trips are bit-identical
    from repro.core import CartLearner
    from repro.core.models import _as_vertical, raw_matrix
    m = CartLearner(label="income", max_depth=8).train(tiny_adult)
    f = m.forest
    f2 = Forest.from_trees(f.to_trees(), like=f)
    X = raw_matrix(_as_vertical(tiny_adult), m.features)
    np.testing.assert_array_equal(predict_raw(f, X), predict_raw(f2, X))
    assert_forest_equal(f2, Forest.from_trees(f2.to_trees(), like=f2))


def test_roundtrip_without_like_is_semantically_equal(random_forest_factory):
    f = random_forest_factory(3, [6, 2], 5, out_dim=2, seed=9, cat_feats=(1,))
    f2 = Forest.from_trees(f.to_trees())
    X = np.random.default_rng(0).normal(size=(50, 5)).astype(np.float32)
    X[:, 1] = np.random.default_rng(1).integers(0, 8, 50)
    np.testing.assert_array_equal(predict_raw(f, X), predict_raw(f2, X))


def test_hand_written_trees_get_level_order_allocation():
    tree = Tree(root=NonLeaf(
        condition=NumericalHigherThan(feature=0, threshold=1.0),
        pos_child=Leaf(RegressionValue(2.0)),
        neg_child=NonLeaf(condition=NumericalHigherThan(feature=1, threshold=-1.0),
                          pos_child=Leaf(RegressionValue(1.0)),
                          neg_child=Leaf(RegressionValue(0.0)))))
    f = forest_from_trees([tree])
    assert f.n_nodes[0] == 5 and f.depth == 2
    assert f.left_child[0, 0] == 1   # root splits first -> children at 1, 2
    X = np.array([[2.0, 0.0], [0.0, 0.0], [0.0, -2.0]], np.float32)
    np.testing.assert_allclose(predict_raw(f, X)[:, 0, 0], [2.0, 1.0, 0.0])


def test_edit_that_deepens_tree_raises_traversal_bound(random_forest_factory):
    # like= copies layout metadata, but depth must track the DEEPENED tree:
    # otherwise predict_raw stops above the new leaves (silent truncation)
    f = random_forest_factory(1, [1], 2, seed=0)  # single root split, depth 1
    trees = f.to_trees()
    leaf = trees[0].root.pos_child
    assert leaf.is_leaf
    trees[0].root.pos_child = NonLeaf(
        condition=NumericalHigherThan(feature=1, threshold=0.0),
        pos_child=Leaf(RegressionValue(4.0)), neg_child=leaf)
    f2 = Forest.from_trees(trees, like=f, max_nodes=8)
    assert f2.depth == 2
    X = np.full((1, 2), 10.0, np.float32)
    np.testing.assert_allclose(predict_raw(f2, X)[:, 0, 0], [4.0])


def test_split_order_preserved_over_edit_roundtrip(random_forest_factory):
    # editing one leaf must not perturb the rest of the SoA
    f = random_forest_factory(2, [8], 4, seed=5)
    trees = f.to_trees()
    node = trees[0].root
    while not node.is_leaf:
        node = node.pos_child
    node.value = RegressionValue(123.0)
    f2 = Forest.from_trees(trees, like=f)
    assert not np.array_equal(f.leaf_value, f2.leaf_value)
    for fld in ("feature", "threshold", "left_child", "n_nodes"):
        assert np.array_equal(getattr(f, fld), getattr(f2, fld))


# --------------------------------------------------------------- validation

def test_from_trees_rejects_empty_categorical_set():
    t = Tree(root=NonLeaf(condition=CategoricalIsIn(feature=0, categories=()),
                          pos_child=Leaf(RegressionValue(1.0)),
                          neg_child=Leaf(RegressionValue(0.0))))
    with pytest.raises(YdfError, match="empty category set"):
        forest_from_trees([t])


def test_from_trees_rejects_out_of_range_category():
    t = Tree(root=NonLeaf(condition=CategoricalIsIn(feature=0, categories=(999,)),
                          pos_child=Leaf(RegressionValue(1.0)),
                          neg_child=Leaf(RegressionValue(0.0))))
    with pytest.raises(YdfError, match=r"\[0, 255\]"):
        forest_from_trees([t])


def test_from_trees_rejects_bad_feature_reference():
    t = Tree(root=NonLeaf(condition=NumericalHigherThan(feature=7, threshold=0.0),
                          pos_child=Leaf(RegressionValue(1.0)),
                          neg_child=Leaf(RegressionValue(0.0))))
    with pytest.raises(YdfError, match="only 2 input feature"):
        forest_from_trees([t], feature_names=["a", "b"])


def test_from_trees_enforces_node_budget():
    t = Tree(root=NonLeaf(condition=NumericalHigherThan(feature=0, threshold=0.0),
                          pos_child=Leaf(RegressionValue(1.0)),
                          neg_child=Leaf(RegressionValue(0.0))))
    with pytest.raises(YdfError, match="node budget"):
        forest_from_trees([t], max_nodes=1)


def test_from_trees_rejects_leaf_dim_mismatch():
    t = Tree(root=NonLeaf(condition=NumericalHigherThan(feature=0, threshold=0.0),
                          pos_child=Leaf(ProbabilityValue((0.5, 0.5))),
                          neg_child=Leaf(RegressionValue(0.0))))
    with pytest.raises(YdfError, match="dimension"):
        forest_from_trees([t])


def test_from_trees_rejects_shared_subtrees():
    shared = Leaf(RegressionValue(1.0))
    t = Tree(root=NonLeaf(condition=NumericalHigherThan(feature=0, threshold=0.0),
                          pos_child=shared, neg_child=shared))
    with pytest.raises(YdfError, match="not DAGs"):
        forest_from_trees([t])


def test_from_trees_rejects_oblique_arity_mismatch():
    t = Tree(root=NonLeaf(
        condition=Oblique(features=(0, 1), weights=(1.0,), threshold=0.0),
        pos_child=Leaf(RegressionValue(1.0)),
        neg_child=Leaf(RegressionValue(0.0))))
    with pytest.raises(YdfError, match="weight"):
        forest_from_trees([t])


# ------------------------------------------------------------------ builder

def _rf_builder():
    return RandomForestBuilder(
        label="y", task=Task.CLASSIFICATION, classes=["no", "yes"],
        features=["age", ("color", "CATEGORICAL", ["red", "blue"])])


def test_builder_end_to_end_with_categorical_strings():
    b = _rf_builder()
    b.add_tree(NonLeaf(
        condition=CategoricalIsIn(feature=1, categories=("red",)),
        pos_child=Leaf(ProbabilityValue((0.2, 0.8))),
        neg_child=NonLeaf(
            condition=NumericalHigherThan(feature=0, threshold=30.0),
            pos_child=Leaf(ProbabilityValue((0.5, 0.5))),
            neg_child=Leaf(ProbabilityValue((0.9, 0.1))))))
    model = b.build()
    p = model.predict({"age": [25, 40, 10], "color": ["red", "blue", "blue"]})
    np.testing.assert_allclose(p, [[0.2, 0.8], [0.5, 0.5], [0.9, 0.1]],
                               atol=1e-6)
    # missing categorical imputes most-frequent (code 1 == "red"), missing
    # numerical imputes the declared mean — exactly like trained models
    p2 = model.predict({"age": [None], "color": [None]})
    np.testing.assert_allclose(p2, [[0.2, 0.8]], atol=1e-6)
    assert model.predict_class({"age": [25], "color": ["red"]})[0] == 1


def test_builder_model_serves_through_engines_and_bundle():
    from repro.serving.forest import make_forest_server
    b = _rf_builder()
    b.add_tree(NonLeaf(
        condition=NumericalHigherThan(feature=0, threshold=30.0),
        pos_child=Leaf(ProbabilityValue((0.1, 0.9))),
        neg_child=Leaf(ProbabilityValue((0.7, 0.3)))))
    model = b.build()
    batch = {"age": [10, 50], "color": ["red", "blue"]}
    ref = model.predict(batch)
    for engine in ("vectorized", "naive", "pallas"):
        model.compile(engine)
        np.testing.assert_allclose(model.predict(batch), ref, atol=1e-6)
    bundle = make_forest_server(model, "vectorized")
    np.testing.assert_allclose(bundle.predict(batch), ref, atol=1e-6)


def test_builder_validates_probability_sums():
    b = _rf_builder()
    b.add_tree(Leaf(ProbabilityValue((0.9, 0.9))))
    with pytest.raises(YdfError, match="sums to"):
        b.build()


def test_builder_requires_classes_for_classification():
    with pytest.raises(YdfError, match="classes"):
        RandomForestBuilder(label="y", features=["a"], classes=None)


def test_builder_rejects_unknown_category_string():
    b = _rf_builder()
    b.add_tree(NonLeaf(
        condition=CategoricalIsIn(feature=1, categories=("green",)),
        pos_child=Leaf(ProbabilityValue((0.5, 0.5))),
        neg_child=Leaf(ProbabilityValue((0.5, 0.5)))))
    with pytest.raises(YdfError, match="green"):
        b.build()


def test_cart_builder_single_tree_only():
    b = CartBuilder(label="y", task=Task.REGRESSION, features=["x"])
    b.add_tree(Leaf(RegressionValue(1.0)))
    b.add_tree(Leaf(RegressionValue(2.0)))
    with pytest.raises(YdfError, match="exactly one"):
        b.build()


def test_gbt_builder_binary_and_multiclass():
    b = GradientBoostedTreesBuilder(
        label="y", task=Task.CLASSIFICATION, classes=["a", "b"],
        features=["x"], init_pred=[0.5])
    b.add_tree(NonLeaf(condition=NumericalHigherThan(feature=0, threshold=0.0),
                       pos_child=Leaf(LogitValue(1.0)),
                       neg_child=Leaf(LogitValue(-1.0))))
    m = b.build()
    p = m.predict({"x": [2.0, -2.0]})
    sig = 1 / (1 + np.exp(-(0.5 + np.array([1.0, -1.0]))))
    np.testing.assert_allclose(p[:, 1], sig, atol=1e-6)

    b3 = GradientBoostedTreesBuilder(
        label="y", task=Task.CLASSIFICATION, classes=["a", "b", "c"],
        features=["x"])
    with pytest.raises(YdfError, match="tree_class"):
        b3.add_tree(Leaf(LogitValue(0.0)))
        b3.build()
    b3.trees.clear()
    for k in range(3):
        b3.add_tree(Leaf(LogitValue(float(k))), tree_class=k)
    p3 = b3.build().predict({"x": [0.0]})
    z = np.array([0.0, 1.0, 2.0])
    np.testing.assert_allclose(p3[0], np.exp(z) / np.exp(z).sum(), atol=1e-6)


# ---------------------------------------------------------------- inspector

def test_inspector_stats_and_render(tiny_adult):
    from repro.core import RandomForestLearner
    m = RandomForestLearner(label="income", num_trees=3, max_depth=4,
                            compute_oob=False).train(tiny_adult)
    insp = m.inspect()
    stats = insp.tree_stats()
    assert len(stats) == 3
    for s in stats:
        assert s["n_nodes"] == 2 * s["n_leaves"] - 1
        assert s["depth"] <= 4
    art = insp.plot_tree(0, max_depth=3)
    assert "(pos)" in art and "(neg)" in art
    assert any(f'"{f}"' in art for f in m.features)
    # probability leaves name the classes
    assert any(c in art for c in m.classes) or "max_depth reached" in art
    verbose = m.summary(verbose=2)
    assert "Tree depths:" in verbose and "Tree #0" in verbose
    assert insp.tree(0).n_leaves >= 2
    with pytest.raises(YdfError, match="out of range"):
        insp.tree(99)


def test_inspector_value_kinds(tiny_adult):
    from repro.core import GradientBoostedTreesLearner
    m = GradientBoostedTreesLearner(label="income", num_trees=2,
                                    max_depth=3).train(tiny_adult)
    leaf = m.inspect().tree(0).leaves()[0]
    assert isinstance(leaf.value, LogitValue)


def test_to_trees_value_kind_matches_leaf_dim(random_forest_factory):
    f = random_forest_factory(1, [2], 3, out_dim=2)
    trees = forest_to_trees(f)
    assert isinstance(trees[0].leaves()[0].value, ProbabilityValue)
    f1 = random_forest_factory(1, [2], 3, out_dim=1)
    assert isinstance(forest_to_trees(f1)[0].leaves()[0].value,
                      RegressionValue)
