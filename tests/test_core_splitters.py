"""Splitter correctness: histogram splitter vs the exact in-sorting oracle
(paper §2.3: simple module == ground truth), categorical CART vs brute force,
property-based invariants."""
import numpy as np
import pytest

from repro.core.binning import BinnedFeatures, bin_features
from repro.core.dataspec import dataset_from_raw
from repro.core.splitters import (
    SplitterParams,
    best_splits,
    build_histogram,
    exact_best_split_numerical,
)


def _gh_stats(rng, n):
    g = rng.normal(size=n)
    h = np.abs(rng.normal(size=n)) + 0.1
    return np.stack([g, h, np.ones(n)], 1)


def test_histogram_splitter_matches_exact_oracle():
    """With unique-value bin boundaries the histogram gain == exact gain."""
    rng = np.random.default_rng(0)
    n = 200
    x = rng.choice(np.linspace(-2, 2, 37), size=n)  # few unique values
    stats = _gh_stats(rng, n)
    params = SplitterParams(stat_kind="gh", min_examples=2, min_gain=-np.inf)

    ds = dataset_from_raw({"x": x.astype(object), "y": np.ones(n, object)})
    binned = bin_features(ds, ["x"])
    hist = build_histogram(binned.codes, stats, np.zeros(n, np.int32), 1)
    split = best_splits(hist, binned, params, np.random.default_rng(1))[0]

    gain_exact, thr_exact = exact_best_split_numerical(x, stats, params)
    assert split.feature == 0
    np.testing.assert_allclose(split.gain, gain_exact, rtol=1e-4)
    # both thresholds must induce the same partition
    np.testing.assert_array_equal(x >= split.threshold + 1e-12,
                                  x > thr_exact)


def test_categorical_cart_binary_is_optimal():
    """Fisher-ordered prefix scan == brute force over all subsets (binary)."""
    rng = np.random.default_rng(2)
    n, V = 300, 6
    codes = rng.integers(0, V, n).astype(np.uint8)
    stats = _gh_stats(rng, n)
    params = SplitterParams(stat_kind="gh", min_examples=1, min_gain=-np.inf,
                            categorical_algorithm="CART")
    binned = BinnedFeatures(codes=codes[:, None], n_bins=np.array([V]),
                            is_cat=np.array([True]), boundaries=[None],
                            names=["c"])
    hist = build_histogram(binned.codes, stats, np.zeros(n, np.int32), 1, V)
    split = best_splits(hist, binned, params, np.random.default_rng(0))[0]

    # brute force all 2^V subsets
    def gain_of(mask):
        right = np.isin(codes, mask)
        if right.all() or (~right).any() == 0:
            return -np.inf
        G, H = stats[:, 0], stats[:, 1]
        sc = lambda sel: 0.5 * G[sel].sum() ** 2 / (H[sel].sum() + 1e-12)
        tot = 0.5 * G.sum() ** 2 / (H.sum() + 1e-12)
        if right.sum() == 0 or (~right).sum() == 0:
            return -np.inf
        return sc(right) + sc(~right) - tot

    best_brute = max(gain_of(np.array(s)) for s in _subsets(V))
    np.testing.assert_allclose(split.gain, best_brute, rtol=1e-4)


def _subsets(V):
    for m in range(1, 2 ** V - 1):
        yield [v for v in range(V) if m >> v & 1]


def test_min_examples_respected():
    rng = np.random.default_rng(3)
    n = 40
    x = np.concatenate([np.zeros(2), np.ones(n - 2)])  # tiny left group
    stats = _gh_stats(rng, n)
    stats[:2, 0] = 100.0  # huge gain if the tiny group could split off
    params = SplitterParams(stat_kind="gh", min_examples=5)
    ds = dataset_from_raw({"x": x.astype(object), "y": np.ones(n, object)})
    binned = bin_features(ds, ["x"])
    hist = build_histogram(binned.codes, stats, np.zeros(n, np.int32), 1)
    split = best_splits(hist, binned, params, np.random.default_rng(0))[0]
    assert not split.valid  # the only cut violates min_examples


def test_oblique_splits_fold_normalization():
    """Raw-space evaluation of an oblique split == training-time partition."""
    from repro.core.splitters import oblique_splits, apply_split, Split
    rng = np.random.default_rng(5)
    n, f = 300, 4
    X = rng.normal(size=(n, f)) * np.array([1, 10, 0.1, 3]) + 5
    w_true = np.array([1.0, -0.5, 2.0, 0.0])
    y = (X @ w_true > np.median(X @ w_true)).astype(float)
    g = (0.5 - y)
    stats = np.stack([g, np.ones(n), np.ones(n)], 1)
    params = SplitterParams(stat_kind="gh", min_examples=2, oblique=True,
                            oblique_num_projections_exponent=1.5)
    splits = oblique_splits(X, X.min(0), X.max(0), stats,
                            np.zeros(n, np.int32), 1, params,
                            np.random.default_rng(0))
    s = splits[0]
    assert s.obl_features is not None and s.gain > 0
    proj = X[:, s.obl_features] @ s.obl_weights
    go = proj >= s.threshold
    # a decent oblique split separates classes far better than chance
    acc = max((y[go] == 1).mean() if go.any() else 0,
              (y[~go] == 1).mean() if (~go).any() else 0)
    assert go.any() and (~go).any()
