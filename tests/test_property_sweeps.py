"""Hypothesis property sweeps (kernels vs oracles over random shapes/dtypes).

Kept in their own module so the rest of the engine/splitter tests stay
runnable when hypothesis is not installed: ``pytest.importorskip`` skips only
this file at collection time.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.models as M  # noqa: E402
from repro.core import GradientBoostedTreesLearner  # noqa: E402
from repro.data.tabular import adult_like, train_test_split  # noqa: E402


def _gh_stats(rng, n):
    g = rng.normal(size=n)
    h = np.abs(rng.normal(size=n)) + 0.1
    return np.stack([g, h, np.ones(n)], 1)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 120), f=st.integers(1, 4), nodes=st.integers(1, 5),
       bins=st.sampled_from([4, 16, 64]), seed=st.integers(0, 10_000))
def test_histogram_partition_property(n, f, nodes, bins, seed):
    """Histogram totals == direct per-node sums; bins partition examples."""
    from repro.core.splitters import build_histogram
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, bins, (n, f)).astype(np.uint8)
    stats = _gh_stats(rng, n)
    node_of = rng.integers(-1, nodes, n).astype(np.int32)
    hist = build_histogram(codes, stats, node_of, nodes, bins)
    assert hist.shape == (nodes, f, bins, 3)
    for node in range(nodes):
        sel = node_of == node
        np.testing.assert_allclose(hist[node, 0].sum(0), stats[sel].sum(0),
                                   atol=1e-4)
        # identical totals across features (each feature sees every example)
        np.testing.assert_allclose(hist[node].sum(1),
                                   np.broadcast_to(stats[sel].sum(0), (f, 3)),
                                   atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 300), f=st.integers(1, 6), s=st.integers(1, 5),
       nodes=st.integers(1, 9), bins=st.sampled_from([8, 32, 256]),
       dt=st.sampled_from(["float32", "float64"]), seed=st.integers(0, 99))
def test_histogram_kernel_sweep(n, f, s, nodes, bins, dt, seed):
    import jax.numpy as jnp
    from repro.kernels.histogram.ops import histogram
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, bins, (n, f)).astype(np.uint8)
    stats = rng.normal(size=(n, s)).astype(dt)
    node_of = rng.integers(-1, nodes, n).astype(np.int32)
    ref = np.asarray(histogram(jnp.asarray(codes), jnp.asarray(stats),
                               jnp.asarray(node_of), nodes, bins, impl="ref"))
    pal = np.asarray(histogram(jnp.asarray(codes), jnp.asarray(stats),
                               jnp.asarray(node_of), nodes, bins,
                               impl="interpret"))
    np.testing.assert_allclose(pal, ref, atol=1e-4, rtol=1e-4)


@pytest.mark.slow
@pytest.mark.traversal
@settings(max_examples=30, deadline=None)
@given(n=st.sampled_from([0, 1, 2, 7, 33]), trees=st.integers(1, 10),
       n_feats=st.integers(2, 6), out_dim=st.sampled_from([1, 3]),
       n_cat=st.integers(0, 2), hostile=st.booleans(),
       seed=st.integers(0, 10_000))
def test_traversal_strategy_equivalence_sweep(n, trees, n_feats, out_dim,
                                              n_cat, hostile, seed):
    """Property: the four CPU traversal strategies are ONE function — on
    random ragged forests (stumps through depth ~10, categorical splits,
    multi-output leaves) and hostile batches (0 rows, 1 row, NaN/±inf on
    numerical columns, unseen/negative category codes), every strategy's
    per-tree output is bit-identical to the vectorized engine."""
    from conftest import _make_random_forest
    from repro.core.tree import (LEAF_PATH_BUDGET, compile_predict_raw,
                                 leaf_path_sizes, predict_naive)
    from repro.kernels.forest_infer.ops import forest_predict_bucketed
    rng = np.random.default_rng(seed)
    cat_feats = tuple(range(n_cat))
    splits = [int(s) for s in rng.integers(0, 11, size=min(trees, 4))]
    forest = _make_random_forest(trees, splits, n_feats, out_dim=out_dim,
                                 seed=seed, cat_feats=cat_feats)
    X = (rng.normal(size=(n, n_feats)) * 2).astype(np.float32)
    for j in cat_feats:
        # unseen (>=256) and negative codes clamp, matching the oracle
        X[:, j] = rng.integers(-5, 400, size=n)
    if hostile and n >= 4 and n_cat < n_feats:
        X[0, n_cat] = np.nan
        X[1, n_cat] = np.inf
        X[2, n_cat] = -np.inf
        X[3, n_cat] = 3e38
    want = compile_predict_raw(forest)(X)
    assert want.shape == (n, trees, out_dim)
    assert np.array_equal(predict_naive(forest, X), want)
    assert np.array_equal(
        np.asarray(forest_predict_bucketed(forest, X)), want)
    i, l = leaf_path_sizes(forest)
    if i * l <= LEAF_PATH_BUDGET:
        assert np.array_equal(np.asarray(
            forest_predict_bucketed(forest, X, strategy="leaf_path")), want)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 100), trees=st.integers(1, 5), seed=st.integers(0, 99))
def test_forest_infer_kernel_sweep(n, trees, seed):
    """Random trained forests (incl. categorical masks) on random inputs."""
    from repro.core.tree import predict_raw
    from repro.kernels.forest_infer.ops import forest_predict
    rng = np.random.default_rng(seed)
    train, _ = train_test_split(adult_like(300, seed=seed), 0.3, seed)
    m = GradientBoostedTreesLearner(label="income", num_trees=trees,
                                    max_depth=4, seed=seed).train(train)
    ds = M._as_vertical(train, m.spec)
    X = M.raw_matrix(ds, m.features)[:n]
    want = predict_raw(m.forest, X)
    got = np.asarray(forest_predict(m.forest, X, impl="interpret"))
    np.testing.assert_allclose(got, want, atol=1e-5)
