"""Hypothesis property sweeps (kernels vs oracles over random shapes/dtypes).

Kept in their own module so the rest of the engine/splitter tests stay
runnable when hypothesis is not installed: ``pytest.importorskip`` skips only
this file at collection time.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.models as M  # noqa: E402
from repro.core import GradientBoostedTreesLearner  # noqa: E402
from repro.data.tabular import adult_like, train_test_split  # noqa: E402


def _gh_stats(rng, n):
    g = rng.normal(size=n)
    h = np.abs(rng.normal(size=n)) + 0.1
    return np.stack([g, h, np.ones(n)], 1)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 120), f=st.integers(1, 4), nodes=st.integers(1, 5),
       bins=st.sampled_from([4, 16, 64]), seed=st.integers(0, 10_000))
def test_histogram_partition_property(n, f, nodes, bins, seed):
    """Histogram totals == direct per-node sums; bins partition examples."""
    from repro.core.splitters import build_histogram
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, bins, (n, f)).astype(np.uint8)
    stats = _gh_stats(rng, n)
    node_of = rng.integers(-1, nodes, n).astype(np.int32)
    hist = build_histogram(codes, stats, node_of, nodes, bins)
    assert hist.shape == (nodes, f, bins, 3)
    for node in range(nodes):
        sel = node_of == node
        np.testing.assert_allclose(hist[node, 0].sum(0), stats[sel].sum(0),
                                   atol=1e-4)
        # identical totals across features (each feature sees every example)
        np.testing.assert_allclose(hist[node].sum(1),
                                   np.broadcast_to(stats[sel].sum(0), (f, 3)),
                                   atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 300), f=st.integers(1, 6), s=st.integers(1, 5),
       nodes=st.integers(1, 9), bins=st.sampled_from([8, 32, 256]),
       dt=st.sampled_from(["float32", "float64"]), seed=st.integers(0, 99))
def test_histogram_kernel_sweep(n, f, s, nodes, bins, dt, seed):
    import jax.numpy as jnp
    from repro.kernels.histogram.ops import histogram
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, bins, (n, f)).astype(np.uint8)
    stats = rng.normal(size=(n, s)).astype(dt)
    node_of = rng.integers(-1, nodes, n).astype(np.int32)
    ref = np.asarray(histogram(jnp.asarray(codes), jnp.asarray(stats),
                               jnp.asarray(node_of), nodes, bins, impl="ref"))
    pal = np.asarray(histogram(jnp.asarray(codes), jnp.asarray(stats),
                               jnp.asarray(node_of), nodes, bins,
                               impl="interpret"))
    np.testing.assert_allclose(pal, ref, atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 100), trees=st.integers(1, 5), seed=st.integers(0, 99))
def test_forest_infer_kernel_sweep(n, trees, seed):
    """Random trained forests (incl. categorical masks) on random inputs."""
    from repro.core.tree import predict_raw
    from repro.kernels.forest_infer.ops import forest_predict
    rng = np.random.default_rng(seed)
    train, _ = train_test_split(adult_like(300, seed=seed), 0.3, seed)
    m = GradientBoostedTreesLearner(label="income", num_trees=trees,
                                    max_depth=4, seed=seed).train(train)
    ds = M._as_vertical(train, m.spec)
    X = M.raw_matrix(ds, m.features)[:n]
    want = predict_raw(m.forest, X)
    got = np.asarray(forest_predict(m.forest, X, impl="interpret"))
    np.testing.assert_allclose(got, want, atol=1e-5)
