"""The fault-tolerant serving front-end (DESIGN.md §9): admission control,
deadlines, retry, engine degradation, circuit breaking, multi-model routing
— every failure path driven DETERMINISTICALLY by the fault harness
(serving/faults.py) on a virtual clock. No wall-clock sleeps, no flaky
timing: same seeds, same faults, same transitions, every run."""
import asyncio

import numpy as np
import pytest

from repro.core import (
    EngineFailure,
    GradientBoostedTreesLearner,
    RandomForestLearner,
    Task,
    YdfError,
)
from repro.data.tabular import adult_like, train_test_split
from repro.serving.faults import POISON, FakeClock, FaultPlan, FaultyPredictor
from repro.serving.server import (
    AsyncForestServer,
    CircuitBreaker,
    ForestServer,
    RequestFailed,
    RequestShed,
    RequestTimedOut,
    RetryPolicy,
)


@pytest.fixture(scope="module")
def trained():
    train, test = train_test_split(adult_like(900), 0.3, 1)
    gbt = GradientBoostedTreesLearner(label="income", num_trees=6).train(train)
    feats = {k: v for k, v in test.items() if k != "income"}
    return gbt, feats


def make_server(model, clock, **kw):
    kw.setdefault("buckets", (16, 64))
    kw.setdefault("failure_threshold", 2)
    kw.setdefault("cooldown_s", 1.0)
    kw.setdefault("retry", RetryPolicy(max_attempts=2, base_s=0.01, seed=5))
    return ForestServer(model, clock=clock.now, sleep=clock.sleep, **kw)


def req_slice(feats, lo, n=8):
    return {k: v[lo:lo + n] for k, v in feats.items()}


# ------------------------------------------------------------- fault harness

def test_fake_clock_and_fault_plan_are_deterministic():
    clk = FakeClock()
    clk.sleep(0.25)
    clk.advance(0.75)
    assert clk.now() == 1.0
    with pytest.raises(ValueError):
        clk.advance(-1)
    a = FaultPlan(seed=3, transient_rate=0.3, poison_rate=0.2,
                  latency_rate=0.5, latency_s=0.01)
    b = FaultPlan(seed=3, transient_rate=0.3, poison_rate=0.2,
                  latency_rate=0.5, latency_s=0.01)
    rolls = [(a.is_transient(i), a.is_poisoned(i), a.latency_for(i))
             for i in range(200)]
    assert rolls == [(b.is_transient(i), b.is_poisoned(i), b.latency_for(i))
                     for i in range(200)]
    assert any(r[0] for r in rolls) and any(r[1] for r in rolls)
    # a different seed gives a different schedule
    c = FaultPlan(seed=4, transient_rate=0.3)
    assert [a.is_transient(i) for i in range(200)] != \
        [c.is_transient(i) for i in range(200)]
    # explicit schedules
    p = FaultPlan(transient_calls=(2,), poison_calls=(3,),
                  latency_calls={1: 0.5}, dead_from=5, dead_until=7)
    assert not p.is_transient(0) and p.is_transient(2)
    assert p.latency_for(1) == 0.5 and p.latency_for(0) == 0.0
    assert [p.is_dead(i) for i in range(4, 8)] == [False, True, True, False]


def test_faulty_predictor_replays_plan(trained):
    gbt, feats = trained
    clk = FakeClock()
    w = FaultyPredictor(gbt.predictor(), FaultPlan(
        transient_calls=(0,), poison_calls=(2,), latency_calls={1: 0.3},
        dead_from=3, dead_until=4), advance=clk.advance)
    X = w.encode(req_slice(feats, 0))
    with pytest.raises(EngineFailure) as e:
        w.predict_encoded(X)                       # call 0: transient
    assert e.value.transient and e.value.engine == w.name
    out = w.predict_encoded(X)                     # call 1: latency, clean
    assert clk.now() == 0.3
    np.testing.assert_array_equal(out, gbt.predict(req_slice(feats, 0)))
    poisoned = w.predict_encoded(X)                # call 2: poisoned, no raise
    assert np.isnan(poisoned).all() and np.isnan(POISON)
    with pytest.raises(EngineFailure) as e:
        w.predict_encoded(X)                       # call 3: sticky death
    assert not e.value.transient
    w.predict_encoded(X)                           # call 4: revived
    assert w.counts == {"latency": 1, "dead": 1, "transient": 1,
                        "poison": 1, "clean": 2}


def test_compiled_predictor_surfaces_typed_engine_failure(trained):
    gbt, feats = trained
    pred = gbt.predictor()
    X = pred.encode(req_slice(feats, 0))
    bad = type(pred)(engine=type(pred.engine)(
        "vectorized", lambda _: (_ for _ in ()).throw(RuntimeError("boom"))),
        encoder=pred.encoder, finalize=pred.finalize)
    with pytest.raises(EngineFailure, match="vectorized.*boom"):
        bad.predict_encoded(X)


# ------------------------------------------------------------ circuit breaker

def test_circuit_breaker_state_machine():
    br = CircuitBreaker(failure_threshold=2, cooldown_s=1.0)
    assert br.allow(0.0) and br.state == "closed"
    assert not br.record_failure(0.0)
    assert br.record_success() is False            # still closed: no close event
    assert not br.record_failure(1.0)              # consecutive count was reset
    assert br.record_failure(2.0)                  # threshold -> OPEN
    assert br.state == "open" and not br.allow(2.5)
    assert br.allow(3.0) and br.state == "half_open"
    assert br.record_failure(3.0)                  # failed probe -> re-OPEN
    assert br.state == "open"
    assert br.allow(4.0)                           # next probe
    assert br.record_success() and br.state == "closed"


# --------------------------------------------------------------- clean paths

def test_clean_requests_match_direct_predictions(trained):
    gbt, feats = trained
    srv = make_server(gbt, FakeClock())
    for lo in (0, 8, 16):
        out = srv.predict(req_slice(feats, lo))
        np.testing.assert_array_equal(out, gbt.predict(req_slice(feats, lo)))
    m = srv.metrics
    assert m.submitted == m.accepted == m.completed == 3
    assert m.shed == m.timed_out == m.failed == 0
    assert m.engine_dispatches == {"vectorized": 3}


def test_requests_micro_batch_into_one_dispatch(trained):
    gbt, feats = trained
    srv = make_server(gbt, FakeClock())
    tickets = [srv.submit(req_slice(feats, lo), pump=False)
               for lo in (0, 8, 16)]
    assert srv.metrics.dispatches == 0
    srv.pump()
    assert srv.metrics.dispatches == 1
    assert srv.metrics.rows_padded == 64 - 24      # one bucket-64 dispatch
    for t, lo in zip(tickets, (0, 8, 16)):
        np.testing.assert_array_equal(
            srv.result(t), gbt.predict(req_slice(feats, lo)))


def test_result_ticket_validation(trained):
    gbt, feats = trained
    srv = make_server(gbt, FakeClock())
    t = srv.submit(req_slice(feats, 0), pump=False)
    with pytest.raises(KeyError):
        srv.result(999)                            # never issued
    assert srv.metrics.dispatches == 0             # and nothing was flushed
    srv.result(t)
    with pytest.raises(KeyError):
        srv.result(t)                              # already claimed


# ---------------------------------------------------- admission + deadlines

def test_admission_sheds_unmeetable_deadlines(trained):
    gbt, feats = trained
    clk = FakeClock()
    srv = make_server(gbt, clk)
    # teach the EWMA a real service rate: 0.16 s per bucket-16 dispatch
    srv.inject_faults(FaultPlan(latency_calls={0: 0.16}))
    srv.predict(req_slice(feats, 0))
    assert srv._state(None).ewma_row_s == pytest.approx(0.01)
    backlog = srv.submit(req_slice(feats, 8), deadline_s=10.0, pump=False)
    with pytest.raises(RequestShed, match="cannot be met"):
        srv.submit(req_slice(feats, 16), deadline_s=0.01, pump=False)
    assert srv.metrics.shed == 1
    # a meetable deadline is still admitted, and the backlog is unharmed
    ok = srv.submit(req_slice(feats, 16), deadline_s=10.0, pump=False)
    srv.pump()
    np.testing.assert_array_equal(srv.result(backlog),
                                  gbt.predict(req_slice(feats, 8)))
    np.testing.assert_array_equal(srv.result(ok),
                                  gbt.predict(req_slice(feats, 16)))


def test_admission_sheds_on_full_queue(trained):
    gbt, feats = trained
    srv = make_server(gbt, FakeClock(), max_queue_rows=20)
    srv.submit(req_slice(feats, 0, 16), pump=False)
    with pytest.raises(RequestShed, match="queue full"):
        srv.submit(req_slice(feats, 16, 8), pump=False)
    assert srv.metrics.shed == 1


def test_timeout_while_queued_skips_dispatch(trained):
    gbt, feats = trained
    clk = FakeClock()
    srv = make_server(gbt, clk)
    t = srv.submit(req_slice(feats, 0), deadline_s=0.5, pump=False)
    clk.advance(1.0)                               # deadline passes in queue
    before = srv.metrics.dispatches
    srv.pump()
    assert srv.metrics.dispatches == before        # no compute for the dead
    with pytest.raises(RequestTimedOut, match="while queued"):
        srv.result(t)
    assert srv.metrics.timed_out == 1


def test_timeout_during_dispatch_discards_late_result(trained):
    gbt, feats = trained
    clk = FakeClock()
    srv = make_server(gbt, clk)
    srv.inject_faults(FaultPlan(latency_calls={0: 0.5}))
    t = srv.submit(req_slice(feats, 0), deadline_s=0.1, pump=False)
    srv.pump()
    with pytest.raises(RequestTimedOut, match="late result discarded"):
        srv.result(t)
    assert srv.metrics.timed_out == 1 and srv.metrics.completed == 0


# ------------------------------------------------- retry / fallback / breaker

def test_transient_failure_retries_with_seeded_backoff(trained):
    gbt, feats = trained
    clk = FakeClock()
    srv = make_server(gbt, clk)
    w = srv.inject_faults(FaultPlan(transient_calls=(0,)))
    t0 = clk.now()
    out = srv.predict(req_slice(feats, 0))
    np.testing.assert_array_equal(out, gbt.predict(req_slice(feats, 0)))
    assert srv.metrics.retries == 1 and w.counts["transient"] == 1
    # the backoff slept the DETERMINISTIC seeded-jitter delay on our clock
    expected = srv.retry.delay(0, 0)
    assert clk.now() - t0 == pytest.approx(expected)
    assert srv.retry.base_s <= expected <= srv.retry.base_s * 1.5
    # same policy, same counters -> same delay (determinism), jitter varies
    assert RetryPolicy(seed=5).delay(0, 0) == RetryPolicy(seed=5).delay(0, 0)
    assert RetryPolicy(seed=5).delay(0, 0) != RetryPolicy(seed=5).delay(1, 0)


def test_transients_exhaust_retries_then_fall_back(trained):
    gbt, feats = trained
    srv = make_server(gbt, FakeClock())
    w = srv.inject_faults(FaultPlan(transient_calls=(0, 1, 2, 3)))
    out = srv.predict(req_slice(feats, 0))         # 2 attempts, both transient
    np.testing.assert_array_equal(out, gbt.predict(req_slice(feats, 0)))
    assert w.counts["transient"] == 2              # max_attempts on primary
    assert srv.metrics.fallback_dispatches == 1
    # the next chain level takes the dispatch (small CPU model: vectorized
    # primary, the §10 bucketed engine behind it, naive last)
    assert srv.metrics.engine_dispatches.get("bucketed") == 1


def test_sticky_death_opens_circuit_probes_restore(trained):
    gbt, feats = trained
    clk = FakeClock()
    srv = make_server(gbt, clk)
    clean = gbt.predict(req_slice(feats, 0))
    # dead for calls 0..2: two failures open the circuit; the first
    # half-open probe (call 2) fails and re-opens; the second succeeds
    w = srv.inject_faults(FaultPlan(dead_from=0, dead_until=3))
    for _ in range(2):
        np.testing.assert_array_equal(srv.predict(req_slice(feats, 0)), clean)
    assert srv.engine_status()[0]["circuit"] == "open"
    assert srv.metrics.circuit_opens == 1
    # while open the primary is never touched
    frozen = w.calls
    np.testing.assert_array_equal(srv.predict(req_slice(feats, 0)), clean)
    assert w.calls == frozen
    # cooldown -> half-open probe; still dead -> re-open
    clk.advance(1.5)
    np.testing.assert_array_equal(srv.predict(req_slice(feats, 0)), clean)
    assert srv.engine_status()[0]["circuit"] == "open"
    assert srv.metrics.circuit_opens == 2 and w.counts["dead"] == 3
    # cooldown -> probe hits the revived engine -> circuit closes
    clk.advance(1.5)
    np.testing.assert_array_equal(srv.predict(req_slice(feats, 0)), clean)
    assert srv.engine_status()[0]["circuit"] == "closed"
    assert srv.metrics.circuit_closes == 1
    # and stays closed: the next dispatch is primary again, no fallback
    fb = srv.metrics.fallback_dispatches
    np.testing.assert_array_equal(srv.predict(req_slice(feats, 0)), clean)
    assert srv.metrics.fallback_dispatches == fb
    assert w.counts["clean"] == 2


def test_poisoned_outputs_never_escape(trained):
    gbt, feats = trained
    srv = make_server(gbt, FakeClock())
    srv.inject_faults(FaultPlan(poison_calls=(0, 1)))
    out = srv.predict(req_slice(feats, 0))         # poisoned twice -> fallback
    np.testing.assert_array_equal(out, gbt.predict(req_slice(feats, 0)))
    assert np.isfinite(out).all()
    assert srv.metrics.poisoned_rejected == 2
    assert srv.metrics.fallback_dispatches == 1


def test_all_engines_down_fails_loudly(trained):
    gbt, feats = trained
    srv = make_server(gbt, FakeClock(), engines=["vectorized"],
                      failure_threshold=100)
    srv.inject_faults(FaultPlan(dead_from=0))
    t = srv.submit(req_slice(feats, 0), pump=False)
    srv.pump()
    with pytest.raises(RequestFailed, match="all engines failed"):
        srv.result(t)
    assert srv.metrics.failed == 1 and srv.metrics.completed == 0


def test_unknown_model_and_unknown_engine_raise(trained):
    gbt, feats = trained
    srv = make_server(gbt, FakeClock())
    with pytest.raises(YdfError, match="Unknown model"):
        srv.submit(req_slice(feats, 0), model="nope")
    with pytest.raises(YdfError):
        ForestServer(gbt, engines=["warp_drive"]).predict(req_slice(feats, 0))


# ----------------------------------------- equivalence under degradation

LEARNERS = {
    "rf": lambda label, task: RandomForestLearner(
        label=label, task=task, num_trees=4, max_depth=6, seed=3),
    "gbt": lambda label, task: GradientBoostedTreesLearner(
        label=label, task=task, num_trees=4, seed=3),
}


@pytest.mark.parametrize("learner", ["rf", "gbt"])
@pytest.mark.parametrize("task", [Task.CLASSIFICATION, Task.REGRESSION])
def test_accepted_requests_bit_identical_under_faults(learner, task):
    """The §9 contract: with faults hammering the primary engine, every
    ACCEPTED request's prediction is bit-identical to a clean direct call —
    degradation changes latency and counters, never bits."""
    label = "income" if task == Task.CLASSIFICATION else "age"
    train, test = train_test_split(adult_like(700), 0.3, 1)
    model = LEARNERS[learner](label, task).train(train)
    requests = [{k: v[lo:lo + 6] for k, v in test.items() if k != label}
                for lo in range(0, 120, 6)]
    clean = [model.predict(r) for r in requests]
    clk = FakeClock()
    srv = make_server(model, clk)
    w = srv.inject_faults(FaultPlan(
        seed=11, transient_rate=0.25, poison_rate=0.15,
        latency_rate=0.1, latency_s=0.01, dead_from=6, dead_until=9))
    served = failed = 0
    for r, want in zip(requests, clean):
        clk.advance(2.0)      # roll cooldowns so probes fire along the way
        try:
            out = srv.predict(r)
        except YdfError:
            failed += 1       # loud typed failure: acceptable, silent is not
            continue
        served += 1
        np.testing.assert_array_equal(out, want)
    assert served >= 15       # the chain kept almost everything alive
    assert sum(w.counts[k] for k in ("transient", "poison", "dead")) >= 5
    assert srv.metrics.completed == served and srv.metrics.failed == failed


# ------------------------------------------------------- routing + metrics

def test_multi_model_routing(trained):
    gbt, feats = trained
    train, test = train_test_split(adult_like(700), 0.3, 1)
    reg = RandomForestLearner(label="age", task=Task.REGRESSION, num_trees=3,
                              max_depth=5).train(train)
    srv = ForestServer({"income": gbt, "age": reg}, clock=FakeClock().now,
                       sleep=lambda _: None)
    r1 = req_slice(feats, 0)
    r2 = {k: v[:8] for k, v in test.items() if k != "age"}
    np.testing.assert_array_equal(srv.predict(r1, model="income"),
                                  gbt.predict(r1))
    np.testing.assert_array_equal(srv.predict(r2, model="age"),
                                  reg.predict(r2))
    assert sorted(srv.models()) == ["age", "income"]
    # default model = first routed
    np.testing.assert_array_equal(srv.predict(r1), gbt.predict(r1))


def test_metrics_surface(trained):
    gbt, feats = trained
    clk = FakeClock()
    srv = make_server(gbt, clk)
    srv.inject_faults(FaultPlan(latency_calls={0: 0.010, 1: 0.200}))
    srv.predict(req_slice(feats, 0))
    srv.predict(req_slice(feats, 8))
    d = srv.metrics.to_dict()
    assert d["latency"]["n"] == 2
    assert d["latency"]["p50_ms"] == pytest.approx(105.0, abs=1.0)
    assert d["latency"]["p99_ms"] <= 200.0
    assert d["padding_by_bucket"]["16"] == {"dispatches": 2, "pad_rows": 16}
    text = srv.metrics.summary()
    assert "p50" in text and "bucket" in text and "completed=2" in text
    # the latency reservoir is bounded (soak-memory contract, §9.4)
    m = srv.metrics
    m.max_latency_samples = 64
    for _ in range(500):
        m.observe_latency(0.001)
    assert len(m._latencies) <= 64


# ------------------------------------------------------------ async front-end

def test_async_front_end_micro_batches_and_sheds(trained):
    gbt, feats = trained
    srv = ForestServer(gbt, buckets=(16, 64), max_queue_rows=40)

    async def fan_in():
        async with AsyncForestServer(srv, flush_interval_s=0.001) as a:
            jobs = [a.predict(req_slice(feats, lo))
                    for lo in range(0, 80, 8)]     # 10 x 8 rows > queue cap
            return await asyncio.gather(*jobs, return_exceptions=True)

    results = asyncio.run(fan_in())
    ok = [r for r in results if isinstance(r, np.ndarray)]
    shed = [r for r in results if isinstance(r, RequestShed)]
    assert len(ok) == 5 and len(shed) == 5         # cap admits exactly 40 rows
    for lo, r in zip(range(0, 80, 8), results):
        if isinstance(r, np.ndarray):
            np.testing.assert_array_equal(r, gbt.predict(req_slice(feats, lo)))
    assert srv.metrics.shed == 5 and srv.metrics.completed == 5


# ------------------------------------------------------------------ CLI smoke

def test_cli_serve_smoke(trained, tmp_path, capsys):
    from repro.cli import main
    from repro.data.io import read_dataset, write_dataset
    gbt, feats = trained
    mdir = str(tmp_path / "model")
    gbt.save(mdir)
    csv = "csv:" + str(tmp_path / "req.csv")
    write_dataset({k: v[:40] for k, v in feats.items()}, csv)
    out_csv = "csv:" + str(tmp_path / "preds.csv")
    main(["serve", "--dataset", csv, "--model", mdir, "--request-rows", "8",
          "--deadline-ms", "5000", "--output", out_csv])
    text = capsys.readouterr().out
    assert "engine chain" in text and "shed=0" in text and "p50" in text
    preds = read_dataset(out_csv)
    want = gbt.predict({k: v[:40] for k, v in feats.items()})
    got = np.stack([preds[f"p_{c}"].astype(np.float32)
                    for c in gbt.classes], 1)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_serve_bench_smoke():
    from benchmarks import serve_bench
    res = serve_bench.run(qps_levels=(400,), duration_s=0.25, num_trees=3,
                          verbose=False)
    lvl = res["levels"]["400"]
    for mode in ("clean", "faults"):
        r = lvl[mode]
        assert r["counters"]["submitted"] > 0
        assert r["equiv_ok"] == r["equiv_checked"] > 0
        assert r["p50_ms"] is not None and r["p99_ms"] is not None
    assert res["benchmark"] == "serve_bench"


# ------------------------------------------------------------------ soak

@pytest.mark.slow
def test_soak_mixed_traffic_no_lost_tickets(trained):
    """Sustained mixed traffic + faults on the virtual clock: every accepted
    ticket resolves EXACTLY once (result or typed error), accounting adds
    up, and server memory stays bounded."""
    gbt, feats = trained
    clk = FakeClock()
    srv = make_server(gbt, clk, max_results=64, max_queue_rows=256,
                      default_deadline_s=0.5)
    srv.inject_faults(FaultPlan(
        seed=2, transient_rate=0.1, poison_rate=0.05,
        latency_rate=0.15, latency_s=0.05, dead_from=40, dead_until=48))
    rng = np.random.default_rng(0)
    n_feat_rows = len(next(iter(feats.values())))
    outcomes = {"ok": 0, "shed": 0, "timeout": 0, "failed": 0}
    open_tickets = []
    for step in range(400):
        lo = int(rng.integers(0, n_feat_rows - 8))
        try:
            t = srv.submit(req_slice(feats, lo, int(rng.integers(1, 8))),
                           deadline_s=float(rng.uniform(0.01, 2.0)),
                           pump=False)
            open_tickets.append(t)
        except RequestShed:
            outcomes["shed"] += 1
        clk.advance(float(rng.uniform(0, 0.02)))
        if step % 7 == 0:
            srv.pump()
            while open_tickets:
                t = open_tickets.pop()
                try:
                    srv.result(t)
                    outcomes["ok"] += 1
                except RequestTimedOut:
                    outcomes["timeout"] += 1
                except RequestFailed:
                    outcomes["failed"] += 1
    srv.pump()
    for t in open_tickets:
        try:
            srv.result(t)
            outcomes["ok"] += 1
        except (RequestTimedOut, RequestFailed):
            outcomes["timeout"] += 1
        except KeyError:
            pytest.fail(f"lost ticket {t}")
    # zero lost tickets: every submit is accounted for exactly once
    assert sum(outcomes.values()) == 400
    m = srv.metrics
    assert m.submitted == 400
    assert m.accepted == outcomes["ok"] + outcomes["timeout"] + \
        outcomes["failed"]
    assert m.shed == outcomes["shed"]
    # bounded memory: results map, ticket map and queue all drained/capped
    assert len(srv._done) == 0
    assert len(srv._ticket_model) == 0
    assert srv._state(None).pending_rows() == 0
    assert len(m._latencies) <= m.max_latency_samples
