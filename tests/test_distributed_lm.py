"""Distributed LM paths on 8 placeholder devices (subprocess): sharded train
step on a (pod, data, model) mesh, int8 hierarchical gradient compression,
GPipe pipeline stage equivalence, elastic resharding restore."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

# ---- 1) sharded train step on (pod=2, data=2, model=2)
from repro.configs import get_arch, smoke_config
from repro.configs.base import ShapeConfig
from repro.models import lm
from repro.sharding import rules_for
from repro.train import init_train_state, make_train_step

cfg = smoke_config(get_arch("qwen2-1.5b")).replace(d_model=64, n_heads=4, head_dim=16,
                                                   n_kv_heads=2, vocab_size=128)
shape = ShapeConfig("t", "train", 64, 8)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = rules_for("train")
bundle = make_train_step(cfg, shape, mesh, rules)
state = init_train_state(jax.random.key(0), cfg)
state = jax.tree.map(jax.device_put, state, bundle.state_shardings)
batch = lm.make_batch(jax.random.key(1), cfg, shape)
batch = jax.tree.map(jax.device_put, batch, bundle.batch_shardings)
step = bundle.jitted(donate=False)
s2, m = step(state, batch)
assert np.isfinite(float(m["loss"]))

# sharded step == unsharded step
b0 = make_train_step(cfg, shape)
s0, m0 = jax.jit(b0.step_fn)(init_train_state(jax.random.key(0), cfg),
                             lm.make_batch(jax.random.key(1), cfg, shape))
assert abs(float(m["loss"]) - float(m0["loss"])) < 1e-3, (float(m["loss"]), float(m0["loss"]))
print("train-step OK")

# ---- 2) elastic resharding restore: save on (2,2,2), restore on (1,4,2)
from repro.distributed.checkpoint import CheckpointManager
from repro.sharding import tree_shardings
from repro.train.step import train_state_specs
import tempfile
d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mgr.save(1, s2)
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
specs, axes = train_state_specs(cfg)
sh2 = tree_shardings(axes, mesh2, rules, specs)
s3, _ = mgr.restore(1, shardings=sh2)
for a, b in zip(jax.tree.leaves(s2), jax.tree.leaves(s3)):
    assert np.allclose(np.asarray(a, dtype=np.float32), np.asarray(b, dtype=np.float32), atol=1e-6)
print("reshard OK")

# ---- 3) int8 hierarchical cross-pod psum
from repro.distributed.compression import hierarchical_psum
mesh3 = jax.make_mesh((2, 4), ("pod", "data"))
x = jax.random.normal(jax.random.key(2), (2, 4, 64))  # (pod, data, D) shards

for compress in (False, True):
    g = jax.jit(shard_map(
        lambda x: hierarchical_psum(x[0, 0], pod_axis="pod",
                                    inner_axis="data", compress=compress),
        mesh=mesh3, in_specs=P("pod", "data", None), out_specs=P()))
    ref = np.asarray(x).sum((0, 1))
    out = np.asarray(g(x))
    err = np.abs(out - ref).max()
    scale = np.abs(np.asarray(x).sum(1)).max() / 127  # max |in-pod sum| / 127
    assert err <= (1.2 * scale if compress else 1e-4), (compress, err, scale)
print("compression OK")

# ---- 4) GPipe pipeline == sequential stages
from repro.train.pipeline import make_pipeline_fn, pipeline_efficiency
mesh4 = jax.make_mesh((4,), ("stage",))
S, Lp, D, M, mb = 4, 1, 16, 6, 8
Ws = jax.random.normal(jax.random.key(3), (S, D, D)) * 0.3

def block(w, x):
    return jnp.tanh(x @ w[0] if w.ndim == 3 else x @ w)

params = Ws[:, None]  # (S, 1, D, D): leading stage dim + per-stage stack
pipe = make_pipeline_fn(lambda p, x: jnp.tanh(x @ p), mesh4, n_micro=M)
xs = jax.random.normal(jax.random.key(4), (M, mb, D))
out = pipe(Ws, xs)
ref = xs
for s in range(S):
    ref = jnp.tanh(ref @ Ws[s])
assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
assert 0 < pipeline_efficiency(M, S) < 1
print("pipeline OK")
"""


@pytest.mark.slow
def test_distributed_lm_paths_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=1200,
                       env=dict(os.environ, PYTHONPATH="src",
                                JAX_PLATFORMS="cpu"))
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    for tag in ("train-step OK", "reshard OK", "compression OK", "pipeline OK"):
        assert tag in r.stdout, r.stdout
