"""CheckpointManager: roundtrip, retention, partial restore, async."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager


def _state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros(3)},
            "step": jnp.asarray(7)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, _state(2.0), extra={"note": "hi"})
    state, manifest = mgr.restore()
    assert manifest["step"] == 10 and manifest["extra"]["note"] == "hi"
    np.testing.assert_allclose(state["params"]["w"], 2.0)


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert mgr.all_steps() == [3, 4]


def test_partial_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"params": {"w": jnp.ones((2, 2))}})
    target = {"params": {"w": jnp.zeros((2, 2)), "new_leaf": jnp.full(3, 9.0)}}
    with pytest.raises(KeyError):
        mgr.restore(1, target=target, strict=True)
    state, _ = mgr.restore(1, target=target, strict=False)
    np.testing.assert_allclose(state["params"]["w"], 1.0)
    np.testing.assert_allclose(state["params"]["new_leaf"], 9.0)  # kept init


def test_dtype_cast_on_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((2,), jnp.float32)})
    target = {"w": jnp.zeros((2,), jnp.bfloat16)}
    state, _ = mgr.restore(1, target=target)
    assert state["w"].dtype == jnp.bfloat16


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(5, _state(5.0))
    mgr.wait()
    state, _ = mgr.restore(5)
    np.testing.assert_allclose(state["params"]["w"], 5.0)


def test_atomicity_tmp_cleanup(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    # a leftover .tmp dir (crashed save) must not be listed as a checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_0000000002.tmp"))
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1
