"""Validate the multi-pod dry-run artifacts (results/dryrun/*.json): every
(arch x applicable shape x mesh) cell must exist and carry sane roofline
terms. Skipped when the dry-run has not been executed yet."""
import glob
import json
import os

import pytest

from repro.configs import applicable_shapes, get_arch, list_archs

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def _cells():
    for arch in list_archs():
        for shape in applicable_shapes(get_arch(arch)):
            for mesh in ("single", "multi"):
                yield arch, shape, mesh


@pytest.mark.skipif(not glob.glob(os.path.join(RESULTS, "*.json")),
                    reason="dry-run artifacts not generated")
def test_all_cells_present_and_sane():
    missing, bad = [], []
    for arch, shape, mesh in _cells():
        p = os.path.join(RESULTS, f"{arch}__{shape}__{mesh}.json")
        if not os.path.exists(p):
            missing.append((arch, shape, mesh))
            continue
        d = json.load(open(p))
        t = d["terms"]
        chips = 512 if mesh == "multi" else 256
        if d["chips"] != chips:
            bad.append((arch, shape, mesh, "chips"))
        if not (t["compute_s"] >= 0 and t["memory_s"] > 0):
            bad.append((arch, shape, mesh, "terms"))
        if t["dominant"] not in ("compute", "memory", "collective"):
            bad.append((arch, shape, mesh, "dominant"))
    assert not missing, f"missing dry-run cells: {missing}"
    assert not bad, f"bad dry-run cells: {bad}"


@pytest.mark.skipif(not glob.glob(os.path.join(RESULTS, "*unrolled.json")),
                    reason="roofline artifacts not generated")
def test_roofline_cells_have_collectives_and_flops():
    for p in glob.glob(os.path.join(RESULTS, "*unrolled.json")):
        d = json.load(open(p))
        assert d["terms"]["flops_per_device"] > 0, p
        assert d["collectives"]["total_bytes"] > 0, p
        assert 0 < d["terms"]["useful_ratio"] < 10, (p, d["terms"]["useful_ratio"])
