"""Task subsystem (DESIGN.md §12): LambdaMART ranking, uplift trees and
isolation forests through the existing growers and engines.

Pins, in order: hand-computed NDCG@k and Qini/AUUC golden oracles (exact
values on tiny fixed inputs); the group-batched lambda pass bit-equal to a
naive per-group loop at equal padded widths; the LambdaMART >= 0.03 NDCG@5
edge over pointwise regression on grouped-relevance data; the isolation
forest's planted-anomaly AUC; wrong-task entry points failing fast with
directions; the CLI --task round trip; and the rank-bench --quick smoke.
"""
import os

import numpy as np
import pytest

from repro.core import GradientBoostedTreesLearner, Model, Task, YdfError
from repro.core.evaluation import evaluate_predictions, ndcg_at_k, qini_curve
from repro.data.tabular import grouped_relevance, planted_anomaly, \
    randomized_treatment
from repro.tasks import (
    IsolationForestLearner,
    UpliftTreesLearner,
    group_aware_split,
    group_layout,
    lambda_grad_batched,
    lambda_grad_naive,
)

pytestmark = pytest.mark.tasks


# ------------------------------------------------------------ metric goldens

def test_ndcg_golden_hand_computed():
    """One 4-doc group, k=3, every term written out by hand.

    Scores order the docs [d1, d3, d2, d0] (descending, stable); their
    relevances are [1, 2, 0, 3], gains 2^rel - 1 = [1, 3, 0, 7].
    DCG@3  = 1/log2(2) + 3/log2(3) + 0/log2(4)
    IDCG@3 = 7/log2(2) + 3/log2(3) + 1/log2(4)   (ideal rel order 3,2,1).
    """
    y = np.array([3.0, 1.0, 0.0, 2.0])
    score = np.array([0.1, 0.4, 0.2, 0.3])
    groups = np.zeros(4, np.int64)
    want = (1.0 + 3.0 / np.log2(3)) / (7.0 + 3.0 / np.log2(3) + 0.5)
    assert ndcg_at_k(y, score, groups, k=3) == pytest.approx(want, abs=1e-12)


def test_ndcg_ties_break_by_index_and_zero_groups_score_zero():
    # tie on scores: the FIRST index wins the top rank (stable argsort)
    y = np.array([0.0, 2.0])
    want = (3.0 / np.log2(3)) / 3.0       # rel-2 doc stuck at rank 2
    assert ndcg_at_k(y, np.array([0.5, 0.5]), np.zeros(2, np.int64),
                     k=2) == pytest.approx(want, abs=1e-12)
    # a group with no relevant doc (IDCG = 0) contributes exactly 0
    y2 = np.r_[y, 0.0, 0.0]
    g2 = np.r_[0, 0, 1, 1].astype(np.int64)
    assert ndcg_at_k(y2, np.array([0.5, 0.5, 1.0, 2.0]), g2,
                     k=2) == pytest.approx(want / 2, abs=1e-12)


def test_qini_auuc_golden_hand_computed():
    """4 rows already sorted by score; every cumulative term by hand:
    g = [1-0, 1-1*1/1, 1-1*2/1, 1-2*2/2] = [1, 0, -1, -1]
    auuc = mean(g)/n = -0.0625
    qini = (mean(g) - g[-1]*(n+1)/(2n))/n = (-0.25 + 0.625)/4 = 0.09375.
    """
    score = np.array([4.0, 3.0, 2.0, 1.0])
    treatment = np.array([1, 0, 1, 0], np.int64)
    y = np.array([1.0, 1.0, 0.0, 1.0])
    np.testing.assert_allclose(qini_curve(y, score, treatment),
                               [1.0, 0.0, -1.0, -1.0], atol=1e-15)
    ev = evaluate_predictions(Task.UPLIFT, score, y, treatment=treatment)
    assert ev.metrics["auuc"] == pytest.approx(-0.0625, abs=1e-12)
    assert ev.metrics["qini"] == pytest.approx(0.09375, abs=1e-12)
    assert ev.primary == ev.metrics["qini"]


# ------------------------------------------------- lambda pass bit-equality

def test_lambda_batched_bit_equals_naive_loop_sweep():
    """The one-padded-pass lambda kernel is bit-identical to a per-group
    Python loop padded to the same width — seeded sweep over ragged shapes
    including size-1 groups (no pairs) and all-tied relevances."""
    rng = np.random.default_rng(0)
    for trial in range(12):
        n_groups = int(rng.integers(2, 40))
        sizes = rng.integers(1, 24, n_groups)
        groups = np.repeat(np.arange(n_groups), sizes)
        rng.shuffle(groups)
        layout = group_layout(groups)
        scores = rng.normal(size=len(groups)) * float(rng.integers(1, 10))
        rel = rng.integers(0, 5, len(groups)).astype(np.float64)
        if trial % 4 == 0:
            rel[:] = 2.0                          # all tied: zero lambdas
        k = int(rng.integers(1, 8))
        gb, hb = lambda_grad_batched(scores, rel, layout, k=k)
        gn, hn = lambda_grad_naive(scores, rel, layout, k=k,
                                   pad_to=layout.max_size)
        assert np.array_equal(gb, gn), trial
        assert np.array_equal(hb, hn), trial
        if (rel[:] == 2.0).all():
            assert np.all(gb == 0.0)


def test_group_layout_round_trip_and_split():
    groups = np.array([3, 0, 3, 1, 0, 3], np.int64)
    layout = group_layout(groups)
    flat = np.arange(6, dtype=np.float64)
    assert np.array_equal(layout.unpad(layout.pad(flat)), flat)
    assert layout.n_groups == 3 and layout.max_size == 3
    # group-aware validation split keeps every group whole
    gid = np.repeat(np.arange(20), 5)
    tr, va = group_aware_split(gid, 0.25, seed=3)
    assert len(np.intersect1d(gid[tr], gid[va])) == 0
    assert len(tr) + len(va) == len(gid) and len(va) == 25


# ------------------------------------------------------------ accuracy pins

def test_lambdamart_beats_pointwise_regression_on_ndcg():
    """The acceptance pin: >= 0.03 NDCG@5 over a pointwise-regression GBT
    on grouped-relevance data (observed ~ +0.08). The mechanism: most label
    variance is an unobserved query-level bias that pointwise must regress
    through, while within-group lambda pairs cancel it exactly."""
    ds = grouped_relevance()
    gid = np.asarray([int(v) for v in ds["group"]], np.int64)
    y = np.array([float(v) for v in ds["rel"]])
    tr_idx, te_idx = group_aware_split(gid, 0.3, 99)
    tr = {k: v[tr_idx] for k, v in ds.items()}
    te = {k: v[te_idx] for k, v in ds.items()}
    g_te, y_te = gid[te_idx], y[te_idx]
    lm = GradientBoostedTreesLearner(label="rel", task=Task.RANKING,
                                     num_trees=80, seed=1).train(tr)
    nd_lm = ndcg_at_k(y_te, np.asarray(lm.predict(te)), g_te, 5)
    reg = GradientBoostedTreesLearner(
        label="rel", task=Task.REGRESSION, num_trees=80, seed=1).train(
        {k: v for k, v in tr.items() if k != "group"})
    nd_reg = ndcg_at_k(y_te, np.asarray(reg.predict(te)), g_te, 5)
    assert nd_lm - nd_reg >= 0.03, (nd_lm, nd_reg)
    # the trained ranking model evaluates through the task head end to end
    ev = lm.evaluate(te)
    assert ev.task == Task.RANKING
    assert ev.metrics["ndcg@5"] == pytest.approx(nd_lm, abs=1e-12)


def test_isolation_forest_planted_anomaly_auc():
    da = planted_anomaly()
    m = IsolationForestLearner(label="anomaly", num_trees=100, seed=3).train(da)
    ev = m.evaluate(da)
    assert ev.task == Task.ANOMALY
    assert ev.metrics["auc"] >= 0.9, ev.metrics
    # scores live in (0, 1]: 2^(-E[h]/c(psi))
    p = np.asarray(m.predict(da))
    assert (p > 0).all() and (p <= 1).all()


def test_uplift_trees_positive_qini_on_randomized_treatment():
    du = randomized_treatment()
    m = UpliftTreesLearner(label="outcome", num_trees=20, seed=2).train(du)
    ev = m.evaluate(du)
    assert ev.task == Task.UPLIFT
    assert ev.metrics["qini"] > 0.0, ev.metrics
    # effects are centered-ish differences of probabilities, not scores
    p = np.asarray(m.predict(du))
    assert (np.abs(p) <= 1.0).all()


# ------------------------------------------------------------- task guards

def _tiny_models():
    ds_r = grouped_relevance(n_groups=25, seed=7)
    ds_u = randomized_treatment(n=300, seed=11)
    ds_a = planted_anomaly(n_inlier=120, n_anomaly=8, seed=13)
    return [
        ("ranking", GradientBoostedTreesLearner(
            label="rel", task=Task.RANKING, num_trees=4,
            seed=1).train(ds_r), ds_r, "group"),
        ("uplift", UpliftTreesLearner(
            label="outcome", num_trees=3, seed=2).train(ds_u), ds_u,
         "treatment"),
        ("anomaly", IsolationForestLearner(
            label="anomaly", num_trees=4, seed=3).train(ds_a), ds_a, None),
    ]


def test_predict_class_fails_fast_before_inference():
    """Wrong-task predict_class raises BEFORE touching the dataset: passing
    garbage as the dataset must still produce the directed task error."""
    for name, model, _, _ in _tiny_models():
        with pytest.raises(YdfError, match="classification model"):
            model.predict_class(object())     # would explode if inferred


def test_summary_names_the_task():
    for name, model, _, _ in _tiny_models():
        assert f"Task: {model.task.value}" in model.summary(), name


def test_evaluate_missing_side_column_is_directed():
    for name, model, data, side in _tiny_models():
        if side is None:
            continue
        broken = {k: v for k, v in data.items() if k != side}
        with pytest.raises(YdfError, match=side):
            model.evaluate(broken)


def test_gbt_rejects_uplift_and_anomaly_with_directions():
    ds = grouped_relevance(n_groups=15, seed=7)   # numerical label
    ds["treatment"] = (np.arange(len(ds["rel"])) % 2).astype(object)
    for task, learner_name in ((Task.UPLIFT, "UPLIFT_TREES"),
                               (Task.ANOMALY, "ISOLATION_FOREST")):
        with pytest.raises(YdfError, match=learner_name):
            GradientBoostedTreesLearner(label="rel", task=task,
                                        num_trees=2).train(ds)
    with pytest.raises(YdfError, match="UPLIFT"):
        UpliftTreesLearner(label="outcome", task=Task.CLASSIFICATION)
    with pytest.raises(YdfError, match="ANOMALY"):
        IsolationForestLearner(task=Task.REGRESSION)


def test_ranking_train_requires_group_column():
    ds = grouped_relevance(n_groups=20, seed=7)
    ds.pop("group")
    with pytest.raises(YdfError, match="group"):
        GradientBoostedTreesLearner(label="rel", task=Task.RANKING,
                                    num_trees=2).train(ds)


# ------------------------------------------------------ serving and analysis

def test_task_models_serve_through_bundle_bit_identical():
    from repro.serving.forest import make_forest_server
    for name, model, data, side in _tiny_models():
        bundle = make_forest_server(model, warmup=False)
        feats = {k: v for k, v in data.items() if k != model.label}
        got = np.asarray(bundle.predict(feats))
        want = np.asarray(model.predict(data))
        assert np.array_equal(got, want), name


def test_ranking_analyze_reports_task_metrics():
    ds = grouped_relevance(n_groups=25, seed=7)
    model = GradientBoostedTreesLearner(label="rel", task=Task.RANKING,
                                        num_trees=4, seed=1).train(ds)
    report = model.analyze(ds, permutation_repetitions=1)
    assert report.task == "RANKING"
    assert report.evaluation is not None
    assert "ndcg@5" in report.evaluation.metrics
    kinds = {t.kind for t in report.importances}
    assert "MEAN_INCREASE_RMSE" in kinds      # scalar-proxy permutation VI


# --------------------------------------------------------------- CLI + bench

def test_cli_train_task_round_trip(tmp_path, capsys):
    from repro.cli import main
    from repro.data.io import write_dataset

    cases = [
        ("ranking", grouped_relevance(n_groups=25, seed=7), "rel",
         Task.RANKING, "GradientBoostedTreesModel"),
        ("uplift", randomized_treatment(n=300, seed=11), "outcome",
         Task.UPLIFT, "UpliftModel"),
        ("anomaly", planted_anomaly(n_inlier=120, n_anomaly=8, seed=13),
         "anomaly", Task.ANOMALY, "IsolationForestModel"),
    ]
    for task_arg, data, label, task, model_cls in cases:
        csv_path = f"csv:{tmp_path}/{task_arg}.csv"
        write_dataset(data, csv_path)
        out = str(tmp_path / f"model_{task_arg}")
        main(["train", "--dataset", csv_path, "--label", label,
              "--task", task_arg, "--seed", "7",
              "--hparam", "num_trees=4", "--output", out])
        model = Model.load(out)
        assert model.task == task
        assert type(model).__name__ == model_cls
        pred_path = f"csv:{tmp_path}/pred_{task_arg}.csv"
        main(["predict", "--dataset", csv_path, "--model", out,
              "--output", pred_path])
        assert os.path.exists(pred_path[len("csv:"):])
    capsys.readouterr()


def test_rank_bench_quick_smoke():
    from benchmarks import rank_bench
    res = rank_bench.run_smoke()
    assert res["all_agree_1e12"] is True
    assert set(res["configs"]) == {"uniform_small", "uniform_large", "skewed"}
    for cfg in res["configs"].values():
        assert cfg["ms_naive"] > 0 and cfg["ms_batched"] > 0
        assert cfg["max_abs_diff_grad"] <= 1e-12
        assert cfg["max_abs_diff_hess"] <= 1e-12
    assert res["headline_speedup"] == max(
        c["speedup"] for c in res["configs"].values())
