"""Inference engines (§3.7): all engines agree; lossy compilation is explicit;
per-kernel allclose vs the jnp oracle with hypothesis shape/dtype sweeps."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core.models as M
from repro.core import GradientBoostedTreesLearner, RandomForestLearner, YdfError
from repro.core.engines import available_engines, benchmark_inference, compile_model
from repro.data.tabular import adult_like, train_test_split


@pytest.fixture(scope="module")
def trained():
    train, test = train_test_split(adult_like(800), 0.3, 1)
    gbt = GradientBoostedTreesLearner(label="income", num_trees=6).train(train)
    rf = RandomForestLearner(label="income", num_trees=4, max_depth=6).train(train)
    ds = M._as_vertical(test, gbt.spec)
    X = M.raw_matrix(ds, gbt.features)
    return gbt, rf, X


def test_all_engines_agree(trained):
    gbt, rf, X = trained
    for model in (gbt, rf):
        outs = {}
        for name in available_engines(model.forest):
            outs[name] = np.asarray(compile_model(model, name).per_tree(X[:40]))
        base = outs["naive"]
        for name, o in outs.items():
            np.testing.assert_allclose(o, base, atol=1e-5, err_msg=name)


def test_engine_selection_is_hardware_aware(trained):
    gbt, _, _ = trained
    eng = compile_model(gbt, None)
    assert eng.name == "vectorized"  # pallas-interpret not picked on CPU


def test_oblique_incompatible_with_pallas_raises():
    train, _ = train_test_split(adult_like(600), 0.3, 1)
    m = GradientBoostedTreesLearner(label="income", num_trees=4,
                                    split_axis="SPARSE_OBLIQUE").train(train)
    if (m.forest.feature == -2).any():
        with pytest.raises(YdfError, match="pallas"):
            compile_model(m, "pallas")
        assert "pallas" not in available_engines(m.forest)
    # auto-selection still works (lossy compilation falls back)
    assert compile_model(m, None).name in ("vectorized",)


def test_benchmark_inference_report(trained):
    gbt, _, _ = trained
    _, test = train_test_split(adult_like(400), 0.5, 1)
    rep = benchmark_inference(gbt, test, repetitions=1)
    assert "us/example" in rep and "vectorized" in rep


# ------------------------------------------------------------------
# hypothesis sweeps: kernels vs jnp oracle over shapes/dtypes
# ------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 300), f=st.integers(1, 6), s=st.integers(1, 5),
       nodes=st.integers(1, 9), bins=st.sampled_from([8, 32, 256]),
       dt=st.sampled_from(["float32", "float64"]), seed=st.integers(0, 99))
def test_histogram_kernel_sweep(n, f, s, nodes, bins, dt, seed):
    import jax.numpy as jnp
    from repro.kernels.histogram.ops import histogram
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, bins, (n, f)).astype(np.uint8)
    stats = rng.normal(size=(n, s)).astype(dt)
    node_of = rng.integers(-1, nodes, n).astype(np.int32)
    ref = np.asarray(histogram(jnp.asarray(codes), jnp.asarray(stats),
                               jnp.asarray(node_of), nodes, bins, impl="ref"))
    pal = np.asarray(histogram(jnp.asarray(codes), jnp.asarray(stats),
                               jnp.asarray(node_of), nodes, bins,
                               impl="interpret"))
    np.testing.assert_allclose(pal, ref, atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 100), trees=st.integers(1, 5), seed=st.integers(0, 99))
def test_forest_infer_kernel_sweep(n, trees, seed):
    """Random trained forests (incl. categorical masks) on random inputs."""
    from repro.core.tree import predict_raw
    from repro.kernels.forest_infer.ops import forest_predict
    rng = np.random.default_rng(seed)
    train, _ = train_test_split(adult_like(300, seed=seed), 0.3, seed)
    m = GradientBoostedTreesLearner(label="income", num_trees=trees,
                                    max_depth=4, seed=seed).train(train)
    ds = M._as_vertical(train, m.spec)
    X = M.raw_matrix(ds, m.features)[:n]
    want = predict_raw(m.forest, X)
    got = np.asarray(forest_predict(m.forest, X, impl="interpret"))
    np.testing.assert_allclose(got, want, atol=1e-5)
