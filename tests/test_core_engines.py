"""Inference engines (§3.7): all engines agree; lossy compilation is explicit;
per-kernel sweeps vs the jnp oracle live in test_property_sweeps.py."""
import numpy as np
import pytest

import repro.core.models as M
from repro.core import GradientBoostedTreesLearner, RandomForestLearner, YdfError
from repro.core.engines import available_engines, benchmark_inference, compile_model
from repro.data.tabular import adult_like, train_test_split


@pytest.fixture(scope="module")
def trained():
    train, test = train_test_split(adult_like(800), 0.3, 1)
    gbt = GradientBoostedTreesLearner(label="income", num_trees=6).train(train)
    rf = RandomForestLearner(label="income", num_trees=4, max_depth=6).train(train)
    ds = M._as_vertical(test, gbt.spec)
    X = M.raw_matrix(ds, gbt.features)
    return gbt, rf, X


def test_all_engines_agree(trained):
    gbt, rf, X = trained
    for model in (gbt, rf):
        outs = {}
        for name in available_engines(model.forest):
            outs[name] = np.asarray(compile_model(model, name).per_tree(X[:40]))
        base = outs["naive"]
        for name, o in outs.items():
            np.testing.assert_allclose(o, base, atol=1e-5, err_msg=name)


def test_engine_selection_is_hardware_aware(trained):
    gbt, _, _ = trained
    eng = compile_model(gbt, None)
    assert eng.name == "vectorized"  # pallas-interpret not picked on CPU


def test_oblique_incompatible_with_pallas_raises():
    train, _ = train_test_split(adult_like(600), 0.3, 1)
    m = GradientBoostedTreesLearner(label="income", num_trees=4,
                                    split_axis="SPARSE_OBLIQUE").train(train)
    if (m.forest.feature == -2).any():
        with pytest.raises(YdfError, match="pallas"):
            compile_model(m, "pallas")
        assert "pallas" not in available_engines(m.forest)
    # auto-selection still works (lossy compilation falls back)
    assert compile_model(m, None).name in ("vectorized",)


def test_benchmark_inference_report(trained):
    gbt, _, _ = trained
    _, test = train_test_split(adult_like(400), 0.5, 1)
    rep = benchmark_inference(gbt, test, repetitions=1)
    assert "us/example" in rep and "vectorized" in rep


# hypothesis shape/dtype sweeps for the kernels live in
# tests/test_property_sweeps.py (skipped when hypothesis is unavailable)
