"""Inference engines (§3.7): all engines agree; lossy compilation is explicit;
per-kernel sweeps vs the jnp oracle live in test_property_sweeps.py."""
import numpy as np
import pytest

import repro.core.models as M
from repro.core import GradientBoostedTreesLearner, RandomForestLearner, YdfError
from repro.core.engines import available_engines, benchmark_inference, compile_model
from repro.data.tabular import adult_like, train_test_split


@pytest.fixture(scope="module")
def trained():
    train, test = train_test_split(adult_like(800), 0.3, 1)
    gbt = GradientBoostedTreesLearner(label="income", num_trees=6).train(train)
    rf = RandomForestLearner(label="income", num_trees=4, max_depth=6).train(train)
    ds = M._as_vertical(test, gbt.spec)
    X = M.raw_matrix(ds, gbt.features)
    return gbt, rf, X


def test_all_engines_agree(trained):
    gbt, rf, X = trained
    for model in (gbt, rf):
        outs = {}
        for name in available_engines(model.forest):
            outs[name] = np.asarray(compile_model(model, name).per_tree(X[:40]))
        base = outs["naive"]
        for name, o in outs.items():
            np.testing.assert_allclose(o, base, atol=1e-5, err_msg=name)


def test_engine_selection_is_hardware_aware(trained):
    gbt, _, _ = trained
    eng = compile_model(gbt, None)
    assert eng.name == "vectorized"  # pallas-interpret not picked on CPU


def test_oblique_incompatible_with_pallas_raises():
    train, _ = train_test_split(adult_like(600), 0.3, 1)
    m = GradientBoostedTreesLearner(label="income", num_trees=4,
                                    split_axis="SPARSE_OBLIQUE").train(train)
    if (m.forest.feature == -2).any():
        with pytest.raises(YdfError, match="pallas"):
            compile_model(m, "pallas")
        assert "pallas" not in available_engines(m.forest)
    # auto-selection still works (lossy compilation falls back)
    assert compile_model(m, None).name in ("vectorized",)


def test_benchmark_inference_report(trained):
    gbt, _, _ = trained
    _, test = train_test_split(adult_like(400), 0.5, 1)
    rep = benchmark_inference(gbt, test, repetitions=1)
    assert "us/example" in rep and "vectorized" in rep
    # per-engine compile time is reported separately (warmup at timed shape)
    assert "compile" in rep


# ---------------------------------------------------------------- §5 matrix

class _Holder:
    """Minimal model stand-in for compile_model on synthetic forests."""
    def __init__(self, forest):
        self.forest = forest


def _assert_engines_agree(forest, X, atol=1e-5, naive_rows=None):
    from repro.core.tree import predict_naive
    model = _Holder(forest)
    engines = available_engines(forest)
    # registry order: pallas, then the §10 CPU strategies (leaf_path only
    # within its table budget), then the host engines
    assert engines[0] == "pallas" and engines[1] == "bucketed"
    assert engines[-2:] == ["vectorized", "naive"]
    assert set(engines) - {"leaf_path"} == {"pallas", "bucketed",
                                            "vectorized", "naive"}
    outs = {name: np.asarray(compile_model(model, name).per_tree(X))
            for name in ("vectorized", "pallas")}
    for name in engines:
        if name in ("bucketed", "leaf_path"):
            got = np.asarray(compile_model(model, name).per_tree(X))
            # the bucketed strategies are BIT-identical to the numpy
            # engine, not merely allclose (DESIGN.md §10.5)
            assert np.array_equal(got, outs["vectorized"]), name
    for name, o in outs.items():
        assert o.shape == (len(X), forest.n_trees, forest.leaf_value.shape[-1])
    np.testing.assert_allclose(outs["pallas"], outs["vectorized"], atol=atol,
                               err_msg="pallas vs vectorized")
    nr = len(X) if naive_rows is None else min(naive_rows, len(X))
    np.testing.assert_allclose(outs["vectorized"][:nr],
                               predict_naive(forest, X[:nr]), atol=atol,
                               err_msg="vectorized vs naive")


def test_engine_matrix_categorical(trained):
    gbt, rf, X = trained
    for model in (gbt, rf):
        _assert_engines_agree(model.forest, X[:40].astype(np.float32))


def test_engine_matrix_ragged_depth(random_forest_factory):
    forest = random_forest_factory(6, [2, 20, 90], 5, out_dim=2, seed=3)
    from repro.core.tree import tree_depths
    d = tree_depths(forest)
    assert d.max() > 3 * max(1, d.min())  # genuinely ragged
    X = np.abs(np.random.default_rng(0).normal(size=(33, 5))) \
        .astype(np.float32) * 3
    _assert_engines_agree(forest, X)


def test_engine_matrix_multiclass():
    from repro.data.tabular import SUITE, make_dataset
    train, test = train_test_split(make_dataset(SUITE[0]), 0.3, 1)  # 3 classes
    gbt = GradientBoostedTreesLearner(label="label", num_trees=9).train(train)
    rf = RandomForestLearner(label="label", num_trees=4, max_depth=6).train(train)
    assert gbt.forest.out_dim == 3 and rf.forest.leaf_value.shape[-1] == 3
    ds = M._as_vertical(test, gbt.spec)
    for model in (gbt, rf):
        X = M.raw_matrix(ds, model.features)[:30]
        _assert_engines_agree(model.forest, X)
        p = model.predict(test)
        assert p.shape == (ds.n_rows, 3)
        np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)


def test_large_forest_compiles_on_pallas(random_forest_factory):
    """Regression: >4096-node forests used to raise 'VMEM budget' on the
    pallas engine; the tree-tiled kernel (DESIGN.md §5.2) compiles them."""
    forest = random_forest_factory(2, [2300], 4, seed=5, cat_feats=(2,))
    assert forest.max_nodes > 4096
    assert "pallas" in available_engines(forest)
    X = np.abs(np.random.default_rng(1).normal(size=(16, 4))) \
        .astype(np.float32) * 3
    X[:, 2] = np.random.default_rng(2).integers(0, 256, size=16)
    _assert_engines_agree(forest, X, naive_rows=3)


# hypothesis shape/dtype sweeps for the kernels live in
# tests/test_property_sweeps.py (skipped when hypothesis is unavailable)
