"""Dataspec inference, overrides, encodings, safety errors (paper §2.1/2.2)."""
import numpy as np
import pytest

from repro.core import Task, YdfError
from repro.core.dataspec import (
    Semantic,
    check_classification_label,
    dataset_from_raw,
    encode_dataset,
    infer_dataspec,
)


def _data():
    return {
        "age": np.array([25, 38, None, 52, 17], dtype=object),
        "color": np.array(["red", "blue", "red", None, "green"], dtype=object),
        "flag": np.array([True, False, True, True, False], dtype=object),
        "mixed": np.array(["2", "x", "3", "2", "x"], dtype=object),
    }


def test_semantic_inference():
    spec = infer_dataspec(_data())
    assert spec["age"].semantic == Semantic.NUMERICAL
    assert spec["color"].semantic == Semantic.CATEGORICAL
    assert spec["flag"].semantic == Semantic.BOOLEAN
    assert spec["mixed"].semantic == Semantic.CATEGORICAL  # non-numeric present
    assert spec["age"].n_missing == 1
    assert spec.n_rows == 5


def test_user_override_wins_and_is_flagged():
    spec = infer_dataspec(_data(), semantics={"age": "CATEGORICAL"})
    assert spec["age"].semantic == Semantic.CATEGORICAL
    assert spec["age"].manually_defined
    assert "manually-defined" in spec.report()


def test_vocab_is_frequency_ordered_with_ood():
    spec = infer_dataspec(_data())
    assert spec["color"].vocab[0] == "<OOD>"
    assert spec["color"].vocab[1] == "red"  # most frequent


def test_encoding_missing_and_ood():
    spec = infer_dataspec(_data())
    ds = encode_dataset(_data(), spec)
    assert np.isnan(ds.numerical["age"][2])
    assert ds.categorical["color"][3] == -1  # missing
    new = dict(_data())
    new["color"] = np.array(["purple"] * 5, dtype=object)  # unseen
    ds2 = encode_dataset(new, spec)
    assert (ds2.categorical["color"] == 0).all()  # OOD bucket


def test_numerical_override_with_strings_raises_helpfully():
    with pytest.raises(YdfError, match="CATEGORICAL"):
        infer_dataspec(_data(), semantics={"mixed": "NUMERICAL"})


def test_classification_label_looks_like_regression():
    """The paper's §2.2 safety check, with actionable message."""
    col = infer_dataspec({"revenue": np.arange(5000, dtype=float)})["revenue"]
    with pytest.raises(YdfError, match="task=REGRESSION"):
        check_classification_label(col, Task.CLASSIFICATION)


def test_mismatched_column_lengths():
    with pytest.raises(YdfError, match="same length"):
        infer_dataspec({"a": np.arange(3), "b": np.arange(4)})


def test_report_contains_stats():
    rep = infer_dataspec(_data()).report()
    assert "NUMERICAL" in rep and "CATEGORICAL" in rep
    assert "vocab-size" in rep and "mean" in rep


def test_single_class_label_error_mentions_solutions():
    from repro.core import GradientBoostedTreesLearner
    data = {"x": np.arange(50, dtype=float).astype(object),
            "y": np.array(["only"] * 50, dtype=object)}
    with pytest.raises(YdfError, match="classe"):
        GradientBoostedTreesLearner(label="y", num_trees=2).train(data)


def test_unknown_hyperparameter_error():
    from repro.core import GradientBoostedTreesLearner
    with pytest.raises(YdfError, match="Known hyper-parameters"):
        GradientBoostedTreesLearner(label="y", num_treez=5)


def test_csv_roundtrip(tmp_path):
    from repro.data.io import read_dataset, write_dataset
    data = _data()
    path = f"csv:{tmp_path}/d.csv"
    write_dataset(data, path)
    back = read_dataset(path)
    assert set(back) == set(data)
    assert back["age"][2] is None
    assert list(back["color"][:3]) == ["red", "blue", "red"]
    with pytest.raises(YdfError, match="format-prefixed"):
        read_dataset(str(tmp_path / "d.csv"))
