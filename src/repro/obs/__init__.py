"""Unified observability layer (DESIGN.md §13).

- ``obs.trace``   — nested spans, injectable clock, zero-cost disabled path
- ``obs.metrics`` — counters / gauges / bounded-reservoir histograms
- ``obs.export``  — Chrome trace-event + phase-aggregate exporters
- ``obs.logs``    — the standardized ``training_logs`` schema
- ``obs.clock``   — the sanctioned timing sources for all of ``src/``
"""
from . import clock, export, logs, metrics, trace
from .export import chrome_trace, phase_summary, profile_dict, \
    write_chrome_trace
from .logs import build_training_logs, summarize_training_logs, \
    validate_training_logs
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Span, Tracer, capture, enabled, event, span

__all__ = [
    "clock", "export", "logs", "metrics", "trace",
    "chrome_trace", "phase_summary", "profile_dict", "write_chrome_trace",
    "build_training_logs", "summarize_training_logs",
    "validate_training_logs",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Tracer", "capture", "enabled", "event", "span",
]
