"""Trace exporters: Chrome trace-event JSON and phase aggregates.

``chrome_trace`` emits the Trace Event Format consumed by
``chrome://tracing`` and Perfetto (https://ui.perfetto.dev): a dict
with a ``traceEvents`` list of complete ("X") events — microsecond
``ts``/``dur``, ``pid``/``tid`` lanes, span args — plus instant ("i")
events for things like worker deaths and checkpoint rollbacks.

``phase_summary`` folds a span tree into per-phase aggregates
(count / total / mean / max seconds, self-time excluding children);
``profile_dict`` is the versioned wrapper that lands in
``Model.training_logs["profile"]`` and the BENCH ``profile`` sections.
"""
from __future__ import annotations

import json
import numbers
from typing import Any, Dict, Iterable, List, Optional, Union

from .trace import Span, Tracer

__all__ = ["chrome_trace", "write_chrome_trace", "phase_summary",
           "profile_dict", "validate_chrome_trace"]

PROFILE_SCHEMA_VERSION = 1


def _roots(source: Union[Tracer, Iterable[Span]]) -> List[Span]:
    if isinstance(source, Tracer):
        return list(source.roots)
    return list(source)


def chrome_trace(source: Union[Tracer, Iterable[Span]],
                 *, pid: int = 1) -> Dict[str, Any]:
    """Render a tracer (or span list) as a Chrome trace-event dict."""
    roots = _roots(source)
    tids: Dict[str, int] = {}

    def tid_of(name: str) -> int:
        if name not in tids:
            tids[name] = len(tids) + 1
        return tids[name]

    events: List[Dict[str, Any]] = []
    t_origin = min((r.t0 for r in roots), default=0.0)
    if isinstance(source, Tracer) and source.events:
        t_origin = min(t_origin,
                       min(ev["ts"] for ev in source.events))

    for root in roots:
        for sp in root.walk():
            ev: Dict[str, Any] = {
                "name": sp.name,
                "cat": sp.name.split("/", 1)[0],
                "ph": "X",
                "ts": round((sp.t0 - t_origin) * 1e6, 3),
                "dur": round(sp.duration * 1e6, 3),
                "pid": pid,
                "tid": tid_of(sp.tid),
            }
            if sp.args:
                ev["args"] = {k: _jsonable(v) for k, v in sp.args.items()}
            events.append(ev)

    if isinstance(source, Tracer):
        for iev in source.events:
            ev = {
                "name": iev["name"],
                "cat": iev["name"].split("/", 1)[0],
                "ph": "i",
                "s": "t",
                "ts": round((iev["ts"] - t_origin) * 1e6, 3),
                "pid": pid,
                "tid": tid_of(iev["tid"]),
            }
            if iev.get("args"):
                ev["args"] = {k: _jsonable(v)
                              for k, v in iev["args"].items()}
            events.append(ev)

    # Thread-name metadata rows make the Perfetto lanes readable.
    for tname, tid in tids.items():
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": tname}})

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str,
                       source: Union[Tracer, Iterable[Span]]) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(source), f)


def phase_summary(source: Union[Tracer, Iterable[Span]]) -> Dict[str, Any]:
    """Aggregate spans by name: count, total/mean/max wall seconds and
    self seconds (duration minus direct children)."""
    phases: Dict[str, Dict[str, float]] = {}
    for root in _roots(source):
        for sp in root.walk():
            d = phases.get(sp.name)
            if d is None:
                d = phases[sp.name] = {"count": 0, "total_s": 0.0,
                                       "self_s": 0.0, "max_s": 0.0}
            dur = sp.duration
            child = sum(c.duration for c in sp.children)
            d["count"] += 1
            d["total_s"] += dur
            d["self_s"] += max(0.0, dur - child)
            d["max_s"] = max(d["max_s"], dur)
    for d in phases.values():
        d["mean_s"] = d["total_s"] / d["count"] if d["count"] else 0.0
    return phases


def profile_dict(tracer: Tracer,
                 *, top_events: Optional[int] = 64) -> Dict[str, Any]:
    """Versioned profile payload for training_logs / BENCH files."""
    events = list(tracer.events)
    truncated = False
    if top_events is not None and len(events) > top_events:
        events = events[:top_events]
        truncated = True
    return {
        "schema_version": PROFILE_SCHEMA_VERSION,
        "span_count": tracer.span_count(),
        "phases": phase_summary(tracer),
        "events": [{k: ({a: _jsonable(b) for a, b in v.items()}
                        if k == "args" else _jsonable(v))
                    for k, v in ev.items()} for ev in events],
        "events_truncated": truncated,
    }


def validate_chrome_trace(doc: Any) -> None:
    """Raise ValueError unless *doc* is a structurally valid Chrome
    trace-event document (used by tests and `cli.py profile`)."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("chrome trace: missing traceEvents")
    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            raise ValueError(f"chrome trace: malformed event {ev!r}")
        if ev["ph"] == "X":
            for k in ("ts", "dur", "pid", "tid"):
                if k not in ev:
                    raise ValueError(
                        f"chrome trace: X event missing {k}: {ev!r}")
            if ev["dur"] < 0 or ev["ts"] < 0:
                raise ValueError(f"chrome trace: negative time: {ev!r}")
        elif ev["ph"] == "i":
            if "ts" not in ev:
                raise ValueError(f"chrome trace: i event missing ts: {ev!r}")


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, numbers.Integral):  # numpy int scalars
        return int(v)
    if isinstance(v, numbers.Real):      # numpy float scalars
        return float(v)
    return str(v)
