"""Standardized ``Model.training_logs`` schema (DESIGN.md §13.4).

Before §13 every learner invented its own dict: GBT had
``train_loss``/``num_trees``, RF added ``oob``/``tree_parallelism``,
CART only wrote logs when checkpointed, the distributed learners wrote
only ``resilience`` — consumers had to probe for every key.  Now every
learner builds its logs through :func:`build_training_logs`, so one
shape holds everywhere:

    {
      "schema_version": 1,
      "learner": "gbt" | "rf" | "cart" | "distributed_gbt"
                 | "simulated_cluster" | "uplift" | "isolation" | ...,
      "num_trees": int,
      "growth_engine": str | None,   # None: learner has no engine choice
      "engine_fallback": str | None, # engine asked for but replaced
      "resilience": list[dict],      # checkpoint/recovery events ([] = none)
      "interrupted": bool,           # cooperative SIGINT/SIGTERM truncation
      # learner-specific extras ride along: train_loss, valid_loss, oob,
      # tree_parallelism, checkpoint, psi, depth_cap, ...
      # "profile": phase breakdown — present iff tracing was active.
    }

:func:`validate_training_logs` is the shared gate (used by learners at
build time and by tests); :func:`attach_profile` snapshots the active
tracer's phase aggregates into ``logs["profile"]``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from . import export as _export
from . import trace as _trace

__all__ = ["TRAINING_LOGS_SCHEMA_VERSION", "REQUIRED_KEYS",
           "build_training_logs", "validate_training_logs",
           "attach_profile", "summarize_training_logs"]

TRAINING_LOGS_SCHEMA_VERSION = 1

REQUIRED_KEYS = ("schema_version", "learner", "num_trees", "growth_engine",
                 "engine_fallback", "resilience", "interrupted")


def build_training_logs(*, learner: str, num_trees: int,
                        growth_engine: Optional[str] = None,
                        engine_fallback: Optional[str] = None,
                        resilience: Optional[list] = None,
                        interrupted: bool = False,
                        extra: Optional[Dict[str, Any]] = None,
                        ) -> Dict[str, Any]:
    """Assemble, profile-stamp and validate one training_logs dict."""
    logs: Dict[str, Any] = {
        "schema_version": TRAINING_LOGS_SCHEMA_VERSION,
        "learner": learner,
        "num_trees": int(num_trees),
        "growth_engine": growth_engine,
        "engine_fallback": engine_fallback,
        "resilience": list(resilience) if resilience is not None else [],
        "interrupted": bool(interrupted),
    }
    if extra:
        for k, v in extra.items():
            if v is not None:
                logs[k] = v
    attach_profile(logs)
    return validate_training_logs(logs)


def validate_training_logs(logs: Dict[str, Any]) -> Dict[str, Any]:
    """Raise ``YdfError`` unless *logs* matches the §13.4 schema."""
    from repro.core.api import YdfError  # late: obs must not import core
    if not isinstance(logs, dict):
        raise YdfError(f"training_logs must be a dict, got {type(logs)}")
    missing = [k for k in REQUIRED_KEYS if k not in logs]
    if missing:
        raise YdfError(f"training_logs missing keys: {missing}")
    if logs["schema_version"] != TRAINING_LOGS_SCHEMA_VERSION:
        raise YdfError("training_logs schema_version "
                       f"{logs['schema_version']!r} != "
                       f"{TRAINING_LOGS_SCHEMA_VERSION}")
    if not isinstance(logs["learner"], str) or not logs["learner"]:
        raise YdfError("training_logs.learner must be a non-empty str")
    if not isinstance(logs["num_trees"], int) or logs["num_trees"] < 0:
        raise YdfError("training_logs.num_trees must be an int >= 0, got "
                       f"{logs['num_trees']!r}")
    for key in ("growth_engine", "engine_fallback"):
        if logs[key] is not None and not isinstance(logs[key], str):
            raise YdfError(f"training_logs.{key} must be str or None")
    if not isinstance(logs["resilience"], list):
        raise YdfError("training_logs.resilience must be a list")
    if not isinstance(logs["interrupted"], bool):
        raise YdfError("training_logs.interrupted must be a bool")
    return logs


def attach_profile(logs: Dict[str, Any]) -> Dict[str, Any]:
    """If a tracer is active, snapshot its phase aggregates into
    ``logs["profile"]`` (no-op when tracing is off)."""
    tracer = _trace.active()
    if tracer is not None:
        logs["profile"] = _export.profile_dict(tracer)
    return logs


def summarize_training_logs(logs: Optional[Dict[str, Any]]) -> list:
    """Uniform `summary()` lines for any schema-v1 training_logs."""
    if not logs:
        return []
    if "schema_version" not in logs:      # pre-§13 model pickle
        return [f"Training logs (legacy): {sorted(logs)}"]
    lines = [
        "Training logs (schema v%s): learner=%s trees=%d engine=%s%s" % (
            logs.get("schema_version"), logs.get("learner"),
            logs.get("num_trees", 0),
            logs.get("growth_engine") or "-",
            " (fallback from %s)" % logs["engine_fallback"]
            if logs.get("engine_fallback") else "")]
    res = logs.get("resilience") or []
    if res or logs.get("interrupted"):
        lines.append("  resilience: %d event(s)%s" % (
            len(res), "; INTERRUPTED (truncated model)"
            if logs.get("interrupted") else ""))
    prof = logs.get("profile")
    if prof:
        top = sorted(prof.get("phases", {}).items(),
                     key=lambda kv: -kv[1]["total_s"])[:3]
        if top:
            lines.append("  profile: " + ", ".join(
                f"{n} {d['total_s']*1e3:.1f}ms x{d['count']}"
                for n, d in top))
    return lines
