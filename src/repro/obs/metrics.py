"""Metrics registry: counters, gauges, bounded-reservoir histograms.

One schema for everything that counts or samples: serving counters
(`ServerMetrics` is a facade over this registry since §13), training
counters, and latency/size distributions.  Series are keyed by
``(name, sorted(labels))`` so `counter("dispatch", engine="pallas")`
and `counter("dispatch", engine="bucketed")` are separate series of
one logical metric.

Histograms keep an exact count/total plus a bounded reservoir (cap
65536, drop-oldest-half on overflow — the §9.4 soak-memory contract)
from which percentiles are computed.  Registries merge (worker →
coordinator roll-ups) and round-trip through plain dicts.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_RESERVOIR_CAP"]

DEFAULT_RESERVOIR_CAP = 65536

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _series_name(name: str, key: _LabelKey) -> str:
    if not key:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in key) + "}"


class Counter:
    """Monotonic-by-convention integer counter (settable for facades)."""

    __slots__ = ("value",)

    def __init__(self, value: int = 0) -> None:
        self.value = value

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_value(self) -> int:
        return self.value


class Gauge:
    """Last-write-wins float sample (queue depth, EWMA rate, ...)."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0) -> None:
        self.value = value

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_value(self) -> float:
        return self.value


class Histogram:
    """Exact count/total + bounded reservoir for percentile estimates.

    The reservoir drops its oldest half when full (cap is mutable so
    facades like ServerMetrics can expose a tunable), matching the
    pre-§13 ServerMetrics latency buffer byte for byte.
    """

    __slots__ = ("cap", "count", "total", "values")

    def __init__(self, cap: int = DEFAULT_RESERVOIR_CAP) -> None:
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.values.append(v)
        if len(self.values) > self.cap:
            del self.values[: len(self.values) // 2]

    def percentile(self, q: float) -> float:
        """q in [0, 100]; nearest-rank over the reservoir; 0.0 if empty."""
        if not self.values:
            return 0.0
        vs = sorted(self.values)
        idx = min(len(vs) - 1, max(0, int(round(q / 100.0 * (len(vs) - 1)))))
        return vs[idx]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "Histogram") -> None:
        self.count += other.count
        self.total += other.total
        self.values.extend(other.values)
        while len(self.values) > self.cap:
            del self.values[: len(self.values) // 2]

    def to_dict(self) -> Dict[str, Any]:
        return {"count": self.count, "total": self.total,
                "cap": self.cap, "reservoir": list(self.values)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Histogram":
        h = cls(cap=int(d.get("cap", DEFAULT_RESERVOIR_CAP)))
        h.count = int(d["count"])
        h.total = float(d["total"])
        h.values = [float(v) for v in d.get("reservoir", ())]
        return h


class MetricsRegistry:
    """Labeled series of counters, gauges and histograms.

    ``counter/gauge/histogram`` are get-or-create: instrumented code
    never pre-registers. ``merge`` adds counters, sums histograms and
    takes the other registry's gauges (last write wins), so worker
    registries roll up into a coordinator's without key coordination.
    """

    SCHEMA_VERSION = 1

    def __init__(self) -> None:
        self._counters: Dict[Tuple[str, _LabelKey], Counter] = {}
        self._gauges: Dict[Tuple[str, _LabelKey], Gauge] = {}
        self._hists: Dict[Tuple[str, _LabelKey], Histogram] = {}

    # -- get-or-create accessors --------------------------------------
    def counter(self, name: str, **labels: Any) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels: Any) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, cap: int = DEFAULT_RESERVOIR_CAP,
                  **labels: Any) -> Histogram:
        key = (name, _label_key(labels))
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram(cap=cap)
        return h

    # -- queries -------------------------------------------------------
    def series(self, name: str) -> Iterator[Tuple[Dict[str, str], Any]]:
        """Yield ``(labels_dict, instrument)`` for every series of name
        across all three kinds."""
        for store in (self._counters, self._gauges, self._hists):
            for (n, key), obj in store.items():
                if n == name:
                    yield dict(key), obj

    def labeled_values(self, name: str, label: str) -> Dict[str, Any]:
        """Collapse one label dimension to ``{label_value: value}`` —
        e.g. ``labeled_values("engine_dispatches", "engine")``."""
        out: Dict[str, Any] = {}
        for labels, obj in self.series(name):
            if label in labels:
                out[labels[label]] = obj.to_value() \
                    if hasattr(obj, "to_value") else obj
        return out

    # -- merge / serialization ----------------------------------------
    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        for (n, key), c in other._counters.items():
            self._counters.setdefault((n, key), Counter()).value += c.value
        for (n, key), g in other._gauges.items():
            self._gauges.setdefault((n, key), Gauge()).value = g.value
        for (n, key), h in other._hists.items():
            mine = self._hists.get((n, key))
            if mine is None:
                mine = self._hists[(n, key)] = Histogram(cap=h.cap)
            mine.merge(h)
        return self

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": self.SCHEMA_VERSION,
            "counters": {_series_name(n, k): c.value
                         for (n, k), c in sorted(self._counters.items())},
            "gauges": {_series_name(n, k): g.value
                       for (n, k), g in sorted(self._gauges.items())},
            "histograms": {_series_name(n, k): h.to_dict()
                           for (n, k), h in sorted(self._hists.items())},
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MetricsRegistry":
        reg = cls()
        for key, v in d.get("counters", {}).items():
            name, labels = _parse_series_name(key)
            reg.counter(name, **labels).value = int(v)
        for key, v in d.get("gauges", {}).items():
            name, labels = _parse_series_name(key)
            reg.gauge(name, **labels).value = float(v)
        for key, hd in d.get("histograms", {}).items():
            name, labels = _parse_series_name(key)
            lk = (name, _label_key(labels))
            reg._hists[lk] = Histogram.from_dict(hd)
        return reg


def _parse_series_name(s: str) -> Tuple[str, Dict[str, str]]:
    if "{" not in s:
        return s, {}
    name, rest = s.split("{", 1)
    body = rest.rstrip("}")
    labels: Dict[str, str] = {}
    if body:
        for part in body.split(","):
            k, v = part.split("=", 1)
            labels[k] = v
    return name, labels
