"""Structured tracing: nested spans with an injectable clock.

Design (DESIGN.md §13):

- One module-level active tracer (``_active``).  Instrumented code calls
  ``trace.span("subsystem/phase", **args)`` unconditionally; when no
  tracer is active the call returns a shared no-op context manager and
  does nothing else — the disabled path is one global load, one ``if``
  and a pre-allocated singleton, gated at ≤1% of a 50-tree GBT train by
  ``tests/test_obs.py::test_disabled_tracer_overhead_gate``.
- Span stacks are thread-local; finished top-level spans from every
  thread land in ``Tracer.roots`` (lock-protected), so lockstep RF
  blocks and server worker threads each get their own well-nested tree.
- The clock is injectable (``Tracer(clock=FakeClock().now)``), reusing
  the §9.3 pattern: span tests are deterministic and wall-clock-free.
- Spans survive exceptions: the ``with`` block closes the span on the
  error path too and tags it ``error=<ExcType>`` so a trace of a failed
  run shows *where* it died.

Span names follow ``subsystem/phase`` (e.g. ``grower/gain_scan``,
``engines/dispatch``, ``checkpoint/save``); exporters group on the
full name and categorize on the prefix.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional

from . import clock as _clock

__all__ = ["Span", "Tracer", "span", "event", "capture", "enabled",
           "active", "start", "stop"]


class Span:
    """One timed phase: name, [t0, t1) in tracer-clock seconds, args,
    children. Plain attributes, no dataclass overhead on the hot path."""

    __slots__ = ("name", "t0", "t1", "args", "children", "tid")

    def __init__(self, name: str, t0: float, args: Dict[str, Any],
                 tid: str) -> None:
        self.name = name
        self.t0 = t0
        self.t1 = t0
        self.args = args
        self.children: List["Span"] = []
        self.tid = tid

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def walk(self):
        """Yield this span and every descendant, depth-first."""
        yield self
        for c in self.children:
            yield from c.walk()

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"name": self.name, "t0": self.t0,
                             "t1": self.t1, "tid": self.tid}
        if self.args:
            d["args"] = dict(self.args)
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, dur={self.duration:.6f}, "
                f"children={len(self.children)})")


class _SpanCtx:
    """Context manager that opens a Span on the calling thread's stack."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Dict[str, Any]) -> None:
        self._tracer = tracer
        self._span = tracer._open(name, args)

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._span.args["error"] = exc_type.__name__
        self._tracer._close(self._span)
        return False


class _NoopCtx:
    """Shared do-nothing context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NOOP_CTX = _NoopCtx()


class Tracer:
    """Collects well-nested spans per thread plus instant events."""

    def __init__(self, clock: Optional[Callable[[], float]] = None) -> None:
        self.clock: Callable[[], float] = clock or _clock.perf
        self.roots: List[Span] = []
        self.events: List[Dict[str, Any]] = []
        self._tls = threading.local()
        self._lock = threading.Lock()

    # -- span lifecycle (called via _SpanCtx) --------------------------
    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _open(self, name: str, args: Dict[str, Any]) -> Span:
        sp = Span(name, self.clock(), args, threading.current_thread().name)
        stack = self._stack()
        if stack:
            stack[-1].children.append(sp)
        stack.append(sp)
        return sp

    def _close(self, sp: Span) -> None:
        sp.t1 = self.clock()
        stack = self._stack()
        # Unwind to sp: exceptions that skipped inner __exit__ calls (or
        # a mis-nested close) must not leave orphans on the stack.
        while stack:
            top = stack.pop()
            if top is sp:
                break
            top.t1 = sp.t1
        if not stack:
            with self._lock:
                self.roots.append(sp)

    def add_event(self, name: str, args: Dict[str, Any]) -> None:
        ev = {"name": name, "ts": self.clock(),
              "tid": threading.current_thread().name}
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    # -- queries -------------------------------------------------------
    def span_count(self) -> int:
        return sum(1 for r in self.roots for _ in r.walk())

    def find(self, name: str) -> List[Span]:
        return [s for r in self.roots for s in r.walk() if s.name == name]

    def phase_names(self) -> List[str]:
        seen: Dict[str, None] = {}
        for r in self.roots:
            for s in r.walk():
                seen.setdefault(s.name, None)
        return list(seen)


# ----------------------------------------------------------------------
# Module-level active tracer.  ``span``/``event`` are the only functions
# instrumented code should call; everything else is test/tooling surface.
# ----------------------------------------------------------------------
_active: Optional[Tracer] = None
_active_lock = threading.Lock()


def span(name: str, **args: Any):
    """Open a span if tracing is on; otherwise return the no-op ctx."""
    t = _active
    if t is None:
        return _NOOP_CTX
    return _SpanCtx(t, name, args)


def event(name: str, **args: Any) -> None:
    """Record an instant event (worker death, rollback, circuit open)."""
    t = _active
    if t is not None:
        t.add_event(name, args)


def enabled() -> bool:
    return _active is not None


def active() -> Optional[Tracer]:
    return _active


def start(clock: Optional[Callable[[], float]] = None) -> Tracer:
    """Install a fresh active tracer and return it (idempotent stop via
    ``stop()``). Prefer ``capture()`` unless you need manual control."""
    global _active
    tracer = Tracer(clock=clock)
    with _active_lock:
        _active = tracer
    return tracer


def stop() -> None:
    global _active
    with _active_lock:
        _active = None


class capture:
    """``with trace.capture() as tracer:`` — scoped tracing.

    Restores the previously active tracer on exit so captures nest; the
    inner capture sees only its own spans.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None) -> None:
        self._clock = clock
        self._prev: Optional[Tracer] = None
        self.tracer: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        global _active
        with _active_lock:
            self._prev = _active
            self.tracer = Tracer(clock=self._clock)
            _active = self.tracer
        return self.tracer

    def __exit__(self, exc_type, exc, tb) -> bool:
        global _active
        with _active_lock:
            _active = self._prev
        return False
