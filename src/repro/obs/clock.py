"""The repo's sanctioned timing sources.

Every wall/monotonic/perf timestamp taken inside ``src/`` flows through
this module (or through an injected clock such as §9.3's ``FakeClock``).
``tests/test_no_stray_timers.py`` enforces this statically: a new
``time.perf_counter()`` / ``time.time()`` call site anywhere else in
``src/`` fails the suite.  The point is that timing is observability —
if a phase is worth timing it is worth a span (`obs.trace`) or a metric
(`obs.metrics`), and ad-hoc timers scattered through the codebase are
how the pre-§13 survivorship bugs happened.

Use:

    from repro.obs import clock
    t0 = clock.perf()      # high-resolution interval timing
    ts = clock.wall()      # epoch seconds (file names, logs)
    tm = clock.monotonic() # deadlines / cadence (injectable default)
"""
from __future__ import annotations

import time

# Aliases, not wrappers: zero call overhead vs. the raw stdlib functions.
perf = time.perf_counter
wall = time.time
monotonic = time.monotonic
