"""Micro-batched decision-forest serving (DESIGN.md §5.4).

Mirrors the ServeBundle shape of ``serving/decode.py`` for forests: a
factory wraps a model's CompiledPredictor (§5.1) into a frozen bundle whose
dispatches are padded to a fixed ladder of batch-size buckets — jit'd
engines then trace one program per bucket instead of one per ragged request
size. ``MicroBatcher`` is the request loop on top: accumulate requests
(encoding each on arrival, off the dispatch path), pad the concatenated
batch to its bucket, dispatch once, and scatter per-request slices back to
their tickets.

Synchronous by design: the loop is driven by ``submit``/``flush`` calls so
it is deterministic and testable; an async front-end would call the same
two methods from its event loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Mapping

import numpy as np

DEFAULT_BUCKETS = (32, 64, 128, 256, 512, 1024)


@dataclass(frozen=True)
class ForestServeBundle:
    """A compiled predictor plus the padded-dispatch policy (§5.4)."""
    predictor: Any                 # repro.core.engines.CompiledPredictor
    buckets: tuple[int, ...] = DEFAULT_BUCKETS

    def __post_init__(self):
        # bucket_for scans for the first bucket >= n: the ladder must ascend
        object.__setattr__(self, "buckets", tuple(sorted(self.buckets)))

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n; beyond the ladder, the next multiple of the
        largest bucket (bounded trace count either way). The single source
        of truth for dispatch sizes — padding stats derive from it too."""
        for b in self.buckets:
            if n <= b:
                return b
        top = self.buckets[-1]
        return -(-n // top) * top

    def padded_size(self, n: int) -> int:
        """The batch size a dispatch of ``n`` rows actually runs at.
        Zero rows dispatch nothing — no phantom-row padding."""
        return self.bucket_for(n) if n else 0

    def predict_encoded(self, X: np.ndarray) -> np.ndarray:
        n = X.shape[0]
        if n == 0:
            # correctly-shaped empty output, no engine dispatch: the
            # predictor knows its trailing prediction shape (§5.1)
            return np.zeros((0,) + tuple(self.predictor.out_shape),
                            np.float32)
        b = self.padded_size(n)
        if b > n:
            X = np.concatenate(
                [X, np.zeros((b - n, X.shape[1]), X.dtype)], axis=0)
        return np.asarray(self.predictor.predict_encoded(X))[:n]

    def predict(self, batch) -> np.ndarray:
        return self.predict_encoded(self.predictor.encode(batch))

    def warm_ladder(self, n_features: int,
                    up_to: int | None = None) -> list[int]:
        """Eagerly trace a jit'd engine (bucketed/leaf_path/pallas) at every
        ladder bucket up to ``up_to`` rows, so no production dispatch ever
        pays a trace. Returns the bucket sizes touched. For trace-free
        engines (vectorized/naive) this is a cheap no-op pass."""
        touched = []
        for b in self.buckets:
            if up_to is not None and b > self.bucket_for(up_to):
                break
            self.predict_encoded(np.zeros((b, n_features), np.float32))
            touched.append(b)
        return touched

    def predict_encoded_bulk(self, X: np.ndarray,
                             chunk_rows: int | None = None) -> np.ndarray:
        """Dispatch one LARGE encoded batch — an analysis replica sweep
        (DESIGN.md §8: permuted copies, PDP grid x sample cross products) —
        through the bucket ladder: ``chunk_rows`` is rounded DOWN to a
        multiple of the top bucket, so every full chunk dispatches at one
        exact ladder shape with zero padding and only the final partial
        chunk pads to its bucket — a jit'd engine traces at most one
        beyond-the-ladder shape for the whole sweep."""
        n = X.shape[0]
        top = self.buckets[-1]
        step = (top if chunk_rows is None
                else max(top, chunk_rows - chunk_rows % top))
        if n <= step:
            return self.predict_encoded(X)
        return np.concatenate([self.predict_encoded(X[i:i + step])
                               for i in range(0, n, step)], axis=0)


def make_forest_server(model, engine: str | None = None,
                       buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                       warmup: bool = True) -> ForestServeBundle:
    """Compile ``model`` for serving and wrap it in a bundle. ``warmup``
    traces jit'd engines at the SMALLEST bucket only — the first dispatch
    that pads to a larger bucket still traces once at that size (warming
    the whole ladder eagerly would pay one compile per bucket up front;
    call ``bundle.warm_ladder(len(model.features))`` if that trade is
    wanted — e.g. a CPU host serving the bucketed engine, §10)."""
    predictor = model.predictor(engine)
    bundle = ForestServeBundle(predictor, tuple(buckets))
    if warmup and len(model.features):
        bundle.predict_encoded(
            np.zeros((1, len(model.features)), np.float32))
    return bundle


@dataclass
class MicroBatcher:
    """Accumulate→pad→dispatch request loop (§5.4).

    ``submit`` encodes a request's feature columns immediately (cheap, and
    it surfaces schema errors at enqueue time) and returns a ticket; once
    pending rows reach ``max_batch`` — or on explicit ``flush`` — all
    pending requests dispatch as ONE padded engine call and every ticket
    resolves. ``result`` flushes on demand, so callers can never deadlock
    on an unfilled batch. Resolved results are held until claimed, capped
    at ``max_results``: beyond it the OLDEST unclaimed results are evicted
    (abandoned tickets — dropped clients, timeouts — must not leak memory
    in a long-running server; late claimers get a KeyError).
    """
    bundle: ForestServeBundle
    max_batch: int = 1024
    max_results: int = 4096
    dispatches: int = 0
    rows_dispatched: int = 0
    rows_padded: int = 0
    _pending: list = field(default_factory=list)      # (ticket, X rows)
    _results: dict = field(default_factory=dict)      # ticket -> np.ndarray
    _next_ticket: int = 0

    def pending_rows(self) -> int:
        return sum(len(x) for _, x in self._pending)

    def submit(self, batch: Mapping) -> int:
        X = self.bundle.predictor.encode(batch)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, X))
        if self.pending_rows() >= self.max_batch:
            self.flush()
        return ticket

    def flush(self) -> None:
        if not self._pending:
            return
        X = np.concatenate([x for _, x in self._pending], axis=0)
        n = X.shape[0]
        out = self.bundle.predict_encoded(X)
        row = 0
        for ticket, x in self._pending:
            self._results[ticket] = out[row:row + len(x)]
            row += len(x)
        # evict oldest unclaimed results — but never the ones this flush just
        # resolved, whose callers are live and about to claim them
        floor = max(self.max_results, len(self._pending))
        while len(self._results) > floor:
            self._results.pop(next(iter(self._results)))
        self.dispatches += 1
        self.rows_dispatched += n
        self.rows_padded += self.bundle.padded_size(n) - n
        self._pending = []

    def result(self, ticket: int) -> np.ndarray:
        if ticket in self._results:
            return self._results.pop(ticket)
        # validate BEFORE the side-effecting flush: a never-issued or
        # already-consumed ticket must raise immediately without dispatching
        # other callers' pending work
        if not (isinstance(ticket, int) and 0 <= ticket < self._next_ticket):
            raise KeyError(f"ticket {ticket!r} was never issued")
        if not any(t == ticket for t, _ in self._pending):
            raise KeyError(f"ticket {ticket} already consumed or evicted")
        self.flush()
        return self._results.pop(ticket)
