"""Serving factories: prefill (full-sequence, cache-building) and decode
(one token against a cache). Both are AOT-lowerable from ShapeDtypeStructs.

``decode_32k`` / ``long_500k`` lower ``decode_step`` with a cache sized to the
shape's seq_len; ``prefill_32k`` lowers ``prefill``. Remat is disabled for
serving (no backward pass).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.models.layers import Ctx
from repro.models.params import schema_axes, schema_shapes
from repro.sharding import tree_shardings


def _serve_cfg(cfg: ModelConfig) -> ModelConfig:
    return cfg.replace(remat="none")


def serve_state_specs(cfg: ModelConfig):
    sch = lm.model_schema(cfg)
    return schema_shapes(sch, cfg.param_dtype), schema_axes(sch)


@dataclass(frozen=True)
class ServeBundle:
    fn: Callable
    param_shardings: Any
    batch_shardings: Any
    cache_shardings: Any = None  # decode only

    def jitted(self, donate_cache: bool = True):
        if self.cache_shardings is not None:
            return jax.jit(
                self.fn,
                in_shardings=(self.param_shardings, self.batch_shardings,
                              self.cache_shardings),
                donate_argnums=(2,) if donate_cache else (),
            )
        return jax.jit(self.fn, in_shardings=(self.param_shardings, self.batch_shardings))


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh=None, rules=None) -> ServeBundle:
    cfg = _serve_cfg(cfg)
    ctx = Ctx(cfg, mesh, rules)

    def decode_step(params, batch, cache):
        return lm.decode_step(params, batch, cache, ctx)

    p_sh = b_sh = c_sh = None
    if mesh is not None and rules is not None:
        p_specs, p_axes = serve_state_specs(cfg)
        p_sh = tree_shardings(p_axes, mesh, rules, p_specs)
        b_sh = tree_shardings(lm.batch_axes(cfg, shape), mesh, rules,
                              lm.batch_spec(cfg, shape))
        c_sh = tree_shardings(lm.cache_axes(cfg), mesh, rules,
                              lm.cache_spec(cfg, shape.global_batch, shape.seq_len))
    return ServeBundle(decode_step, p_sh, b_sh, c_sh)


def make_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh=None, rules=None) -> ServeBundle:
    cfg = _serve_cfg(cfg)
    ctx = Ctx(cfg, mesh, rules)

    def prefill(params, batch):
        return lm.prefill(params, batch, ctx)

    p_sh = b_sh = None
    if mesh is not None and rules is not None:
        p_specs, p_axes = serve_state_specs(cfg)
        p_sh = tree_shardings(p_axes, mesh, rules, p_specs)
        b_sh = tree_shardings(lm.batch_axes(cfg, shape), mesh, rules,
                              lm.batch_spec(cfg, shape))
    return ServeBundle(prefill, p_sh, b_sh)


def greedy_generate(params, prompt_batch, cfg: ModelConfig, n_steps: int,
                    mesh=None, rules=None):
    """Small convenience driver: prefill a prompt then greedy-decode n tokens.
    Used by examples and smoke tests (CPU-sized models)."""
    ctx = Ctx(_serve_cfg(cfg), mesh, rules)
    B = jax.tree.leaves(prompt_batch)[0].shape[0]
    S = prompt_batch["tokens"].shape[1] + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits, cache = lm.prefill(params, prompt_batch, ctx)
    # grow the cache to fit generated tokens
    full = lm.init_cache(cfg, B, S + n_steps)
    cache = jax.tree.map(_embed_cache, full, cache)
    tokens = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]

    @jax.jit
    def step(params, tok, cache):
        logits, cache = lm.decode_step(params, {"token": tok}, cache, ctx)
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None], cache

    for _ in range(n_steps):
        tokens.append(tok)
        tok, cache = step(params, tok, cache)
    return jnp.concatenate(tokens, axis=1)


def _embed_cache(full, part):
    """Write a prefill cache into a (larger) zeroed decode cache."""
    if full.shape == part.shape:
        return part
    idx = (0,) * part.ndim
    return jax.lax.dynamic_update_slice(full, part.astype(full.dtype), idx)
