from repro.serving.decode import (  # noqa: F401
    ServeBundle,
    make_decode_step,
    make_prefill,
    serve_state_specs,
)
from repro.serving.forest import (  # noqa: F401
    ForestServeBundle,
    MicroBatcher,
    make_forest_server,
)
from repro.serving.faults import (  # noqa: F401
    FakeClock,
    FaultPlan,
    FaultyPredictor,
)
from repro.serving.server import (  # noqa: F401
    AsyncForestServer,
    CircuitBreaker,
    ForestServer,
    RequestFailed,
    RequestShed,
    RequestTimedOut,
    RetryPolicy,
    ServerMetrics,
)
