from repro.serving.decode import (  # noqa: F401
    ServeBundle,
    make_decode_step,
    make_prefill,
    serve_state_specs,
)
from repro.serving.forest import (  # noqa: F401
    ForestServeBundle,
    MicroBatcher,
    make_forest_server,
)
