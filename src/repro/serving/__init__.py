from repro.serving.decode import (  # noqa: F401
    ServeBundle,
    make_decode_step,
    make_prefill,
    serve_state_specs,
)
