"""Fault-tolerant serving front-end (DESIGN.md §9).

``ForestServer`` is the layer that faces production traffic, built over the
§5.4 ``ForestServeBundle`` dispatch policy. It adds what a synchronous
single-model micro-batcher cannot offer:

* **Deadlines + admission control** (§9.2): every request carries a
  latency budget. At submit time the server estimates completion from
  queue depth × an EWMA per-row service-time estimate; requests whose
  deadline cannot be met are SHED immediately — a loud, cheap ``RequestShed``
  at enqueue beats a silent timeout after wasted compute. Requests whose
  deadline expires while queued or during dispatch resolve as
  ``RequestTimedOut``: an accepted request either returns a correct
  prediction or raises a typed error, never a stale/partial result.
* **Retry with seeded-jitter exponential backoff** (§9.2): transient
  engine failures (``EngineFailure(transient=True)``, or output-validation
  rejections — non-finite predictions never escape) retry on the same
  engine; the jitter stream is seeded, so retry timing is deterministic
  under the fault harness.
* **Graceful degradation + circuit breaker** (§9.2): each model compiles a
  CHAIN of engines (pallas → vectorized → naive — every engine produces
  bit-identical per-tree leaf outputs, so degradation is invisible in the
  predictions). Repeated primary failures open the circuit and traffic
  flows through the next engine; after a cooldown a half-open probe tries
  the primary again and closes the circuit on success.
* **Multi-model routing**: bundles are per model name; device-forest
  uploads stay deduplicated by the id-keyed caches in
  ``kernels/forest_infer/ops.py``, so N routed models cost N uploads, not
  N × requests.
* **Metrics** (§9.4): accepted/shed/timed-out/retried/fallback counters,
  circuit transitions, per-bucket padding waste, and p50/p99 latency over a
  bounded reservoir.

The core is deliberately synchronous and clock-injected: driven by
``submit``/``pump``/``result`` it is deterministic under
``serving.faults.FakeClock``, which is how every failure path gets tier-1
coverage. ``AsyncForestServer`` is the thin asyncio front-end that drives
the same core from an event loop.
"""
from __future__ import annotations

import asyncio
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Mapping

import numpy as np

from repro.core.api import EngineFailure, YdfError
from repro.obs.metrics import MetricsRegistry
from repro.serving.forest import DEFAULT_BUCKETS, ForestServeBundle


# ------------------------------------------------------------ typed outcomes

class RequestShed(YdfError):
    """Admission control refused the request: its deadline cannot be met
    given the current queue depth and observed service rate (or the queue
    is full). Retry later, widen the deadline, or add capacity."""


class RequestTimedOut(YdfError):
    """The request was accepted but its deadline expired before a result
    was produced. The computed result (if any) is discarded — a late
    answer is treated as no answer."""


class RequestFailed(YdfError):
    """Every engine in the degradation chain failed for this dispatch.
    The underlying EngineFailure is chained as ``__cause__``."""


# ------------------------------------------------------------- retry policy

@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with seeded jitter (§9.2). ``max_attempts`` is
    the total number of tries per engine per dispatch; the delay before
    retry ``k`` (0-based) is ``base * factor**k * (1 + jitter*u)`` with
    ``u`` a counter-hashed uniform[0,1) draw from ``seed`` — deterministic,
    but decorrelated across dispatches (no retry convoys)."""
    max_attempts: int = 3
    base_s: float = 0.001
    factor: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def delay(self, dispatch: int, attempt: int) -> float:
        u = float(np.random.default_rng(
            (self.seed, dispatch, attempt)).random())
        return self.base_s * self.factor ** attempt * (1.0 + self.jitter * u)


# ---------------------------------------------------------- circuit breaker

class CircuitBreaker:
    """CLOSED → (threshold consecutive failures) → OPEN → (cooldown) →
    HALF_OPEN probe → CLOSED on success / OPEN on failure (§9.2)."""

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 1.0):
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self.state = "closed"
        self.failures = 0          # consecutive
        self.opened_at = -np.inf

    def allow(self, now: float) -> bool:
        """May this engine be tried? Transitions OPEN→HALF_OPEN once the
        cooldown has elapsed (the next dispatch is the probe)."""
        if self.state == "open":
            if now - self.opened_at >= self.cooldown_s:
                self.state = "half_open"
                return True
            return False
        return True                 # closed or half_open (probe in flight)

    def record_success(self) -> bool:
        """Returns True when this success CLOSED a non-closed circuit."""
        self.failures = 0
        if self.state != "closed":
            self.state = "closed"
            return True
        return False

    def record_failure(self, now: float) -> bool:
        """Returns True when this failure OPENED the circuit."""
        self.failures += 1
        if self.state == "half_open" or (
                self.state == "closed"
                and self.failures >= self.failure_threshold):
            self.state = "open"
            self.opened_at = now
            self.failures = 0
            return True
        if self.state == "open":    # failure while open (shouldn't dispatch)
            self.opened_at = now
        return False


# ------------------------------------------------------------------ metrics

# scalar counters exposed as plain attributes (call sites use `+=`); each
# is one unlabeled Counter series in the backing registry
_COUNTER_FIELDS = ("submitted", "accepted", "shed", "timed_out", "completed",
                   "failed", "retries", "fallback_dispatches",
                   "poisoned_rejected", "circuit_opens", "circuit_closes",
                   "dispatches", "rows_dispatched", "rows_padded")

# latency series outcomes (§13.4 survivorship fix): pre-§13 only COMPLETED
# requests entered the reservoir, so p50/p99 under overload silently
# excluded every shed and timed-out request — exactly the requests that
# make overload painful. Each outcome is its own labeled series now.
LATENCY_OUTCOMES = ("completed", "timed_out", "shed")


class ServerMetrics:
    """Serving counters + latency reservoirs (§9.4), a facade over one
    ``obs.metrics.MetricsRegistry`` (§13.4 — same schema as every other
    metric in the system). ``to_dict`` is the machine surface (benchmarks,
    CLI --json) and keeps its pre-§13 keys; ``summary`` the human one.

    Latency is a labeled histogram series ``latency_s{outcome=...}``:
    ``completed`` feeds the headline p50/p99 (unchanged semantics),
    ``timed_out`` records the sojourn time of requests that missed their
    deadline, ``shed`` the estimated-completion time that triggered
    admission shedding — so overload is measured, not censored.
    """

    def __init__(self, registry: MetricsRegistry | None = None,
                 max_latency_samples: int = 65536) -> None:
        object.__setattr__(self, "registry", registry or MetricsRegistry())
        object.__setattr__(self, "max_latency_samples",
                           int(max_latency_samples))
        for name in _COUNTER_FIELDS:
            self.registry.counter(name)
        for oc in LATENCY_OUTCOMES:
            self.registry.histogram("latency_s", outcome=oc)

    # counter attributes proxy to registry series so `metrics.shed += 1`
    # call sites stay untouched while the data lives in one schema
    def __getattr__(self, name: str):
        if name in _COUNTER_FIELDS:
            return self.__dict__["registry"].counter(name).value
        raise AttributeError(name)

    def __setattr__(self, name: str, value) -> None:
        if name in _COUNTER_FIELDS:
            self.__dict__["registry"].counter(name).value = int(value)
        else:
            object.__setattr__(self, name, value)

    @property
    def engine_dispatches(self) -> dict:
        return {k: int(v) for k, v in self.registry.labeled_values(
            "engine_dispatches", "engine").items()}

    @property
    def padding_by_bucket(self) -> dict:
        out: dict = {}
        for b, v in self.registry.labeled_values(
                "bucket_dispatches", "bucket").items():
            out[int(b)] = {"dispatches": int(v), "pad_rows": 0}
        for b, v in self.registry.labeled_values(
                "bucket_pad_rows", "bucket").items():
            out.setdefault(int(b), {"dispatches": 0, "pad_rows": 0})[
                "pad_rows"] = int(v)
        return out

    @property
    def _latencies(self) -> list:
        # legacy view: the completed-outcome reservoir (soak tests, §9.4)
        return self.registry.histogram("latency_s",
                                       outcome="completed").values

    def observe_latency(self, seconds: float,
                        outcome: str = "completed") -> None:
        h = self.registry.histogram("latency_s", outcome=outcome)
        h.cap = self.max_latency_samples
        h.observe(float(seconds))

    def observe_dispatch(self, engine: str, rows: int, padded: int) -> None:
        self.dispatches += 1
        self.rows_dispatched += rows
        self.rows_padded += padded - rows
        self.registry.counter("engine_dispatches", engine=engine).inc()
        self.registry.counter("bucket_dispatches", bucket=int(padded)).inc()
        self.registry.counter("bucket_pad_rows",
                              bucket=int(padded)).inc(padded - rows)

    def latency_percentiles(self, outcome: str = "completed") -> dict:
        vals = self.registry.histogram("latency_s", outcome=outcome).values
        if not vals:
            return {"p50_ms": None, "p99_ms": None, "n": 0}
        lat = np.asarray(vals)
        return {"p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 4),
                "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 4),
                "n": len(lat)}

    def to_dict(self) -> dict:
        out = {k: getattr(self, k) for k in _COUNTER_FIELDS}
        out["engine_dispatches"] = dict(self.engine_dispatches)
        out["padding_by_bucket"] = {str(k): dict(v) for k, v in
                                    sorted(self.padding_by_bucket.items())}
        out["latency"] = self.latency_percentiles()
        out["latency_by_outcome"] = {
            oc: self.latency_percentiles(outcome=oc)
            for oc in LATENCY_OUTCOMES}
        return out

    def summary(self) -> str:
        lat = self.latency_percentiles()
        lines = [
            "ForestServer metrics:",
            f"  requests : submitted={self.submitted} accepted={self.accepted}"
            f" shed={self.shed} timed_out={self.timed_out}"
            f" completed={self.completed} failed={self.failed}",
            f"  resilience: retries={self.retries}"
            f" fallback_dispatches={self.fallback_dispatches}"
            f" poisoned_rejected={self.poisoned_rejected}"
            f" circuit_opens={self.circuit_opens}"
            f" circuit_closes={self.circuit_closes}",
            f"  dispatch : {self.dispatches} dispatches,"
            f" {self.rows_dispatched} rows (+{self.rows_padded} pad)"
            + (", engines " + " ".join(
                f"{k}={v}" for k, v in self.engine_dispatches.items())
               if self.engine_dispatches else ""),
        ]
        if lat["n"]:
            lines.append(f"  latency  : p50={lat['p50_ms']:.3f} ms "
                         f"p99={lat['p99_ms']:.3f} ms over {lat['n']} "
                         "completed requests")
        for oc in ("timed_out", "shed"):
            ol = self.latency_percentiles(outcome=oc)
            if ol["n"]:
                lines.append(f"  latency  : [{oc}] p50={ol['p50_ms']:.3f} ms "
                             f"p99={ol['p99_ms']:.3f} ms over {ol['n']} "
                             "requests (excluded from headline percentiles)")
        for b, s in sorted(self.padding_by_bucket.items()):
            total = s["dispatches"] * b
            waste = s["pad_rows"] / total if total else 0.0
            lines.append(f"  bucket {b:>5d}: {s['dispatches']} dispatches, "
                         f"{s['pad_rows']} pad rows ({waste:.1%} waste)")
        return "\n".join(lines)


# ------------------------------------------------------------- model state

@dataclass
class _Request:
    ticket: int
    model: str
    X: np.ndarray
    deadline: float | None         # absolute, server-clock time
    t_submit: float


class _ModelState:
    """Per-routed-model serving state: the engine chain with its lazily
    compiled bundles, one circuit breaker per engine level, the EWMA
    service-rate estimate, and the pending request queue."""

    def __init__(self, name: str, model, chain: list[str],
                 buckets: tuple[int, ...], failure_threshold: int,
                 cooldown_s: float):
        self.name = name
        self.model = model
        self.chain = chain
        self.buckets = tuple(buckets)
        self.bundles: list[ForestServeBundle | None] = [None] * len(chain)
        self.breakers = [CircuitBreaker(failure_threshold, cooldown_s)
                         for _ in chain]
        self.ewma_row_s: float | None = None
        self.queue: list[_Request] = []

    def bundle(self, level: int) -> ForestServeBundle:
        if self.bundles[level] is None:
            from repro.core.engines import compile_predictor
            self.bundles[level] = ForestServeBundle(
                compile_predictor(self.model, self.chain[level]),
                self.buckets)
        return self.bundles[level]

    def pending_rows(self) -> int:
        return sum(len(r.X) for r in self.queue)


def _default_chain(model) -> list[str]:
    """The degradation chain, hardware-aware like ``compile_model``: start
    at the engine a default compile would pick (pallas on TPU; on CPU the
    size-aware bucketed/vectorized choice — interpret-mode pallas is a
    correctness path, not a serving fallback) and continue down the
    preference order. "leaf_path" never appears: it is an explicit-request
    strategy, not a degradation level (on CPU it is strictly slower than
    the bucketed scan it would 'degrade' to, §10.3)."""
    import jax

    from repro.core.engines import available_engines, select_cpu_engine
    chain = [e for e in available_engines(model.forest) if e != "leaf_path"]
    if jax.default_backend() == "cpu":
        head = select_cpu_engine(model.forest)
        chain = [e for e in chain if e != "pallas"]
        if head in chain and chain[0] != head:
            # small forests: skip the bucketed trace, start at vectorized
            chain = [head] + [e for e in chain if e != head]
    return chain


# ------------------------------------------------------------------- server

class ForestServer:
    """The fault-tolerant request front-end (§9). See module docstring.

    ``models`` is one model or a ``{name: model}`` mapping (multi-model
    routing); requests address a model by name, defaulting to the single /
    first one. ``clock``/``sleep`` default to real time; hand in
    ``FakeClock.now``/``FakeClock.sleep`` for deterministic tests.
    """

    def __init__(self, models, *,
                 engines: Mapping[str, list[str]] | list[str] | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 default_deadline_s: float | None = None,
                 max_batch: int = 1024,
                 max_queue_rows: int = 8192,
                 max_results: int = 4096,
                 retry: RetryPolicy = RetryPolicy(),
                 failure_threshold: int = 3,
                 cooldown_s: float = 0.5,
                 ewma_alpha: float = 0.3,
                 admission_overhead_s: float = 0.0,
                 validate_output: Callable[[np.ndarray], bool] | None = None,
                 clock: Callable[[], float] | None = None,
                 sleep: Callable[[float], None] | None = None,
                 warmup: bool = False):
        if not isinstance(models, Mapping):
            models = {"default": models}
        if not models:
            raise YdfError("ForestServer needs at least one model to route.")
        self.default_deadline_s = default_deadline_s
        self.max_batch = max_batch
        self.max_queue_rows = max_queue_rows
        self.max_results = max_results
        self.retry = retry
        self.ewma_alpha = ewma_alpha
        self.admission_overhead_s = admission_overhead_s
        # non-finite predictions are treated as an engine failure: never
        # silently corrupt a caller's result (§2.1 safety of use)
        self.validate_output = validate_output or \
            (lambda out: bool(np.isfinite(out).all()))
        self._clock = clock or time.monotonic
        self._sleep = sleep or time.sleep
        self.metrics = ServerMetrics()
        self._states: dict[str, _ModelState] = {}
        for name, model in models.items():
            chain = engines.get(name) if isinstance(engines, Mapping) \
                else engines
            chain = list(chain) if chain else _default_chain(model)
            self._states[name] = _ModelState(
                name, model, chain, buckets, failure_threshold, cooldown_s)
        self._default_model = next(iter(self._states))
        self._next_ticket = 0
        self._ticket_model: dict[int, str] = {}
        # ticket -> ("ok", array) | ("err", exception); insertion-ordered so
        # abandoned results evict oldest-first (bounded memory, §9.4)
        self._done: "OrderedDict[int, tuple]" = OrderedDict()
        self._dispatch_seq = 0      # retry-jitter counter
        if warmup:
            for st in self._states.values():
                st.bundle(0).predict_encoded(np.zeros(
                    (1, len(st.model.features)), np.float32))

    # ------------------------------------------------------------- routing

    def _state(self, model: str | None) -> _ModelState:
        name = model if model is not None else self._default_model
        st = self._states.get(name)
        if st is None:
            raise YdfError(
                f"Unknown model {name!r}. Routed models: "
                f"{sorted(self._states)}.")
        return st

    def models(self) -> list[str]:
        return list(self._states)

    def engine_status(self, model: str | None = None) -> list[dict]:
        """Chain snapshot for introspection / the CLI: one row per engine
        level with its circuit state."""
        st = self._state(model)
        return [{"engine": e, "circuit": br.state,
                 "compiled": st.bundles[i] is not None}
                for i, (e, br) in enumerate(zip(st.chain, st.breakers))]

    def inject_faults(self, plan, model: str | None = None, level: int = 0,
                      advance: Callable[[float], None] | None = None):
        """Wrap the engine at ``level`` of ``model``'s chain in a
        ``FaultyPredictor`` replaying ``plan`` (serving/faults.py). Returns
        the wrapper so tests can assert on its call/fault counts. Injected
        latency advances the server's own timeline by default. Re-injecting
        REPLACES any previous plan (wrappers never stack)."""
        from repro.serving.faults import FaultyPredictor
        st = self._state(model)
        base = st.bundle(level)
        pred = base.predictor
        while isinstance(pred, FaultyPredictor):
            pred = pred.inner
        wrapped = FaultyPredictor(pred, plan, advance=advance or self._sleep)
        st.bundles[level] = ForestServeBundle(wrapped, base.buckets)
        return wrapped

    def clear_faults(self, model: str | None = None, level: int = 0) -> None:
        """Restore the pristine predictor at ``level`` (undo inject_faults)."""
        from repro.serving.faults import FaultyPredictor
        st = self._state(model)
        base = st.bundle(level)
        pred = base.predictor
        while isinstance(pred, FaultyPredictor):
            pred = pred.inner
        st.bundles[level] = ForestServeBundle(pred, base.buckets)

    # ----------------------------------------------------------- admission

    def _estimate_service_s(self, st: _ModelState, rows: int) -> float | None:
        """Expected seconds to serve a dispatch of ``rows`` queued rows:
        padded batch size × EWMA per-row service time (+ fixed overhead).
        None until the first dispatch has been observed (optimistic
        admission: with no evidence, accept)."""
        if st.ewma_row_s is None or rows == 0:
            return None
        padded = st.bundle(0).bucket_for(rows)
        return padded * st.ewma_row_s + self.admission_overhead_s

    def submit(self, batch, *, model: str | None = None,
               deadline_s: float | None = None, pump: bool = True) -> int:
        """Encode + admit one request. Returns a ticket, or raises
        ``RequestShed`` (loudly, at enqueue) when the deadline cannot be
        met or the queue is full. ``deadline_s`` is relative to now;
        ``None`` falls back to the server default (``None`` = no deadline).
        """
        st = self._state(model)
        X = st.bundle(0).predictor.encode(batch)   # schema errors = caller's
        now = self._clock()
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        self.metrics.submitted += 1
        queued = st.pending_rows()
        if queued + len(X) > self.max_queue_rows:
            self.metrics.shed += 1
            est = self._estimate_service_s(st, queued + len(X))
            self.metrics.observe_latency(est or 0.0, outcome="shed")
            raise RequestShed(
                f"queue full for model {st.name!r}: {queued} rows pending, "
                f"request adds {len(X)} (max_queue_rows={self.max_queue_rows})."
                " Retry later or raise max_queue_rows.")
        if deadline_s is not None:
            est = self._estimate_service_s(st, queued + len(X))
            if est is not None and est > deadline_s:
                self.metrics.shed += 1
                self.metrics.observe_latency(est, outcome="shed")
                raise RequestShed(
                    f"deadline {deadline_s * 1e3:.2f} ms cannot be met for "
                    f"model {st.name!r}: {queued} rows queued ahead, "
                    f"estimated completion in {est * 1e3:.2f} ms "
                    f"(EWMA {st.ewma_row_s * 1e6:.1f} us/row). "
                    "Shed at admission — widen the deadline or add capacity.")
        ticket = self._next_ticket
        self._next_ticket += 1
        deadline = None if deadline_s is None else now + deadline_s
        st.queue.append(_Request(ticket, st.name, X, deadline, now))
        self._ticket_model[ticket] = st.name
        self.metrics.accepted += 1
        if pump and st.pending_rows() >= self.max_batch:
            self.pump(model=st.name)
        return ticket

    # ------------------------------------------------------------ dispatch

    def _attempt_engine(self, st: _ModelState, level: int,
                        X: np.ndarray) -> np.ndarray:
        """One engine's tries for this dispatch: up to ``retry.max_attempts``
        attempts with backoff on TRANSIENT failures (injected transients,
        output-validation rejections). Non-transient failures propagate
        immediately — retrying a dead engine only burns the deadline."""
        bundle = st.bundle(level)
        seq = self._dispatch_seq
        self._dispatch_seq += 1
        last: EngineFailure | None = None
        for attempt in range(max(1, self.retry.max_attempts)):
            if attempt:
                self.metrics.retries += 1
                self._sleep(self.retry.delay(seq, attempt - 1))
            t0 = self._clock()
            try:
                out = np.asarray(bundle.predict_encoded(X))
                if not self.validate_output(out):
                    self.metrics.poisoned_rejected += 1
                    raise EngineFailure(
                        f"engine {st.chain[level]!r} returned invalid "
                        f"(non-finite) predictions for {len(X)} rows",
                        engine=st.chain[level], transient=True)
            except EngineFailure as e:
                last = e
                if not e.transient:
                    raise
                continue
            dt = self._clock() - t0
            padded = bundle.padded_size(len(X))
            rate = dt / max(1, padded)
            st.ewma_row_s = rate if st.ewma_row_s is None else (
                self.ewma_alpha * rate
                + (1.0 - self.ewma_alpha) * st.ewma_row_s)
            self.metrics.observe_dispatch(st.chain[level], len(X), padded)
            if level > 0:
                self.metrics.fallback_dispatches += 1
            return out
        raise last  # transient retries exhausted

    def _predict_resilient(self, st: _ModelState, X: np.ndarray) -> np.ndarray:
        """Walk the degradation chain under the circuit breakers. Raises
        ``RequestFailed`` only when every engine is down."""
        last: Exception | None = None
        for level in range(len(st.chain)):
            br = st.breakers[level]
            if not br.allow(self._clock()):
                continue                      # circuit open: skip this engine
            try:
                out = self._attempt_engine(st, level, X)
            except EngineFailure as e:
                last = e
                if br.record_failure(self._clock()):
                    self.metrics.circuit_opens += 1
                continue
            if br.record_success():
                self.metrics.circuit_closes += 1
            return out
        raise RequestFailed(
            f"all engines failed for model {st.name!r} "
            f"(chain {st.chain}): {last}") from last

    def _resolve(self, req: _Request, value=None, error=None) -> None:
        self._ticket_model.pop(req.ticket, None)
        self._done[req.ticket] = ("err", error) if error is not None \
            else ("ok", value)
        # abandoned-results cap: oldest unclaimed entries go first (§9.4)
        while len(self._done) > self.max_results:
            self._done.popitem(last=False)

    def pump(self, model: str | None = None) -> list[int]:
        """Dispatch all pending requests (for one model, or every model) as
        padded batches; resolve their tickets. Returns the resolved
        tickets. Expired requests are dropped BEFORE dispatch (no compute
        for a caller that already gave up) and requests whose deadline
        passes DURING dispatch resolve as timed out — a late result is
        discarded, never delivered."""
        states = [self._state(model)] if model is not None \
            else list(self._states.values())
        resolved: list[int] = []
        for st in states:
            if not st.queue:
                continue
            reqs, st.queue = st.queue, []
            now = self._clock()
            live: list[_Request] = []
            for r in reqs:
                if r.deadline is not None and now > r.deadline:
                    self.metrics.timed_out += 1
                    self.metrics.observe_latency(now - r.t_submit,
                                                 outcome="timed_out")
                    self._resolve(r, error=RequestTimedOut(
                        f"deadline expired while queued "
                        f"({(now - r.t_submit) * 1e3:.2f} ms since submit)"))
                    resolved.append(r.ticket)
                else:
                    live.append(r)
            if not live:
                continue
            X = np.concatenate([r.X for r in live], axis=0)
            try:
                out = self._predict_resilient(st, X)
            except RequestFailed as e:
                for r in live:
                    self.metrics.failed += 1
                    self._resolve(r, error=RequestFailed(str(e)))
                    resolved.append(r.ticket)
                continue
            t_done = self._clock()
            row = 0
            for r in live:
                end = row + len(r.X)
                if r.deadline is not None and t_done > r.deadline:
                    self.metrics.timed_out += 1
                    self.metrics.observe_latency(t_done - r.t_submit,
                                                 outcome="timed_out")
                    self._resolve(r, error=RequestTimedOut(
                        f"deadline expired during dispatch "
                        f"({(t_done - r.t_submit) * 1e3:.2f} ms since "
                        "submit); late result discarded"))
                else:
                    self.metrics.completed += 1
                    self.metrics.observe_latency(t_done - r.t_submit)
                    self._resolve(r, value=out[row:end])
                resolved.append(r.ticket)
                row = end
        return resolved

    # ------------------------------------------------------------- results

    def done(self, ticket: int) -> bool:
        return ticket in self._done

    def result(self, ticket: int) -> np.ndarray:
        """Claim a ticket: returns its predictions or raises its typed
        error (RequestTimedOut / RequestFailed). Pending tickets pump
        their model on demand; never-issued or already-claimed tickets
        raise KeyError without side effects."""
        if ticket not in self._done:
            name = self._ticket_model.get(ticket)
            if name is None:
                raise KeyError(
                    f"ticket {ticket!r} was never issued, already claimed, "
                    "or evicted")
            self.pump(model=name)
        status, payload = self._done.pop(ticket)
        if status == "err":
            raise payload
        return payload

    def predict(self, batch, *, model: str | None = None,
                deadline_s: float | None = None) -> np.ndarray:
        """Synchronous convenience: submit + pump + result."""
        ticket = self.submit(batch, model=model, deadline_s=deadline_s)
        return self.result(ticket)


# ------------------------------------------------------------ async wrapper

class AsyncForestServer:
    """The asyncio front-end over the deterministic core (§9.5).

    ``await aserver.predict(batch)`` submits into the shared queue and
    awaits its ticket; a background flusher pumps the server every
    ``flush_interval_s`` so concurrent awaiters micro-batch into shared
    padded dispatches. Shed requests fail their future at submit. Dispatch
    runs inline on the loop (inference is a C-level numpy/XLA call; for
    multi-core serving put the whole server behind a thread/process pool).

        async with AsyncForestServer(server) as a:
            preds = await asyncio.gather(*(a.predict(b) for b in batches))
    """

    def __init__(self, server: ForestServer, flush_interval_s: float = 0.002):
        self.server = server
        self.flush_interval_s = flush_interval_s
        self._futures: dict[int, asyncio.Future] = {}
        self._task: asyncio.Task | None = None

    async def __aenter__(self) -> "AsyncForestServer":
        self._task = asyncio.get_running_loop().create_task(self._run())
        return self

    async def __aexit__(self, *exc) -> None:
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        self._drain()   # resolve anything the last pump completed

    async def _run(self) -> None:
        while True:
            await asyncio.sleep(self.flush_interval_s)
            if self._futures:
                self.server.pump()
                self._drain()

    def _drain(self) -> None:
        for ticket in [t for t in self._futures if self.server.done(t)]:
            fut = self._futures.pop(ticket)
            if fut.done():
                continue
            try:
                fut.set_result(self.server.result(ticket))
            except YdfError as e:
                fut.set_exception(e)

    async def predict(self, batch, *, model: str | None = None,
                      deadline_s: float | None = None) -> np.ndarray:
        loop = asyncio.get_running_loop()
        # pump=False: resolution happens on the flusher tick so concurrent
        # submitters share one padded dispatch instead of racing max_batch
        ticket = self.server.submit(batch, model=model,
                                    deadline_s=deadline_s, pump=False)
        fut: asyncio.Future = loop.create_future()
        self._futures[ticket] = fut
        if self.server.done(ticket):
            self._drain()
        return await fut
