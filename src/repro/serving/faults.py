"""Deterministic fault injection for the serving stack (DESIGN.md §9.3).

The paper's *safety of use* principle demands that every failure path of the
serving front-end — shed, timeout, retry-then-succeed, engine fallback,
circuit open/half-open/close — is *exercised*, not hoped-for. This module
makes failure a first-class, reproducible input:

* ``FakeClock`` — a virtual clock the server and the fault wrapper share, so
  latency spikes, deadlines, backoff sleeps and circuit-breaker cooldowns
  advance the SAME timeline deterministically (no wall-clock flakiness in
  tier-1 tests).
* ``FaultPlan`` — a declarative fault schedule keyed by predictor-call
  index: explicit call lists for tier-1 tests, seeded Bernoulli rates for
  soak tests and benchmarks. Same seed → same faults, always.
* ``FaultyPredictor`` — wraps any CompiledPredictor-shaped object and
  replays the plan: added latency, transient exceptions, sticky engine
  death (with optional revival, for half-open probe tests), and
  poisoned-output sentinels (NaN-filled results returned WITHOUT an
  exception — the adversarial case output validation must catch).

Faults model ENGINE failures: ``encode`` is never injected (schema errors
are caller errors and follow a different path).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Mapping

import numpy as np

from repro.core.api import EngineFailure

#: the poisoned-output sentinel: a correct serving stack must never let a
#: non-finite prediction escape to a caller (DESIGN.md §9.3)
POISON = np.float32(np.nan)


class FakeClock:
    """A virtual monotonic clock: ``sleep`` advances time instead of
    waiting. Hand ``clock.now``/``clock.sleep`` to ForestServer and
    ``clock.advance`` to FaultyPredictor and the whole timing stack —
    deadlines, EWMA estimates, backoff, cooldowns — runs deterministically
    in zero wall time."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"cannot advance a monotonic clock by {dt}")
        self._t += float(dt)

    def sleep(self, dt: float) -> None:
        self.advance(max(0.0, dt))


def _hash_uniform(seed: int, call: int, salt: int) -> float:
    """Counter-based uniform[0,1) draw: independent of draw order, so the
    fault at call #k is the same whether or not earlier calls happened."""
    return float(np.random.default_rng((seed, call, salt)).random())


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic fault schedule over predictor calls 0,1,2,…

    Explicit schedules (tier-1 tests):
      * ``transient_calls`` — call indices that raise a retryable
        ``EngineFailure(transient=True)``.
      * ``poison_calls``    — call indices whose output is returned
        NaN-poisoned (no exception raised: the silent-corruption case).
      * ``latency_calls``   — {call index: seconds} of added latency.
      * ``dead_from``/``dead_until`` — sticky engine death: every call ``i``
        with ``dead_from <= i`` and (``dead_until`` is None or
        ``i < dead_until``) raises a NON-transient ``EngineFailure``.
        ``dead_until`` models an engine coming back, so circuit-breaker
        half-open probes can be driven to both re-open and close.

    Seeded rates (soak tests, benchmarks) — drawn per call with
    counter-based hashing, so the schedule is reproducible from ``seed``
    alone:
      * ``transient_rate``, ``poison_rate`` — Bernoulli per call.
      * ``latency_rate`` + ``latency_s`` — Bernoulli latency spikes.
    """
    seed: int = 0
    transient_calls: tuple[int, ...] = ()
    poison_calls: tuple[int, ...] = ()
    latency_calls: Mapping[int, float] | tuple[tuple[int, float], ...] = ()
    dead_from: int | None = None
    dead_until: int | None = None
    transient_rate: float = 0.0
    poison_rate: float = 0.0
    latency_rate: float = 0.0
    latency_s: float = 0.0

    def _latency_map(self) -> dict[int, float]:
        return dict(self.latency_calls)

    def latency_for(self, call: int) -> float:
        dt = self._latency_map().get(call, 0.0)
        if self.latency_rate and \
                _hash_uniform(self.seed, call, 0) < self.latency_rate:
            dt += self.latency_s
        return dt

    def is_dead(self, call: int) -> bool:
        return (self.dead_from is not None and call >= self.dead_from
                and (self.dead_until is None or call < self.dead_until))

    def is_transient(self, call: int) -> bool:
        return (call in self.transient_calls
                or (self.transient_rate > 0.0 and
                    _hash_uniform(self.seed, call, 1) < self.transient_rate))

    def is_poisoned(self, call: int) -> bool:
        return (call in self.poison_calls
                or (self.poison_rate > 0.0 and
                    _hash_uniform(self.seed, call, 2) < self.poison_rate))


@dataclass
class FaultyPredictor:
    """A CompiledPredictor look-alike that replays a FaultPlan.

    Wrap the PRIMARY engine's predictor (``ForestServer.inject_faults``
    does this in place) and drive traffic: every ``predict_encoded`` call
    consumes one plan index. ``advance`` is how injected latency passes —
    ``time.sleep`` against the real clock, ``FakeClock.advance`` in tests.
    ``counts`` records what actually fired, so tests can assert the exact
    fault sequence they scheduled.
    """
    inner: object                       # CompiledPredictor (or another wrapper)
    plan: FaultPlan = field(default_factory=FaultPlan)
    advance: Callable[[float], None] = time.sleep
    calls: int = 0
    counts: dict = field(default_factory=lambda: {
        "latency": 0, "dead": 0, "transient": 0, "poison": 0, "clean": 0})

    # -- passthrough of the CompiledPredictor surface
    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def out_shape(self) -> tuple:
        return tuple(getattr(self.inner, "out_shape", ()))

    @property
    def compile_s(self) -> float:
        return getattr(self.inner, "compile_s", 0.0)

    def encode(self, dataset) -> np.ndarray:
        return self.inner.encode(dataset)      # never fault-injected

    def per_tree(self, X: np.ndarray) -> np.ndarray:
        return self.inner.per_tree(X)

    # -- the injected surface
    def predict_encoded(self, X: np.ndarray) -> np.ndarray:
        i = self.calls
        self.calls += 1
        lat = self.plan.latency_for(i)
        if lat > 0.0:
            self.counts["latency"] += 1
            self.advance(lat)
        if self.plan.is_dead(i):
            self.counts["dead"] += 1
            raise EngineFailure(
                f"injected sticky engine death at call {i}",
                engine=self.name, transient=False)
        if self.plan.is_transient(i):
            self.counts["transient"] += 1
            raise EngineFailure(
                f"injected transient failure at call {i}",
                engine=self.name, transient=True)
        out = np.asarray(self.inner.predict_encoded(X))
        if self.plan.is_poisoned(i):
            self.counts["poison"] += 1
            return np.full_like(out, POISON)
        self.counts["clean"] += 1
        return out

    def predict(self, dataset) -> np.ndarray:
        return self.predict_encoded(self.encode(dataset))
