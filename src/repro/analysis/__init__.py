"""Model analysis (DESIGN.md §8) — the paper's third pillar: "the training,
serving and INTERPRETATION of decision forest models".

``analyze_model(model, ds)`` (surfaced as ``model.analyze(ds)``) bundles the
three engines into one AnalysisReport:

  * structural variable importances — one vectorized pass over the Forest
    SoA (importance.structural_importances);
  * permutation variable importances (+ the Random-Forest out-of-bag
    variant) — inference-heavy sweeps dispatched as stacked replica batches
    through the cached CompiledPredictor / ForestServeBundle
    (importance.permutation_importances / oob_permutation_importances);
  * partial dependence + ICE curves — grid x sample cross products through
    the same compiled path (partial_dependence.partial_dependence).

Reports render as text (``report()``) and as JSON payloads (``to_dict()``).
"""
from __future__ import annotations

from repro.analysis.importance import (  # noqa: F401
    oob_permutation_importances,
    permutation_importances,
    regenerate_oob_masks,
    structural_importances,
)
from repro.analysis.partial_dependence import partial_dependence  # noqa: F401
from repro.analysis.report import (  # noqa: F401
    AnalysisReport,
    ImportanceEntry,
    ImportanceTable,
    PDPCurve,
    sparkline,
)
from repro.core.api import Task, YdfError


def _has_label(model, dataset) -> bool:
    from repro.core.dataspec import VerticalDataset
    if isinstance(dataset, VerticalDataset):
        return (model.label in dataset.spec.columns
                and (model.label in dataset.numerical
                     or model.label in dataset.categorical))
    try:
        return model.label in dataset
    except TypeError:
        return False


def analyze_model(model, dataset=None, *, permutation_repetitions: int = 3,
                  features: list[str] | None = None, grid_size: int = 16,
                  sample_rows: int = 256, ice: bool = False,
                  oob: bool | str = "auto", seed: int = 42, bundle=None,
                  row_budget: int | None = None) -> AnalysisReport:
    """Build the full analysis report.

    Without ``dataset`` only the structural importances are computed. With
    one, permutation importances and an evaluation are added when the label
    column is present, the OOB variant when ``oob`` is "auto"/True and the
    model carries regenerable bags for a same-sized dataset, and PDP curves
    always. ``bundle`` routes every sweep through a ForestServeBundle's
    padded buckets; ``row_budget`` caps rows per stacked dispatch.
    """
    if oob is True and dataset is None:
        raise YdfError(
            "oob=True requires the training dataset; analyze() was called "
            "without one. Solution: model.analyze(train_ds, oob=True).")
    notes: list[str] = []
    tables = structural_importances(model)
    evaluation = None
    pdp: list[PDPCurve] = []
    n_examples = 0
    kw = {} if row_budget is None else {"row_budget": row_budget}
    if dataset is not None:
        if _has_label(model, dataset):
            table, evaluation = permutation_importances(
                model, dataset, repetitions=permutation_repetitions,
                seed=seed, bundle=bundle, **kw)
            tables.append(table)
            n_examples = evaluation.n_examples
            bag_ok = (getattr(model, "bag_info", None) is not None
                      and evaluation.n_examples
                      == model.bag_info.get("n_rows", -1))
            if oob is True or (oob == "auto" and bag_ok):
                # the engine itself verifies the dataset IS the training
                # set (size + content fingerprint); under "auto" a mismatch
                # downgrades to a note instead of failing the analysis
                try:
                    oob_table, oob_eval = oob_permutation_importances(
                        model, dataset, seed=seed,
                        repetitions=permutation_repetitions, **kw)
                    tables.append(oob_table)
                    notes.append(
                        f"out-of-bag baseline {oob_table.metric}="
                        f"{oob_table.baseline:.6g} over "
                        f"{oob_eval.n_examples} examples")
                except YdfError as e:
                    if oob is True:
                        raise
                    notes.append(f"OOB importances skipped: {e}")
            elif oob == "auto" and getattr(model, "bag_info", None):
                notes.append(
                    "OOB importances skipped: dataset size differs from the "
                    "training set (pass the training dataset to enable)")
        else:
            if oob is True:
                raise YdfError(
                    "oob=True requires the training dataset WITH its label "
                    f'column, but "{model.label}" is absent. Solution: pass '
                    "the labeled training dataset to analyze().")
            notes.append(
                f'label column "{model.label}" absent: permutation '
                "importances and evaluation skipped")
        pdp = partial_dependence(
            model, dataset, features=features, grid_size=grid_size,
            sample_rows=sample_rows, ice=ice, seed=seed, bundle=bundle, **kw)
        if not n_examples and pdp:
            n_examples = pdp[0].n_sample
    return AnalysisReport(
        model_type=type(model).__name__, task=model.task.value,
        label=model.label, n_examples=n_examples, importances=tables,
        pdp=pdp, evaluation=evaluation, notes=notes)
