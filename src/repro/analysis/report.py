"""Structured model-analysis reports (DESIGN.md §8).

Every analysis engine (structural / permutation / OOB importances, partial
dependence) returns one of the dataclasses below; ``AnalysisReport`` bundles
them with the optional evaluation. Each object renders BOTH ways the paper's
§4.1 artefact style demands: ``report()`` (human text, with ASCII sparklines
for curves) and ``to_dict()`` (pure-JSON payload for the CLI ``--json`` path
and for downstream tooling).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.evaluation import Evaluation

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values) -> str:
    """Min-max-scaled block-character rendering of a 1-D series."""
    v = np.asarray(values, np.float64).ravel()
    if v.size == 0:
        return ""
    lo, hi = float(np.nanmin(v)), float(np.nanmax(v))
    if not np.isfinite(lo) or not np.isfinite(hi) or hi - lo < 1e-12:
        return _SPARK[0] * v.size
    idx = np.clip(((v - lo) / (hi - lo) * (len(_SPARK) - 1) + 0.5).astype(int),
                  0, len(_SPARK) - 1)
    return "".join(_SPARK[i] for i in idx)


@dataclass
class ImportanceEntry:
    feature: str
    importance: float
    ci95: tuple[float, float] | None = None  # bootstrap CI (permutation kinds)

    def to_dict(self) -> dict:
        d = {"feature": self.feature, "importance": float(self.importance)}
        if self.ci95 is not None:
            d["ci95"] = [float(self.ci95[0]), float(self.ci95[1])]
        return d


@dataclass
class ImportanceTable:
    """One importance kind, entries sorted most-important-first. All kinds
    are higher-is-more-important (structural kinds by construction;
    permutation kinds measure the drop of the higher-is-better primary
    metric), so every table shares one sort order."""
    kind: str                  # e.g. "SUM_SCORE", "MEAN_DECREASE_ACCURACY"
    source: str                # structure | permutation | oob-permutation
    entries: list[ImportanceEntry]
    metric: str | None = None     # underlying metric for permutation kinds
    baseline: float | None = None  # unpermuted metric value
    repetitions: int | None = None

    def __post_init__(self):
        self.entries = sorted(self.entries, key=lambda e: -e.importance)

    def ranking(self) -> list[str]:
        return [e.feature for e in self.entries]

    def top(self, n: int = 5) -> list[ImportanceEntry]:
        return self.entries[:n]

    def __getitem__(self, feature: str) -> float:
        for e in self.entries:
            if e.feature == feature:
                return e.importance
        raise KeyError(feature)

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "source": self.source,
             "entries": [e.to_dict() for e in self.entries]}
        if self.metric is not None:
            d["metric"] = self.metric
            d["baseline"] = float(self.baseline)
            d["repetitions"] = self.repetitions
        return d

    def report(self) -> str:
        head = f"Variable importance {self.kind} ({self.source}"
        if self.metric is not None:
            head += (f"; baseline {self.metric}={self.baseline:.6g}, "
                     f"{self.repetitions} repetition(s)")
        lines = [head + "):"]
        width = max((len(e.feature) for e in self.entries), default=0)
        for i, e in enumerate(self.entries):
            ci = (f"  CI95[{e.ci95[0]:.6g}, {e.ci95[1]:.6g}]"
                  if e.ci95 is not None else "")
            lines.append(f"  {i + 1:>3}. {e.feature:<{width}} "
                         f"{e.importance:>12.6g}{ci}")
        return "\n".join(lines)


@dataclass
class PDPCurve:
    """Partial dependence of the model output on one feature, plus the
    per-grid-point dispersion of the underlying conditional-expectation
    (ICE) curves. ``mean``/``stdev`` are (grid, out) where out is
    n_classes for classification and 1 for regression; ``ice`` (optional)
    keeps the full (grid, sample, out) curves."""
    feature: str
    semantic: str                    # NUMERICAL | CATEGORICAL | BOOLEAN
    grid: np.ndarray                 # (g,) raw values / category codes
    mean: np.ndarray                 # (g, out)
    stdev: np.ndarray                # (g, out)
    labels: list[str] | None = None  # categorical grid value names
    classes: list[str] | None = None
    n_sample: int = 0
    ice: np.ndarray | None = None    # (g, n_sample, out)

    def curve(self, class_idx: int = -1) -> np.ndarray:
        """The (g,) mean curve for one output column (default: last class —
        the positive class for binary models — or the regression output)."""
        return self.mean[:, class_idx]

    def to_dict(self) -> dict:
        d = {"feature": self.feature, "semantic": self.semantic,
             "grid": [float(v) for v in self.grid],
             "mean": self.mean.tolist(), "stdev": self.stdev.tolist(),
             "n_sample": int(self.n_sample)}
        if self.labels is not None:
            d["labels"] = list(self.labels)
        if self.classes is not None:
            d["classes"] = list(self.classes)
        if self.ice is not None:
            d["ice"] = self.ice.tolist()
        return d

    def report(self) -> str:
        out = self.mean.shape[1]
        heads = (self.classes if self.classes and len(self.classes) == out
                 else ([""] if out == 1 else [str(k) for k in range(out)]))
        lines = []
        for k, cname in enumerate(heads):
            tag = f" p({cname})" if cname else ""
            lo, hi = float(self.mean[:, k].min()), float(self.mean[:, k].max())
            lines.append(
                f'  "{self.feature}"{tag} [{lo:.4g}, {hi:.4g}] '
                f"{sparkline(self.mean[:, k])}")
            if out == 1:
                break
        if self.labels is not None:
            shown = ", ".join(self.labels[:6])
            lines.append(f"    grid: {shown}"
                         + (", ..." if len(self.labels) > 6 else ""))
        else:
            lines.append(f"    grid: {self.grid[0]:.4g} .. "
                         f"{self.grid[-1]:.4g} ({len(self.grid)} points)")
        return "\n".join(lines)


@dataclass
class AnalysisReport:
    """The ``model.analyze(ds)`` result: text via ``report()``/``str()``,
    JSON payload via ``to_dict()``."""
    model_type: str
    task: str
    label: str
    n_examples: int                       # 0 for structure-only analyses
    importances: list[ImportanceTable] = field(default_factory=list)
    pdp: list[PDPCurve] = field(default_factory=list)
    evaluation: Evaluation | None = None
    notes: list[str] = field(default_factory=list)

    def importance(self, kind: str) -> ImportanceTable:
        for t in self.importances:
            if t.kind == kind:
                return t
        raise KeyError(
            f"No importance table {kind!r}. Available: "
            f"{[t.kind for t in self.importances]}")

    def pdp_curve(self, feature: str) -> PDPCurve:
        for c in self.pdp:
            if c.feature == feature:
                return c
        raise KeyError(
            f"No PDP curve for {feature!r}. Available: "
            f"{[c.feature for c in self.pdp]}")

    def to_dict(self) -> dict:
        return {
            "model_type": self.model_type, "task": self.task,
            "label": self.label, "n_examples": int(self.n_examples),
            "variable_importances": [t.to_dict() for t in self.importances],
            "partial_dependence": [c.to_dict() for c in self.pdp],
            "evaluation": (None if self.evaluation is None
                           else self.evaluation.to_dict()),
            "notes": list(self.notes),
        }

    def report(self) -> str:
        lines = [f"Analysis of {self.model_type} "
                 f'(task={self.task}, label="{self.label}")']
        if self.n_examples:
            lines.append(f"Examples analyzed: {self.n_examples}")
        for t in self.importances:
            lines += ["", t.report()]
        if self.pdp:
            lines += ["", "Partial dependence:"]
            for c in self.pdp:
                lines.append(c.report())
        if self.evaluation is not None:
            lines += ["", self.evaluation.report()]
        for n in self.notes:
            lines += ["", f"note: {n}"]
        return "\n".join(lines)

    __str__ = report
