"""Partial dependence + individual conditional expectation (DESIGN.md §8).

PD(f, v) = E_x[ model(x with x_f := v) ] (Friedman 2001): for every grid
value of the analyzed feature, every sampled background example is re-scored
with that feature overridden. That grid x sample cross product is a pure
inference sweep, so it is materialized as ONE stacked encoded batch and
dispatched through the compiled serving path (row-budget-chunked), exactly
like the permutation-importance replicas — never one predict call per grid
point.

Numerical grids reuse the binning machinery (binning._quantile_boundaries)
on the analysis dataset, i.e. the same quantile bin edges training splits
are drawn from; categorical/boolean grids come from the DataSpec dictionary
(frequency-ordered, OOD code 0 excluded).
"""
from __future__ import annotations

import numpy as np

from repro.analysis.importance import DEFAULT_ROW_BUDGET, _chunked, \
    _require_predictor
from repro.analysis.report import PDPCurve
from repro.core.api import Task, YdfError
from repro.core.binning import _quantile_boundaries
from repro.core.dataspec import Semantic


def _numerical_grid(x: np.ndarray, grid_size: int) -> np.ndarray:
    bounds = _quantile_boundaries(x.astype(np.float64), grid_size)
    return np.unique(np.concatenate(
        [[float(x.min())], bounds, [float(x.max())]])).astype(np.float32)


def _categorical_grid(col, x: np.ndarray, grid_size: int
                      ) -> tuple[np.ndarray, list[str]]:
    """Dictionary codes in frequency order (code 1 = most frequent), capped
    at ``grid_size``; boolean columns grid over {0, 1}."""
    if col.semantic == Semantic.BOOLEAN or col.vocab_size <= 1:
        codes = np.unique(x.astype(np.int64))
        return codes.astype(np.float32), [str(int(c)) for c in codes]
    n = min(col.vocab_size - 1, grid_size)
    codes = np.arange(1, n + 1)
    return codes.astype(np.float32), [col.vocab[c] for c in codes]


def partial_dependence(model, dataset, *, features: list[str] | None = None,
                       grid_size: int = 16, sample_rows: int = 256,
                       ice: bool = False, seed: int = 7, bundle=None,
                       row_budget: int = DEFAULT_ROW_BUDGET,
                       ) -> list[PDPCurve]:
    """One PDPCurve per analyzed feature (default: every input feature)."""
    pred = _require_predictor(model)
    X = pred.encode(dataset)
    N = X.shape[0]
    if N == 0:
        raise YdfError("Cannot analyze an empty dataset.")
    names = list(features) if features is not None else list(model.features)
    unknown = [f for f in names if f not in model.features]
    if unknown:
        raise YdfError(
            f"Feature(s) {unknown} are not inputs of the model. Model "
            f"features: {model.features}.")
    rng = np.random.default_rng(seed)
    sel = (np.sort(rng.choice(N, sample_rows, replace=False))
           if N > sample_rows else np.arange(N))
    Xs = X[sel]
    n = len(Xs)
    dispatch = ((lambda Z: bundle.predict_encoded_bulk(Z, row_budget))
                if bundle is not None
                else lambda Z: _chunked(pred.predict_encoded, Z, row_budget))
    classes = getattr(model, "classes", None)
    curves: list[PDPCurve] = []
    for name in names:
        j = model.features.index(name)
        col = model.spec[name]
        if col.semantic == Semantic.NUMERICAL:
            grid, labels = _numerical_grid(X[:, j], grid_size), None
        else:
            grid, labels = _categorical_grid(col, X[:, j], grid_size)
        g = len(grid)
        X_rep = np.tile(Xs, (g, 1))
        X_rep[:, j] = np.repeat(grid, n)
        out = np.asarray(dispatch(X_rep), np.float64)
        out = out.reshape(g, n, -1)            # (g, n, out)
        curves.append(PDPCurve(
            feature=name, semantic=col.semantic.value, grid=grid,
            mean=out.mean(axis=1), stdev=out.std(axis=1), labels=labels,
            classes=(classes if model.task == Task.CLASSIFICATION else None),
            n_sample=n, ice=(out if ice else None)))
    return curves
