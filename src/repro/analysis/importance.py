"""Variable-importance engines (DESIGN.md §8).

Three engines, one contract (an ``ImportanceTable`` per kind):

  * ``structural_importances`` — read straight off the Forest SoA in one
    vectorized pass (tree.Forest.variable_importances): NUM_NODES,
    NUM_AS_ROOT, SUM_SCORE (training-time split gains), INV_MEAN_MIN_DEPTH.
  * ``permutation_importances`` — mean decrease of the primary metric when
    one feature column is shuffled (Breiman 2001). Analysis is an
    inference-heavy sweep: ALL (feature, repetition) replicas are stacked
    into one large encoded batch and dispatched through the cached
    CompiledPredictor (or a ForestServeBundle's bucket ladder) — never a
    per-feature python predict loop. Bootstrap CI95s come from
    evaluation._bootstrap_ci over per-example score contributions.
  * ``oob_permutation_importances`` — the Random-Forest out-of-bag variant:
    per-tree bootstrap bags are REGENERATED from ``model.bag_info`` (the
    multinomial draw is the first consumption of each per-tree rng stream,
    rf.py), per-tree outputs come from the compiled engine's ``per_tree``,
    and each example is scored only by trees that did not train on it —
    the same accumulation ``compute_oob`` performs during training, so the
    unpermuted baseline reproduces ``model.self_evaluation``.

Permutations are keyed by (seed, feature, repetition), never by dispatch
order, so the batched-replica path is bit-equal to a naive per-feature loop
at equal seeds (tested).
"""
from __future__ import annotations

import numpy as np

from repro.analysis.report import ImportanceEntry, ImportanceTable
from repro.core.api import Task, YdfError
from repro.core.dataspec import label_values
from repro.core.evaluation import Evaluation, _bootstrap_ci, \
    evaluate_predictions

# row budget per stacked dispatch: large enough to amortize per-call
# overheads, small enough that the traversal's per-round (rows, trees)
# index/state arrays stay cache-resident on CPU hosts (measured sweet spot;
# the TPU path hides this behind the serving bundle's bucket ladder)
DEFAULT_ROW_BUDGET = 8192


def structural_importances(model) -> list[ImportanceTable]:
    """Every structural kind the model exposes, as sorted tables."""
    out = []
    for kind, table in model.variable_importances().items():
        out.append(ImportanceTable(
            kind=kind, source="structure",
            entries=[ImportanceEntry(f, v) for f, v in table.items()]))
    return out


# ------------------------------------------------------------------ shared

def _require_predictor(model):
    if not hasattr(model, "predictor"):
        raise YdfError(
            f"{type(model).__name__} has no compiled predictor; dataset-"
            "based analysis (permutation importances, PDP) supports "
            "decision-forest models. Solution: run structural analysis only "
            "(model.analyze() without a dataset).")
    return model.predictor()


def _permutation(seed: int, feature: int, rep: int, n: int) -> np.ndarray:
    """The shuffle used for replica (feature, rep) — a pure function of
    (seed, feature, rep) so batching layout can never change scores."""
    return np.random.default_rng((seed, 1021, feature, rep)).permutation(n)


def _chunked(fn, X: np.ndarray, row_budget: int) -> np.ndarray:
    if X.shape[0] <= row_budget:
        return np.asarray(fn(X))
    return np.concatenate([np.asarray(fn(X[i:i + row_budget]))
                           for i in range(0, X.shape[0], row_budget)], axis=0)


def _example_scores(task: Task, out: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Per-example contributions the primary metric is a function of:
    correctness for classification, squared error for every scalar-output
    task (regression, and — as a proxy — ranking scores vs graded
    relevance, uplift effects vs outcome, anomaly scores vs indicator;
    the task-true metric still appears in the baseline Evaluation)."""
    if task == Task.CLASSIFICATION:
        return (np.asarray(out).argmax(1) == y).astype(np.float64)
    return np.square(np.asarray(out).reshape(-1).astype(np.float64) - y)


def _primary(task: Task, scores: np.ndarray) -> float:
    """Higher-is-better metric from per-example scores (Evaluation.primary
    convention): accuracy, or -rmse."""
    if task == Task.CLASSIFICATION:
        return float(scores.mean())
    return -float(np.sqrt(scores.mean()))


def _metric_name(task: Task) -> str:
    return "accuracy" if task == Task.CLASSIFICATION else "rmse"


def _kind_name(task: Task, oob: bool = False) -> str:
    base = ("MEAN_DECREASE_ACCURACY" if task == Task.CLASSIFICATION
            else "MEAN_INCREASE_RMSE")
    return ("OOB_" + base) if oob else base


def _entry_with_ci(task: Task, feature: str, s_base: np.ndarray,
                   s_perm: np.ndarray) -> ImportanceEntry:
    """Importance = primary(base) - mean_r primary(perm_r), CI95 by
    bootstrapping examples jointly across the base and permuted scores."""
    R = s_perm.shape[0]
    imp = _primary(task, s_base) - float(
        np.mean([_primary(task, s_perm[r]) for r in range(R)]))
    values = np.concatenate([s_base[:, None], s_perm.T], axis=1)  # (N, 1+R)

    def stat(v):
        return _primary(task, v[:, 0]) - float(
            np.mean([_primary(task, v[:, 1 + r]) for r in range(R)]))

    lo, hi = _bootstrap_ci(values, stat)
    return ImportanceEntry(feature=feature, importance=imp, ci95=(lo, hi))


# ------------------------------------------------------- permutation engine

def permutation_importances(model, dataset, *, repetitions: int = 3,
                            seed: int = 42, bundle=None,
                            row_budget: int = DEFAULT_ROW_BUDGET,
                            ) -> tuple[ImportanceTable, Evaluation]:
    """Mean decrease of the primary metric per feature, plus the unpermuted
    baseline Evaluation. All F x repetitions permuted replicas are stacked
    into encoded batches of <= ``row_budget`` rows and dispatched through
    the compiled serving path (``bundle`` routes dispatches through a
    ForestServeBundle's padding buckets instead)."""
    if repetitions < 1:
        raise YdfError(f"repetitions must be >= 1, got {repetitions}.")
    pred = _require_predictor(model)
    X = pred.encode(dataset)
    y = label_values(model, dataset)
    N, F = X.shape
    if N == 0:
        raise YdfError("Cannot analyze an empty dataset.")
    dispatch = ((lambda Z: bundle.predict_encoded_bulk(Z, row_budget))
                if bundle is not None
                else lambda Z: _chunked(pred.predict_encoded, Z, row_budget))
    base_out = dispatch(X)
    from repro.core.api import _evaluation_extras
    baseline = evaluate_predictions(
        model.task, base_out, y, classes=getattr(model, "classes", None),
        source="analysis", **_evaluation_extras(model, dataset))
    s_base = _example_scores(model.task, base_out, y)

    pairs = [(j, r) for j in range(F) for r in range(repetitions)]
    group = max(1, row_budget // N)
    s_perm = np.empty((F, repetitions, N), np.float64)
    for g0 in range(0, len(pairs), group):
        chunk = pairs[g0:g0 + group]
        X_rep = np.tile(X, (len(chunk), 1))
        for i, (j, r) in enumerate(chunk):
            X_rep[i * N:(i + 1) * N, j] = X[_permutation(seed, j, r, N), j]
        out = dispatch(X_rep)
        for i, (j, r) in enumerate(chunk):
            s_perm[j, r] = _example_scores(model.task, out[i * N:(i + 1) * N], y)

    entries = [_entry_with_ci(model.task, model.features[j], s_base, s_perm[j])
               for j in range(F)]
    table = ImportanceTable(
        kind=_kind_name(model.task), source="permutation", entries=entries,
        metric=_metric_name(model.task),
        baseline=abs(_primary(model.task, s_base)), repetitions=repetitions)
    return table, baseline


# --------------------------------------------------------- OOB permutation

def regenerate_oob_masks(bag_info: dict, n_trees: int) -> np.ndarray:
    """(T, N) bool: example i is OUT of tree t's bootstrap bag. Reproduces
    rf.py's per-tree streams: rng((seed, 104729, t)).multinomial is the
    first draw of each stream, so bags regenerate exactly."""
    N = bag_info["n_rows"]
    p = np.full(N, 1.0 / N)
    oob = np.empty((n_trees, N), bool)
    for t in range(n_trees):
        rng = np.random.default_rng((bag_info["seed"], 104729, t))
        oob[t] = rng.multinomial(N, p) == 0
    return oob


def _oob_aggregate(model, per_tree: np.ndarray, oob: np.ndarray,
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Training-time compute_oob accumulation, vectorized: per_tree
    (N, T, C) leaf outputs, oob (T, N). Returns (predictions over seen
    examples, seen mask)."""
    pt = np.asarray(per_tree, np.float64)
    C = pt.shape[-1]
    cls = model.task == Task.CLASSIFICATION
    if cls and getattr(model, "winner_take_all", False) and C > 1:
        votes = np.zeros_like(pt)
        np.put_along_axis(votes, pt.argmax(-1)[..., None], 1.0, axis=-1)
        pt = votes
    mask = oob.T[:, :, None]                      # (N, T, 1)
    sums = (pt * mask).sum(axis=1)                # (N, C)
    cnt = oob.sum(axis=0)                         # (N,)
    seen = cnt > 0
    preds = sums[seen] / cnt[seen, None]
    if cls:
        preds = preds / np.maximum(preds.sum(1, keepdims=True), 1e-12)
    return preds, seen


def oob_permutation_importances(model, dataset, *, repetitions: int = 1,
                                seed: int = 42,
                                row_budget: int = DEFAULT_ROW_BUDGET,
                                ) -> tuple[ImportanceTable, Evaluation]:
    """Breiman's out-of-bag permutation importance. ``dataset`` must be the
    exact training dataset: bags are regenerated from ``model.bag_info``
    and each example is scored only by trees it is out-of-bag for, so the
    unpermuted baseline reproduces the training-time OOB self-evaluation."""
    bag_info = getattr(model, "bag_info", None)
    if bag_info is None:
        raise YdfError(
            "OOB permutation importance needs a Random Forest trained with "
            "bootstrap=True and compute_oob=True (the learner then records "
            "model.bag_info for bag regeneration). Solutions: (1) retrain "
            "with those defaults, or (2) use permutation_importances on a "
            "held-out dataset.")
    pred = _require_predictor(model)
    X = pred.encode(dataset)
    y = label_values(model, dataset)
    N, F = X.shape
    if N != bag_info["n_rows"]:
        raise YdfError(
            f"OOB permutation importance must run on the exact training "
            f"dataset: the model trained on {bag_info['n_rows']} rows, got "
            f"{N}. Solution: pass the training dataset (or use "
            "permutation_importances on held-out data).")
    expect = bag_info.get("fingerprint")
    if expect is not None:
        from repro.core.rf import training_data_fingerprint
        if training_data_fingerprint(X, y) != expect:
            raise YdfError(
                "OOB permutation importance must run on the exact training "
                "dataset: this dataset has the right size but different "
                "content (the regenerated bootstrap bags would be "
                "meaningless). Solution: pass the training dataset, or use "
                "permutation_importances on held-out data.")
    T = model.forest.n_trees
    oob = regenerate_oob_masks(bag_info, T)
    if not oob.any():
        raise YdfError("No example is out-of-bag (forest too small); cannot "
                       "compute OOB importances.")
    out_dim = model.forest.leaf_value.shape[-1]
    # per-tree sweeps hold (rows, T, out) floats; budget rows accordingly
    rows_cap = max(256, int(row_budget * 4 // max(1, T * out_dim)))
    per_tree = lambda Z: _chunked(pred.per_tree, Z, rows_cap)

    def oob_scores(Z: np.ndarray) -> np.ndarray:
        preds, seen = _oob_aggregate(model, per_tree(Z), oob)
        return _example_scores(model.task, preds, y[seen])

    preds, seen = _oob_aggregate(model, per_tree(X), oob)
    s_base = _example_scores(model.task, preds, y[seen])
    baseline = evaluate_predictions(
        model.task, preds, y[seen], classes=getattr(model, "classes", None),
        source="out-of-bag")
    s_perm = np.empty((F, repetitions, len(s_base)), np.float64)
    for j in range(F):
        for r in range(repetitions):
            Xp = X.copy()
            Xp[:, j] = X[_permutation(seed, j, r, N), j]
            s_perm[j, r] = oob_scores(Xp)
    entries = [_entry_with_ci(model.task, model.features[j], s_base, s_perm[j])
               for j in range(F)]
    table = ImportanceTable(
        kind=_kind_name(model.task, oob=True), source="oob-permutation",
        entries=entries, metric=_metric_name(model.task),
        baseline=abs(_primary(model.task, s_base)), repetitions=repetitions)
    return table, baseline
