"""The CLI API (paper §4.1): the same verbs, over format-prefixed datasets.

  python -m repro.cli infer_dataspec --dataset=csv:train.csv --output=spec.json
  python -m repro.cli show_dataspec  --dataspec=spec.json
  python -m repro.cli train  --dataset=csv:train.csv --label=income \
        --learner=GRADIENT_BOOSTED_TREES --output=/tmp/model \
        [--task=CLASSIFICATION] [--hparam num_trees=50] [--template=...]
  python -m repro.cli show_model --model=/tmp/model
  python -m repro.cli evaluate --dataset=csv:test.csv --model=/tmp/model [--json]
  python -m repro.cli analyze  --dataset=csv:test.csv --model=/tmp/model \
        [--json] [--output=report.json] [--repetitions=3] [--sample=256]
  python -m repro.cli predict  --dataset=csv:test.csv --model=/tmp/model \
        --output=csv:predictions.csv
  python -m repro.cli serve    --dataset=csv:requests.csv --model=/tmp/model \
        [--deadline-ms=50] [--request-rows=32] [--engines=vectorized,naive] \
        [--output=csv:predictions.csv] [--json]
  python -m repro.cli benchmark_inference --dataset=csv:test.csv --model=/tmp/model
  python -m repro.cli profile train --dataset=csv:train.csv --label=income \
        --trace=trace.json [--learner=...] [--hparam k=v]
  python -m repro.cli profile infer --dataset=csv:test.csv --model=/tmp/model \
        --trace=trace.json

Training configurations are cross-API compatible (§3.10): a model trained
here loads from Python and vice versa.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _load_spec(path: str):
    from repro.core.dataspec import spec_from_dict
    with open(path) as f:
        return spec_from_dict(json.load(f))


def _dump_spec(spec, path: str):
    from repro.core.dataspec import spec_to_dict
    with open(path, "w") as f:
        json.dump(spec_to_dict(spec), f, indent=1)


def cmd_infer_dataspec(args):
    from repro.core.dataspec import infer_dataspec
    from repro.data.io import read_dataset
    spec = infer_dataspec(read_dataset(args.dataset),
                          semantics=dict(kv.split("=") for kv in args.semantic))
    _dump_spec(spec, args.output)
    print(f"dataspec written to {args.output} "
          f"({len(spec.columns)} columns, {spec.n_rows} rows)")


def cmd_show_dataspec(args):
    print(_load_spec(args.dataspec).report())


def _parse_hparams(pairs):
    hparams = {}
    for kv in pairs:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                pass
        if v in ("true", "false", "True", "False"):
            v = str(v).lower() == "true"
        hparams[k] = v
    return hparams


def cmd_train(args):
    from repro.core import Task, get_learner
    from repro.data.io import read_dataset
    if args.resume:
        # continue an interrupted run: the learner is rebuilt from the
        # checkpoint manifest's train_config — only the dataset is re-read
        from repro.train.checkpoint import resume_training
        data = read_dataset(args.dataset)
        valid = read_dataset(args.valid) if args.valid else None
        model = resume_training(args.resume, data, valid)
        model.save(args.output)
        print(f"resumed from {args.resume}; model written to {args.output}")
        logs = getattr(model, "training_logs", None)
        for ev in (logs or {}).get("resilience", []):
            print(f"  resilience: {ev}")
        return
    hparams = _parse_hparams(args.hparam)
    task = Task(args.task.upper())
    learner_name = args.learner
    if args.learner == "GRADIENT_BOOSTED_TREES":
        # the flag default; tasks with a dedicated learner re-route
        learner_name = {Task.UPLIFT: "UPLIFT_TREES",
                        Task.ANOMALY: "ISOLATION_FOREST"}.get(task,
                                                              args.learner)
    cls = get_learner(learner_name)
    kw = dict(label=args.label, task=task, seed=args.seed, **hparams)
    if args.template:
        kw["template"] = args.template
    learner = cls(**kw)
    data = read_dataset(args.dataset)
    valid = read_dataset(args.valid) if args.valid else None
    checkpoint = None
    if args.checkpoint_dir:
        from repro.train.checkpoint import CheckpointPolicy
        checkpoint = CheckpointPolicy(args.checkpoint_dir,
                                      every_n_trees=args.checkpoint_every)
    model = learner.train(data, valid, checkpoint=checkpoint)
    model.save(args.output)
    se = getattr(model, "self_evaluation", None)
    logs = getattr(model, "training_logs", None)
    if isinstance(logs, dict) and logs.get("interrupted"):
        print("training interrupted; truncated model saved "
              f"(resume with: train --resume {args.checkpoint_dir} ...)")
    print(f"model written to {args.output}")
    if se is not None:
        print(se.report())


def cmd_show_model(args):
    from repro.core import Model
    print(Model.load(args.model).summary(verbose=args.verbose))


def cmd_import_sklearn(args):
    """Import a pickled fitted sklearn estimator into a servable model
    directory (DESIGN.md §7: the interop seam on the CLI)."""
    import pickle

    from repro.interop import from_sklearn
    with open(args.estimator, "rb") as f:
        est = pickle.load(f)
    names = args.feature_names.split(",") if args.feature_names else None
    model = from_sklearn(est, label=args.label, feature_names=names)
    model.save(args.output)
    print(f"imported {type(est).__name__} -> {type(model).__name__} "
          f"({model.forest.n_trees} trees) written to {args.output}")


def cmd_evaluate(args):
    from repro.core import Model
    from repro.data.io import read_dataset
    model = Model.load(args.model)
    ev = model.evaluate(read_dataset(args.dataset))
    if args.json:
        print(json.dumps(ev.to_dict(), indent=1))
    else:
        print(ev.report())


def cmd_analyze(args):
    """Model analysis (DESIGN.md §8): structural importances always;
    permutation importances, PDP curves and an evaluation when a dataset
    is given. The report prints as text or dumps as JSON."""
    from repro.core import Model
    from repro.data.io import read_dataset
    model = Model.load(args.model)
    data = read_dataset(args.dataset) if args.dataset else None
    rep = model.analyze(data, permutation_repetitions=args.repetitions,
                        sample_rows=args.sample)
    if args.output:
        with open(args.output, "w") as f:
            json.dump(rep.to_dict(), f, indent=1)
        print(f"analysis report written to {args.output}")
    if args.json:
        print(json.dumps(rep.to_dict(), indent=1))
    elif not args.output:
        print(rep.report())


def cmd_predict(args):
    from repro.core import Model, Task
    from repro.data.io import read_dataset, write_dataset
    model = Model.load(args.model)
    pred = model.predict(read_dataset(args.dataset))
    if model.task == Task.CLASSIFICATION:
        cols = {f"p_{c}": pred[:, i] for i, c in enumerate(model.classes)}
    else:
        cols = {"prediction": np.asarray(pred)}
    write_dataset(cols, args.output)
    print(f"{len(pred)} predictions written to {args.output}")


def cmd_serve(args):
    """Batch-score a dataset through the fault-tolerant ForestServer
    (DESIGN.md §9) and print the serving-metrics summary. Rows ride as
    deadline-bounded requests through admission control, retries and the
    engine-degradation chain — sheds and timeouts surface as NaN rows in
    the output and as counters in the summary, never as silent gaps."""
    from repro.core import Model, Task
    from repro.data.io import read_dataset, write_dataset
    from repro.serving.server import ForestServer, RequestShed, YdfError
    model = Model.load(args.model)
    data = read_dataset(args.dataset)
    data.pop(model.label, None)          # serving requests carry features only
    n = len(next(iter(data.values())))
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms else None
    engines = args.engines.split(",") if args.engines else None
    srv = ForestServer(model, engines=engines,
                       default_deadline_s=deadline_s, warmup=True)
    step = max(1, args.request_rows)
    spans, tickets = [], []
    for lo in range(0, n, step):
        req = {k: v[lo:lo + step] for k, v in data.items()}
        try:
            tickets.append(srv.submit(req))
        except RequestShed:
            tickets.append(None)
        spans.append((lo, min(lo + step, n)))
    srv.pump()
    out = np.full((n,) + tuple(srv._state(None).bundle(0).predictor.out_shape),
                  np.nan, np.float32)
    for t, (lo, hi) in zip(tickets, spans):
        if t is None:
            continue
        try:
            out[lo:hi] = srv.result(t)
        except YdfError:
            pass                         # timed out / failed: NaN rows, counted
    if args.output:
        if model.task == Task.CLASSIFICATION:
            cols = {f"p_{c}": out[:, i] for i, c in enumerate(model.classes)}
        else:
            cols = {"prediction": out.reshape(n)}
        write_dataset(cols, args.output)
        print(f"{n} rows scored to {args.output}")
    chain = " -> ".join(f"{e['engine']}[{e['circuit']}]"
                        for e in srv.engine_status())
    print(f"served {len(spans)} requests x {step} rows "
          f"(deadline {'none' if deadline_s is None else f'{args.deadline_ms:g} ms'}, "
          f"engine chain {chain})")
    if args.json:
        print(json.dumps(srv.metrics.to_dict(), indent=1))
    else:
        print(srv.metrics.summary())


def cmd_benchmark_inference(args):
    from repro.core import Model
    from repro.core.engines import benchmark_inference
    from repro.data.io import read_dataset
    model = Model.load(args.model)
    print(benchmark_inference(model, read_dataset(args.dataset),
                              repetitions=args.repetitions))


def cmd_profile(args):
    """Per-phase profiling (DESIGN.md §13): run one training or one
    inference pass under the tracer, write a Chrome trace-event file
    (loadable in chrome://tracing / ui.perfetto.dev) and print the phase
    summary — where the time went, phase by phase, subsystem by
    subsystem. No flags change what runs; profiling observes, it does
    not steer."""
    from repro.data.io import read_dataset
    from repro.obs import trace
    from repro.obs.export import (phase_summary, profile_dict,
                                  write_chrome_trace)
    data = read_dataset(args.dataset)
    if args.what == "train":
        from repro.core import Task, get_learner
        cls = get_learner(args.learner)
        learner = cls(label=args.label, task=Task(args.task.upper()),
                      seed=args.seed, **_parse_hparams(args.hparam))
        with trace.capture() as tracer:
            model = learner.train(data)
        if args.output:
            model.save(args.output)
            print(f"model written to {args.output}")
    else:
        from repro.core import Model
        model = Model.load(args.model)
        data.pop(model.label, None)
        with trace.capture() as tracer:
            for _ in range(max(1, args.repetitions)):
                model.predict(data)
    write_chrome_trace(args.trace, tracer)
    print(f"chrome trace ({tracer.span_count()} spans, "
          f"{len(tracer.events)} events) written to {args.trace}")
    if args.json:
        print(json.dumps(profile_dict(tracer), indent=1))
        return
    rows = sorted(phase_summary(tracer).items(),
                  key=lambda kv: -kv[1]["self_s"])
    print(f"{'phase':<32} {'count':>7} {'total_ms':>10} "
          f"{'self_ms':>10} {'mean_ms':>9}")
    for name, d in rows:
        print(f"{name:<32} {d['count']:>7} {d['total_s'] * 1e3:>10.2f} "
              f"{d['self_s'] * 1e3:>10.2f} {d['mean_s'] * 1e3:>9.3f}")


def main(argv=None):
    ap = argparse.ArgumentParser(prog="repro.cli", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("infer_dataspec")
    p.add_argument("--dataset", required=True)
    p.add_argument("--output", required=True)
    p.add_argument("--semantic", action="append", default=[],
                   help="override col=SEMANTIC")
    p.set_defaults(fn=cmd_infer_dataspec)

    p = sub.add_parser("show_dataspec")
    p.add_argument("--dataspec", required=True)
    p.set_defaults(fn=cmd_show_dataspec)

    p = sub.add_parser("train")
    p.add_argument("--dataset", required=True)
    p.add_argument("--valid")
    p.add_argument("--label", required=True)
    p.add_argument("--task", default="CLASSIFICATION",
                   help="CLASSIFICATION | REGRESSION | ranking | uplift | "
                        "anomaly (case-insensitive; uplift/anomaly pick "
                        "their dedicated learner automatically)")
    p.add_argument("--learner", default="GRADIENT_BOOSTED_TREES")
    p.add_argument("--template")
    p.add_argument("--seed", type=int, default=1234)
    p.add_argument("--hparam", action="append", default=[])
    p.add_argument("--output", required=True)
    p.add_argument("--checkpoint-dir", dest="checkpoint_dir",
                   help="write atomic tree-boundary training checkpoints here "
                        "(interruption-safe training, DESIGN.md §11)")
    p.add_argument("--checkpoint-every", dest="checkpoint_every", type=int,
                   default=10, help="checkpoint cadence in trees")
    p.add_argument("--resume", metavar="CHECKPOINT_DIR",
                   help="resume an interrupted run from its checkpoint "
                        "directory (learner rebuilt from the manifest; "
                        "bit-identical to an uninterrupted run)")
    p.set_defaults(fn=cmd_train)

    p = sub.add_parser("show_model")
    p.add_argument("--model", required=True)
    p.add_argument("--verbose", type=int, default=0, nargs="?", const=4,
                   help="render tree #0 down to this depth")
    p.set_defaults(fn=cmd_show_model)

    p = sub.add_parser("import_sklearn")
    p.add_argument("--estimator", required=True,
                   help="pickled fitted sklearn estimator (.pkl)")
    p.add_argument("--label", default="label")
    p.add_argument("--feature-names", dest="feature_names",
                   help="comma-separated feature column names")
    p.add_argument("--output", required=True)
    p.set_defaults(fn=cmd_import_sklearn)

    p = sub.add_parser("evaluate")
    p.add_argument("--dataset", required=True)
    p.add_argument("--model", required=True)
    p.add_argument("--json", action="store_true",
                   help="dump the evaluation as JSON instead of text")
    p.set_defaults(fn=cmd_evaluate)

    p = sub.add_parser("analyze")
    p.add_argument("--model", required=True)
    p.add_argument("--dataset",
                   help="analysis dataset; omit for structural-only analysis")
    p.add_argument("--json", action="store_true",
                   help="dump the report as JSON instead of text")
    p.add_argument("--output", help="write the JSON report to this path")
    p.add_argument("--repetitions", type=int, default=3,
                   help="permutation-importance repetitions")
    p.add_argument("--sample", type=int, default=256,
                   help="background sample size for PDP curves")
    p.set_defaults(fn=cmd_analyze)

    p = sub.add_parser("predict")
    p.add_argument("--dataset", required=True)
    p.add_argument("--model", required=True)
    p.add_argument("--output", required=True)
    p.set_defaults(fn=cmd_predict)

    p = sub.add_parser("serve")
    p.add_argument("--dataset", required=True)
    p.add_argument("--model", required=True)
    p.add_argument("--output", help="write predictions (csv:/json: path); "
                                    "shed/timed-out rows are NaN")
    p.add_argument("--deadline-ms", dest="deadline_ms", type=float, default=0,
                   help="per-request deadline in ms (0 = no deadline)")
    p.add_argument("--request-rows", dest="request_rows", type=int, default=32,
                   help="rows per simulated request")
    p.add_argument("--engines", help="comma-separated degradation chain, "
                                     "e.g. vectorized,naive")
    p.add_argument("--json", action="store_true",
                   help="dump the serving metrics as JSON instead of text")
    p.set_defaults(fn=cmd_serve)

    p = sub.add_parser("benchmark_inference")
    p.add_argument("--dataset", required=True)
    p.add_argument("--model", required=True)
    p.add_argument("--repetitions", type=int, default=3)
    p.set_defaults(fn=cmd_benchmark_inference)

    p = sub.add_parser("profile",
                       help="trace one train/infer pass (DESIGN.md §13)")
    p.add_argument("what", choices=("train", "infer"))
    p.add_argument("--dataset", required=True)
    p.add_argument("--trace", default="profile_trace.json",
                   help="Chrome trace-event output path "
                        "(chrome://tracing / ui.perfetto.dev)")
    p.add_argument("--json", action="store_true",
                   help="dump the phase breakdown as JSON instead of a table")
    # train mode
    p.add_argument("--label", help="label column (train mode)")
    p.add_argument("--task", default="CLASSIFICATION")
    p.add_argument("--learner", default="GRADIENT_BOOSTED_TREES")
    p.add_argument("--seed", type=int, default=1234)
    p.add_argument("--hparam", action="append", default=[])
    p.add_argument("--output", help="also save the trained model here")
    # infer mode
    p.add_argument("--model", help="model directory (infer mode)")
    p.add_argument("--repetitions", type=int, default=1,
                   help="predict passes to trace (infer mode)")
    p.set_defaults(fn=cmd_profile)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
