"""Sub-quadratic sequence mixers: Mamba2 (SSD) and RWKV6 (Finch).

Both ship two forms sharing weights:
  * chunked-parallel (train / prefill): scan over sequence chunks carrying the
    recurrent state; within-chunk terms are dense matmuls (MXU-friendly).
  * single-step recurrence (decode): O(1) state update.
Reference naive recurrences live in tests and must match the chunked forms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, rmsnorm
from repro.models.params import ParamSpec

# =====================================================================
# Mamba2 / SSD
# =====================================================================

def mamba2_schema(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    inner = H * P
    conv_dim = inner + 2 * N
    return {
        "in_proj": ParamSpec((D, 2 * inner + 2 * N + H), ("embed", "heads")),
        "conv_w": ParamSpec((cfg.d_conv, conv_dim), ("conv", "heads")),
        "conv_b": ParamSpec((conv_dim,), ("heads",), init="zeros"),
        "A_log": ParamSpec((H,), ("heads",), init="zeros"),
        "D": ParamSpec((H,), ("heads",), init="ones"),
        "dt_bias": ParamSpec((H,), ("heads",), init="zeros"),
        "norm": ParamSpec((inner,), ("heads",), init="ones"),
        "out_proj": ParamSpec((inner, D), ("heads", "embed")),
    }


def _mamba2_project(p, x, ctx: Ctx):
    cfg = ctx.cfg
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    inner = H * P
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [inner, 2 * inner, 2 * inner + N, 2 * inner + 2 * N], axis=-1)
    return z, xin, Bc, Cc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """xbc: (B, S, C); conv_w: (K, C) depthwise causal conv.

    conv_state: (B, K-1, C) trailing inputs from the previous segment (decode).
    Returns (y, new_conv_state).
    """
    K = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i:i + xbc.shape[1], :] * conv_w[i][None, None, :].astype(xbc.dtype)
            for i in range(K))
    y = jax.nn.silu(y + conv_b.astype(xbc.dtype))
    new_state = xp[:, -(K - 1):, :] if K > 1 else pad
    return y, new_state


def mamba2_chunked(p, x, ctx: Ctx, conv_state=None, ssm_state=None):
    """x: (B, S, D) -> (y (B, S, D), (conv_state, ssm_state))."""
    cfg = ctx.cfg
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    B, S, D = x.shape
    from repro.models.layers import largest_divisor_leq
    inner = H * P
    Q = largest_divisor_leq(S, cfg.ssm_chunk)
    nc = S // Q

    z, xin, Bc, Cc, dt = _mamba2_project(p, x, ctx)
    xbc = jnp.concatenate([xin, Bc, Cc], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xin, Bc, Cc = jnp.split(xbc, [inner, inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    loga = -dt * jnp.exp(p["A_log"].astype(jnp.float32))  # log decay per step, <= 0
    xh = xin.reshape(B, S, H, P)
    xdt = xh.astype(jnp.float32) * dt[..., None]  # input scaled by dt

    # chunk views
    xdt_c = xdt.reshape(B, nc, Q, H, P)
    B_c = Bc.reshape(B, nc, Q, N).astype(jnp.float32)
    C_c = Cc.reshape(B, nc, Q, N).astype(jnp.float32)
    loga_c = loga.reshape(B, nc, Q, H)

    if ssm_state is None:
        ssm_state = jnp.zeros((B, H, P, N), jnp.float32)

    def body(h, xs):
        xb, Bk, Ck, la = xs  # (B,Q,H,P), (B,Q,N), (B,Q,N), (B,Q,H)
        cum = jnp.cumsum(la, axis=1)              # (B,Q,H) inclusive
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bqn,bhpn->bqhp", Ck, h) * jnp.exp(cum)[..., None]
        # intra-chunk: masked pairwise decays
        dmat = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,K,H) = cum_q - cum_k
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        dmat = jnp.where(mask[None, :, :, None], jnp.exp(dmat), 0.0)
        sc = jnp.einsum("bqn,bkn->bqk", Ck, Bk)
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", sc, dmat, xb)
        # state update: h' = decay_total * h + sum_k exp(cum_last - cum_k) B_k xb_k
        dk = jnp.exp(cum[:, -1:, :] - cum)        # (B,Q,H)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + \
            jnp.einsum("bkn,bkh,bkhp->bhpn", Bk, dk, xb)
        return h_new, y_inter + y_intra

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (xdt_c, B_c, C_c, loga_c))
    h_final, ys = jax.lax.scan(body, ssm_state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return ctx.constrain(out, ("batch", "seq", "embed_act")), (new_conv, h_final)


def mamba2_step(p, x, ctx: Ctx, conv_state, ssm_state):
    """Single-token decode. x: (B, 1, D). States as in mamba2_chunked."""
    cfg = ctx.cfg
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    B = x.shape[0]
    inner = H * P
    z, xin, Bc, Cc, dt = _mamba2_project(p, x, ctx)
    xbc = jnp.concatenate([xin, Bc, Cc], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xin, Bc, Cc = jnp.split(xbc, [inner, inner + N], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"].astype(jnp.float32)))          # (B,H)
    xh = xin[:, 0].reshape(B, H, P).astype(jnp.float32)
    xdt = xh * dt[..., None]
    Bk = Bc[:, 0].astype(jnp.float32)  # (B,N)
    Ck = Cc[:, 0].astype(jnp.float32)
    h_new = a[:, :, None, None] * ssm_state + jnp.einsum("bn,bhp->bhpn", Bk, xdt)
    y = jnp.einsum("bn,bhpn->bhp", Ck, h_new)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, (new_conv, h_new)


# =====================================================================
# RWKV6 (Finch)
# =====================================================================

def rwkv6_schema(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H = D // cfg.rwkv_head_dim
    C = cfg.rwkv_head_dim
    lora = max(32, D // 16)
    return {
        "time": {
            "mu_r": ParamSpec((D,), ("embed_act",), init="zeros"),
            "mu_k": ParamSpec((D,), ("embed_act",), init="zeros"),
            "mu_v": ParamSpec((D,), ("embed_act",), init="zeros"),
            "mu_w": ParamSpec((D,), ("embed_act",), init="zeros"),
            "mu_g": ParamSpec((D,), ("embed_act",), init="zeros"),
            "wr": ParamSpec((D, D), ("embed", "heads")),
            "wk": ParamSpec((D, D), ("embed", "heads")),
            "wv": ParamSpec((D, D), ("embed", "heads")),
            "wg": ParamSpec((D, D), ("embed", "heads")),
            "wo": ParamSpec((D, D), ("heads", "embed")),
            "w0": ParamSpec((D,), ("embed_act",), init="zeros"),
            "w_lora_a": ParamSpec((D, lora), ("embed", None)),
            "w_lora_b": ParamSpec((lora, D), (None, "heads")),
            "u": ParamSpec((H, C), ("heads", None), init="zeros"),
            "ln_scale": ParamSpec((D,), ("embed_act",), init="ones"),
            "ln_bias": ParamSpec((D,), ("embed_act",), init="zeros"),
        },
        "channel": {
            "mu_k": ParamSpec((D,), ("embed_act",), init="zeros"),
            "mu_r": ParamSpec((D,), ("embed_act",), init="zeros"),
            "wk": ParamSpec((D, cfg.d_ff), ("embed", "mlp")),
            "wv": ParamSpec((cfg.d_ff, D), ("mlp", "embed")),
            "wr": ParamSpec((D, D), ("embed", "heads")),
        },
    }


def _token_shift(x, shift_state):
    """x: (B, S, D); shift_state: (B, D) last token of previous segment."""
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _rwkv_time_inputs(p, x, prev, ctx: Ctx):
    cfg = ctx.cfg
    D = cfg.d_model
    H = D // cfg.rwkv_head_dim
    C = cfg.rwkv_head_dim
    dt = x.dtype

    def mix(mu):
        return x + (prev - x) * mu.astype(dt)

    r = jnp.einsum("bsd,de->bse", mix(p["mu_r"]), p["wr"].astype(dt))
    k = jnp.einsum("bsd,de->bse", mix(p["mu_k"]), p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", mix(p["mu_v"]), p["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mix(p["mu_g"]), p["wg"].astype(dt)))
    xw = mix(p["mu_w"])
    w_dd = jnp.einsum("bsd,dl->bsl", xw, p["w_lora_a"].astype(dt))
    w_dd = jnp.einsum("bsl,ld->bsd", jnp.tanh(w_dd), p["w_lora_b"].astype(dt))
    logw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) + w_dd.astype(jnp.float32),
                             -8.0, 4.0))  # (B,S,D), in (-inf, 0)
    B_, S, _ = x.shape
    shp = (B_, S, H, C)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp), g, logw.reshape(shp))


def rwkv6_time_mix(p, x, ctx: Ctx, shift_state=None, wkv_state=None):
    """x: (B, S, D) -> (out, (shift_state, wkv_state)). Chunked-parallel form."""
    cfg = ctx.cfg
    B, S, D = x.shape
    from repro.models.layers import largest_divisor_leq
    H, C = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    Q = largest_divisor_leq(S, cfg.rwkv_chunk)
    nc = S // Q
    if shift_state is None:
        shift_state = jnp.zeros((B, D), x.dtype)
    if wkv_state is None:
        wkv_state = jnp.zeros((B, H, C, C), jnp.float32)

    prev = _token_shift(x, shift_state)
    r, k, v, g, logw = _rwkv_time_inputs(p, x, prev, ctx)
    u = p["u"].astype(jnp.float32)

    r_c = r.reshape(B, nc, Q, H, C).astype(jnp.float32)
    k_c = k.reshape(B, nc, Q, H, C).astype(jnp.float32)
    v_c = v.reshape(B, nc, Q, H, C).astype(jnp.float32)
    w_c = logw.reshape(B, nc, Q, H, C)

    def body(state, xs):
        rq, kq, vq, lw = xs  # (B,Q,H,C) each
        cum = jnp.cumsum(lw, axis=1)  # inclusive cumulative log-decay
        # inter-chunk: state contribution decayed to position q (decay applied
        # over steps 1..q, exclusive of q's own w? RWKV applies w before adding
        # token q's kv, so state seen by q is decayed by prod_{i<=q-1} w_i ...
        # with cum_ex = cum - lw (exclusive cumsum).
        cum_ex = cum - lw
        y_inter = jnp.einsum("bqhc,bhcp->bqhp", rq * jnp.exp(cum_ex), state)
        # intra-chunk: token j<q contributes decay prod_{i=j+1}^{q-1} w_i
        dmat = cum_ex[:, :, None] - cum[:, None, :]  # (B,Q,K,H,C): cum_ex_q - cum_k
        mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
        A = jnp.where(mask[None, :, :, None, None], jnp.exp(dmat), 0.0)
        sc = jnp.einsum("bqhc,bqkhc,bkhc->bqkh", rq, A, kq)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", sc, vq)
        # current token bonus: u
        y_diag = jnp.einsum("bqhc,bqhc->bqh", rq, u[None, None] * kq)[..., None] * vq
        # state update to end of chunk
        dk = jnp.exp(cum[:, -1:] - cum)  # decay from step k(+1) to chunk end
        s_new = jnp.exp(cum[:, -1])[..., None] * state + \
            jnp.einsum("bkhc,bkhp->bhcp", kq * dk, vq)
        return s_new, y_inter + y_intra + y_diag

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r_c, k_c, v_c, w_c))
    s_final, ys = jax.lax.scan(body, wkv_state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D).astype(x.dtype)
    # group norm over heads (ln_x in rwkv): normalize per head
    yh = y.reshape(B, S, H, C).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = (yh.reshape(B, S, D) * p["ln_scale"].astype(jnp.float32)
         + p["ln_bias"].astype(jnp.float32)).astype(x.dtype)
    y = y * g
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(x.dtype))
    out = ctx.constrain(out, ("batch", "seq", "embed_act"))
    return out, (x[:, -1, :], s_final)


def rwkv6_time_step(p, x, ctx: Ctx, shift_state, wkv_state):
    """Single-token decode. x: (B, 1, D)."""
    cfg = ctx.cfg
    B, _, D = x.shape
    H, C = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    prev = shift_state[:, None, :]
    r, k, v, g, logw = _rwkv_time_inputs(p, x, prev, ctx)
    r1, k1, v1 = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
    w1 = jnp.exp(logw[:, 0])  # (B,H,C)
    u = p["u"].astype(jnp.float32)
    kv = jnp.einsum("bhc,bhp->bhcp", k1, v1)
    y = jnp.einsum("bhc,bhcp->bhp", r1, wkv_state + u[None, ..., None] * kv)
    s_new = w1[..., None] * wkv_state + kv
    yh = y.reshape(B, 1, H, C)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = (yh.reshape(B, 1, D) * p["ln_scale"].astype(jnp.float32)
         + p["ln_bias"].astype(jnp.float32)).astype(x.dtype)
    y = y * g
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(x.dtype))
    return out, (x[:, -1, :], s_new)


def rwkv6_channel_mix(p, x, ctx: Ctx, shift_state=None):
    """RWKV channel-mix FFN with token shift. x: (B,S,D)."""
    if shift_state is None:
        shift_state = jnp.zeros((x.shape[0], x.shape[2]), x.dtype)
    prev = _token_shift(x, shift_state)
    dt = x.dtype

    def mix(mu):
        return x + (prev - x) * mu.astype(dt)

    k = jnp.einsum("bsd,df->bsf", mix(p["mu_k"]), p["wk"].astype(dt))
    k = jnp.square(jax.nn.relu(k))
    vv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", mix(p["mu_r"]), p["wr"].astype(dt)))
    out = ctx.constrain(rr * vv, ("batch", "seq", "embed_act"))
    return out, x[:, -1, :]
