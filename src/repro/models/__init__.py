"""LM substrate: transformer/MoE/SSM building blocks and model assembly."""
