"""Model assembly for all assigned architectures.

One uniform interface over six families (dense / moe / vlm / audio / hybrid /
ssm):

  * ``model_schema(cfg)``     — nested ParamSpec tree (init + AOT specs + axes)
  * ``forward(params, batch, ctx)``            — final hidden states (train/prefill)
  * ``loss_fn(params, batch, ctx)``            — chunked CE loss (+ MoE aux)
  * ``init_cache / cache_specs / cache_axes``  — decode caches per family
  * ``prefill(params, batch, ctx)``            — forward + cache population
  * ``decode_step(params, batch, cache, ctx)`` — one-token serving step

Params are plain nested dicts; layers are stacked on a leading 'layers' dim and
applied with ``lax.scan`` (+ optional ``jax.checkpoint``), which keeps the HLO
small enough to AOT-compile 64-layer / 314B-param configs on the CPU host.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (
    attention_schema,
    decode_attention,
    flash_attention,
    out_project,
    qkv_project,
)
from repro.models.layers import (
    Ctx,
    chunked_softmax_xent,
    embed,
    embed_schema,
    layernorm,
    layernorm_schema,
    logits_last,
    mlp,
    mlp_schema,
    rmsnorm,
    rmsnorm_schema,
    unembed_matrix,
)
from repro.models.moe import moe_block, moe_schema
from repro.models.params import ParamSpec, Schema, stack_layers


# =====================================================================
# Schemas
# =====================================================================

def _attn_mlp_block_schema(cfg: ModelConfig) -> Schema:
    """One decoder block: [ln1 -> attn] + [ln2 -> mlp/moe] (or parallel)."""
    sch: Schema = {
        "ln1": rmsnorm_schema(cfg.d_model),
        "attn": attention_schema(cfg),
    }
    if not cfg.parallel_block:
        sch["ln2"] = rmsnorm_schema(cfg.d_model)
    if cfg.n_experts:
        sch["moe"] = moe_schema(cfg)
    else:
        sch["mlp"] = mlp_schema(cfg)
    return sch


def _whisper_enc_block_schema(cfg: ModelConfig) -> Schema:
    return {
        "ln1": layernorm_schema(cfg.d_model),
        "attn": attention_schema(cfg),
        "ln2": layernorm_schema(cfg.d_model),
        "mlp": mlp_schema(cfg),
    }


def _whisper_dec_block_schema(cfg: ModelConfig) -> Schema:
    return {
        "ln1": layernorm_schema(cfg.d_model),
        "self_attn": attention_schema(cfg),
        "ln2": layernorm_schema(cfg.d_model),
        "cross_attn": attention_schema(cfg),
        "ln3": layernorm_schema(cfg.d_model),
        "mlp": mlp_schema(cfg),
    }


def _zamba_groups(cfg: ModelConfig) -> tuple[int, int]:
    per = cfg.attn_every
    assert per and cfg.n_layers % per == 0, (cfg.n_layers, per)
    return cfg.n_layers // per, per


def model_schema(cfg: ModelConfig) -> Schema:
    fam = cfg.family
    sch: Schema = {"embed": embed_schema(cfg)}
    if fam in ("dense", "moe", "vlm"):
        sch["layers"] = stack_layers(cfg.n_layers, _attn_mlp_block_schema(cfg))
        sch["final_norm"] = rmsnorm_schema(cfg.d_model)
    elif fam == "audio":
        sch["enc_layers"] = stack_layers(cfg.n_enc_layers, _whisper_enc_block_schema(cfg))
        sch["enc_norm"] = layernorm_schema(cfg.d_model)
        sch["dec_layers"] = stack_layers(cfg.n_layers, _whisper_dec_block_schema(cfg))
        sch["final_norm"] = layernorm_schema(cfg.d_model)
    elif fam == "hybrid":
        G, per = _zamba_groups(cfg)
        mamba = {"ln": rmsnorm_schema(cfg.d_model), "m": ssm_mod.mamba2_schema(cfg)}
        sch["mamba"] = stack_layers(G, stack_layers(per, mamba))
        sch["shared"] = {  # ONE weight set, invoked G times
            "ln1": rmsnorm_schema(cfg.d_model),
            "attn": attention_schema(cfg),
            "ln2": rmsnorm_schema(cfg.d_model),
            "mlp": mlp_schema(cfg),
        }
        sch["final_norm"] = rmsnorm_schema(cfg.d_model)
    elif fam == "ssm":
        block = {
            "ln1": layernorm_schema(cfg.d_model),
            "time": ssm_mod.rwkv6_schema(cfg)["time"],
            "ln2": layernorm_schema(cfg.d_model),
            "channel": ssm_mod.rwkv6_schema(cfg)["channel"],
        }
        sch["ln0"] = layernorm_schema(cfg.d_model)
        sch["layers"] = stack_layers(cfg.n_layers, block)
        sch["final_norm"] = layernorm_schema(cfg.d_model)
    else:
        raise ValueError(fam)
    return sch


# =====================================================================
# Block applications
# =====================================================================

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # 'full': save nothing


def _attn_mlp_block(p, x, ctx: Ctx, positions, *, causal=True, prefix_len=None):
    """Standard decoder block over full sequences (train / prefill)."""
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = qkv_project(p["attn"], h, h, ctx, positions, positions)
    a = flash_attention(q, k, v, positions, positions, ctx, causal=causal,
                        prefix_len=prefix_len)
    a = out_project(p["attn"], a, ctx)
    if cfg.parallel_block:
        if "moe" in p:
            m, aux = moe_block(p["moe"], h, ctx)
        else:
            m = mlp(p["mlp"], h, ctx)
        x = x + a + m
    else:
        x = x + a
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            m, aux = moe_block(p["moe"], h2, ctx)
        else:
            m = mlp(p["mlp"], h2, ctx)
        x = x + m
    return ctx.constrain(x, ("batch", "seq", "embed_act")), (a, k, v, aux)


def _scan(body, carry, stacked, cfg: ModelConfig):
    """Scan `body` over the leading 'layers' dim of `stacked` params."""
    body = _remat(body, cfg)
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        p_i = jax.tree.map(lambda a: a[i], stacked)
        carry, y = body(carry, p_i)
        ys.append(y)
    stack = (None if all(y is None for y in ys)
             else jax.tree.map(lambda *a: jnp.stack(a), *ys))
    return carry, stack


# =====================================================================
# Forward (train / prefill) per family
# =====================================================================

def _positions(B: int, S: int, offset: int = 0):
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :] + offset, (B, S))


def _embed_inputs(params, batch, ctx: Ctx):
    """Returns (x, positions, prefix_len). Handles vlm patch prefix and
    audio(decoder) token embedding."""
    cfg = ctx.cfg
    if cfg.family == "vlm":
        patches = batch["patches"].astype(ctx.dtype)  # (B, P, D)
        toks = embed(params["embed"], batch["tokens"], ctx)  # (B, S-P, D)
        x = jnp.concatenate([patches, toks], axis=1)
        B, S = x.shape[0], x.shape[1]
        return ctx.constrain(x, ("batch", "seq", "embed_act")), _positions(B, S), cfg.n_patches
    x = embed(params["embed"], batch["tokens"], ctx)
    B, S = x.shape[0], x.shape[1]
    if cfg.family == "audio":
        x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)[None]
    if cfg.family == "ssm":
        x = layernorm(params["ln0"], x, cfg.norm_eps)
    return x, _positions(B, S), None


def _sinusoid(S: int, D: int):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2.0 * dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _whisper_encode(params, frames, ctx: Ctx):
    """frames: (B, T, D) stub frame embeddings -> encoder states (B, T, D)."""
    cfg = ctx.cfg
    x = frames.astype(ctx.dtype) + _sinusoid(frames.shape[1], cfg.d_model).astype(ctx.dtype)[None]
    x = ctx.constrain(x, ("batch", "seq", "embed_act"))
    pos = _positions(x.shape[0], x.shape[1])

    def body(x, p):
        h = layernorm(p["ln1"], x, cfg.norm_eps)
        q, k, v = qkv_project(p["attn"], h, h, ctx, pos, pos, use_rope=False)
        a = out_project(p["attn"], flash_attention(q, k, v, pos, pos, ctx, causal=False), ctx)
        x = x + a
        x = x + mlp(p["mlp"], layernorm(p["ln2"], x, cfg.norm_eps), ctx)
        return ctx.constrain(x, ("batch", "seq", "embed_act")), None

    x, _ = _scan(body, x, params["enc_layers"], cfg)
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, batch, ctx: Ctx, *, return_cache: bool = False):
    """Full-sequence forward. Returns (h_final, cache_or_None, aux_loss).

    cache (when return_cache) is the same structure ``decode_step`` consumes,
    with entries valid for positions [0, S).
    """
    cfg = ctx.cfg
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _forward_attn(params, batch, ctx, return_cache)
    if fam == "audio":
        return _forward_whisper(params, batch, ctx, return_cache)
    if fam == "hybrid":
        return _forward_zamba(params, batch, ctx, return_cache)
    if fam == "ssm":
        return _forward_rwkv(params, batch, ctx, return_cache)
    raise ValueError(fam)


def _forward_attn(params, batch, ctx: Ctx, return_cache: bool):
    cfg = ctx.cfg
    x, pos, prefix = _embed_inputs(params, batch, ctx)

    def body(x, p):
        x, (_, k, v, aux) = _attn_mlp_block(p, x, ctx, pos, prefix_len=prefix)
        return x, ((k, v) if return_cache else None, aux)

    x, (kv, auxs) = _scan(body, x, params["layers"], cfg)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    aux = auxs.sum() if cfg.n_experts else jnp.zeros((), jnp.float32)
    cache = None
    if return_cache:
        cache = {"k": kv[0], "v": kv[1], "pos": jnp.full((x.shape[0],), x.shape[1], jnp.int32)}
    return h, cache, aux


def _forward_whisper(params, batch, ctx: Ctx, return_cache: bool):
    cfg = ctx.cfg
    enc = _whisper_encode(params, batch["frames"], ctx)  # (B, T, D)
    enc = ctx.constrain(enc, ("batch", "kv_len", "embed_act"))
    x, pos, _ = _embed_inputs(params, batch, ctx)
    enc_pos = _positions(enc.shape[0], enc.shape[1])

    def body(x, p):
        h = layernorm(p["ln1"], x, cfg.norm_eps)
        q, k, v = qkv_project(p["self_attn"], h, h, ctx, pos, pos, use_rope=False)
        x = x + out_project(p["self_attn"],
                            flash_attention(q, k, v, pos, pos, ctx, causal=True), ctx)
        h = layernorm(p["ln2"], x, cfg.norm_eps)
        cq, ck, cv = qkv_project(p["cross_attn"], h, enc, ctx, use_rope=False)
        x = x + out_project(p["cross_attn"],
                            flash_attention(cq, ck, cv, pos, enc_pos, ctx, causal=False), ctx)
        x = x + mlp(p["mlp"], layernorm(p["ln3"], x, cfg.norm_eps), ctx)
        x = ctx.constrain(x, ("batch", "seq", "embed_act"))
        return x, ((k, v, ck, cv) if return_cache else None)

    x, kv = _scan(body, x, params["dec_layers"], cfg)
    h = layernorm(params["final_norm"], x, cfg.norm_eps)
    cache = None
    if return_cache:
        cache = {"k": kv[0], "v": kv[1], "xk": kv[2], "xv": kv[3],
                 "pos": jnp.full((x.shape[0],), x.shape[1], jnp.int32)}
    return h, cache, jnp.zeros((), jnp.float32)


def _shared_attn_block(p, x, ctx: Ctx, pos):
    cfg = ctx.cfg
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = qkv_project(p["attn"], h, h, ctx, pos, pos)
    x = x + out_project(p["attn"], flash_attention(q, k, v, pos, pos, ctx, causal=True), ctx)
    x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), ctx)
    return ctx.constrain(x, ("batch", "seq", "embed_act")), (k, v)


def _forward_zamba(params, batch, ctx: Ctx, return_cache: bool):
    cfg = ctx.cfg
    x, pos, _ = _embed_inputs(params, batch, ctx)
    shared = params["shared"]

    def group(x, p_g):
        def mamba_layer(x, p_l):
            y, (conv, ssm) = ssm_mod.mamba2_chunked(
                p_l["m"], rmsnorm(p_l["ln"], x, cfg.norm_eps), ctx)
            return x + y, ((conv, ssm) if return_cache else None)

        x, states = _scan(mamba_layer, x, p_g, cfg)
        x, (k, v) = _shared_attn_block(shared, x, ctx, pos)
        return x, ((states, (k, v)) if return_cache else None)

    x, packed = _scan(group, x, params["mamba"], cfg)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    cache = None
    if return_cache:
        states, kv = packed
        cache = {"conv": states[0], "ssm": states[1], "k": kv[0], "v": kv[1],
                 "pos": jnp.full((x.shape[0],), x.shape[1], jnp.int32)}
    return h, cache, jnp.zeros((), jnp.float32)


def _forward_rwkv(params, batch, ctx: Ctx, return_cache: bool):
    cfg = ctx.cfg
    x, _, _ = _embed_inputs(params, batch, ctx)

    def body(x, p):
        t, (tshift, wkv) = ssm_mod.rwkv6_time_mix(
            p["time"], layernorm(p["ln1"], x, cfg.norm_eps), ctx)
        x = x + t
        c, cshift = ssm_mod.rwkv6_channel_mix(
            p["channel"], layernorm(p["ln2"], x, cfg.norm_eps), ctx)
        x = x + c
        return x, ((tshift, wkv, cshift) if return_cache else None)

    x, states = _scan(body, x, params["layers"], cfg)
    h = layernorm(params["final_norm"], x, cfg.norm_eps)
    cache = None
    if return_cache:
        cache = {"tshift": states[0], "wkv": states[1], "cshift": states[2],
                 "pos": jnp.full((x.shape[0],), x.shape[1], jnp.int32)}
    return h, cache, jnp.zeros((), jnp.float32)


# =====================================================================
# Loss
# =====================================================================

def loss_fn(params, batch, ctx: Ctx):
    """Mean CE over label positions (+ MoE aux). Returns (loss, metrics)."""
    cfg = ctx.cfg
    h, _, aux = forward(params, batch, ctx)
    if cfg.family == "vlm":  # loss on text positions only
        h = h[:, cfg.n_patches:, :]
    labels = batch["labels"]
    weights = batch.get("weights")
    if weights is None:
        weights = jnp.ones(labels.shape, jnp.float32)
    un = unembed_matrix(params["embed"], ctx)
    sum_loss, sum_w = chunked_softmax_xent(h, un, labels, weights, ctx)
    ce = sum_loss / jnp.maximum(sum_w, 1.0)
    return ce + aux, {"ce": ce, "aux": aux, "tokens": sum_w}


# =====================================================================
# Decode caches
# =====================================================================

def cache_spec(cfg: ModelConfig, batch_size: int, max_len: int) -> dict[str, Any]:
    """ShapeDtypeStructs for the decode cache (also defines the structure)."""
    dt = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
    B, L = batch_size, cfg.n_layers
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim()
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    fam = cfg.family
    out: dict[str, Any] = {"pos": sds((B,), jnp.int32)}
    if fam in ("dense", "moe", "vlm"):
        out["k"] = sds((L, B, max_len, KV, Dh), dt)
        out["v"] = sds((L, B, max_len, KV, Dh), dt)
    elif fam == "audio":
        out["k"] = sds((L, B, max_len, KV, Dh), dt)
        out["v"] = sds((L, B, max_len, KV, Dh), dt)
        out["xk"] = sds((L, B, cfg.enc_seq, KV, Dh), dt)
        out["xv"] = sds((L, B, cfg.enc_seq, KV, Dh), dt)
    elif fam == "hybrid":
        G, per = _zamba_groups(cfg)
        H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = H * P + 2 * N
        out["conv"] = sds((G, per, B, cfg.d_conv - 1, conv_dim), dt)
        out["ssm"] = sds((G, per, B, H, P, N), f32)
        out["k"] = sds((G, B, max_len, KV, Dh), dt)
        out["v"] = sds((G, B, max_len, KV, Dh), dt)
    elif fam == "ssm":
        D = cfg.d_model
        H, C = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
        out["tshift"] = sds((L, B, D), dt)
        out["cshift"] = sds((L, B, D), dt)
        out["wkv"] = sds((L, B, H, C, C), f32)
    return out


CACHE_AXES = {
    "pos": ("batch",),
    "k": ("layers", "batch", "kv_len", "kv_heads", "qkv"),
    "v": ("layers", "batch", "kv_len", "kv_heads", "qkv"),
    "xk": ("layers", "batch", "kv_len", "kv_heads", "qkv"),
    "xv": ("layers", "batch", "kv_len", "kv_heads", "qkv"),
    "conv": ("layers", None, "batch", None, "heads"),
    "ssm": ("layers", None, "batch", "heads", None, None),
    "tshift": ("layers", "batch", "embed_act"),
    "cshift": ("layers", "batch", "embed_act"),
    "wkv": ("layers", "batch", "heads", None, None),
}


def cache_axes(cfg: ModelConfig) -> dict[str, tuple]:
    return {k: CACHE_AXES[k] for k in cache_spec(cfg, 1, 8)}


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch_size, max_len))


def _cache_insert(cache_l, new, pos):
    """cache_l: (B, Smax, KV, Dh); new: (B, 1, KV, Dh); pos: (B,) int32."""
    def ins(c, n, p):
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (p, 0, 0))
    return jax.vmap(ins)(cache_l, new, pos)


# =====================================================================
# Decode step (one new token) per family
# =====================================================================

def decode_step(params, batch, cache, ctx: Ctx):
    """batch: {'token': (B,1) int32}. Returns (logits (B,V) fp32, new cache)."""
    cfg = ctx.cfg
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        h, cache = _decode_attn(params, batch, cache, ctx)
    elif fam == "audio":
        h, cache = _decode_whisper(params, batch, cache, ctx)
    elif fam == "hybrid":
        h, cache = _decode_zamba(params, batch, cache, ctx)
    elif fam == "ssm":
        h, cache = _decode_rwkv(params, batch, cache, ctx)
    else:
        raise ValueError(fam)
    logits = logits_last(h[:, -1, :], unembed_matrix(params["embed"], ctx), ctx)
    return logits, cache


def _decode_embed(params, batch, cache, ctx: Ctx):
    x = embed(params["embed"], batch["token"], ctx)  # (B, 1, D)
    pos = cache["pos"]  # (B,) index where this token is written
    if ctx.cfg.family == "audio":
        x = x + jax.vmap(lambda p: _sinusoid_at(p, ctx.cfg.d_model))(pos)[:, None, :].astype(x.dtype)
    if ctx.cfg.family == "ssm":
        x = layernorm(params["ln0"], x, ctx.cfg.norm_eps)
    return x, pos


def _sinusoid_at(p, D: int):
    dim = jnp.arange(D // 2, dtype=jnp.float32)
    ang = p.astype(jnp.float32) / jnp.power(10_000.0, 2.0 * dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _decode_attn(params, batch, cache, ctx: Ctx):
    cfg = ctx.cfg
    x, pos = _decode_embed(params, batch, cache, ctx)
    pos2 = pos[:, None]  # (B, 1)

    def body(x, xs):
        p, k_c, v_c = xs
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        q, k, v = qkv_project(p["attn"], h, h, ctx, pos2, pos2)
        k_c = _cache_insert(k_c, k, pos)
        v_c = _cache_insert(v_c, v, pos)
        a = decode_attention(q, k_c, v_c, pos, ctx)
        a = out_project(p["attn"], a, ctx)
        if cfg.parallel_block:
            m = moe_block(p["moe"], h, ctx)[0] if "moe" in p else mlp(p["mlp"], h, ctx)
            x = x + a + m
        else:
            x = x + a
            h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
            m = moe_block(p["moe"], h2, ctx)[0] if "moe" in p else mlp(p["mlp"], h2, ctx)
            x = x + m
        return x, (k_c, v_c)

    x, (k_new, v_new) = _scan(body, x, (params["layers"], cache["k"], cache["v"]), cfg)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return h, dict(cache, k=k_new, v=v_new, pos=pos + 1)


def _decode_whisper(params, batch, cache, ctx: Ctx):
    cfg = ctx.cfg
    x, pos = _decode_embed(params, batch, cache, ctx)
    pos2 = pos[:, None]

    def body(x, xs):
        p, k_c, v_c, xk, xv = xs
        h = layernorm(p["ln1"], x, cfg.norm_eps)
        q, k, v = qkv_project(p["self_attn"], h, h, ctx, pos2, pos2, use_rope=False)
        k_c = _cache_insert(k_c, k, pos)
        v_c = _cache_insert(v_c, v, pos)
        x = x + out_project(p["self_attn"], decode_attention(q, k_c, v_c, pos, ctx), ctx)
        h = layernorm(p["ln2"], x, cfg.norm_eps)
        cq, _, _ = qkv_project(p["cross_attn"], h, h[:, :0], ctx, use_rope=False)
        ca = decode_attention(cq, xk, xv, pos, ctx, valid_len=cfg.enc_seq)
        x = x + out_project(p["cross_attn"], ca, ctx)
        x = x + mlp(p["mlp"], layernorm(p["ln3"], x, cfg.norm_eps), ctx)
        return x, (k_c, v_c)

    x, (k_new, v_new) = _scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]), cfg)
    h = layernorm(params["final_norm"], x, cfg.norm_eps)
    return h, dict(cache, k=k_new, v=v_new, pos=pos + 1)


def _decode_zamba(params, batch, cache, ctx: Ctx):
    cfg = ctx.cfg
    x, pos = _decode_embed(params, batch, cache, ctx)
    shared = params["shared"]

    def group(x, xs):
        p_g, conv_g, ssm_g, k_c, v_c = xs

        def mamba_layer(x, xs_l):
            p_l, conv, ssmst = xs_l
            y, (conv2, ssm2) = ssm_mod.mamba2_step(
                p_l["m"], rmsnorm(p_l["ln"], x, cfg.norm_eps), ctx, conv, ssmst)
            return x + y, (conv2, ssm2)

        x, (conv2, ssm2) = _scan(mamba_layer, x, (p_g, conv_g, ssm_g), cfg)
        h = rmsnorm(shared["ln1"], x, cfg.norm_eps)
        q, k, v = qkv_project(shared["attn"], h, h, ctx, pos[:, None], pos[:, None])
        k_c = _cache_insert(k_c, k, pos)
        v_c = _cache_insert(v_c, v, pos)
        x = x + out_project(shared["attn"], decode_attention(q, k_c, v_c, pos, ctx), ctx)
        x = x + mlp(shared["mlp"], rmsnorm(shared["ln2"], x, cfg.norm_eps), ctx)
        return x, (conv2, ssm2, k_c, v_c)

    x, (conv_n, ssm_n, k_n, v_n) = _scan(
        group, x, (params["mamba"], cache["conv"], cache["ssm"], cache["k"], cache["v"]), cfg)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return h, dict(cache, conv=conv_n, ssm=ssm_n, k=k_n, v=v_n, pos=pos + 1)


def _decode_rwkv(params, batch, cache, ctx: Ctx):
    cfg = ctx.cfg
    x, pos = _decode_embed(params, batch, cache, ctx)

    def body(x, xs):
        p, tsh, wkv, csh = xs
        t, (tsh2, wkv2) = ssm_mod.rwkv6_time_step(
            p["time"], layernorm(p["ln1"], x, cfg.norm_eps), ctx, tsh, wkv)
        x = x + t
        c, csh2 = ssm_mod.rwkv6_channel_mix(
            p["channel"], layernorm(p["ln2"], x, cfg.norm_eps), ctx, csh)
        x = x + c
        return x, (tsh2, wkv2, csh2)

    x, (tsh_n, wkv_n, csh_n) = _scan(
        body, x, (params["layers"], cache["tshift"], cache["wkv"], cache["cshift"]), cfg)
    h = layernorm(params["final_norm"], x, cfg.norm_eps)
    return h, dict(cache, tshift=tsh_n, wkv=wkv_n, cshift=csh_n, pos=pos + 1)


def prefill(params, batch, ctx: Ctx):
    """Full-sequence prefill: returns (last-token logits (B,V), cache)."""
    h, cache, _ = forward(params, batch, ctx, return_cache=True)
    logits = logits_last(h[:, -1, :], unembed_matrix(params["embed"], ctx), ctx)
    return logits, cache


# =====================================================================
# Batch specs (ShapeDtypeStructs for AOT lowering) + logical axes
# =====================================================================

BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "weights": ("batch", "seq"),
    "patches": ("batch", "seq", "embed_act"),
    "frames": ("batch", "kv_len", "embed_act"),
    "token": ("batch", None),
}


def batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Model inputs for a given assigned shape, as ShapeDtypeStructs."""
    sds = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    i32, dt = jnp.int32, jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"token": sds((B, 1), i32)}
    out: dict[str, Any] = {}
    if cfg.family == "vlm":
        S_text = S - cfg.n_patches
        out["patches"] = sds((B, cfg.n_patches, cfg.d_model), dt)
        out["tokens"] = sds((B, S_text), i32)
        if shape.kind == "train":
            out["labels"] = sds((B, S_text), i32)
        return out
    if cfg.family == "audio":
        out["frames"] = sds((B, cfg.enc_seq, cfg.d_model), dt)
    out["tokens"] = sds((B, S), i32)
    if shape.kind == "train":
        out["labels"] = sds((B, S), i32)
    return out


def batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, tuple]:
    return {k: BATCH_AXES[k] for k in batch_spec(cfg, shape)}


def make_batch(key, cfg: ModelConfig, shape: ShapeConfig):
    """Random concrete batch matching batch_spec (for smoke tests/examples)."""
    spec = batch_spec(cfg, shape)
    out = {}
    for name, s in spec.items():
        key, k = jax.random.split(key)
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype) * 0.02
    return out
