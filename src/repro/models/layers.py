"""Shared transformer building blocks (pure functions over param dicts)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec
from repro.sharding import with_logical_constraint


@dataclass(frozen=True)
class Ctx:
    """Runtime context threaded through apply functions."""
    cfg: ModelConfig
    mesh: Any = None            # jax.sharding.Mesh | None
    rules: Mapping[str, tuple[str, ...]] | None = None

    def constrain(self, x, logical):
        return with_logical_constraint(x, logical, self.mesh, self.rules)

    @property
    def dtype(self):
        return jnp.dtype(self.cfg.dtype)


# ---------------------------------------------------------------- norms

def rmsnorm_schema(dim: int, axes=("embed_act",)) -> ParamSpec:
    return ParamSpec((dim,), axes, init="ones")


def rmsnorm(scale, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layernorm_schema(dim: int):
    return {"scale": ParamSpec((dim,), ("embed_act",), init="ones"),
            "bias": ParamSpec((dim,), ("embed_act",), init="zeros")}


def layernorm(p, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (>=1). Used to pick chunk sizes."""
    c = max(1, min(cap, n))
    while n % c:
        c -= 1
    return c


# ---------------------------------------------------------------- rope

def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- mlp

def mlp_schema(cfg: ModelConfig, d_ff: int | None = None,
               mlp_axis: str = "mlp") -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    sch = {
        "w_in": ParamSpec((d, f), ("embed", mlp_axis)),
        "w_out": ParamSpec((f, d), (mlp_axis, "embed")),
    }
    if gated:
        sch["w_gate"] = ParamSpec((d, f), ("embed", mlp_axis))
    return sch


def _act(name: str, x):
    if name == "swiglu":
        return jax.nn.silu(x)
    if name == "geglu" or name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def mlp(p, x, ctx: Ctx, act: str | None = None):
    """x: (B, S, D) -> (B, S, D)."""
    act = act or ctx.cfg.act
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(x.dtype))
    h = ctx.constrain(h, ("batch", "seq", "mlp"))
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = _act(act, g) * h
    else:
        h = _act(act, h)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(x.dtype))
    return ctx.constrain(out, ("batch", "seq", "embed_act"))


# ---------------------------------------------------------------- embedding / unembed

def embed_schema(cfg: ModelConfig) -> dict:
    sch = {"tokens": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                               init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        sch["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return sch


def embed(p, tokens, ctx: Ctx):
    x = jnp.take(p["tokens"], tokens, axis=0).astype(ctx.dtype)
    if ctx.cfg.embed_scale:
        x = x * jnp.asarray(ctx.cfg.d_model ** 0.5, ctx.dtype)
    return ctx.constrain(x, ("batch", "seq", "embed_act"))


def unembed_matrix(p, ctx: Ctx):
    if "unembed" in p:
        return p["unembed"].astype(ctx.dtype)  # (D, V)
    return p["tokens"].T.astype(ctx.dtype)


def chunked_softmax_xent(h, unembed_dv, labels, weights, ctx: Ctx):
    """Cross-entropy without materializing (B, S, V) logits.

    h: (B, S, D) final hidden states; unembed_dv: (D, V);
    labels: (B, S) int32; weights: (B, S) float (0 for padding).
    Returns (sum_loss, sum_weight).
    """
    B, S, D = h.shape
    C = largest_divisor_leq(S, ctx.cfg.loss_chunk)
    n = S // C

    def body(carry, xs):
        hs, ls, ws = xs  # (B, C, D), (B, C), (B, C)
        logits = jnp.einsum("bcd,dv->bcv", hs, unembed_dv).astype(jnp.float32)
        logits = ctx.constrain(logits, ("batch", "seq", "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        loss = (lse - gold) * ws
        sl, sw = carry
        return (sl + loss.sum(), sw + ws.sum()), None

    xs = (h.reshape(B, n, C, D).swapaxes(0, 1),
          labels.reshape(B, n, C).swapaxes(0, 1),
          weights.reshape(B, n, C).swapaxes(0, 1))
    (sum_loss, sum_w), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                               jnp.zeros((), jnp.float32)), xs)
    return sum_loss, sum_w


def logits_last(h_last, unembed_dv, ctx: Ctx):
    """h_last: (B, D) -> (B, V) logits (for serving)."""
    logits = jnp.einsum("bd,dv->bv", h_last, unembed_dv).astype(jnp.float32)
    return ctx.constrain(logits, ("batch", "vocab"))
