"""GShard/Switch-style MoE with capacity-based one-hot dispatch einsums.

TPU-native formulation: routing produces dense (group, token, expert, capacity)
dispatch/combine tensors consumed by einsums — these lower to all-to-alls under
GSPMD when the expert dim is sharded over 'data' (EP) and the group dim is
sharded over 'data' on the token side.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, _act
from repro.models.params import ParamSpec


def moe_schema(cfg: ModelConfig) -> dict:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    gated = cfg.act in ("swiglu", "geglu")
    sch = {
        "router": ParamSpec((D, E), ("embed", None), dtype="float32"),
        "w_in": ParamSpec((E, D, F), ("expert", "embed", "expert_mlp")),
        "w_out": ParamSpec((E, F, D), ("expert", "expert_mlp", "embed")),
    }
    if gated:
        sch["w_gate"] = ParamSpec((E, D, F), ("expert", "embed", "expert_mlp"))
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * cfg.moe_d_ff
        sch["shared"] = {
            "w_in": ParamSpec((D, Fs), ("embed", "mlp")),
            "w_out": ParamSpec((Fs, D), ("mlp", "embed")),
        }
        if gated:
            sch["shared"]["w_gate"] = ParamSpec((D, Fs), ("embed", "mlp"))
        sch["shared_gate"] = ParamSpec((D, 1), ("embed", None))
    return sch


def _top_k_dispatch(gates, k: int, capacity: int):
    """gates: (G, T, E) fp32 -> dispatch (G,T,E,C) bool-ish, combine (G,T,E,C)."""
    G, T, E = gates.shape
    topv, topi = jax.lax.top_k(gates, k)                     # (G, T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)      # (G, T, k, E)
    # Capacity slots: priority by (k-slot, token index): flatten (T, k) -> Tk,
    # k-major order so first choices beat second choices at equal position.
    oh_flat = onehot.transpose(0, 2, 1, 3).reshape(G, k * T, E)
    pos = jnp.cumsum(oh_flat, axis=1) - oh_flat              # slots before me
    pos = pos.reshape(G, k, T, E).transpose(0, 2, 1, 3)      # (G, T, k, E)
    pos = (pos * onehot).sum(-1)                             # (G, T, k)
    keep = (pos < capacity).astype(jnp.float32) * (topv > 0)
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                             dtype=jnp.float32)  # (G, T, k, C)
    disp = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, slot_oh, keep)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, slot_oh, keep * topv)
    return disp, comb


def moe_block(p, x, ctx: Ctx):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    cfg = ctx.cfg
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    from repro.models.layers import largest_divisor_leq
    T = largest_divisor_leq(B * S, cfg.moe_group_size)
    G = (B * S) // T
    cap = max(4, int(cfg.capacity_factor * T * k / E))
    xt = x.reshape(G, T, D)
    xt = ctx.constrain(xt, ("expert_group", None, "embed_act"))

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    disp, comb = _top_k_dispatch(gates, k, cap)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    f_e = disp.sum(axis=(1, 3)) / T                          # (G, E) dispatched frac
    p_e = gates.mean(axis=1)                                 # (G, E)
    aux = (E * (f_e * p_e).sum(-1)).mean() * cfg.router_aux_weight

    dt = x.dtype
    disp = disp.astype(dt)
    expert_in = jnp.einsum("gtec,gtd->egcd", disp, xt)       # all-to-all (EP)
    # Constrain the GROUP dim (kept sharded over data/pod) as well as the
    # expert dim: when E doesn't divide the expert axis the expert dim drops
    # to replicated, and without the group constraint GSPMD would insert a
    # full all-gather of the dispatched activations (measured 60-160s of
    # collective time on the MoE train cells — see EXPERIMENTS.md §Perf).
    expert_in = ctx.constrain(expert_in, ("expert", "expert_group", None, "embed_act"))
    h = jnp.einsum("egcd,edf->egcf", expert_in, p["w_in"].astype(dt))
    h = ctx.constrain(h, ("expert", "expert_group", None, "expert_mlp"))
    if "w_gate" in p:
        g = jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"].astype(dt))
        h = _act(cfg.act, g) * h
    else:
        h = _act(cfg.act, h)
    eo = jnp.einsum("egcf,efd->egcd", h, p["w_out"].astype(dt))
    eo = ctx.constrain(eo, ("expert", "expert_group", None, "embed_act"))
    out = jnp.einsum("gtec,egcd->gtd", comb.astype(dt), eo)  # all-to-all back
    out = ctx.constrain(out, ("expert_group", None, "embed_act"))
    out = out.reshape(B, S, D)

    if "shared" in p:
        from repro.models.layers import mlp
        shared = mlp(p["shared"], x, ctx)
        sg = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x.astype(jnp.float32), p["shared_gate"].astype(jnp.float32)))
        out = out + shared * sg.astype(dt)
    return ctx.constrain(out, ("batch", "seq", "embed_act")), aux
