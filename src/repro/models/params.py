"""Param schema: one declaration yields init values, ShapeDtypeStructs (for AOT
dry-runs) and logical sharding axes. No flax — params are plain nested dicts.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis per dim
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float = 1.0
    dtype: str | None = None      # override param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = dict[str, Any]  # nested dict with ParamSpec leaves


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def map_schema(fn: Callable[[ParamSpec], Any], schema: Schema):
    return jax.tree.map(fn, schema, is_leaf=is_spec)


def schema_axes(schema: Schema):
    return map_schema(lambda s: s.axes, schema)


def schema_shapes(schema: Schema, default_dtype: str):
    return map_schema(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or default_dtype)),
        schema,
    )


def schema_n_params(schema: Schema) -> int:
    total = 0
    for leaf in jax.tree.leaves(schema, is_leaf=is_spec):
        total += int(np.prod(leaf.shape))
    return total


def init_params(key: jax.Array, schema: Schema, default_dtype: str):
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = []
    for k, spec in zip(keys, leaves):
        dtype = jnp.dtype(spec.dtype or default_dtype)
        if spec.init == "zeros":
            v = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            v = jnp.ones(spec.shape, dtype)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale / math.sqrt(max(1, fan_in))
            if spec.init == "embed":
                std = spec.scale
            v = (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)
        vals.append(v)
    return jax.tree.unflatten(treedef, vals)


def stack_layers(n: int, schema: Schema) -> Schema:
    """Prefix every spec with a leading scanned 'layers' dim."""
    return map_schema(
        lambda s: dataclasses.replace(s, shape=(n,) + s.shape, axes=("layers",) + s.axes),
        schema,
    )
