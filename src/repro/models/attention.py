"""GQA attention: flash-style chunked softmax attention (pure JAX, never
materializes the full score matrix), causal/bidirectional/prefix-LM masks,
KV-cache decode, and an optional causal-block-skip variant (perf lever).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, rmsnorm
from repro.models.params import ParamSpec

NEG = -1.0e30


def attention_schema(cfg: ModelConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    sch = {
        "wq": ParamSpec((d, h, dh), ("embed", "heads", "qkv")),
        "wk": ParamSpec((d, kv, dh), ("embed", "kv_heads", "qkv")),
        "wv": ParamSpec((d, kv, dh), ("embed", "kv_heads", "qkv")),
        "wo": ParamSpec((h, dh, d), ("heads", "qkv", "embed")),
    }
    if cfg.qkv_bias:
        sch["bq"] = ParamSpec((h, dh), ("heads", "qkv"), init="zeros")
        sch["bk"] = ParamSpec((kv, dh), ("kv_heads", "qkv"), init="zeros")
        sch["bv"] = ParamSpec((kv, dh), ("kv_heads", "qkv"), init="zeros")
    if cfg.qk_norm:
        sch["q_norm"] = ParamSpec((dh,), (None,), init="ones")
        sch["k_norm"] = ParamSpec((dh,), (None,), init="ones")
    return sch


def qkv_project(p, xq, xkv, ctx: Ctx, q_positions=None, kv_positions=None,
                use_rope: bool = True):
    """xq: (B, Sq, D); xkv: (B, Skv, D). Returns q (B,Sq,H,Dh), k/v (B,Skv,KV,Dh)."""
    cfg = ctx.cfg
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if use_rope and cfg.rope_theta > 0:
        from repro.models.layers import rope
        q = rope(q, q_positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    q = ctx.constrain(q, ("batch", "seq", "heads", "qkv"))
    k = ctx.constrain(k, ("batch", "seq", "kv_heads", "qkv"))
    v = ctx.constrain(v, ("batch", "seq", "kv_heads", "qkv"))
    return q, k, v


def out_project(p, attn_out, ctx: Ctx):
    """attn_out: (B, S, H, Dh) -> (B, S, D)."""
    out = jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"].astype(attn_out.dtype))
    return ctx.constrain(out, ("batch", "seq", "embed_act"))


def _mask(qp, kp, causal: bool, prefix_len):
    """qp: (B, cq), kp: (B, ck) -> bool (B, cq, ck). True = attend."""
    if causal:
        m = kp[:, None, :] <= qp[:, :, None]
        if prefix_len is not None:
            m = m | (kp[:, None, :] < prefix_len)
        return m
    return jnp.ones((qp.shape[0], qp.shape[1], kp.shape[1]), bool)


def flash_attention(q, k, v, q_pos, k_pos, ctx: Ctx, *, causal=True,
                    prefix_len=None):
    """Chunked-softmax attention.

    q: (B, Sq, H, Dh); k, v: (B, Skv, KV, Dh); *_pos: (B, S) int32.
    Scans over (q-chunk, kv-chunk) tiles keeping a running max/denominator in
    fp32, so peak memory is O(cq * ck) per head instead of O(Sq * Skv).
    ``attn_impl='chunked_causal_skip'`` only visits the lower-triangular tiles.
    """
    cfg = ctx.cfg
    B, Sq, H, Dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    from repro.models.layers import largest_divisor_leq
    cq = largest_divisor_leq(Sq, cfg.attn_chunk_q)
    ck = largest_divisor_leq(Skv, cfg.attn_chunk_kv)
    nq, nk = Sq // cq, Skv // ck
    scale = Dh ** -0.5
    qg = (q * scale).reshape(B, nq, cq, KV, G, Dh)
    qp = q_pos.reshape(B, nq, cq)
    kc = k.reshape(B, nk, ck, KV, Dh)
    vc = v.reshape(B, nk, ck, KV, Dh)
    kp = k_pos.reshape(B, nk, ck)

    def tile(qcb, qpb, carry, ki):
        """One (q-chunk x kv-chunk) tile update. carry = (m, l, acc) fp32."""
        m, l, acc = carry
        kcb = jnp.take(kc, ki, axis=1)  # (B, ck, KV, Dh)
        vcb = jnp.take(vc, ki, axis=1)
        kpb = jnp.take(kp, ki, axis=1)  # (B, ck)
        s = jnp.einsum("bqvgd,bkvd->bqvgk", qcb, kcb,
                       preferred_element_type=jnp.float32)
        msk = _mask(qpb, kpb, causal, prefix_len)[:, :, None, None, :]
        s = jnp.where(msk, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None]) * msk
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqvgk,bkvd->bqvgd", p.astype(vcb.dtype), vcb,
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    def init_carry():
        m = jnp.full((B, cq, KV, G), NEG, jnp.float32)
        l = jnp.zeros((B, cq, KV, G), jnp.float32)
        acc = jnp.zeros((B, cq, KV, G, Dh), jnp.float32)
        return m, l, acc

    def finalize(carry):
        m, l, acc = carry
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l[..., None]).reshape(B, cq, H, Dh)

    if cfg.attn_impl == "chunked_causal_skip" and causal and prefix_len is None \
            and Sq == Skv and cq == ck:
        # Visit only lower-triangular tiles: scan over the static list of
        # (qi, ki<=qi) pairs; accumulators live in full-size buffers updated at
        # row qi. Eliminates the ~2x masked-tile compute of the dense variant.
        pairs = [(qi, ki) for qi in range(nq) for ki in range(qi + 1)]
        pair_q = jnp.array([p_[0] for p_ in pairs], jnp.int32)
        pair_k = jnp.array([p_[1] for p_ in pairs], jnp.int32)
        M = jnp.full((nq, B, cq, KV, G), NEG, jnp.float32)
        L = jnp.zeros((nq, B, cq, KV, G), jnp.float32)
        ACC = jnp.zeros((nq, B, cq, KV, G, Dh), jnp.float32)

        def body(carry, pq_pk):
            M, L, ACC = carry
            qi, ki = pq_pk
            qcb = jnp.take(qg, qi, axis=1)
            qpb = jnp.take(qp, qi, axis=1)
            sub = (jnp.take(M, qi, axis=0), jnp.take(L, qi, axis=0),
                   jnp.take(ACC, qi, axis=0))
            m, l, acc = tile(qcb, qpb, sub, ki)
            M = jax.lax.dynamic_update_index_in_dim(M, m, qi, 0)
            L = jax.lax.dynamic_update_index_in_dim(L, l, qi, 0)
            ACC = jax.lax.dynamic_update_index_in_dim(ACC, acc, qi, 0)
            return (M, L, ACC), None

        (M, L, ACC), _ = jax.lax.scan(body, (M, L, ACC), (pair_q, pair_k))
        L = jnp.where(L == 0.0, 1.0, L)
        out = (ACC / L[..., None]).reshape(nq, B, cq, H, Dh)
        out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, Dh)
        return out.astype(q.dtype)

    # Dense tiling: outer scan over q chunks, inner scan over all kv chunks.
    def q_body(_, xs):
        qcb, qpb = xs

        def kv_body(carry, ki):
            return tile(qcb, qpb, carry, ki), None

        carry, _ = jax.lax.scan(kv_body, init_carry(), jnp.arange(nk))
        return None, finalize(carry)

    _, outs = jax.lax.scan(q_body, None,
                           (jnp.moveaxis(qg, 1, 0), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, Dh)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, ctx: Ctx, *, valid_len=None):
    """Single-token attention over a cache.

    q: (B, 1, H, Dh); k_cache/v_cache: (B, Smax, KV, Dh); pos: (B,) int32 —
    index of the current token inside the cache (inclusive upper bound of the
    causal mask). valid_len: optional static bound (cross-attn: no mask).
    """
    B, _, H, Dh = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    # dequantize low-precision caches (e.g. float8_e4m3fn) at read time
    k_cache = k_cache.astype(q.dtype)
    v_cache = v_cache.astype(q.dtype)
    qg = (q * Dh ** -0.5).reshape(B, KV, G, Dh)
    s = jnp.einsum("bvgd,bkvd->bvgk", qg, k_cache,
                   preferred_element_type=jnp.float32)  # (B, KV, G, Smax)
    kpos = jnp.arange(Smax, dtype=jnp.int32)
    if valid_len is None:
        msk = kpos[None, :] <= pos[:, None]  # (B, Smax)
    else:
        msk = jnp.broadcast_to(kpos[None, :] < valid_len, (B, Smax))
    s = jnp.where(msk[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bvgk,bkvd->bvgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def reference_attention(q, k, v, q_pos, k_pos, *, causal=True, prefix_len=None):
    """O(S^2)-memory oracle used by tests."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, Dh).astype(jnp.float32) * Dh ** -0.5
    s = jnp.einsum("bqvgd,bkvd->bqvgk", qg, k.astype(jnp.float32))
    msk = _mask(q_pos, k_pos, causal, prefix_len)[:, :, None, None, :]
    s = jnp.where(msk, s, NEG)
    p = jax.nn.softmax(s, axis=-1) * msk
    out = jnp.einsum("bqvgk,bkvd->bqvgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)
