"""scikit-learn model import (paper §2.1 "integration with other libraries").

``from_sklearn(estimator)`` converts a fitted sklearn tree-based estimator
into the matching model class here — the imported model then flows unchanged
through the compiled serving stack: ``compile()``, the tree-tiled pallas
engine, ``serving/forest.py`` bundles and the MicroBatcher. This is the
serving win the inference-platform comparison (Guan et al., 2023) measures:
one fast runtime for forests trained anywhere.

Supported estimators -> model classes:

  * ``DecisionTreeClassifier`` / ``ExtraTreeClassifier``     -> CartModel
  * ``DecisionTreeRegressor``  / ``ExtraTreeRegressor``      -> CartModel
  * ``RandomForestClassifier`` / ``ExtraTreesClassifier``    -> RandomForestModel
  * ``RandomForestRegressor``  / ``ExtraTreesRegressor``     -> RandomForestModel
  * ``GradientBoostingClassifier`` / ``GradientBoostingRegressor``
                                                 -> GradientBoostedTreesModel

Prediction equivalence (enforced in tests, 1e-5): probabilities match
``predict_proba``, regressions match ``predict``. Two conversion details
make that exact:

  * sklearn splits send ``x <= threshold`` LEFT; our conditions send
    ``x >= threshold`` RIGHT. The imported threshold is lifted to the
    smallest float32 strictly above sklearn's float64 threshold, so both
    route identically for every float32 input.
  * sklearn classification leaves hold per-class counts (fractions since
    sklearn 1.4); both normalize to the same distribution.

Caveats (documented, §2.1): sklearn imputes nothing — imported numerical
features impute missing values with 0.0 at serving time; estimators fitted
with NaN support (missing_go_to_left) are imported without that routing.
"""
from __future__ import annotations

import numpy as np

from repro.core.api import Task, YdfError
from repro.core.py_tree import (
    CartBuilder,
    GradientBoostedTreesBuilder,
    Leaf,
    LogitValue,
    NonLeaf,
    NumericalHigherThan,
    ProbabilityValue,
    RandomForestBuilder,
    RegressionValue,
    Tree,
)

_SUPPORTED = (
    "DecisionTreeClassifier, DecisionTreeRegressor, ExtraTreeClassifier, "
    "ExtraTreeRegressor, RandomForestClassifier, RandomForestRegressor, "
    "ExtraTreesClassifier, ExtraTreesRegressor, GradientBoostingClassifier, "
    "GradientBoostingRegressor")


def _strictly_above(t: float) -> float:
    """Smallest float32 strictly greater than the float64 ``t``: makes our
    ``x >= t'`` route exactly like sklearn's ``x > t`` for float32 x."""
    t32 = np.float32(t)
    if t32 <= t:
        t32 = np.nextafter(t32, np.float32(np.inf))
    return float(t32)


def _check_fitted(est, attr: str) -> None:
    if not hasattr(est, attr):
        raise YdfError(
            f"{type(est).__name__} is not fitted (missing {attr!r}). "
            "Solution: call estimator.fit(X, y) before from_sklearn().")


def _convert_tree(sk_tree, value_of) -> Tree:
    """sklearn ``Tree`` arrays -> typed nodes. sklearn allocates children
    after parents, so a reverse-index sweep builds bottom-up without
    recursion (imported trees can be deeper than the recursion limit)."""
    left = sk_tree.children_left
    right = sk_tree.children_right
    feature = sk_tree.feature
    threshold = sk_tree.threshold
    nodes: list = [None] * sk_tree.node_count
    for i in range(sk_tree.node_count - 1, -1, -1):
        if left[i] < 0:  # TREE_LEAF
            nodes[i] = Leaf(value=value_of(i))
        else:
            nodes[i] = NonLeaf(
                condition=NumericalHigherThan(
                    feature=int(feature[i]),
                    threshold=_strictly_above(float(threshold[i]))),
                neg_child=nodes[int(left[i])],   # sklearn: x <= t goes left
                pos_child=nodes[int(right[i])])
    return Tree(root=nodes[0])


def _classification_value(sk_tree):
    values = sk_tree.value  # (n_nodes, 1, C): counts, or fractions >= 1.4

    def value_of(i):
        v = np.asarray(values[i][0], np.float64)
        s = v.sum()
        p = v / s if s > 0 else np.full(len(v), 1.0 / len(v))
        return ProbabilityValue(tuple(float(x) for x in p))

    return value_of


def _regression_value(sk_tree, scale: float = 1.0, logit: bool = False):
    values = sk_tree.value

    def value_of(i):
        v = float(values[i][0][0]) * scale
        return LogitValue(v) if logit else RegressionValue(v)

    return value_of


def _feature_columns(est, feature_names):
    n = int(est.n_features_in_)
    if feature_names is None:
        feature_names = [str(f) for f in getattr(
            est, "feature_names_in_", [f"f{i}" for i in range(n)])]
    if len(feature_names) != n:
        raise YdfError(
            f"feature_names has {len(feature_names)} entries but the "
            f"estimator was fitted on {n} features. Solution: pass one name "
            "per training column, in column order.")
    return list(feature_names)


def _single_output_or_raise(est) -> None:
    if getattr(est, "n_outputs_", 1) != 1:
        raise YdfError(
            f"{type(est).__name__} has n_outputs_={est.n_outputs_}; only "
            "single-label classification and scalar regression import. "
            "Solution: fit one estimator per output.")


# ------------------------------------------------------------------ converters

def _convert_cart(est, label, feature_names, classification: bool):
    _check_fitted(est, "tree_")
    _single_output_or_raise(est)
    names = _feature_columns(est, feature_names)
    if classification:
        builder = CartBuilder(label=label, task=Task.CLASSIFICATION,
                              features=names,
                              classes=[str(c) for c in est.classes_])
        builder.add_tree(_convert_tree(est.tree_, _classification_value(est.tree_)))
    else:
        builder = CartBuilder(label=label, task=Task.REGRESSION,
                              features=names)
        builder.add_tree(_convert_tree(est.tree_, _regression_value(est.tree_)))
    return builder.build()


def _convert_forest(est, label, feature_names, classification: bool):
    _check_fitted(est, "estimators_")
    _single_output_or_raise(est)
    names = _feature_columns(est, feature_names)
    if classification:
        # sklearn averages per-tree class distributions -> mean aggregation
        builder = RandomForestBuilder(
            label=label, task=Task.CLASSIFICATION, features=names,
            classes=[str(c) for c in est.classes_], winner_take_all=False)
        for t in est.estimators_:
            builder.add_tree(_convert_tree(t.tree_,
                                           _classification_value(t.tree_)))
    else:
        builder = RandomForestBuilder(label=label, task=Task.REGRESSION,
                                      features=names, winner_take_all=False)
        for t in est.estimators_:
            builder.add_tree(_convert_tree(t.tree_, _regression_value(t.tree_)))
    return builder.build()


def _gbt_init_pred(est, trees_by_class: list[list], lr: float,
                   n_features: int, K: int) -> np.ndarray:
    """The constant initial raw score, recovered through public API only:
    raw(x0) - lr * sum of tree outputs at x0, for a probe row x0."""
    x0 = np.zeros((1, n_features), np.float64)
    if est._estimator_type == "classifier":
        raw = np.atleast_2d(est.decision_function(x0))  # (1,) -> (1, 1)
        if raw.shape == (1, 1) and K == 1:
            raw = raw.reshape(1, 1)
    else:
        raw = est.predict(x0).reshape(1, 1)
    init = np.zeros(K, np.float32)
    for k in range(K):
        tree_sum = sum(float(t.predict(x0)[0]) for t in trees_by_class[k])
        init[k] = np.float32(raw[0, k if raw.shape[1] > 1 else 0]
                             - lr * tree_sum)
    return init


def _convert_gbt(est, label, feature_names, classification: bool):
    _check_fitted(est, "estimators_")
    names = _feature_columns(est, feature_names)
    lr = float(est.learning_rate)
    stages = est.estimators_              # (n_stages, K) DecisionTreeRegressors
    K = stages.shape[1]
    if classification:
        classes = [str(c) for c in est.classes_]
        builder = GradientBoostedTreesBuilder(
            label=label, task=Task.CLASSIFICATION, features=names,
            classes=classes)
        if builder.loss.out_dim != K:
            raise YdfError(
                f"GradientBoostingClassifier has {K} tree column(s) but "
                f"{len(classes)} classes map to {builder.loss.out_dim} "
                "output dimension(s); this estimator's loss layout is not "
                "supported.")
    else:
        builder = GradientBoostedTreesBuilder(label=label,
                                              task=Task.REGRESSION,
                                              features=names)
        if K != 1:
            raise YdfError(
                f"GradientBoostingRegressor with {K} tree columns is not "
                "supported (expected scalar regression).")
    trees_by_class: list[list] = [[] for _ in range(K)]
    for stage in stages:
        for k in range(K):
            trees_by_class[k].append(stage[k])
            builder.add_tree(
                _convert_tree(stage[k].tree_,
                              _regression_value(stage[k].tree_, scale=lr,
                                                logit=True)),
                tree_class=k if K > 1 else None)
    builder.init_pred = _gbt_init_pred(est, trees_by_class, lr,
                                       int(est.n_features_in_), K)
    return builder.build()


# ------------------------------------------------------------------ public API

def from_sklearn(estimator, *, label: str = "label",
                 feature_names: list[str] | None = None):
    """Convert a fitted sklearn tree-based estimator into a servable model.

    ``label`` names the synthesized label column (sklearn does not keep
    one); ``feature_names`` overrides the feature column names (defaults to
    ``feature_names_in_`` when the estimator was fitted on a DataFrame,
    else ``f0..f{n-1}``). The returned model predicts from raw feature
    dicts/column mappings like any trained model.
    """
    try:
        from sklearn import ensemble, tree  # noqa: F401
    except ImportError:
        raise YdfError(
            "from_sklearn requires scikit-learn, which is not installed. "
            "Solution: pip install scikit-learn (it is an optional "
            "dependency used only for model import).") from None

    kind = type(estimator).__name__
    table = {
        "DecisionTreeClassifier": (_convert_cart, True),
        "ExtraTreeClassifier": (_convert_cart, True),
        "DecisionTreeRegressor": (_convert_cart, False),
        "ExtraTreeRegressor": (_convert_cart, False),
        "RandomForestClassifier": (_convert_forest, True),
        "ExtraTreesClassifier": (_convert_forest, True),
        "RandomForestRegressor": (_convert_forest, False),
        "ExtraTreesRegressor": (_convert_forest, False),
        "GradientBoostingClassifier": (_convert_gbt, True),
        "GradientBoostingRegressor": (_convert_gbt, False),
    }
    if kind not in table:
        hist = "HistGradientBoosting" in kind
        raise YdfError(
            f"Cannot import a {kind}: unsupported estimator type"
            + (" (HistGradientBoosting stores bins, not raw-domain trees)"
               if hist else "")
            + f". Supported: {_SUPPORTED}.")
    fn, classification = table[kind]
    return fn(estimator, label, feature_names, classification)
