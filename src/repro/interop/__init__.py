"""Interop with other ML libraries (paper §2.1 "integration"): import
externally-trained forests into this runtime's compiled serving stack."""
from repro.interop.sklearn import from_sklearn  # noqa: F401
