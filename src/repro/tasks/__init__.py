"""The task subsystem (DESIGN.md §12): LambdaMART ranking, honest uplift
trees and isolation forests, all routed through the existing growers and
compiled serving engines.

Importing this package registers the task-specific learners; the RANKING
task needs no learner of its own — it is a loss on GRADIENT_BOOSTED_TREES
(repro.tasks.ranking.LambdaMARTLoss, wired in core/gbt.py).
"""
from repro.tasks.isolation import IsolationForestLearner  # noqa: F401
from repro.tasks.ranking import (  # noqa: F401
    GroupLayout,
    LambdaMARTLoss,
    group_aware_split,
    group_layout,
    lambda_grad_batched,
    lambda_grad_naive,
)
from repro.tasks.uplift import UpliftTreesLearner  # noqa: F401
