"""Isolation forests (DESIGN.md §12.3; Liu, Ting & Zhou 2008).

task=ANOMALY is the deliberate stress test of the engine seams: growth uses
NO histograms, NO gain scan and NO labels — each tree picks a random feature
and a uniform random threshold over the node's value range, on a small
per-tree row subsample (psi), until rows isolate or the depth cap
``ceil(log2 psi)`` hits. The splitter machinery is bypassed entirely; trees
are written straight into the ordinary Forest SoA, where every leaf stores
its PATH LENGTH ``depth + c(n)`` — so the compiled traversal engines
(vectorized/bucketed/leaf_path/pallas/naive) serve anomaly scores with zero
changes, bit-identically to each other.

All features are treated as ordinals (categorical codes included): every
node is a plain ``x >= threshold`` condition, the one kind every engine
implements identically.
"""
from __future__ import annotations

import math

import numpy as np

from repro.obs import build_training_logs
from repro.core.api import Learner, Task, YdfError, register_learner
from repro.core.hparams import IsolationForestHparams
from repro.core.models import IsolationForestModel, _as_vertical, raw_matrix
from repro.core.tree import empty_forest


def average_path_length(n: int) -> float:
    """c(n): expected BST search depth over n rows (Liu et al. eq. 1) —
    the unbuilt-subtree correction added to leaf path lengths."""
    if n <= 1:
        return 0.0
    if n == 2:
        return 1.0
    h = math.log(n - 1.0) + 0.5772156649015329  # harmonic via ln + gamma
    return 2.0 * h - 2.0 * (n - 1.0) / n


def _grow_iso_tree(forest, t: int, X: np.ndarray, rows: np.ndarray,
                   depth_cap: int, rng: np.random.Generator) -> int:
    """Random-split frontier growth of tree ``t`` in place; returns depth."""
    n_nodes = 1
    max_d = 0
    frontier = [(0, rows, 0)]           # LIFO: deterministic rng consumption
    while frontier:
        node, r, d = frontier.pop()
        max_d = max(max_d, d)
        xs = X[r]
        lo, hi = xs.min(axis=0), xs.max(axis=0)
        cands = np.flatnonzero(lo < hi)
        if d >= depth_cap or len(r) <= 1 or len(cands) == 0 \
                or n_nodes + 2 > forest.max_nodes:
            forest.leaf_value[t, node, 0] = d + average_path_length(len(r))
            continue
        f = int(cands[rng.integers(len(cands))])
        thr = float(rng.uniform(lo[f], hi[f]))
        go = xs[:, f] >= thr
        if not go.any() or go.all():
            forest.leaf_value[t, node, 0] = d + average_path_length(len(r))
            continue
        forest.feature[t, node] = f
        forest.threshold[t, node] = np.float32(thr)
        forest.left_child[t, node] = n_nodes
        # push right first so the LEFT child pops (and draws rng) first
        frontier.append((n_nodes + 1, r[go], d + 1))
        frontier.append((n_nodes, r[~go], d + 1))
        n_nodes += 2
    forest.n_nodes[t] = n_nodes
    return max_d


@register_learner("ISOLATION_FOREST")
class IsolationForestLearner(Learner):
    """Unsupervised: ``label`` is only used at evaluate() time (a 0/1
    anomaly indicator); when present in the training set it is excluded
    from the features, never required."""

    def __init__(self, label: str = "", task: Task = Task.ANOMALY, **kw):
        if task != Task.ANOMALY:
            raise YdfError(
                f"ISOLATION_FOREST only supports task=ANOMALY, got {task}.")
        super().__init__(label, task, **kw)

    def default_hparams(self) -> IsolationForestHparams:
        return IsolationForestHparams()

    def train(self, dataset, valid=None, checkpoint=None) -> IsolationForestModel:
        hp: IsolationForestHparams = self.hparams
        ds = _as_vertical(dataset)
        label = self.label if self.label in ds.spec.columns else None
        feats = ds.spec.feature_names(label)
        if not feats:
            raise YdfError("Isolation forest needs at least one feature.")
        X = raw_matrix(ds, feats)
        N = X.shape[0]
        psi = max(2, min(int(hp.subsample_count), N))
        depth_cap = int(hp.max_depth) or max(1, math.ceil(math.log2(psi)))
        forest = empty_forest(hp.num_trees, 2 * psi + 1, 1,
                              feature_names=feats)
        forest.tree_class = None
        depth = 0
        for t in range(hp.num_trees):
            rng = np.random.default_rng((self.seed & 0xFFFFFFFF, 104729, t))
            rows = rng.choice(N, size=psi, replace=False)
            depth = max(depth, _grow_iso_tree(forest, t, X, rows,
                                              depth_cap, rng))
        forest.depth = depth
        model = IsolationForestModel(
            c_psi=average_path_length(psi), forest=forest, spec=ds.spec,
            features=feats, label=self.label, task=self.task, classes=None)
        model.training_logs = build_training_logs(
            learner="isolation", num_trees=forest.n_trees,
            extra={"psi": psi, "depth_cap": depth_cap})
        return model
