"""Honest uplift trees (DESIGN.md §12.2; Rzepakowski & Jaroszewicz 2012).

task=UPLIFT rides the ordinary RF-style growth path: the ONLY new pieces are
the "uplift" splitter statistics layout ``[sum_y_treated, n_treated,
sum_y_control, n]`` and its Euclidean-distance gain ``n * (p_t - p_c)^2``
(splitters._score), plus leaves that store the local treatment effect
``p_t - p_c``. Everything else — binning, keyed feature sampling, lockstep
tree blocks, the compiled serving engines — is reused unchanged, which is
exactly the modularity claim the paper makes (§3.1).
"""
from __future__ import annotations

import numpy as np

from repro.obs import build_training_logs
from repro.core.api import Learner, Task, YdfError, register_learner
from repro.core.grower import GrowthParams, grow_trees, resolve_engine
from repro.core.hparams import UpliftHparams
from repro.core.models import UpliftModel, prepare_train_data
from repro.core.splitters import SplitterParams
from repro.core.tree import empty_forest


def uplift_leaf(s: np.ndarray) -> np.ndarray:
    """Leaf value = local treatment effect p_t - p_c; a leaf whose bag
    misses one arm has no estimate and predicts 0 (neutral)."""
    nt = s[1]
    nc = s[3] - s[1]
    if nt <= 0 or nc <= 0:
        return np.zeros(1, np.float32)
    return np.array([s[0] / nt - s[2] / nc], np.float32)


@register_learner("UPLIFT_TREES")
class UpliftTreesLearner(Learner):
    """Forest of honest uplift trees; predict() = estimated uplift."""

    def __init__(self, label: str, task: Task = Task.UPLIFT, **kw):
        if task != Task.UPLIFT:
            raise YdfError(
                f"UPLIFT_TREES only supports task=UPLIFT, got {task}. "
                "Solution: use RANDOM_FOREST/GRADIENT_BOOSTED_TREES for "
                "classification or regression.")
        super().__init__(label, task, **kw)

    def default_hparams(self) -> UpliftHparams:
        return UpliftHparams()

    def train(self, dataset, valid=None, checkpoint=None) -> UpliftModel:
        hp: UpliftHparams = self.hparams
        td = prepare_train_data(self, dataset, max_bins=hp.max_bins)
        N, F = td.binned.codes.shape
        t01 = td.treatment.astype(np.float64)
        base_stats = np.stack([td.y * t01, t01,
                               td.y * (1.0 - t01), np.ones(N)], 1)

        if hp.num_candidate_attributes == "SQRT":
            ratio = min(1.0, np.sqrt(F) / F)
        elif hp.num_candidate_attributes == "ALL":
            ratio = 1.0
        else:
            ratio = float(hp.num_candidate_attributes)
        sp = SplitterParams(stat_kind="uplift", min_examples=hp.min_examples,
                            num_candidate_ratio=ratio)
        gp = GrowthParams(max_depth=hp.max_depth, max_nodes=hp.max_num_nodes,
                          splitter=sp, engine=hp.growth_engine,
                          histogram_backend=hp.histogram_backend,
                          feature_sampling="keyed",
                          sampling_key=self.seed & 0xFFFFFFFF)
        engine_used, fallback = resolve_engine(gp, td.binned, False)
        block = max(1, int(hp.tree_parallelism))
        forest = empty_forest(hp.num_trees, hp.max_num_nodes, 1,
                              feature_names=td.features)
        forest.tree_class = None
        tree_rng = [np.random.default_rng((self.seed & 0xFFFFFFFF, 104729, t))
                    for t in range(hp.num_trees)]
        for b0 in range(0, hp.num_trees, block):
            ts = list(range(b0, min(b0 + block, hp.num_trees)))
            counts_b = []
            for t in ts:
                if hp.bootstrap:
                    counts_b.append(tree_rng[t].multinomial(
                        N, np.full(N, 1.0 / N)).astype(np.float64))
                else:
                    counts_b.append(np.ones(N))
            grow_trees(forest, ts, td.binned, td.X_raw,
                       [base_stats * c[:, None] for c in counts_b],
                       [c > 0 for c in counts_b], uplift_leaf, gp,
                       [tree_rng[t] for t in ts], td.num_lo, td.num_hi,
                       block=block)

        model = UpliftModel(
            treatment_col=getattr(hp, "treatment", "treatment"),
            forest=forest, spec=td.ds.spec, features=td.features,
            label=self.label, task=self.task, classes=None)
        model.training_logs = build_training_logs(
            learner="uplift", num_trees=forest.n_trees,
            growth_engine=engine_used, engine_fallback=fallback,
            extra={"tree_parallelism": block})
        return model
