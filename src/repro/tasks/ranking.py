"""LambdaMART ranking (DESIGN.md §12.1; Burges 2010).

The RANKING task rides the ordinary GBT learner: the only new piece is the
loss. Pairwise lambda gradients weighted by |ΔNDCG@k| are computed as ONE
padded ``(groups, max_group, max_group)`` tensor pass — no per-group Python
loop on the training path. The naive per-group loop lives here too, as the
differential oracle (tests assert bit-equality) and the benchmark baseline
(benchmarks/rank_bench.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


# ------------------------------------------------------------ group layout

@dataclass(frozen=True)
class GroupLayout:
    """Padded gather/scatter plan for per-group segment ops.

    ``pad_index[g, i]`` is a ROW index into the flat (N,) arrays; invalid
    (padding) slots repeat the group's last row and are masked out by
    ``pad_mask``. Scatter back with ``flat[pad_index[pad_mask]] =
    padded[pad_mask]`` — every valid slot maps to a distinct row.
    """
    n_rows: int
    sizes: np.ndarray       # (G,) group sizes
    pad_index: np.ndarray   # (G, m) int64 row indices
    pad_mask: np.ndarray    # (G, m) bool: True for real rows

    @property
    def n_groups(self) -> int:
        return len(self.sizes)

    @property
    def max_size(self) -> int:
        return self.pad_index.shape[1] if self.pad_index.ndim == 2 else 0

    def pad(self, flat: np.ndarray, fill: float = 0.0) -> np.ndarray:
        out = flat[self.pad_index].astype(np.float64)
        out[~self.pad_mask] = fill
        return out

    def unpad(self, padded: np.ndarray) -> np.ndarray:
        out = np.zeros(self.n_rows, np.float64)
        out[self.pad_index[self.pad_mask]] = padded[self.pad_mask]
        return out


def group_layout(groups: np.ndarray) -> GroupLayout:
    """Build the padded layout from per-row group ids (any order)."""
    groups = np.asarray(groups, np.int64).reshape(-1)
    order = np.argsort(groups, kind="stable")
    sg = groups[order]
    if len(sg) == 0:
        return GroupLayout(0, np.zeros(0, np.int64),
                           np.zeros((0, 0), np.int64),
                           np.zeros((0, 0), bool))
    starts = np.flatnonzero(np.r_[True, sg[1:] != sg[:-1]])
    sizes = np.diff(np.r_[starts, len(sg)]).astype(np.int64)
    m = int(sizes.max())
    ar = np.arange(m)
    pad_mask = ar[None, :] < sizes[:, None]
    idx = starts[:, None] + np.minimum(ar[None, :], sizes[:, None] - 1)
    return GroupLayout(len(groups), sizes, order[idx], pad_mask)


# ------------------------------------------------------- padded NDCG pieces

def _padded_rank_discounts(S: np.ndarray, valid: np.ndarray,
                           k: int) -> np.ndarray:
    """(G, m) rank discounts: d_i = 1/log2(1+rank_i) for rank_i <= k else 0,
    ranks 1-based by score descending with stable index tie-break. Padding
    slots sort last (score -> -inf) and get discount 0 via the rank cut."""
    s = np.where(valid, S, -np.inf)
    order = np.argsort(-s, axis=1, kind="stable")
    G, m = S.shape
    rank = np.empty((G, m), np.int64)
    np.put_along_axis(rank, order, np.broadcast_to(np.arange(1, m + 1), (G, m)),
                      axis=1)
    d = np.where(rank <= k, 1.0 / np.log2(1.0 + rank), 0.0)
    return np.where(valid, d, 0.0)


def _padded_idcg(gains: np.ndarray, valid: np.ndarray, k: int) -> np.ndarray:
    """(G,) ideal DCG@k from padded gains (2^rel - 1, zero on padding)."""
    g = np.where(valid, gains, -np.inf)
    top = -np.sort(-g, axis=1)[:, :k]
    disc = 1.0 / np.log2(np.arange(2, top.shape[1] + 2, dtype=np.float64))
    # elementwise * + last-axis sum (NOT a matmul): the same per-row
    # reduction order whether one group or G are in flight — bit-equality
    # between the batched pass and the per-group oracle depends on it
    return (np.where(np.isfinite(top), top, 0.0) * disc).sum(axis=1)


def ndcg_padded(S: np.ndarray, R: np.ndarray, valid: np.ndarray,
                k: int) -> float:
    """Mean NDCG@k over padded groups (IDCG==0 groups score 0)."""
    gains = np.where(valid, np.power(2.0, R) - 1.0, 0.0)
    disc = _padded_rank_discounts(S, valid, k)
    dcg = (gains * disc).sum(axis=1)
    idcg = _padded_idcg(gains, valid, k)
    return float(np.where(idcg > 0, dcg / np.maximum(idcg, 1e-300), 0.0).mean())


# -------------------------------------------------------- lambda gradients

def _lambda_pass(S: np.ndarray, R: np.ndarray, valid: np.ndarray,
                 k: int) -> tuple[np.ndarray, np.ndarray]:
    """The shared pairwise kernel over ALREADY-PADDED (G, m) tensors.

    For each ordered pair (i, j) with rel_i > rel_j (both valid):
      rho   = 1 / (1 + exp(s_i - s_j))              (RankNet crossing prob.)
      |ΔZ|  = |gain_i - gain_j| * |d_i - d_j| / IDCG (NDCG@k swap delta)
      g_i -= rho*|ΔZ|;  g_j += rho*|ΔZ|
      h_i += rho*(1-rho)*|ΔZ|;  h_j likewise
    Newton leaves (-Σg/Σh) then push winners' scores up.

    The naive per-group oracle calls this SAME kernel one group at a time;
    because every elementwise op and every reduction sees the same values in
    the same order per row, batched and looped results are bit-equal.
    """
    gains = np.where(valid, np.power(2.0, R) - 1.0, 0.0)
    disc = _padded_rank_discounts(S, valid, k)
    idcg = _padded_idcg(gains, valid, k)                       # (G,)
    inv_idcg = np.where(idcg > 0, 1.0 / np.maximum(idcg, 1e-300), 0.0)

    sdiff = S[:, :, None] - S[:, None, :]                      # s_i - s_j
    with np.errstate(over="ignore"):
        rho = 1.0 / (1.0 + np.exp(sdiff))
    dz = (np.abs(gains[:, :, None] - gains[:, None, :])
          * np.abs(disc[:, :, None] - disc[:, None, :])
          * inv_idcg[:, None, None])
    M = ((R[:, :, None] > R[:, None, :])
         & valid[:, :, None] & valid[:, None, :])
    lam = np.where(M, rho * dz, 0.0)
    hlam = np.where(M, rho * (1.0 - rho) * dz, 0.0)
    g = lam.sum(axis=1) - lam.sum(axis=2)       # loser gets +, winner gets -
    h = hlam.sum(axis=1) + hlam.sum(axis=2)
    return g, h


def lambda_grad_batched(scores: np.ndarray, rel: np.ndarray,
                        layout: GroupLayout, k: int = 5
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Flat (N,) lambda gradients/hessians via one padded (G, m, m) pass."""
    S = layout.pad(scores, fill=0.0)
    R = layout.pad(rel, fill=0.0)
    g, h = _lambda_pass(S, R, layout.pad_mask, k)
    return layout.unpad(g), layout.unpad(h)


def lambda_grad_naive(scores: np.ndarray, rel: np.ndarray,
                      layout: GroupLayout, k: int = 5,
                      pad_to: int | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """The per-group Python loop the batched pass replaces.

    ``pad_to`` pads every group to a common width before calling the shared
    kernel — the configuration the bit-equality test uses. With ``pad_to``
    None each group runs at its own (m_g, m_g) size: the honest baseline
    benchmarks/rank_bench.py times (scores then agree to 1e-12, not bits,
    since reduction shapes differ).
    """
    g_out = np.zeros(layout.n_rows, np.float64)
    h_out = np.zeros(layout.n_rows, np.float64)
    S = layout.pad(scores, fill=0.0)
    R = layout.pad(rel, fill=0.0)
    for gi in range(layout.n_groups):
        size = int(layout.sizes[gi])
        width = size if pad_to is None else max(pad_to, size)
        Sg = np.zeros((1, width)); Rg = np.zeros((1, width))
        Vg = np.zeros((1, width), bool)
        Sg[0, :size] = S[gi, :size]
        Rg[0, :size] = R[gi, :size]
        Vg[0, :size] = True
        gg, hg = _lambda_pass(Sg, Rg, Vg, k)
        rows = layout.pad_index[gi, :size]
        g_out[rows] = gg[0, :size]
        h_out[rows] = hg[0, :size]
    return g_out, h_out


# ----------------------------------------------------------------- the loss

@dataclass
class RankingActivation:
    """Picklable serving head (losses.Loss ``activation`` contract): raw
    GBT scores ARE the ranking scores."""

    def activation(self, scores: np.ndarray) -> np.ndarray:
        return np.asarray(scores)[:, 0]


class LambdaMARTLoss:
    """The GBT ``Loss`` for task=RANKING (drop-in for losses.Loss).

    Holds the train/validation group layouts; ``value`` reports
    ``1 - mean NDCG@k`` (lower is better, so LOSS_INCREASE early stopping
    works unchanged) and dispatches train vs valid by label-array identity.
    ``serving_head()`` strips the group arrays so pickled models stay small.
    """
    name = "LAMBDA_MART_NDCG"
    out_dim = 1

    def __init__(self, y_train: np.ndarray, layout_train: GroupLayout,
                 k: int = 5, y_valid: np.ndarray | None = None,
                 layout_valid: GroupLayout | None = None):
        self._y_train = y_train
        self._layout_train = layout_train
        self._y_valid = y_valid
        self._layout_valid = layout_valid
        self.k = int(k)

    def _layout_for(self, y) -> GroupLayout:
        if y is self._y_train:
            return self._layout_train
        if self._y_valid is not None and y is self._y_valid:
            return self._layout_valid
        raise ValueError(
            "LambdaMARTLoss saw a label array it has no group layout for; "
            "it is bound to the training/validation sets it was built with.")

    def init_pred(self, y, w):
        return np.zeros(1, np.float32)

    def grad_hess(self, pred, y, w):
        layout = self._layout_for(y)
        g, h = lambda_grad_batched(np.asarray(pred)[:, 0], y, layout, self.k)
        # ranking groups are the weighting unit; per-example w stays 1 —
        # guard h away from 0 so Newton leaves stay finite in pairless nodes
        return g[:, None], np.maximum(h, 1e-12)[:, None]

    def value(self, pred, y, w):
        layout = self._layout_for(y)
        S = layout.pad(np.asarray(pred)[:, 0])
        R = layout.pad(np.asarray(y, np.float64))
        return 1.0 - ndcg_padded(S, R, layout.pad_mask, self.k)

    def activation(self, scores):
        return np.asarray(scores)[:, 0]

    def serving_head(self):
        return RankingActivation()


def group_aware_split(groups: np.ndarray, ratio: float, seed: int
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Train/valid row split that keeps every group WHOLE (a group torn
    across the split would corrupt both its lambda pairs and its NDCG)."""
    groups = np.asarray(groups, np.int64)
    uniq = np.unique(groups)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(uniq))
    n_valid = int(round(len(uniq) * ratio))
    valid_groups = set(uniq[perm[:n_valid]].tolist())
    in_valid = np.isin(groups, list(valid_groups))
    return np.flatnonzero(~in_valid), np.flatnonzero(in_valid)
