"""Pure-jnp oracle for forest inference (lockstep traversal, gather-based).

Semantics match repro.core.tree.predict_raw on the SoA forest layout:
numerical 'x >= threshold', categorical bit-mask test (mask non-empty), depth
rounds of traversal, leaves self-loop. Oblique nodes are NOT supported here
(the engine layer routes oblique models elsewhere — lossy compilation, §3.7).

This is the simple-module ground truth (§2.3) for BOTH pallas kernels in
forest_infer.py — the small-forest one-tree-per-step kernel and the
tree-tiled serving kernel (DESIGN.md §5.2); it consumes the raw (T, M) SoA,
not the depth-packed layout, so packing/unpacking is under test too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

MASK_WORDS = 8


@functools.partial(jax.jit, static_argnames=("depth",))
def forest_predict_ref(X, feature, threshold, cat_mask, left_child, leaf_value,
                       depth: int):
    """X: (N, F) f32; feature/left_child: (T, M) i32; threshold: (T, M) f32;
    cat_mask: (T, M, W) uint32; leaf_value: (T, M, O) f32 -> (N, T, O)."""
    N, F = X.shape
    T, M = feature.shape

    def tree_fn(feat_t, thr_t, cat_t, lc_t, leaf_t):
        def body(node, _):
            f = feat_t[node]                       # (N,) gather
            f_safe = jnp.maximum(f, 0)
            x = jnp.take_along_axis(X, f_safe[:, None], axis=1)[:, 0]
            thr = thr_t[node]
            go_num = x >= thr
            code = jnp.clip(x.astype(jnp.int32), 0, MASK_WORDS * 32 - 1)
            words = cat_t[node]                    # (N, W)
            w = jnp.take_along_axis(words, (code[:, None] // 32), axis=1)[:, 0]
            bit = (w >> (code % 32).astype(jnp.uint32)) & 1
            go = jnp.where(words.any(-1), bit.astype(bool), go_num)
            lc = lc_t[node]
            nxt = lc + go.astype(jnp.int32)
            return jnp.where(lc >= 0, nxt, node), None

        node0 = jnp.zeros((N,), jnp.int32)
        node, _ = jax.lax.scan(body, node0, None, length=max(1, depth))
        return leaf_t[node]                        # (N, O)

    out = jax.vmap(tree_fn, in_axes=(0, 0, 0, 0, 0), out_axes=1)(
        feature, threshold, cat_mask, left_child, leaf_value)
    return out                                     # (N, T, O)
