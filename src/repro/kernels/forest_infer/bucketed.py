"""XLA-compiled depth-bucketed CPU traversal (DESIGN.md §10.2).

Compiles a ``tree.BucketedForest`` into ONE jit'd dispatch: every bucket is
scored by its own strategy and its own (shorter) round count, results are
concatenated and un-permuted back to original tree order inside the same
XLA program. On the CPU backend this is the fast path that beats both the
numpy ``compile_predict_raw`` engine and sklearn's C traversal — XLA fuses
each scan round's gather + compare + advance into one pass over the lanes,
where numpy issues them as separate full-array sweeps.

Strategies (tables built in ``repro.core.tree``):

* ``scan`` — flat global-id node tables with SENTINEL LEAVES: a leaf's slot
  holds feature = (virtual zero column), threshold = +inf, child = itself,
  so finished lanes self-loop through ``child[node] + (x >= thr)`` and the
  inner round needs no leaf mask, no select, no bounds fixup.
* ``leaf_path`` — evaluate all internal conditions in one vectorized pass,
  then count per-path correct decisions with a batched matmul over the
  signed path matrix; the true leaf is the unique argmax. No loop at all.

Bit-exactness: both strategies reproduce ``predict_naive`` decisions
exactly, including the numpy float->int categorical code cast (NaN and
non-finite values land on code 0, huge finite values clamp to the last
category bit — see ``_cat_code``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import MASK_WORDS, BucketedForest

_CODE_MAX = float(MASK_WORDS * 32 - 1)
_TWO63 = 9223372036854775808.0  # 2**63, exactly representable in float32


def _cat_code(x: jnp.ndarray) -> jnp.ndarray:
    """Categorical code cast, bit-identical to numpy's ``astype(int64)`` +
    ``clip(0, 255)`` for EVERY float32 input: numpy sends NaN/inf/|x|>=2^63
    to INT64_MIN (clips to 0) and truncates the rest toward zero. Clamping
    in float space first keeps the intermediate inside int32 range."""
    bad = jnp.isnan(x) | (x >= _TWO63) | (x <= -_TWO63)
    xf = jnp.clip(jnp.where(bad, 0.0, x), 0.0, _CODE_MAX)
    return xf.astype(jnp.int32)


def _scan_block(Xflat, row, tb, has_cat: bool, depth: int, F: int):
    """One bucket, scan strategy: ``depth`` lockstep rounds over the bucket's
    flat tables. ``row`` pre-multiplies the example index by the padded
    feature stride so the per-round gather is a single flat ``Xflat[f+row]``."""
    feat = jnp.where(tb["feature"] < 0, F, tb["feature"])  # leaf -> sentinel col
    thr, child, leaf = tb["threshold"], tb["child"], tb["leaf_value"]
    N = row.shape[0]
    node0 = jnp.broadcast_to(tb["root"][None, :], (N, tb["root"].shape[0]))
    if has_cat:
        iscat, catw = tb["is_cat"], tb["cat_words"].ravel()

    def body(node, _):
        x = Xflat[feat[node] + row]
        go = x >= thr[node]
        if has_cat:
            code = _cat_code(x)
            word = catw[node * MASK_WORDS + (code >> 5)]
            bit = (word >> (code & 31).astype(jnp.uint32)) & 1
            go = jnp.where(iscat[node], bit == 1, go)
        return child[node] + go.astype(jnp.int32), None

    node, _ = jax.lax.scan(body, node0, None, length=depth)
    return leaf[node]                                       # (N, k, O)


def _leaf_path_block(Xs, tb, has_cat: bool):
    """One bucket, leaf_path strategy: single-pass condition evaluation plus
    predicate-matrix scoring. ``hits - path_len`` is 0 exactly at the true
    leaf and <= -1 at every other real leaf (the first divergence decision
    is wrong), so argmax is the traversal result; all sums are small ints in
    float32, hence exact."""
    feat, thr, P = tb["feature"], tb["threshold"], tb["paths"]
    x = Xs[:, feat]                                         # (N, k, I)
    go = x >= thr[None]
    if has_cat:
        k, I = feat.shape
        code = _cat_code(x)
        flat_node = (jnp.arange(k * I, dtype=jnp.int32)
                     .reshape(k, I)[None] * MASK_WORDS)
        word = tb["cat_words"].reshape(-1)[flat_node + (code >> 5)]
        bit = (word >> (code & 31).astype(jnp.uint32)) & 1
        go = jnp.where(tb["is_cat"][None], bit == 1, go)
    C = go.astype(jnp.float32)
    hits = jnp.einsum("nki,kil->nkl", C, P) + tb["base"][None]
    sel = jnp.argmax(hits - tb["path_len"][None], axis=-1)  # (N, k)
    k = feat.shape[0]
    return tb["leaf_value"][jnp.arange(k)[None, :], sel]    # (N, k, O)


@partial(jax.jit, static_argnames=("spec",))
def _run(X, tables, inv, spec):
    """spec: per-bucket (strategy, depth, has_cat) tuples — static, so the
    bucket structure is baked into the XLA program; tables ride along as a
    pytree argument (no giant jaxpr constants, no retrace on new arrays)."""
    N, F = X.shape
    Xs = jnp.concatenate([X, jnp.zeros((N, 1), X.dtype)], axis=1)
    Xflat = Xs.ravel()
    row = (jnp.arange(N, dtype=jnp.int32) * (F + 1))[:, None]
    outs = []
    for (strategy, depth, has_cat), tb in zip(spec, tables):
        if strategy == "leaf_path":
            outs.append(_leaf_path_block(Xs, tb, has_cat))
        else:
            outs.append(_scan_block(Xflat, row, tb, has_cat, depth, F))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return jnp.take(out, inv, axis=1)                       # original tree order


_SCAN_KEYS = ("feature", "threshold", "child", "leaf_value", "root",
              "is_cat", "cat_words")
_PATH_KEYS = ("feature", "threshold", "is_cat", "cat_words", "paths",
              "base", "path_len", "leaf_value")


def build_bucketed_runner(bf: BucketedForest):
    """Upload a BucketedForest once and return
    ``run(X: (N, F) float32) -> (N, T, out_dim) float32 (numpy)``.

    The jit specializes on (bucket spec, N, F); ops.py caches the runner per
    forest so repeated serving calls at a stable batch shape hit the traced
    executable directly."""
    T, O = bf.n_trees, bf.out_dim
    if T == 0:
        return lambda X: np.zeros((np.asarray(X).shape[0], 0, O), np.float32)
    spec = tuple((b.strategy, b.depth, bool(b.tables["has_cat"]))
                 for b in bf.buckets)
    keys = {"scan": _SCAN_KEYS, "leaf_path": _PATH_KEYS}
    tables = tuple({k: jnp.asarray(b.tables[k])
                    for k in keys[b.strategy]} for b in bf.buckets)
    inv = jnp.asarray(bf.inv_order)

    def runner(X: np.ndarray) -> np.ndarray:
        X = np.ascontiguousarray(np.asarray(X), np.float32)
        if X.shape[0] == 0:
            return np.zeros((0, T, O), np.float32)
        return np.asarray(_run(X, tables, inv, spec))
    return runner
