"""Pallas TPU kernel: branch-free forest inference with VMEM-resident trees.

QuickScorer's insight (eliminate branch misprediction + random memory access)
restated for the TPU (DESIGN.md §2.2): all examples traverse all trees in
lockstep for `depth` rounds; per round, the per-lane "pointer chase" becomes
one-hot matmuls against the node table (M <= a few hundred nodes for GBT
trees), which the MXU executes at full tilt — no gathers, no branches:

    f      = onehot(node, M) @ feature_t        (TN, M) @ (M,)
    x      = sum(X * onehot(f, F), axis=1)      row-select on the VPU
    go     = x >= onehot(node, M) @ threshold_t (or category bit test)
    node   = onehot(node, M) @ left_child_t + go

Grid: (N // TN, T). Per step: X tile (TN, F) + one tree's arrays in VMEM.
VMEM at TN=256, F<=512, M<=512: X 512KB + onehot 512KB + tree ~20KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MASK_WORDS = 8


def _infer_kernel(x_ref, feat_ref, thr_ref, cat_ref, lc_ref, leaf_ref, out_ref,
                  *, depth: int, n_nodes: int):
    X = x_ref[...]                                    # (TN, F)
    feat = feat_ref[...][0].astype(jnp.float32)       # (M,)
    thr = thr_ref[...][0]                             # (M,)
    cat = cat_ref[...][0].astype(jnp.float32)         # (M, W)
    lc = lc_ref[...][0].astype(jnp.float32)           # (M,)
    leaf = leaf_ref[...][0]                           # (M, O)
    TN, F = X.shape
    M = n_nodes

    has_cat = (cat.sum(-1) > 0).astype(jnp.float32)   # (M,)
    node = jnp.zeros((TN,), jnp.float32)

    for _ in range(max(1, depth)):
        m_iota = jax.lax.broadcasted_iota(jnp.float32, (TN, M), 1)
        oh = (node[:, None] == m_iota).astype(jnp.float32)        # (TN, M)
        f = oh @ feat                                             # (TN,)
        t = oh @ thr
        l = oh @ lc
        is_cat = oh @ has_cat
        f_iota = jax.lax.broadcasted_iota(jnp.float32, (TN, F), 1)
        x_oh = (jnp.maximum(f, 0.0)[:, None] == f_iota).astype(jnp.float32)
        x = jnp.sum(X * x_oh, axis=1)                             # (TN,)
        go_num = (x >= t).astype(jnp.float32)
        # categorical bit test: word/bit via one-hot over mask words
        words = oh @ cat                                          # (TN, W)
        code = jnp.clip(x, 0.0, MASK_WORDS * 32 - 1).astype(jnp.int32)
        w_iota = jax.lax.broadcasted_iota(jnp.int32, (TN, MASK_WORDS), 1)
        w_oh = ((code[:, None] // 32) == w_iota).astype(jnp.float32)
        word = jnp.sum(words * w_oh, axis=1).astype(jnp.uint32)
        bit = ((word >> (code % 32).astype(jnp.uint32)) & 1).astype(jnp.float32)
        go = jnp.where(is_cat > 0, bit, go_num)
        nxt = l + go
        node = jnp.where(l >= 0, nxt, node)

    m_iota = jax.lax.broadcasted_iota(jnp.float32, (TN, M), 1)
    oh = (node[:, None] == m_iota).astype(jnp.float32)
    out_ref[:, 0, :] = oh @ leaf                                  # (TN, O)


@functools.partial(jax.jit, static_argnames=("depth", "tile_n", "interpret"))
def forest_predict_pallas(X, feature, threshold, cat_mask, left_child,
                          leaf_value, depth: int, tile_n: int = 256,
                          interpret: bool = False):
    """-> (N, T, O). Inputs as in ref.forest_predict_ref."""
    N, F = X.shape
    T, M = feature.shape
    O = leaf_value.shape[-1]
    TN = min(tile_n, N)
    pad = (-N) % TN
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
    Np = N + pad

    out = pl.pallas_call(
        functools.partial(_infer_kernel, depth=depth, n_nodes=M),
        grid=(Np // TN, T),
        in_specs=[
            pl.BlockSpec((TN, F), lambda i, t: (i, 0)),
            pl.BlockSpec((1, M), lambda i, t: (t, 0)),
            pl.BlockSpec((1, M), lambda i, t: (t, 0)),
            pl.BlockSpec((1, M, MASK_WORDS), lambda i, t: (t, 0, 0)),
            pl.BlockSpec((1, M), lambda i, t: (t, 0)),
            pl.BlockSpec((1, M, O), lambda i, t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TN, 1, O), lambda i, t: (i, t, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, T, O), jnp.float32),
        interpret=interpret,
    )(X.astype(jnp.float32), feature, threshold.astype(jnp.float32),
      cat_mask, left_child, leaf_value.astype(jnp.float32))
    return out[:N]
