"""Pallas TPU kernel: branch-free forest inference with VMEM-resident trees.

QuickScorer's insight (eliminate branch misprediction + random memory access)
restated for the TPU (DESIGN.md §2.2): all examples traverse all trees in
lockstep for `depth` rounds; per round, the per-lane "pointer chase" becomes
one-hot matmuls against the node table (M <= a few hundred nodes for GBT
trees), which the MXU executes at full tilt — no gathers, no branches:

    f      = onehot(node, M) @ feature_t        (TN, M) @ (M,)
    x      = sum(X * onehot(f, F), axis=1)      row-select on the VPU
    go     = x >= onehot(node, M) @ threshold_t (or category bit test)
    node   = onehot(node, M) @ left_child_t + go

Grid: (N // TN, T). Per step: X tile (TN, F) + one tree's arrays in VMEM.
VMEM at TN=256, F<=512, M<=512: X 512KB + onehot 512KB + tree ~20KB.

Two kernels live here (DESIGN.md §5.2):

  * ``forest_predict_pallas`` — the small-forest specialization above: one
    tree per grid step, whole node table addressed by a single (TN, M)
    one-hot. The (TN, M) intermediate caps M at the VMEM budget.
  * ``forest_predict_pallas_tiled`` — the serving kernel. Grid is
    (example_tile, tree_block) over a depth-packed forest
    (``core.tree.pack_by_depth``): each step holds a *block* of trees and
    the per-round one-hot is tiled over node chunks of ``node_tile``, so
    arbitrarily large node tables compile — the per-step VMEM high-water is
    (TN, node_tile) plus the block's (trimmed) tree arrays, independent of
    total forest size. The traversal loop is a ``fori_loop`` bounded by the
    *block's* max depth (§5.3): ragged forests pay max-depth-per-block, not
    global max depth. Categorical mask words travel as exact 16-bit halves
    (float32 carries < 2^24 exactly) instead of lossy whole-word floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MASK_WORDS = 8

# Gather matmuls carry INTEGER payloads (node ids, child indices, 16-bit
# mask halves) through float32: the MXU's default precision would round
# inputs to bfloat16 (exact only to 256) and silently corrupt traversal —
# pin the highest precision so f32 operands survive intact.
_dot = functools.partial(jnp.dot, precision=jax.lax.Precision.HIGHEST,
                         preferred_element_type=jnp.float32)


def _infer_kernel(x_ref, feat_ref, thr_ref, cat_ref, lc_ref, leaf_ref, out_ref,
                  *, depth: int, n_nodes: int):
    X = x_ref[...]                                    # (TN, F)
    feat = feat_ref[...][0].astype(jnp.float32)       # (M,)
    thr = thr_ref[...][0]                             # (M,)
    cat = cat_ref[...][0].astype(jnp.float32)         # (M, W)
    lc = lc_ref[...][0].astype(jnp.float32)           # (M,)
    leaf = leaf_ref[...][0]                           # (M, O)
    TN, F = X.shape
    M = n_nodes

    has_cat = (cat.sum(-1) > 0).astype(jnp.float32)   # (M,)
    node = jnp.zeros((TN,), jnp.float32)

    for _ in range(max(1, depth)):
        m_iota = jax.lax.broadcasted_iota(jnp.float32, (TN, M), 1)
        oh = (node[:, None] == m_iota).astype(jnp.float32)        # (TN, M)
        f = _dot(oh, feat)                                        # (TN,)
        t = _dot(oh, thr)
        l = _dot(oh, lc)
        is_cat = _dot(oh, has_cat)
        f_iota = jax.lax.broadcasted_iota(jnp.float32, (TN, F), 1)
        x_oh = (jnp.maximum(f, 0.0)[:, None] == f_iota).astype(jnp.float32)
        x = jnp.sum(X * x_oh, axis=1)                             # (TN,)
        go_num = (x >= t).astype(jnp.float32)
        # categorical bit test: word/bit via one-hot over mask words
        words = _dot(oh, cat)                                     # (TN, W)
        code = jnp.clip(x, 0.0, MASK_WORDS * 32 - 1).astype(jnp.int32)
        w_iota = jax.lax.broadcasted_iota(jnp.int32, (TN, MASK_WORDS), 1)
        w_oh = ((code[:, None] // 32) == w_iota).astype(jnp.float32)
        word = jnp.sum(words * w_oh, axis=1).astype(jnp.uint32)
        bit = ((word >> (code % 32).astype(jnp.uint32)) & 1).astype(jnp.float32)
        go = jnp.where(is_cat > 0, bit, go_num)
        nxt = l + go
        node = jnp.where(l >= 0, nxt, node)

    m_iota = jax.lax.broadcasted_iota(jnp.float32, (TN, M), 1)
    oh = (node[:, None] == m_iota).astype(jnp.float32)
    out_ref[:, 0, :] = _dot(oh, leaf)                             # (TN, O)


@functools.partial(jax.jit, static_argnames=("depth", "tile_n", "interpret"))
def forest_predict_pallas(X, feature, threshold, cat_mask, left_child,
                          leaf_value, depth: int, tile_n: int = 256,
                          interpret: bool = False):
    """-> (N, T, O). Inputs as in ref.forest_predict_ref."""
    N, F = X.shape
    T, M = feature.shape
    O = leaf_value.shape[-1]
    TN = min(tile_n, N)
    pad = (-N) % TN
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
    Np = N + pad

    out = pl.pallas_call(
        functools.partial(_infer_kernel, depth=depth, n_nodes=M),
        grid=(Np // TN, T),
        in_specs=[
            pl.BlockSpec((TN, F), lambda i, t: (i, 0)),
            pl.BlockSpec((1, M), lambda i, t: (t, 0)),
            pl.BlockSpec((1, M), lambda i, t: (t, 0)),
            pl.BlockSpec((1, M, MASK_WORDS), lambda i, t: (t, 0, 0)),
            pl.BlockSpec((1, M), lambda i, t: (t, 0)),
            pl.BlockSpec((1, M, O), lambda i, t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TN, 1, O), lambda i, t: (i, t, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, T, O), jnp.float32),
        interpret=interpret,
    )(X.astype(jnp.float32), feature, threshold.astype(jnp.float32),
      cat_mask, left_child, leaf_value.astype(jnp.float32))
    return out[:N]


# ===================================================================== §5.2
# Tree-tiled serving kernel: grid (example_tile, tree_block), node-chunked
# one-hots, per-block depth bound. Inputs come from core.tree.pack_by_depth.
# =========================================================================

def _infer_tiled_kernel(depth_ref, x_ref, feat_ref, thr_ref, cat_lo_ref,
                        cat_hi_ref, lc_ref, leaf_ref, out_ref, *,
                        node_tile: int):
    X = x_ref[...]                                    # (TN, F)
    TN, F = X.shape
    TB, M = feat_ref.shape[1], feat_ref.shape[2]
    n_tiles = M // node_tile
    d = depth_ref[0, 0]                               # this block's max depth
    f_iota = jax.lax.broadcasted_iota(jnp.float32, (TN, F), 1)
    mt_iota = jax.lax.broadcasted_iota(jnp.float32, (TN, node_tile), 1)
    w_iota = jax.lax.broadcasted_iota(jnp.int32, (TN, MASK_WORDS), 1)

    for j in range(TB):
        feat = feat_ref[0, j].astype(jnp.float32)     # (M,)
        thr = thr_ref[0, j]                           # (M,)
        lo = cat_lo_ref[0, j]                         # (M, W) f32, low 16 bits
        hi = cat_hi_ref[0, j]                         # (M, W) f32, high 16 bits
        lc = lc_ref[0, j].astype(jnp.float32)         # (M,)
        leaf = leaf_ref[0, j]                         # (M, O)
        has_cat = ((lo + hi).sum(-1) > 0).astype(jnp.float32)  # (M,)

        def chunk_oh(node, k):
            # one-hot over node chunk k — zero for nodes outside the chunk,
            # so summing chunk matmuls reconstructs the full-table gather
            return (node[:, None] == mt_iota + k * node_tile).astype(jnp.float32)

        def round_body(_, node):
            f = t = l = ic = jnp.zeros((TN,), jnp.float32)
            wlo = whi = jnp.zeros((TN, MASK_WORDS), jnp.float32)
            for k in range(n_tiles):
                oh = chunk_oh(node, k)                # (TN, node_tile)
                sl = slice(k * node_tile, (k + 1) * node_tile)
                f = f + _dot(oh, feat[sl])
                t = t + _dot(oh, thr[sl])
                l = l + _dot(oh, lc[sl])
                ic = ic + _dot(oh, has_cat[sl])
                wlo = wlo + _dot(oh, lo[sl])
                whi = whi + _dot(oh, hi[sl])
            x_oh = (jnp.maximum(f, 0.0)[:, None] == f_iota).astype(jnp.float32)
            x = jnp.sum(X * x_oh, axis=1)             # (TN,)
            go_num = (x >= t).astype(jnp.float32)
            code = jnp.clip(x, 0.0, MASK_WORDS * 32 - 1).astype(jnp.int32)
            w_oh = ((code[:, None] // 32) == w_iota).astype(jnp.float32)
            word = jnp.sum(wlo * w_oh, axis=1).astype(jnp.uint32) | \
                (jnp.sum(whi * w_oh, axis=1).astype(jnp.uint32) << 16)
            bit = ((word >> (code % 32).astype(jnp.uint32)) & 1).astype(jnp.float32)
            go = jnp.where(ic > 0, bit, go_num)
            nxt = l + go
            return jnp.where(l >= 0, nxt, node)

        node = jax.lax.fori_loop(0, d, round_body,
                                 jnp.zeros((TN,), jnp.float32))
        acc = jnp.zeros((TN, leaf.shape[-1]), jnp.float32)
        for k in range(n_tiles):
            sl = slice(k * node_tile, (k + 1) * node_tile)
            acc = acc + _dot(chunk_oh(node, k), leaf[sl])
        out_ref[:, j, :] = acc


@functools.partial(jax.jit,
                   static_argnames=("node_tile", "tile_n", "interpret"))
def forest_predict_pallas_tiled(X, feature, threshold, cat_mask, left_child,
                                leaf_value, block_depth, node_tile: int = 128,
                                tile_n: int = 256, interpret: bool = False):
    """Tree-tiled lockstep traversal over a depth-packed forest (§5.2).

    X: (N, F) f32; feature/left_child: (B, TB, M) i32; threshold (B, TB, M)
    f32; cat_mask (B, TB, M, W) u32; leaf_value (B, TB, M, O) f32;
    block_depth (B, 1) i32. M must be a multiple of ``node_tile``
    (``pack_by_depth`` guarantees it). -> (N, B*TB, O) in *packed* tree
    order; callers restore the original order with PackedForest.inv_order.
    """
    N, F = X.shape
    B, TB, M = feature.shape
    O = leaf_value.shape[-1]
    mt = min(node_tile, M)
    if M % mt:
        raise ValueError(f"node capacity {M} is not a multiple of the node "
                         f"tile {mt}; pack the forest with pack_by_depth")
    TN = min(tile_n, N) if N else tile_n
    pad = (-N) % TN
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
    Np = N + pad
    # exact 16-bit halves: a float32 one-hot matmul carries < 2^24 losslessly
    cat_lo = (cat_mask & jnp.uint32(0xFFFF)).astype(jnp.float32)
    cat_hi = (cat_mask >> jnp.uint32(16)).astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_infer_tiled_kernel, node_tile=mt),
        grid=(Np // TN, B),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, b: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((TN, F), lambda i, b: (i, 0)),
            pl.BlockSpec((1, TB, M), lambda i, b: (b, 0, 0)),
            pl.BlockSpec((1, TB, M), lambda i, b: (b, 0, 0)),
            pl.BlockSpec((1, TB, M, MASK_WORDS), lambda i, b: (b, 0, 0, 0)),
            pl.BlockSpec((1, TB, M, MASK_WORDS), lambda i, b: (b, 0, 0, 0)),
            pl.BlockSpec((1, TB, M), lambda i, b: (b, 0, 0)),
            pl.BlockSpec((1, TB, M, O), lambda i, b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TN, TB, O), lambda i, b: (i, b, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, B * TB, O), jnp.float32),
        interpret=interpret,
    )(block_depth, X.astype(jnp.float32), feature,
      threshold.astype(jnp.float32), cat_lo, cat_hi, left_child,
      leaf_value.astype(jnp.float32))
    return out[:N]
