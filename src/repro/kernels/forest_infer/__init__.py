from repro.kernels.forest_infer.ops import forest_predict  # noqa: F401
