"""jit'd wrapper: Forest SoA -> device arrays -> kernel dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.forest_infer.forest_infer import forest_predict_pallas
from repro.kernels.forest_infer.ref import forest_predict_ref


def forest_predict(forest, X: np.ndarray, impl: str | None = None):
    """forest: repro.core.tree.Forest; X: (N, F) raw-value matrix.
    -> (N, T, out_dim) per-tree outputs."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "interpret"
    args = (jnp.asarray(X, jnp.float32),
            jnp.asarray(forest.feature), jnp.asarray(forest.threshold),
            jnp.asarray(forest.cat_mask), jnp.asarray(forest.left_child),
            jnp.asarray(forest.leaf_value))
    depth = int(max(1, forest.depth))
    if impl == "ref":
        return forest_predict_ref(*args, depth=depth)
    if impl == "pallas":
        return forest_predict_pallas(*args, depth=depth)
    if impl == "interpret":
        return forest_predict_pallas(*args, depth=depth, interpret=True)
    raise ValueError(f"unknown impl {impl!r}")
