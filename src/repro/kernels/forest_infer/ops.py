"""Dispatch + per-forest device caches for the inference kernels.

The serving contract (DESIGN.md §5.1) is that a compiled forest is uploaded
to the device ONCE: ``forest_predict`` keeps a small id-keyed cache mapping a
live Forest to (a) its raw SoA device arrays (ref kernel) and (b) its
depth-packed device layout (tiled kernel, §5.2–§5.3), so repeat predictions
do zero host-to-device transfers and zero re-packing. Entries are validated
against a weakref (id reuse after GC cannot alias) and evicted LRU.

impls: "pallas" (tiled, compiled), "interpret" (tiled, interpret mode —
the CPU correctness path), "ref" (jnp gather oracle), "pallas_single"
(legacy one-tree-per-step kernel; node capacity must fit its VMEM budget).
"""
from __future__ import annotations

import weakref
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.forest_infer.forest_infer import (
    forest_predict_pallas,
    forest_predict_pallas_tiled,
)
from repro.kernels.forest_infer.ref import forest_predict_ref

_CACHE: "OrderedDict[int, tuple]" = OrderedDict()
_CACHE_CAP = 8


def _forest_cache(forest) -> dict:
    """Per-forest payload dict, id-keyed + weakref-validated, LRU-capped.
    A weakref finalizer evicts the entry the moment the forest is GC'd, so
    a retired model's device buffers free immediately instead of lingering
    until LRU pressure pushes them out."""
    key = id(forest)
    ent = _CACHE.get(key)
    if ent is not None and ent[0]() is forest:
        _CACHE.move_to_end(key)
        return ent[1]
    payload: dict = {}

    def _evict(_ref, key=key):
        _CACHE.pop(key, None)

    _CACHE[key] = (weakref.ref(forest, _evict), payload)
    _CACHE.move_to_end(key)
    while len(_CACHE) > _CACHE_CAP:
        _CACHE.popitem(last=False)
    return payload


def device_soa(forest) -> tuple:
    """Raw Forest SoA as device arrays, uploaded once per forest."""
    c = _forest_cache(forest)
    if "soa" not in c:
        c["soa"] = (jnp.asarray(forest.feature), jnp.asarray(forest.threshold),
                    jnp.asarray(forest.cat_mask),
                    jnp.asarray(forest.left_child),
                    jnp.asarray(forest.leaf_value))
    return c["soa"]


def device_packed(forest) -> tuple:
    """Depth-packed device layout (pack_by_depth output), built/uploaded once.
    Returns (feature, threshold, cat_mask, left_child, leaf_value,
    block_depth, inv_order) with the first six on device."""
    c = _forest_cache(forest)
    if "packed" not in c:
        from repro.core.tree import pack_by_depth
        p = pack_by_depth(forest)
        c["packed"] = (jnp.asarray(p.feature), jnp.asarray(p.threshold),
                       jnp.asarray(p.cat_mask), jnp.asarray(p.left_child),
                       jnp.asarray(p.leaf_value), jnp.asarray(p.block_depth),
                       jnp.asarray(p.inv_order))
    return c["packed"]


def bucketed_runner(forest, strategy: str | None = None):
    """Compiled depth-bucketed runner (DESIGN.md §10), built/uploaded once
    per (forest, strategy). ``strategy`` None lets the per-bucket cost model
    choose ("leaf_path" only where the matmul is ~free — an MXU backend);
    "scan"/"leaf_path" force one strategy for every bucket (benchmarks,
    differential tests)."""
    c = _forest_cache(forest)
    key = f"bucketed:{strategy or 'auto'}"
    if key not in c:
        from repro.core.tree import pack_depth_buckets
        from repro.kernels.forest_infer.bucketed import build_bucketed_runner
        bf = pack_depth_buckets(forest, strategy=strategy,
                                matmul_cheap=(jax.default_backend() == "tpu"))
        c[key] = build_bucketed_runner(bf)
    return c[key]


def forest_predict_bucketed(forest, X: np.ndarray,
                            strategy: str | None = None) -> np.ndarray:
    """Depth-bucketed prediction: (N, F) raw-value matrix ->
    (N, T, out_dim) numpy, original tree order. Same EngineFailure contract
    as ``forest_predict``."""
    from repro.core.api import EngineFailure
    try:
        return bucketed_runner(forest, strategy)(X)
    except (EngineFailure, KeyboardInterrupt):
        raise
    except Exception as e:
        name = "leaf_path" if strategy == "leaf_path" else "bucketed"
        raise EngineFailure(
            f"forest_infer impl {name!r} failed on a "
            f"({np.shape(X)[0] if np.ndim(X) else '?'}, ...) batch: "
            f"{type(e).__name__}: {e}", engine=name) from e


def forest_predict(forest, X: np.ndarray, impl: str | None = None):
    """forest: repro.core.tree.Forest; X: (N, F) raw-value matrix.
    -> (N, T, out_dim) per-tree outputs (original tree order).

    Kernel/dispatch errors surface as a typed ``EngineFailure`` naming the
    impl (DESIGN.md §9.1): a serving front-end must be able to tell "the
    pallas engine died on this batch" apart from a schema or caller error
    without parsing XLA tracebacks. Caller errors (unknown impl) stay
    ``ValueError``.
    """
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "interpret"
    if impl not in ("ref", "pallas_single", "pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    from repro.core.api import EngineFailure
    try:
        Xd = jnp.asarray(X, jnp.float32)
        depth = int(max(1, forest.depth))
        if impl == "ref":
            return forest_predict_ref(Xd, *device_soa(forest), depth=depth)
        if impl == "pallas_single":
            return forest_predict_pallas(Xd, *device_soa(forest), depth=depth)
        feat, thr, cat, lc, leaf, bd, inv = device_packed(forest)
        out = forest_predict_pallas_tiled(
            Xd, feat, thr, cat, lc, leaf, bd,
            interpret=(impl == "interpret"))
        return jnp.take(out, inv, axis=1)
    except (EngineFailure, KeyboardInterrupt):
        raise
    except Exception as e:
        raise EngineFailure(
            f"forest_infer impl {impl!r} failed on a "
            f"({np.shape(X)[0] if np.ndim(X) else '?'}, ...) batch: "
            f"{type(e).__name__}: {e}", engine=impl) from e
