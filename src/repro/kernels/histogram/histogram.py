"""Pallas TPU kernel: per-node gradient histograms as one-hot MXU matmuls.

The CPU/GPU formulation of histogram building is a scatter-add; TPUs have no
fast scatter, but they have a 128x128 systolic MXU. The TPU-native insight
(DESIGN.md §2.1): express the histogram as

    hist[n, b, s] = onehot_node[i, n] * onehot_bin[i, b] * stats[i, s]
                  = (onehot_node^T @ (onehot_bin * stats_s))[n, b]

i.e. S matmuls of (n_nodes, TN) @ (TN, B) per feature — fully MXU-resident.

Grid: (F, N // TN). Example tiles accumulate into the same per-feature output
block (revisited across the trailing grid dim; TPU grid steps are sequential,
so read-modify-write on out_ref is well-defined).

VMEM per step (TN=512, B=256, S=4, n_nodes=32):
    codes tile 512B + stats 8KB + onehot_bin 512KB + onehot_node 64KB
    + out block 128KB  ->  ~0.7 MB  (fits far under the ~16MB/core budget)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(codes_ref, stats_ref, node_ref, out_ref, *, n_nodes: int,
                 n_bins: int, n_stats: int):
    i = pl.program_id(1)  # example-tile index (trailing, sequential)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...].astype(jnp.int32)[:, 0]      # (TN,)
    node = node_ref[...].astype(jnp.int32)              # (TN,)
    stats = stats_ref[...]                              # (TN, S)
    active = (node >= 0).astype(jnp.float32)
    TN = codes.shape[0]

    bin_iota = jax.lax.broadcasted_iota(jnp.int32, (TN, n_bins), 1)
    onehot_bin = (codes[:, None] == bin_iota).astype(jnp.float32)   # (TN, B)
    node_iota = jax.lax.broadcasted_iota(jnp.int32, (TN, n_nodes), 1)
    onehot_node = (node[:, None] == node_iota).astype(jnp.float32)  # (TN, nodes)
    onehot_node = onehot_node * active[:, None]

    acc = out_ref[...]                                  # (1, nodes, B, S)
    for s in range(n_stats):
        weighted = onehot_bin * stats[:, s][:, None]    # (TN, B)
        h = jax.lax.dot_general(
            onehot_node, weighted, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)         # (nodes, B) MXU
        acc = acc.at[0, :, :, s].add(h)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "tile_n",
                                             "interpret"))
def histogram_pallas(codes: jax.Array, stats: jax.Array, node_of: jax.Array,
                     n_nodes: int, n_bins: int = 256, tile_n: int = 512,
                     interpret: bool = False) -> jax.Array:
    """codes: (N, F) uint8; stats: (N, S) f32; node_of: (N,) int32 (-1 =
    inactive). -> (n_nodes, F, B, S) f32."""
    N, F = codes.shape
    S = stats.shape[1]
    TN = min(tile_n, N)
    pad = (-N) % TN
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
        stats = jnp.pad(stats, ((0, pad), (0, 0)))
        node_of = jnp.pad(node_of, (0, pad), constant_values=-1)
    Np = N + pad

    out = pl.pallas_call(
        functools.partial(_hist_kernel, n_nodes=n_nodes, n_bins=n_bins,
                          n_stats=S),
        grid=(F, Np // TN),
        in_specs=[
            pl.BlockSpec((TN, 1), lambda f, i: (i, f)),          # codes column
            pl.BlockSpec((TN, S), lambda f, i: (i, 0)),          # stats tile
            pl.BlockSpec((TN,), lambda f, i: (i,)),              # node tile
        ],
        out_specs=pl.BlockSpec((1, n_nodes, n_bins, S),
                               lambda f, i: (f, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((F, n_nodes, n_bins, S), jnp.float32),
        interpret=interpret,
    )(codes, stats.astype(jnp.float32), node_of.astype(jnp.int32))
    return out.transpose(1, 0, 2, 3)                     # (nodes, F, B, S)
