"""Fused Pallas TPU kernel: histogram accumulation + gain scan + argmax.

PR 1's training path built the full ``(nodes, F, B, S)`` histogram on device,
shipped it to the host, and scanned gains in numpy — the kernel was off the
critical path because the transfer dwarfed the accumulation (DESIGN.md §6).
This kernel keeps the whole per-feature pipeline in VMEM:

    1. accumulate hist[w, b, s] as one-hot MXU matmuls (as in histogram.py),
    2. cumulative-sum the bins with an upper-triangular MXU matmul,
    3. score left/right partitions per split position (gh / class / moment
       stat layouts, §3.8), mask by min_examples,
    4. argmax over bins, then fold into the running per-slot best across
       features (grid-sequential read-modify-write, strict ``>`` so ties keep
       the lowest feature index — numpy argmax semantics).

Only the ``(n_slots, 3)`` best-(gain, feature, split_bin) tensor ever leaves
the kernel; the ``(nodes, F, B, S)`` histogram lives and dies in VMEM scratch.

Numerical (ordered-bin) conditions only: categorical splitters need a
Fisher-order argsort, which the device engine runs as jnp inside the same jit
(grower_device.py). Gain math lives in ``score_stats`` and is shared with the
jnp reference path so kernel and oracle stay formula-identical.

Grid: (kf, N // TN) — feature-major, example tiles inner (sequential on TPU,
so the scratch accumulator and the cross-feature running best are
well-defined read-modify-write).

VMEM per step (TN=512, W=256, B=256, S=4): codes 512B + stats 8KB + one-hots
~600KB + hist scratch 1MB + (1, W) outputs — well under the ~16MB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu is import-safe on CPU; only used for scratch memory spaces
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = pltpu.VMEM
except Exception:  # pragma: no cover - very old jax
    _SCRATCH = None

NEG_INF = -1e30  # matches splitters.NEG_INF


def score_stats(stats, kind: str, l2: float):
    """jnp mirror of splitters._score on (..., S) stat vectors. Gain of a
    split = score(L) + score(R) - score(P)."""
    if kind == "gh":
        g, h = stats[..., 0], stats[..., 1]
        return 0.5 * jnp.square(g) / (h + l2 + 1e-12)
    if kind == "class":
        counts = stats[..., :-1]
        n = stats[..., -1]
        tot = jnp.maximum(n, 1e-12)[..., None]
        p = counts / tot
        ent = -(p * jnp.log(jnp.maximum(p, 1e-12))).sum(-1)
        return -n * ent
    if kind == "moment":
        sy, n = stats[..., 0], stats[..., -1]
        return jnp.square(sy) / jnp.maximum(n, 1e-12)
    raise ValueError(kind)


def _numerical_gains(hist, parent, kind: str, l2: float, min_examples: int):
    """Split-position gains for ordered bins. hist: (..., B, S); parent:
    (..., S). Position b means 'bins <= b go left' i.e. split_bin = b + 1;
    the last position (nothing right) is masked. Returns (..., B) gains."""
    B = hist.shape[-2]
    left = jnp.cumsum(hist, axis=-2)                       # (..., B, S)
    right = parent[..., None, :] - left
    g = (score_stats(left, kind, l2) + score_stats(right, kind, l2)
         - score_stats(parent, kind, l2)[..., None])
    ok = ((left[..., -1] >= min_examples)
          & (right[..., -1] >= min_examples)
          & (jax.lax.broadcasted_iota(jnp.int32, g.shape, g.ndim - 1) < B - 1))
    return jnp.where(ok, g, NEG_INF)


def _fused_kernel(codes_ref, stats_ref, slot_ref, gain_ref, feat_ref, bin_ref,
                  hist_ref, *, n_slots: int, n_bins: int, n_stats: int,
                  n_tiles: int, kind: str, l2: float, min_examples: int):
    j = pl.program_id(0)      # feature index (outer)
    i = pl.program_id(1)      # example-tile index (inner, sequential)

    @pl.when(i == 0)
    def _init_hist():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    codes = codes_ref[...].astype(jnp.int32)[:, 0]              # (TN,)
    slot = slot_ref[...].astype(jnp.int32)                      # (TN,)
    stats = stats_ref[...]                                      # (TN, S)
    active = (slot >= 0).astype(jnp.float32)
    TN = codes.shape[0]

    bin_iota = jax.lax.broadcasted_iota(jnp.int32, (TN, n_bins), 1)
    onehot_bin = (codes[:, None] == bin_iota).astype(jnp.float32)
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (TN, n_slots), 1)
    onehot_slot = (slot[:, None] == slot_iota).astype(jnp.float32)
    onehot_slot = onehot_slot * active[:, None]

    acc = hist_ref[...]                                         # (W, B, S)
    for s in range(n_stats):
        weighted = onehot_bin * stats[:, s][:, None]            # (TN, B)
        h = jax.lax.dot_general(
            onehot_slot, weighted, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                 # (W, B) MXU
        acc = acc.at[:, :, s].add(h)
    hist_ref[...] = acc

    @pl.when(i == n_tiles - 1)
    def _scan():
        hist = hist_ref[...]                                    # (W, B, S)
        parent = hist.sum(axis=1)                               # (W, S)
        # cumulative sum over bins as an upper-triangular MXU matmul:
        # cum[w, b] = sum_{b' <= b} hist[w, b']
        r = jax.lax.broadcasted_iota(jnp.int32, (n_bins, n_bins), 0)
        c = jax.lax.broadcasted_iota(jnp.int32, (n_bins, n_bins), 1)
        tri = (r <= c).astype(jnp.float32)                      # (B, B)
        left = jnp.stack(
            [jax.lax.dot_general(hist[:, :, s], tri, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             for s in range(n_stats)], axis=-1)                 # (W, B, S)
        right = parent[:, None, :] - left
        g = (score_stats(left, kind, l2) + score_stats(right, kind, l2)
             - score_stats(parent, kind, l2)[:, None])          # (W, B)
        pos = jax.lax.broadcasted_iota(jnp.int32, (n_slots, n_bins), 1)
        ok = ((left[:, :, -1] >= min_examples)
              & (right[:, :, -1] >= min_examples)
              & (pos < n_bins - 1))
        g = jnp.where(ok, g, NEG_INF)
        bi = jnp.argmax(g, axis=1).astype(jnp.int32)            # (W,)
        gb = jnp.max(g, axis=1)
        prev_g = jnp.where(j == 0, NEG_INF, gain_ref[...][0])
        prev_f = jnp.where(j == 0, -1, feat_ref[...][0])
        prev_b = jnp.where(j == 0, 0, bin_ref[...][0])
        better = gb > prev_g    # strict: ties keep the lowest feature index
        gain_ref[...] = jnp.where(better, gb, prev_g)[None]
        feat_ref[...] = jnp.where(better, j, prev_f).astype(jnp.int32)[None]
        bin_ref[...] = jnp.where(better, bi + 1,
                                 prev_b).astype(jnp.int32)[None]


@functools.partial(jax.jit, static_argnames=(
    "n_slots", "n_bins", "kind", "l2", "min_examples", "tile_n", "interpret"))
def fused_split_pallas(codes: jax.Array, stats: jax.Array, slot_of: jax.Array,
                       n_slots: int, n_bins: int = 256, *, kind: str = "gh",
                       l2: float = 0.0, min_examples: int = 5,
                       tile_n: int = 512, interpret: bool = False):
    """codes: (N, kf) uint8 (numerical bin codes, one column per candidate
    feature); stats: (N, S) f32; slot_of: (N,) int32 in [-1, n_slots).
    -> (gain (n_slots,) f32, feature-column (n_slots,) i32, split_bin
    (n_slots,) i32). feature == -1 when no position was scoreable."""
    N, kf = codes.shape
    S = stats.shape[1]
    TN = min(tile_n, max(N, 1))
    pad = (-N) % TN
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
        stats = jnp.pad(stats, ((0, pad), (0, 0)))
        slot_of = jnp.pad(slot_of, (0, pad), constant_values=-1)
    n_tiles = (N + pad) // TN

    kernel = functools.partial(
        _fused_kernel, n_slots=n_slots, n_bins=n_bins, n_stats=S,
        n_tiles=n_tiles, kind=kind, l2=float(l2),
        min_examples=int(min_examples))
    out_shape = [
        jax.ShapeDtypeStruct((1, n_slots), jnp.float32),
        jax.ShapeDtypeStruct((1, n_slots), jnp.int32),
        jax.ShapeDtypeStruct((1, n_slots), jnp.int32),
    ]
    out_spec = pl.BlockSpec((1, n_slots), lambda j, i: (0, 0))
    gain, feat, sbin = pl.pallas_call(
        kernel,
        grid=(kf, n_tiles),
        in_specs=[
            pl.BlockSpec((TN, 1), lambda j, i: (i, j)),      # one feature col
            pl.BlockSpec((TN, S), lambda j, i: (i, 0)),
            pl.BlockSpec((TN,), lambda j, i: (i,)),
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=out_shape,
        scratch_shapes=[_SCRATCH((n_slots, n_bins, S), jnp.float32)],
        interpret=interpret,
    )(codes, stats.astype(jnp.float32), slot_of.astype(jnp.int32))
    return gain[0], feat[0], sbin[0]
