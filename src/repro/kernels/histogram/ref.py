"""Pure-jnp oracle for the gradient-histogram kernel.

hist[n, f, b, s] = sum over examples i with node_of[i]==n and codes[i,f]==b
of stats[i, s].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def histogram_ref(codes: jax.Array, stats: jax.Array, node_of: jax.Array,
                  n_nodes: int, n_bins: int) -> jax.Array:
    """codes: (N, F) uint8/int32; stats: (N, S) f32; node_of: (N,) int32 with
    -1 = inactive. -> (n_nodes, F, B, S) f32."""
    N, F = codes.shape
    S = stats.shape[1]
    B = n_bins
    active = node_of >= 0
    node = jnp.where(active, node_of, 0)
    # flat segment id per (example, feature): (node * F + f) * B + code
    seg = (node[:, None] * F + jnp.arange(F)[None, :]) * B + codes.astype(jnp.int32)
    w = jnp.where(active, 1.0, 0.0)[:, None] * stats          # (N, S)
    contrib = w[:, None, :] * jnp.ones((1, F, 1), stats.dtype)  # (N, F, S)
    flat = jax.ops.segment_sum(contrib.reshape(N * F, S), seg.reshape(N * F),
                               num_segments=n_nodes * F * B)
    return flat.reshape(n_nodes, F, B, S)
