"""Pure-jnp oracle for the gradient-histogram kernel.

hist[n, f, b, s] = sum over examples i with node_of[i]==n and codes[i,f]==b
of stats[i, s].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def histogram_ref(codes: jax.Array, stats: jax.Array, node_of: jax.Array,
                  n_nodes: int, n_bins: int) -> jax.Array:
    """codes: (N, F) uint8/int32; stats: (N, S) f32; node_of: (N,) int32 with
    -1 = inactive. -> (n_nodes, F, B, S) f32."""
    N, F = codes.shape
    S = stats.shape[1]
    B = n_bins
    active = node_of >= 0
    node = jnp.where(active, node_of, 0)
    # flat segment id per (example, feature): (node * F + f) * B + code
    seg = (node[:, None] * F + jnp.arange(F)[None, :]) * B + codes.astype(jnp.int32)
    w = jnp.where(active, 1.0, 0.0)[:, None] * stats          # (N, S)
    contrib = w[:, None, :] * jnp.ones((1, F, 1), stats.dtype)  # (N, F, S)
    flat = jax.ops.segment_sum(contrib.reshape(N * F, S), seg.reshape(N * F),
                               num_segments=n_nodes * F * B)
    return flat.reshape(n_nodes, F, B, S)


def fused_split_ref(codes: jax.Array, stats: jax.Array, slot_of: jax.Array,
                    n_slots: int, n_bins: int = 256, *, kind: str = "gh",
                    l2: float = 0.0, min_examples: int = 5):
    """Pure-jnp oracle for the fused hist+gain kernel (fused.py): builds the
    full histogram, runs the ordered-bin gain scan, and reduces to per-slot
    best-(gain, feature-column, split_bin). Tie-breaking matches the kernel
    and the numpy scan: flat argmax picks the lowest (feature, bin)."""
    from repro.kernels.histogram.fused import NEG_INF, _numerical_gains

    kf = codes.shape[1]
    hist = histogram_ref(codes, stats.astype(jnp.float32), slot_of,
                         n_slots, n_bins)                     # (W, kf, B, S)
    parent = hist.sum(axis=2)                                 # (W, kf, S)
    g = _numerical_gains(hist, parent, kind, float(l2),
                         int(min_examples))                   # (W, kf, B)
    flat = g.reshape(n_slots, kf * n_bins)
    bi = jnp.argmax(flat, axis=1)
    gain = jnp.max(flat, axis=1)
    feat = (bi // n_bins).astype(jnp.int32)
    sbin = (bi % n_bins).astype(jnp.int32) + 1
    feat = jnp.where(gain <= NEG_INF, -1, feat)
    return gain, feat, sbin
