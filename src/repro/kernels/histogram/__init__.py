from repro.kernels.histogram.ops import fused_best_split, histogram  # noqa: F401
