from repro.kernels.histogram.ops import histogram  # noqa: F401
