"""jit'd dispatch wrapper for the histogram op.

impl:
  * "pallas"    — compiled Pallas kernel (TPU target)
  * "interpret" — Pallas kernel body interpreted on CPU (correctness path)
  * "ref"       — pure-jnp oracle (segment_sum)
  * "auto"/None — pallas on TPU, ref elsewhere

Inputs may be numpy or jax arrays — the training path
(``repro.core.hist_backend.PallasHistogramBackend``) feeds host numpy arrays
straight in. ``n_nodes`` is a static shape argument: callers that invoke this
in a loop over growing frontiers should pad it (the training backend pads to
the next power of two) to bound jit recompilation.
"""
from __future__ import annotations

import jax

from repro.kernels.histogram.histogram import histogram_pallas
from repro.kernels.histogram.ref import histogram_ref


def histogram(codes, stats, node_of, n_nodes: int, n_bins: int = 256,
              impl: str | None = None):
    if impl is None or impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return histogram_ref(codes, stats, node_of, n_nodes, n_bins)
    if impl == "pallas":
        return histogram_pallas(codes, stats, node_of, n_nodes, n_bins)
    if impl == "interpret":
        return histogram_pallas(codes, stats, node_of, n_nodes, n_bins,
                                interpret=True)
    raise ValueError(f"unknown impl {impl!r}")


def fused_best_split(codes, stats, slot_of, n_slots: int, n_bins: int = 256,
                     *, kind: str = "gh", l2: float = 0.0,
                     min_examples: int = 5, impl: str | None = None):
    """Fused histogram + ordered-bin gain scan + per-slot argmax (DESIGN.md
    §6.1). codes: (N, kf) uint8 numerical bin codes; -> per-slot
    (gain, feature-column, split_bin), the tiny ``(nodes, 3)`` output that
    replaces the full ``(nodes, F, B, S)`` histogram on the training path."""
    from repro.kernels.histogram.fused import fused_split_pallas
    from repro.kernels.histogram.ref import fused_split_ref

    if impl is None or impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return fused_split_ref(codes, stats, slot_of, n_slots, n_bins,
                               kind=kind, l2=l2, min_examples=min_examples)
    if impl in ("pallas", "interpret"):
        return fused_split_pallas(codes, stats, slot_of, n_slots, n_bins,
                                  kind=kind, l2=l2, min_examples=min_examples,
                                  interpret=(impl == "interpret"))
    raise ValueError(f"unknown impl {impl!r}")
