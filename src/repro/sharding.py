"""Logical-axis sharding rules (MaxText-style) mapped onto the production mesh.

Params and activations are annotated with *logical* axis names; a rules table
maps each logical axis to zero or more physical mesh axes. This gives
DP/FSDP/TP/EP/SP from one table, and lets the perf loop swap sharding schemes
without touching model code.
"""
from __future__ import annotations

from typing import Iterable, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rules. Each logical axis maps to a tuple of mesh axes (or ()).
# "pod" only exists on the multi-pod mesh; missing axes are dropped at
# resolution time, so one table serves both meshes.
TRAIN_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "embed": ("data",),        # FSDP shard of params + optimizer state
    "embed_act": (),           # activations: d_model dim left unsharded
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("data",),       # EP: experts sharded over data (all-to-all dispatch)
    "expert_mlp": ("model",),
    "expert_group": ("pod", "data"),
    "kv_len": (),
    "layers": (),
    "conv": (),
    "state": (),
}

SERVE_RULES: dict[str, tuple[str, ...]] = dict(
    TRAIN_RULES,
    batch=("pod", "data"),
    embed=("data",),           # weight-gathered serving; revisit per-arch in perf loop
    # KV-cache LENGTH sharded over 'model' (flash-decoding style): validated
    # in §Perf hillclimb #2 — decode_32k caches for large-KV archs do not fit
    # HBM otherwise (e.g. qwen1.5-32b: 321 -> 21 GiB/dev). Non-divisible
    # lengths (whisper cross-attn 1500) fall back to replicated automatically.
    kv_len=("model",),
)

# long-context decode: shard the KV/cache length over 'data' (flash-decoding).
LONG_DECODE_RULES: dict[str, tuple[str, ...]] = dict(
    SERVE_RULES,
    batch=(),
    kv_len=("pod", "data"),
    embed=("data",),
)


def rules_for(kind: str, *, long_context: bool = False) -> dict[str, tuple[str, ...]]:
    if kind == "train":
        return dict(TRAIN_RULES)
    if long_context:
        return dict(LONG_DECODE_RULES)
    return dict(SERVE_RULES)


def resolve_spec(logical: Sequence[str | None], mesh: Mesh,
                 rules: Mapping[str, tuple[str, ...]],
                 shape: Sequence[int] | None = None) -> P:
    """Map logical axis names to a PartitionSpec valid on `mesh`.

    If `shape` is given, mesh axes that do not divide the dimension size are
    dropped (jit in_shardings require exact divisibility): e.g. kv_heads=2
    cannot shard over model=16 and falls back to replication on that dim.
    """
    used: set[str] = set()
    parts = []
    for i, ax in enumerate(logical):
        if ax is None:
            parts.append(None)
            continue
        cand = [a for a in rules.get(ax, ()) if a in mesh.axis_names and a not in used]
        phys = []
        prod = 1
        for a in cand:
            n = mesh.shape[a]
            if shape is not None and shape[i] % (prod * n) != 0:
                continue
            phys.append(a)
            prod *= n
        used.update(phys)
        if not phys:
            parts.append(None)
        elif len(phys) == 1:
            parts.append(phys[0])
        else:
            parts.append(tuple(phys))
    return P(*parts)


def named_sharding(logical: Sequence[str | None], mesh: Mesh,
                   rules: Mapping[str, tuple[str, ...]],
                   shape: Sequence[int] | None = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical, mesh, rules, shape))


def with_logical_constraint(x: jax.Array, logical: Sequence[str | None], mesh: Mesh | None,
                            rules: Mapping[str, tuple[str, ...]] | None) -> jax.Array:
    """Apply a sharding constraint if running under a mesh; no-op otherwise."""
    if mesh is None or rules is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(logical, mesh, rules, x.shape))


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def tree_shardings(logical_tree, mesh: Mesh, rules: Mapping[str, tuple[str, ...]],
                   shape_tree=None):
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings.

    `shape_tree` (ShapeDtypeStructs or arrays, same structure) enables
    divisibility-aware resolution — always pass it for jit in_shardings.
    """
    if shape_tree is None:
        return jax.tree.map(lambda logical: named_sharding(logical, mesh, rules),
                            logical_tree, is_leaf=_is_axes_leaf)
    shapes, treedef = jax.tree.flatten(shape_tree)
    axes = treedef.flatten_up_to(logical_tree)
    out = [named_sharding(a, mesh, rules, s.shape) for a, s in zip(axes, shapes)]
    return treedef.unflatten(out)
