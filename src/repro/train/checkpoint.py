"""Checkpointed, interruption-safe training (DESIGN.md §11).

The paper's *safety of use* principle says a library failure must never
silently cost the user their work: YDF's distributed training checkpoints
the boosting state so an interrupted or partially-failed run resumes instead
of restarting. This module is that discipline for the whole training stack,
with **bit-identical resume** as the invariant: a run interrupted at any
tree boundary and resumed produces the exact same forest — byte for byte —
as an uninterrupted run.

Three layers:

* **Atomic checkpoint store** — ``write_checkpoint``/``latest_checkpoint``.
  A checkpoint is a directory ``ckpt-<trees>`` holding ``state.pkl`` (the
  payload) and ``manifest.json`` (format version, trees-done, the learner's
  train_config, the encoded-training-data fingerprint, and a content sha1
  per payload file). Writes go write-temp → fsync → rename, so a crash
  mid-write can never produce a half-visible checkpoint; reads verify the
  sha1s and ROLL BACK to the previous good checkpoint when a file is
  corrupt or truncated (the bad directory is renamed ``*.corrupt``, never
  silently trusted).

* **CheckpointSession** — the seam learners drive at tree boundaries:
  ``resume()`` (verifies the dataset fingerprint and training config before
  trusting any state — resuming against the wrong dataset is REJECTED, not
  silently mis-trained), ``save()`` (every ``every_n_trees``, retention
  ``keep_last``), and ``should_stop()`` (cooperative interruption: a
  SIGINT/SIGTERM captured by the session, or a ``CheckpointPolicy.cancel``
  callback). On interruption the learner finalizes a *valid, servable*
  truncated model instead of raising mid-write. Every resume / rollback /
  checkpoint / interruption is recorded as an event, surfaced in
  ``model.training_logs["resilience"]``.

* **resume_training(dir, dataset)** — rebuilds the learner from the
  manifest's train_config and continues it against the same checkpoint
  directory.

What a checkpoint captures (the bit-identical-resume closure): trees grown
so far (forest SoA slices), cached boosting predictions (train + validation),
early-stopping bookkeeping, and the host RNG stream state
(``Generator.bit_generator.state`` snapshotted at the tree boundary — GBT's
bagging and stream-sampled growth draws continue mid-stream exactly where
they stopped; RF's per-tree keyed streams need no state, they are re-derived
from ``(seed, tree)``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.obs import trace
from repro.core.api import YdfError

CHECKPOINT_FORMAT_VERSION = 1

_CKPT_PREFIX = "ckpt-"
_STATE_FILE = "state.pkl"
_MANIFEST_FILE = "manifest.json"


# ---------------------------------------------------------------- policy

@dataclass(frozen=True)
class CheckpointPolicy:
    """Where and how often training checkpoints (DESIGN.md §11.1).

    ``cancel`` is the cooperative-interruption probe: polled at every tree
    boundary; returning True stops training AFTER the current tree, saves a
    final checkpoint and finalizes a servable truncated model. SIGINT /
    SIGTERM are captured to the same effect while a session is active.

    ``every_seconds`` adds a wall-clock cadence ON TOP of the tree cadence:
    a save becomes due when EITHER ``every_n_trees`` trees have grown since
    the last checkpoint OR ``every_seconds`` have elapsed — but it still
    only fires at the same tree/block boundaries the training loop already
    drives, never mid-tree. ``clock`` is the injectable time source
    (monotonic seconds; tests substitute a FakeClock) and is deliberately
    NOT part of the manifest.
    """
    directory: str
    every_n_trees: int = 10
    every_seconds: float | None = None
    keep_last: int = 2
    cancel: Callable[[], bool] | None = None
    clock: Callable[[], float] = time.monotonic

    def to_manifest(self) -> dict:
        return {"every_n_trees": int(self.every_n_trees),
                "every_seconds": (None if self.every_seconds is None
                                  else float(self.every_seconds)),
                "keep_last": int(self.keep_last)}


def as_policy(checkpoint) -> CheckpointPolicy | None:
    if checkpoint is None or isinstance(checkpoint, CheckpointPolicy):
        return checkpoint
    if isinstance(checkpoint, (str, os.PathLike)):
        return CheckpointPolicy(os.fspath(checkpoint))
    raise YdfError(
        f"checkpoint must be a CheckpointPolicy or a directory path, got "
        f"{type(checkpoint).__name__}. Example: "
        "learner.train(ds, checkpoint=CheckpointPolicy('/tmp/ck', every_n_trees=10)).")


# ---------------------------------------------------------------- store

def _sha1(path: str) -> str:
    h = hashlib.sha1()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:          # platforms without directory fds
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def checkpoint_name(trees_done: int) -> str:
    return f"{_CKPT_PREFIX}{trees_done:08d}"


def write_checkpoint(directory: str, trees_done: int, payload: dict, *,
                     config: dict, fingerprint: str, done: bool = False,
                     policy: CheckpointPolicy | None = None,
                     keep_last: int = 2) -> str:
    """Atomically write ``<directory>/ckpt-<trees_done>``.

    Protocol: payload + manifest land in a ``.tmp-<pid>`` sibling, every
    file is fsync'ed, then ONE rename publishes the checkpoint. A crash at
    any point leaves either the previous state or a complete new checkpoint
    — never a torn one. Old checkpoints beyond ``keep_last`` are removed
    AFTER the new one is durable.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, checkpoint_name(trees_done))
    tmp = f"{final}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        import shutil
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    state_path = os.path.join(tmp, _STATE_FILE)
    with open(state_path, "wb") as f:
        pickle.dump(payload, f)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "format_version": CHECKPOINT_FORMAT_VERSION,
        "trees_done": int(trees_done),
        "done": bool(done),
        "config": config,
        "data_fingerprint": fingerprint,
        "files": {_STATE_FILE: _sha1(state_path)},
        "policy": (policy.to_manifest() if policy is not None
                   else {"every_n_trees": 10, "every_seconds": None,
                         "keep_last": keep_last}),
    }
    mpath = os.path.join(tmp, _MANIFEST_FILE)
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):      # same-boundary overwrite: replace whole
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(directory)
    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int) -> None:
    entries = sorted(_list_checkpoints(directory))
    for _, name in entries[:-max(1, keep_last)]:
        import shutil
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)


def _list_checkpoints(directory: str) -> list[tuple[int, str]]:
    out = []
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return out
    for name in names:
        if not name.startswith(_CKPT_PREFIX) or "." in name:
            continue                      # skips *.tmp-* and *.corrupt
        try:
            out.append((int(name[len(_CKPT_PREFIX):]), name))
        except ValueError:
            continue
    return out


def _validate(path: str) -> dict | None:
    """Manifest of a checkpoint directory iff every content sha1 matches;
    None when missing/corrupt/truncated."""
    try:
        with open(os.path.join(path, _MANIFEST_FILE)) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if not isinstance(manifest, dict) or \
            manifest.get("format_version", 1 << 30) > CHECKPOINT_FORMAT_VERSION:
        return None
    for fname, digest in manifest.get("files", {}).items():
        fpath = os.path.join(path, fname)
        if not os.path.exists(fpath) or _sha1(fpath) != digest:
            return None
    return manifest


def latest_checkpoint(directory: str
                      ) -> tuple[dict | None, dict | None, list[str]]:
    """(payload, manifest, rolled_back_names) of the newest VALID checkpoint.

    Newer checkpoints that fail validation (corrupt manifest, sha1 mismatch
    from a truncated write) are renamed ``<name>.corrupt`` — evidence kept,
    never re-trusted — and the previous good checkpoint wins.
    """
    rolled_back: list[str] = []
    for _, name in sorted(_list_checkpoints(directory), reverse=True):
        path = os.path.join(directory, name)
        manifest = _validate(path)
        if manifest is None:
            quarantine = path + ".corrupt"
            if os.path.exists(quarantine):
                import shutil
                shutil.rmtree(quarantine, ignore_errors=True)
            os.rename(path, quarantine)
            rolled_back.append(name)
            continue
        try:
            with open(os.path.join(path, _STATE_FILE), "rb") as f:
                payload = pickle.load(f)
        except Exception:                # sha1 passed but unpickle failed
            os.rename(path, path + ".corrupt")
            rolled_back.append(name)
            continue
        return payload, manifest, rolled_back
    return None, None, rolled_back


# ---------------------------------------------------------------- forest I/O

_FOREST_KEYS = ("feature", "threshold", "split_bin", "cat_mask", "left_child",
                "leaf_value", "n_nodes", "split_gain", "obl_weights",
                "obl_features", "tree_class")


def forest_payload(forest, n_trees: int) -> dict:
    """Copy the first ``n_trees`` trees of a Forest SoA into a plain dict
    (the grown-so-far state; independent of the preallocated capacity)."""
    out: dict[str, Any] = {"depth": int(forest.depth)}
    for k in _FOREST_KEYS:
        a = getattr(forest, k)
        out[k] = None if a is None else np.copy(a[:n_trees])
    return out


def restore_forest(forest, payload: dict) -> int:
    """Write a ``forest_payload`` back into a preallocated Forest. Returns
    the number of trees restored."""
    n = payload["feature"].shape[0]
    for k in _FOREST_KEYS:
        v = payload[k]
        a = getattr(forest, k)
        if v is None or a is None:
            continue
        a[:n] = v
    forest.depth = max(forest.depth, payload["depth"])
    return n


# ---------------------------------------------------------------- session

def _normalize_config(config: dict) -> dict:
    return json.loads(json.dumps(config))


class CheckpointSession:
    """The tree-boundary checkpoint seam a training loop drives.

    Use as a context manager so SIGINT/SIGTERM become cooperative
    interruptions (flag checked at tree boundaries) instead of mid-write
    crashes; previous handlers are restored on exit and the signal is
    re-raised if it arrived outside the training window's control (second
    Ctrl-C still kills).
    """

    def __init__(self, policy: CheckpointPolicy, *, config: dict,
                 fingerprint: str):
        self.policy = policy
        self.config = _normalize_config(config)
        self.fingerprint = fingerprint
        self.events: list[dict] = []
        self.last_saved = 0
        # wall-clock cadence baseline: session open counts as "last save"
        # so a slow first tree cannot trigger an instant checkpoint storm
        self._last_save_time = policy.clock()
        self._interrupted = False
        self._prev_handlers: dict[int, Any] = {}

    # -- signals ------------------------------------------------------
    def __enter__(self) -> "CheckpointSession":
        if threading.current_thread() is threading.main_thread():
            for sig in (signal.SIGINT, signal.SIGTERM):
                try:
                    self._prev_handlers[sig] = signal.signal(
                        sig, self._on_signal)
                except (ValueError, OSError):
                    pass
        return self

    def __exit__(self, *exc) -> None:
        for sig, h in self._prev_handlers.items():
            try:
                signal.signal(sig, h)
            except (ValueError, OSError):
                pass
        self._prev_handlers.clear()

    def _on_signal(self, signum, frame) -> None:
        self._interrupted = True
        self.events.append({"event": "signal", "signal": int(signum)})

    # -- lifecycle ----------------------------------------------------
    def should_stop(self) -> bool:
        if self._interrupted:
            return True
        cb = self.policy.cancel
        if cb is not None and cb():
            self._interrupted = True
            self.events.append({"event": "cancel"})
            return True
        return False

    @property
    def interrupted(self) -> bool:
        return self._interrupted

    def resume(self) -> dict | None:
        """The newest valid checkpoint's payload, or None for a fresh run.

        Rejects (YdfError with directions, nothing loaded) when the stored
        encoded-data fingerprint or training config does not match — a
        checkpoint must never silently continue onto the wrong dataset or
        under different hyper-parameters.
        """
        t0 = self.policy.clock()
        with trace.span("checkpoint/restore", directory=self.policy.directory):
            payload, manifest, rolled_back = latest_checkpoint(
                self.policy.directory)
        # quarantines newer than the loaded checkpoint count as rollbacks
        # even when an earlier reader (resume_training's manifest pre-read)
        # did the renaming before this session opened
        base = manifest["trees_done"] if manifest is not None else -1
        try:
            for name in os.listdir(self.policy.directory):
                if not name.endswith(".corrupt"):
                    continue
                stem = name[: -len(".corrupt")]
                try:
                    n = int(stem[len(_CKPT_PREFIX):])
                except ValueError:
                    continue
                if n > base and stem not in rolled_back:
                    rolled_back.append(stem)
        except FileNotFoundError:
            pass
        for name in rolled_back:
            self.events.append({"event": "rollback", "checkpoint": name,
                                "reason": "corrupt or truncated"})
        if payload is None:
            return None
        if manifest["data_fingerprint"] != self.fingerprint:
            raise YdfError(
                f"Checkpoint at {self.policy.directory!r} was written for a "
                "DIFFERENT dataset (encoded-data fingerprint "
                f"{manifest['data_fingerprint'][:12]}… != "
                f"{self.fingerprint[:12]}…). Resuming would silently mis-train. "
                "Solutions: (1) pass the original training dataset, or (2) "
                "point checkpoint.directory at a fresh directory to train "
                "from scratch.")
        if manifest["config"] != self.config:
            raise YdfError(
                f"Checkpoint at {self.policy.directory!r} was written under a "
                "different training configuration (learner / hyper-parameters "
                "/ seed changed). Bit-identical resume is impossible. "
                "Solutions: (1) recreate the learner with the original "
                "configuration (see resume_training), or (2) use a fresh "
                "checkpoint directory.")
        self.last_saved = manifest["trees_done"]
        self.events.append({"event": "resume",
                            "trees_done": manifest["trees_done"],
                            "done": manifest["done"],
                            "restore_s": self.policy.clock() - t0})
        return payload

    def save(self, trees_done: int, payload: dict, *, done: bool = False,
             force: bool = False) -> bool:
        """Checkpoint iff a cadence is due or forced: ``every_n_trees``
        trees since the last save, OR ``every_seconds`` of wall clock
        (policy.clock) since the last save. Returns True when a checkpoint
        was written. Called at tree/block boundaries only, so the wall-clock
        cadence can never tear a tree."""
        if trees_done <= 0:
            return False
        due_trees = (trees_done - self.last_saved
                     >= self.policy.every_n_trees)
        es = self.policy.every_seconds
        due_time = (es is not None
                    and self.policy.clock() - self._last_save_time >= es)
        if not (force or due_trees or due_time):
            return False
        t0 = self.policy.clock()
        with trace.span("checkpoint/save", trees_done=trees_done, done=done):
            write_checkpoint(self.policy.directory, trees_done, payload,
                             config=self.config, fingerprint=self.fingerprint,
                             done=done, policy=self.policy,
                             keep_last=self.policy.keep_last)
        self.last_saved = trees_done
        self._last_save_time = self.policy.clock()
        self.events.append({"event": "checkpoint", "trees_done": trees_done,
                            "done": done,
                            "save_s": self._last_save_time - t0})
        return True


def open_session(checkpoint, config: dict,
                 fingerprint: str) -> CheckpointSession | None:
    """Session from a ``Learner.train(checkpoint=...)`` argument (None, a
    directory path, or a CheckpointPolicy)."""
    policy = as_policy(checkpoint)
    if policy is None:
        return None
    return CheckpointSession(policy, config=config, fingerprint=fingerprint)


# ---------------------------------------------------------------- resume

def resume_training(directory: str, dataset, valid=None):
    """Continue an interrupted training run from its checkpoint directory.

    The learner is rebuilt from the manifest's cross-API train_config
    (§3.10), so the caller supplies only the (same) dataset. The finished
    model is bit-identical to an uninterrupted run (tested).
    """
    _, manifest, _ = latest_checkpoint(directory)
    if manifest is None:
        raise YdfError(
            f"No valid checkpoint found in {directory!r}. A checkpoint "
            "directory is created by learner.train(..., checkpoint="
            "CheckpointPolicy(dir)). Solutions: (1) check the path, or (2) "
            "start a fresh training run with a checkpoint policy.")
    config = manifest["config"]
    if "learner" not in config:
        raise YdfError(
            f"Checkpoint at {directory!r} was not written by a Learner "
            f"(config: {sorted(config)}). Use the owning trainer's resume "
            "path (e.g. DistributedGBT.fit(checkpoint=...)).")
    from repro.core.api import make_learner
    learner = make_learner(config)
    pol = manifest.get("policy", {})
    policy = CheckpointPolicy(directory,
                              every_n_trees=pol.get("every_n_trees", 10),
                              every_seconds=pol.get("every_seconds"),
                              keep_last=pol.get("keep_last", 2))
    return learner.train(dataset, valid, checkpoint=policy)
