"""Train-step factory.

Builds the jit-able ``train_step(state, batch) -> (state, metrics)`` for any
assigned architecture, with:
  * gradient accumulation (``cfg.grad_accum`` microbatches via ``lax.scan``),
  * global-norm clipping + AdamW/Adafactor update,
  * logical-axis shardings for state and batch (FSDP over 'data', TP over
    'model', DP over 'pod'+'data') suitable both for live execution and for
    AOT ``.lower().compile()`` dry-runs from ShapeDtypeStructs.

State is a plain dict: {"params", "slots", "step"}.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.models.layers import Ctx
from repro.models.params import init_params, schema_axes, schema_shapes
from repro.optim import make_optimizer, opt_slot_specs
from repro.optim.optimizers import clip_by_global_norm
from repro.sharding import tree_shardings


# ----------------------------------------------------------------- state

def train_state_specs(cfg: ModelConfig):
    """(ShapeDtypeStruct tree, logical-axes tree) for the full train state."""
    sch = lm.model_schema(cfg)
    p_specs = schema_shapes(sch, cfg.param_dtype)
    p_axes = schema_axes(sch)
    s_specs, s_axes = opt_slot_specs(cfg, p_specs, p_axes)
    specs = {"params": p_specs, "slots": s_specs,
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    axes = {"params": p_axes, "slots": s_axes, "step": ()}
    return specs, axes


def init_train_state(key, cfg: ModelConfig):
    sch = lm.model_schema(cfg)
    params = init_params(key, sch, cfg.param_dtype)
    opt = make_optimizer(cfg)
    return {"params": params, "slots": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


# ----------------------------------------------------------------- step

@dataclass(frozen=True)
class TrainStepBundle:
    step_fn: Callable          # (state, batch) -> (state, metrics)
    state_specs: Any
    state_shardings: Any
    batch_shardings: Any

    def jitted(self, donate: bool = True):
        return jax.jit(
            self.step_fn,
            in_shardings=(self.state_shardings, self.batch_shardings),
            donate_argnums=(0,) if donate else (),
        )


def _split_microbatches(batch: Mapping[str, jax.Array], n: int):
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return {k: r(v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh=None, rules=None) -> TrainStepBundle:
    ctx = Ctx(cfg, mesh, rules)
    opt = make_optimizer(cfg)
    accum = max(1, cfg.grad_accum)

    def loss_for(params, batch):
        return lm.loss_fn(params, batch, ctx)

    def train_step(state, batch):
        params = state["params"]
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_for, has_aux=True)(params, batch)
        else:
            micro = _split_microbatches(batch, accum)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_for, has_aux=True)(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = {}

        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        new_params, new_slots = opt.update(grads, state["slots"], params, state["step"])
        new_state = {"params": new_params, "slots": new_slots, "step": state["step"] + 1}
        out_metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        for k, v in (metrics or {}).items():
            out_metrics[k] = v.astype(jnp.float32)
        return new_state, out_metrics

    state_specs, state_axes = train_state_specs(cfg)
    state_sh = batch_sh = None
    if mesh is not None and rules is not None:
        state_sh = tree_shardings(state_axes, mesh, rules, state_specs)
        batch_sh = tree_shardings(lm.batch_axes(cfg, shape), mesh, rules,
                                  lm.batch_spec(cfg, shape))
    return TrainStepBundle(train_step, state_specs, state_sh, batch_sh)
