"""GPipe-style pipeline parallelism over a mesh axis via shard_map+ppermute.

Not the default path for the assigned configs (the pod axis serves as extra
DP; see DESIGN.md §3 Parallelism for the rationale) but implemented and
tested so a >2-pod deployment can move layers onto a 'stage' axis when the
per-pod model no longer fits.

Schedule: classic GPipe fill-drain over M microbatches and S stages:
T = M + S - 1 slots; stage s works on microbatch (t - s) at slot t;
activations hop stage->stage+1 with ``ppermute`` each slot. Bubble fraction
= (S-1)/T, reported by ``pipeline_efficiency``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_efficiency(n_micro: int, n_stages: int) -> float:
    return n_micro / (n_micro + n_stages - 1)


def make_pipeline_fn(block_fn: Callable, mesh: Mesh, *, stage_axis: str = "stage",
                     n_micro: int):
    """block_fn(params_stage, x) -> x, applied per stage.

    Returns fn(stage_params, x_micro) where stage_params leaves have leading
    dim S (sharded over stage_axis) and x_micro is (M, mb, ...) (replicated).
    Output: (M, mb, ...) activations after all S stages.
    """
    S = mesh.shape[stage_axis]

    def pipelined(params, xs):
        # per-shard: params leaf (1, ...) local stage params; xs (M, mb, d)
        params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(stage_axis)
        M = xs.shape[0]
        T = M + S - 1
        buf = jnp.zeros_like(xs[0])          # activation currently held
        outs = jnp.zeros_like(xs)

        def slot(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others use what arrived
            take = jnp.clip(t, 0, M - 1)
            buf = jnp.where(sid == 0, xs[take], buf)
            y = block_fn(params, buf)
            # last stage emits microbatch (t - S + 1)
            out_idx = jnp.clip(t - S + 1, 0, M - 1)
            emit = (sid == S - 1) & (t - S + 1 >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                lambda o: o, outs)
            # shift activations forward one stage
            perm = [(i, i + 1) for i in range(S - 1)]
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, T, slot, (buf, outs))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(jnp.where(sid == S - 1, outs, 0.0), stage_axis)
        return outs

    return jax.jit(shard_map(
        pipelined, mesh=mesh,
        in_specs=(P(stage_axis), P()), out_specs=P(), check_rep=False))
