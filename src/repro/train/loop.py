"""Fault-tolerant training loop: checkpoint/resume, async saves, deadline
('preemption') detection, deterministic data replay.

The loop is mesh-agnostic: pass mesh/rules for distributed runs (launch/train
does), or None for single-host CPU runs (examples, tests). Restarting —
including on a DIFFERENT mesh shape (elastic) — reproduces the exact state:
data is a pure function of (seed, step) and the checkpoint restores by
logical name with resharding.
"""
from __future__ import annotations

from repro.obs import clock
from dataclasses import dataclass

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.lm_data import batch_at
from repro.distributed.checkpoint import CheckpointManager
from repro.sharding import tree_shardings
from repro.train.step import init_train_state, make_train_step, train_state_specs


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    seed: int = 0
    deadline_s: float | None = None  # stop cleanly after this wall-time
    async_ckpt: bool = True


def train_loop(cfg: ModelConfig, shape: ShapeConfig, ckpt_dir: str,
               loop: LoopConfig, *, mesh=None, rules=None,
               batch_override: int | None = None, log=print) -> dict:
    bundle = make_train_step(cfg, shape, mesh, rules)
    step_fn = bundle.jitted() if mesh is not None else jax.jit(
        bundle.step_fn, donate_argnums=(0,))
    mgr = CheckpointManager(ckpt_dir)

    state_sh = bundle.state_shardings
    start = mgr.latest_step()
    if start is None:
        state = init_train_state(jax.random.key(loop.seed), cfg)
        if state_sh is not None:
            state = jax.tree.map(jax.device_put, state, state_sh)
        start = 0
    else:
        _, state_axes = train_state_specs(cfg)
        state, _ = mgr.restore(start, shardings=state_sh)
        log(f"resumed from step {start}")

    t0 = clock.wall()
    losses = []
    step = start
    preempted = False
    for step in range(start, loop.total_steps):
        batch = batch_at(cfg, shape, step, seed=loop.seed,
                         batch_override=batch_override)
        state, metrics = step_fn(state, batch)
        if (step + 1) % loop.log_every == 0 or step + 1 == loop.total_steps:
            loss = float(metrics["loss"])
            losses.append((step + 1, loss))
            log(f"step {step + 1}: loss={loss:.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"({(clock.wall() - t0):.1f}s)")
        if (step + 1) % loop.ckpt_every == 0:
            if loop.async_ckpt:
                mgr.save_async(step + 1, state)
            else:
                mgr.save(step + 1, state)
        if loop.deadline_s and clock.wall() - t0 > loop.deadline_s:
            preempted = True
            log(f"deadline hit at step {step + 1}; checkpoint + clean exit "
                "(restart resumes here)")
            break
    mgr.wait()
    final = mgr.save(step + 1, state)
    return {"final_step": step + 1, "losses": losses, "ckpt": final,
            "preempted": preempted}
