from repro.train.step import (  # noqa: F401
    TrainStepBundle,
    init_train_state,
    make_train_step,
    train_state_specs,
)
