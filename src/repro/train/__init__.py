"""Training utilities.

``repro.train.step`` (the LM train-step factory) pulls jax + the model stack;
``repro.train.checkpoint`` (decision-forest training checkpoints, DESIGN.md
§11) is numpy-only and imported from inside ``Learner.train``. Lazy re-export
keeps the light path light: importing ``repro.train.checkpoint`` must not pay
for jax.
"""
_STEP_SYMBOLS = ("TrainStepBundle", "init_train_state", "make_train_step",
                 "train_state_specs")
_CKPT_SYMBOLS = ("CheckpointPolicy", "CheckpointSession", "as_policy",
                 "latest_checkpoint", "open_session", "resume_training",
                 "write_checkpoint")


def __getattr__(name):
    if name in _STEP_SYMBOLS:
        from repro.train import step
        return getattr(step, name)
    if name in _CKPT_SYMBOLS:
        from repro.train import checkpoint
        return getattr(checkpoint, name)
    raise AttributeError(f"module 'repro.train' has no attribute {name!r}")


__all__ = list(_STEP_SYMBOLS + _CKPT_SYMBOLS)
