"""Optimizers: AdamW and Adafactor (factored second moment for >=2-D params —
required to fit the 314B-param grok-1 optimizer state in 16 GB/chip), global
gradient-norm clipping, warmup+cosine schedule.

State layout: ``slots`` mirrors the param tree with each array leaf replaced by
a dict of slot arrays; ``opt_slot_specs`` produces the matching
ShapeDtypeStruct + logical-axes trees so AOT dry-runs can shard the state
without materializing it.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]                  # params -> slots
    update: Callable[[Any, Any, Any, Any], tuple[Any, Any]]
    # update(grads, slots, params, step) -> (new_params, new_slots)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def lr_schedule(cfg: ModelConfig, warmup: int = 100, total: int = 10_000):
    base = cfg.learning_rate

    def sched(step):
        step = step.astype(jnp.float32)
        warm = base * (step + 1.0) / warmup
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return sched


# --------------------------------------------------------------- map helpers

def _apply_leafwise(leaf_fn, params, grads, slots):
    """Apply leaf_fn(g, s, p) over the param tree; slots leaves are dicts.
    Returns (new_params, new_slots)."""
    leaves, treedef = jax.tree.flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    s_flat = treedef.flatten_up_to(slots)
    out = [leaf_fn(g, s, p) for g, s, p in zip(g_flat, s_flat, leaves)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_s = treedef.unflatten([o[1] for o in out])
    return new_p, new_s


# --------------------------------------------------------------- AdamW

def _adamw(cfg: ModelConfig, b1=0.9, b2=0.95, eps=1e-8) -> Optimizer:
    sched = lr_schedule(cfg)
    wd = cfg.weight_decay

    def init(params):
        return jax.tree.map(
            lambda p: {"m": jnp.zeros(p.shape, jnp.float32),
                       "v": jnp.zeros(p.shape, jnp.float32)}, params)

    def update(grads, slots, params, step):
        lr = sched(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            m = b1 * s["m"] + (1 - b1) * g
            v = b2 * s["v"] + (1 - b2) * jnp.square(g)
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            decay = wd * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
            newp = (p.astype(jnp.float32) - lr * (upd + decay)).astype(p.dtype)
            return newp, {"m": m, "v": v}

        return _apply_leafwise(leaf, params, grads, slots)

    return Optimizer(init, update)


# --------------------------------------------------------------- Adafactor

def _adafactor(cfg: ModelConfig, eps=1e-30, clip_thresh=1.0) -> Optimizer:
    """Factored second moment over the trailing two dims; leading dims
    (scanned layers, experts) are kept, so slot size ~ O(rows + cols)."""
    sched = lr_schedule(cfg)
    wd = cfg.weight_decay
    b2_base = 0.999

    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(leaf, params)

    def update(grads, slots, params, step):
        lr = sched(step)
        t = step.astype(jnp.float32) + 1.0
        b2 = 1.0 - t ** -0.8  # Shazeer & Stern decay schedule
        bc = 1.0 - b2_base ** t  # mild bias correction for stability

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = b2 * s["vr"] + (1 - b2) * g2.mean(axis=-1)
                vc = b2 * s["vc"] + (1 - b2) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
                upd = g * jax.lax.rsqrt(vhat + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = b2 * s["v"] + (1 - b2) * g2
                upd = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping by RMS (Adafactor's d=1.0 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_thresh)
            decay = wd * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
            newp = (p.astype(jnp.float32) - lr * (upd + decay)).astype(p.dtype)
            return newp, new_s

        return _apply_leafwise(leaf, params, grads, slots)

    return Optimizer(init, update)


def make_optimizer(cfg: ModelConfig) -> Optimizer:
    if cfg.optimizer == "adamw":
        return _adamw(cfg)
    if cfg.optimizer == "adafactor":
        return _adafactor(cfg)
    raise ValueError(cfg.optimizer)


# --------------------------------------------------------------- AOT specs

def opt_slot_specs(cfg: ModelConfig, param_specs, param_axes):
    """(ShapeDtypeStruct tree, logical-axes tree) for the optimizer slots,
    mirroring what ``Optimizer.init`` would build — without allocating."""
    sds = jax.ShapeDtypeStruct

    def leaf(spec, axes):
        if cfg.optimizer == "adamw":
            return ({"m": sds(spec.shape, jnp.float32), "v": sds(spec.shape, jnp.float32)},
                    {"m": tuple(axes), "v": tuple(axes)})
        if len(spec.shape) >= 2:
            return ({"vr": sds(spec.shape[:-1], jnp.float32),
                     "vc": sds(spec.shape[:-2] + spec.shape[-1:], jnp.float32)},
                    {"vr": tuple(axes[:-1]), "vc": tuple(axes[:-2] + axes[-1:])})
        return ({"v": sds(spec.shape, jnp.float32)}, {"v": tuple(axes)})

    leaves, treedef = jax.tree.flatten(param_specs)
    ax_flat = treedef.flatten_up_to(param_axes)
    out = [leaf(s, a) for s, a in zip(leaves, ax_flat)]
    specs = treedef.unflatten([o[0] for o in out])
    axes = treedef.unflatten([o[1] for o in out])
    return specs, axes
