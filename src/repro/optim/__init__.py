from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    global_norm,
    lr_schedule,
    make_optimizer,
    opt_slot_specs,
)
