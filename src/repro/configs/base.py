"""Config system: architecture + shape + run configs, with a registry.

Every assigned architecture registers a ``ModelConfig`` via ``register_arch``.
Shapes (train_4k / prefill_32k / decode_32k / long_500k) are global and paired
with every LM arch; applicability filtering (e.g. long_500k only for
sub-quadratic families) lives in ``applicable_shapes``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "unnamed"
    family: str = "dense"  # dense | moe | vlm | audio | hybrid | ssm
    # core transformer dims
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    d_ff: int = 256
    vocab_size: int = 512
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    parallel_block: bool = False  # command-r style parallel attn+ffn residual
    rope_theta: float = 10_000.0
    # mlp
    act: str = "swiglu"  # swiglu | geglu | gelu
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    router_aux_weight: float = 0.01
    capacity_factor: float = 1.25
    moe_group_size: int = 512
    # ssm / hybrid (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_chunk: int = 256
    d_conv: int = 4
    attn_every: int = 0  # zamba2: shared attention block period (0 = none)
    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 128
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0  # stub frontend sequence length (whisper frames)
    # vlm (paligemma)
    n_patches: int = 0  # stub frontend patch embeddings per example
    # embeddings / norm
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scaling
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    kv_cache_dtype: str = ""  # "" -> dtype; "float8_e4m3fn" halves decode HBM
    # runtime / performance knobs (hillclimb levers)
    attn_impl: str = "chunked"  # chunked | chunked_causal_skip | naive
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    loss_chunk: int = 512
    scan_layers: bool = True
    remat: str = "full"  # full | dots | none
    # optimizer
    optimizer: str = "adamw"  # adamw | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # parallelism
    grad_accum: int = 1

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Families with sub-quadratic sequence mixing: the only ones that run long_500k.
_SUBQUADRATIC = {"hybrid", "ssm"}

_ARCHS: dict[str, ModelConfig] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _ARCHS:
        raise ValueError(f"duplicate arch {cfg.name!r}")
    _ARCHS[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _ARCHS:
        raise KeyError(
            f"unknown arch {name!r}. Available: {sorted(_ARCHS)}. "
            "Architectures are registered by modules in repro.configs."
        )
    return _ARCHS[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_ARCHS)


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Shapes that are well-defined for this architecture (assignment rules)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in _SUBQUADRATIC:
        names.append("long_500k")
    return names


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # Import all arch config modules for registration side effects.
    from repro.configs import archs  # noqa: F401


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """A reduced config of the same family for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128,
        vocab_size=128,
        head_dim=16,
        loss_chunk=32,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        moe_group_size=16,
        scan_layers=cfg.scan_layers,
        dtype="float32",
        param_dtype="float32",
        kv_cache_dtype="",
    )
    if cfg.n_experts:
        kw.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                  moe_d_ff=64, n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_heads=4, ssm_head_dim=16, ssm_chunk=16)
    if cfg.attn_every:
        kw.update(attn_every=2, n_layers=4)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2, enc_seq=24)
    if cfg.n_patches:
        kw.update(n_patches=8)
    if cfg.family == "ssm":
        kw.update(rwkv_head_dim=16, rwkv_chunk=16)
    return cfg.replace(**kw)
