from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
    get_arch,
    list_archs,
    register_arch,
    smoke_config,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "applicable_shapes",
    "get_arch",
    "list_archs",
    "register_arch",
    "smoke_config",
]
