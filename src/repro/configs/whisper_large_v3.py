"""whisper-large-v3: encoder-decoder, 32 encoder + 32 decoder layers,
d_model 1280, 20H (no GQA), d_ff 5120, vocab 51866. The conv/mel frontend is a
STUB: input_specs() provides 1500 precomputed frame embeddings per example.
Decode shapes lower the decoder serve_step (self-attn KV cache + cross-attn to
encoder states). [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,          # decoder layers
    n_enc_layers=32,      # encoder layers
    enc_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    qkv_bias=True,
    act="gelu",
    tie_embeddings=True,
    rope_theta=0.0,       # whisper uses learned/sinusoidal positions, not RoPE
    optimizer="adamw",
))
