"""paligemma-3b: VLM; transformer backbone = gemma-2b-style decoder: 18L,
d_model 2048, 8H MQA(kv=1), d_ff 16384, vocab 257216. The SigLIP vision
frontend is a STUB: input_specs() provides 256 precomputed patch embeddings
per example, prepended (prefix-LM) to the text tokens. [arXiv:2407.07726; hf]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    qkv_bias=False,
    act="geglu",
    n_patches=256,
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=1e4,
    optimizer="adamw",
))
