"""rwkv6-3b (Finch): attention-free, 32L, d_model 2560, d_ff 8960, vocab 65536,
data-dependent decay linear attention. Chunked-parallel form for train/prefill;
O(1)-state recurrence for decode (long_500k applicable). [arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,           # time-mix heads = d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    rwkv_head_dim=64,
    rwkv_chunk=128,
    act="relu_sq",        # rwkv channel-mix uses squared relu
    tie_embeddings=False,
    rope_theta=0.0,
    optimizer="adamw",
))
