"""zamba2-2.7b: hybrid, 54 Mamba2 (SSD) layers, d_model 2560, ssm_state 64,
plus a SHARED attention(32H)+MLP(d_ff 10240) block invoked every 6 mamba
layers (9 invocations, one set of weights, per-invocation KV caches),
vocab 32000. [arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    qkv_bias=False,
    act="gelu",
    ssm_state=64,
    ssm_heads=80,
    ssm_head_dim=64,     # expand=2 -> d_inner 5120 = 80 heads x 64
    ssm_chunk=256,
    d_conv=4,
    attn_every=6,
    tie_embeddings=True,
    rope_theta=1e4,
    optimizer="adamw",
))
