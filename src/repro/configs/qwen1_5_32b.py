"""qwen1.5-32b: dense decoder, 64L, d_model 5120, 40H GQA(kv=40 -> MHA), d_ff 27392,
vocab 152064. QKV bias. [hf:Qwen/Qwen1.5-32B; hf]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    act="swiglu",
    tie_embeddings=False,
    rope_theta=1e6,
    optimizer="adamw",
))
