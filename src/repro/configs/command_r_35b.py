"""command-r-35b: dense decoder, 40L, d_model 8192, 64H GQA(kv=8), d_ff 22528,
vocab 256000. GQA, no bias, parallel attention+FFN residual (Cohere layout).
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    head_dim=128,
    qkv_bias=False,
    parallel_block=True,
    act="swiglu",
    tie_embeddings=True,
    rope_theta=8e6,
    optimizer="adamw",
))
