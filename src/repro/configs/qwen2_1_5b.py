"""qwen2-1.5b: dense decoder, 28L, d_model 1536, 12H GQA(kv=2), d_ff 8960,
vocab 151936. GQA with QKV bias, tied embeddings. [arXiv:2407.10671; hf]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    act="swiglu",
    tie_embeddings=True,
    rope_theta=1e6,
    optimizer="adamw",
))
