"""grok-1-314b: MoE decoder, 64L, d_model 6144, 48H GQA(kv=8), d_ff 32768,
vocab 131072, 8 experts top-2. Adafactor optimizer (Adam m/v would not fit
16 GB/chip at 314B params on a 256-chip pod). [hf:xai-org/grok-1; unverified]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,       # dense-equivalent ff width; experts use moe_d_ff
    vocab_size=131072,
    head_dim=128,
    qkv_bias=False,
    act="geglu",
    n_experts=8,
    top_k=2,
    moe_d_ff=32768,
    n_shared_experts=0,
    tie_embeddings=True,
    rope_theta=1e4,
    optimizer="adafactor",
))
