"""qwen3-8b: dense decoder, 36L, d_model 4096, 32H GQA(kv=8), d_ff 12288,
vocab 151936. Per-head RMS qk_norm, no attention bias. [hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=False,
    qk_norm=True,
    act="swiglu",
    tie_embeddings=False,
    rope_theta=1e6,
    optimizer="adamw",
))
