"""qwen2-moe-a2.7b: MoE decoder, 24L, d_model 2048, 16H GQA(kv=16), expert
d_ff 1408, vocab 151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,        # shared-expert path width (4 x 1408)
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    act="swiglu",
    n_experts=60,
    top_k=4,
    moe_d_ff=1408,
    n_shared_experts=4,
    tie_embeddings=False,
    rope_theta=1e6,
    optimizer="adamw",
))
