"""Imports every architecture config module for registration side effects."""
from repro.configs import (  # noqa: F401
    command_r_35b,
    qwen2_1_5b,
    qwen1_5_32b,
    qwen3_8b,
    grok_1_314b,
    qwen2_moe_a2_7b,
    paligemma_3b,
    whisper_large_v3,
    zamba2_2_7b,
    rwkv6_3b,
)
