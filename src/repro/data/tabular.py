"""Deterministic synthetic tabular datasets.

The paper benchmarks on 70 OpenML datasets (150–96k examples, 5–1777 features,
mixed semantics, missing values). There is no network access here, so we
generate a seeded suite matched to those statistics; accuracy NUMBERS are not
comparable 1:1 with the paper's tables, but the protocol (10-fold CV, fold
splits shared across learners, rank aggregation) is reproduced faithfully and
the expected ORDERINGS are asserted in tests (see EXPERIMENTS.md).

Generator: a random ground-truth decision forest + nonlinear numeric
interactions + label noise — a tabular world where tree learners are apt but
not trivially perfect, and a linear model is a meaningful baseline.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticSpec:
    name: str
    n: int
    n_num: int
    n_cat: int
    n_classes: int  # 0 -> regression
    missing_rate: float = 0.02
    noise: float = 0.1
    seed: int = 0


# A small "OpenML-like" suite (size range mirrors the paper's small datasets).
SUITE: list[SyntheticSpec] = [
    SyntheticSpec("synth_iris", 300, 4, 0, 3, seed=1),
    SyntheticSpec("synth_blood", 748, 4, 0, 2, seed=2),
    SyntheticSpec("synth_adult", 2000, 6, 8, 2, missing_rate=0.05, seed=3),
    SyntheticSpec("synth_credit", 1000, 7, 13, 2, seed=4),
    SyntheticSpec("synth_vowel", 990, 10, 2, 11, seed=5),
    SyntheticSpec("synth_segment", 1500, 19, 0, 7, seed=6),
    SyntheticSpec("synth_cmc", 1473, 2, 7, 3, seed=7),
    SyntheticSpec("synth_wine_reg", 900, 11, 0, 0, seed=8),
]


def make_dataset(spec: SyntheticSpec) -> dict[str, np.ndarray]:
    """Returns raw columns (object arrays with missing as None) + 'label'."""
    rng = np.random.default_rng(spec.seed * 9973 + 17)
    n, F_num, F_cat = spec.n, spec.n_num, spec.n_cat
    Xn = rng.normal(size=(n, F_num))
    cat_sizes = rng.integers(2, 12, size=F_cat)
    Xc = np.stack([rng.integers(0, s, size=n) for s in cat_sizes], axis=1) \
        if F_cat else np.zeros((n, 0), np.int64)

    # ground truth: random shallow forest over both feature kinds + smooth part
    score = np.zeros(n)
    n_rules = 8 + F_num + F_cat
    for _ in range(n_rules):
        w = rng.normal()
        if F_num and (rng.random() < 0.6 or not F_cat):
            j = rng.integers(F_num)
            t = rng.normal()
            cond = Xn[:, j] > t
            if rng.random() < 0.3 and F_num > 1:  # interaction
                j2 = rng.integers(F_num)
                cond &= Xn[:, j2] > rng.normal()
        else:
            j = rng.integers(F_cat)
            keep = rng.random(cat_sizes[j]) < 0.5
            cond = keep[Xc[:, j]]
        score += w * cond
    if F_num:
        beta = rng.normal(size=F_num) * 0.5
        score += np.tanh(Xn @ beta)
    score += rng.normal(scale=spec.noise * max(score.std(), 1e-6), size=n)

    data: dict[str, np.ndarray] = {}
    for j in range(F_num):
        col = Xn[:, j].astype(object)
        miss = rng.random(n) < spec.missing_rate
        col[miss] = None
        data[f"num_{j}"] = col
    for j in range(F_cat):
        col = np.array([f"v{v}" for v in Xc[:, j]], dtype=object)
        miss = rng.random(n) < spec.missing_rate
        col[miss] = None
        data[f"cat_{j}"] = col

    if spec.n_classes == 0:
        data["label"] = score.astype(object)
    else:
        qs = np.quantile(score, np.linspace(0, 1, spec.n_classes + 1)[1:-1])
        y = np.digitize(score, qs)
        data["label"] = np.array([f"c{c}" for c in y], dtype=object)
    return data


def train_test_split(data: dict[str, np.ndarray], test_ratio: float = 0.3,
                     seed: int = 0) -> tuple[dict, dict]:
    n = len(next(iter(data.values())))
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    nt = int(n * test_ratio)
    te, tr = perm[:nt], perm[nt:]
    return ({k: v[tr] for k, v in data.items()},
            {k: v[te] for k, v in data.items()})


def adult_like(n: int = 3000, seed: int = 42) -> dict[str, np.ndarray]:
    """An Adult/Census-shaped fixture (paper §4): mixed semantics, missing
    values, a '>50K'/'<=50K'-style binary label driven by realistic rules."""
    rng = np.random.default_rng(seed)
    age = rng.integers(17, 91, n)
    edu_levels = ["HS-grad", "Some-college", "Bachelors", "Masters", "Doctorate",
                  "7th-8th", "Assoc-voc", "10th"]
    edu_rank = {e: i for i, e in enumerate(
        ["7th-8th", "10th", "HS-grad", "Assoc-voc", "Some-college", "Bachelors",
         "Masters", "Doctorate"])}
    education = rng.choice(edu_levels, n, p=[.32, .22, .17, .06, .01, .02, .13, .07])
    occupation = rng.choice(["Exec-managerial", "Prof-specialty", "Sales",
                             "Adm-clerical", "Other-service", "Machine-op-inspct",
                             "Handlers-cleaners"], n)
    workclass = rng.choice(["Private", "Self-emp-inc", "Government"], n,
                           p=[.75, .1, .15])
    hours = np.clip(rng.normal(40, 12, n), 1, 99).astype(int)
    capital_gain = np.where(rng.random(n) < 0.08,
                            rng.lognormal(8, 1.2, n).astype(int), 0)
    z = (0.045 * (age - 38) + 0.55 * np.array([edu_rank[e] for e in education])
         + 0.35 * np.isin(occupation, ["Exec-managerial", "Prof-specialty"])
         + 0.02 * (hours - 40) + 0.9 * (capital_gain > 3000)
         + 0.4 * (workclass == "Self-emp-inc") - 1.9)
    p = 1 / (1 + np.exp(-(z + rng.logistic(0, 0.6, n))))
    income = np.where(p > 0.5, ">50K", "<=50K")

    def with_missing(col, rate=0.03):
        col = col.astype(object)
        col[rng.random(n) < rate] = None
        return col

    return {
        "age": age.astype(object),
        "workclass": with_missing(workclass),
        "education": education.astype(object),
        "occupation": with_missing(occupation),
        "hours_per_week": hours.astype(object),
        "capital_gain": capital_gain.astype(object),
        "income": income.astype(object),
    }


# ------------------------------------------------ task datasets (§12)

def grouped_relevance(n_groups: int = 150, seed: int = 7
                      ) -> dict[str, np.ndarray]:
    """Grouped-relevance ranking dataset (task=RANKING, label "rel",
    group column "group").

    Within-group order is driven by the document features num_0/num_1. A
    large group-CONSTANT bias — deliberately NOT exposed as a feature —
    leaks into the graded label (global quantile bins): most label variance
    is unexplainable query-level noise. A pointwise regression learns
    E[rel|x] through that noise, while LambdaMART's within-group pairs
    cancel the bias exactly (both documents share it), so its gradients see
    the clean document signal. That sample-efficiency edge is the NDCG@5
    gap the acceptance test pins.
    """
    rng = np.random.default_rng(seed)
    sizes = rng.integers(8, 17, n_groups)
    gid = np.repeat(np.arange(n_groups), sizes)
    n = len(gid)
    x0, x1, x2 = rng.normal(size=(3, n))
    bias = (rng.normal(scale=4.0, size=n_groups))[gid]
    u_doc = x0 + 0.8 * x1 + 0.4 * x0 * x1
    u = u_doc + bias + rng.normal(scale=0.25, size=n)
    qs = np.quantile(u, [0.3, 0.55, 0.75, 0.9])
    rel = np.digitize(u, qs).astype(np.float64)
    return {
        "num_0": x0.astype(object), "num_1": x1.astype(object),
        "num_2": x2.astype(object),
        "group": gid.astype(object), "rel": rel.astype(object),
    }


def randomized_treatment(n: int = 4000, seed: int = 11
                         ) -> dict[str, np.ndarray]:
    """Randomized-treatment uplift dataset (task=UPLIFT, label "outcome",
    treatment column "treatment"): a 50/50 randomized assignment, a baseline
    conversion driven by num_0/num_1, and a heterogeneous effect that is
    POSITIVE for num_2 > 0 and slightly negative otherwise — so ranking by
    true uplift is learnable and Qini > 0 is achievable."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 4))
    t = (rng.random(n) < 0.5).astype(np.int64)
    p0 = 1.0 / (1.0 + np.exp(-(0.8 * x[:, 0] - 0.4 * x[:, 1] - 0.5)))
    tau = np.where(x[:, 2] > 0, 0.25, -0.05)
    p = np.clip(p0 + t * tau, 0.01, 0.99)
    y = (rng.random(n) < p).astype(np.int64)
    data = {f"num_{j}": x[:, j].astype(object) for j in range(4)}
    data["treatment"] = t.astype(object)
    data["outcome"] = y.astype(object)
    return data


def planted_anomaly(n_inlier: int = 1000, n_anomaly: int = 40,
                    n_features: int = 6, seed: int = 13
                    ) -> dict[str, np.ndarray]:
    """Planted-anomaly dataset (task=ANOMALY, label "anomaly"): a tight
    gaussian inlier cloud plus sparse uniform outliers far outside it. The
    label is the 0/1 indicator — used only by evaluate(), never training."""
    rng = np.random.default_rng(seed)
    inliers = rng.normal(scale=1.0, size=(n_inlier, n_features))
    anomalies = rng.uniform(-6.0, 6.0, size=(n_anomaly, n_features))
    # keep planted points genuinely outside the cloud
    far = np.abs(anomalies).max(axis=1) > 3.0
    anomalies[~far] += np.sign(anomalies[~far]) * 4.0
    X = np.concatenate([inliers, anomalies], axis=0)
    y = np.r_[np.zeros(n_inlier), np.ones(n_anomaly)]
    perm = rng.permutation(len(y))
    X, y = X[perm], y[perm]
    data = {f"num_{j}": X[:, j].astype(object) for j in range(n_features)}
    data["anomaly"] = y.astype(object)
    return data
