"""Dataset READERS and WRITERS (paper §3.5 modules): format-prefixed paths in
YDF's CLI style — ``read_dataset("csv:/tmp/train.csv")``. New formats register
via ``register_format``.
"""
from __future__ import annotations

import csv
from typing import Callable

import numpy as np

from repro.core.api import YdfError

_READERS: dict[str, Callable] = {}
_WRITERS: dict[str, Callable] = {}


def register_format(name: str, reader: Callable, writer: Callable) -> None:
    _READERS[name] = reader
    _WRITERS[name] = writer


def _split(path: str) -> tuple[str, str]:
    if ":" not in path:
        raise YdfError(
            f"Dataset paths are format-prefixed, e.g. 'csv:{path}'. "
            f"Registered formats: {sorted(_READERS)}.")
    fmt, p = path.split(":", 1)
    if fmt not in _READERS:
        raise YdfError(f"Unknown dataset format {fmt!r}. "
                       f"Registered formats: {sorted(_READERS)}.")
    return fmt, p


def read_dataset(path: str) -> dict[str, np.ndarray]:
    fmt, p = _split(path)
    return _READERS[fmt](p)


def write_dataset(data: dict[str, np.ndarray], path: str) -> None:
    fmt, p = _split(path)
    _WRITERS[fmt](data, p)


# ----------------------------------------------------------------- csv

def _read_csv(path: str) -> dict[str, np.ndarray]:
    with open(path, newline="") as f:
        rows = list(csv.reader(f))
    if not rows:
        raise YdfError(f"CSV file {path!r} is empty.")
    header, body = rows[0], rows[1:]
    cols = {h: np.empty(len(body), dtype=object) for h in header}
    for i, row in enumerate(body):
        for h, v in zip(header, row):
            cols[h][i] = v if v != "" else None
    return cols


def _write_csv(data: dict[str, np.ndarray], path: str) -> None:
    names = list(data)
    n = len(next(iter(data.values()))) if data else 0
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(names)
        for i in range(n):
            w.writerow(["" if data[c][i] is None else data[c][i] for c in names])


register_format("csv", _read_csv, _write_csv)
