"""Data pipelines: synthetic tabular suites (OpenML stand-ins), token streams
for LM training, and file readers/writers."""
