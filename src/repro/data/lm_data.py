"""Deterministic synthetic token pipeline for LM training.

``batch_at(cfg_like, step)`` is a pure function of (seed, step): a restarted
job replays the exact stream with no shuffle-buffer state to checkpoint —
the data-side half of fault tolerance (DESIGN.md §3).

The stream is a seeded order-2 Markov chain over the vocabulary with Zipfian
marginals — enough structure that a ~100M model visibly learns (loss drops
well below uniform) while staying generation-free and offline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@functools.lru_cache(maxsize=8)
def _chain(vocab: int, seed: int, branch: int = 32):
    """Sparse transition structure: each state -> `branch` successors."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branch), dtype=np.int32)
    # Zipfian choice over the branch slots
    p = 1.0 / np.arange(1, branch + 1)
    p /= p.sum()
    return jnp.asarray(succ), jnp.asarray(p.astype(np.float32))


def batch_at(cfg: ModelConfig, shape: ShapeConfig, step: int, *,
             seed: int = 0, batch_override: int | None = None) -> dict:
    """Returns the training batch for `step` ({tokens, labels [, frames,
    patches]}), deterministically."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    succ, p = _chain(cfg.vocab_size, seed)
    key = jax.random.fold_in(jax.random.key(seed), step)

    if cfg.family == "vlm":
        S_text = S - cfg.n_patches
        k1, k2, k3 = jax.random.split(key, 3)
        toks = _markov(succ, p, k1, B, S_text + 1)
        patches = jax.random.normal(k2, (B, cfg.n_patches, cfg.d_model),
                                    jnp.float32).astype(jnp.dtype(cfg.dtype)) * 0.02
        return {"patches": patches, "tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "audio":
        k1, k2 = jax.random.split(key)
        toks = _markov(succ, p, k1, B, S + 1)
        frames = jax.random.normal(k2, (B, cfg.enc_seq, cfg.d_model),
                                   jnp.float32).astype(jnp.dtype(cfg.dtype)) * 0.02
        return {"frames": frames, "tokens": toks[:, :-1], "labels": toks[:, 1:]}
    toks = _markov(succ, p, key, B, S + 1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@functools.partial(jax.jit, static_argnums=(3, 4))
def _markov(succ, p, key, B: int, S: int):
    k0, kseq = jax.random.split(key)
    state = jax.random.randint(k0, (B,), 0, succ.shape[0], jnp.int32)

    def step_fn(state, k):
        slot = jax.random.choice(k, succ.shape[1], (B,), p=p)
        nxt = succ[state, slot]
        return nxt, state

    _, toks = jax.lax.scan(step_fn, state, jax.random.split(kseq, S))
    return toks.T  # (B, S)
