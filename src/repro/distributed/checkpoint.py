"""Fault-tolerant checkpointing.

Design (multi-host-shaped, exercised single-host here):
  * ``save`` writes one ``.npz`` per pytree leaf group + a JSON manifest with
    the treedef, step, and config fingerprint; writes go to a temp dir that is
    atomically renamed — a preempted save never corrupts the latest step.
  * ``restore`` is RESHARDING: arrays are loaded on host and ``device_put``
    with the *target* shardings, so a job restarted on a different mesh shape
    (elastic scaling / degraded pod) resumes transparently.
  * ``save_async`` snapshots to host memory synchronously (cheap) and writes
    in a background thread — the step loop never blocks on disk.
  * best-effort partial restore: missing leaves keep their init values
    (``strict=False``), enabling schema evolution.
  * retention: keep the last ``keep`` checkpoints; GBT boosting state (trees +
    predictions) uses the same manager (paper §3.9 fault tolerance).
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
from repro.obs import clock
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---------------------------------------------------------- save
    def save(self, step: int, state, extra: dict | None = None) -> str:
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        return self._write(step, host_state, extra or {})

    def save_async(self, step: int, state, extra: dict | None = None) -> None:
        self.wait()  # one in-flight save at a time
        host_state = jax.tree.map(lambda x: np.asarray(x), state)  # snapshot now
        self._thread = threading.Thread(
            target=self._write, args=(step, host_state, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, extra: dict) -> str:
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        named = _flatten_with_names(host_state)
        arrays = {f"a{i}": leaf for i, (_, leaf) in enumerate(named)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        treedef = jax.tree.structure(host_state)
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(treedef, f)
        manifest = {"step": step, "names": [n for n, _ in named],
                    "time": clock.wall(), "extra": extra}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, *, target=None, shardings=None,
                strict: bool = True):
        """Load a checkpoint. ``target``: template pytree (for partial restore
        + dtype casts). ``shardings``: matching pytree of Shardings — arrays
        are placed there (RESHARDING restore: target mesh may differ from the
        mesh that saved)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        z = np.load(os.path.join(path, "arrays.npz"))
        leaves = [z[f"a{i}"] for i in range(len(manifest["names"]))]
        state = jax.tree.unflatten(treedef, leaves)
        if target is not None:
            state = _merge(target, state, manifest["names"], strict)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                state, shardings)
        return state, manifest

    def restore_or_init(self, init_fn, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return init_fn(), None
        return self.restore(step, shardings=shardings)


def _merge(target, loaded, names, strict: bool):
    t_named = dict(_flatten_with_names(target))
    l_named = dict(_flatten_with_names(loaded))
    missing = set(t_named) - set(l_named)
    if missing and strict:
        raise KeyError(f"checkpoint is missing leaves {sorted(missing)[:5]}...; "
                       "pass strict=False for best-effort partial restore")
    leaves, treedef = jax.tree.flatten(target)
    named = _flatten_with_names(target)
    out = []
    for (name, t_leaf) in named:
        if name in l_named:
            v = l_named[name]
            if hasattr(t_leaf, "dtype") and v.dtype != t_leaf.dtype:
                v = v.astype(t_leaf.dtype)
            out.append(v)
        else:
            out.append(t_leaf)
    return jax.tree.unflatten(treedef, out)
