"""Distributed runtime: checkpointing (sharded, resharding restore, async),
gradient compression, elastic-mesh helpers."""
from repro.distributed.checkpoint import CheckpointManager  # noqa: F401
