"""Gradient compression for the slow cross-pod links.

Hierarchical int8 all-reduce: full-precision psum *inside* a pod (fast ICI),
then int8-quantized psum *across* pods (slow inter-pod links: 2 pods here,
1000+-node deployments hang off the same primitive), then dequantize. Scale
is per-tensor max-abs (stochastic-rounding optional).

Cross-pod bytes drop 4x (f32 -> i8) at a quantization error bounded by
scale/254 per element per pod (tested). Plug point: the DP gradient sync of an
explicit shard_map training step (see tests/test_distributed_lm.py) — the
implicit-GSPMD train path keeps fp32 reductions by default (documented).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, stochastic_key=None):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    scaled = x / scale
    if stochastic_key is not None:
        noise = jax.random.uniform(stochastic_key, x.shape, minval=-0.5, maxval=0.5)
        scaled = scaled + noise
    q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def hierarchical_psum(x: jax.Array, *, pod_axis: str = "pod",
                      inner_axis: str | tuple[str, ...] = "data",
                      compress: bool = True) -> jax.Array:
    """psum over (inner_axis, pod_axis) with int8 compression on the pod hop.
    Must run inside shard_map with both axes in scope."""
    x = jax.lax.psum(x, inner_axis)                     # fast in-pod fp32
    if not compress:
        return jax.lax.psum(x, pod_axis)
    # agree on ONE scale across pods first (a single scalar pmax), so the
    # int8 payloads are commensurable and the int32 sum dequantizes exactly.
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), pod_axis)
    scale = amax / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    summed = jax.lax.psum(q.astype(jnp.int32), pod_axis)
    return summed.astype(jnp.float32) * scale


def compressed_grad_psum(grads, *, pod_axis="pod", inner_axis="data",
                         compress=True):
    return jax.tree.map(
        functools.partial(hierarchical_psum, pod_axis=pod_axis,
                          inner_axis=inner_axis, compress=compress), grads)
