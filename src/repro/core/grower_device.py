"""Device-resident training engine (DESIGN.md §6).

One training level = one compiled XLA program. The host never sees a
histogram: per level the jitted ``level step`` samples candidate features
(hash-keyed, sampling.py), accumulates per-(tree, slot, feature, bin)
gradient stats, runs the gain scans (numerical cumulative-sum; categorical
Fisher-order / one-hot), argmaxes the best split per frontier slot, allocates
children, routes every example, derives child stats, and writes the chosen
conditions into device-resident forest arrays. The only per-level host
traffic is one int32 — the compacted frontier width, used to pick the next
power-of-two shape bucket — and the forest arrays are fetched once per tree
block at the end.

Shapes are fixed per level: the frontier is padded to a power of two and
inactive slots are masked, so the jit cache holds at most
``log2(max_frontier)`` programs per configuration. Wide frontiers are
processed in ``W``-slot chunks inside the step so histogram scratch stays
bounded (the full ``(slots, F, B, S)`` tensor is never materialized for deep
trees).

Random Forests grow a block of K trees in lockstep: every state array
carries a leading tree axis and K is padded to the block size so all blocks
share one compiled program. Tree independence is preserved because feature
subsets are keyed by (tree, node), not drawn from a shared stream.

On TPU the numerical hist+gain pipeline is the fused Pallas kernel
(kernels/histogram/fused.py); on CPU hosts the same math runs as jnp inside
the jit (the kernel's interpret mode is only for the CI smoke —
resolve_backend's rule that interpret mode must never be the silent hot path
applies here too). Datasets with categorical features always use the jnp
path, which shares ``score_stats`` with the kernel.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from repro.obs import trace
from repro.core.api import YdfError
from repro.core.binning import BinnedFeatures
from repro.core.sampling import keyed_feature_select_jnp, sample_size
from repro.core.splitters import REL_GAIN_EPS as _REL_EPS
from repro.core.tree import MASK_WORDS, Forest

_B = 256          # bin axis (uint8 codes)
_W_CAP = 512      # per-chunk slot width inside the level step

# (cfg, K, N, P) shape buckets whose level step has already been jitted in
# this process — lets tracing label the first call at a bucket as compile
# time and the rest as execute time (DESIGN.md §13.2).
_stepped_shapes: set = set()


def device_unsupported_reason(params, binned: BinnedFeatures | None = None,
                              oblique_active: bool = False) -> str | None:
    """None when the device engine supports this configuration, else a
    human-readable reason (callers fall back to the batched host engine)."""
    sp = params.splitter
    if params.growing_strategy != "LOCAL":
        return ("growing_strategy=BEST_FIRST_GLOBAL is heap-ordered and "
                "host-sequential; device engine is level-wise (LOCAL) only")
    if oblique_active or sp.oblique:
        return "sparse-oblique projections scan raw columns on the host"
    if sp.categorical_algorithm == "RANDOM":
        return ("categorical_algorithm=RANDOM draws per-feature trial masks "
                "from the host rng stream")
    if sp.num_candidate_ratio < 1.0 and params.feature_sampling != "keyed":
        return ("per-node feature sampling on device requires keyed "
                "(hash-based) sampling; feature_sampling='stream' draws from "
                "the host rng")
    return None


def _resolve_impl(impl: str, has_cat: bool) -> str:
    if impl in (None, "auto"):
        import jax
        if jax.default_backend() == "tpu" and not has_cat:
            return "pallas"
        return "jnp"
    if impl in ("pallas", "interpret") and has_cat:
        raise YdfError(
            f"device_impl={impl!r} uses the fused numerical kernel, which "
            "does not handle categorical features. Solutions: (1) use "
            "device_impl='jnp', (2) drop categorical features.")
    if impl not in ("jnp", "pallas", "interpret"):
        raise YdfError(f"Unknown device_impl {impl!r}. Expected one of: "
                       "'auto', 'jnp', 'pallas', 'interpret'.")
    return impl


@dataclass(frozen=True)
class _StepConfig:
    kind: str
    l2: float
    min_examples: int
    min_gain: float
    cat_mode: str          # none | cart | onehot
    sample: bool           # per-node keyed feature sampling active
    sampling_key: int
    kf: int                # candidate features per node
    F: int
    S: int
    M: int                 # node capacity
    max_nodes: int         # allocation budget (<= M)
    impl: str              # jnp | pallas | interpret


@functools.lru_cache(maxsize=64)
def _level_step(cfg: _StepConfig):
    """Build the jitted level step for one engine configuration. The returned
    function recompiles per input shape bucket (P doubles level to level, K
    fixed per block) — at most log2(max_frontier) variants live in cache."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.histogram.fused import (
        NEG_INF,
        _numerical_gains,
        fused_split_pallas,
        score_stats,
    )

    kind, l2, min_ex = cfg.kind, cfg.l2, cfg.min_examples
    kf, S, M = cfg.kf, cfg.S, cfg.M

    def order_key(h):
        """jnp mirror of splitters._order_key on (..., B, S) histograms."""
        n = jnp.maximum(h[..., -1], 1e-12)
        if kind == "gh":
            return h[..., 0] / jnp.maximum(h[..., 1], 1e-12)
        if kind == "class":
            return h[..., 1] / n
        return h[..., 0] / n

    def chunk_best(codes, nbins, iscat, stats, fsel_c, loc, w_slots):
        """Best split per slot for one W-wide slot chunk.

        codes (N, F) i32; stats (K, N, S) f32; fsel_c (K, W, kf) i32;
        loc (K, N) i32 local slot in [-1, W). Returns per-(K, W): gain f32,
        feature i32 (original column), split_bin i32, iscat bool, and the
        (K, W, B) go-right-by-code table.
        """
        K, N = loc.shape
        act = loc >= 0
        locc = jnp.maximum(loc, 0)
        # per-example candidate codes: codes[i, fsel_c[k, loc[k,i], j]]
        fex = jnp.take_along_axis(
            fsel_c, locc[:, :, None], axis=1)                 # (K, N, kf)
        cex = codes[jnp.arange(N)[None, :, None], fex]        # (K, N, kf)

        if cfg.impl in ("pallas", "interpret"):
            # fused kernel: hist + numerical scan + argmax fully in VMEM
            gains, js, sbins = [], [], []
            for k in range(K):
                gk, jk, bk = fused_split_pallas(
                    cex[k].astype(jnp.uint8), stats[k], loc[k], w_slots,
                    _B, kind=kind, l2=l2, min_examples=min_ex,
                    interpret=(cfg.impl == "interpret"))
                gains.append(gk), js.append(jk), sbins.append(bk)
            gain = jnp.stack(gains)                           # (K, W)
            jwin = jnp.maximum(jnp.stack(js), 0)
            sbin = jnp.stack(sbins)
            feat = jnp.take_along_axis(
                fsel_c, jwin[:, :, None], axis=2)[:, :, 0]
            tbl = (jnp.arange(_B)[None, None, :] >= sbin[:, :, None])
            iscat_w = jnp.zeros(gain.shape, bool)
            seg = jnp.where(act, loc, w_slots)
            pstats = jax.vmap(lambda s, v: jax.ops.segment_sum(
                v, s, num_segments=w_slots + 1))(
                    seg, jnp.where(act[:, :, None], stats, 0.0))
            ps = score_stats(pstats[:, :w_slots], kind, l2)   # (K, W)
            return gain, feat, sbin, iscat_w, tbl, ps

        # ---- jnp path: explicit histogram + both scans under the same jit
        ws = jnp.where(act[:, :, None], stats, 0.0)           # (K, N, S)
        hists = []
        for j in range(kf):
            seg = jnp.where(act, locc * _B + cex[:, :, j], w_slots * _B)
            h = jax.vmap(lambda s, v: jax.ops.segment_sum(
                v, s, num_segments=w_slots * _B + 1))(seg, ws)
            hists.append(h[:, :w_slots * _B].reshape(K, w_slots, _B, S))
        hist = jnp.stack(hists, axis=2)                       # (K, W, kf, B, S)
        parent = hist.sum(axis=3)                             # (K, W, kf, S)

        g_num = _numerical_gains(hist, parent, kind, l2, min_ex)
        pos = jnp.arange(_B)[None, None, None, :]
        if cfg.cat_mode == "none":
            g = g_num
            order = None
        else:
            nb_sel = nbins[fsel_c][..., None]                 # (K, W, kf, 1)
            iscat_sel = iscat[fsel_c]                         # (K, W, kf)
            if cfg.cat_mode == "cart":
                key = jnp.where(pos >= nb_sel, jnp.inf, order_key(hist))
                order = jnp.argsort(key, axis=3, stable=True)
                hs = jnp.take_along_axis(hist, order[..., None], axis=3)
                cum = jnp.cumsum(hs, axis=3)
                right = parent[:, :, :, None, :] - cum
                g_cat = (score_stats(cum, kind, l2)
                         + score_stats(right, kind, l2)
                         - score_stats(parent, kind, l2)[..., None])
                ok = ((cum[..., -1] >= min_ex) & (right[..., -1] >= min_ex)
                      & (pos < nb_sel - 1))
                g_cat = jnp.where(ok, g_cat, NEG_INF)
            else:  # one category vs rest
                order = None
                rest = parent[:, :, :, None, :] - hist
                g_cat = (score_stats(hist, kind, l2)
                         + score_stats(rest, kind, l2)
                         - score_stats(parent, kind, l2)[..., None])
                ok = ((hist[..., -1] >= min_ex) & (rest[..., -1] >= min_ex)
                      & (pos < nb_sel))
                g_cat = jnp.where(ok, g_cat, NEG_INF)
            g = jnp.where(iscat_sel[..., None], g_cat, g_num)

        flat = g.reshape(K, w_slots, kf * _B)
        fi = jnp.argmax(flat, axis=2)                         # lowest (j, b)
        gain = jnp.max(flat, axis=2)
        ps = score_stats(parent[:, :, 0], kind, l2)           # (K, W)
        jwin = (fi // _B).astype(jnp.int32)
        bwin = (fi % _B).astype(jnp.int32)
        feat = jnp.take_along_axis(fsel_c, jwin[:, :, None], axis=2)[:, :, 0]
        if cfg.cat_mode == "none":
            iscat_w = jnp.zeros(gain.shape, bool)
        else:
            iscat_w = jnp.take_along_axis(
                iscat[fsel_c], jwin[:, :, None], axis=2)[:, :, 0]
        sbin = jnp.where(iscat_w, 0, bwin + 1)

        # go-right-by-code table for routing + the forest's category mask
        bins = jnp.arange(_B)[None, None, :]
        tbl_num = bins >= sbin[:, :, None]
        if cfg.cat_mode == "none":
            return gain, feat, sbin, iscat_w, tbl_num, ps
        nb_win = jnp.take_along_axis(
            nbins[fsel_c], jwin[:, :, None], axis=2)[:, :, 0]
        if cfg.cat_mode == "cart":
            owin = jnp.take_along_axis(
                order, jwin[:, :, None, None],
                axis=2)[:, :, 0]                              # (K, W, B)
            rank = jnp.argsort(owin, axis=2, stable=True)     # inverse perm
            tbl_cat = (rank > bwin[:, :, None]) & (bins < nb_win[:, :, None])
        else:
            tbl_cat = bins == bwin[:, :, None]
        tbl = jnp.where(iscat_w[:, :, None], tbl_cat, tbl_num)
        return gain, feat, sbin, iscat_w, tbl, ps

    @jax.jit
    def step(codes, nbins, iscat, stats, tree_ids, slot_of, slot_node,
             feat_a, sbin_a, catm_a, left_a, gain_a, lstats_a, nn, node_of,
             depth):
        K, P = slot_node.shape
        N = codes.shape[0]
        karange = jnp.arange(K)[:, None]

        # 1. candidate features per (tree, slot), keyed by (tree, node id)
        if cfg.sample:
            fsel = keyed_feature_select_jnp(
                cfg.sampling_key, tree_ids[:, None],
                jnp.maximum(slot_node, 0), cfg.F, kf)         # (K, P, kf)
        else:
            fsel = jnp.broadcast_to(jnp.arange(cfg.F, dtype=jnp.int32),
                                    (K, P, cfg.F))

        # 2. best split per slot, W slots at a time (bounds hist scratch)
        W = min(P, _W_CAP)
        outs = []
        for g0 in range(0, P, W):
            loc = jnp.where((slot_of >= g0) & (slot_of < g0 + W),
                            slot_of - g0, -1)
            outs.append(chunk_best(codes, nbins, iscat, stats,
                                   fsel[:, g0:g0 + W], loc, W))
        gain, feat_w, sbin_w, iscat_w, tbl, ps = (
            jnp.concatenate([o[i] for o in outs], axis=1) if len(outs) > 1
            else outs[0][i] for i in range(6))

        # 3. validity + child allocation (frontier-order, budget-capped).
        # The gain floor is scale-aware (splitters.REL_GAIN_EPS): f32 noise
        # around a true gain of 0 must not read as a valid split.
        floor = jnp.maximum(cfg.min_gain, _REL_EPS * jnp.abs(ps))
        valid = (gain > floor) & jnp.isfinite(gain) & (slot_node >= 0)
        vi = valid.astype(jnp.int32)
        rank = jnp.cumsum(vi, axis=1) - vi                    # exclusive
        valid &= nn[:, None] + 2 * (rank + 1) <= cfg.max_nodes
        left_id = jnp.where(valid, nn[:, None] + 2 * rank, -1)
        nv = valid.sum(axis=1).astype(jnp.int32)
        nn = nn + 2 * nv
        depth = depth + (nv > 0)

        # 4. write the chosen conditions into the device forest arrays
        pidx = jnp.where(valid, slot_node, M)                 # M drops
        feat_a = feat_a.at[karange, pidx].set(feat_w, mode="drop")
        sbin_a = sbin_a.at[karange, pidx].set(sbin_w, mode="drop")
        left_a = left_a.at[karange, pidx].set(left_id, mode="drop")
        gain_a = gain_a.at[karange, pidx].set(jnp.maximum(gain, 0.0),
                                              mode="drop")
        bits = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
        packed = (tbl.reshape(K, P, MASK_WORDS, 32).astype(jnp.uint32)
                  * bits).sum(axis=3, dtype=jnp.uint32)
        cidx = jnp.where(valid & iscat_w, slot_node, M)
        catm_a = catm_a.at[karange, cidx].set(packed, mode="drop")

        # 5. route every example of a split slot to its child
        slotc = jnp.maximum(slot_of, 0)
        route = (slot_of >= 0) & jnp.take_along_axis(valid, slotc, axis=1)
        f_ex = jnp.take_along_axis(feat_w, slotc, axis=1)     # (K, N)
        c_ex = codes[jnp.arange(N)[None, :], f_ex]
        go = tbl[karange, slotc, c_ex]
        l_ex = jnp.take_along_axis(left_id, slotc, axis=1)
        node_of = jnp.where(route, l_ex + go, node_of)
        r_ex = jnp.take_along_axis(rank, slotc, axis=1)
        slot_of = jnp.where(route, 2 * r_ex + go, -1)

        # 6. child stats in one segment-sum; new frontier = compacted children
        seg = jnp.where(slot_of >= 0, slot_of, 2 * P)
        csum = jax.vmap(lambda s, v: jax.ops.segment_sum(
            v, s, num_segments=2 * P + 1))(
                seg, jnp.where(slot_of[:, :, None] >= 0, stats, 0.0))
        csum = csum[:, :2 * P]                                # (K, 2P, S)
        child_node = jnp.full((K, 2 * P), -1, jnp.int32)
        lidx = jnp.where(valid, 2 * rank, 2 * P)
        child_node = child_node.at[karange, lidx].set(left_id, mode="drop")
        child_node = child_node.at[karange, lidx + 1].set(left_id + 1,
                                                          mode="drop")
        nidx = jnp.where(child_node >= 0, child_node, M)
        lstats_a = lstats_a.at[karange, nidx].set(csum, mode="drop")

        return (slot_of, child_node, feat_a, sbin_a, catm_a, left_a, gain_a,
                lstats_a, nn, node_of, depth, nv)

    return step


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def _device_codes(binned: BinnedFeatures):
    """codes as a device int32 array, cached on the BinnedFeatures instance
    (shared across trees, blocks, and boosting iterations)."""
    import jax.numpy as jnp
    cached = getattr(binned, "_device_codes", None)
    if cached is None:
        cached = (jnp.asarray(binned.codes.astype(np.int32)),
                  jnp.asarray(binned.n_bins.astype(np.int32)),
                  jnp.asarray(binned.is_cat))
        binned._device_codes = cached
    return cached


def grow_trees_device(forest: Forest, ts, binned: BinnedFeatures,
                      stats_list, actives, leaf_fn, params,
                      block: int | None = None) -> np.ndarray:
    """Grow trees ``ts`` of ``forest`` in device-resident lockstep. The block
    is padded to ``block`` trees so every block reuses one compiled program.
    Returns the final ``node_of`` routing, (len(ts), N) int32."""
    import jax
    import jax.numpy as jnp

    sp = params.splitter
    Kr = len(ts)
    K = max(Kr, block or Kr)
    N, F = binned.codes.shape
    S = stats_list[0].shape[1]
    M = min(forest.max_nodes, params.max_nodes)
    has_cat = bool(binned.is_cat.any())
    impl = _resolve_impl(getattr(params, "device_impl", "auto"), has_cat)
    one_hot = sp.categorical_algorithm == "ONE_HOT" or (
        sp.stat_kind == "class" and S > 3)
    cfg = _StepConfig(
        kind=sp.stat_kind, l2=float(sp.l2), min_examples=int(sp.min_examples),
        min_gain=float(sp.min_gain),
        cat_mode=("none" if not has_cat else
                  "onehot" if one_hot else "cart"),
        sample=sp.num_candidate_ratio < 1.0,
        sampling_key=int(params.sampling_key),
        kf=(sample_size(sp.num_candidate_ratio, F)
            if sp.num_candidate_ratio < 1.0 else F),
        F=F, S=S, M=M, max_nodes=int(params.max_nodes), impl=impl)
    step = _level_step(cfg)

    codes, nbins, iscat = _device_codes(binned)
    stats_np = np.zeros((K, N, S), np.float32)
    act_np = np.zeros((K, N), bool)
    for b in range(Kr):
        stats_np[b] = stats_list[b].astype(np.float32)
        act_np[b] = actives[b]
    stats = jnp.asarray(stats_np)
    node_of = jnp.asarray(np.where(act_np, 0, -1).astype(np.int32))
    slot_of = node_of
    slot_node = jnp.zeros((K, 1), jnp.int32)
    tree_ids = jnp.asarray(np.asarray(
        [int(t) for t in ts] + [0] * (K - Kr), np.int32))
    feat_a = jnp.full((K, M), -1, jnp.int32)
    sbin_a = jnp.zeros((K, M), jnp.int32)
    catm_a = jnp.zeros((K, M, MASK_WORDS), jnp.uint32)
    left_a = jnp.full((K, M), -1, jnp.int32)
    gain_a = jnp.zeros((K, M), jnp.float32)
    lstats_a = jnp.zeros((K, M, S), jnp.float32)
    lstats_a = lstats_a.at[:, 0].set(stats.sum(axis=1))
    nn = jnp.ones((K,), jnp.int32)
    depth = jnp.zeros((K,), jnp.int32)

    for _level in range(params.max_depth):
        # Tracing splits compile time from execute time per (cfg, shape
        # bucket): the first call at a new frontier bucket pays the jit
        # trace+compile, later calls replay the cached executable. The
        # block_until_ready sync only happens while a tracer is active —
        # the untraced path keeps the async dispatch pipeline intact.
        if trace.enabled():
            shape_key = (cfg, K, N, int(slot_node.shape[1]))
            first = shape_key not in _stepped_shapes
            _stepped_shapes.add(shape_key)
            with trace.span("grower_device/level_step", level=_level,
                            P=int(slot_node.shape[1]), compile=first):
                out = step(
                    codes, nbins, iscat, stats, tree_ids, slot_of,
                    slot_node, feat_a, sbin_a, catm_a, left_a, gain_a,
                    lstats_a, nn, node_of, depth)
                jax.block_until_ready(out)
        else:
            out = step(
                codes, nbins, iscat, stats, tree_ids, slot_of, slot_node,
                feat_a, sbin_a, catm_a, left_a, gain_a, lstats_a, nn,
                node_of, depth)
        (slot_of, slot_node, feat_a, sbin_a, catm_a, left_a, gain_a,
         lstats_a, nn, node_of, depth, nv) = out
        # the single per-level host sync: the compacted frontier width,
        # used to choose the next power-of-two shape bucket
        with trace.span("grower_device/host_sync", level=_level):
            nv_max = int(nv.max())
        if nv_max == 0:
            break
        P_next = _next_pow2(2 * nv_max)
        slot_node = slot_node[:, :P_next]

    # one fetch per block: decode device arrays into the host Forest
    with trace.span("grower_device/fetch", trees=Kr):
        (feat_h, sbin_h, catm_h, left_h, gain_h, lstats_h, nn_h, node_h,
         depth_h) = tuple(np.asarray(a) for a in
                          (feat_a, sbin_a, catm_a, left_a, gain_a, lstats_a,
                           nn, node_of, depth))
    for b, t in enumerate(ts):
        n_t = int(nn_h[b])
        forest.n_nodes[t] = n_t
        forest.feature[t, :M] = feat_h[b]
        forest.left_child[t, :M] = left_h[b]
        forest.cat_mask[t, :M] = catm_h[b]
        forest.split_bin[t, :M] = np.maximum(sbin_h[b], 0).astype(np.uint16)
        if forest.split_gain is not None:
            forest.split_gain[t, :M] = gain_h[b]
        for n in range(1, n_t):
            forest.leaf_value[t, n] = leaf_fn(lstats_h[b, n].astype(np.float64))
        for n in np.where((feat_h[b, :n_t] >= 0)
                          & ~binned.is_cat[np.maximum(feat_h[b, :n_t], 0)])[0]:
            f, sb = int(feat_h[b, n]), int(sbin_h[b, n])
            sb = min(sb, len(binned.boundaries[f]))
            forest.threshold[t, n] = binned.threshold_value(f, sb)
        forest.depth = max(forest.depth, int(depth_h[b]))
    return node_h[:Kr]
