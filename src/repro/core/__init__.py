"""Yggdrasil Decision Forests in JAX — the paper's primary contribution.

Public API (Learner–Model abstraction, §3.1):

    from repro.core import GradientBoostedTreesLearner, Task
    model = GradientBoostedTreesLearner(label="income").train(train_ds)
    print(model.evaluate(test_ds).report())
"""
from repro.core.api import (  # noqa: F401
    Learner,
    Model,
    Task,
    YdfError,
    get_learner,
    list_learners,
    make_learner,
    register_learner,
)
from repro.core.dataspec import (  # noqa: F401
    DataSpec,
    Semantic,
    VerticalDataset,
    dataset_from_raw,
    encode_dataset,
    infer_dataspec,
)
from repro.core.evaluation import Evaluation, evaluate_predictions  # noqa: F401


def __getattr__(name):
    # lazy: importing learners pulls numpy-heavy modules only when used
    lazy = {
        "GradientBoostedTreesLearner": "repro.core.gbt",
        "RandomForestLearner": "repro.core.rf",
        "CartLearner": "repro.core.cart",
        "LinearLearner": "repro.core.baselines",
        "HyperParameterTuner": "repro.core.metalearners",
        "Ensembler": "repro.core.metalearners",
        "Calibrator": "repro.core.metalearners",
        "FeatureSelector": "repro.core.metalearners",
        "cross_validate": "repro.core.metalearners",
        "benchmark_inference": "repro.core.engines",
        "CompiledPredictor": "repro.core.engines",
        "compile_predictor": "repro.core.engines",
    }
    if name in lazy:
        import importlib
        return getattr(importlib.import_module(lazy[name]), name)
    raise AttributeError(name)
