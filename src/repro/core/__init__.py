"""Yggdrasil Decision Forests in JAX — the paper's primary contribution.

Public API (Learner–Model abstraction, §3.1):

    from repro.core import GradientBoostedTreesLearner, Task
    model = GradientBoostedTreesLearner(label="income").train(train_ds)
    print(model.evaluate(test_ds).report())
"""
from repro.core.api import (  # noqa: F401
    EngineFailure,
    Learner,
    Model,
    Task,
    YdfError,
    get_learner,
    list_learners,
    make_learner,
    register_learner,
)
from repro.core.dataspec import (  # noqa: F401
    DataSpec,
    Semantic,
    VerticalDataset,
    dataset_from_raw,
    encode_dataset,
    infer_dataspec,
)
from repro.core.evaluation import Evaluation, evaluate_predictions  # noqa: F401


def __getattr__(name):
    # lazy: importing learners pulls numpy-heavy modules only when used
    lazy = {
        "GradientBoostedTreesLearner": "repro.core.gbt",
        "RandomForestLearner": "repro.core.rf",
        "CartLearner": "repro.core.cart",
        "LinearLearner": "repro.core.baselines",
        "HyperParameterTuner": "repro.core.metalearners",
        "Ensembler": "repro.core.metalearners",
        "Calibrator": "repro.core.metalearners",
        "FeatureSelector": "repro.core.metalearners",
        "cross_validate": "repro.core.metalearners",
        "benchmark_inference": "repro.core.engines",
        "CompiledPredictor": "repro.core.engines",
        "compile_predictor": "repro.core.engines",
        # typed tree API (DESIGN.md §7)
        "Tree": "repro.core.py_tree",
        "Leaf": "repro.core.py_tree",
        "NonLeaf": "repro.core.py_tree",
        "NumericalHigherThan": "repro.core.py_tree",
        "CategoricalIsIn": "repro.core.py_tree",
        "Oblique": "repro.core.py_tree",
        "ProbabilityValue": "repro.core.py_tree",
        "RegressionValue": "repro.core.py_tree",
        "LogitValue": "repro.core.py_tree",
        "ModelInspector": "repro.core.py_tree",
        "ModelBuilder": "repro.core.py_tree",
        "RandomForestBuilder": "repro.core.py_tree",
        "GradientBoostedTreesBuilder": "repro.core.py_tree",
        "CartBuilder": "repro.core.py_tree",
        "FeatureColumn": "repro.core.py_tree",
        # interop (train elsewhere, serve here)
        "from_sklearn": "repro.interop.sklearn",
        # analysis subsystem (DESIGN.md §8)
        "analyze_model": "repro.analysis",
        "AnalysisReport": "repro.analysis",
        "ImportanceTable": "repro.analysis",
        "PDPCurve": "repro.analysis",
        "permutation_importances": "repro.analysis",
        "oob_permutation_importances": "repro.analysis",
        "structural_importances": "repro.analysis",
        "partial_dependence": "repro.analysis",
    }
    if name in lazy:
        import importlib
        return getattr(importlib.import_module(lazy[name]), name)
    raise AttributeError(name)
