"""The typed tree API (DESIGN.md §7): inspect, edit and build forests.

The Forest SoA (repro/core/tree.py) is the *execution* format — flat arrays,
engine-friendly, closed. This module is the *manipulation* format: plain
dataclasses (``Leaf`` / ``NonLeaf`` with typed conditions and leaf values)
that round-trip with the SoA exactly:

    trees  = forest.to_trees()              # SoA -> typed nodes
    forest = Forest.from_trees(trees, like=forest)   # typed nodes -> SoA

Round-trips are bit-identical for compact forests (everything the growers
produce): ``NonLeaf.split_order`` preserves the original child-pair
allocation order, ``NonLeaf.value`` preserves the per-node statistics the
growers leave on internal nodes (CART pruning reads them), and conditions
carry both the raw-domain threshold and the binned split index.

On top of it:
  * ``ModelInspector`` — per-tree structure stats + plot_tree-style ASCII
    rendering (``DecisionForestModel.inspect()`` / ``summary(verbose=)``).
  * ``ModelBuilder`` subclasses — construct RandomForest / GBT / CART models
    from hand-written or converted trees, synthesizing the DataSpec so built
    models encode raw request dicts exactly like trained ones (§5.1) and flow
    unchanged through ``compile()``, the pallas engine and serving bundles.

Validation follows the paper's §2.1 error style: say what failed in task
terms, show the offending values, propose concrete fixes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Union

import numpy as np

from repro.core.api import Task, YdfError
from repro.core.dataspec import OOD, Column, DataSpec, Semantic
from repro.core.tree import MASK_WORDS, Forest, empty_forest

MAX_CATEGORY = MASK_WORDS * 32 - 1  # ids above this cannot be mask-encoded


# ===================================================================== values

@dataclass(frozen=True)
class ProbabilityValue:
    """A leaf holding a class distribution (RF / CART classification)."""
    probability: tuple[float, ...]

    def vector(self) -> np.ndarray:
        return np.asarray(self.probability, np.float32)


@dataclass(frozen=True)
class RegressionValue:
    """A leaf holding a scalar target estimate (regression trees)."""
    value: float

    def vector(self) -> np.ndarray:
        return np.asarray([self.value], np.float32)


@dataclass(frozen=True)
class LogitValue:
    """A leaf holding an additive score contribution (GBT trees)."""
    logit: float

    def vector(self) -> np.ndarray:
        return np.asarray([self.logit], np.float32)


AbstractValue = Union[ProbabilityValue, RegressionValue, LogitValue]


def value_from_vector(vec: np.ndarray, kind: str) -> AbstractValue:
    vec = np.asarray(vec)
    if kind == "probability":
        return ProbabilityValue(tuple(float(v) for v in vec))
    if kind == "logit":
        return LogitValue(float(vec[0]))
    if kind == "regression":
        return RegressionValue(float(vec[0]))
    raise YdfError(f"Unknown leaf-value kind {kind!r}. "
                   "Expected 'probability', 'regression' or 'logit'.")


# ================================================================= conditions

@dataclass(frozen=True)
class NumericalHigherThan:
    """Go to ``pos_child`` when ``x[feature] >= threshold``.

    ``split_bin`` is the binned-domain split index the training engines use;
    it is carried so SoA round-trips are exact, and may stay 0 for
    hand-written or imported trees (inference never reads it).
    """
    feature: int
    threshold: float
    split_bin: int = 0


@dataclass(frozen=True)
class CategoricalIsIn:
    """Go to ``pos_child`` when the category code of ``x[feature]`` is in
    ``categories``. Codes index the column's dictionary (0 = out-of-dict);
    ``ModelBuilder`` also accepts the category *strings* and resolves them
    against the feature's vocabulary."""
    feature: int
    categories: tuple = ()


@dataclass(frozen=True)
class Oblique:
    """Go to ``pos_child`` when ``sum_k weights[k] * x[features[k]] >=
    threshold`` (sparse-oblique, paper §3.8)."""
    features: tuple[int, ...]
    weights: tuple[float, ...]
    threshold: float


AbstractCondition = Union[NumericalHigherThan, CategoricalIsIn, Oblique]


# ====================================================================== nodes

@dataclass
class Leaf:
    value: AbstractValue

    @property
    def is_leaf(self) -> bool:
        return True


@dataclass
class NonLeaf:
    """``neg_child`` is taken when the condition is False, ``pos_child`` when
    True. ``value`` optionally carries the node-level statistics growers
    leave on internal nodes (CART pruning promotes them to leaf values).
    ``split_order`` is the SoA child-pair allocation rank; ``to_trees`` fills
    it so round-trips are bit-identical, hand-written trees may leave it None
    (children are then allocated in level order)."""
    condition: AbstractCondition
    neg_child: "AnyNode"
    pos_child: "AnyNode"
    value: AbstractValue | None = None
    split_order: int | None = None

    @property
    def is_leaf(self) -> bool:
        return False


AnyNode = Union[Leaf, NonLeaf]


@dataclass
class Tree:
    """One decision tree. ``tree_class`` is the GBT multiclass tree->class
    assignment (None outside multiclass GBT)."""
    root: AnyNode
    tree_class: int | None = None

    # ------------------------------------------------------------- traversal
    def iter_nodes(self) -> Iterator[tuple[AnyNode, int]]:
        """Yields (node, depth) in pre-order."""
        stack = [(self.root, 0)]
        while stack:
            node, d = stack.pop()
            yield node, d
            if not node.is_leaf:
                stack.append((node.pos_child, d + 1))
                stack.append((node.neg_child, d + 1))

    def leaves(self) -> list[Leaf]:
        return [n for n, _ in self.iter_nodes() if n.is_leaf]

    @property
    def n_nodes(self) -> int:
        return sum(1 for _ in self.iter_nodes())

    @property
    def n_leaves(self) -> int:
        return len(self.leaves())

    @property
    def depth(self) -> int:
        return max(d for _, d in self.iter_nodes())

    def pretty(self, *, feature_names: list[str] | None = None,
               cat_vocabs: dict[int, list[str]] | None = None,
               classes: list[str] | None = None, max_depth: int = 8) -> str:
        return render_tree(self, feature_names=feature_names,
                           cat_vocabs=cat_vocabs, classes=classes,
                           max_depth=max_depth)


# ============================================================== SoA -> trees

def _condition_at(forest: Forest, t: int, s: int) -> AbstractCondition:
    f = int(forest.feature[t, s])
    if f == -2:
        w = forest.obl_weights[t, s]
        fo = forest.obl_features[t, s]
        P = len(w)
        while P > 1 and w[P - 1] == 0.0 and fo[P - 1] == 0:
            P -= 1  # trailing zero padding is layout, not semantics
        return Oblique(features=tuple(int(v) for v in fo[:P]),
                       weights=tuple(float(v) for v in w[:P]),
                       threshold=float(forest.threshold[t, s]))
    if f < 0:
        raise YdfError(
            f"Tree {t} node {s} is internal (left_child="
            f"{int(forest.left_child[t, s])}) but has no condition "
            f"(feature={f}). The forest arrays are corrupt.")
    if forest.cat_mask[t, s].any():
        bits = np.unpackbits(forest.cat_mask[t, s].view(np.uint8),
                             bitorder="little")
        return CategoricalIsIn(
            feature=f, categories=tuple(int(c) for c in np.where(bits)[0]))
    return NumericalHigherThan(feature=f,
                               threshold=float(forest.threshold[t, s]),
                               split_bin=int(forest.split_bin[t, s]))


def forest_to_trees(forest: Forest, *, value_kind: str | None = None
                    ) -> list[Tree]:
    """Extract the reachable structure of every tree as typed nodes.

    ``value_kind`` selects the leaf wrapper ('probability' / 'regression' /
    'logit'); default: 'probability' when the leaf dimension is > 1, else
    'regression'. ``ModelInspector`` passes the model-accurate kind.
    """
    leaf_dim = forest.leaf_value.shape[-1]
    kind = value_kind or ("probability" if leaf_dim > 1 else "regression")
    trees: list[Tree] = []
    for t in range(forest.n_trees):
        lc = forest.left_child[t]
        order = [0]
        i = 0
        while i < len(order):
            s = order[i]
            i += 1
            if lc[s] >= 0:
                order += [int(lc[s]), int(lc[s]) + 1]
        node_of: dict[int, AnyNode] = {}
        for s in reversed(order):
            vec = forest.leaf_value[t, s]
            if lc[s] < 0:
                node_of[s] = Leaf(value=value_from_vector(vec, kind))
            else:
                left = int(lc[s])
                node_of[s] = NonLeaf(
                    condition=_condition_at(forest, t, s),
                    neg_child=node_of[left], pos_child=node_of[left + 1],
                    value=(value_from_vector(vec, kind) if vec.any() else None),
                    split_order=((left - 1) // 2 if left % 2 == 1 else None))
        tc = (int(forest.tree_class[t])
              if forest.tree_class is not None else None)
        trees.append(Tree(root=node_of[0], tree_class=tc))
    return trees


# ============================================================== trees -> SoA

def _resolve_categories(cond: CategoricalIsIn, ti: int,
                        cat_vocabs: dict[int, list[str]] | None) -> list[int]:
    codes: list[int] = []
    for c in cond.categories:
        if isinstance(c, (int, np.integer)):
            codes.append(int(c))
            continue
        vocab = (cat_vocabs or {}).get(cond.feature)
        if vocab is None:
            raise YdfError(
                f"Tree {ti}: CategoricalIsIn on feature {cond.feature} uses "
                f"the category string {c!r} but no vocabulary is known for "
                "that feature. Solutions: (1) use integer category codes, or "
                "(2) build through ModelBuilder with a CATEGORICAL feature "
                "declaring its vocabulary.")
        if str(c) not in vocab:
            raise YdfError(
                f"Tree {ti}: category {c!r} is not in the vocabulary of "
                f"feature {cond.feature}: {vocab}. Solution: declare it in "
                "the feature's vocabulary or drop it from the condition.")
        codes.append(vocab.index(str(c)))
    return codes


def _validate_condition(cond, ti: int, n_features: int | None,
                        cat_vocabs) -> list[int] | None:
    """Returns resolved category codes for CategoricalIsIn, else None."""
    if isinstance(cond, NumericalHigherThan):
        if not np.isfinite(cond.threshold):
            raise YdfError(
                f"Tree {ti}: NumericalHigherThan(feature={cond.feature}) has "
                f"a non-finite threshold ({cond.threshold}). Solution: use a "
                "finite float threshold.")
        if not 0 <= int(cond.split_bin) <= 0xFFFF:
            raise YdfError(
                f"Tree {ti}: split_bin={cond.split_bin} does not fit uint16. "
                "Solution: leave split_bin at 0 for hand-written trees.")
        feats = [cond.feature]
    elif isinstance(cond, CategoricalIsIn):
        codes = _resolve_categories(cond, ti, cat_vocabs)
        if not codes:
            raise YdfError(
                f"Tree {ti}: CategoricalIsIn(feature={cond.feature}) has an "
                "empty category set — the SoA encodes categorical tests as "
                "bit masks and an empty mask means 'numerical'. Solution: "
                "put at least one category in the set, or replace the node "
                "by its neg_child.")
        bad = [c for c in codes if not 0 <= c <= MAX_CATEGORY]
        if bad:
            raise YdfError(
                f"Tree {ti}: category code(s) {bad} out of the supported "
                f"range [0, {MAX_CATEGORY}] (the SoA stores {MASK_WORDS}*32 "
                "category bits per node). Solution: re-map rare categories "
                "into the dictionary's first 256 entries.")
        feats = [cond.feature]
    elif isinstance(cond, Oblique):
        if len(cond.features) != len(cond.weights) or not cond.features:
            raise YdfError(
                f"Tree {ti}: Oblique condition has {len(cond.features)} "
                f"feature(s) but {len(cond.weights)} weight(s); both must be "
                "equal-length and non-empty.")
        if not (np.isfinite(cond.threshold)
                and np.isfinite(cond.weights).all()):
            raise YdfError(
                f"Tree {ti}: Oblique condition has non-finite threshold or "
                f"weights (threshold={cond.threshold}, "
                f"weights={cond.weights}).")
        feats = list(cond.features)
    else:
        raise YdfError(
            f"Tree {ti}: unsupported condition type {type(cond).__name__!r}. "
            "Supported: NumericalHigherThan, CategoricalIsIn, Oblique.")
    for f in feats:
        if not isinstance(f, (int, np.integer)) or f < 0:
            raise YdfError(
                f"Tree {ti}: condition references feature {f!r}; features "
                "are referenced by non-negative column index into the "
                "model's feature list.")
        if n_features is not None and f >= n_features:
            raise YdfError(
                f"Tree {ti}: condition references feature index {int(f)} but "
                f"the model has only {n_features} input feature(s). "
                "Solutions: (1) fix the feature index, or (2) declare the "
                "missing feature column.")
    return codes if isinstance(cond, CategoricalIsIn) else None


def _leaf_vector(value, ti: int, leaf_dim: int | None) -> np.ndarray:
    if not hasattr(value, "vector"):
        raise YdfError(
            f"Tree {ti}: leaf value {value!r} is not a typed value. Wrap it "
            "as ProbabilityValue / RegressionValue / LogitValue.")
    vec = value.vector()
    if not np.isfinite(vec).all():
        raise YdfError(
            f"Tree {ti}: leaf value {value!r} contains non-finite entries.")
    if leaf_dim is not None and len(vec) != leaf_dim:
        raise YdfError(
            f"Tree {ti}: leaf value has dimension {len(vec)} but the forest "
            f"leaf dimension is {leaf_dim} (every leaf must agree; "
            "classification leaves carry one probability per class). "
            f"Offending value: {value!r}.")
    return vec


@dataclass
class _TreeLayout:
    nodes: list  # BFS list of (node, slot, depth)
    ranks: dict  # id(internal node) -> child-pair allocation rank
    n_nodes: int
    depth: int


def _layout_tree(tr: Tree, ti: int, max_nodes: int | None) -> _TreeLayout:
    """Assign SoA slots: root at 0, the k-th split's children at (1+2k, 2+2k).

    Ranks come from ``split_order`` when every internal node carries a
    consistent hint (bit-identical round-trips); otherwise — hand-written or
    edited trees — ranks are assigned in level order.
    """
    if not isinstance(tr, Tree):
        raise YdfError(
            f"Expected a py_tree.Tree at index {ti}, got {type(tr).__name__}."
            " Wrap the root node: Tree(root=node).")
    # BFS collect, with cycle/DAG detection
    order: list[tuple[AnyNode, AnyNode | None, int]] = [(tr.root, None, 0)]
    seen: set[int] = {id(tr.root)}
    i = 0
    internals: list[NonLeaf] = []
    depth = 0
    while i < len(order):
        node, _, d = order[i]
        i += 1
        depth = max(depth, d)
        if node.is_leaf:
            continue
        if not isinstance(node, NonLeaf):
            raise YdfError(
                f"Tree {ti}: node {node!r} is neither Leaf nor NonLeaf.")
        internals.append(node)
        for child in (node.neg_child, node.pos_child):
            if id(child) in seen:
                raise YdfError(
                    f"Tree {ti}: the same node object appears twice — trees "
                    "must be trees, not DAGs or cycles. Solution: "
                    "copy.deepcopy the shared subtree.")
            seen.add(id(child))
            order.append((child, node, d + 1))
    S = len(internals)
    n_nodes = 1 + 2 * S
    if max_nodes is not None and n_nodes > max_nodes:
        raise YdfError(
            f"Tree {ti} needs {n_nodes} node slots ({S} splits) but the "
            f"node budget is max_nodes={max_nodes}. Solutions: (1) raise "
            "max_nodes, or (2) prune the tree.")
    # ranks: honor split_order hints when complete and consistent
    ranks: dict[int, int] | None = {}
    hints = [n.split_order for n in internals]
    if S and all(h is not None for h in hints):
        if sorted(hints) != list(range(S)):
            ranks = None
        else:
            for n in internals:
                ranks[id(n)] = int(n.split_order)
            for node, parent, _ in order:
                if (ranks is not None and parent is not None
                        and not node.is_leaf
                        and ranks[id(node)] <= ranks[id(parent)]):
                    ranks = None  # child allocated before its parent: invalid
                    break
    else:
        ranks = None
    if ranks is None:  # level-order fallback
        ranks = {id(n): r for r, n in enumerate(internals)}
    # slots from parent ranks
    slot: dict[int, int] = {id(tr.root): 0}
    nodes = []
    for node, parent, d in order:
        if parent is not None:
            base = 1 + 2 * ranks[id(parent)]
            slot[id(node)] = base + (1 if node is parent.pos_child else 0)
        nodes.append((node, slot[id(node)], d))
    return _TreeLayout(nodes=nodes, ranks=ranks, n_nodes=n_nodes, depth=depth)


def forest_from_trees(trees: list[Tree], *,
                      feature_names: list[str] | None = None,
                      n_features: int | None = None,
                      out_dim: int | None = None,
                      max_nodes: int | None = None,
                      oblique_dims: int | None = None,
                      init_pred: np.ndarray | None = None,
                      tree_class: str = "auto",
                      depth: int | None = None,
                      cat_vocabs: dict[int, list[str]] | None = None,
                      like: Forest | None = None) -> Forest:
    """Build a Forest SoA from typed trees, validating as it goes.

    ``like`` copies layout metadata (capacity, leaf/out dims, oblique
    projection width, feature names, init_pred, depth) from an existing
    forest so ``Forest.from_trees(f.to_trees(), like=f)`` is bit-identical.
    Without ``like`` the layout is sized to fit the trees exactly.
    """
    if not trees:
        raise YdfError("from_trees needs at least one Tree; got an empty "
                       "list. Solution: add a tree, e.g. "
                       "Tree(root=Leaf(value=RegressionValue(0.0))).")
    if like is not None:
        feature_names = (like.feature_names if feature_names is None
                         else feature_names)
        n_features = (len(like.feature_names) or None) if n_features is None \
            else n_features
        out_dim = like.out_dim if out_dim is None else out_dim
        max_nodes = like.max_nodes if max_nodes is None else max_nodes
        if oblique_dims is None:
            oblique_dims = (0 if like.obl_weights is None
                            else like.obl_weights.shape[-1])
        init_pred = like.init_pred if init_pred is None else init_pred
        depth = like.depth if depth is None else depth
    if feature_names and n_features is None:
        n_features = len(feature_names)

    # -------- validate + layout every tree
    layouts: list[_TreeLayout] = []
    leaf_dim: int | None = None
    max_obl = 0
    max_feat = -1
    for ti, tr in enumerate(trees):
        layout = _layout_tree(tr, ti, max_nodes)
        for node, _, _ in layout.nodes:
            if node.is_leaf:
                vec = _leaf_vector(node.value, ti, leaf_dim)
                leaf_dim = len(vec) if leaf_dim is None else leaf_dim
            else:
                _validate_condition(node.condition, ti, n_features, cat_vocabs)
                if isinstance(node.condition, Oblique):
                    max_obl = max(max_obl, len(node.condition.features))
                    max_feat = max(max_feat, *node.condition.features)
                else:
                    max_feat = max(max_feat, node.condition.feature)
                if node.value is not None:
                    _leaf_vector(node.value, ti, leaf_dim)
        layouts.append(layout)
    if n_features is None:
        n_features = max_feat + 1
    if oblique_dims is None:
        oblique_dims = max_obl
    elif max_obl > oblique_dims:
        raise YdfError(
            f"An Oblique condition projects over {max_obl} features but the "
            f"forest's oblique projection width is {oblique_dims}. Solution: "
            f"pass oblique_dims>={max_obl} (or drop `like=`).")
    if max_nodes is None:
        max_nodes = max(l.n_nodes for l in layouts)

    T = len(trees)
    forest = empty_forest(
        T, max_nodes, out_dim or (leaf_dim or 1),
        oblique_dims=oblique_dims,
        feature_names=list(feature_names or [f"f{j}" for j in range(n_features)]))
    # empty_forest sizes leaf_value by out_dim; the leaf dim can differ
    # (GBT multiclass: scalar leaves + tree->class map)
    if (leaf_dim or 1) != forest.leaf_value.shape[-1]:
        forest.leaf_value = np.zeros((T, max_nodes, leaf_dim), np.float32)
    forest.out_dim = out_dim or (leaf_dim or 1)
    if init_pred is not None:
        forest.init_pred = np.asarray(init_pred, np.float32).copy()
    else:
        forest.init_pred = np.zeros(forest.out_dim, np.float32)

    computed_depth = 0
    for t, (tr, layout) in enumerate(zip(trees, layouts)):
        forest.n_nodes[t] = layout.n_nodes
        computed_depth = max(computed_depth, layout.depth)
        for node, s, _ in layout.nodes:
            if node.is_leaf:
                forest.leaf_value[t, s] = node.value.vector()
                continue
            if node.value is not None:
                forest.leaf_value[t, s] = node.value.vector()
            cond = node.condition
            forest.left_child[t, s] = 1 + 2 * layout.ranks[id(node)]
            if isinstance(cond, Oblique):
                forest.feature[t, s] = -2
                k = len(cond.features)
                forest.obl_features[t, s, :k] = cond.features
                forest.obl_weights[t, s, :k] = cond.weights
                forest.threshold[t, s] = cond.threshold
            elif isinstance(cond, CategoricalIsIn):
                forest.feature[t, s] = cond.feature
                for c in _resolve_categories(cond, t, cat_vocabs):
                    forest.cat_mask[t, s, c // 32] |= \
                        np.uint32(1) << np.uint32(c % 32)
            else:
                forest.feature[t, s] = cond.feature
                forest.threshold[t, s] = cond.threshold
                forest.split_bin[t, s] = cond.split_bin
    # depth is the engines' traversal bound: honor a larger stored depth
    # (truncated forests keep the pre-truncation max) but never a smaller
    # one — an edit that deepens a tree must deepen the bound too, or
    # inference silently stops above the new leaves
    forest.depth = max(computed_depth, depth or 0)

    classes_of = [tr.tree_class for tr in trees]
    if tree_class == "none" or all(c is None for c in classes_of):
        forest.tree_class = None
    else:
        forest.tree_class = np.asarray(
            [0 if c is None else int(c) for c in classes_of], np.int32)
    return forest


# ============================================================== ASCII render

def _fname(j: int, feature_names: list[str] | None) -> str:
    if feature_names and 0 <= j < len(feature_names):
        return f'"{feature_names[j]}"'
    return f'"f{j}"'


def _condition_str(cond: AbstractCondition,
                   feature_names: list[str] | None,
                   cat_vocabs: dict[int, list[str]] | None) -> str:
    if isinstance(cond, NumericalHigherThan):
        return f"{_fname(cond.feature, feature_names)} >= {cond.threshold:g}"
    if isinstance(cond, CategoricalIsIn):
        vocab = (cat_vocabs or {}).get(cond.feature)
        names = [vocab[c] if vocab and isinstance(c, (int, np.integer))
                 and c < len(vocab) else str(c) for c in cond.categories]
        shown = names[:6] + (["..."] if len(names) > 6 else [])
        return (f"{_fname(cond.feature, feature_names)} in "
                "{" + ", ".join(shown) + "}")
    terms = " + ".join(f"{w:g}*{_fname(f, feature_names)}"
                       for f, w in zip(cond.features, cond.weights))
    return f"{terms} >= {cond.threshold:g}"


def _value_str(value: AbstractValue, classes: list[str] | None) -> str:
    if isinstance(value, ProbabilityValue):
        p = value.probability
        if classes and len(classes) == len(p):
            inner = ", ".join(f"{c}:{v:.3g}" for c, v in zip(classes, p))
        else:
            inner = ", ".join(f"{v:.3g}" for v in p)
        return f"p=[{inner}]"
    if isinstance(value, LogitValue):
        return f"logit={value.logit:g}"
    return f"value={value.value:g}"


def render_tree(tree: Tree, *, feature_names: list[str] | None = None,
                cat_vocabs: dict[int, list[str]] | None = None,
                classes: list[str] | None = None, max_depth: int = 8) -> str:
    """plot_tree-style ASCII rendering (paper §4.1 show_model artefacts)."""
    lines: list[str] = []
    # iterative: imported trees can be deeper than the recursion limit
    stack = [(tree.root, "", "", 0)]
    while stack:
        node, prefix, tag, depth = stack.pop()
        head = f"{tag} " if tag else ""
        if node.is_leaf:
            lines.append(prefix + head + _value_str(node.value, classes))
            continue
        lines.append(prefix + head + _condition_str(
            node.condition, feature_names, cat_vocabs))
        bar = prefix + ("│   " if tag.startswith("├") else "    ")
        if depth >= max_depth:
            lines.append(bar + "... (max_depth reached)")
            continue
        stack.append((node.neg_child, bar, "└─(neg)", depth + 1))
        stack.append((node.pos_child, bar, "├─(pos)", depth + 1))
    return "\n".join(lines)


# ================================================================= inspector

class ModelInspector:
    """Read-side of the typed API: iterate a model's trees, per-tree
    structure stats, ASCII rendering. Conversion is lazy and cached."""

    def __init__(self, model):
        self.model = model
        self._trees: list[Tree] | None = None

    @property
    def value_kind(self) -> str:
        from repro.core.models import GradientBoostedTreesModel
        if isinstance(self.model, GradientBoostedTreesModel):
            return "logit"
        return ("probability" if self.model.task == Task.CLASSIFICATION
                else "regression")

    def trees(self) -> list[Tree]:
        if self._trees is None:
            self._trees = forest_to_trees(self.model.forest,
                                          value_kind=self.value_kind)
        return self._trees

    def iter_trees(self) -> Iterator[Tree]:
        return iter(self.trees())

    def tree(self, i: int) -> Tree:
        trees = self.trees()
        if not 0 <= i < len(trees):
            raise YdfError(f"Tree index {i} out of range: the model has "
                           f"{len(trees)} trees.")
        return trees[i]

    @property
    def n_trees(self) -> int:
        return self.model.forest.n_trees

    def tree_stats(self) -> list[dict]:
        return [{"tree": i, "depth": tr.depth, "n_nodes": tr.n_nodes,
                 "n_leaves": tr.n_leaves, "tree_class": tr.tree_class}
                for i, tr in enumerate(self.trees())]

    def stats_summary(self) -> dict:
        st = self.tree_stats()
        depths = np.array([s["depth"] for s in st])
        leaves = np.array([s["n_leaves"] for s in st])
        return {"n_trees": len(st),
                "depth_min": int(depths.min()), "depth_max": int(depths.max()),
                "depth_mean": float(depths.mean()),
                "leaves_mean": float(leaves.mean()),
                "leaves_total": int(leaves.sum())}

    def _cat_vocabs(self) -> dict[int, list[str]]:
        out = {}
        for j, name in enumerate(self.model.features):
            col = self.model.spec[name]
            if col.semantic == Semantic.CATEGORICAL:
                out[j] = list(col.vocab)
        return out

    def plot_tree(self, i: int = 0, max_depth: int = 8) -> str:
        return self.tree(i).pretty(
            feature_names=list(self.model.features),
            cat_vocabs=self._cat_vocabs(),
            classes=getattr(self.model, "classes", None),
            max_depth=max_depth)


# ==================================================================== builder

@dataclass
class FeatureColumn:
    """A feature declaration for DataSpec synthesis. ``mean`` is the
    numerical imputation value served for missing inputs; ``vocab`` is the
    categorical dictionary in frequency order (most frequent first — code 1
    doubles as the categorical imputation, like trained models)."""
    name: str
    semantic: Semantic = Semantic.NUMERICAL
    vocab: tuple[str, ...] = ()
    mean: float = 0.0


def _coerce_feature(obj, idx: int) -> FeatureColumn:
    if isinstance(obj, FeatureColumn):
        return obj
    if isinstance(obj, str):
        return FeatureColumn(name=obj)
    if isinstance(obj, (tuple, list)) and len(obj) >= 2:
        name, sem = obj[0], Semantic(obj[1]) if not isinstance(obj[1], Semantic) else obj[1]
        vocab = tuple(obj[2]) if len(obj) > 2 else ()
        if sem == Semantic.CATEGORICAL and not vocab:
            raise YdfError(
                f"Feature {name!r} is CATEGORICAL but declares no "
                "vocabulary. Solution: pass (name, 'CATEGORICAL', "
                "['red', 'blue', ...]) in frequency order.")
        return FeatureColumn(name=name, semantic=sem, vocab=vocab)
    raise YdfError(
        f"Cannot interpret feature declaration #{idx}: {obj!r}. Accepted: a "
        "name (NUMERICAL), a (name, semantic[, vocab]) tuple, or a "
        "FeatureColumn.")


def synthesize_dataspec(features: list[FeatureColumn], label: str,
                        task: Task, classes: list[str] | None) -> DataSpec:
    """Build the DataSpec a trained model would have carried, so built
    models encode raw request dicts exactly like trained ones (§5.1)."""
    columns: dict[str, Column] = {}
    for fc in features:
        if fc.name == label:
            raise YdfError(f"Feature {fc.name!r} collides with the label "
                           "column name. Solution: rename one of them.")
        if fc.semantic == Semantic.CATEGORICAL:
            vocab = [OOD] + [str(v) for v in fc.vocab]
            if len(set(vocab)) != len(vocab):
                raise YdfError(
                    f"Feature {fc.name!r} has duplicate vocabulary entries: "
                    f"{list(fc.vocab)}.")
            columns[fc.name] = Column(
                name=fc.name, semantic=Semantic.CATEGORICAL, vocab=vocab,
                counts={v: len(vocab) - i for i, v in enumerate(vocab[1:])},
                manually_defined=True)
        else:
            columns[fc.name] = Column(
                name=fc.name, semantic=fc.semantic, mean=fc.mean,
                manually_defined=True)
    if task == Task.CLASSIFICATION:
        vocab = [OOD] + [str(c) for c in (classes or [])]
        columns[label] = Column(
            name=label, semantic=Semantic.CATEGORICAL, vocab=vocab,
            counts={v: len(vocab) - i for i, v in enumerate(vocab[1:])},
            manually_defined=True)
    else:
        columns[label] = Column(name=label, semantic=Semantic.NUMERICAL,
                                manually_defined=True)
    return DataSpec(columns=columns, n_rows=0)


class ModelBuilder:
    """Base of the write-side API: accumulate typed trees, synthesize the
    DataSpec, emit a servable model. Subclasses fix the model family."""

    def __init__(self, *, label: str, features,
                 task: Task = Task.CLASSIFICATION,
                 classes: list[str] | None = None):
        self.label = label
        self.task = task
        self.features = [_coerce_feature(f, i) for i, f in enumerate(features)]
        names = [f.name for f in self.features]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise YdfError(f"Duplicate feature name(s): {dup}.")
        if task == Task.CLASSIFICATION:
            if not classes or len(classes) < 2:
                raise YdfError(
                    "A classification ModelBuilder needs the label classes "
                    f"(got {classes!r}). Solution: pass classes=['no', 'yes'] "
                    "in the probability-column order the leaves use.")
            self.classes: list[str] | None = [str(c) for c in classes]
        else:
            self.classes = None
        self.trees: list[Tree] = []

    # ------------------------------------------------------------ helpers
    @property
    def n_classes(self) -> int:
        return len(self.classes) if self.classes else 0

    def _cat_vocabs(self) -> dict[int, list[str]]:
        return {j: [OOD] + [str(v) for v in fc.vocab]
                for j, fc in enumerate(self.features)
                if fc.semantic == Semantic.CATEGORICAL}

    def add_tree(self, tree: Tree | AnyNode) -> "ModelBuilder":
        if isinstance(tree, (Leaf, NonLeaf)):
            tree = Tree(root=tree)
        self.trees.append(tree)
        return self

    def _spec(self) -> DataSpec:
        return synthesize_dataspec(self.features, self.label, self.task,
                                   self.classes)

    def _check_leaf_kind(self, allowed: tuple, leaf_dim: int) -> None:
        for ti, tr in enumerate(self.trees):
            for node, _ in tr.iter_nodes():
                if not node.is_leaf:
                    continue
                if not isinstance(node.value, allowed):
                    names = "/".join(a.__name__ for a in allowed)
                    raise YdfError(
                        f"Tree {ti}: {type(self).__name__} expects {names} "
                        f"leaves, got {type(node.value).__name__}. Solution: "
                        "wrap leaf values in the matching type.")
                vec = node.value.vector()
                if len(vec) != leaf_dim:
                    raise YdfError(
                        f"Tree {ti}: leaf dimension {len(vec)} != expected "
                        f"{leaf_dim} ({'one probability per class' if leaf_dim > 1 else 'a scalar'}).")
                if isinstance(node.value, ProbabilityValue):
                    s = float(vec.sum())
                    if not np.isclose(s, 1.0, atol=1e-3):
                        raise YdfError(
                            f"Tree {ti}: ProbabilityValue sums to {s:.4g}, "
                            "not 1. Solution: normalize the distribution "
                            "(or use RegressionValue for raw scores).")

    def build(self):
        raise NotImplementedError


class RandomForestBuilder(ModelBuilder):
    """Builds a ``RandomForestModel``: classification leaves are class
    distributions averaged (or majority-voted) across trees; regression
    leaves are scalar estimates averaged across trees."""

    def __init__(self, *, winner_take_all: bool = False, **kw):
        super().__init__(**kw)
        self.winner_take_all = winner_take_all

    def build(self, *, max_nodes: int | None = None):
        if not self.trees:
            raise YdfError(f"{type(self).__name__} has no trees; call "
                           "add_tree() before build().")
        leaf_dim = self.n_classes if self.task == Task.CLASSIFICATION else 1
        self._check_leaf_kind(
            (ProbabilityValue,) if leaf_dim > 1 else (RegressionValue,),
            leaf_dim)
        forest = forest_from_trees(
            self.trees, feature_names=[f.name for f in self.features],
            out_dim=leaf_dim, max_nodes=max_nodes, tree_class="none",
            cat_vocabs=self._cat_vocabs())
        return self._model_cls()(
            winner_take_all=self.winner_take_all, forest=forest,
            spec=self._spec(), features=[f.name for f in self.features],
            label=self.label, task=self.task, classes=self.classes)

    def _model_cls(self):
        from repro.core.models import RandomForestModel
        return RandomForestModel


class CartBuilder(RandomForestBuilder):
    """Builds a single-tree ``CartModel``."""

    def build(self, *, max_nodes: int | None = None):
        if len(self.trees) != 1:
            raise YdfError(
                f"CartBuilder builds exactly one tree, got {len(self.trees)}."
                " Solution: use RandomForestBuilder for multi-tree models.")
        return super().build(max_nodes=max_nodes)

    def _model_cls(self):
        from repro.core.models import CartModel
        return CartModel


class GradientBoostedTreesBuilder(ModelBuilder):
    """Builds a ``GradientBoostedTreesModel``: leaves are additive logit /
    score contributions, summed per class (``tree_class`` routes multiclass
    trees) on top of ``init_pred``, then passed through the task's
    activation (sigmoid / softmax / identity)."""

    def __init__(self, *, init_pred=None, **kw):
        super().__init__(**kw)
        from repro.core.losses import make_loss
        self.loss = make_loss(self.task, "DEFAULT", self.n_classes)
        self.init_pred = np.zeros(self.loss.out_dim, np.float32) \
            if init_pred is None else np.asarray(init_pred, np.float32)
        if self.init_pred.shape != (self.loss.out_dim,):
            raise YdfError(
                f"init_pred has shape {self.init_pred.shape}, expected "
                f"({self.loss.out_dim},) — one bias per output dimension "
                f"({self.loss.name}).")

    def add_tree(self, tree: Tree | AnyNode,
                 tree_class: int | None = None) -> "ModelBuilder":
        if isinstance(tree, (Leaf, NonLeaf)):
            tree = Tree(root=tree)
        if tree_class is not None:
            tree = dataclasses.replace(tree, tree_class=tree_class)
        self.trees.append(tree)
        return self

    def build(self, *, max_nodes: int | None = None):
        from repro.core.models import GradientBoostedTreesModel
        if not self.trees:
            raise YdfError("GradientBoostedTreesBuilder has no trees; call "
                           "add_tree() before build().")
        K = self.loss.out_dim
        self._check_leaf_kind((LogitValue, RegressionValue), 1)
        if K > 1:
            missing = [i for i, tr in enumerate(self.trees)
                       if tr.tree_class is None]
            if missing:
                raise YdfError(
                    f"Multiclass GBT ({K} classes) needs a tree_class on "
                    f"every tree; tree(s) {missing[:5]} have none. Solution: "
                    "add_tree(tree, tree_class=k) with k in "
                    f"[0, {K - 1}].")
            bad = [i for i, tr in enumerate(self.trees)
                   if not 0 <= tr.tree_class < K]
            if bad:
                raise YdfError(
                    f"tree_class out of range on tree(s) {bad[:5]}; must be "
                    f"in [0, {K - 1}].")
        forest = forest_from_trees(
            self.trees, feature_names=[f.name for f in self.features],
            out_dim=K, max_nodes=max_nodes,
            tree_class="auto" if K > 1 else "none",
            init_pred=self.init_pred, cat_vocabs=self._cat_vocabs())
        if K > 1 and forest.tree_class is None:
            forest.tree_class = np.zeros(forest.n_trees, np.int32)
        return GradientBoostedTreesModel(
            loss=self.loss, forest=forest, spec=self._spec(),
            features=[f.name for f in self.features], label=self.label,
            task=self.task, classes=self.classes)
