"""The Learner–Model abstraction (paper §3.1) and the registries (§3.5).

A MODEL is a function observation -> prediction. A LEARNER is a function
examples -> Model. Training and inference logic are deliberately separated
(unlike fit/predict estimators): different Learners can produce the same Model
type, Models deploy without their Learner, and meta-learners compose Learners
generically (§3.2).

Registration mirrors YDF's ``REGISTER_AbstractLearner``:

    @register_learner("GRADIENT_BOOSTED_TREES")
    class GradientBoostedTreesLearner(Learner): ...

Error messages follow the paper's §2.1/§2.2 guidance: say what failed in task
terms, show the offending values, and propose concrete fixes.
"""
from __future__ import annotations

import abc
import dataclasses
import enum
import json
import os
import pickle
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


class Task(enum.Enum):
    CLASSIFICATION = "CLASSIFICATION"
    REGRESSION = "REGRESSION"
    RANKING = "RANKING"


class YdfError(ValueError):
    """An error with directions (paper Table 1b style)."""


# --------------------------------------------------------------------- Model

class Model(abc.ABC):
    """observation -> prediction. Serializable, inspectable, engine-compilable."""

    task: Task
    label: str

    @abc.abstractmethod
    def predict(self, dataset) -> np.ndarray:
        """Classification: (N, n_classes) probabilities. Regression: (N,)."""

    def predict_class(self, dataset) -> np.ndarray:
        p = self.predict(dataset)
        if self.task != Task.CLASSIFICATION:
            raise YdfError(
                f"predict_class requires a classification model, got task={self.task}. "
                "Use predict() for regression/ranking predictions.")
        return np.argmax(p, axis=-1)

    def evaluate(self, dataset) -> "Evaluation":
        from repro.core.evaluation import evaluate_predictions
        from repro.core.dataspec import label_values
        y = label_values(self, dataset)
        return evaluate_predictions(self.task, self.predict(dataset), y,
                                    classes=getattr(self, "classes", None))

    # ---- self-description (show_model analogue)
    def summary(self) -> str:
        return f"{type(self).__name__}(task={self.task.value}, label={self.label!r})"

    def variable_importances(self) -> dict[str, dict[str, float]]:
        return {}

    # ---- engines (§3.7): lossy compilation to the fastest compatible engine
    def compile(self, engine: str | None = None):
        raise YdfError(
            f"{type(self).__name__} has no inference engines. Engines exist for "
            "decision-forest models (see repro.core.engines).")

    # ---- serialization: backwards-compatible via format version tag
    FORMAT_VERSION = 1

    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        meta = {"format_version": self.FORMAT_VERSION, "class": type(self).__name__}
        with open(os.path.join(path, "header.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(path, "model.pkl"), "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: str) -> "Model":
        with open(os.path.join(path, "header.json")) as f:
            meta = json.load(f)
        if meta["format_version"] > Model.FORMAT_VERSION:
            raise YdfError(
                f"Model at {path!r} was saved with format v{meta['format_version']}, "
                f"this library reads up to v{Model.FORMAT_VERSION}. Solutions: (1) "
                "upgrade the library, or (2) re-export the model in an older format.")
        with open(os.path.join(path, "model.pkl"), "rb") as f:
            return pickle.load(f)


# --------------------------------------------------------------------- Learner

class Learner(abc.ABC):
    """examples -> Model. Hyper-parameters are fixed at construction; ``train``
    is deterministic given (hyper-parameters, dataset, seed) — paper §3.11."""

    def __init__(self, label: str, task: Task = Task.CLASSIFICATION, *,
                 seed: int = 1234, **hparams):
        self.label = label
        self.task = task
        self.seed = seed
        self.hparams = self.default_hparams()
        unknown = set(hparams) - set(dataclasses.asdict(self.hparams))
        if unknown:
            known = sorted(dataclasses.asdict(self.hparams))
            raise YdfError(
                f"Unknown hyper-parameter(s) {sorted(unknown)} for "
                f"{type(self).__name__}. Known hyper-parameters: {known}.")
        self.hparams = dataclasses.replace(self.hparams, **hparams)

    @abc.abstractmethod
    def train(self, dataset, valid=None) -> Model:
        """Train a Model. ``valid`` is optional (§3.3): when a learner needs
        validation (e.g. GBT early stopping) and none is given, it extracts one
        from the training set itself."""

    @abc.abstractmethod
    def default_hparams(self):
        ...

    # cross-API-compatible training configuration (paper §3.10)
    def train_config(self) -> dict:
        return {"learner": _name_of(type(self)), "label": self.label,
                "task": self.task.value, "seed": self.seed,
                "hparams": dataclasses.asdict(self.hparams)}


# --------------------------------------------------------------------- registry

_LEARNERS: dict[str, type] = {}


def register_learner(name: str) -> Callable[[type], type]:
    def deco(cls: type) -> type:
        if name in _LEARNERS and _LEARNERS[name] is not cls:
            raise ValueError(f"duplicate learner registration {name!r}")
        _LEARNERS[name] = cls
        cls._registry_name = name
        return cls
    return deco


def _name_of(cls: type) -> str:
    return getattr(cls, "_registry_name", cls.__name__)


def get_learner(name: str) -> type:
    _ensure_builtin()
    if name not in _LEARNERS:
        raise YdfError(
            f"Unknown learner {name!r}. Registered learners: {sorted(_LEARNERS)}. "
            "Register custom learners with @register_learner(name).")
    return _LEARNERS[name]


def list_learners() -> list[str]:
    _ensure_builtin()
    return sorted(_LEARNERS)


def make_learner(config: dict) -> Learner:
    """Build a learner from a cross-API training configuration dict."""
    cls = get_learner(config["learner"])
    return cls(label=config["label"], task=Task(config.get("task", "CLASSIFICATION")),
               seed=config.get("seed", 1234), **config.get("hparams", {}))


_BUILTIN = False


def _ensure_builtin() -> None:
    global _BUILTIN
    if _BUILTIN:
        return
    _BUILTIN = True
    from repro.core import cart, gbt, rf, baselines, metalearners  # noqa: F401
