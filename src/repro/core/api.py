"""The Learner–Model abstraction (paper §3.1) and the registries (§3.5).

A MODEL is a function observation -> prediction. A LEARNER is a function
examples -> Model. Training and inference logic are deliberately separated
(unlike fit/predict estimators): different Learners can produce the same Model
type, Models deploy without their Learner, and meta-learners compose Learners
generically (§3.2).

Registration mirrors YDF's ``REGISTER_AbstractLearner``:

    @register_learner("GRADIENT_BOOSTED_TREES")
    class GradientBoostedTreesLearner(Learner): ...

Error messages follow the paper's §2.1/§2.2 guidance: say what failed in task
terms, show the offending values, and propose concrete fixes.
"""
from __future__ import annotations

import abc
import dataclasses
import enum
import json
import os
import pickle
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


class Task(enum.Enum):
    CLASSIFICATION = "CLASSIFICATION"
    REGRESSION = "REGRESSION"
    RANKING = "RANKING"
    UPLIFT = "UPLIFT"
    ANOMALY = "ANOMALY"


class YdfError(ValueError):
    """An error with directions (paper Table 1b style)."""


class EngineFailure(YdfError):
    """A typed inference-engine failure (DESIGN.md §9.1).

    Raised when a compiled engine call fails *at serving time* — a kernel
    dispatch error, a device fault, an injected fault from the test harness
    (serving/faults.py). Carries the engine name so the serving front-end
    (serving/server.py) can attribute the failure to a circuit breaker, and
    ``transient`` so it knows whether a retry on the same engine is worth
    attempting (timeouts, spurious device errors) or the engine should be
    treated as down (sticky death, incompatibility discovered late).
    """

    def __init__(self, message: str, *, engine: str = "?",
                 transient: bool = False):
        super().__init__(message)
        self.engine = engine
        self.transient = transient


# --------------------------------------------------------------------- Model

class Model(abc.ABC):
    """observation -> prediction. Serializable, inspectable, engine-compilable."""

    task: Task
    label: str

    @abc.abstractmethod
    def predict(self, dataset) -> np.ndarray:
        """Classification: (N, n_classes) probabilities. Regression: (N,)."""

    def predict_class(self, dataset) -> np.ndarray:
        # check the task BEFORE predicting: a wrong-task call must fail fast,
        # not after paying for a full inference pass
        if self.task != Task.CLASSIFICATION:
            raise YdfError(
                f"predict_class requires a classification model, got task={self.task}. "
                "Use predict() for regression/ranking scores, uplift effects or "
                "anomaly scores; use evaluate() for task-appropriate metrics.")
        return np.argmax(self.predict(dataset), axis=-1)

    def evaluate(self, dataset) -> "Evaluation":
        from repro.core.evaluation import evaluate_predictions
        from repro.core.dataspec import label_values
        # task side-channels (ranking groups, uplift treatment) come out of
        # the DATASET, not the prediction — fetch them BEFORE inference so a
        # mis-shaped evaluation call fails fast without paying for a predict
        extras = _evaluation_extras(self, dataset)
        y = label_values(self, dataset)
        ev = evaluate_predictions(self.task, self.predict(dataset), y,
                                  classes=getattr(self, "classes", None),
                                  **extras)
        # kept so Model.save can write the report beside summary.txt
        self._last_evaluation = ev
        return ev

    def analyze(self, dataset=None, **kwargs) -> "AnalysisReport":
        """Model-analysis report (DESIGN.md §8): structural variable
        importances always; permutation importances, partial dependence and
        an evaluation when a dataset is given. Decision-forest models route
        every analysis sweep through the compiled serving stack."""
        from repro.analysis import analyze_model
        return analyze_model(self, dataset, **kwargs)

    # ---- self-description (show_model analogue)
    def summary(self, verbose: int | bool = False) -> str:
        return f"{type(self).__name__}(task={self.task.value}, label={self.label!r})"

    def variable_importances(self) -> dict[str, dict[str, float]]:
        return {}

    # ---- engines (§3.7): lossy compilation to the fastest compatible engine
    def compile(self, engine: str | None = None):
        raise YdfError(
            f"{type(self).__name__} has no inference engines. Engines exist for "
            "decision-forest models (see repro.core.engines).")

    # ---- serialization: backwards-compatible via format version tag
    FORMAT_VERSION = 1

    def save(self, path: str) -> None:
        """Write the model directory: ``header.json`` (format tag),
        ``model.pkl`` (the model), plus human-readable artefacts —
        ``summary.txt`` and, when the model carries a dataspec,
        ``dataspec.json`` — so saved models are inspectable without
        unpickling (paper §4.1 artefact style).

        The write is ATOMIC (DESIGN.md §11.4): everything lands in a
        temporary sibling directory, files are fsync'ed, and one rename
        publishes the model. A crash mid-save can never leave the corrupt
        half-written ``header.json``/``model.pkl`` states that Model.load
        diagnoses — the target either keeps its previous contents or holds
        the complete new model.
        """
        parent = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(parent, exist_ok=True)
        if os.path.isdir(path) and os.listdir(path) and \
                not os.path.exists(os.path.join(path, "header.json")):
            raise YdfError(
                f"Refusing to overwrite {path!r}: the directory exists, is "
                "not empty, and does not look like a model directory (no "
                "header.json). Solutions: (1) save to a fresh path, or (2) "
                "remove the directory first.")
        import shutil
        import tempfile
        tmp = tempfile.mkdtemp(
            prefix=os.path.basename(path) + ".tmp-", dir=parent)
        try:
            self._write_model_dir(tmp)
            for name in os.listdir(tmp):
                fd = os.open(os.path.join(tmp, name), os.O_RDONLY)
                try:
                    os.fsync(fd)
                finally:
                    os.close(fd)
            if os.path.isdir(path):
                old = tempfile.mkdtemp(
                    prefix=os.path.basename(path) + ".old-", dir=parent)
                os.rename(path, os.path.join(old, "m"))
                os.rename(tmp, path)
                shutil.rmtree(old, ignore_errors=True)
            else:
                if os.path.exists(path):
                    os.remove(path)
                os.rename(tmp, path)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    def _write_model_dir(self, path: str) -> None:
        meta = {"format_version": self.FORMAT_VERSION, "class": type(self).__name__}
        with open(os.path.join(path, "header.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(path, "model.pkl"), "wb") as f:
            pickle.dump(self, f)
        with open(os.path.join(path, "summary.txt"), "w") as f:
            f.write(self.summary() + "\n")
        spec = getattr(self, "spec", None)
        if spec is not None:
            from repro.core.dataspec import spec_to_dict
            with open(os.path.join(path, "dataspec.json"), "w") as f:
                json.dump(spec_to_dict(spec), f, indent=1)
        # the last evaluate() result rides along as a readable artefact
        # (plus its JSON form) so a saved model directory answers "how good
        # is it?" without re-running inference
        ev = getattr(self, "_last_evaluation", None)
        if ev is not None:
            with open(os.path.join(path, "evaluation.txt"), "w") as f:
                f.write(ev.report() + "\n")
            with open(os.path.join(path, "evaluation.json"), "w") as f:
                json.dump(ev.to_dict(), f, indent=1)

    @staticmethod
    def load(path: str) -> "Model":
        header = os.path.join(path, "header.json")
        try:
            with open(header) as f:
                meta = json.load(f)
        except FileNotFoundError:
            raise YdfError(
                f"No model found at {path!r}: missing 'header.json'. A model "
                "directory is created by Model.save and contains header.json "
                "+ model.pkl. Solutions: (1) check the path points at the "
                "model DIRECTORY (not a file inside it), or (2) re-save the "
                "model with model.save(path).") from None
        except json.JSONDecodeError as e:
            raise YdfError(
                f"Model header {header!r} is corrupt (invalid JSON: {e}). "
                "Solution: re-save the model with model.save(path); if the "
                "file was hand-edited, restore the original header.") from None
        if not isinstance(meta, dict) or "format_version" not in meta:
            raise YdfError(
                f"Model header {header!r} has no 'format_version' field "
                f"(got: {meta!r}). Solution: re-save the model with "
                "model.save(path) — headers are written automatically.")
        if meta["format_version"] > Model.FORMAT_VERSION:
            raise YdfError(
                f"Model at {path!r} was saved with format v{meta['format_version']}, "
                f"this library reads up to v{Model.FORMAT_VERSION}. Solutions: (1) "
                "upgrade the library, or (2) re-export the model in an older format.")
        pkl = os.path.join(path, "model.pkl")
        try:
            with open(pkl, "rb") as f:
                return pickle.load(f)
        except FileNotFoundError:
            raise YdfError(
                f"Model directory {path!r} has a header but no 'model.pkl'. "
                "The save was interrupted or the file was removed. Solution: "
                "re-save the model with model.save(path).") from None


def _side_column(dataset, name: str, *, task: str, role: str) -> np.ndarray:
    """Fetch a task side-channel column (ranking group / uplift treatment)
    from a VerticalDataset or a raw column mapping."""
    from repro.core.dataspec import VerticalDataset
    if isinstance(dataset, VerticalDataset):
        if name in dataset.numerical or name in dataset.categorical:
            return np.asarray(dataset.column(name))
    else:
        try:
            if name in dataset:
                return np.asarray(dataset[name], dtype=object).ravel()
        except TypeError:
            pass
    raise YdfError(
        f"{task} evaluation requires the {role} column {name!r} and the "
        f"dataset does not carry it. Solution: pass a dataset with {name!r} "
        "alongside the features and label.")


def _evaluation_extras(model, dataset) -> dict:
    """Per-task evaluation side-channels, resolved BEFORE inference."""
    if model.task == Task.RANKING:
        col = _side_column(dataset, getattr(model, "ranking_group", "group"),
                           task="Ranking", role="group/query")
        groups = np.unique(col.astype(str), return_inverse=True)[1]
        return {"groups": groups.astype(np.int64)}
    if model.task == Task.UPLIFT:
        col = _side_column(dataset, getattr(model, "treatment_col", "treatment"),
                           task="Uplift", role="treatment")
        # two-arm normalization: smallest distinct value = control (0)
        vals, t = np.unique(col.astype(str), return_inverse=True)
        if len(vals) > 2:
            raise YdfError(
                f"Uplift evaluation supports two treatment arms, the "
                f"treatment column has {len(vals)} distinct values: "
                f"{list(vals[:5])}...")
        return {"treatment": t.astype(np.int64)}
    return {}


# --------------------------------------------------------------------- Learner

class Learner(abc.ABC):
    """examples -> Model. Hyper-parameters are fixed at construction; ``train``
    is deterministic given (hyper-parameters, dataset, seed) — paper §3.11."""

    def __init__(self, label: str, task: Task = Task.CLASSIFICATION, *,
                 seed: int = 1234, template: str | None = None, **hparams):
        self.label = label
        self.task = task
        self.seed = seed
        self.template = template
        hp = self.default_hparams()
        if template:
            # template first, explicit overrides second (§3.11): a template
            # is a bundle of defaults the caller can still override per-key
            from repro.core.hparams import apply_template
            hp = apply_template(_name_of(type(self)), hp, template)
        unknown = set(hparams) - set(dataclasses.asdict(hp))
        if unknown:
            known = sorted(dataclasses.asdict(hp))
            raise YdfError(
                f"Unknown hyper-parameter(s) {sorted(unknown)} for "
                f"{type(self).__name__}. Known hyper-parameters: {known}.")
        self.hparams = dataclasses.replace(hp, **hparams)

    @abc.abstractmethod
    def train(self, dataset, valid=None, checkpoint=None) -> Model:
        """Train a Model. ``valid`` is optional (§3.3): when a learner needs
        validation (e.g. GBT early stopping) and none is given, it extracts one
        from the training set itself. ``checkpoint`` (a directory path or a
        ``repro.train.checkpoint.CheckpointPolicy``) turns on interruption-
        safe training with bit-identical resume (DESIGN.md §11); learners
        without a checkpoint seam ignore it."""

    @abc.abstractmethod
    def default_hparams(self):
        ...

    # cross-API-compatible training configuration (paper §3.10)
    def train_config(self) -> dict:
        cfg = {"learner": _name_of(type(self)), "label": self.label,
               "task": self.task.value, "seed": self.seed,
               "hparams": dataclasses.asdict(self.hparams)}
        if getattr(self, "template", None):
            cfg["template"] = self.template
        return cfg


# --------------------------------------------------------------------- registry

_LEARNERS: dict[str, type] = {}


def register_learner(name: str) -> Callable[[type], type]:
    def deco(cls: type) -> type:
        if name in _LEARNERS and _LEARNERS[name] is not cls:
            raise ValueError(f"duplicate learner registration {name!r}")
        _LEARNERS[name] = cls
        cls._registry_name = name
        return cls
    return deco


def _name_of(cls: type) -> str:
    return getattr(cls, "_registry_name", cls.__name__)


def get_learner(name: str) -> type:
    _ensure_builtin()
    if name not in _LEARNERS:
        raise YdfError(
            f"Unknown learner {name!r}. Registered learners: {sorted(_LEARNERS)}. "
            "Register custom learners with @register_learner(name).")
    return _LEARNERS[name]


def list_learners() -> list[str]:
    _ensure_builtin()
    return sorted(_LEARNERS)


def make_learner(config: dict) -> Learner:
    """Build a learner from a cross-API training configuration dict. The
    hparams dict already carries post-template values, so re-applying the
    template then overriding with them reproduces the learner exactly —
    the template name rides along for provenance."""
    cls = get_learner(config["learner"])
    kw = dict(config.get("hparams", {}))
    if config.get("template"):
        kw["template"] = config["template"]
    return cls(label=config["label"], task=Task(config.get("task", "CLASSIFICATION")),
               seed=config.get("seed", 1234), **kw)


_BUILTIN = False


def _ensure_builtin() -> None:
    global _BUILTIN
    if _BUILTIN:
        return
    _BUILTIN = True
    from repro.core import cart, gbt, rf, baselines, metalearners  # noqa: F401
    from repro import tasks  # noqa: F401  (uplift trees, isolation forest)
