"""Model evaluation with confidence intervals (paper §2.2 "easily accessible,
correct methods"; App. B.3 report format) and the Self-Evaluation abstraction
(§3.6): OOB / validation / cross-validation all produce the same Evaluation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.api import Task, YdfError


@dataclass
class Evaluation:
    task: Task
    n_examples: int
    metrics: dict = field(default_factory=dict)
    confusion: np.ndarray | None = None
    classes: list[str] | None = None
    source: str = "test"  # test | validation | out-of-bag | cross-validation

    def __getitem__(self, k):
        return self.metrics[k]

    @property
    def primary(self) -> float:
        """Higher-is-better scalar for model selection."""
        if self.task == Task.CLASSIFICATION:
            return self.metrics["accuracy"]
        if self.task == Task.RANKING:
            return self.metrics["ndcg@5"]
        if self.task == Task.UPLIFT:
            return self.metrics["qini"]
        if self.task == Task.ANOMALY:
            return self.metrics["auc"]
        return -self.metrics["rmse"]

    def to_dict(self) -> dict:
        """JSON-serializable form (analysis reports, CLI --json, artefacts)."""
        metrics = {k: (list(v) if isinstance(v, tuple) else float(v))
                   for k, v in self.metrics.items()}
        return {"task": self.task.value, "n_examples": int(self.n_examples),
                "source": self.source, "metrics": metrics,
                "classes": self.classes,
                "confusion": (None if self.confusion is None
                              else self.confusion.tolist())}

    def report(self) -> str:
        L = [f"Evaluation ({self.source}):",
             f"Number of predictions: {self.n_examples}",
             f"Task: {self.task.value}"]
        for k, v in self.metrics.items():
            if isinstance(v, tuple):
                L.append(f"{k}: CI95[B][{v[0]:.6g} {v[1]:.6g}]")
            else:
                L.append(f"{k}: {v:.6g}")
        if self.confusion is not None:
            L.append("Confusion (truth x prediction):")
            L.append(str(self.confusion))
        return "\n".join(L)


def _bootstrap_ci(values: np.ndarray, stat, n_boot: int = 200, seed: int = 7):
    """95% bootstrap CI of `stat` over example-level values (paper's [B]/[W])."""
    rng = np.random.default_rng(seed)
    n = len(values)
    if n == 0:
        return 0.0, 0.0
    stats = [stat(values[rng.integers(0, n, n)]) for _ in range(n_boot)]
    return float(np.quantile(stats, 0.025)), float(np.quantile(stats, 0.975))


def auc_binary(y: np.ndarray, score: np.ndarray) -> float:
    """Rank-based AUC (Mann-Whitney)."""
    order = np.argsort(score, kind="stable")
    ranks = np.empty(len(score), np.float64)
    ranks[order] = np.arange(1, len(score) + 1)
    # midranks for ties
    s_sorted = score[order]
    i = 0
    while i < len(s_sorted):
        j = i
        while j + 1 < len(s_sorted) and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2 + 1
        i = j + 1
    pos = y == 1
    n1, n0 = int(pos.sum()), int((~pos).sum())
    if n1 == 0 or n0 == 0:
        return 0.5
    return float((ranks[pos].sum() - n1 * (n1 + 1) / 2) / (n1 * n0))


def _ndcg_group(rel: np.ndarray, score: np.ndarray, k: int) -> float:
    """NDCG@k for one group: DCG = sum (2^rel_i - 1)/log2(i+2) over the top-k
    by score (descending, stable index tie-break); IDCG sorts by relevance.
    A group with no relevant item (IDCG == 0) scores 0."""
    order = np.argsort(-np.asarray(score, np.float64), kind="stable")
    gains = np.power(2.0, np.asarray(rel, np.float64)) - 1.0
    disc = 1.0 / np.log2(np.arange(2, min(k, len(rel)) + 2))
    dcg = float((gains[order[:k]] * disc).sum())
    ideal = np.sort(gains)[::-1]
    idcg = float((ideal[:k] * disc).sum())
    return dcg / idcg if idcg > 0 else 0.0


def ndcg_at_k(y: np.ndarray, score: np.ndarray, groups: np.ndarray,
              k: int = 5) -> float:
    """Mean NDCG@k over groups (the ranking quality metric, paper §3.1)."""
    vals = [_ndcg_group(y[idx], score[idx], k)
            for g in np.unique(groups)
            for idx in (np.flatnonzero(groups == g),)]
    return float(np.mean(vals))


def qini_curve(y: np.ndarray, score: np.ndarray,
               treatment: np.ndarray) -> np.ndarray:
    """Incremental-uplift curve: rows sorted by predicted uplift descending
    (stable index tie-break); at cut k the value is the treated outcome sum
    minus the control outcome sum scaled to the treated count,
    ``yt_k - yc_k * nt_k / max(nc_k, 1)``."""
    order = np.argsort(-np.asarray(score, np.float64).reshape(-1),
                       kind="stable")
    t = np.asarray(treatment, np.float64)[order]
    yy = np.asarray(y, np.float64)[order]
    nt, nc = np.cumsum(t), np.cumsum(1.0 - t)
    yt, yc = np.cumsum(yy * t), np.cumsum(yy * (1.0 - t))
    return yt - yc * nt / np.maximum(nc, 1.0)


def evaluate_predictions(task: Task, pred: np.ndarray, y: np.ndarray, *,
                         classes: list[str] | None = None,
                         source: str = "test",
                         groups: np.ndarray | None = None,
                         treatment: np.ndarray | None = None) -> Evaluation:
    n = len(y)
    if n == 0:
        raise YdfError("Cannot evaluate on an empty dataset.")
    m: dict = {}
    confusion = None
    if task == Task.CLASSIFICATION:
        pred = np.asarray(pred)
        if pred.ndim != 2:
            raise YdfError(f"Classification predictions must be (N, n_classes), "
                           f"got shape {pred.shape}.")
        yhat = pred.argmax(1)
        correct = (yhat == y).astype(np.float64)
        lo, hi = _bootstrap_ci(correct, np.mean)
        m["accuracy"] = float(correct.mean())
        m["accuracy_ci95"] = (lo, hi)
        p = np.clip(pred[np.arange(n), y], 1e-12, None)
        m["logloss"] = float(-np.log(p).mean())
        m["error_rate"] = 1.0 - float(correct.mean())
        C = pred.shape[1]
        default = np.bincount(y, minlength=C).max() / n
        m["default_accuracy"] = float(default)
        if C == 2:
            m["auc"] = auc_binary(y, pred[:, 1])
        confusion = np.zeros((C, C), np.int64)
        np.add.at(confusion, (y, yhat), 1)
    elif task == Task.REGRESSION:
        pred = np.asarray(pred).reshape(-1)
        err = pred - y
        m["rmse"] = float(np.sqrt(np.mean(np.square(err))))
        m["mae"] = float(np.mean(np.abs(err)))
        denom = max(np.var(y), 1e-12)
        m["r2"] = float(1.0 - np.mean(np.square(err)) / denom)
    elif task == Task.RANKING:
        if groups is None:
            raise YdfError(
                "Ranking evaluation requires per-example group ids. Solution: "
                "pass groups= (Model.evaluate extracts them from the group "
                "column automatically).")
        pred = np.asarray(pred).reshape(-1)
        for k in (1, 5, 10):
            m[f"ndcg@{k}"] = ndcg_at_k(y, pred, groups, k)
        m["n_groups"] = float(len(np.unique(groups)))
    elif task == Task.UPLIFT:
        if treatment is None:
            raise YdfError(
                "Uplift evaluation requires per-example treatment assignment. "
                "Solution: pass treatment= (Model.evaluate extracts it from "
                "the treatment column automatically).")
        pred = np.asarray(pred).reshape(-1)
        g = qini_curve(y, pred, np.asarray(treatment))
        # areas normalized per example: auuc is the mean curve height / n,
        # qini subtracts the random-targeting straight line to g[-1]
        m["auuc"] = float(g.mean()) / n
        m["qini"] = float(g.mean() - g[-1] * (n + 1) / (2 * n)) / n
    elif task == Task.ANOMALY:
        pred = np.asarray(pred).reshape(-1)
        # label = 1 for planted/true anomalies; higher score = more anomalous
        m["auc"] = auc_binary((np.asarray(y, np.float64) == 1).astype(np.int64),
                              pred)
        m["mean_score"] = float(pred.mean())
    else:
        raise YdfError(f"Evaluation for task={task} not implemented.")
    return Evaluation(task=task, n_examples=n, metrics=m, confusion=confusion,
                      classes=classes, source=source)


def compare_correctness(correct_a: np.ndarray, correct_b: np.ndarray,
                        n_boot: int = 500, seed: int = 11) -> dict:
    """Paired bootstrap comparison (§2.2): per-example correctness/score
    vectors of two models on the SAME examples. Returns the mean difference,
    its CI95, and P(a beats b) under resampling."""
    if len(correct_a) != len(correct_b):
        raise YdfError("compare_correctness requires predictions on the same "
                       f"examples ({len(correct_a)} vs {len(correct_b)}).")
    d = np.asarray(correct_a, np.float64) - np.asarray(correct_b, np.float64)
    rng = np.random.default_rng(seed)
    n = len(d)
    means = np.array([d[rng.integers(0, n, n)].mean() for _ in range(n_boot)])
    return {"mean_diff": float(d.mean()),
            "ci95": (float(np.quantile(means, 0.025)),
                     float(np.quantile(means, 0.975))),
            "p_a_better": float((means > 0).mean())}
