"""Splitters (paper §3.8): find the best condition per frontier node.

The workhorse is the *histogram splitter*: binned codes (uint8) + per-node
stat histograms + cumulative-sum gain scans. Stat layouts ("label type"
modules, §2.3):

  * "gh"     — [grad, hess, count]            (GBT, any smooth loss)
  * "class"  — [count_class_0..C-1, count]    (RF/CART classification)
  * "moment" — [sum_y, sum_y^2, count]        (RF/CART regression)

Feature-type modules: numerical (ordered-bin scan), categorical CART
(Fisher-ordered prefix scan), categorical RANDOM (random-set projections,
Breiman), one-hot (single category vs rest), and sparse oblique numerical
projections (Tomita et al.). The exact in-sorting splitter is the reference
oracle (§2.3: the simple module is the ground truth for the optimized ones).

Histogram building: numpy bincount on host; repro/kernels/histogram has the
one-hot-MXU Pallas kernel + jnp oracle used by the distributed/TPU path.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import numpy as np

from repro.core.api import YdfError
from repro.core.binning import BinnedFeatures
from repro.core.hist_backend import HistogramBackend, resolve_backend
from repro.core.tree import MASK_WORDS

NEG_INF = -1e30

# Scale-aware validity floor for split gains. Gains are evaluated in float32
# (score(L) + score(R) - score(P) — a catastrophic cancellation when the
# split is worthless), so a node whose true gain is 0 reads as noise of order
# eps_f32 * |score(P)| accumulated over the cumulative scan. Any fixed
# min_gain below that floor turns pure-noise argmax flips into spurious
# splits that differ between backends (f64-accumulate-then-round vs native
# f32). All engines gate on max(min_gain, REL_GAIN_EPS * |score(parent)|) so
# they agree that such splits are invalid.
REL_GAIN_EPS = 4e-6


def gain_floor(min_gain: float, parent_score) -> np.ndarray:
    return np.maximum(min_gain, REL_GAIN_EPS * np.abs(parent_score))


@dataclass
class SplitterParams:
    stat_kind: str = "gh"            # gh | class | moment
    min_examples: int = 5
    l2: float = 0.0                  # lambda (gh gain)
    min_gain: float = 1e-12
    categorical_algorithm: str = "CART"   # CART | RANDOM | ONE_HOT
    random_cat_trials: int = 32
    num_candidate_ratio: float = 1.0  # per-node feature sampling (RF: sqrt rule)
    # sparse oblique (benchmark_rank1 template)
    oblique: bool = False
    oblique_num_projections_exponent: float = 1.0
    oblique_density: float = 0.5     # P(feature in projection)
    oblique_bins: int = 128


@dataclass
class Split:
    """Best split decision for one node. feature == -1 -> no valid split."""
    gain: float = NEG_INF
    feature: int = -1
    split_bin: int = 0                     # numerical: codes >= split_bin go right
    threshold: float = 0.0                 # raw-value threshold
    cat_right: np.ndarray | None = None    # categorical: codes going right
    obl_features: np.ndarray | None = None
    obl_weights: np.ndarray | None = None

    @property
    def valid(self) -> bool:
        return self.feature != -1 or self.obl_features is not None


# =====================================================================
# Histogram building (host path; kernels/histogram is the device path)
# =====================================================================

def build_histogram(codes: np.ndarray, stats: np.ndarray, node_of: np.ndarray,
                    n_nodes: int, max_bins: int = 256,
                    backend: str | HistogramBackend | None = None) -> np.ndarray:
    """codes: (N, F) uint8; stats: (N, S) float32; node_of: (N,) int32 in
    [-1, n_nodes) (-1 = inactive example). -> (n_nodes, F, B, S) float32.

    Accumulation is delegated to a histogram backend (hist_backend.py): one
    flattened bincount on the host, the one-hot-MXU Pallas kernel on TPU.
    ``backend=None`` keeps the host path (the seed-equivalent oracle)."""
    be = resolve_backend("numpy" if backend is None else backend)
    return be.build(codes, stats, node_of, n_nodes, max_bins).astype(np.float32)


# =====================================================================
# Gain functions per stat layout
# =====================================================================

def _score(stats: np.ndarray, kind: str, l2: float) -> np.ndarray:
    """'Goodness' of a node given aggregated stats (..., S). Gain of a split =
    score(L) + score(R) - score(P) (all formulations arranged to be additive)."""
    if kind == "gh":
        g, h = stats[..., 0], stats[..., 1]
        return 0.5 * np.square(g) / (h + l2 + 1e-12)
    if kind == "class":
        counts = stats[..., :-1]
        n = stats[..., -1]
        tot = np.maximum(n, 1e-12)[..., None]
        p = counts / tot
        ent = -(p * np.log(np.maximum(p, 1e-12))).sum(-1)
        return -n * ent  # negative weighted entropy: gain = info gain * n
    if kind == "moment":
        sy, sy2, n = stats[..., 0], stats[..., 1], stats[..., 2]
        return np.square(sy) / np.maximum(n, 1e-12) - 0.0 * sy2  # -SSE + const
    if kind == "uplift":
        # [sum_y_treated, n_treated, sum_y_control, n] — Euclidean-distance
        # uplift gain (DESIGN.md §12.2): n * (p_t - p_c)^2, additive over
        # children; a child with an empty arm contributes 0 (no estimate)
        st, nt, sc, n = (stats[..., 0], stats[..., 1],
                         stats[..., 2], stats[..., 3])
        ncb = n - nt
        pt = st / np.maximum(nt, 1e-12)
        pc = sc / np.maximum(ncb, 1e-12)
        both = (nt > 0) & (ncb > 0)
        return np.where(both, n * np.square(pt - pc), 0.0)
    raise ValueError(kind)


def _counts(stats: np.ndarray, kind: str) -> np.ndarray:
    return stats[..., -1]


def _order_key(stats: np.ndarray, kind: str) -> np.ndarray:
    """Per-bin ordering key for categorical CART (Fisher 1958 grouping)."""
    n = np.maximum(stats[..., -1], 1e-12)
    if kind == "gh":
        return stats[..., 0] / np.maximum(stats[..., 1], 1e-12)
    if kind == "class":
        return stats[..., 1] / n  # P(second class); multiclass handled by caller
    if kind == "uplift":
        # per-bin treatment-effect estimate p_t - p_c orders categories
        pt = stats[..., 0] / np.maximum(stats[..., 1], 1e-12)
        pc = stats[..., 2] / np.maximum(n - stats[..., 1], 1e-12)
        return pt - pc
    return stats[..., 0] / n      # mean target


# =====================================================================
# Best-split search over a histogram
# =====================================================================

def best_splits(hist: np.ndarray, binned: BinnedFeatures, params: SplitterParams,
                rng: np.random.Generator,
                feature_mask: np.ndarray | None = None,
                simple: bool = False) -> list[Split]:
    """hist: (n_nodes, F, B, S) -> one Split per node (numerical+categorical).
    feature_mask: optional (n_nodes, F) bool of candidate features per node.
    simple=True evaluates categorical features one at a time (the readable
    ground-truth module, paper §2.3) instead of the batched scan; results are
    bit-identical (tested)."""
    n_nodes, F, B, S = hist.shape
    kind, l2 = params.stat_kind, params.l2
    parent = hist.sum(axis=2)                       # (n_nodes, F, S)
    parent_score = _score(parent, kind, l2)         # (n_nodes, F)
    n_parent = _counts(parent, kind)

    is_cat = binned.is_cat
    num_idx = np.where(~is_cat)[0]
    cat_idx = np.where(is_cat)[0]

    gains = np.full((n_nodes, F), NEG_INF, np.float64)
    best_bin = np.zeros((n_nodes, F), np.int32)
    cat_sets: dict[tuple[int, int], tuple] = {}     # lazy payloads (see below)

    # ---- numerical: ordered cumulative scan; split s: bins < s left
    if len(num_idx):
        h = hist[:, num_idx]                        # (n, Fn, B, S)
        cum = np.cumsum(h, axis=2)
        left = cum[:, :, :-1]                       # split after bin b -> s = b+1
        right = parent[:, num_idx, None, :] - left
        g = (_score(left, kind, l2) + _score(right, kind, l2)
             - parent_score[:, num_idx, None])
        ok = ((_counts(left, kind) >= params.min_examples)
              & (_counts(right, kind) >= params.min_examples))
        g = np.where(ok, g, NEG_INF)
        bi = np.argmax(g, axis=2)                   # (n, Fn)
        gains[:, num_idx] = np.take_along_axis(g, bi[..., None], 2)[..., 0]
        best_bin[:, num_idx] = bi + 1

    # ---- categorical: all features of one algorithm evaluated in one batch
    # (RANDOM keeps a per-feature loop so the rng draw order is unchanged;
    # simple=True keeps the per-feature ground-truth handlers for all three)
    if len(cat_idx):
        one_hot = params.categorical_algorithm == "ONE_HOT" or (
            kind == "class" and parent.shape[-1] > 3)
        if params.categorical_algorithm == "RANDOM":
            for f in cat_idx:
                nb = int(binned.n_bins[f])
                _cat_random(f, hist[:, f, :nb], parent[:, f],
                            parent_score[:, f], params, rng, gains, cat_sets)
        elif simple:
            for f in cat_idx:
                nb = int(binned.n_bins[f])
                handler = _cat_one_hot_simple if one_hot else _cat_cart_simple
                handler(f, hist[:, f, :nb], parent[:, f], parent_score[:, f],
                        params, gains, cat_sets, kind)
        elif one_hot:
            _cat_one_hot_batch(cat_idx, hist, binned, parent, parent_score,
                               params, gains, cat_sets)
        else:
            _cat_cart_batch(cat_idx, hist, binned, parent, parent_score,
                            params, gains, cat_sets, kind)

    if feature_mask is not None:
        gains = np.where(feature_mask, gains, NEG_INF)

    out: list[Split] = []
    for i in range(n_nodes):
        j = int(np.argmax(gains[i]))
        gain = float(gains[i, j])
        floor = float(gain_floor(params.min_gain, parent_score[i, j]))
        if gain <= floor or gain <= NEG_INF or not np.isfinite(gain):
            out.append(Split())
            continue
        if is_cat[j]:
            out.append(Split(gain=gain, feature=j,
                             cat_right=_materialize_cat(cat_sets[(i, j)])))
        else:
            sb = int(best_bin[i, j])
            out.append(Split(gain=gain, feature=j, split_bin=sb,
                             threshold=binned.threshold_value(j, sb)))
    return out


def best_splits_gathered(hist: np.ndarray, feat_sel: np.ndarray,
                         binned: BinnedFeatures, params: SplitterParams
                         ) -> list[Split]:
    """Best split per node from per-node GATHERED candidate columns.

    hist: (n_nodes, kf, B, S) f32 — histogram of only the kf sampled features
    of each node; feat_sel: (n_nodes, kf) int32 original column ids, sorted
    ascending. Bit-identical to ``best_splits`` on the full (n, F, B, S)
    histogram under the matching feature mask: the same f32 values are
    computed for exactly the sampled (node, feature) pairs, and the argmax
    over ascending-sorted candidates breaks ties toward the lowest feature
    index just like the masked full-matrix argmax (tested). RANDOM
    categorical trials draw from the rng stream and are not supported here —
    callers (the lockstep/device paths) exclude them.

    Numerical and categorical pairs are compacted into two flat lists before
    scanning, so the scan cost is O(sampled pairs), not O(nodes * F).
    """
    n_nodes, kf, B, S = hist.shape
    kind, l2 = params.stat_kind, params.l2
    if params.categorical_algorithm == "RANDOM":
        raise YdfError("best_splits_gathered does not support "
                       "categorical_algorithm='RANDOM' (stream rng draws).")
    parent = hist.sum(axis=2)                       # (n, kf, S)
    parent_score = _score(parent, kind, l2)
    gains = np.full((n_nodes, kf), NEG_INF, np.float64)
    best_bin = np.zeros((n_nodes, kf), np.int32)
    is_cat_sel = binned.is_cat[feat_sel]            # (n, kf)
    pair_row = np.full((n_nodes, kf), -1, np.int64)

    pn = np.nonzero(~is_cat_sel)
    if len(pn[0]):
        h = hist[pn]                                # (m, B, S)
        cum = np.cumsum(h, axis=1)
        left = cum[:, :-1]
        right = parent[pn][:, None, :] - left
        g = (_score(left, kind, l2) + _score(right, kind, l2)
             - parent_score[pn][:, None])
        ok = ((_counts(left, kind) >= params.min_examples)
              & (_counts(right, kind) >= params.min_examples))
        g = np.where(ok, g, NEG_INF)
        bi = np.argmax(g, axis=1)
        gains[pn] = np.take_along_axis(g, bi[:, None], 1)[:, 0]
        best_bin[pn] = bi + 1

    pc = np.nonzero(is_cat_sel)
    one_hot = params.categorical_algorithm == "ONE_HOT" or (
        kind == "class" and S > 3)
    cat_bi = cat_order = cat_nb = None
    if len(pc[0]):
        fc = feat_sel[pc]
        nb = binned.n_bins[fc].astype(np.int64)     # (m,)
        Bmax = int(nb.max())
        hf = hist[pc][:, :Bmax]                     # (m, Bmax, S)
        par, ps = parent[pc], parent_score[pc]
        if one_hot:
            left = par[:, None, :] - hf
            g = (_score(hf, kind, l2) + _score(left, kind, l2) - ps[:, None])
            ok = ((_counts(hf, kind) >= params.min_examples)
                  & (_counts(left, kind) >= params.min_examples)
                  & (np.arange(Bmax)[None] < nb[:, None]))
            g = np.where(ok, g, NEG_INF)
            cat_bi = np.argmax(g, axis=1)
            gains[pc] = np.take_along_axis(g, cat_bi[:, None], 1)[:, 0]
            pair_row[pc] = np.arange(len(fc))
        elif Bmax >= 2:
            pad = np.arange(Bmax)[None] >= nb[:, None]
            key = np.where(pad, np.inf, _order_key(hf, kind))
            cat_order = np.argsort(key, axis=1, kind="stable")
            hs = np.take_along_axis(hf, cat_order[..., None], axis=1)
            cum = np.cumsum(hs, axis=1)[:, :-1]
            right = par[:, None, :] - cum
            g = (_score(cum, kind, params.l2) + _score(right, kind, params.l2)
                 - ps[:, None])
            ok = ((_counts(cum, kind) >= params.min_examples)
                  & (_counts(right, kind) >= params.min_examples)
                  & (np.arange(Bmax - 1)[None] < nb[:, None] - 1))
            g = np.where(ok, g, NEG_INF)
            cat_bi = np.argmax(g, axis=1)
            gains[pc] = np.take_along_axis(g, cat_bi[:, None], 1)[:, 0]
            cat_nb = nb
            pair_row[pc] = np.arange(len(fc))

    out: list[Split] = []
    for i in range(n_nodes):
        j = int(np.argmax(gains[i]))
        gain = float(gains[i, j])
        floor = float(gain_floor(params.min_gain, parent_score[i, j]))
        if gain <= floor or gain <= NEG_INF or not np.isfinite(gain):
            out.append(Split())
            continue
        f = int(feat_sel[i, j])
        if is_cat_sel[i, j]:
            r = int(pair_row[i, j])
            if one_hot:
                payload = ("onehot", int(cat_bi[r]))
            else:
                payload = ("cart", cat_order[r], int(cat_bi[r]),
                           int(cat_nb[r]))
            out.append(Split(gain=gain, feature=f,
                             cat_right=_materialize_cat(payload)))
        else:
            sb = int(best_bin[i, j])
            out.append(Split(gain=gain, feature=f, split_bin=sb,
                             threshold=binned.threshold_value(f, sb)))
    return out


def _materialize_cat(payload) -> np.ndarray:
    """Candidate category sets are kept as lazy payloads during the scan and
    only turned into sorted index arrays for the winning feature per node."""
    tag = payload[0]
    if tag == "cart":
        _, order_row, bi, nb = payload
        tail = order_row[bi + 1:]
        return np.sort(tail[tail < nb]).astype(np.int32)
    if tag == "onehot":
        return np.array([payload[1]], np.int32)
    return payload[1]                               # "set": precomputed


def _cat_cart_simple(f, hf, parent, parent_score, params, gains, cat_sets,
                     kind):
    """Per-feature Fisher-ordered prefix scan — the seed ground-truth module
    (paper §2.3) that `_cat_cart_batch` is verified against."""
    n_nodes, nb, S = hf.shape
    key = _order_key(hf, kind)                      # (n, nb)
    order = np.argsort(key, axis=1, kind="stable")  # (n, nb)
    hs = np.take_along_axis(hf, order[..., None], axis=1)
    cum = np.cumsum(hs, axis=1)[:, :-1]             # prefixes (n, nb-1, S)
    right = parent[:, None, :] - cum
    g = (_score(cum, kind, params.l2) + _score(right, kind, params.l2)
         - parent_score[:, None])
    ok = ((_counts(cum, kind) >= params.min_examples)
          & (_counts(right, kind) >= params.min_examples))
    g = np.where(ok, g, NEG_INF)
    if g.shape[1] == 0:
        return
    bi = np.argmax(g, axis=1)
    gv = np.take_along_axis(g, bi[:, None], 1)[:, 0]
    for i in range(n_nodes):
        if gv[i] > gains[i, f]:
            gains[i, f] = gv[i]
            cat_sets[(i, f)] = ("set",
                                np.sort(order[i, bi[i] + 1:]).astype(np.int32))


def _cat_one_hot_simple(f, hf, parent, parent_score, params, gains, cat_sets,
                        kind):
    """Per-feature single-category-vs-rest scan — the seed ground-truth module
    that `_cat_one_hot_batch` is verified against."""
    l2 = params.l2
    left = parent[:, None, :] - hf                  # all but category b
    g = (_score(hf, kind, l2) + _score(left, kind, l2) - parent_score[:, None])
    ok = ((_counts(hf, kind) >= params.min_examples)
          & (_counts(left, kind) >= params.min_examples))
    g = np.where(ok, g, NEG_INF)
    bi = np.argmax(g, axis=1)
    gv = np.take_along_axis(g, bi[:, None], 1)[:, 0]
    for i in range(hf.shape[0]):
        if gv[i] > gains[i, f]:
            gains[i, f] = gv[i]
            cat_sets[(i, f)] = ("onehot", int(bi[i]))


def _cat_cart_batch(cat_idx, hist, binned, parent, parent_score, params,
                    gains, cat_sets, kind):
    """Fisher-ordered prefix scan (Fisher 1958 grouping; exact for
    binary/regression), batched over every categorical feature at once.
    Features are padded to the widest dictionary; padded bins sort last
    (+inf key) and padded cut positions are masked, so per-feature results
    are bit-identical to a per-feature scan."""
    n_nodes = hist.shape[0]
    nb = binned.n_bins[cat_idx].astype(np.int64)    # (Fc,)
    Bmax = int(nb.max())
    if Bmax < 2:
        return
    hf = hist[:, cat_idx, :Bmax]                    # (n, Fc, Bmax, S)
    pad = np.arange(Bmax)[None, :] >= nb[:, None]   # (Fc, Bmax)
    key = np.where(pad[None], np.inf, _order_key(hf, kind))
    order = np.argsort(key, axis=2, kind="stable")  # (n, Fc, Bmax)
    hs = np.take_along_axis(hf, order[..., None], axis=2)
    cum = np.cumsum(hs, axis=2)[:, :, :-1]          # prefixes (n, Fc, Bmax-1, S)
    right = parent[:, cat_idx, None, :] - cum
    g = (_score(cum, kind, params.l2) + _score(right, kind, params.l2)
         - parent_score[:, cat_idx, None])
    ok = ((_counts(cum, kind) >= params.min_examples)
          & (_counts(right, kind) >= params.min_examples)
          & (np.arange(Bmax - 1)[None, :] < nb[:, None] - 1)[None])
    g = np.where(ok, g, NEG_INF)
    bi = np.argmax(g, axis=2)                       # (n, Fc)
    gv = np.take_along_axis(g, bi[..., None], 2)[..., 0]
    improve = gv > gains[:, cat_idx]
    for i, fi in zip(*np.nonzero(improve)):
        cat_sets[(i, cat_idx[fi])] = ("cart", order[i, fi], int(bi[i, fi]),
                                      int(nb[fi]))
    gains[:, cat_idx] = np.where(improve, gv, gains[:, cat_idx])


def _cat_one_hot_batch(cat_idx, hist, binned, parent, parent_score, params,
                       gains, cat_sets):
    """Single category vs rest (== one-hot encoding splits), batched over
    every categorical feature at once (padded bins masked)."""
    kind, l2 = params.stat_kind, params.l2
    nb = binned.n_bins[cat_idx].astype(np.int64)
    Bmax = int(nb.max())
    hf = hist[:, cat_idx, :Bmax]                    # (n, Fc, Bmax, S)
    left = parent[:, cat_idx, None, :] - hf         # all but category b
    g = (_score(hf, kind, l2) + _score(left, kind, l2)
         - parent_score[:, cat_idx, None])
    ok = ((_counts(hf, kind) >= params.min_examples)
          & (_counts(left, kind) >= params.min_examples)
          & (np.arange(Bmax)[None, :] < nb[:, None])[None])
    g = np.where(ok, g, NEG_INF)
    bi = np.argmax(g, axis=2)
    gv = np.take_along_axis(g, bi[..., None], 2)[..., 0]
    improve = gv > gains[:, cat_idx]
    for i, fi in zip(*np.nonzero(improve)):
        cat_sets[(i, cat_idx[fi])] = ("onehot", int(bi[i, fi]))
    gains[:, cat_idx] = np.where(improve, gv, gains[:, cat_idx])


def _cat_random(f, hf, parent, parent_score, params, rng, gains, cat_sets):
    """Breiman-style random category subsets (benchmark_rank1 categorical)."""
    kind, l2 = params.stat_kind, params.l2
    n_nodes, nb, S = hf.shape
    T = params.random_cat_trials
    masks = rng.random((T, nb)) < 0.5               # True -> right
    right = np.einsum("tb,nbs->nts", masks.astype(np.float64), hf)
    left = parent[:, None, :] - right
    g = (_score(left, kind, l2) + _score(right, kind, l2) - parent_score[:, None])
    ok = ((_counts(left, kind) >= params.min_examples)
          & (_counts(right, kind) >= params.min_examples))
    g = np.where(ok, g, NEG_INF)
    ti = np.argmax(g, axis=1)
    gv = np.take_along_axis(g, ti[:, None], 1)[:, 0]
    for i in range(n_nodes):
        if gv[i] > gains[i, f]:
            gains[i, f] = gv[i]
            cat_sets[(i, f)] = ("set",
                                np.where(masks[ti[i]])[0].astype(np.int32))


# =====================================================================
# Sparse oblique projections (Tomita et al. 2020; benchmark_rank1 template)
# =====================================================================

def oblique_splits(Xn: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                   stats: np.ndarray, node_of: np.ndarray, n_nodes: int,
                   params: SplitterParams, rng: np.random.Generator) -> list[Split]:
    """Xn: (N, Fn) numerical features; lo/hi: (Fn,) min-max normalization
    bounds. Projections use +-1 weights on a sparse feature subset; projected
    values are linearly binned per projection and scanned like a numerical
    feature. Returns one (possibly invalid) Split per node."""
    N, Fn = Xn.shape
    if Fn == 0:
        return [Split() for _ in range(n_nodes)]
    n_proj = max(1, int(round(Fn ** params.oblique_num_projections_exponent)))
    scale = 1.0 / np.maximum(hi - lo, 1e-12)
    B = params.oblique_bins
    out = [Split() for _ in range(n_nodes)]
    for _ in range(n_proj):
        nnz = max(1, (rng.random(Fn) < params.oblique_density).sum())
        feats = rng.choice(Fn, size=min(nnz, Fn), replace=False)
        w = rng.choice(np.array([-1.0, 1.0]), size=len(feats))
        proj = ((Xn[:, feats] - lo[feats]) * scale[feats]) @ w  # (N,)
        pmin, pmax = float(proj.min()), float(proj.max())
        if pmax - pmin < 1e-12:
            continue
        codes = np.minimum(((proj - pmin) * (B / (pmax - pmin))).astype(np.int64),
                           B - 1).astype(np.uint8)
        hist = build_histogram(codes[:, None], stats, node_of, n_nodes, B)
        kind, l2 = params.stat_kind, params.l2
        h = hist[:, 0]                                  # (n, B, S)
        parent = h.sum(1)
        ps = _score(parent, kind, l2)
        cum = np.cumsum(h, axis=1)[:, :-1]
        right = parent[:, None, :] - cum
        g = _score(cum, kind, l2) + _score(right, kind, l2) - ps[:, None]
        ok = ((_counts(cum, kind) >= params.min_examples)
              & (_counts(right, kind) >= params.min_examples))
        g = np.where(ok, g, NEG_INF)
        if g.shape[1] == 0:
            continue
        bi = np.argmax(g, axis=1)
        gv = np.take_along_axis(g, bi[:, None], 1)[:, 0]
        for i in range(n_nodes):
            if gv[i] > max(out[i].gain, params.min_gain):
                thr = pmin + (int(bi[i]) + 1) * (pmax - pmin) / B
                # fold min-max normalization into weights/threshold:
                w_raw = w * scale[feats]
                t_raw = thr + float((lo[feats] * scale[feats]) @ w)
                out[i] = Split(gain=float(gv[i]), feature=-2,
                               obl_features=feats.astype(np.int32),
                               obl_weights=w_raw.astype(np.float32),
                               threshold=t_raw)
    return out


# =====================================================================
# Exact in-sorting splitter — the reference oracle (paper §2.3)
# =====================================================================

def exact_best_split_numerical(x: np.ndarray, stats: np.ndarray,
                               params: SplitterParams) -> tuple[float, float]:
    """Sort values, scan every midpoint. Returns (gain, threshold)."""
    order = np.argsort(x, kind="stable")
    xs, ss = x[order], stats[order]
    kind, l2 = params.stat_kind, params.l2
    parent = ss.sum(0)
    ps = _score(parent, kind, l2)
    cum = np.cumsum(ss, axis=0)[:-1]
    right = parent[None] - cum
    g = _score(cum, kind, l2) + _score(right, kind, l2) - ps
    ok = ((_counts(cum, kind) >= params.min_examples)
          & (_counts(right, kind) >= params.min_examples)
          & (xs[:-1] != xs[1:]))  # can't split between equal values
    g = np.where(ok, g, NEG_INF)
    if len(g) == 0:
        return NEG_INF, 0.0
    i = int(np.argmax(g))
    thr = 0.5 * (xs[i] + xs[i + 1])
    return float(g[i]), float(thr)


# =====================================================================
# Partition application
# =====================================================================

def apply_split(split: Split, binned: BinnedFeatures, X_raw: np.ndarray,
                idx: np.ndarray) -> np.ndarray:
    """go-right decision for examples `idx`. X_raw: (N, F) raw-valued matrix
    (same column order as binned; categorical columns hold codes)."""
    if split.obl_features is not None:
        proj = X_raw[np.ix_(idx, split.obl_features)] @ split.obl_weights
        return proj >= split.threshold
    codes = binned.codes[idx, split.feature]
    if split.cat_right is not None:
        return np.isin(codes, split.cat_right)
    return codes >= split.split_bin
