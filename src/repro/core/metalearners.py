"""Meta-Learners (paper §3.2): Learners that wrap other Learners.

All four of the paper's examples, each itself a Learner (so they compose —
Fig. 3's calibrator(ensembler(tuner(RF), GBT)) works):

  * HyperParameterTuner — random search over a space (App. C.2), scored by
    cross-validation or train-valid, optimizing loss or accuracy.
  * Ensembler           — averages the predictions of several Learners.
  * Calibrator          — Platt-scales a base Learner's scores on a held-out
    validation split.
  * FeatureSelector     — greedy backward feature elimination using the
    model's Self-Evaluation (§3.6: OOB for RF, validation for GBT).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.api import Learner, Model, Task, YdfError, register_learner
from repro.core.dataspec import VerticalDataset, label_values
from repro.core.evaluation import evaluate_predictions
from repro.core.models import _as_vertical


def _subset(ds: VerticalDataset, idx: np.ndarray) -> VerticalDataset:
    return ds.subset(idx)


def kfold_indices(n: int, k: int, seed: int) -> list[tuple[np.ndarray, np.ndarray]]:
    """Fold splits consistent across learners for fair comparison (§5.2)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    folds = np.array_split(perm, k)
    out = []
    for i in range(k):
        va = np.sort(folds[i])
        tr = np.sort(np.concatenate([folds[j] for j in range(k) if j != i]))
        out.append((tr, va))
    return out


def _score_model(model: Model, ds: VerticalDataset, metric: str) -> float:
    """Higher is better."""
    ev = model.evaluate(ds)
    if metric == "accuracy":
        return ev.metrics["accuracy"]
    if metric == "loss":
        key = "logloss" if model.task == Task.CLASSIFICATION else "rmse"
        return -ev.metrics[key]
    raise YdfError(f"Unknown tuner metric {metric!r}; use 'loss' or 'accuracy'.")


class MetaLearner(Learner):
    """Base: meta-learners have no own hparams dataclass."""

    def default_hparams(self):
        return dataclasses.make_dataclass("Empty", [])()


@register_learner("HYPERPARAMETER_TUNER")
class HyperParameterTuner(MetaLearner):
    """Random-search tuner. The evaluation protocol is itself a
    hyper-parameter of the tuner (paper §3.2): 'train-valid' or 'cv'."""

    def __init__(self, base_factory: Callable[..., Learner], space: dict[str, list],
                 *, label: str, task: Task = Task.CLASSIFICATION,
                 n_trials: int = 30, metric: str = "loss",
                 protocol: str = "train-valid", cv_folds: int = 5,
                 valid_ratio: float = 0.2, seed: int = 1234):
        super().__init__(label, task, seed=seed)
        self.base_factory = base_factory
        self.space = space
        self.n_trials = n_trials
        self.metric = metric
        self.protocol = protocol
        self.cv_folds = cv_folds
        self.valid_ratio = valid_ratio

    def _sample(self, rng) -> dict:
        return {k: v[rng.integers(0, len(v))] for k, v in self.space.items()}

    def train(self, dataset, valid=None) -> Model:
        ds = _as_vertical(dataset)
        rng = np.random.default_rng(self.seed)
        n = ds.n_rows
        trials: list[dict] = []
        seen = set()
        for _ in range(self.n_trials * 5):
            if len(trials) >= self.n_trials:
                break
            hp = self._sample(rng)
            key = tuple(sorted(hp.items()))
            if key not in seen:
                seen.add(key)
                trials.append(hp)

        if self.protocol == "cv":
            folds = kfold_indices(n, self.cv_folds, self.seed)
        else:
            tr, va = kfold_indices(n, max(2, int(round(1 / self.valid_ratio))),
                                   self.seed)[0]
            folds = [(tr, va)]

        best_score, best_hp = -np.inf, None
        log = []
        for hp in trials:
            scores = []
            for tr, va in folds:
                learner = self.base_factory(label=self.label, task=self.task,
                                            seed=self.seed, **hp)
                model = learner.train(_subset(ds, tr))
                scores.append(_score_model(model, _subset(ds, va), self.metric))
            s = float(np.mean(scores))
            log.append({"hparams": hp, "score": s})
            if s > best_score:
                best_score, best_hp = s, hp
        if best_hp is None:
            raise YdfError("Hyper-parameter tuning produced no trials; "
                           "check the search space.")
        final = self.base_factory(label=self.label, task=self.task,
                                  seed=self.seed, **best_hp)
        model = final.train(ds, valid)
        model.tuning_logs = {"best": best_hp, "score": best_score, "trials": log}
        return model


@register_learner("ENSEMBLER")
class Ensembler(MetaLearner):
    def __init__(self, learners: Sequence[Learner], *, label: str,
                 task: Task = Task.CLASSIFICATION, seed: int = 1234):
        super().__init__(label, task, seed=seed)
        self.learners = list(learners)
        if not self.learners:
            raise YdfError("Ensembler requires at least one sub-learner.")

    def train(self, dataset, valid=None) -> "EnsembleModel":
        ds = _as_vertical(dataset)
        models = [l.train(ds, valid) for l in self.learners]
        m0 = models[0]
        return EnsembleModel(models=models, label=self.label, task=self.task,
                             classes=getattr(m0, "classes", None))


class EnsembleModel(Model):
    def __init__(self, *, models, label, task, classes):
        self.models, self.label, self.task, self.classes = models, label, task, classes

    def predict(self, dataset) -> np.ndarray:
        preds = [m.predict(dataset) for m in self.models]
        return np.mean(preds, axis=0)


@register_learner("CALIBRATOR")
class Calibrator(MetaLearner):
    """Platt scaling of a binary classifier's score on a held-out split."""

    def __init__(self, base: Learner, *, label: str,
                 task: Task = Task.CLASSIFICATION, valid_ratio: float = 0.2,
                 seed: int = 1234):
        super().__init__(label, task, seed=seed)
        self.base = base
        self.valid_ratio = valid_ratio

    def train(self, dataset, valid=None) -> "CalibratedModel":
        ds = _as_vertical(dataset)
        if valid is None:
            from repro.core.models import extract_validation
            tr, va = extract_validation(ds.n_rows, self.valid_ratio, self.seed)
            train_ds, valid_ds = _subset(ds, tr), _subset(ds, va)
        else:
            train_ds, valid_ds = ds, _as_vertical(valid, ds.spec)
        base_model = self.base.train(train_ds)
        p = base_model.predict(valid_ds)
        if p.ndim != 2 or p.shape[1] != 2:
            raise YdfError("Calibrator supports binary classification models "
                           f"(got predictions of shape {np.shape(p)}).")
        y = label_values(base_model, valid_ds)
        score = np.log(np.clip(p[:, 1], 1e-9, 1) / np.clip(1 - p[:, 1], 1e-9, 1))
        a, b = _platt_fit(score, y)
        return CalibratedModel(base=base_model, a=a, b=b, label=self.label,
                               task=self.task, classes=base_model.classes)


def _platt_fit(score: np.ndarray, y: np.ndarray, iters: int = 50):
    """1-D logistic regression p = sigmoid(a*score + b) by Newton iterations.
    Uses Platt's smoothed targets t+=(n+ +1)/(n+ +2), t-=1/(n- +2) so the fit
    cannot diverge on a separable validation set."""
    n_pos, n_neg = float((y == 1).sum()), float((y != 1).sum())
    t_pos, t_neg = (n_pos + 1) / (n_pos + 2), 1.0 / (n_neg + 2)
    y = np.where(y == 1, t_pos, t_neg)
    lam = 1e-3  # ridge: keeps the optimum finite and Newton stable
    a, b = 1.0, 0.0
    for _ in range(iters):
        z = np.clip(a * score + b, -35, 35)
        p = 1 / (1 + np.exp(-z))
        g = p - y
        ga, gb = (g * score).sum() + lam * a, g.sum() + lam * b
        h = np.maximum(p * (1 - p), 1e-9)
        haa = (h * score * score).sum() + lam
        hab = (h * score).sum()
        hbb = h.sum() + lam
        det = haa * hbb - hab * hab
        if abs(det) < 1e-12:
            break
        da = (hbb * ga - hab * gb) / det
        db = (haa * gb - hab * ga) / det
        # damp oversized Newton steps (separable-ish validation sets)
        norm = abs(da) + abs(db)
        if norm > 10.0:
            da, db = da * 10.0 / norm, db * 10.0 / norm
        a, b = a - da, b - db
        if norm < 1e-10:
            break
    return float(a), float(b)


class CalibratedModel(Model):
    def __init__(self, *, base, a, b, label, task, classes):
        self.base, self.a, self.b = base, a, b
        self.label, self.task, self.classes = label, task, classes

    def predict(self, dataset) -> np.ndarray:
        p = self.base.predict(dataset)
        score = np.log(np.clip(p[:, 1], 1e-9, 1) / np.clip(1 - p[:, 1], 1e-9, 1))
        p1 = 1 / (1 + np.exp(-np.clip(self.a * score + self.b, -35, 35)))
        return np.stack([1 - p1, p1], 1)


@register_learner("FEATURE_SELECTOR")
class FeatureSelector(MetaLearner):
    """Greedy backward elimination scored by the model's Self-Evaluation
    (OOB for RF — the paper's §3.6 example).

    ``tolerance``: a removal is accepted when the self-eval score drops by at
    most this much (default 0.0 — only score-preserving removals). Self-eval
    scores carry sampling noise (OOB on a few hundred rows moves +-1-2%
    between refits), so a small tolerance is what actually lets elimination
    shed near-zero-value features instead of stalling on noise."""

    def __init__(self, base_factory: Callable[..., Learner], *, label: str,
                 task: Task = Task.CLASSIFICATION, max_removals: int | None = None,
                 tolerance: float = 0.0, seed: int = 1234):
        super().__init__(label, task, seed=seed)
        self.base_factory = base_factory
        self.max_removals = max_removals
        self.tolerance = tolerance

    def train(self, dataset, valid=None) -> Model:
        ds = _as_vertical(dataset)
        features = ds.spec.feature_names(self.label)

        def fit(feats: list[str]) -> Model:
            learner = self.base_factory(label=self.label, task=self.task,
                                        seed=self.seed)
            return learner.train_with_features(ds, feats) \
                if hasattr(learner, "train_with_features") else \
                _train_on_features(learner, ds, feats)

        best_model = fit(features)
        best_score = _self_eval_score(best_model)
        removed = []
        max_rm = self.max_removals or max(0, len(features) - 1)
        improved = True
        while improved and len(features) > 1 and len(removed) < max_rm:
            improved = False
            # fast path: try dropping the 3 least-important features first
            # (NUM_NODES), then — only if none of those helps — the rest.
            # NUM_NODES over-counts deep overfit splits on continuous noise
            # columns, so the guided candidates alone can miss exactly the
            # features most worth dropping.
            vi = best_model.variable_importances().get("NUM_NODES", {})
            order = sorted(features, key=lambda f: vi.get(f, 0.0))
            for cands in (order[:3], order[3:]):
                if not cands:
                    continue
                trials = []
                for cand in cands:
                    trial_feats = [f for f in features if f != cand]
                    m = fit(trial_feats)
                    trials.append((_self_eval_score(m), cand, m, trial_feats))
                s, cand, m, trial_feats = max(trials, key=lambda t: t[0])
                # each single removal may cost at most `tolerance` relative
                # to the CURRENT model (plain thresholded elimination)
                if s >= best_score - self.tolerance:
                    best_model, best_score = m, s
                    features = trial_feats
                    removed.append(cand)
                    improved = True
                    break
        best_model.selected_features = features
        best_model.removed_features = removed
        return best_model


def _train_on_features(learner: Learner, ds: VerticalDataset,
                       feats: list[str]) -> Model:
    keep = set(feats) | {learner.label}
    sub = VerticalDataset(
        spec=dataclasses.replace(
            ds.spec, columns={k: v for k, v in ds.spec.columns.items() if k in keep}),
        numerical={k: v for k, v in ds.numerical.items() if k in keep},
        categorical={k: v for k, v in ds.categorical.items() if k in keep},
        n_rows=ds.n_rows)
    return learner.train(sub)


def _self_eval_score(model: Model) -> float:
    ev = getattr(model, "self_evaluation", None)
    if ev is None:
        raise YdfError(
            "FeatureSelector requires a base learner with Self-Evaluation "
            "(RF out-of-bag or GBT validation). Enable compute_oob / "
            "early_stopping on the base learner.")
    return ev.primary


# --------------------------------------------------------------- CV utility

def cross_validate(make_learner: Callable[[], Learner], dataset, k: int = 10,
                   seed: int = 1234) -> list:
    """Technology-agnostic k-fold CV evaluator (a §3.1 'tool over Learners')."""
    ds = _as_vertical(dataset)
    evals = []
    for tr, va in kfold_indices(ds.n_rows, k, seed):
        model = make_learner().train(_subset(ds, tr))
        evals.append(model.evaluate(_subset(ds, va)))
    return evals
