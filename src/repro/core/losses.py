"""GBT losses: initial prediction, per-example gradients/hessians, and the
loss value (used by early stopping). Predictions are raw scores (logits)."""
from __future__ import annotations

import numpy as np

from repro.core.api import Task, YdfError


class Loss:
    name = "?"
    out_dim = 1

    def init_pred(self, y, w) -> np.ndarray: ...
    def grad_hess(self, pred, y, w) -> tuple[np.ndarray, np.ndarray]:
        """-> grad (N, K), hess (N, K); boosting fits trees to -grad."""
    def value(self, pred, y, w) -> float: ...
    def activation(self, scores) -> np.ndarray: ...


class Binomial(Loss):
    """BINOMIAL_LOG_LIKELIHOOD: y in {0,1}, single logit."""
    name = "BINOMIAL_LOG_LIKELIHOOD"
    out_dim = 1

    def init_pred(self, y, w):
        p = np.clip(np.average(y, weights=w), 1e-6, 1 - 1e-6)
        return np.array([np.log(p / (1 - p))], np.float32)

    def grad_hess(self, pred, y, w):
        p = 1.0 / (1.0 + np.exp(-pred[:, 0]))
        g = (p - y) * w
        h = np.maximum(p * (1 - p), 1e-12) * w
        return g[:, None], h[:, None]

    def value(self, pred, y, w):
        z = pred[:, 0]
        ll = np.logaddexp(0, z) - y * z
        return float(np.average(ll, weights=w))

    def activation(self, scores):
        p1 = 1.0 / (1.0 + np.exp(-scores[:, 0]))
        return np.stack([1 - p1, p1], axis=1)


class Multinomial(Loss):
    name = "MULTINOMIAL_LOG_LIKELIHOOD"

    def __init__(self, n_classes: int):
        self.out_dim = n_classes

    def init_pred(self, y, w):
        pri = np.array([np.average(y == c, weights=w) for c in range(self.out_dim)])
        return np.log(np.clip(pri, 1e-6, None)).astype(np.float32)

    def grad_hess(self, pred, y, w):
        z = pred - pred.max(1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(1, keepdims=True)
        onehot = np.eye(self.out_dim, dtype=np.float64)[y]
        g = (p - onehot) * w[:, None]
        h = np.maximum(p * (1 - p), 1e-12) * w[:, None]
        return g, h

    def value(self, pred, y, w):
        z = pred - pred.max(1, keepdims=True)
        lse = np.log(np.exp(z).sum(1))
        ll = lse - z[np.arange(len(y)), y]
        return float(np.average(ll, weights=w))

    def activation(self, scores):
        z = scores - scores.max(1, keepdims=True)
        p = np.exp(z)
        return p / p.sum(1, keepdims=True)


class SquaredError(Loss):
    name = "SQUARED_ERROR"
    out_dim = 1

    def init_pred(self, y, w):
        return np.array([np.average(y, weights=w)], np.float32)

    def grad_hess(self, pred, y, w):
        return ((pred[:, 0] - y) * w)[:, None], w[:, None].astype(np.float64)

    def value(self, pred, y, w):
        return float(np.average(np.square(pred[:, 0] - y), weights=w))

    def activation(self, scores):
        return scores[:, 0]


def make_loss(task: Task, loss_name: str, n_classes: int) -> Loss:
    if loss_name != "DEFAULT":
        table = {"BINOMIAL": Binomial(), "SQUARED_ERROR": SquaredError(),
                 "MULTINOMIAL": Multinomial(n_classes)}
        if loss_name not in table:
            raise YdfError(f"Unknown loss {loss_name!r}. Available: "
                           f"{sorted(table) + ['DEFAULT']}.")
        return table[loss_name]
    if task == Task.REGRESSION:
        return SquaredError()
    if task == Task.CLASSIFICATION:
        if n_classes < 2:
            raise YdfError(
                f"Classification requires a label with >= 2 classes, found "
                f"{n_classes}. Solutions: (1) check the label column, or (2) "
                "use task=REGRESSION for numerical targets.")
        return Binomial() if n_classes == 2 else Multinomial(n_classes)
    # RANKING is handled by gbt.py directly (repro.tasks.ranking.LambdaMARTLoss
    # needs the group layout, which make_loss does not see)
    raise YdfError(
        f"GBT does not support task={task}. Supported: CLASSIFICATION, "
        "REGRESSION, RANKING. For UPLIFT use UPLIFT_TREES, for ANOMALY use "
        "ISOLATION_FOREST.")
