"""Pluggable histogram-building backends (DESIGN.md §4).

Training spends most of its time accumulating per-node gradient histograms
(paper §3.8). Two implementations of the same contract:

  * "numpy"  — host path: one flattened ``np.bincount`` over
               (node, feature, bin, stat) buckets. Bit-compatible with the
               historical per-stat loop (identical per-bucket accumulation
               order), but a single pass with no per-stat broadcast copies.
  * "pallas" — device path: the one-hot-MXU kernel from
               ``repro/kernels/histogram`` (DESIGN.md §2.1). Compiled on TPU;
               interpret-mode (correctness, slow) elsewhere.

``resolve_backend("auto")`` mirrors the lossy-compilation engine choice in
``engines.py``: hardware-aware, pallas only where it is the fast path.

Backends return float64 arrays; callers cast to float32 for the gain scan.
Backends that genuinely ACCUMULATE in float64 advertise
``exact_subtraction = True`` — only those may serve the parent-minus-sibling
subtraction trick (grower.py, DESIGN.md §4); the pallas kernel accumulates
in float32 on the MXU and returns upcast values, so the growers build both
children directly under it.
"""
from __future__ import annotations

import numpy as np

from repro.core.api import YdfError


class HistogramBackend:
    """Contract: ``build(codes, stats, node_of, n_nodes, max_bins)``.

    codes: (N, F) uint8; stats: (N, S) float; node_of: (N,) int32 in
    [-1, n_nodes) (-1 = inactive example). Returns (n_nodes, F, B, S) float64
    with ``out[n, f, b, s] = sum(stats[i, s] for active i in node n with
    codes[i, f] == b)``.
    """

    name = "?"
    # True when build() accumulates in float64, making parent-minus-sibling
    # subtraction (grower.py) safe: the f64 residual vanishes under the f32
    # cast of the gain scan. Backends that accumulate in float32 (pallas MXU)
    # must not be used for subtraction — residuals of f32-rounding scale can
    # leave derived buckets (e.g. hessians) slightly negative.
    exact_subtraction = False

    def build(self, codes: np.ndarray, stats: np.ndarray, node_of: np.ndarray,
              n_nodes: int, max_bins: int = 256) -> np.ndarray:
        raise NotImplementedError


class NumpyHistogramBackend(HistogramBackend):
    """Feature-major flattened bincount: one (examples,)-length scatter per
    (feature, unique stat) pair. Weight vectors are plain column views — no
    (N, F) broadcast copies — and each scatter touches a single
    (n_nodes * B) strip, so the working set stays cache-resident. Per-bucket
    accumulation order remains example-ascending, which keeps results
    bit-identical to the historical example-major per-stat pass."""

    name = "numpy"
    exact_subtraction = True

    def build(self, codes, stats, node_of, n_nodes, max_bins=256):
        F = codes.shape[1]
        S = stats.shape[1]
        B = max_bins
        act = node_of >= 0
        if not act.all():
            codes, stats, node_of = codes[act], stats[act], node_of[act]
        stats = np.ascontiguousarray(stats, np.float64)
        node = node_of.astype(np.int64) * B
        # Duplicate stat columns (e.g. GBT's hessian-gain-off layout repeats
        # the weight column) are accumulated once and copied to each alias.
        uniq, inv = _unique_stat_columns(stats)
        out = np.empty((n_nodes, F, B, S), np.float64)
        for f in range(F):
            flat = node + codes[:, f]
            strips = [np.bincount(flat, weights=stats[:, s],
                                  minlength=n_nodes * B).reshape(n_nodes, B)
                      for s in uniq]
            for s in range(S):
                out[:, f, :, s] = strips[inv[s]]
        return out


class SimpleHistogramBackend(HistogramBackend):
    """The historical example-major formulation: one bincount per stat over an
    (N, F)-shaped flat index, with per-stat broadcast weight copies. Kept as
    the readable ground-truth module (paper §2.3) — the oracle growth engine
    uses it, and the optimized backends are tested against it bit-for-bit."""

    name = "simple"
    exact_subtraction = True

    def build(self, codes, stats, node_of, n_nodes, max_bins=256):
        F = codes.shape[1]
        S = stats.shape[1]
        B = max_bins
        act = node_of >= 0
        codes_a = codes[act]
        stats_a = stats[act]
        node_a = node_of[act].astype(np.int64)
        out = np.zeros((n_nodes * F * B, S), np.float64)
        base = node_a[:, None] * (F * B) + np.arange(F)[None, :] * B  # (n, F)
        flat = (base + codes_a).ravel()
        for s in range(S):
            w = np.broadcast_to(stats_a[:, s:s + 1], (len(node_a), F)).ravel()
            out[:, s] = np.bincount(flat, weights=w, minlength=n_nodes * F * B)
        return out.reshape(n_nodes, F, B, S)


def _unique_stat_columns(stats: np.ndarray) -> tuple[list[int], np.ndarray]:
    """Indices of the first occurrence of each distinct stat column, plus the
    inverse map expanding unique columns back to the full layout."""
    S = stats.shape[1]
    uniq: list[int] = []
    inv = np.zeros(S, np.int64)
    for s in range(S):
        for k, u in enumerate(uniq):
            if np.array_equal(stats[:, s], stats[:, u]):
                inv[s] = k
                break
        else:
            inv[s] = len(uniq)
            uniq.append(s)
    return uniq, inv


class PallasHistogramBackend(HistogramBackend):
    """One-hot-MXU kernel (DESIGN.md §2.1) behind the host-side contract.

    ``n_nodes`` is padded to the next power of two so the jit cache sees a
    bounded set of shapes as the frontier grows (at most log2(max_nodes)
    compilations per feature count).
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None):
        if interpret is None:
            import jax
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret

    def build(self, codes, stats, node_of, n_nodes, max_bins=256):
        from repro.kernels.histogram.ops import histogram
        n_pad = max(8, 1 << (int(n_nodes) - 1).bit_length())
        impl = "interpret" if self.interpret else "pallas"
        out = histogram(np.ascontiguousarray(codes),
                        np.ascontiguousarray(stats, np.float32),
                        np.ascontiguousarray(node_of, np.int32),
                        n_pad, max_bins, impl=impl)
        return np.asarray(out)[:n_nodes].astype(np.float64)


_CACHE: dict[str, HistogramBackend] = {}
_AUTO_NAME: str | None = None


def _auto_backend_name() -> str:
    """Hardware-aware default, computed once. Importing jax costs seconds, so
    a host that never loaded jax (and has no TPU runtime installed) resolves
    to numpy without paying for it."""
    global _AUTO_NAME
    if _AUTO_NAME is None:
        import importlib.util
        import sys
        if "jax" in sys.modules:
            _AUTO_NAME = ("pallas" if sys.modules["jax"].default_backend()
                          == "tpu" else "numpy")
        elif importlib.util.find_spec("libtpu") is not None:
            import jax
            _AUTO_NAME = ("pallas" if jax.default_backend() == "tpu"
                          else "numpy")
        else:
            _AUTO_NAME = "numpy"
    return _AUTO_NAME


def resolve_backend(name: str | HistogramBackend | None = "auto"
                    ) -> HistogramBackend:
    """Map a ``histogram_backend`` hparam value to a backend instance.

    "auto" is hardware-aware (mirrors engines.compile_model): the pallas
    kernel is only the fast path on TPU; on CPU hosts it would run in
    interpret mode, so numpy wins. Forcing "pallas" without a supporting
    device is an error — interpret mode is orders of magnitude slower than
    numpy and must never end up on the training hot path silently; tests
    and kernel debugging opt in explicitly with "pallas_interpret".
    """
    if isinstance(name, HistogramBackend):
        return name
    if name is None:
        name = "auto"
    if name == "auto":
        name = _auto_backend_name()
    if name == "pallas":
        import jax
        if jax.default_backend() != "tpu":
            raise YdfError(
                "histogram_backend='pallas' requires a TPU device; this host "
                f"has jax backend {jax.default_backend()!r}, where the kernel "
                "would run in interpret mode (orders of magnitude slower "
                "than numpy). Solutions: (1) use histogram_backend='auto' "
                "(hardware-aware), (2) use 'numpy', (3) opt into interpret "
                "mode explicitly with 'pallas_interpret' (tests/debugging "
                "only).")
    if name not in ("numpy", "pallas", "pallas_interpret", "simple"):
        raise YdfError(
            f"Unknown histogram_backend {name!r}. "
            "Expected one of: 'auto', 'numpy', 'pallas', 'pallas_interpret', "
            "'simple'.")
    if name not in _CACHE:
        _CACHE[name] = {
            "numpy": NumpyHistogramBackend,
            "pallas": PallasHistogramBackend,
            "pallas_interpret": lambda: PallasHistogramBackend(interpret=True),
            "simple": SimpleHistogramBackend}[name]()
    return _CACHE[name]
