"""Feature binning for the histogram splitter (paper §3.8 "approximate
splitting by discretization", the TPU-native default — see DESIGN.md §2).

Numerical features are quantile-binned to <=255 uint8 codes; categorical
features map their dictionary ids to codes directly (capped). Missing values
use GLOBAL imputation (mean / most-frequent, §3.4) at binning time.

The *exact* in-sorting splitter (splitters.exact_best_split) remains the
reference oracle: when bin boundaries are the unique feature values, the
histogram splitter must match it exactly (tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.api import YdfError
from repro.core.dataspec import Semantic, VerticalDataset

MAX_BINS = 256  # uint8 codes


@dataclass
class BinnedFeatures:
    codes: np.ndarray                 # (N, F) uint8
    n_bins: np.ndarray                # (F,) int32, actual bins used per feature
    is_cat: np.ndarray                # (F,) bool
    boundaries: list[np.ndarray | None]  # per numerical feature: ascending thresholds
    names: list[str]
    # categorical: code c corresponds to dictionary id c (identity, capped)

    @property
    def n_features(self) -> int:
        return self.codes.shape[1]

    def threshold_value(self, f: int, split_bin: int) -> float:
        """Raw-value threshold for 'code >= split_bin' on numerical feature f:
        x > boundaries[split_bin-1]."""
        b = self.boundaries[f]
        assert b is not None and 1 <= split_bin <= len(b)
        return float(b[split_bin - 1])


def bin_features(ds: VerticalDataset, features: list[str], *,
                 max_bins: int = 255, seed: int = 0) -> BinnedFeatures:
    if not features:
        raise YdfError(
            "No input features. Solutions: (1) pass features explicitly, or "
            "(2) check that the dataset has columns other than the label.")
    N = ds.n_rows
    F = len(features)
    codes = np.zeros((N, F), np.uint8)
    n_bins = np.zeros(F, np.int32)
    is_cat = np.zeros(F, bool)
    boundaries: list[np.ndarray | None] = []
    for j, name in enumerate(features):
        col = ds.spec[name]
        if col.semantic == Semantic.NUMERICAL:
            x = ds.numerical[name].astype(np.float64).copy()
            miss = np.isnan(x)
            if miss.all():
                x[:] = 0.0
            elif miss.any():
                x[miss] = x[~miss].mean()  # GLOBAL imputation
            bounds = _quantile_boundaries(x, max_bins)
            codes[:, j] = np.searchsorted(bounds, x, side="left").astype(np.uint8)
            n_bins[j] = len(bounds) + 1
            boundaries.append(bounds.astype(np.float32))
        else:  # categorical / boolean: ids are already dense
            v = ds.categorical[name].copy()
            if (v < 0).any():
                present = v[v >= 0]
                fill = np.bincount(present).argmax() if present.size else 0
                v[v < 0] = fill  # GLOBAL imputation: most frequent
            v = np.minimum(v, max_bins - 1)
            codes[:, j] = v.astype(np.uint8)
            n_bins[j] = int(v.max()) + 1 if v.size else 1
            is_cat[j] = True
            boundaries.append(None)
    return BinnedFeatures(codes=codes, n_bins=n_bins, is_cat=is_cat,
                          boundaries=boundaries, names=list(features))


def _quantile_boundaries(x: np.ndarray, max_bins: int) -> np.ndarray:
    """Ascending thresholds t_1..t_k (k <= max_bins-1); bin(x) = #(t <= x).
    If the feature has fewer unique values than bins, boundaries are the exact
    midpoints between consecutive unique values -> the histogram splitter is
    then EXACT (matches the in-sorting oracle)."""
    uniq = np.unique(x)
    if len(uniq) <= 1:
        return np.empty(0, np.float64)
    if len(uniq) <= max_bins:
        return (uniq[1:] + uniq[:-1]) / 2.0
    qs = np.quantile(x, np.linspace(0, 1, max_bins + 1)[1:-1], method="nearest")
    return np.unique(qs)
