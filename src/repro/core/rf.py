"""Random Forest learner (Breiman 2001): bootstrap bagging, per-node attribute
sampling (sqrt rule default), deep trees, winner-take-all voting, and
out-of-bag Self-Evaluation (§3.6).
"""
from __future__ import annotations

import hashlib

import numpy as np

from repro.core.api import Learner, Task, YdfError, register_learner
from repro.core.evaluation import evaluate_predictions
from repro.core.grower import GrowthParams, grow_trees, resolve_engine
from repro.core.hparams import RFHparams
from repro.obs import build_training_logs, trace
from repro.core.models import RandomForestModel, prepare_train_data
from repro.core.splitters import SplitterParams
from repro.core.tree import empty_forest, predict_raw


def training_data_fingerprint(X: np.ndarray, y: np.ndarray) -> str:
    """Digest of the encoded feature matrix + labels. The BatchEncoder
    reproduces ``raw_matrix`` bit-for-bit (tested), so re-encoding the
    training dataset at analysis time yields the same digest — and any
    other dataset (even one of equal size) does not."""
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(X, np.float32).tobytes())
    h.update(np.ascontiguousarray(y, np.float64).tobytes())
    return h.hexdigest()


@register_learner("RANDOM_FOREST")
class RandomForestLearner(Learner):
    # hyper-parameter templates (``template="benchmark_rank1"``) are applied
    # by the Learner base BEFORE explicit overrides (§3.11)

    def default_hparams(self) -> RFHparams:
        return RFHparams()

    def train(self, dataset, valid=None, checkpoint=None) -> RandomForestModel:
        hp: RFHparams = self.hparams
        td = prepare_train_data(self, dataset, max_bins=hp.max_bins)
        N, F = td.binned.codes.shape
        if self.task == Task.CLASSIFICATION:
            C = td.n_classes
            stat_kind, out_dim, S = "class", C, C + 1
            onehot = np.eye(C)[td.y]                     # (N, C)
            base_stats = np.concatenate([onehot, np.ones((N, 1))], 1)

            def leaf_fn(s):
                tot = max(s[-1], 1e-12)
                return (s[:-1] / tot).astype(np.float32)
        else:
            stat_kind, out_dim, S = "moment", 1, 3
            base_stats = np.stack([td.y, np.square(td.y), np.ones(N)], 1)

            def leaf_fn(s):
                return np.array([s[0] / max(s[-1], 1e-12)], np.float32)

        if hp.num_candidate_attributes == "SQRT":
            ratio = min(1.0, np.sqrt(F) / F)  # Breiman rule of thumb
        elif hp.num_candidate_attributes == "ALL":
            ratio = 1.0
        else:
            ratio = float(hp.num_candidate_attributes)
        oblique = hp.split_axis == "SPARSE_OBLIQUE"
        sp = SplitterParams(
            stat_kind=stat_kind, min_examples=hp.min_examples,
            categorical_algorithm=hp.categorical_algorithm,
            num_candidate_ratio=ratio, oblique=oblique,
            oblique_num_projections_exponent=hp.sparse_oblique_num_projections_exponent)
        # Per-tree rng streams + keyed per-node feature sampling: every draw
        # is a function of (seed, tree) or (seed, tree, node), never of the
        # order trees or nodes are processed in. That makes the growth
        # schedule semantics-free, so independent trees can grow as lockstep
        # BLOCKS (one level pass over tree_parallelism trees at a time —
        # grower.grow_trees / DESIGN.md §6.3) with forests bit-identical to
        # sequential growth at equal seeds (tested).
        gp = GrowthParams(max_depth=hp.max_depth, max_nodes=hp.max_num_nodes,
                          growing_strategy=hp.growing_strategy, splitter=sp,
                          engine=hp.growth_engine,
                          histogram_backend=hp.histogram_backend,
                          feature_sampling="keyed",
                          sampling_key=self.seed & 0xFFFFFFFF)
        engine_used, fallback = resolve_engine(gp, td.binned, oblique)
        block = max(1, int(hp.tree_parallelism))
        n_num = int((~td.binned.is_cat).sum())
        forest = empty_forest(hp.num_trees, hp.max_num_nodes, out_dim,
                              oblique_dims=n_num if oblique else 0,
                              feature_names=td.features)
        forest.out_dim = out_dim
        forest.tree_class = None
        forest.init_pred = np.zeros(out_dim, np.float32)

        oob_sum = np.zeros((N, out_dim), np.float64)
        oob_cnt = np.zeros(N, np.int64)
        tree_rng = [np.random.default_rng((self.seed & 0xFFFFFFFF, 104729, t))
                    for t in range(hp.num_trees)]

        # -- checkpoint seam (DESIGN.md §11). RF checkpoints only at
        # LOCKSTEP BLOCK boundaries so the resumed `range(trees_done, ...)`
        # realigns with the tree-parallel blocks; per-tree keyed rng streams
        # are re-derived from (seed, tree), so no generator state is stored.
        from repro.train.checkpoint import (
            forest_payload, open_session, restore_forest)
        sess = open_session(checkpoint, self.train_config(),
                            training_data_fingerprint(td.X_raw, td.y))
        trees_done, interrupted = 0, False

        def _payload(complete: bool) -> dict:
            return {"kind": "rf", "trees_done": trees_done,
                    "done": bool(complete),
                    "forest": forest_payload(forest, trees_done),
                    "oob_sum": np.copy(oob_sum), "oob_cnt": np.copy(oob_cnt)}

        if sess is not None:
            state = sess.resume()
            if state is not None:
                trees_done = int(state["trees_done"])
                restore_forest(forest, state["forest"])
                oob_sum[:] = state["oob_sum"]
                oob_cnt[:] = state["oob_cnt"]

        import contextlib
        with (sess if sess is not None else contextlib.nullcontext()):
            for b0 in range(trees_done, hp.num_trees, block):
                ts = list(range(b0, min(b0 + block, hp.num_trees)))
                counts_b, stats_b = [], []
                for t in ts:
                    if hp.bootstrap:
                        counts = tree_rng[t].multinomial(
                            N, np.full(N, 1.0 / N)).astype(np.float64)
                    else:
                        counts = np.ones(N)
                    counts_b.append(counts)
                    stats_b.append(base_stats * counts[:, None])
                with trace.span("rf/block", first_tree=ts[0],
                                trees=len(ts)):
                    grow_trees(forest, ts, td.binned, td.X_raw, stats_b,
                               [c > 0 for c in counts_b], leaf_fn, gp,
                               [tree_rng[t] for t in ts], td.num_lo,
                               td.num_hi, block=block)
                if hp.compute_oob and hp.bootstrap:
                    from repro.core.gbt import _one_tree
                    for bi, t in enumerate(ts):
                        oob = counts_b[bi] == 0
                        if not oob.any():
                            continue
                        pr = predict_raw(_one_tree(forest, t), td.X_raw[oob])[:, 0]
                        if hp.winner_take_all and out_dim > 1:
                            vote = np.zeros_like(pr)
                            vote[np.arange(len(pr)), pr.argmax(1)] = 1.0
                            pr = vote
                        oob_sum[oob] += pr
                        oob_cnt[oob] += 1
                trees_done = ts[-1] + 1
                if sess is not None:
                    complete = trees_done == hp.num_trees
                    if not complete and sess.should_stop():
                        interrupted = True
                    sess.save(trees_done, _payload(complete), done=complete,
                              force=complete or interrupted)
                    if interrupted:
                        break
        if interrupted:
            # servable truncated model: only fully-grown trees survive
            forest = forest.truncated(max(trees_done, 1))

        self_eval = None
        if hp.compute_oob and hp.bootstrap and (oob_cnt > 0).any():
            seen = oob_cnt > 0
            preds = oob_sum[seen] / oob_cnt[seen, None]
            if self.task == Task.CLASSIFICATION:
                preds = preds / np.maximum(preds.sum(1, keepdims=True), 1e-12)
                self_eval = evaluate_predictions(
                    self.task, preds, td.y[seen], classes=td.classes,
                    source="out-of-bag")
            else:
                self_eval = evaluate_predictions(self.task, preds[:, 0],
                                                 td.y[seen], source="out-of-bag")

        model = RandomForestModel(
            winner_take_all=hp.winner_take_all, forest=forest, spec=td.ds.spec,
            features=td.features, label=self.label, task=self.task,
            classes=td.classes, self_evaluation=self_eval)
        oob_logs = None
        if self_eval is not None:
            # surface the OOB result (it was previously reachable only via
            # self_evaluation) and the per-example coverage
            oob_logs = {
                "source": self_eval.source,
                "n_examples": self_eval.n_examples,
                "metrics": {k: float(v) for k, v in self_eval.metrics.items()
                            if isinstance(v, float)},
                "coverage": float((oob_cnt > 0).mean()),
                "mean_trees_per_example": float(oob_cnt.mean()),
            }
        model.training_logs = build_training_logs(
            learner="rf", num_trees=forest.n_trees,
            growth_engine=engine_used, engine_fallback=fallback,
            resilience=sess.events if sess is not None else None,
            interrupted=interrupted,
            extra={"tree_parallelism": block, "oob": oob_logs})
        if hp.compute_oob and hp.bootstrap:
            # everything needed to REGENERATE the per-tree bootstrap bags
            # post-hoc (the multinomial draw is the first consumption of each
            # per-tree rng stream): the OOB permutation-importance engine
            # (repro/analysis) rebuilds counts from this instead of the model
            # storing T x N masks. The fingerprint lets that engine verify a
            # dataset IS the training set (same encoded features + labels),
            # not merely one of the same size.
            model.bag_info = {
                "seed": self.seed & 0xFFFFFFFF, "n_rows": N,
                "num_trees": forest.n_trees,
                "fingerprint": training_data_fingerprint(td.X_raw, td.y)}
        return model
