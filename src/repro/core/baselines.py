"""Baseline learners the paper benchmarks against (§5): a linear model
(TF Linear analogue — trained with JAX autodiff, demonstrating the §2.4
neural-library composition), and an exact-splitter GBT stand-in for the
XGBoost-style "exact" configuration.
"""
from __future__ import annotations

import numpy as np

from repro.core.api import Learner, Model, Task, YdfError, register_learner
from repro.core.dataspec import Semantic, VerticalDataset
from repro.core.models import _as_vertical, prepare_train_data


def _design_matrix(ds: VerticalDataset, features: list[str], spec) -> np.ndarray:
    """Standardized numericals + one-hot categoricals (the paper's encoding
    for libraries without native categorical support)."""
    cols = []
    for name in features:
        col = spec[name]
        if col.semantic == Semantic.NUMERICAL:
            v = ds.numerical[name].astype(np.float64).copy()
            v[np.isnan(v)] = col.mean
            sd = col.std if col.std > 1e-12 else 1.0
            cols.append(((v - col.mean) / sd)[:, None])
        else:
            v = ds.categorical[name].copy()
            v[v < 0] = 0
            V = max(col.vocab_size, int(v.max()) + 1, 2)
            oh = np.zeros((len(v), V), np.float64)
            oh[np.arange(len(v)), v] = 1.0
            cols.append(oh)
    return np.concatenate(cols, axis=1)


class LinearModel(Model):
    def __init__(self, *, W, b, spec, features, label, task, classes):
        self.W, self.b = W, b
        self.spec, self.features = spec, features
        self.label, self.task, self.classes = label, task, classes

    def predict(self, dataset) -> np.ndarray:
        ds = _as_vertical(dataset, self.spec)
        X = _design_matrix(ds, self.features, self.spec)
        z = X @ self.W + self.b
        if self.task == Task.REGRESSION:
            return z[:, 0]
        z = z - z.max(1, keepdims=True)
        p = np.exp(z)
        return p / p.sum(1, keepdims=True)


@register_learner("LINEAR")
class LinearLearner(Learner):
    """Multinomial logistic / linear regression, trained with JAX (Adam)."""

    def default_hparams(self):
        from dataclasses import make_dataclass
        HP = make_dataclass("LinearHparams", [("steps", int, 300),
                                              ("lr", float, 0.05),
                                              ("l2", float, 1e-4)])
        return HP()

    def train(self, dataset, valid=None) -> LinearModel:
        import jax
        import jax.numpy as jnp

        td = prepare_train_data(self, dataset)
        X = _design_matrix(td.ds, td.features, td.ds.spec)
        N, D = X.shape
        K = td.n_classes if self.task == Task.CLASSIFICATION else 1
        y = td.y
        hp = self.hparams
        Xj = jnp.asarray(X, jnp.float32)
        yj = jnp.asarray(y)

        def loss_fn(params):
            z = Xj @ params["W"] + params["b"]
            if self.task == Task.REGRESSION:
                l = jnp.mean(jnp.square(z[:, 0] - yj))
            else:
                l = jnp.mean(jax.nn.logsumexp(z, 1) - z[jnp.arange(N), yj])
            return l + hp.l2 * jnp.sum(jnp.square(params["W"]))

        params = {"W": jnp.zeros((D, K), jnp.float32),
                  "b": jnp.zeros((K,), jnp.float32)}
        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)

        @jax.jit
        def step(params, m, v, t):
            g = jax.grad(loss_fn)(params)
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * jnp.square(b), v, g)
            mh = jax.tree.map(lambda a: a / (1 - 0.9 ** (t + 1)), m)
            vh = jax.tree.map(lambda a: a / (1 - 0.999 ** (t + 1)), v)
            params = jax.tree.map(
                lambda p, a, b: p - hp.lr * a / (jnp.sqrt(b) + 1e-8), params, mh, vh)
            return params, m, v

        for t in range(hp.steps):
            params, m, v = step(params, m, v, t)

        return LinearModel(W=np.asarray(params["W"]), b=np.asarray(params["b"]),
                           spec=td.ds.spec, features=td.features,
                           label=self.label, task=self.task, classes=td.classes)
