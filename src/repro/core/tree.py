"""Decision forests as structure-of-arrays (SoA) — the TPU-native model format.

Pointer-based compact layout (NOT 2^depth-complete, so deep RF trees don't
explode): per tree, arrays of capacity ``max_nodes``; children are allocated
in pairs so ``right = left_child + 1``. Leaves have ``feature == -1``.

Three condition kinds (paper §3.8):
  * numerical axis-aligned:  x[f] >= threshold
  * categorical set:         bit f of cat_mask at x[f]  (id-capped to 255)
  * sparse oblique:          sum_k w_k * x[f_k] >= threshold  (Tomita et al.)

Vectorized inference traverses all (example, tree) pairs in lockstep for
``depth`` rounds of gathers — branch-free, the QuickScorer insight restated
for the VPU/MXU (DESIGN.md §2.2). ``predict_*`` here are the readable
reference engines; repro/kernels/forest_infer holds the Pallas VMEM engine.

Serving additions (DESIGN.md §5):
  * ``compile_predict_raw`` — a one-time specialization of ``predict_raw``
    (flattened node tables, single word-level categorical gather, unused
    condition kinds removed) that the compiled predictor reuses per batch.
  * ``pack_by_depth`` — the depth-packed SoA layout (§5.3): trees sorted by
    depth and grouped into fixed-size blocks, so the tree-tiled kernel pays
    max-depth-per-block rather than global max depth on ragged forests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

MASK_WORDS = 8  # 8 * 32 = 256 category bits


@dataclass
class Forest:
    """A stack of T trees with capacity M nodes each."""
    feature: np.ndarray        # (T, M) int32; -1 = leaf, -2 = oblique
    threshold: np.ndarray      # (T, M) float32 (raw-value domain)
    split_bin: np.ndarray      # (T, M) uint16 (binned domain, for binned engines)
    cat_mask: np.ndarray       # (T, M, MASK_WORDS) uint32; bit set -> go right
    left_child: np.ndarray     # (T, M) int32; -1 = leaf
    leaf_value: np.ndarray     # (T, M, out_dim) float32
    n_nodes: np.ndarray        # (T,) int32
    depth: int                 # max depth over trees
    # oblique extension (all-zero when unused)
    obl_weights: np.ndarray | None = None  # (T, M, P) float32
    obl_features: np.ndarray | None = None # (T, M, P) int32
    # metadata
    out_dim: int = 1
    tree_class: np.ndarray | None = None  # (T,) int32: GBT multiclass tree->class
    init_pred: np.ndarray | None = None   # (out_dim,) float32 bias (GBT)
    feature_names: list[str] = field(default_factory=list)

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def max_nodes(self) -> int:
        return self.feature.shape[1]

    def has_oblique(self) -> bool:
        """True when any live node carries a sparse-oblique condition (the
        single source of truth for engine-compatibility checks)."""
        return bool(self.obl_weights is not None and self.obl_weights.shape[-1]
                    and (self.feature == -2).any())

    # ------------------------------------------ typed tree API (DESIGN.md §7)
    def to_trees(self, *, value_kind: str | None = None) -> list:
        """The SoA as typed ``py_tree.Tree`` nodes (inspect/edit format)."""
        from repro.core.py_tree import forest_to_trees
        return forest_to_trees(self, value_kind=value_kind)

    @staticmethod
    def from_trees(trees: list, **kw) -> "Forest":
        """Typed trees -> SoA; ``from_trees(f.to_trees(), like=f)`` is
        bit-identical for compact forests. See py_tree.forest_from_trees."""
        from repro.core.py_tree import forest_from_trees
        return forest_from_trees(trees, **kw)

    def truncated(self, n_trees: int) -> "Forest":
        sl = lambda a: None if a is None else a[:n_trees]
        return dataclasses.replace(
            self, feature=sl(self.feature), threshold=sl(self.threshold),
            split_bin=sl(self.split_bin), cat_mask=sl(self.cat_mask),
            left_child=sl(self.left_child), leaf_value=sl(self.leaf_value),
            n_nodes=sl(self.n_nodes),
            obl_weights=sl(self.obl_weights), obl_features=sl(self.obl_features),
            tree_class=sl(self.tree_class))

    # -------------------------------------------------- structure stats
    def node_counts(self) -> dict:
        leaves = (self.feature == -1) & _reachable(self)
        per_tree = leaves.sum(1)
        return {"n_trees": self.n_trees, "total_nodes": int(self.n_nodes.sum()),
                "leaves_per_tree_mean": float(per_tree.mean()),
                "nodes_per_tree_mean": float(self.n_nodes.mean())}

    def variable_importances(self) -> dict[str, dict[str, float]]:
        """NUM_AS_ROOT and NUM_NODES (paper App. B.2)."""
        reach = _reachable(self)
        internal = (self.feature >= 0) & reach
        num_nodes: dict[str, float] = {}
        num_root: dict[str, float] = {}
        for name in self.feature_names:
            num_nodes[name] = 0.0
            num_root[name] = 0.0
        flat = self.feature[internal]
        for f, c in zip(*np.unique(flat, return_counts=True)):
            if 0 <= f < len(self.feature_names):
                num_nodes[self.feature_names[f]] = float(c)
        roots = self.feature[:, 0]
        for f, c in zip(*np.unique(roots[roots >= 0], return_counts=True)):
            num_root[self.feature_names[f]] = float(c)
        return {"NUM_NODES": num_nodes, "NUM_AS_ROOT": num_root}


def _reachable(forest: Forest) -> np.ndarray:
    reach = np.zeros(forest.feature.shape, bool)
    reach[:, 0] = True
    for t in range(forest.n_trees):
        for i in range(forest.n_nodes[t]):
            if reach[t, i] and forest.left_child[t, i] >= 0:
                reach[t, forest.left_child[t, i]] = True
                reach[t, forest.left_child[t, i] + 1] = True
    return reach


def empty_forest(n_trees: int, max_nodes: int, out_dim: int, *,
                 oblique_dims: int = 0, feature_names: list[str] | None = None) -> Forest:
    T, M = n_trees, max_nodes
    return Forest(
        feature=np.full((T, M), -1, np.int32),
        threshold=np.zeros((T, M), np.float32),
        split_bin=np.zeros((T, M), np.uint16),
        cat_mask=np.zeros((T, M, MASK_WORDS), np.uint32),
        left_child=np.full((T, M), -1, np.int32),
        leaf_value=np.zeros((T, M, out_dim), np.float32),
        n_nodes=np.ones(T, np.int32),
        depth=0,
        obl_weights=np.zeros((T, M, oblique_dims), np.float32) if oblique_dims else None,
        obl_features=np.zeros((T, M, oblique_dims), np.int32) if oblique_dims else None,
        out_dim=out_dim,
        tree_class=np.zeros(T, np.int32),
        init_pred=np.zeros(out_dim, np.float32),
        feature_names=list(feature_names or []),
    )


# =====================================================================
# Reference engines (numpy). See repro/core/engines.py for selection and
# repro/kernels/forest_infer for the Pallas VMEM engine.
# =====================================================================

def eval_node_conditions(forest: Forest, X: np.ndarray, t: np.ndarray,
                         node: np.ndarray) -> np.ndarray:
    """Branch decision (True = right) for (example, tree) pairs.

    X: (N, 1, F) float32 (categorical features hold integer codes);
    t, node: (N, T) int arrays.
    """
    f = forest.feature[t, node]                       # (N, T)
    is_leaf = f == -1
    is_obl = f == -2
    f_safe = np.maximum(f, 0)
    x = np.take_along_axis(X, f_safe[..., None], axis=-1)[..., 0]  # (N, T)
    go = x >= forest.threshold[t, node]
    # categorical: bit test on the node's category mask
    cat = forest.cat_mask[t, node]                    # (N, T, MASK_WORDS)
    code = np.clip(x.astype(np.int64), 0, MASK_WORDS * 32 - 1)
    word = np.take_along_axis(cat, (code // 32)[..., None], axis=-1)[..., 0]
    bit = (word >> (code % 32).astype(np.uint32)) & 1
    go = np.where(cat.any(axis=-1), bit.astype(bool), go)
    if forest.obl_weights is not None and forest.obl_weights.shape[-1]:
        w = forest.obl_weights[t, node]               # (N, T, P)
        fo = forest.obl_features[t, node]             # (N, T, P)
        xs = np.take_along_axis(np.broadcast_to(X, fo.shape[:2] + X.shape[-1:]),
                                fo, axis=-1)
        proj = (w * xs).sum(-1)
        go = np.where(is_obl, proj >= forest.threshold[t, node], go)
    return np.where(is_leaf, False, go)


def predict_raw(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Vectorized lockstep traversal. X: (N, F) float32. -> (N, T) leaf scalar
    (out_dim=1) or (N, T, out_dim)."""
    N = X.shape[0]
    T = forest.n_trees
    t = np.arange(T)[None, :].repeat(N, 0)        # (N, T)
    node = np.zeros((N, T), np.int64)
    Xe = X[:, None, :]                             # (N, 1, F) broadcast over trees
    for _ in range(max(1, forest.depth)):
        go = eval_node_conditions(forest, Xe, t, node)
        child = forest.left_child[t, node]
        nxt = child + go
        node = np.where(child >= 0, nxt, node)
    out = forest.leaf_value[t, node]               # (N, T, out_dim)
    return out


def predict_naive(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Algorithm 1 of the paper: per-example while-loop. The readable oracle."""
    N = X.shape[0]
    out = np.zeros((N, forest.n_trees, forest.leaf_value.shape[-1]), np.float32)
    for n in range(N):
        for t in range(forest.n_trees):
            node = 0
            while forest.left_child[t, node] >= 0:
                f = forest.feature[t, node]
                if f == -2:
                    proj = float(np.dot(forest.obl_weights[t, node],
                                        X[n, forest.obl_features[t, node]]))
                    go = proj >= forest.threshold[t, node]
                elif forest.cat_mask[t, node].any():
                    code = int(X[n, f])
                    code = min(max(code, 0), MASK_WORDS * 32 - 1)
                    go = bool((forest.cat_mask[t, node, code // 32] >> (code % 32)) & 1)
                else:
                    go = X[n, f] >= forest.threshold[t, node]
                node = forest.left_child[t, node] + int(go)
            out[n, t] = forest.leaf_value[t, node]
    return out


def compile_predict_raw(forest: Forest):
    """One-time specialization of ``predict_raw`` for serving (DESIGN.md §5.1).

    Compared to the generic lockstep traversal, compilation:
      * flattens the (T, M) node tables once, so every round reuses a single
        (N, T) flat index for the feature/threshold/child gathers instead of
        rebuilding advanced-index pairs;
      * gathers only the addressed 32-bit mask word per categorical test
        (the generic path materializes the full (N, T, MASK_WORDS) block);
      * drops condition kinds the forest does not use — a pure-numerical
        forest pays nothing for the categorical path (lossy-compilation
        specialization, §3.7).

    Oblique forests fall back to the generic traversal (still a valid
    compiled predictor; the specialization simply does not apply).
    Returns ``run(X: (N, F) float32) -> (N, T, out_dim) float32``.
    """
    if forest.has_oblique():
        return lambda X: predict_raw(forest, X)
    T, M = forest.n_trees, forest.max_nodes
    depth = max(1, forest.depth)
    feat_flat = np.ascontiguousarray(forest.feature.ravel())
    thr_flat = np.ascontiguousarray(forest.threshold.ravel())
    lc_flat = np.ascontiguousarray(forest.left_child.ravel())
    # trailing leaf dim can differ from out_dim (GBT multiclass stores
    # scalar leaves + a tree->class map)
    leaf_flat = np.ascontiguousarray(
        forest.leaf_value.reshape(T * M, forest.leaf_value.shape[-1]))
    off = (np.arange(T, dtype=np.int64) * M)[None, :]          # (1, T)
    has_cat = bool(forest.cat_mask.any())
    if has_cat:
        is_cat_flat = forest.cat_mask.any(-1).ravel()
        catw_flat = np.ascontiguousarray(forest.cat_mask.ravel())  # (T*M*W,)

    def run(X: np.ndarray) -> np.ndarray:
        N = X.shape[0]
        rows = np.arange(N)[:, None]
        node = np.zeros((N, T), np.int64)
        for _ in range(depth):
            idx = node + off                                   # (N, T) flat
            f = feat_flat[idx]
            x = X[rows, np.maximum(f, 0)]                      # (N, T)
            go = x >= thr_flat[idx]
            if has_cat:
                code = np.clip(x.astype(np.int64), 0, MASK_WORDS * 32 - 1)
                word = catw_flat[idx * MASK_WORDS + (code >> 5)]
                bit = (word >> (code & 31).astype(np.uint32)) & 1
                go = np.where(is_cat_flat[idx], bit.astype(bool), go)
            lc = lc_flat[idx]
            node = np.where(lc >= 0, lc + go, node)
        return leaf_flat[node + off]                           # (N, T, O)

    return run


# ------------------------------------------------- depth-packed layout (§5.3)

def tree_depths(forest: Forest) -> np.ndarray:
    """Per-tree depth, (T,) int32, by level-order frontier propagation: each
    pass expands every frontier node of every tree at once, so the cost is
    O(depth) vectorized passes over O(total nodes) work — flat host time
    even for the arbitrarily-large forests the tiled kernel accepts."""
    T = forest.n_trees
    depths = np.zeros(T, np.int32)
    if T == 0:
        return depths
    cur_t = np.arange(T, dtype=np.int64)   # frontier (tree, node) pairs
    cur_n = np.zeros(T, np.int64)
    level = 0
    while cur_t.size:
        lc = forest.left_child[cur_t, cur_n]
        m = lc >= 0
        if not m.any():
            break
        level += 1
        ct, cl = cur_t[m], lc[m]
        depths[ct] = level                  # deepest level seen so far wins
        cur_t = np.concatenate([ct, ct])
        cur_n = np.concatenate([cl, cl + 1])
    return depths


@dataclass
class PackedForest:
    """Depth-packed SoA (DESIGN.md §5.3): trees sorted by depth, grouped into
    ``n_blocks`` blocks of ``trees_per_block``, node capacity trimmed to the
    forest's live node count (padded to ``node_tile``). ``block_depth`` lets
    the tree-tiled kernel (§5.2) bound its traversal loop per block, and
    ``inv_order`` restores the original tree order after the kernel."""
    feature: np.ndarray      # (B, TB, M) int32
    threshold: np.ndarray    # (B, TB, M) float32
    cat_mask: np.ndarray     # (B, TB, M, MASK_WORDS) uint32
    left_child: np.ndarray   # (B, TB, M) int32
    leaf_value: np.ndarray   # (B, TB, M, out_dim) float32
    block_depth: np.ndarray  # (B, 1) int32: max tree depth within the block
    inv_order: np.ndarray    # (T,) int32: original tree t lives at packed
                             # slot inv_order[t] (flat over (B, TB))
    n_trees: int             # original T (packed slots beyond are padding)
    out_dim: int             # trailing leaf dim (1 for GBT multiclass)

    @property
    def n_blocks(self) -> int:
        return self.feature.shape[0]

    @property
    def trees_per_block(self) -> int:
        return self.feature.shape[1]

    @property
    def max_nodes(self) -> int:
        return self.feature.shape[2]


def pack_by_depth(forest: Forest, *, trees_per_block: int | None = None,
                  node_tile: int = 128,
                  vmem_budget_bytes: int = 4 * 1024 * 1024) -> PackedForest:
    """Pack a Forest for the tree-tiled kernel (DESIGN.md §5.2–§5.3).

    Trees are sorted by depth so each block is depth-homogeneous; the kernel
    runs ``block_depth[b]`` traversal rounds instead of the global max.
    ``trees_per_block`` defaults to as many trees as fit the per-step VMEM
    budget given the trimmed node capacity — large-node forests degrade to
    one tree per block rather than refusing to compile (this is what removes
    the old 4096-node ceiling)."""
    T = forest.n_trees
    O = forest.leaf_value.shape[-1]
    depths = tree_depths(forest)
    # trim capacity to live nodes, pad to the kernel's node tile
    live = int(forest.n_nodes.max()) if T else 1
    M = max(node_tile, -(-live // node_tile) * node_tile)
    # feat/thr/lc f32 + cat mask as TWO f32 half-word arrays in-kernel + leaf
    bytes_per_tree = M * (4 * 3 + 2 * 4 * MASK_WORDS + 4 * O)
    if trees_per_block is None:
        trees_per_block = int(max(1, min(8, vmem_budget_bytes // max(1, bytes_per_tree))))
    TB = min(trees_per_block, max(1, T))
    order = np.argsort(depths, kind="stable").astype(np.int32)  # slot -> tree
    B = -(-max(1, T) // TB)
    S = B * TB

    def take(a, fill=0):
        # (T, M_old, ...) -> (B, TB, M, ...) in sorted order, padded trees
        out_shape = (S, M) + a.shape[2:]
        out = np.full(out_shape, fill, a.dtype)
        if T:
            m = min(M, a.shape[1])
            out[:T, :m] = a[order][:, :m]
        return out.reshape((B, TB) + out_shape[1:])

    feature = take(forest.feature, -1)
    left_child = take(forest.left_child, -1)
    threshold = take(forest.threshold)
    cat_mask = take(forest.cat_mask)
    leaf_value = take(forest.leaf_value)
    block_depth = np.zeros((B, 1), np.int32)
    if T:
        sorted_d = np.zeros(S, np.int32)
        sorted_d[:T] = depths[order]
        block_depth[:, 0] = np.maximum(
            sorted_d.reshape(B, TB).max(axis=1), 1)
    inv_order = np.empty(T, np.int32)
    inv_order[order] = np.arange(T, dtype=np.int32)
    return PackedForest(feature=feature, threshold=threshold, cat_mask=cat_mask,
                        left_child=left_child, leaf_value=leaf_value,
                        block_depth=block_depth, inv_order=inv_order,
                        n_trees=T, out_dim=O)


# ------------------------------------------------------------ aggregation

def aggregate_gbt(per_tree: np.ndarray, forest: Forest) -> np.ndarray:
    """Sum tree outputs into (N, out_dim) logits/score, adding init_pred."""
    N, T = per_tree.shape[:2]
    out = np.tile(forest.init_pred[None, :], (N, 1)).astype(np.float32)
    if forest.out_dim == 1 or forest.tree_class is None:
        out += per_tree.sum(axis=1)[:, : forest.out_dim]
    else:
        for c in range(forest.out_dim):
            sel = forest.tree_class == c
            out[:, c] += per_tree[:, sel, 0].sum(axis=1)
    return out


def aggregate_rf(per_tree: np.ndarray, winner_take_all: bool) -> np.ndarray:
    """per_tree: (N, T, C) leaf distributions -> (N, C) probabilities."""
    if winner_take_all and per_tree.shape[-1] > 1:
        votes = per_tree.argmax(-1)                     # (N, T)
        C = per_tree.shape[-1]
        out = np.zeros((per_tree.shape[0], C), np.float32)
        for c in range(C):
            out[:, c] = (votes == c).mean(axis=1)
        return out
    return per_tree.mean(axis=1)
