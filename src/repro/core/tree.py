"""Decision forests as structure-of-arrays (SoA) — the TPU-native model format.

Pointer-based compact layout (NOT 2^depth-complete, so deep RF trees don't
explode): per tree, arrays of capacity ``max_nodes``; children are allocated
in pairs so ``right = left_child + 1``. Leaves have ``feature == -1``.

Three condition kinds (paper §3.8):
  * numerical axis-aligned:  x[f] >= threshold
  * categorical set:         bit f of cat_mask at x[f]  (id-capped to 255)
  * sparse oblique:          sum_k w_k * x[f_k] >= threshold  (Tomita et al.)

Vectorized inference traverses all (example, tree) pairs in lockstep for
``depth`` rounds of gathers — branch-free, the QuickScorer insight restated
for the VPU/MXU (DESIGN.md §2.2). ``predict_*`` here are the readable
reference engines; repro/kernels/forest_infer holds the Pallas VMEM engine.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

MASK_WORDS = 8  # 8 * 32 = 256 category bits


@dataclass
class Forest:
    """A stack of T trees with capacity M nodes each."""
    feature: np.ndarray        # (T, M) int32; -1 = leaf, -2 = oblique
    threshold: np.ndarray      # (T, M) float32 (raw-value domain)
    split_bin: np.ndarray      # (T, M) uint16 (binned domain, for binned engines)
    cat_mask: np.ndarray       # (T, M, MASK_WORDS) uint32; bit set -> go right
    left_child: np.ndarray     # (T, M) int32; -1 = leaf
    leaf_value: np.ndarray     # (T, M, out_dim) float32
    n_nodes: np.ndarray        # (T,) int32
    depth: int                 # max depth over trees
    # oblique extension (all-zero when unused)
    obl_weights: np.ndarray | None = None  # (T, M, P) float32
    obl_features: np.ndarray | None = None # (T, M, P) int32
    # metadata
    out_dim: int = 1
    tree_class: np.ndarray | None = None  # (T,) int32: GBT multiclass tree->class
    init_pred: np.ndarray | None = None   # (out_dim,) float32 bias (GBT)
    feature_names: list[str] = field(default_factory=list)

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def max_nodes(self) -> int:
        return self.feature.shape[1]

    def truncated(self, n_trees: int) -> "Forest":
        sl = lambda a: None if a is None else a[:n_trees]
        return dataclasses.replace(
            self, feature=sl(self.feature), threshold=sl(self.threshold),
            split_bin=sl(self.split_bin), cat_mask=sl(self.cat_mask),
            left_child=sl(self.left_child), leaf_value=sl(self.leaf_value),
            n_nodes=sl(self.n_nodes),
            obl_weights=sl(self.obl_weights), obl_features=sl(self.obl_features),
            tree_class=sl(self.tree_class))

    # -------------------------------------------------- structure stats
    def node_counts(self) -> dict:
        leaves = (self.feature == -1) & _reachable(self)
        per_tree = leaves.sum(1)
        return {"n_trees": self.n_trees, "total_nodes": int(self.n_nodes.sum()),
                "leaves_per_tree_mean": float(per_tree.mean()),
                "nodes_per_tree_mean": float(self.n_nodes.mean())}

    def variable_importances(self) -> dict[str, dict[str, float]]:
        """NUM_AS_ROOT and NUM_NODES (paper App. B.2)."""
        reach = _reachable(self)
        internal = (self.feature >= 0) & reach
        num_nodes: dict[str, float] = {}
        num_root: dict[str, float] = {}
        for name in self.feature_names:
            num_nodes[name] = 0.0
            num_root[name] = 0.0
        flat = self.feature[internal]
        for f, c in zip(*np.unique(flat, return_counts=True)):
            if 0 <= f < len(self.feature_names):
                num_nodes[self.feature_names[f]] = float(c)
        roots = self.feature[:, 0]
        for f, c in zip(*np.unique(roots[roots >= 0], return_counts=True)):
            num_root[self.feature_names[f]] = float(c)
        return {"NUM_NODES": num_nodes, "NUM_AS_ROOT": num_root}


def _reachable(forest: Forest) -> np.ndarray:
    reach = np.zeros(forest.feature.shape, bool)
    reach[:, 0] = True
    for t in range(forest.n_trees):
        for i in range(forest.n_nodes[t]):
            if reach[t, i] and forest.left_child[t, i] >= 0:
                reach[t, forest.left_child[t, i]] = True
                reach[t, forest.left_child[t, i] + 1] = True
    return reach


def empty_forest(n_trees: int, max_nodes: int, out_dim: int, *,
                 oblique_dims: int = 0, feature_names: list[str] | None = None) -> Forest:
    T, M = n_trees, max_nodes
    return Forest(
        feature=np.full((T, M), -1, np.int32),
        threshold=np.zeros((T, M), np.float32),
        split_bin=np.zeros((T, M), np.uint16),
        cat_mask=np.zeros((T, M, MASK_WORDS), np.uint32),
        left_child=np.full((T, M), -1, np.int32),
        leaf_value=np.zeros((T, M, out_dim), np.float32),
        n_nodes=np.ones(T, np.int32),
        depth=0,
        obl_weights=np.zeros((T, M, oblique_dims), np.float32) if oblique_dims else None,
        obl_features=np.zeros((T, M, oblique_dims), np.int32) if oblique_dims else None,
        out_dim=out_dim,
        tree_class=np.zeros(T, np.int32),
        init_pred=np.zeros(out_dim, np.float32),
        feature_names=list(feature_names or []),
    )


# =====================================================================
# Reference engines (numpy). See repro/core/engines.py for selection and
# repro/kernels/forest_infer for the Pallas VMEM engine.
# =====================================================================

def eval_node_conditions(forest: Forest, X: np.ndarray, t: np.ndarray,
                         node: np.ndarray) -> np.ndarray:
    """Branch decision (True = right) for (example, tree) pairs.

    X: (N, 1, F) float32 (categorical features hold integer codes);
    t, node: (N, T) int arrays.
    """
    f = forest.feature[t, node]                       # (N, T)
    is_leaf = f == -1
    is_obl = f == -2
    f_safe = np.maximum(f, 0)
    x = np.take_along_axis(X, f_safe[..., None], axis=-1)[..., 0]  # (N, T)
    go = x >= forest.threshold[t, node]
    # categorical: bit test on the node's category mask
    cat = forest.cat_mask[t, node]                    # (N, T, MASK_WORDS)
    code = np.clip(x.astype(np.int64), 0, MASK_WORDS * 32 - 1)
    word = np.take_along_axis(cat, (code // 32)[..., None], axis=-1)[..., 0]
    bit = (word >> (code % 32).astype(np.uint32)) & 1
    go = np.where(cat.any(axis=-1), bit.astype(bool), go)
    if forest.obl_weights is not None and forest.obl_weights.shape[-1]:
        w = forest.obl_weights[t, node]               # (N, T, P)
        fo = forest.obl_features[t, node]             # (N, T, P)
        xs = np.take_along_axis(np.broadcast_to(X, fo.shape[:2] + X.shape[-1:]),
                                fo, axis=-1)
        proj = (w * xs).sum(-1)
        go = np.where(is_obl, proj >= forest.threshold[t, node], go)
    return np.where(is_leaf, False, go)


def predict_raw(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Vectorized lockstep traversal. X: (N, F) float32. -> (N, T) leaf scalar
    (out_dim=1) or (N, T, out_dim)."""
    N = X.shape[0]
    T = forest.n_trees
    t = np.arange(T)[None, :].repeat(N, 0)        # (N, T)
    node = np.zeros((N, T), np.int64)
    Xe = X[:, None, :]                             # (N, 1, F) broadcast over trees
    for _ in range(max(1, forest.depth)):
        go = eval_node_conditions(forest, Xe, t, node)
        child = forest.left_child[t, node]
        nxt = child + go
        node = np.where(child >= 0, nxt, node)
    out = forest.leaf_value[t, node]               # (N, T, out_dim)
    return out


def predict_naive(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Algorithm 1 of the paper: per-example while-loop. The readable oracle."""
    N = X.shape[0]
    out = np.zeros((N, forest.n_trees, forest.out_dim), np.float32)
    for n in range(N):
        for t in range(forest.n_trees):
            node = 0
            while forest.left_child[t, node] >= 0:
                f = forest.feature[t, node]
                if f == -2:
                    proj = float(np.dot(forest.obl_weights[t, node],
                                        X[n, forest.obl_features[t, node]]))
                    go = proj >= forest.threshold[t, node]
                elif forest.cat_mask[t, node].any():
                    code = int(X[n, f])
                    code = min(max(code, 0), MASK_WORDS * 32 - 1)
                    go = bool((forest.cat_mask[t, node, code // 32] >> (code % 32)) & 1)
                else:
                    go = X[n, f] >= forest.threshold[t, node]
                node = forest.left_child[t, node] + int(go)
            out[n, t] = forest.leaf_value[t, node]
    return out


# ------------------------------------------------------------ aggregation

def aggregate_gbt(per_tree: np.ndarray, forest: Forest) -> np.ndarray:
    """Sum tree outputs into (N, out_dim) logits/score, adding init_pred."""
    N, T = per_tree.shape[:2]
    out = np.tile(forest.init_pred[None, :], (N, 1)).astype(np.float32)
    if forest.out_dim == 1 or forest.tree_class is None:
        out += per_tree.sum(axis=1)[:, : forest.out_dim]
    else:
        for c in range(forest.out_dim):
            sel = forest.tree_class == c
            out[:, c] += per_tree[:, sel, 0].sum(axis=1)
    return out


def aggregate_rf(per_tree: np.ndarray, winner_take_all: bool) -> np.ndarray:
    """per_tree: (N, T, C) leaf distributions -> (N, C) probabilities."""
    if winner_take_all and per_tree.shape[-1] > 1:
        votes = per_tree.argmax(-1)                     # (N, T)
        C = per_tree.shape[-1]
        out = np.zeros((per_tree.shape[0], C), np.float32)
        for c in range(C):
            out[:, c] = (votes == c).mean(axis=1)
        return out
    return per_tree.mean(axis=1)
