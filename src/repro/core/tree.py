"""Decision forests as structure-of-arrays (SoA) — the TPU-native model format.

Pointer-based compact layout (NOT 2^depth-complete, so deep RF trees don't
explode): per tree, arrays of capacity ``max_nodes``; children are allocated
in pairs so ``right = left_child + 1``. Leaves have ``feature == -1``.

Three condition kinds (paper §3.8):
  * numerical axis-aligned:  x[f] >= threshold
  * categorical set:         bit f of cat_mask at x[f]  (id-capped to 255)
  * sparse oblique:          sum_k w_k * x[f_k] >= threshold  (Tomita et al.)

Vectorized inference traverses all (example, tree) pairs in lockstep for
``depth`` rounds of gathers — branch-free, the QuickScorer insight restated
for the VPU/MXU (DESIGN.md §2.2). ``predict_*`` here are the readable
reference engines; repro/kernels/forest_infer holds the Pallas VMEM engine.

Serving additions (DESIGN.md §5):
  * ``compile_predict_raw`` — a one-time specialization of ``predict_raw``
    (flattened node tables, single word-level categorical gather, unused
    condition kinds removed) that the compiled predictor reuses per batch.
  * ``pack_by_depth`` — the depth-packed SoA layout (§5.3): trees sorted by
    depth and grouped into fixed-size blocks, so the tree-tiled kernel pays
    max-depth-per-block rather than global max depth on ragged forests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

MASK_WORDS = 8  # 8 * 32 = 256 category bits


@dataclass
class Forest:
    """A stack of T trees with capacity M nodes each."""
    feature: np.ndarray        # (T, M) int32; -1 = leaf, -2 = oblique
    threshold: np.ndarray      # (T, M) float32 (raw-value domain)
    split_bin: np.ndarray      # (T, M) uint16 (binned domain, for binned engines)
    cat_mask: np.ndarray       # (T, M, MASK_WORDS) uint32; bit set -> go right
    left_child: np.ndarray     # (T, M) int32; -1 = leaf
    leaf_value: np.ndarray     # (T, M, out_dim) float32
    n_nodes: np.ndarray        # (T,) int32
    depth: int                 # max depth over trees
    # oblique extension (all-zero when unused)
    obl_weights: np.ndarray | None = None  # (T, M, P) float32
    obl_features: np.ndarray | None = None # (T, M, P) int32
    # split gain recorded at training time (analysis §8: SUM_SCORE variable
    # importance). None on forests predating the field (old pickles); zero on
    # built/imported forests, whose trees carry no training gains.
    split_gain: np.ndarray | None = None   # (T, M) float32
    # metadata
    out_dim: int = 1
    tree_class: np.ndarray | None = None  # (T,) int32: GBT multiclass tree->class
    init_pred: np.ndarray | None = None   # (out_dim,) float32 bias (GBT)
    feature_names: list[str] = field(default_factory=list)

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def max_nodes(self) -> int:
        return self.feature.shape[1]

    def has_oblique(self) -> bool:
        """True when any live node carries a sparse-oblique condition (the
        single source of truth for engine-compatibility checks)."""
        return bool(self.obl_weights is not None and self.obl_weights.shape[-1]
                    and (self.feature == -2).any())

    # ------------------------------------------ typed tree API (DESIGN.md §7)
    def to_trees(self, *, value_kind: str | None = None) -> list:
        """The SoA as typed ``py_tree.Tree`` nodes (inspect/edit format)."""
        from repro.core.py_tree import forest_to_trees
        return forest_to_trees(self, value_kind=value_kind)

    @staticmethod
    def from_trees(trees: list, **kw) -> "Forest":
        """Typed trees -> SoA; ``from_trees(f.to_trees(), like=f)`` is
        bit-identical for compact forests. See py_tree.forest_from_trees."""
        from repro.core.py_tree import forest_from_trees
        return forest_from_trees(trees, **kw)

    def truncated(self, n_trees: int) -> "Forest":
        sl = lambda a: None if a is None else a[:n_trees]
        return dataclasses.replace(
            self, feature=sl(self.feature), threshold=sl(self.threshold),
            split_bin=sl(self.split_bin), cat_mask=sl(self.cat_mask),
            left_child=sl(self.left_child), leaf_value=sl(self.leaf_value),
            n_nodes=sl(self.n_nodes),
            obl_weights=sl(self.obl_weights), obl_features=sl(self.obl_features),
            split_gain=sl(self.split_gain),
            tree_class=sl(self.tree_class))

    # -------------------------------------------------- structure stats
    def node_counts(self) -> dict:
        # a leaf is any reachable node without children — including CART-
        # pruned nodes, which keep their stale condition but no children
        leaves = (self.left_child < 0) & _reachable(self)
        per_tree = leaves.sum(1)
        return {"n_trees": self.n_trees, "total_nodes": int(self.n_nodes.sum()),
                "leaves_per_tree_mean": float(per_tree.mean()),
                "nodes_per_tree_mean": float(self.n_nodes.mean())}

    def variable_importances(self) -> dict[str, dict[str, float]]:
        """Structural variable importances (paper App. B.2), one vectorized
        pass over the SoA (analysis subsystem, DESIGN.md §8):

          * NUM_NODES          — #splits using the feature
          * NUM_AS_ROOT        — #trees whose root splits on it
          * SUM_SCORE          — total split gain (recorded at training time;
                                 omitted when no gains were recorded)
          * INV_MEAN_MIN_DEPTH — 1 / (1 + mean over trees of the minimal
                                 depth at which the feature appears; a tree
                                 not using the feature contributes its own
                                 depth). Higher = closer to the roots.

        Every kind is higher-is-more-important so reports can share one
        sort order. A pruned node (CART: left_child reset to -1 while the
        stale condition remains) is a leaf and counts toward nothing.
        """
        depth = node_depths(self)
        reach = depth >= 0
        internal = (self.left_child >= 0) & reach
        F = len(self.feature_names)
        name_of = self.feature_names

        def table(counts: np.ndarray) -> dict[str, float]:
            return {name_of[j]: float(counts[j]) for j in range(F)}

        t_idx, n_idx = np.nonzero(internal)
        feats = self.feature[t_idx, n_idx]
        # oblique nodes (feature == -2) reference several columns each
        if (feats == -2).any() and self.obl_features is not None:
            ax = feats >= 0
            obl = feats == -2
            w = self.obl_weights[t_idx[obl], n_idx[obl]]       # (n_obl, P)
            fo = self.obl_features[t_idx[obl], n_idx[obl]]
            live = w != 0.0
            t_ax = np.concatenate([t_idx[ax], np.repeat(t_idx[obl], live.sum(1))])
            n_ax = np.concatenate([n_idx[ax], np.repeat(n_idx[obl], live.sum(1))])
            f_ax = np.concatenate([feats[ax], fo[live]])
        else:
            keep = feats >= 0
            t_ax, n_ax, f_ax = t_idx[keep], n_idx[keep], feats[keep]
        ok = (f_ax >= 0) & (f_ax < F)
        t_ax, n_ax, f_ax = t_ax[ok], n_ax[ok], f_ax[ok]

        out = {"NUM_NODES": table(np.bincount(f_ax, minlength=F))}
        roots = self.feature[:, 0]
        root_counts = np.bincount(
            roots[(roots >= 0) & (roots < F)], minlength=F).astype(np.float64)
        if (roots == -2).any() and self.obl_features is not None:
            # oblique roots credit every feature they project over, matching
            # the NUM_NODES / min-depth expansion above
            ow = self.obl_weights[roots == -2, 0]
            of = self.obl_features[roots == -2, 0]
            fr = of[ow != 0.0]
            root_counts += np.bincount(fr[(fr >= 0) & (fr < F)], minlength=F)
        out["NUM_AS_ROOT"] = table(root_counts)
        sg = self.split_gain
        if sg is not None and len(f_ax) and sg[t_ax, n_ax].any():
            out["SUM_SCORE"] = table(np.bincount(
                f_ax, weights=np.maximum(sg[t_ax, n_ax], 0.0), minlength=F))
        if F:
            # min depth of each feature per tree; absent -> the tree's depth
            T = self.n_trees
            tree_depth = np.maximum(depth.max(axis=1), 0).astype(np.float64)
            min_depth = np.tile(tree_depth[:, None], (1, F))
            np.minimum.at(min_depth, (t_ax, f_ax),
                          depth[t_ax, n_ax].astype(np.float64))
            out["INV_MEAN_MIN_DEPTH"] = table(
                1.0 / (1.0 + min_depth.mean(axis=0))) if T else table(
                np.ones(F))
        return out


def node_depths(forest: Forest) -> np.ndarray:
    """Per-node depth, (T, M) int32, -1 for unreachable slots: one
    level-order frontier propagation — O(depth) vectorized passes — shared
    by every structural accumulator (tree_depths, _reachable, the §8
    importances). First visit wins, and already-visited children are
    dropped from the frontier, so a corrupt SoA with a child back-edge
    (only py_tree validates DAGs) terminates instead of looping."""
    T, M = forest.feature.shape
    depth = np.full((T, M), -1, np.int32)
    if T == 0:
        return depth
    depth[:, 0] = 0
    cur_t = np.arange(T, dtype=np.int64)
    cur_n = np.zeros(T, np.int64)
    level = 0
    while cur_t.size:
        lc = forest.left_child[cur_t, cur_n]
        m = (lc >= 0) & (lc + 1 < M)
        if not m.any():
            break
        level += 1
        ct, cl = cur_t[m], lc[m]
        fresh = (depth[ct, cl] < 0) & (depth[ct, cl + 1] < 0)
        ct, cl = ct[fresh], cl[fresh]
        if not ct.size:
            break
        depth[ct, cl] = level
        depth[ct, cl + 1] = level
        cur_t = np.concatenate([ct, ct])
        cur_n = np.concatenate([cl, cl + 1])
    return depth


def _reachable(forest: Forest) -> np.ndarray:
    return node_depths(forest) >= 0


def empty_forest(n_trees: int, max_nodes: int, out_dim: int, *,
                 oblique_dims: int = 0, feature_names: list[str] | None = None) -> Forest:
    T, M = n_trees, max_nodes
    return Forest(
        feature=np.full((T, M), -1, np.int32),
        threshold=np.zeros((T, M), np.float32),
        split_bin=np.zeros((T, M), np.uint16),
        cat_mask=np.zeros((T, M, MASK_WORDS), np.uint32),
        left_child=np.full((T, M), -1, np.int32),
        leaf_value=np.zeros((T, M, out_dim), np.float32),
        n_nodes=np.ones(T, np.int32),
        depth=0,
        obl_weights=np.zeros((T, M, oblique_dims), np.float32) if oblique_dims else None,
        obl_features=np.zeros((T, M, oblique_dims), np.int32) if oblique_dims else None,
        split_gain=np.zeros((T, M), np.float32),
        out_dim=out_dim,
        tree_class=np.zeros(T, np.int32),
        init_pred=np.zeros(out_dim, np.float32),
        feature_names=list(feature_names or []),
    )


# =====================================================================
# Reference engines (numpy). See repro/core/engines.py for selection and
# repro/kernels/forest_infer for the Pallas VMEM engine.
# =====================================================================

def eval_node_conditions(forest: Forest, X: np.ndarray, t: np.ndarray,
                         node: np.ndarray) -> np.ndarray:
    """Branch decision (True = right) for (example, tree) pairs.

    X: (N, 1, F) float32 (categorical features hold integer codes);
    t, node: (N, T) int arrays.
    """
    f = forest.feature[t, node]                       # (N, T)
    is_leaf = f == -1
    is_obl = f == -2
    f_safe = np.maximum(f, 0)
    x = np.take_along_axis(X, f_safe[..., None], axis=-1)[..., 0]  # (N, T)
    go = x >= forest.threshold[t, node]
    # categorical: bit test on the node's category mask
    cat = forest.cat_mask[t, node]                    # (N, T, MASK_WORDS)
    # numpy float->int semantics ARE the documented garbage domain (§10.2):
    # NaN/±inf/|x|>=2^63 cast to INT64_MIN, then clip to code 0
    with np.errstate(invalid="ignore"):
        code = np.clip(x.astype(np.int64), 0, MASK_WORDS * 32 - 1)
    word = np.take_along_axis(cat, (code // 32)[..., None], axis=-1)[..., 0]
    bit = (word >> (code % 32).astype(np.uint32)) & 1
    go = np.where(cat.any(axis=-1), bit.astype(bool), go)
    if forest.obl_weights is not None and forest.obl_weights.shape[-1]:
        w = forest.obl_weights[t, node]               # (N, T, P)
        fo = forest.obl_features[t, node]             # (N, T, P)
        xs = np.take_along_axis(np.broadcast_to(X, fo.shape[:2] + X.shape[-1:]),
                                fo, axis=-1)
        proj = (w * xs).sum(-1)
        go = np.where(is_obl, proj >= forest.threshold[t, node], go)
    return np.where(is_leaf, False, go)


def predict_raw(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Vectorized lockstep traversal. X: (N, F) float32. -> (N, T) leaf scalar
    (out_dim=1) or (N, T, out_dim)."""
    N = X.shape[0]
    T = forest.n_trees
    t = np.arange(T)[None, :].repeat(N, 0)        # (N, T)
    node = np.zeros((N, T), np.int64)
    Xe = X[:, None, :]                             # (N, 1, F) broadcast over trees
    for _ in range(max(1, forest.depth)):
        go = eval_node_conditions(forest, Xe, t, node)
        child = forest.left_child[t, node]
        nxt = child + go
        node = np.where(child >= 0, nxt, node)
    out = forest.leaf_value[t, node]               # (N, T, out_dim)
    return out


def predict_naive(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Algorithm 1 of the paper: per-example while-loop. The readable oracle."""
    N = X.shape[0]
    out = np.zeros((N, forest.n_trees, forest.leaf_value.shape[-1]), np.float32)
    for n in range(N):
        for t in range(forest.n_trees):
            node = 0
            while forest.left_child[t, node] >= 0:
                f = forest.feature[t, node]
                if f == -2:
                    proj = float(np.dot(forest.obl_weights[t, node],
                                        X[n, forest.obl_features[t, node]]))
                    go = proj >= forest.threshold[t, node]
                elif forest.cat_mask[t, node].any():
                    # same float->int semantics as the vectorized engines
                    # (PR 7 divergence: python int() overflowed to 255 /
                    # raised on NaN where numpy casts to INT64_MIN -> 0)
                    with np.errstate(invalid="ignore"):
                        code = int(np.clip(
                            np.float32(X[n, f]).astype(np.int64),
                            0, MASK_WORDS * 32 - 1))
                    go = bool((forest.cat_mask[t, node, code // 32] >> (code % 32)) & 1)
                else:
                    go = X[n, f] >= forest.threshold[t, node]
                node = forest.left_child[t, node] + int(go)
            out[n, t] = forest.leaf_value[t, node]
    return out


def compile_predict_raw(forest: Forest):
    """One-time specialization of ``predict_raw`` for serving (DESIGN.md §5.1).

    Compared to the generic lockstep traversal, compilation:
      * flattens the (T, M) node tables once, TRIMMED to the forest's live
        node capacity (``n_nodes.max()``, like ``pack_by_depth``) — on
        growers that allocate generous capacity the tables shrink by ~an
        order of magnitude, so the per-round random gathers stay in cache
        instead of striding a mostly-padding working set;
      * clamps leaf/feature indices at compile time and reuses ``np.take``
        scratch buffers across rounds, so every round is gathers + compares
        with no per-round index fixup or allocator churn;
      * gathers only the addressed 32-bit mask word per categorical test
        (the generic path materializes the full (N, T, MASK_WORDS) block);
      * drops condition kinds the forest does not use — a pure-numerical
        forest pays nothing for the categorical path (lossy-compilation
        specialization, §3.7).

    Oblique forests fall back to the generic traversal (still a valid
    compiled predictor; the specialization simply does not apply).
    Returns ``run(X: (N, F) float32) -> (N, T, out_dim) float32``.
    """
    if forest.has_oblique():
        return lambda X: predict_raw(forest, X)
    T = forest.n_trees
    if T == 0:
        O0 = forest.leaf_value.shape[-1]
        return lambda X: np.zeros((X.shape[0], 0, O0), np.float32)
    M = max(1, int(forest.n_nodes.max()))      # live-capacity trim
    depth = max(1, forest.depth)
    O = forest.leaf_value.shape[-1]
    has_cat = bool(forest.cat_mask.any())
    # tree-blocked tables (the §5.2 tiling insight restated for the host):
    # each block's node tables must stay cache-resident through all `depth`
    # gather rounds, so blocks are sized to ~a few hundred KB of tables
    TB = int(np.clip(16384 // M, 1, T)) if M else T
    blocks = []
    for b0 in range(0, T, TB):
        k = min(TB, T - b0)
        sl = slice(b0, b0 + k)
        # trailing leaf dim can differ from out_dim (GBT multiclass stores
        # scalar leaves + a tree->class map)
        blk = {
            "k": k,
            "feat": np.ascontiguousarray(
                np.maximum(forest.feature[sl, :M], 0).astype(np.intp).ravel()),
            "thr": np.ascontiguousarray(forest.threshold[sl, :M].ravel()),
            "lc": np.ascontiguousarray(
                forest.left_child[sl, :M].astype(np.intp).ravel()),
            "leaf": np.ascontiguousarray(
                forest.leaf_value[sl, :M].reshape(k * M, O)),
            "off": (np.arange(k, dtype=np.intp) * M)[None, :],
        }
        if has_cat:
            blk["iscat"] = forest.cat_mask[sl, :M].any(-1).ravel()
            blk["catw"] = np.ascontiguousarray(forest.cat_mask[sl, :M].ravel())
        blocks.append(blk)

    def run(X: np.ndarray) -> np.ndarray:
        N = X.shape[0]
        Xf = np.ascontiguousarray(X, np.float32).ravel()
        row_base = (np.arange(N, dtype=np.intp) * X.shape[1])[:, None]
        out = np.empty((N, T, O), np.float32)
        c0 = 0
        for blk in blocks:
            k, off = blk["k"], blk["off"]
            node = np.zeros((N, k), np.intp)
            idx = np.empty((N, k), np.intp)
            gat = np.empty((N, k), np.intp)   # shared int gather scratch
            x = np.empty((N, k), np.float32)
            for _ in range(depth):
                np.add(node, off, out=idx)                     # (N, k) flat
                blk["feat"].take(idx, out=gat)
                np.add(gat, row_base, out=gat)
                Xf.take(gat, out=x)
                go = x >= blk["thr"].take(idx)
                if has_cat:
                    # NaN/inf cast to INT64_MIN (a numpy warning, not an
                    # error) and clip to code 0 — the documented hostile-
                    # input behavior the XLA engines replicate (§10.2)
                    with np.errstate(invalid="ignore"):
                        code = np.clip(x.astype(np.intp), 0,
                                       MASK_WORDS * 32 - 1)
                    word = blk["catw"].take(idx * MASK_WORDS + (code >> 5))
                    bit = (word >> (code & 31).astype(np.uint32)) & 1
                    go = np.where(blk["iscat"].take(idx),
                                  bit.astype(bool), go)
                blk["lc"].take(idx, out=gat)
                node = np.where(gat >= 0, gat + go, node)
            out[:, c0:c0 + k] = blk["leaf"][node + off]
            c0 += k
        return out                                             # (N, T, O)

    return run


# --------------------------------------- depth-bucketed CPU layout (§10)
#
# The compiled numpy traversal (§5.1) and the depth-packed pallas layout
# (§5.3) both pay the forest-wide max depth in lockstep gather rounds. The
# bucketed layout groups trees into a handful of depth-homogeneous BUCKETS so
# each bucket runs exactly its own depth of rounds (early exit for shallow
# trees), and each bucket independently chooses its scoring strategy:
#
#   * "scan"      — flat-table lockstep traversal with sentinel leaves
#                   (leaves self-loop via a zero-valued sentinel feature
#                   column, so the inner round is gather+compare+advance with
#                   no leaf masking at all);
#   * "leaf_path" — root-to-leaf paths enumerated as a signed predicate
#                   matrix plus leaf-value table: every internal condition is
#                   evaluated in ONE vectorized pass and a batched matmul
#                   counts per-path predicate hits — no traversal loop
#                   (the SIMD decision-tree transform, arXiv:2205.07307).
#
# The tables here are pure numpy; repro/kernels/forest_infer/bucketed.py
# compiles them into a single jit'd dispatch. See DESIGN.md §10.

LEAF_PATH_BUDGET = 1 << 14   # max internal x leaf predicate entries per tree


@dataclass
class TreeBucket:
    """One depth-homogeneous group of trees plus its scoring tables."""
    trees: np.ndarray        # original tree indices in this bucket
    depth: int               # max actual depth within the bucket
    strategy: str            # "scan" | "leaf_path"
    tables: dict             # strategy-specific numpy tables


@dataclass
class BucketedForest:
    """Depth-bucketed CPU layout (DESIGN.md §10.1)."""
    buckets: list
    inv_order: np.ndarray    # original tree t lives at packed slot inv_order[t]
    n_trees: int
    out_dim: int             # trailing leaf dim


def plan_depth_buckets(depths: np.ndarray, *, max_buckets: int = 4,
                       min_trees: int = 8) -> list[np.ndarray]:
    """Group trees into <= ``max_buckets`` depth-homogeneous buckets.

    Trees are sorted by actual depth; runs of equal depth seed the buckets,
    then adjacent buckets merge greedily by least extra traversal cost
    (trees in the shallower bucket x the depth gap) until the bucket count
    and the ``min_trees`` floor (tiny buckets are pure dispatch overhead)
    are both satisfied. Deterministic, so engine selection is testable."""
    T = len(depths)
    if T == 0:
        return []
    order = np.argsort(depths, kind="stable")
    sd = np.asarray(depths)[order]
    bounds = [0] + [i for i in range(1, T) if sd[i] != sd[i - 1]] + [T]
    buckets = [[bounds[i], bounds[i + 1]] for i in range(len(bounds) - 1)]

    def merge_cost(i: int) -> int:
        a, b = buckets[i], buckets[i + 1]
        return int((sd[b[1] - 1] - sd[a[0]:a[1]]).sum())

    while len(buckets) > 1:
        small = any(e - s < min_trees for s, e in buckets)
        if len(buckets) <= max_buckets and not small:
            break
        i = int(np.argmin([merge_cost(j) for j in range(len(buckets) - 1)]))
        buckets[i] = [buckets[i][0], buckets[i + 1][1]]
        del buckets[i + 1]
    return [order[s:e] for s, e in buckets]


def leaf_path_sizes(forest: Forest) -> tuple[int, int]:
    """(max internal nodes, max leaves) over trees — the predicate-matrix
    footprint that gates leaf_path availability (engines.py)."""
    if forest.n_trees == 0:
        return 0, 1
    reach = _reachable(forest)
    internal = reach & (forest.left_child >= 0)
    leaves = reach & (forest.left_child < 0)
    return int(internal.sum(1).max()), max(1, int(leaves.sum(1).max()))


def select_block_strategy(depth: int, n_internal: int, n_leaves: int, *,
                          matmul_cheap: bool = False,
                          leaf_path_budget: int = LEAF_PATH_BUDGET) -> str:
    """Pick the scoring strategy for one bucket.

    Measured on CPU XLA (DESIGN.md §10.3), the scan's ``depth`` fused gather
    rounds beat the predicate matmul at EVERY depth — including boosted
    stumps — because the matmul evaluates all ``n_internal`` conditions per
    tree where the scan evaluates ``depth``, and the MAC itself is not free
    on the VPU. leaf_path is therefore chosen only where the MAC is ~free
    (``matmul_cheap``: an MXU-class backend) and the predicate matrix stays
    small enough to live in fast memory."""
    if matmul_cheap and depth <= 6 and n_internal * n_leaves <= leaf_path_budget:
        return "leaf_path"
    return "scan"


def _flatten_scan_bucket(forest: Forest, sub: np.ndarray) -> dict:
    """Flat global-id tables for the scan strategy. Leaves become sentinel
    nodes: feature -1 (rewritten at compile time to a zero-valued sentinel
    column appended to X), threshold +inf, child = the node's own flat id —
    so a finished (example, tree) lane keeps gathering `0 >= inf -> stay`
    with no leaf mask or conditional select in the round."""
    k = len(sub)
    M = max(1, int(forest.n_nodes[sub].max()))
    O = forest.leaf_value.shape[-1]
    feat = forest.feature[sub][:, :M].astype(np.int32)
    thr = forest.threshold[sub][:, :M].astype(np.float32)
    lc = forest.left_child[sub][:, :M].astype(np.int32)
    cat = forest.cat_mask[sub][:, :M]
    node_ids = np.broadcast_to(np.arange(M, dtype=np.int32)[None, :], (k, M))
    off = (np.arange(k, dtype=np.int32) * M)[:, None]
    is_leaf = lc < 0
    iscat = cat.any(-1) & ~is_leaf   # a stale mask on a leaf slot must not
    #                                  override the sentinel 0 >= inf self-loop
    return {
        "feature": np.where(is_leaf, np.int32(-1), feat).ravel(),
        "threshold": np.where(is_leaf, np.float32(np.inf), thr).ravel(),
        "child": (np.where(is_leaf, node_ids, lc) + off).ravel(),
        "leaf_value": np.ascontiguousarray(
            forest.leaf_value[sub][:, :M]).reshape(k * M, O),
        "root": np.ascontiguousarray(off[:, 0]),
        "is_cat": iscat.ravel(),
        "cat_words": np.ascontiguousarray(cat).reshape(k * M, MASK_WORDS),
        "has_cat": bool(iscat.any()),
    }


def enumerate_leaf_paths(forest: Forest, sub: np.ndarray) -> dict:
    """Root-to-leaf paths of every tree in ``sub`` as predicate tables.

    Per tree: internal-node conditions (feature/threshold/category mask,
    padded to the bucket-wide ``I`` with never-true sentinels) and a signed
    path matrix ``P`` (I, L): +1 where leaf l's path turns RIGHT at internal
    node i, -1 where it turns LEFT, 0 off-path. With C the 0/1 condition
    vector, ``C @ P + base`` counts correct decisions along each path
    (``base[l]`` = number of left turns); exactly the true leaf reaches its
    ``path_len``, so argmax(hits - path_len) selects it — all sums are small
    integers in float32, hence exact, hence bit-identical to traversal."""
    k = len(sub)
    O = forest.leaf_value.shape[-1]
    per = []
    for t in sub:
        lc = forest.left_child[t]
        internal: list[int] = []
        leaves: list[tuple[int, list]] = []
        stack: list[tuple[int, list]] = [(0, [])]
        while stack:
            node, path = stack.pop()
            if lc[node] < 0:
                leaves.append((node, path))
            else:
                li = len(internal)
                internal.append(node)
                stack.append((lc[node] + 1, path + [(li, 1)]))
                stack.append((lc[node], path + [(li, 0)]))
        per.append((internal, leaves))
    I = max(1, max(len(p[0]) for p in per))
    L = max(1, max(len(p[1]) for p in per))
    feat = np.zeros((k, I), np.int32)
    thr = np.full((k, I), np.inf, np.float32)
    iscat = np.zeros((k, I), bool)
    catw = np.zeros((k, I, MASK_WORDS), np.uint32)
    P = np.zeros((k, I, L), np.float32)
    base = np.zeros((k, L), np.float32)
    plen = np.full((k, L), np.float32(2 ** 20), np.float32)  # pads never match
    leafv = np.zeros((k, L, O), np.float32)
    for j, (t, (internal, leaves)) in enumerate(zip(sub, per)):
        for li, node in enumerate(internal):
            feat[j, li] = forest.feature[t, node]
            thr[j, li] = forest.threshold[t, node]
            cm = forest.cat_mask[t, node]
            if cm.any():
                iscat[j, li] = True
                catw[j, li] = cm
        for l, (node, path) in enumerate(leaves):
            plen[j, l] = len(path)
            leafv[j, l] = forest.leaf_value[t, node]
            for li, go in path:
                P[j, li, l] = 1.0 if go else -1.0
                if not go:
                    base[j, l] += 1.0
    return {"feature": feat, "threshold": thr, "is_cat": iscat,
            "cat_words": catw, "paths": P, "base": base, "path_len": plen,
            "leaf_value": leafv, "has_cat": bool(iscat.any()),
            "n_internal": I, "n_leaves": L}


def pack_depth_buckets(forest: Forest, *, strategy: str | None = None,
                       max_buckets: int = 4, min_trees: int = 8,
                       matmul_cheap: bool = False) -> BucketedForest:
    """Pack a Forest into the depth-bucketed CPU layout (DESIGN.md §10.1).

    ``strategy`` forces "scan" or "leaf_path" for every bucket; None lets
    ``select_block_strategy`` choose per bucket. Oblique forests are not
    supported (the engine layer gates them — lossy compilation, §3.7)."""
    if forest.has_oblique():
        raise ValueError("bucketed packing does not support oblique forests")
    T = forest.n_trees
    O = forest.leaf_value.shape[-1]
    depths = tree_depths(forest)
    subs = plan_depth_buckets(depths, max_buckets=max_buckets,
                              min_trees=min_trees)
    buckets = []
    for sub in subs:
        d = int(depths[sub].max())
        if strategy is not None:
            strat = strategy
        else:
            strat = select_block_strategy(
                d, *_bucket_path_sizes(forest, sub), matmul_cheap=matmul_cheap)
        if strat == "leaf_path":
            tables = enumerate_leaf_paths(forest, sub)
        else:
            strat = "scan"
            tables = _flatten_scan_bucket(forest, sub)
        buckets.append(TreeBucket(trees=sub, depth=max(1, d), strategy=strat,
                                  tables=tables))
    order = (np.concatenate([b.trees for b in buckets])
             if buckets else np.zeros(0, np.int64))
    inv_order = np.empty(T, np.int64)
    inv_order[order] = np.arange(T)
    return BucketedForest(buckets=buckets, inv_order=inv_order, n_trees=T,
                          out_dim=O)


def _bucket_path_sizes(forest: Forest, sub: np.ndarray) -> tuple[int, int]:
    reach = _reachable(forest)[sub]
    internal = reach & (forest.left_child[sub] >= 0)
    leaves = reach & (forest.left_child[sub] < 0)
    return int(internal.sum(1).max()), max(1, int(leaves.sum(1).max()))


# ------------------------------------------------- depth-packed layout (§5.3)

def tree_depths(forest: Forest) -> np.ndarray:
    """Per-tree depth, (T,) int32: the deepest reachable level of each tree
    (one ``node_depths`` level-order pass — O(depth) vectorized passes over
    O(total nodes) work, flat host time even for the arbitrarily-large
    forests the tiled kernel accepts)."""
    if forest.n_trees == 0:
        return np.zeros(0, np.int32)
    return np.maximum(node_depths(forest).max(axis=1), 0).astype(np.int32)


@dataclass
class PackedForest:
    """Depth-packed SoA (DESIGN.md §5.3): trees sorted by depth, grouped into
    ``n_blocks`` blocks of ``trees_per_block``, node capacity trimmed to the
    forest's live node count (padded to ``node_tile``). ``block_depth`` lets
    the tree-tiled kernel (§5.2) bound its traversal loop per block, and
    ``inv_order`` restores the original tree order after the kernel."""
    feature: np.ndarray      # (B, TB, M) int32
    threshold: np.ndarray    # (B, TB, M) float32
    cat_mask: np.ndarray     # (B, TB, M, MASK_WORDS) uint32
    left_child: np.ndarray   # (B, TB, M) int32
    leaf_value: np.ndarray   # (B, TB, M, out_dim) float32
    block_depth: np.ndarray  # (B, 1) int32: max tree depth within the block
    inv_order: np.ndarray    # (T,) int32: original tree t lives at packed
                             # slot inv_order[t] (flat over (B, TB))
    n_trees: int             # original T (packed slots beyond are padding)
    out_dim: int             # trailing leaf dim (1 for GBT multiclass)

    @property
    def n_blocks(self) -> int:
        return self.feature.shape[0]

    @property
    def trees_per_block(self) -> int:
        return self.feature.shape[1]

    @property
    def max_nodes(self) -> int:
        return self.feature.shape[2]


def pack_by_depth(forest: Forest, *, trees_per_block: int | None = None,
                  node_tile: int = 128,
                  vmem_budget_bytes: int = 4 * 1024 * 1024) -> PackedForest:
    """Pack a Forest for the tree-tiled kernel (DESIGN.md §5.2–§5.3).

    Trees are sorted by depth so each block is depth-homogeneous; the kernel
    runs ``block_depth[b]`` traversal rounds instead of the global max.
    ``trees_per_block`` defaults to as many trees as fit the per-step VMEM
    budget given the trimmed node capacity — large-node forests degrade to
    one tree per block rather than refusing to compile (this is what removes
    the old 4096-node ceiling)."""
    T = forest.n_trees
    O = forest.leaf_value.shape[-1]
    depths = tree_depths(forest)
    # trim capacity to live nodes, pad to the kernel's node tile
    live = int(forest.n_nodes.max()) if T else 1
    M = max(node_tile, -(-live // node_tile) * node_tile)
    # feat/thr/lc f32 + cat mask as TWO f32 half-word arrays in-kernel + leaf
    bytes_per_tree = M * (4 * 3 + 2 * 4 * MASK_WORDS + 4 * O)
    if trees_per_block is None:
        trees_per_block = int(max(1, min(8, vmem_budget_bytes // max(1, bytes_per_tree))))
    TB = min(trees_per_block, max(1, T))
    order = np.argsort(depths, kind="stable").astype(np.int32)  # slot -> tree
    B = -(-max(1, T) // TB)
    S = B * TB

    def take(a, fill=0):
        # (T, M_old, ...) -> (B, TB, M, ...) in sorted order, padded trees
        out_shape = (S, M) + a.shape[2:]
        out = np.full(out_shape, fill, a.dtype)
        if T:
            m = min(M, a.shape[1])
            out[:T, :m] = a[order][:, :m]
        return out.reshape((B, TB) + out_shape[1:])

    feature = take(forest.feature, -1)
    left_child = take(forest.left_child, -1)
    threshold = take(forest.threshold)
    cat_mask = take(forest.cat_mask)
    leaf_value = take(forest.leaf_value)
    block_depth = np.zeros((B, 1), np.int32)
    if T:
        sorted_d = np.zeros(S, np.int32)
        sorted_d[:T] = depths[order]
        block_depth[:, 0] = np.maximum(
            sorted_d.reshape(B, TB).max(axis=1), 1)
    inv_order = np.empty(T, np.int32)
    inv_order[order] = np.arange(T, dtype=np.int32)
    return PackedForest(feature=feature, threshold=threshold, cat_mask=cat_mask,
                        left_child=left_child, leaf_value=leaf_value,
                        block_depth=block_depth, inv_order=inv_order,
                        n_trees=T, out_dim=O)


# ------------------------------------------------------------ aggregation

def aggregate_gbt(per_tree: np.ndarray, forest: Forest) -> np.ndarray:
    """Sum tree outputs into (N, out_dim) logits/score, adding init_pred."""
    N, T = per_tree.shape[:2]
    out = np.tile(forest.init_pred[None, :], (N, 1)).astype(np.float32)
    if forest.out_dim == 1 or forest.tree_class is None:
        out += per_tree.sum(axis=1)[:, : forest.out_dim]
    else:
        for c in range(forest.out_dim):
            sel = forest.tree_class == c
            out[:, c] += per_tree[:, sel, 0].sum(axis=1)
    return out


def aggregate_rf(per_tree: np.ndarray, winner_take_all: bool) -> np.ndarray:
    """per_tree: (N, T, C) leaf distributions -> (N, C) probabilities."""
    if winner_take_all and per_tree.shape[-1] > 1:
        votes = per_tree.argmax(-1)                     # (N, T)
        C = per_tree.shape[-1]
        out = np.zeros((per_tree.shape[0], C), np.float32)
        for c in range(C):
            out[:, c] = (votes == c).mean(axis=1)
        return out
    return per_tree.mean(axis=1)
