"""Distributed decision-forest training (paper §3.9; Guillame-Bert & Teytaud
2018) mapped onto SPMD collectives (DESIGN.md §2.3).

The 2-D training grid composes both of the paper's distributions:
  * example-parallel over the 'data' mesh axis — histograms are psum'ed;
    traffic per level = histogram size, INDEPENDENT of the number of examples
    (the key scaling property of the 2018 paper);
  * feature-parallel over the 'model' mesh axis — each shard owns a slice of
    feature columns, exchanges only (gain, feature, bin) candidates
    (all_gather of 3 scalars per node) and the winning example partition as a
    BIT-PACKED uint32 bitmap (32x less traffic than a float mask — the
    delta-bit-encoding insight of §3.9 restated).

Trees grown here use a fixed-depth COMPLETE layout in level order (node n ->
children 2n+1/2n+2), fully jittable: nodes without a valid split emit a
degenerate all-left split with zero gain. The host converts to the pointer
SoA ``Forest`` for serving. Numerical (binned uint8) features only — the
categorical path stays on the host learner (documented scope split).

A third backend — the paper's single-process SIMULATION backend for
development/debugging/fault-injection — lives in ``SimulatedCluster``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.obs import build_training_logs, trace, validate_training_logs
from repro.core.tree import Forest, empty_forest


# =====================================================================
# jnp gh-gain machinery (device-side mirror of splitters.best_splits)
# =====================================================================

def _gh_score(g, h, l2):
    return 0.5 * jnp.square(g) / (h + l2 + 1e-12)


def split_gain_tensor(hist: jax.Array, min_examples: int, l2: float):
    """hist: (nodes, F, B, 3) [g, h, n] -> full gain tensor (nodes, F, B-1),
    invalid splits = -inf. Per-feature columns are independent, so a
    feature's gain values do not depend on which other features share the
    histogram batch (the property the fault-recovery merge relies on)."""
    parent = hist.sum(2)                              # (nodes, F, 3)
    ps = _gh_score(parent[..., 0], parent[..., 1], l2)
    cum = jnp.cumsum(hist, axis=2)[:, :, :-1]         # (nodes, F, B-1, 3)
    right = parent[:, :, None] - cum
    gain = (_gh_score(cum[..., 0], cum[..., 1], l2)
            + _gh_score(right[..., 0], right[..., 1], l2) - ps[..., None])
    ok = (cum[..., 2] >= min_examples) & (right[..., 2] >= min_examples)
    return jnp.where(ok, gain, -jnp.inf)


def best_split_gh(hist: jax.Array, min_examples: int, l2: float):
    """hist: (nodes, F, B, 3) [g, h, n] -> (gain, feat, bin) per node (local
    feature indices; bin = first right bin)."""
    gain = split_gain_tensor(hist, min_examples, l2)
    flat = gain.reshape(gain.shape[0], -1)            # (nodes, F*(B-1))
    idx = jnp.argmax(flat, axis=1)
    best = jnp.take_along_axis(flat, idx[:, None], 1)[:, 0]
    feat = idx // (hist.shape[2] - 1)
    bin_ = idx % (hist.shape[2] - 1) + 1
    return best, feat.astype(jnp.int32), bin_.astype(jnp.int32)


def _pack_bits(bits: jax.Array) -> jax.Array:
    """(N,) {0,1} int32 -> (N/32,) uint32 (N must be a multiple of 32)."""
    b = bits.reshape(-1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (b << shifts[None, :]).sum(1, dtype=jnp.uint32)


def _unpack_bits(words: jax.Array) -> jax.Array:
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return ((words[:, None] >> shifts[None, :]) & 1).astype(jnp.int32).reshape(-1)


# =====================================================================
# shard_map level step
# =====================================================================

@dataclass(frozen=True)
class DistGBTConfig:
    max_depth: int = 5
    n_bins: int = 64
    min_examples: int = 2
    l2: float = 0.0
    shrinkage: float = 0.1
    num_trees: int = 20
    data_axis: str = "data"
    model_axis: str = "model"
    hist_impl: str = "ref"   # ref | pallas (kernels/histogram)


def make_level_step(mesh: Mesh, cfg: DistGBTConfig, n_nodes: int, F_local: int):
    """Returns jitted fn(codes_l, stats_l, node_of_l) ->
    (feat_global, bin, gain, go_bits_l, hist) executing one tree level on the
    2-D grid. All inputs/outputs are per-shard (shard_map)."""
    from repro.kernels.histogram.ops import histogram

    da, ma = cfg.data_axis, cfg.model_axis

    def level(codes, stats, node_of):
        # codes: (N_l, F_l) uint8; stats: (N_l, 3); node_of: (N_l,)
        hist = histogram(codes, stats, node_of, n_nodes, cfg.n_bins,
                         impl=cfg.hist_impl)
        hist = jax.lax.psum(hist, da)                 # example-parallel reduce
        gain, feat_l, bin_ = best_split_gh(hist, cfg.min_examples, cfg.l2)
        # feature-parallel candidate exchange: 3 scalars per node per shard
        gains = jax.lax.all_gather(gain, ma)          # (W, nodes)
        feats = jax.lax.all_gather(feat_l, ma)
        bins = jax.lax.all_gather(bin_, ma)
        winner = jnp.argmax(jnp.where(jnp.isfinite(gains), gains, -jnp.inf), 0)
        nid = jnp.arange(n_nodes)
        w_gain = gains[winner, nid]
        w_feat_local = feats[winner, nid]
        w_bin = bins[winner, nid]
        me = jax.lax.axis_index(ma)
        owner_feat = jnp.where(winner == me, w_feat_local, 0)
        valid = jnp.isfinite(w_gain)
        # owner computes the partition for ITS example rows; psum over the
        # model axis broadcasts it (others contribute zeros); bit-packed.
        my_codes = jnp.take_along_axis(
            codes, owner_feat[node_of.clip(0)][:, None], axis=1)[:, 0]
        thr = w_bin[node_of.clip(0)]
        go = ((winner[node_of.clip(0)] == me)
              & (my_codes >= thr.astype(codes.dtype))
              & (node_of >= 0)).astype(jnp.int32)
        packed = _pack_bits(go)
        packed = jax.lax.psum(packed, ma)
        go_all = _unpack_bits(packed)
        w_feat_global = w_feat_local + winner * F_local
        return (w_feat_global, w_bin, jnp.where(valid, w_gain, -jnp.inf),
                go_all, hist)

    specs_in = (P(cfg.data_axis, cfg.model_axis), P(cfg.data_axis, None),
                P(cfg.data_axis))
    specs_out = (P(), P(), P(), P(cfg.data_axis), P())
    return jax.jit(shard_map(level, mesh=mesh, in_specs=specs_in,
                             out_specs=specs_out, check_rep=False))


# =====================================================================
# Distributed GBT boosting loop (host-orchestrated, device-stepped)
# =====================================================================

def grow_tree_complete(level_fns, codes_sh, stats_sh, node_of0, cfg: DistGBTConfig):
    """Grow one fixed-depth complete tree. Returns (feat, bin, gain) arrays in
    level order (2^D - 1 internal nodes) + final per-leaf [g, h, n]."""
    D = cfg.max_depth
    feats, bins, gains = [], [], []
    node_of = node_of0
    for d in range(D):
        n_nodes = 2 ** d
        f, b, g, go, hist = level_fns[d](codes_sh, stats_sh, node_of)
        feats.append(np.asarray(f))
        bins.append(np.asarray(b))
        gains.append(np.asarray(g))
        valid = np.isfinite(np.asarray(g))
        go = jnp.where(jnp.asarray(valid)[node_of.clip(0)], go, 0)
        node_of = jnp.where(node_of >= 0, node_of * 2 + go, node_of)
    # final per-leaf [g, h, n]: one more psum'd histogram at leaf granularity.
    # hist is per-model-shard (its own features); summing the BINS of any one
    # feature column yields the per-node stat totals, identical on all shards.
    _, _, _, _, hist = level_fns[D](codes_sh, stats_sh, node_of)
    leaf_stats = np.asarray(hist[:, 0].sum(axis=1))
    return (np.concatenate(feats), np.concatenate(bins), np.concatenate(gains),
            leaf_stats, node_of)


# ---- shared boosting-state helpers (host side, backend-agnostic) ----

def _init_pred(y: np.ndarray, task: str) -> float:
    if task == "binary":
        p0 = np.clip(y.mean(), 1e-6, 1 - 1e-6)
        return float(np.log(p0 / (1 - p0)))
    return float(y.mean())


def _grad_hess(pred: np.ndarray, y: np.ndarray, task: str):
    if task == "binary":
        p = 1 / (1 + np.exp(-pred))
        return p - y, np.maximum(p * (1 - p), 1e-12)
    return pred - y, np.ones(len(y))


def predict_scores_complete(trees: list[dict], init_pred: float, D: int,
                            codes: np.ndarray) -> np.ndarray:
    """Score complete-layout trees (shared by both distributed backends)."""
    s = np.full(codes.shape[0], init_pred, np.float64)
    for tree in trees:
        node = np.zeros(codes.shape[0], np.int64)
        off = 0
        for d in range(D):
            nid = off + node
            f, b = tree["feat"][nid], tree["bin"][nid]
            go = (codes[np.arange(len(codes)), f] >= b) \
                & np.isfinite(tree["gain"][nid])
            node = node * 2 + go
            off += 2 ** d
        s += tree["leaf"][node]
    return s


def complete_trees_to_forest(trees: list[dict], init_pred: float, D: int,
                             feature_names: list[str] | None = None) -> Forest:
    """Convert complete-layout trees to the pointer SoA for the engines."""
    T = len(trees)
    M = 2 ** (D + 1)
    forest = empty_forest(T, M, 1, feature_names=feature_names)
    forest.depth = D
    forest.init_pred = np.array([init_pred], np.float32)
    for t, tree in enumerate(trees):
        # complete level order -> pointer layout (children in pairs).
        # Invalid (degenerate) splits become always-false conditions so
        # inference routes everything left, matching training.
        nxt = 1
        ptr = {0: 0}  # complete-id -> pointer-id
        off = 0
        for d in range(D):
            for i in range(2 ** d):
                cid = off + i
                pid = ptr[cid]
                valid = bool(np.isfinite(tree["gain"][cid]))
                forest.feature[t, pid] = max(int(tree["feat"][cid]), 0)
                if valid:
                    forest.split_bin[t, pid] = tree["bin"][cid]
                    forest.threshold[t, pid] = float(tree["bin"][cid]) - 0.5
                    forest.split_gain[t, pid] = max(
                        float(tree["gain"][cid]), 0.0)
                else:
                    forest.split_bin[t, pid] = 65535
                    forest.threshold[t, pid] = np.float32(3e38)
                forest.left_child[t, pid] = nxt
                left_cid = off + 2 ** d + 2 * i  # = 2^(d+1)-1 + 2i
                ptr[left_cid] = nxt
                ptr[left_cid + 1] = nxt + 1
                nxt += 2
            off += 2 ** d
        for i in range(2 ** D):  # off == 2^D - 1 here
            pid = ptr[off + i]
            forest.left_child[t, pid] = -1
            forest.feature[t, pid] = -1
            forest.leaf_value[t, pid, 0] = tree["leaf"][i]
        forest.n_nodes[t] = nxt
    return forest


class DistributedGBT:
    """Boosted trees on the (data x model) mesh. Binary classification /
    regression on pre-binned numerical features (uint8 codes).

    Fault tolerance rides the DESIGN.md §11 checkpoint layer:
    ``fit(..., checkpoint=CheckpointPolicy(dir))`` writes atomic tree-boundary
    checkpoints and resumes bit-identically — the same serialization path the
    host learners use (the bespoke ``state_dict`` is gone). The stored config
    excludes the mesh shape on purpose: trees are numerically equivalent
    across mesh placements (tested at 1e-4), so a run checkpointed on one
    grid may resume on another.
    """

    def __init__(self, cfg: DistGBTConfig, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.trees: list[dict] = []
        self.training_logs: dict = {}
        self._level_fns: dict[int, list] = {}

    def _fns(self, F_local: int):
        if F_local not in self._level_fns:
            self._level_fns[F_local] = [
                make_level_step(self.mesh, self.cfg, 2 ** d, F_local)
                for d in range(self.cfg.max_depth + 1)]
        return self._level_fns[F_local]

    def _train_config(self, task: str) -> dict:
        import dataclasses as dc
        return {"trainer": "DistributedGBT", "task": task,
                "cfg": dc.asdict(self.cfg)}

    def fit(self, codes: np.ndarray, y: np.ndarray, *, task: str = "binary",
            checkpoint=None):
        cfg = self.cfg
        N, F = codes.shape
        da = self.mesh.shape[cfg.data_axis]
        ma = self.mesh.shape[cfg.model_axis]
        assert N % (da * 32) == 0, f"N={N} must be divisible by 32*data={32 * da}"
        assert F % ma == 0, f"F={F} must divide model axis {ma}"
        F_local = F // ma
        fns = self._fns(F_local)

        sh = NamedSharding(self.mesh, P(cfg.data_axis, cfg.model_axis))
        codes_d = jax.device_put(jnp.asarray(codes), sh)
        pred = np.zeros(N, np.float64)
        self.init_pred = _init_pred(y, task)
        pred[:] = self.init_pred
        self.trees = []

        from repro.core.rf import training_data_fingerprint
        from repro.train.checkpoint import open_session
        sess = open_session(checkpoint, self._train_config(task),
                            training_data_fingerprint(codes, y))
        interrupted = False
        if sess is not None:
            state = sess.resume()
            if state is not None:
                self.trees = list(state["trees"])
                pred = np.copy(state["pred"])
                self.init_pred = float(state["init_pred"])

        import contextlib
        rep = NamedSharding(self.mesh, P(cfg.data_axis))
        with (sess if sess is not None else contextlib.nullcontext()):
            for it in range(len(self.trees), cfg.num_trees):
                g, h = _grad_hess(pred, y, task)
                stats = np.stack([g, h, np.ones(N)], 1).astype(np.float32)
                stats_d = jax.device_put(jnp.asarray(stats),
                                         NamedSharding(self.mesh, P(cfg.data_axis, None)))
                node0 = jax.device_put(jnp.zeros(N, jnp.int32), rep)
                with trace.span("distributed/tree", tree=it):
                    feat, bin_, gain, leaf_stats, node_of = grow_tree_complete(
                        fns, codes_d, stats_d, node0, cfg)
                leaf = -cfg.shrinkage * leaf_stats[:, 0] / (leaf_stats[:, 1]
                                                            + cfg.l2 + 1e-12)
                tree = {"feat": feat, "bin": bin_, "gain": gain,
                        "leaf": leaf.astype(np.float32)}
                self.trees.append(tree)
                # node_of is in leaf-level space [0, 2^D) after D split rounds
                pred += leaf[np.asarray(node_of)]
                if sess is not None:
                    done = len(self.trees) == cfg.num_trees
                    if not done and sess.should_stop():
                        interrupted = True
                    sess.save(len(self.trees),
                              {"kind": "dist_gbt", "trees": list(self.trees),
                               "pred": np.copy(pred),
                               "init_pred": self.init_pred},
                              done=done, force=done or interrupted)
                    if interrupted:
                        break
        self.training_logs = build_training_logs(
            learner="distributed_gbt", num_trees=len(self.trees),
            resilience=sess.events if sess is not None else None,
            interrupted=interrupted)
        return self

    def predict_scores(self, codes: np.ndarray) -> np.ndarray:
        return predict_scores_complete(self.trees, self.init_pred,
                                       self.cfg.max_depth, codes)

    def to_forest(self, feature_names: list[str] | None = None) -> Forest:
        return complete_trees_to_forest(self.trees, self.init_pred,
                                        self.cfg.max_depth, feature_names)


# =====================================================================
# Simulation backend (paper §3.9's third implementation) + fault tolerance
# =====================================================================

@dataclass(frozen=True)
class WorkerFaultPlan:
    """A deterministic worker-death schedule for the simulation backend,
    mirroring ``serving/faults.py``: explicit ``(tree, level, worker)``
    triples for targeted tier-1 scenarios plus a seeded per-(tree, level,
    worker) Bernoulli ``death_rate`` for soak runs. Pure counter-hash — no
    wall-clock — so every fault run is exactly reproducible.
    """
    seed: int = 0
    deaths: tuple = ()           # ((tree, level, worker), ...)
    death_rate: float = 0.0

    def deaths_at(self, tree: int, level: int,
                  worker_ids: list[int]) -> list[int]:
        out = [w for (t, l, w) in self.deaths
               if t == tree and l == level and w in worker_ids]
        if self.death_rate > 0.0:
            for w in worker_ids:
                if w in out:
                    continue
                u = np.random.default_rng(
                    (self.seed & 0xFFFFFFFF, 7919, tree, level, w)).random()
                if u < self.death_rate:
                    out.append(w)
        return sorted(out)


class SimulatedWorker:
    """A training worker owning a set of feature columns."""

    def __init__(self, wid: int, codes: np.ndarray, feature_ids: list[int]):
        self.wid = wid
        self.feature_ids = list(feature_ids)
        self.codes = codes  # full matrix; worker only READS its columns
        self.alive = True

    def local_best(self, stats, node_of, n_nodes, cfg) -> list[tuple]:
        from repro.core.splitters import build_histogram
        if not self.feature_ids:
            return [(-np.inf, -1, 0)] * n_nodes
        # scan features in GLOBAL-id order so the within-worker tie-break
        # (first max = smallest feature id, then smallest bin) is a property
        # of the features themselves, not of the assignment order — after a
        # death reassigns features, the surviving workers still propose the
        # exact same candidates (fault runs stay bit-identical to clean)
        fids = sorted(self.feature_ids)
        sub = self.codes[:, fids]
        hist = build_histogram(sub, stats, node_of, n_nodes, cfg.n_bins)
        gain = np.asarray(split_gain_tensor(jnp.asarray(hist),
                                            cfg.min_examples, cfg.l2))
        B1 = gain.shape[2]
        flat = gain.reshape(n_nodes, -1)
        idx = flat.argmax(1)
        return [(float(flat[i, idx[i]]), fids[int(idx[i]) // B1],
                 int(idx[i]) % B1 + 1) for i in range(n_nodes)]

    def partition(self, feature: int, bin_: int) -> np.ndarray:
        return self.codes[:, feature] >= bin_


class SimulatedCluster:
    """Single-process multi-worker simulation: breakpoint-able, step-wise,
    with worker-failure injection and dynamic feature reassignment (§3.9).

    Fault-tolerant by construction (DESIGN.md §11.3):

    * a ``WorkerFaultPlan`` kills workers at scheduled ``(tree, level)``
      points — candidates computed in that level pass are treated as LOST
      and the level RESTARTS against the surviving workers after dynamic
      feature reassignment;
    * candidate merge uses a total order — (highest gain, then smallest
      feature id, then smallest bin) — so the chosen split is independent of
      which worker proposed it. That makes a faulted run's forest
      BIT-IDENTICAL to the clean run (the invariant the recovery tests pin);
    * ``fit(..., checkpoint=CheckpointPolicy(dir))`` writes the same atomic
      tree-boundary checkpoints as every other trainer, so a full cluster
      crash resumes mid-forest.

    Every death / reassignment / restart is recorded in
    ``training_logs["resilience"]``.
    """

    def __init__(self, codes: np.ndarray, n_workers: int, cfg: DistGBTConfig,
                 seed: int = 0, fault_plan: WorkerFaultPlan | None = None):
        self.cfg = cfg
        self.codes = codes
        self.seed = seed
        F = codes.shape[1]
        rng = np.random.default_rng(seed)
        assign = np.array_split(rng.permutation(F), n_workers)
        self.workers = [SimulatedWorker(w, codes, list(a))
                        for w, a in enumerate(assign)]
        self.traffic_bytes = 0
        self.fault_plan = fault_plan if fault_plan is not None else WorkerFaultPlan()
        self.trees: list[dict] = []
        self.init_pred = 0.0
        self.resilience: list[dict] = []
        # pre-fit logs hold a LIVE reference to the resilience list so
        # direct grow_tree() users see deaths as they happen; fit() rebuilds
        # the dict through the same §13.4 schema with final values
        self.training_logs: dict = validate_training_logs({
            "schema_version": 1, "learner": "simulated_cluster",
            "num_trees": 0, "growth_engine": None, "engine_fallback": None,
            "resilience": self.resilience, "interrupted": False})
        self._tree_counter = 0

    def kill_worker(self, wid: int, *, tree: int | None = None,
                    level: int | None = None) -> None:
        """Fault injection: reassign the dead worker's features round-robin
        (the paper's dynamic feature re-allocation)."""
        dead = self.workers[wid]
        dead.alive = False
        alive = [w for w in self.workers if w.alive]
        if not alive:
            raise RuntimeError("all workers failed")
        n_feats = len(dead.feature_ids)
        for i, f in enumerate(dead.feature_ids):
            alive[i % len(alive)].feature_ids.append(f)
        dead.feature_ids = []
        self.resilience.append(
            {"event": "worker_death", "worker": wid, "tree": tree,
             "level": level, "features_reassigned": n_feats,
             "workers_alive": len(alive)})
        trace.event("distributed/worker_death", worker=wid, tree=tree,
                    level=level, features_reassigned=n_feats)

    def _train_config(self, task: str) -> dict:
        import dataclasses as dc
        return {"trainer": "SimulatedCluster", "task": task,
                "cfg": dc.asdict(self.cfg)}

    def grow_tree(self, stats: np.ndarray, tree_index: int | None = None) -> dict:
        t = self._tree_counter if tree_index is None else tree_index
        self._tree_counter = t + 1
        cfg = self.cfg
        N = self.codes.shape[0]
        node_of = np.zeros(N, np.int32)
        feats, bins, gains = [], [], []
        for d in range(cfg.max_depth):
            n_nodes = 2 ** d
            level_ctx = trace.span("distributed/level", tree=t, level=d,
                                   nodes=n_nodes)
            level_ctx.__enter__()
            while True:
                cands = []
                for w in self.workers:
                    if not w.alive:
                        continue
                    with trace.span("distributed/worker_best", worker=w.wid,
                                    tree=t, level=d,
                                    features=len(w.feature_ids)):
                        cands.append(w.local_best(stats, node_of, n_nodes,
                                                  cfg))
                self.traffic_bytes += sum(len(c) for c in cands) * 12  # 3 scalars
                dead = self.fault_plan.deaths_at(
                    t, d, [w.wid for w in self.workers if w.alive])
                if not dead:
                    break
                # deaths mid-level: the level pass's candidates are lost.
                # Reassign the dead workers' features, restart the level.
                # Histograms are pure functions of (data, node_of), and the
                # merge order is total, so the restarted level is
                # bit-identical to a clean level over the same partition.
                for wid in dead:
                    self.kill_worker(wid, tree=t, level=d)
                self.resilience.append(
                    {"event": "level_restart", "tree": t, "level": d,
                     "deaths": list(dead)})
                trace.event("distributed/level_restart", tree=t, level=d,
                            deaths=len(dead))
            for i in range(n_nodes):
                # assignment-independent merge: gain desc, feature id asc,
                # bin asc — a worker death can never change the winner
                g, f, b = max((c[i] for c in cands),
                              key=lambda x: (x[0], -x[1], -x[2]))
                feats.append(f if np.isfinite(g) else 0)
                bins.append(b)
                gains.append(g)
            level = np.array(gains[-n_nodes:])
            go = np.zeros(N, bool)
            for i in range(n_nodes):
                if np.isfinite(level[i]):
                    f, b = feats[-n_nodes + i], bins[-n_nodes + i]
                    owner = next(w for w in self.workers
                                 if w.alive and f in w.feature_ids)
                    sel = node_of == i
                    go[sel] = owner.partition(f, b)[sel]
            self.traffic_bytes += (N + 7) // 8  # bit-packed partition
            node_of = node_of * 2 + go
            level_ctx.__exit__(None, None, None)
        # leaves
        leaf = np.zeros(2 ** cfg.max_depth, np.float32)
        for i in range(2 ** cfg.max_depth):
            sel = node_of == i
            G, H = stats[sel, 0].sum(), stats[sel, 1].sum()
            leaf[i] = -cfg.shrinkage * G / (H + cfg.l2 + 1e-12)
        return {"feat": np.array(feats), "bin": np.array(bins),
                "gain": np.array(gains), "leaf": leaf, "node_of": node_of}

    # ---- boosting driver (same loop shape as DistributedGBT.fit) ----
    def fit(self, y: np.ndarray, *, task: str = "binary", checkpoint=None):
        cfg = self.cfg
        N = self.codes.shape[0]
        pred = np.zeros(N, np.float64)
        self.init_pred = _init_pred(y, task)
        pred[:] = self.init_pred
        self.trees = []

        from repro.core.rf import training_data_fingerprint
        from repro.train.checkpoint import open_session
        sess = open_session(checkpoint, self._train_config(task),
                            training_data_fingerprint(self.codes, y))
        interrupted = False
        if sess is not None:
            state = sess.resume()
            if state is not None:
                self.trees = list(state["trees"])
                pred = np.copy(state["pred"])
                self.init_pred = float(state["init_pred"])

        import contextlib
        with (sess if sess is not None else contextlib.nullcontext()):
            for it in range(len(self.trees), cfg.num_trees):
                g, h = _grad_hess(pred, y, task)
                stats = np.stack([g, h, np.ones(N)], 1)
                tree = self.grow_tree(stats, tree_index=it)
                self.trees.append(
                    {k: tree[k] for k in ("feat", "bin", "gain", "leaf")})
                pred += tree["leaf"][tree["node_of"]]
                if sess is not None:
                    done = len(self.trees) == cfg.num_trees
                    if not done and sess.should_stop():
                        interrupted = True
                    sess.save(len(self.trees),
                              {"kind": "sim_gbt", "trees": list(self.trees),
                               "pred": np.copy(pred),
                               "init_pred": self.init_pred},
                              done=done, force=done or interrupted)
                    if interrupted:
                        break
        self.training_logs = build_training_logs(
            learner="simulated_cluster", num_trees=len(self.trees),
            resilience=self.resilience, interrupted=interrupted,
            extra={"checkpoint":
                   sess.events if sess is not None else []})
        return self

    def predict_scores(self, codes: np.ndarray) -> np.ndarray:
        return predict_scores_complete(self.trees, self.init_pred,
                                       self.cfg.max_depth, codes)

    def to_forest(self, feature_names: list[str] | None = None) -> Forest:
        return complete_trees_to_forest(self.trees, self.init_pred,
                                        self.cfg.max_depth, feature_names)
