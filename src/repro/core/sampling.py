"""Keyed (counter-based) per-node feature sampling.

Random Forests draw a feature subset per tree node (Breiman's sqrt rule).
The seed implementation draws those subsets from the learner's sequential rng
stream, which couples the draw order to the *growth schedule*: pruning an
unsplittable node, reordering the frontier, or growing trees in lockstep all
shift every later draw. That coupling is why PR 1's batched engine had to
disable frontier pruning whenever ``num_candidate_ratio < 1``.

Keyed sampling removes the coupling: the subset for node ``n`` of tree ``t``
is a pure function ``hash(key, t, n)`` (a murmur3-style 32-bit finalizer,
implemented identically in numpy and jnp). Any engine — sequential oracle,
batched, K-tree lockstep, the device-resident jitted loop — derives the same
subsets for the same (tree, node) pairs, so execution strategy is
semantics-free by construction (tested bit-identical in
tests/test_grower_device.py).

The subset of size k is the k features with the smallest hash values
(stable-argsorted, then index-sorted ascending so argmax tie-breaking matches
the masked full-matrix scan: lowest feature index wins).
"""
from __future__ import annotations

import numpy as np

_GOLD = 0x9E3779B9


def _mix_np(x: np.ndarray) -> np.ndarray:
    """murmur3 fmix32 finalizer on uint32 arrays (wrapping arithmetic)."""
    with np.errstate(over="ignore"):
        x = x.astype(np.uint32, copy=True)
        x ^= x >> np.uint32(16)
        x *= np.uint32(0x85EBCA6B)
        x ^= x >> np.uint32(13)
        x *= np.uint32(0xC2B2AE35)
        x ^= x >> np.uint32(16)
    return x


def feature_hash(key: int, tree: int, nodes: np.ndarray, F: int) -> np.ndarray:
    """(len(nodes), F) uint32 hash lattice for (key, tree, node, feature)."""
    h1 = _mix_np(np.uint32(key & 0xFFFFFFFF) ^ np.uint32(_GOLD))
    h2 = _mix_np(h1 ^ np.uint32(tree & 0xFFFFFFFF))
    hn = _mix_np(h2 ^ np.asarray(nodes, np.uint32))          # (n,)
    with np.errstate(over="ignore"):
        hf = np.arange(F, dtype=np.uint32) * np.uint32(_GOLD)
    return _mix_np(hn[:, None] ^ hf[None, :])                # (n, F)


def keyed_feature_select(key: int, tree: int, nodes: np.ndarray, F: int,
                         k: int) -> np.ndarray:
    """Per-node sampled feature indices: (len(nodes), k) int32, ascending."""
    h = feature_hash(key, tree, nodes, F)
    sel = np.argsort(h, axis=1, kind="stable")[:, :k]
    return np.sort(sel, axis=1).astype(np.int32)


def sample_size(ratio: float, F: int) -> int:
    """Subset size for a sampling ratio — must match grower's stream-mode
    ``_feature_sample_mask`` so keyed and stream modes sample equally many."""
    return max(1, int(round(ratio * F)))


# ---------------------------------------------------------------- jnp mirror

def keyed_feature_select_jnp(key: int, tree, nodes, F: int, k: int):
    """jnp mirror of keyed_feature_select. ``tree``/``nodes`` may be traced
    (device) values; results are bit-identical to the numpy version, which is
    what lets the device engine reproduce the host engines' feature subsets."""
    import jax.numpy as jnp

    def mix(x):
        x = x.astype(jnp.uint32)
        x = x ^ (x >> 16)
        x = x * jnp.uint32(0x85EBCA6B)
        x = x ^ (x >> 13)
        x = x * jnp.uint32(0xC2B2AE35)
        return x ^ (x >> 16)

    h1 = mix(jnp.uint32(key & 0xFFFFFFFF) ^ jnp.uint32(_GOLD))
    h2 = mix(h1 ^ jnp.asarray(tree, jnp.uint32))
    hn = mix(h2 ^ jnp.asarray(nodes, jnp.uint32))            # (...,)
    hf = jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(_GOLD)
    h = mix(hn[..., None] ^ hf)                              # (..., F)
    sel = jnp.argsort(h, axis=-1, stable=True)[..., :k]
    return jnp.sort(sel, axis=-1).astype(jnp.int32)
