"""Inference engines (paper §3.7): a Model *compiles* — possibly lossily — to
the fastest engine compatible with its structure and the hardware.

Engines (ordered by preference):
  * "pallas"     — VMEM-tiled lockstep traversal (repro/kernels/forest_infer);
                   requires axis-aligned numerical/categorical conditions and
                   node counts that fit the kernel's VMEM budget. On CPU runs
                   in interpret mode (correctness path); TPU is the target.
  * "vectorized" — numpy lockstep traversal (tree.predict_raw).
  * "naive"      — Algorithm 1 of the paper: per-example while-loop. Readable
                   oracle; always compatible.

``compile_model(model)`` picks the best compatible engine; requesting an
incompatible engine by name raises with the reason (lossy-compilation made
explicit, §2.1).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core.api import YdfError
from repro.core.tree import Forest, predict_naive, predict_raw


@dataclass
class Engine:
    name: str
    per_tree: Callable[[np.ndarray], np.ndarray]  # X (N,F) -> (N,T,out_dim)
    note: str = ""


def _compat_pallas(forest: Forest) -> str | None:
    if forest.obl_weights is not None and forest.obl_weights.shape[-1] and \
            (forest.feature == -2).any():
        return "oblique conditions are not supported by the pallas engine"
    if forest.max_nodes > 4096:
        return "node capacity exceeds the pallas engine VMEM budget"
    return None


def available_engines(forest: Forest) -> list[str]:
    out = []
    if _compat_pallas(forest) is None:
        out.append("pallas")
    out += ["vectorized", "naive"]
    return out


def compile_model(model, engine: str | None = None) -> Engine:
    forest: Forest = model.forest
    if engine is None:
        engine = available_engines(forest)[0]
        # prefer vectorized on CPU hosts: pallas-interpret is a correctness
        # path, not a fast path (lossy-compilation choice is hardware-aware)
        if engine == "pallas":
            import jax
            if jax.default_backend() == "cpu":
                engine = "vectorized"
    if engine == "naive":
        return Engine("naive", lambda X: predict_naive(forest, X))
    if engine == "vectorized":
        return Engine("vectorized", lambda X: predict_raw(forest, X))
    if engine == "pallas":
        reason = _compat_pallas(forest)
        if reason:
            raise YdfError(
                f"Model is not compatible with the 'pallas' engine: {reason}. "
                f"Compatible engines: {available_engines(forest)}.")
        from repro.kernels.forest_infer.ops import forest_predict
        return Engine("pallas", lambda X: np.asarray(forest_predict(forest, X)),
                      note="interpret-mode on CPU; compiled on TPU")
    raise YdfError(f"Unknown engine {engine!r}. "
                   f"Available: {available_engines(forest)}.")


def benchmark_inference(model, dataset, *, repetitions: int = 5) -> str:
    """App. B.4 analogue: time every compatible engine on the dataset."""
    from repro.core.models import _as_vertical, raw_matrix
    ds = _as_vertical(dataset, model.spec)
    X = raw_matrix(ds, model.features)
    lines = ["benchmark_inference (avg over %d reps, batch=%d):"
             % (repetitions, X.shape[0])]
    for name in available_engines(model.forest):
        eng = compile_model(model, name)
        eng.per_tree(X[:min(64, len(X))])  # warmup / trace
        t0 = time.perf_counter()
        for _ in range(repetitions):
            eng.per_tree(X)
        dt = (time.perf_counter() - t0) / repetitions
        us = dt / max(1, X.shape[0]) * 1e6
        lines.append(f"  {name:<12s} {us:10.3f} us/example  "
                     f"({dt * 1e3:.2f} ms/batch)")
    return "\n".join(lines)
