"""Inference engines and the compiled serving stack (paper §3.7;
DESIGN.md §5, §10): a Model *compiles* — possibly lossily — to the fastest
engine compatible with its structure and the hardware.

Engines (ordered by preference):
  * "pallas"     — tree-tiled lockstep traversal over the depth-packed
                   layout (repro/kernels/forest_infer, §5.2–§5.3); requires
                   axis-aligned numerical/categorical conditions. Node count
                   is unbounded (the old 4096-node VMEM ceiling is gone —
                   large forests tile instead of raising). On CPU runs in
                   interpret mode (correctness path); TPU is the target.
  * "bucketed"   — depth-bucketed XLA traversal (§10): trees grouped by
                   actual depth, each bucket pays its own round count
                   (early exit for shallow trees) and picks its scoring
                   strategy per the §10.3 cost model. The CPU fast path.
  * "leaf_path"  — the bucketed engine with leaf-path flattening FORCED on
                   every bucket (predicate-matrix matmul scoring, §10.2);
                   only offered when every tree's path table fits the
                   LEAF_PATH_BUDGET. Explicit-request strategy, not a
                   default: on CPU the scan beats it at every depth.
  * "vectorized" — specialized numpy lockstep traversal
                   (tree.compile_predict_raw, §5.1). No jit trace, so it is
                   also the right engine for small forests / tiny batches.
  * "naive"      — Algorithm 1 of the paper: per-example while-loop. Readable
                   oracle; always compatible.

``compile_model(model)`` picks the best compatible engine —
hardware-aware: on CPU hosts ``select_cpu_engine`` weighs the bucketed
engine's one-off jit trace against forest size. Requesting an incompatible
engine by name raises with the reason (lossy-compilation made explicit,
§2.1).

``compile_predictor(model)`` builds the full serving artifact (§5.1): a
``CompiledPredictor`` bundles the engine closure with pre-compiled raw→code
encode tables (dataspec.BatchEncoder) and the model's output head, so a
request batch pays exactly one vectorized encode + one engine call + one
aggregation — no dataspec walk, no host round-trips, no re-upload.
``Model.predict`` caches one and reuses it across calls. The artifact
pickles: engines serialize as (name, forest) and recompile on load, so a
round-tripped predictor keeps its engine choice without shipping closures.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.obs import clock, trace
from repro.core.api import EngineFailure, YdfError
from repro.core.dataspec import BatchEncoder
from repro.core.tree import (
    Forest,
    LEAF_PATH_BUDGET,
    compile_predict_raw,
    leaf_path_sizes,
    predict_naive,
    tree_depths,
)

# Minimum n_trees * depth for the bucketed engine to win by default on CPU:
# below this, its one-off jit trace (~0.1 s per batch shape) dwarfs any
# steady-state gain over the numpy engine, which compiles in microseconds.
BUCKETED_MIN_WORK = 256


@dataclass
class Engine:
    name: str
    per_tree: Callable[[np.ndarray], np.ndarray]  # X (N,F) -> (N,T,out_dim)
    note: str = ""
    # the source forest rides along so the engine can pickle as (name,
    # forest) and rebuild its closure — device buffers and jit caches do not
    # serialize (CompiledPredictor round-trip, DESIGN.md §10.4)
    forest: Forest | None = None

    def __getstate__(self):
        return {"name": self.name, "note": self.note, "forest": self.forest}

    def __setstate__(self, state):
        rebuilt = _compile_forest_engine(state["forest"], state["name"])
        self.__dict__.update(rebuilt.__dict__)


def _compat_pallas(forest: Forest) -> str | None:
    if forest.has_oblique():
        return "oblique conditions are not supported by the pallas engine"
    return None


def _compat_bucketed(forest: Forest) -> str | None:
    if forest.has_oblique():
        return "oblique conditions are not supported by the bucketed engine"
    return None


def _compat_leaf_path(forest: Forest) -> str | None:
    if forest.has_oblique():
        return "oblique conditions are not supported by the leaf_path engine"
    n_internal, n_leaves = leaf_path_sizes(forest)
    if n_internal * n_leaves > LEAF_PATH_BUDGET:
        return (f"leaf-path flattening needs a {n_internal}x{n_leaves} "
                f"predicate matrix per tree (> {LEAF_PATH_BUDGET} budget); "
                f"the transform targets shallow trees")
    return None


def available_engines(forest: Forest) -> list[str]:
    out = []
    if _compat_pallas(forest) is None:
        out.append("pallas")
    if _compat_bucketed(forest) is None:
        out.append("bucketed")
    if _compat_leaf_path(forest) is None:
        out.append("leaf_path")
    out += ["vectorized", "naive"]
    return out


def select_cpu_engine(forest: Forest) -> str:
    """Size-aware CPU default between the two compiled traversals.

    Steady-state the bucketed XLA engine wins (~3x over the numpy engine on
    the §B.4 forests, ~2x over sklearn's C traversal), but it pays a jit
    trace per batch shape. ``n_trees * depth`` below BUCKETED_MIN_WORK means
    the forest is so small that the numpy engine is already in the tens of
    microseconds per batch — take it and skip the trace."""
    if _compat_bucketed(forest) is not None:
        return "vectorized"
    if forest.n_trees == 0:
        return "vectorized"
    depth = int(tree_depths(forest).max())
    if forest.n_trees * max(1, depth) >= BUCKETED_MIN_WORK:
        return "bucketed"
    return "vectorized"


def compile_model(model, engine: str | None = None) -> Engine:
    return _compile_forest_engine(model.forest, engine)


def _compile_forest_engine(forest: Forest, engine: str | None) -> Engine:
    if engine is None:
        engine = available_engines(forest)[0]
        # hardware-aware default (lossy-compilation choice, §3.7): pallas
        # targets TPU (interpret mode on CPU is a correctness path, not a
        # fast path); on CPU hosts pick between the XLA bucketed engine and
        # the trace-free numpy engine by forest size
        if engine in ("pallas", "bucketed"):
            import jax
            if jax.default_backend() == "cpu":
                engine = select_cpu_engine(forest)
    if engine == "naive":
        return Engine("naive", lambda X: predict_naive(forest, X),
                      forest=forest)
    if engine == "vectorized":
        return Engine("vectorized", compile_predict_raw(forest),
                      note="specialized flat-table traversal (§5.1)",
                      forest=forest)
    if engine in ("bucketed", "leaf_path"):
        compat = (_compat_bucketed if engine == "bucketed"
                  else _compat_leaf_path)
        reason = compat(forest)
        if reason:
            raise YdfError(
                f"Model is not compatible with the {engine!r} engine: "
                f"{reason}. Compatible engines: {available_engines(forest)}.")
        from repro.kernels.forest_infer.ops import bucketed_runner
        strategy = "leaf_path" if engine == "leaf_path" else None
        run = bucketed_runner(forest, strategy)  # pack + upload once, now
        note = ("predicate-matrix (leaf-path) scoring forced on every "
                "bucket (§10.2)" if engine == "leaf_path" else
                "depth-bucketed XLA traversal, per-bucket early exit and "
                "strategy choice (§10)")
        return Engine(engine, run, note=note, forest=forest)
    if engine == "pallas":
        reason = _compat_pallas(forest)
        if reason:
            raise YdfError(
                f"Model is not compatible with the 'pallas' engine: {reason}. "
                f"Compatible engines: {available_engines(forest)}.")
        from repro.kernels.forest_infer.ops import device_packed, forest_predict
        device_packed(forest)  # upload the depth-packed layout once, now
        return Engine("pallas", lambda X: np.asarray(forest_predict(forest, X)),
                      note="tree-tiled over depth-packed blocks (§5.2); "
                           "interpret-mode on CPU, compiled on TPU",
                      forest=forest)
    raise YdfError(f"Unknown engine {engine!r}. "
                   f"Available: {available_engines(forest)}.")


# engines whose first call at a new batch shape traces/compiles — the layer
# that knows its dispatch shapes (serving, benchmarks) warms these
JIT_ENGINES = ("pallas", "bucketed", "leaf_path")


# ------------------------------------------------- compiled predictor (§5.1)

@dataclass
class CompiledPredictor:
    """The reusable end-to-end serving artifact (DESIGN.md §5.1).

    Built once per model: ``encoder`` holds the vectorized raw→code tables,
    ``engine`` the traversal closure (device-resident forest for pallas),
    ``finalize`` the model's aggregation + activation head. ``predict`` is
    then a pure batch function with no per-call compilation, conversion, or
    host↔device forest traffic; ``encode``/``predict_encoded`` split the two
    halves so a micro-batcher (serving/forest.py, §5.4) can encode per
    request but dispatch per padded batch.

    Pickles as a whole (§10.4): Engine serializes to (name, forest) and
    recompiles on load, encoder/finalize are plain data — so a predictor
    saved after engine selection comes back with the SAME engine choice,
    not a re-run of the hardware heuristic.
    """
    engine: Engine
    encoder: BatchEncoder
    finalize: Callable[[np.ndarray], np.ndarray]
    compile_s: float = 0.0
    # trailing shape of one prediction — () for regression, (n_classes,) for
    # classification. Lets a zero-row dispatch return a correctly-shaped
    # empty array without running the engine (serving/forest.py).
    out_shape: tuple = ()

    @property
    def name(self) -> str:
        return self.engine.name

    def encode(self, dataset) -> np.ndarray:
        return self.encoder.encode(dataset)

    def per_tree(self, X: np.ndarray) -> np.ndarray:
        # engine failures surface TYPED (DESIGN.md §9.1): the serving
        # front-end routes EngineFailure into retry / circuit-breaker logic,
        # while schema errors (encode) stay YdfError and reach the caller
        try:
            with trace.span("engines/dispatch", engine=self.name,
                            rows=len(X)):
                return self.engine.per_tree(X)
        except (EngineFailure, KeyboardInterrupt):
            raise
        except Exception as e:
            raise EngineFailure(
                f"engine {self.name!r} failed on a batch of "
                f"{len(X)} rows: {type(e).__name__}: {e}",
                engine=self.name) from e

    def predict_encoded(self, X: np.ndarray) -> np.ndarray:
        if len(X) == 0:
            return np.zeros((0,) + self.out_shape, np.float32)
        return self.finalize(np.asarray(self.per_tree(X)))

    def predict(self, dataset) -> np.ndarray:
        return self.predict_encoded(self.encode(dataset))


def compile_predictor(model, engine: str | None = None) -> CompiledPredictor:
    """Compile ``model`` into a CompiledPredictor. Jit'd engines retrace per
    batch shape, so shape warmup belongs to the layer that knows the
    dispatch sizes — serving/forest.py warms at its padding buckets."""
    t0 = clock.perf()
    with trace.span("engines/compile", engine=engine or "auto"):
        eng = compile_model(model, engine)
    encoder = BatchEncoder(model.spec, model.features)
    # _compile_finalize returns a picklable callable over the needed fields
    # only — a bound model method would cycle Model <-> predictor (models.py)
    finalize = model._compile_finalize()
    # probe the output head on a zero per-tree stack to learn the trailing
    # prediction shape — no engine call, so it is free even for jit'd engines
    probe = finalize(np.zeros(
        (1, model.forest.n_trees, model.forest.leaf_value.shape[-1]),
        np.float32))
    return CompiledPredictor(engine=eng, encoder=encoder,
                             finalize=finalize,
                             compile_s=clock.perf() - t0,
                             out_shape=tuple(np.asarray(probe).shape[1:]))


def benchmark_inference(model, dataset, *, repetitions: int = 5) -> str:
    """App. B.4 analogue: time every compatible engine on the dataset.

    Jit'd engines (JIT_ENGINES) warm up AT THE TIMED SHAPE — they retrace
    per batch shape, so a 64-row warmup would leave the retrace in the first
    timed rep — and that warmup is reported separately as compile time. It
    is an upper bound: the warmup call necessarily executes once after
    tracing (on TPU, XLA compiles during that first call; in interpret mode
    on CPU the execution dominates). Non-jit engines have no trace to warm:
    their compile time is the closure-specialization cost alone, and a
    tiny-slice warmup just touches the code path.
    """
    # the compiled encoder only needs the FEATURE columns, so imported /
    # built models benchmark on label-free request batches too (§5.1)
    X = BatchEncoder(model.spec, model.features).encode(dataset)
    lines = ["benchmark_inference (avg over %d reps, batch=%d):"
             % (repetitions, X.shape[0])]
    for name in available_engines(model.forest):
        t0 = clock.perf()
        eng = compile_model(model, name)
        if name in JIT_ENGINES:
            eng.per_tree(X)          # warmup / trace at the timed shape
            compile_s = clock.perf() - t0
        else:
            compile_s = clock.perf() - t0
            eng.per_tree(X[:min(64, len(X))])  # untimed code-path touch
        t0 = clock.perf()
        for _ in range(repetitions):
            eng.per_tree(X)
        dt = (clock.perf() - t0) / repetitions
        us = dt / max(1, X.shape[0]) * 1e6
        lines.append(f"  {name:<12s} {us:10.3f} us/example  "
                     f"({dt * 1e3:.2f} ms/batch, compile {compile_s * 1e3:.1f} ms)")
    return "\n".join(lines)
