"""Hyper-parameters with paper-exact defaults (App. C.1) and versioned
templates (§3.11): defaults never change; newer methods are opt-in; templates
like ``benchmark_rank1@v1`` bundle the best-known settings per version.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.api import YdfError


@dataclass(frozen=True)
class GBTHparams:
    num_trees: int = 300
    # -- App C.1 "Gradient Boosted Trees hyper-parameters"
    early_stopping: str = "LOSS_INCREASE"   # LOSS_INCREASE | NONE
    l1_regularization: float = 0.0
    l2_regularization: float = 0.0
    max_depth: int = 6
    num_candidate_attributes_ratio: float = 1.0   # -1 i.e. all
    shrinkage: float = 0.1
    subsample: float = 1.0                  # sampling_method: NONE
    use_hessian_gain: bool = False
    growing_strategy: str = "LOCAL"         # LOCAL | BEST_FIRST_GLOBAL
    categorical_algorithm: str = "CART"     # CART | RANDOM | ONE_HOT
    split_axis: str = "AXIS_ALIGNED"        # AXIS_ALIGNED | SPARSE_OBLIQUE
    sparse_oblique_normalization: str = "MIN_MAX"
    sparse_oblique_num_projections_exponent: float = 1.0
    # non-C.1 plumbing
    min_examples: int = 5
    max_num_nodes: int = 256                # BEST_FIRST_GLOBAL budget
    validation_ratio: float = 0.1
    early_stopping_patience: int = 30       # trees without improvement
    max_bins: int = 255
    loss: str = "DEFAULT"                   # DEFAULT | BINOMIAL | MULTINOMIAL | SQUARED_ERROR
    growth_engine: str = "batched"          # batched | oracle | device (§6)
    histogram_backend: str = "auto"         # auto | numpy | pallas
    # -- ranking (task=RANKING, DESIGN.md §12.1): LambdaMART pairwise loss
    ranking_group: str = "group"            # group/query column name
    ndcg_truncation: int = 5                # the k in the |ΔNDCG@k| weights


@dataclass(frozen=True)
class RFHparams:
    num_trees: int = 300
    # -- App C.1 "Random Forest default hyper-parameters"
    categorical_algorithm: str = "CART"
    growing_strategy: str = "LOCAL"
    max_depth: int = 16
    min_examples: int = 5
    num_candidate_attributes: str = "SQRT"  # Breiman rule of thumb | "ALL" | float ratio
    split_axis: str = "AXIS_ALIGNED"
    sparse_oblique_normalization: str = "MIN_MAX"
    sparse_oblique_num_projections_exponent: float = 1.0
    # non-C.1 plumbing
    bootstrap: bool = True
    winner_take_all: bool = True
    compute_oob: bool = True
    max_num_nodes: int = 4096
    max_bins: int = 255
    growth_engine: str = "batched"          # batched | oracle | device (§6)
    histogram_backend: str = "auto"         # auto | numpy | pallas
    # trees grown per lockstep block (grower.grow_trees). Execution-only:
    # forests are bit-identical for any value (keyed feature sampling).
    tree_parallelism: int = 8


@dataclass(frozen=True)
class CartHparams:
    max_depth: int = 16
    min_examples: int = 5
    categorical_algorithm: str = "CART"
    validation_ratio: float = 0.1           # for pruning
    max_num_nodes: int = 4096
    max_bins: int = 255
    growth_engine: str = "batched"          # batched | oracle | device (§6)
    histogram_backend: str = "auto"         # auto | numpy | pallas


@dataclass(frozen=True)
class UpliftHparams:
    """Honest uplift trees (task=UPLIFT, DESIGN.md §12.2): RF-style growth
    over the "uplift" splitter statistics — per-node treated/control outcome
    sums scored by the Euclidean-distance gain n*(p_t - p_c)^2."""
    num_trees: int = 100
    max_depth: int = 8
    min_examples: int = 20                  # per node, BOTH arms pooled
    num_candidate_attributes: str = "SQRT"
    bootstrap: bool = True
    max_num_nodes: int = 4096
    max_bins: int = 255
    treatment: str = "treatment"            # 0/1 treatment column name
    growth_engine: str = "batched"          # batched | oracle | device (§6)
    histogram_backend: str = "auto"
    tree_parallelism: int = 8


@dataclass(frozen=True)
class IsolationForestHparams:
    """Isolation forest (task=ANOMALY, DESIGN.md §12.3; Liu et al. 2008).
    Random splits, no histograms: the splitter never scans gains, so the
    grower seam is bypassed and trees are written straight into the Forest
    SoA, then served through the ordinary compiled engines."""
    num_trees: int = 100
    subsample_count: int = 256              # psi: rows sampled per tree
    max_depth: int = 0                      # 0 = ceil(log2(subsample_count))


# ---------------------------------------------------------------- templates

_TEMPLATES: dict[tuple[str, str], dict] = {
    # paper App C.1 "rank1@v1": same as defaults with these changes
    ("GRADIENT_BOOSTED_TREES", "benchmark_rank1@v1"): dict(
        growing_strategy="BEST_FIRST_GLOBAL",
        categorical_algorithm="RANDOM",
        split_axis="SPARSE_OBLIQUE",
        sparse_oblique_normalization="MIN_MAX",
        sparse_oblique_num_projections_exponent=1.0,
    ),
    ("RANDOM_FOREST", "benchmark_rank1@v1"): dict(
        categorical_algorithm="RANDOM",
        split_axis="SPARSE_OBLIQUE",
        sparse_oblique_normalization="MIN_MAX",
        sparse_oblique_num_projections_exponent=1.0,
    ),
}
# unversioned alias -> latest version (version pinning keeps old behaviour)
_LATEST = {"benchmark_rank1": "benchmark_rank1@v1"}


def apply_template(learner_name: str, hp, template: str | None):
    if not template:
        return hp
    template = _LATEST.get(template, template)
    key = (learner_name, template)
    if key not in _TEMPLATES:
        avail = sorted(t for (l, t) in _TEMPLATES if l == learner_name)
        raise YdfError(
            f"Unknown hyper-parameter template {template!r} for {learner_name}. "
            f"Available templates: {avail}.")
    return dataclasses.replace(hp, **_TEMPLATES[key])


# -------------------------------------------------- tuner search spaces (C.2)

GBT_SEARCH_SPACE = {
    "min_examples": [2, 5, 7, 10],
    "categorical_algorithm": ["CART", "RANDOM"],
    "split_axis": ["AXIS_ALIGNED", "SPARSE_OBLIQUE"],
    "use_hessian_gain": [True, False],
    "shrinkage": [0.02, 0.05, 0.10, 0.15],
    "num_candidate_attributes_ratio": [0.2, 0.5, 0.9, 1.0],
    "growing_strategy": ["LOCAL", "BEST_FIRST_GLOBAL"],
    "max_depth": [3, 4, 6, 8],
    "max_num_nodes": [16, 32, 64, 128, 256],
}

RF_SEARCH_SPACE = {
    "min_examples": [2, 5, 7, 10],
    "categorical_algorithm": ["CART", "RANDOM"],
    "split_axis": ["AXIS_ALIGNED", "SPARSE_OBLIQUE"],
    "max_depth": [12, 16, 20, 30],
}
