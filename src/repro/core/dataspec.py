"""Automated feature ingestion (paper §3.4).

A ``DataSpec`` records, per column, its *semantic* (NUMERICAL / CATEGORICAL /
BOOLEAN), dictionary, and statistics. Semantics are inferred by heuristics and
are overridable by the user — automation, surfaced, controllable (§2.1).

``VerticalDataset`` is the encoded, column-major view learners consume:
  * numerical  -> float32, missing = NaN
  * categorical -> int32 in [0, vocab), 0 = out-of-dictionary; missing = -1
  * boolean    -> int32 {0, 1}, missing = -1
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Any, Mapping

import numpy as np

from repro.core.api import Task, YdfError

OOD = "<OOD>"


class Semantic(enum.Enum):
    NUMERICAL = "NUMERICAL"
    CATEGORICAL = "CATEGORICAL"
    BOOLEAN = "BOOLEAN"


@dataclass
class Column:
    name: str
    semantic: Semantic
    # categorical
    vocab: list[str] = field(default_factory=list)  # vocab[0] == OOD
    counts: dict[str, int] = field(default_factory=dict)
    # numerical
    mean: float = 0.0
    std: float = 0.0
    min: float = 0.0
    max: float = 0.0
    n_missing: int = 0
    manually_defined: bool = False

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)


@dataclass
class DataSpec:
    columns: dict[str, Column]
    n_rows: int

    def __getitem__(self, name: str) -> Column:
        return self.columns[name]

    def feature_names(self, label: str | None = None,
                      features: list[str] | None = None,
                      exclude: list[str] | tuple[str, ...] = ()) -> list[str]:
        """``exclude`` drops task side-channel columns (ranking group,
        uplift treatment — DESIGN.md §12) from the default feature set."""
        if features is not None:
            missing = [f for f in features if f not in self.columns]
            if missing:
                raise YdfError(
                    f"Input feature(s) {missing} not found in the dataset. "
                    f"Available columns: {sorted(self.columns)}.")
            return list(features)
        drop = {label, *exclude}
        return [c for c in self.columns if c not in drop]

    # show_dataspec analogue (§4.1 artefacts)
    def report(self) -> str:
        by_sem: dict[str, list[Column]] = {}
        for c in self.columns.values():
            by_sem.setdefault(c.semantic.value, []).append(c)
        lines = [f"Number of records: {self.n_rows}",
                 f"Number of columns: {len(self.columns)}", ""]
        for sem, cols in sorted(by_sem.items()):
            pct = 100.0 * len(cols) / max(1, len(self.columns))
            lines.append(f"{sem}: {len(cols)} ({pct:.0f}%)")
            for c in sorted(cols, key=lambda c: c.name):
                if c.semantic == Semantic.NUMERICAL:
                    lines.append(
                        f'  "{c.name}" NUMERICAL mean:{c.mean:g} min:{c.min:g} '
                        f"max:{c.max:g} sd:{c.std:g} nas:{c.n_missing}")
                else:
                    top = max(c.counts, key=c.counts.get) if c.counts else "-"
                    lines.append(
                        f'  "{c.name}" {c.semantic.value} has-dict '
                        f"vocab-size:{c.vocab_size} most-frequent:{top!r} "
                        f"nas:{c.n_missing}"
                        + (" manually-defined" if c.manually_defined else ""))
        return "\n".join(lines)


# -------------------------------------------------- JSON (de)serialization

def spec_to_dict(spec: DataSpec) -> dict:
    """The stable JSON form of a DataSpec (CLI artefacts, Model.save's
    ``dataspec.json``)."""
    out = {"n_rows": spec.n_rows, "columns": {}}
    for name, c in spec.columns.items():
        d = dataclasses.asdict(c)
        d["semantic"] = c.semantic.value
        out["columns"][name] = d
    return out


def spec_from_dict(raw: dict) -> DataSpec:
    cols = {}
    for name, c in raw["columns"].items():
        c = dict(c)
        c["semantic"] = Semantic(c["semantic"])
        cols[name] = Column(name=name,
                            **{k: v for k, v in c.items() if k != "name"})
    return DataSpec(columns=cols, n_rows=raw["n_rows"])


# ----------------------------------------------------------------- inference

_MISSING_TOKENS = {"", "na", "n/a", "nan", "none", "null", "?"}
_MISSING_TOKEN_ARR = np.array(sorted(_MISSING_TOKENS))


def _is_missing(v) -> bool:
    if v is None:
        return True
    if isinstance(v, float) and np.isnan(v):
        return True
    return isinstance(v, str) and v.strip().lower() in _MISSING_TOKENS


def _try_float(v) -> float | None:
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


def _missing_mask(vals: np.ndarray) -> np.ndarray:
    """Vectorized ``_is_missing`` over a raw object column.

    Numeric path: one bulk float conversion (numpy maps None -> NaN) and an
    isnan; NaN-parsing strings that are NOT missing tokens (e.g. "-nan") are
    re-checked cell-by-cell so the result matches ``_is_missing`` exactly.
    String path (bulk conversion fails): match the stripped, lowercased
    string forms against the missing tokens — str(None) is "none" and
    str(nan) is "nan", both tokens, so non-string missing cells still hit.
    """
    try:
        miss = np.isnan(vals.astype(np.float64))
    except (TypeError, ValueError):
        s = np.char.lower(np.char.strip(vals.astype(str)))
        return np.isin(s, _MISSING_TOKEN_ARR)
    if miss.any():
        for i in np.where(miss)[0]:
            v = vals[i]
            if v is None or isinstance(v, float):
                continue  # genuinely missing; skip the per-cell re-check
            if not _is_missing(v):
                miss[i] = False
    return miss


def infer_dataspec(data: Mapping[str, Any], *,
                   semantics: Mapping[str, Semantic | str] | None = None,
                   max_vocab: int = 2048, min_vocab_frequency: int = 1) -> DataSpec:
    """Infer column semantics from raw columns (lists / object arrays).

    Heuristics (documented, §2.1 "clarity"): numeric dtypes -> NUMERICAL;
    strings -> CATEGORICAL (numeric-looking strings stay CATEGORICAL only if
    non-numeric values are present); bools / {0,1}-only integers -> BOOLEAN.
    ``semantics`` overrides win and are flagged ``manually-defined``.
    """
    semantics = dict(semantics or {})
    columns: dict[str, Column] = {}
    n_rows = None
    for name, raw in data.items():
        vals = np.asarray(raw, dtype=object).ravel()
        if n_rows is None:
            n_rows = len(vals)
        elif len(vals) != n_rows:
            raise YdfError(
                f"Column {name!r} has {len(vals)} values but previous columns "
                f"have {n_rows}. All columns must have the same length.")
        missing = _missing_mask(vals)
        present = vals[~missing]
        override = semantics.get(name)
        if override is not None:
            sem = Semantic(override) if not isinstance(override, Semantic) else override
        else:
            sem = _infer_semantic(present)
        col = Column(name=name, semantic=sem, n_missing=int(missing.sum()),
                     manually_defined=override is not None)
        if sem == Semantic.NUMERICAL:
            try:
                fs = present.astype(np.float64)
            except (TypeError, ValueError):
                bad = [v for v in present if _try_float(v) is None]
                raise YdfError(
                    f"Column {name!r} is NUMERICAL but contains non-numeric "
                    f"value(s) e.g. {bad[:3]}. Solutions: (1) declare the column "
                    f"CATEGORICAL via semantics={{{name!r}: 'CATEGORICAL'}}, or "
                    "(2) clean the values.")
            if fs.size:
                col.mean, col.std = float(fs.mean()), float(fs.std())
                col.min, col.max = float(fs.min()), float(fs.max())
        elif sem == Semantic.BOOLEAN:
            pass
        else:
            uniq, cnt = np.unique(present.astype(str), return_counts=True)
            order = np.argsort(-cnt, kind="stable")
            vocab = [OOD]
            counts = {}
            for i in order:
                if cnt[i] >= min_vocab_frequency and len(vocab) < max_vocab:
                    vocab.append(str(uniq[i]))
                    counts[str(uniq[i])] = int(cnt[i])
            col.vocab = vocab
            col.counts = counts
        columns[name] = col
    return DataSpec(columns=columns, n_rows=n_rows or 0)


def _infer_semantic(present: np.ndarray) -> Semantic:
    if present.size == 0:
        return Semantic.NUMERICAL
    if all(isinstance(v, (bool, np.bool_)) for v in present[:100]):
        return Semantic.BOOLEAN
    try:
        floats = present.astype(np.float64)  # all-parseable or ValueError
    except (TypeError, ValueError):
        return Semantic.CATEGORICAL
    if np.isin(floats[:1000], (0.0, 1.0)).all():
        return Semantic.BOOLEAN
    return Semantic.NUMERICAL


# ----------------------------------------------------------------- encoding

@dataclass
class VerticalDataset:
    spec: DataSpec
    numerical: dict[str, np.ndarray]    # float32, NaN = missing
    categorical: dict[str, np.ndarray]  # int32, -1 = missing, 0 = OOD
    n_rows: int

    def column(self, name: str) -> np.ndarray:
        if name in self.numerical:
            return self.numerical[name]
        return self.categorical[name]

    def subset(self, idx: np.ndarray) -> "VerticalDataset":
        return VerticalDataset(
            spec=self.spec,
            numerical={k: v[idx] for k, v in self.numerical.items()},
            categorical={k: v[idx] for k, v in self.categorical.items()},
            n_rows=len(idx),
        )


def _parse_numerical(vals: np.ndarray) -> np.ndarray:
    """Raw object column -> float32 with NaN for missing/unparsable. The
    single parse used by encode_dataset AND the compiled BatchEncoder (§5.1)
    so training-time and serving-time encodes can never drift apart."""
    try:
        return vals.astype(np.float64).astype(np.float32)
    except (TypeError, ValueError):
        out = np.full(len(vals), np.nan, np.float32)
        for i, v in enumerate(vals):
            if not _is_missing(v):
                f = _try_float(v)
                out[i] = np.nan if f is None else f
        return out


def _parse_boolean(vals: np.ndarray) -> np.ndarray:
    """Raw object column -> int32 {0, 1} with -1 for missing (shared by
    encode_dataset and BatchEncoder, like ``_parse_numerical``)."""
    miss = _missing_mask(vals)
    s = np.char.lower(np.char.strip(vals.astype(str)))
    out = np.isin(s, ("1", "1.0", "true")).astype(np.int32)
    out[miss] = -1
    return out


def encode_dataset(data: Mapping[str, Any], spec: DataSpec) -> VerticalDataset:
    numerical: dict[str, np.ndarray] = {}
    categorical: dict[str, np.ndarray] = {}
    n_rows = 0
    for name, col in spec.columns.items():
        if name not in data:
            raise YdfError(
                f"Column {name!r} of the dataspec is missing from the dataset. "
                "Solutions: (1) provide the column, or (2) re-infer the dataspec "
                "on this dataset.")
        vals = np.asarray(data[name], dtype=object).ravel()
        n_rows = len(vals)
        if col.semantic == Semantic.NUMERICAL:
            numerical[name] = _parse_numerical(vals)
        elif col.semantic == Semantic.BOOLEAN:
            categorical[name] = _parse_boolean(vals)
        else:
            lookup = {v: i for i, v in enumerate(col.vocab)}
            miss = _missing_mask(vals)
            uq, inv = np.unique(vals.astype(str), return_inverse=True)
            code_of = np.fromiter((lookup.get(u, 0) for u in uq),
                                  np.int32, len(uq))  # 0 = OOD
            out = code_of[inv.reshape(len(vals))]
            out[miss] = -1
            categorical[name] = out
    return VerticalDataset(spec=spec, numerical=numerical,
                           categorical=categorical, n_rows=n_rows)


def dataset_from_raw(data: Mapping[str, Any], **kw) -> VerticalDataset:
    return encode_dataset(data, infer_dataspec(data, **kw))


# ------------------------------------------- compiled row encoding (§5.1)

class BatchEncoder:
    """Vectorized raw->code tables, compiled once per (spec, features).

    The per-call predict path walks the dataspec, builds per-unique-value
    python dict lookups (``encode_dataset``) and then re-imputes in a second
    pass (``raw_matrix``) — on every request. Compiling a model
    (DESIGN.md §5.1) bakes those decisions into flat tables up front:

      numerical   -> bulk float cast + the column's mean as imputation value
      boolean     -> truthy-string table, missing -> 0
      categorical -> sorted-vocab ``searchsorted`` table with the matching
                     code permutation; out-of-dictionary -> 0 (OOD), missing
                     -> most-frequent (code 1) exactly like global imputation

    ``encode`` then returns the same (N, F) float32 matrix as
    ``raw_matrix(encode_dataset(data, spec), features)``, without dict
    lookups or a second pass — and, unlike the training-path encoder, only
    requires the *feature* columns (serving requests carry no label).
    """

    def __init__(self, spec: DataSpec, features: list[str]):
        self.spec = spec
        self.features = list(features)
        self._plan: list[tuple] = []
        for name in self.features:
            col = spec[name]
            if col.semantic == Semantic.NUMERICAL:
                self._plan.append(("num", name, np.float32(col.mean), None, None))
            elif col.semantic == Semantic.BOOLEAN:
                fill = np.float32(1.0 if col.vocab_size > 1 else 0.0)
                self._plan.append(("bool", name, fill, None, None))
            else:
                vocab = np.asarray(col.vocab, dtype=str)
                order = np.argsort(vocab, kind="stable")
                fill = np.float32(1.0 if col.vocab_size > 1 else 0.0)
                self._plan.append(("cat", name, fill, vocab[order],
                                   order.astype(np.int32)))

    def encode(self, data) -> np.ndarray:
        """data: raw column mapping (feature columns only suffice) or an
        already-encoded VerticalDataset. -> (N, F) float32 raw matrix."""
        if isinstance(data, VerticalDataset):
            from repro.core.models import raw_matrix
            return raw_matrix(data, self.features)
        missing = [n for n in self.features if n not in data]
        if missing:
            raise YdfError(
                f"Feature column(s) {missing} are missing from the request "
                f"batch. The model requires: {self.features}.")
        first = np.asarray(data[self.features[0]], dtype=object).ravel() \
            if self.features else np.zeros(0, object)
        X = np.empty((len(first), len(self.features)), np.float32)
        for j, (kind, name, fill, sorted_vocab, codes) in enumerate(self._plan):
            vals = np.asarray(data[name], dtype=object).ravel()
            if len(vals) != len(first):
                raise YdfError(
                    f"Feature column {name!r} has {len(vals)} values but "
                    f"{self.features[0]!r} has {len(first)}; request batches "
                    "must be rectangular.")
            if kind == "num":
                v = _parse_numerical(vals)
                v[np.isnan(v)] = fill
            elif kind == "bool":
                v = _parse_boolean(vals).astype(np.float32)
                v[v < 0] = fill
            else:
                miss = _missing_mask(vals)
                s = vals.astype(str)
                pos = np.searchsorted(sorted_vocab, s)
                pos_c = np.minimum(pos, len(sorted_vocab) - 1)
                found = sorted_vocab[pos_c] == s
                v = np.where(found, codes[pos_c], 0).astype(np.float32)
                v[miss] = fill
            X[:, j] = v
        return X


# ----------------------------------------------------------------- labels

def check_classification_label(col: Column, task: Task) -> None:
    """The paper's §2.2 safety check, verbatim in spirit."""
    if col.semantic == Semantic.NUMERICAL:
        raise YdfError(
            f'The classification label column "{col.name}" is NUMERICAL '
            f"({col.mean:.4g} mean over a [{col.min:g}, {col.max:g}] range) and "
            "looks like a regression target. Solutions: (1) configure the "
            "training as a regression with task=REGRESSION, or (2) declare the "
            "label CATEGORICAL explicitly if the numbers are class ids.")
    n_classes = col.vocab_size - 1
    if n_classes > 0.5 * 10_000 and n_classes > 100:
        raise YdfError(
            f'The classification label column "{col.name}" has {n_classes} '
            "unique values and looks like a regression column. Solutions: (1) "
            "use task=REGRESSION, or (2) reduce the label cardinality.")


def label_values(model, dataset) -> np.ndarray:
    """0-based class indices (classification) or float targets (regression),
    aligned with ``Model.predict`` output columns."""
    if isinstance(dataset, VerticalDataset):
        y = dataset.column(model.label)
        if model.task == Task.CLASSIFICATION:
            if (y <= 0).any():
                raise YdfError(
                    f'Label column "{model.label}" contains missing or '
                    "out-of-dictionary values; evaluation requires labeled "
                    "examples. Solution: filter unlabeled rows first.")
            return (y - 1).astype(np.int32)  # vocab[0] is OOD
        return y.astype(np.float32)
    raw = np.asarray(dataset[model.label], dtype=object).ravel()
    if model.task == Task.CLASSIFICATION:
        lookup = {str(v): i for i, v in enumerate(model.classes)}
        try:
            return np.array([lookup[str(v)] for v in raw], np.int32)
        except KeyError as e:
            raise YdfError(
                f"Label value {e.args[0]!r} was not seen during training. "
                f"Training classes: {model.classes}.")
    return np.array([float(v) for v in raw], np.float32)
