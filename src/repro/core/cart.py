"""CART learner (Breiman et al. 1984): a single tree grown on a train split
and pruned bottom-up on a self-extracted validation split (reduced-error
pruning), as in YDF's CART.
"""
from __future__ import annotations

import numpy as np

from repro.obs import build_training_logs, trace
from repro.core.api import Learner, Task, register_learner
from repro.core.grower import GrowthParams, grow_tree
from repro.core.hparams import CartHparams
from repro.core.models import CartModel, extract_validation, prepare_train_data
from repro.core.splitters import SplitterParams
from repro.core.tree import Forest, predict_raw, empty_forest


@register_learner("CART")
class CartLearner(Learner):
    def default_hparams(self) -> CartHparams:
        return CartHparams()

    def train(self, dataset, valid=None, checkpoint=None) -> CartModel:
        hp: CartHparams = self.hparams
        rng = np.random.default_rng(self.seed)
        td = prepare_train_data(self, dataset, max_bins=hp.max_bins)
        N = td.ds.n_rows
        if valid is None and N >= 20:
            tr_idx, va_idx = extract_validation(N, hp.validation_ratio, self.seed)
        else:
            tr_idx, va_idx = np.arange(N), np.arange(0)
        if self.task == Task.CLASSIFICATION:
            C = td.n_classes
            stat_kind, out_dim = "class", C
            base = np.concatenate([np.eye(C)[td.y], np.ones((N, 1))], 1)

            def leaf_fn(s):
                return (s[:-1] / max(s[-1], 1e-12)).astype(np.float32)
        else:
            stat_kind, out_dim = "moment", 1
            base = np.stack([td.y, np.square(td.y), np.ones(N)], 1)

            def leaf_fn(s):
                return np.array([s[0] / max(s[-1], 1e-12)], np.float32)

        sp = SplitterParams(stat_kind=stat_kind, min_examples=hp.min_examples,
                            categorical_algorithm=hp.categorical_algorithm)
        gp = GrowthParams(max_depth=hp.max_depth, max_nodes=hp.max_num_nodes,
                          growing_strategy="LOCAL", splitter=sp,
                          engine=hp.growth_engine,
                          histogram_backend=hp.histogram_backend)
        forest = empty_forest(1, hp.max_num_nodes, out_dim,
                              feature_names=td.features)
        forest.out_dim = out_dim
        forest.tree_class = None

        # -- checkpoint seam (DESIGN.md §11). A single tree has one interior
        # boundary: grown-but-unpruned. Pruning is deterministic given
        # (forest, seed-derived validation split), so resuming from the
        # "grown" stage and re-pruning is bit-identical to a clean run.
        from repro.train.checkpoint import (
            forest_payload, open_session, restore_forest)
        from repro.core.rf import training_data_fingerprint
        sess = open_session(checkpoint, self.train_config(),
                            training_data_fingerprint(td.X_raw, td.y))
        state = sess.resume() if sess is not None else None
        grown = pruned = False
        interrupted = False
        if state is not None:
            restore_forest(forest, state["forest"])
            grown, pruned = True, bool(state["done"])

        def _payload(complete: bool) -> dict:
            return {"kind": "cart", "trees_done": 1, "done": bool(complete),
                    "forest": forest_payload(forest, 1)}

        import contextlib
        with (sess if sess is not None else contextlib.nullcontext()):
            if not grown:
                w = np.zeros(N)
                w[tr_idx] = 1.0
                with trace.span("cart/grow"):
                    grow_tree(forest, 0, td.binned, td.X_raw,
                              base * w[:, None], w > 0, leaf_fn, gp, rng)
                if sess is not None and sess.should_stop():
                    # servable unpruned tree now; pruning happens on resume
                    interrupted = True
                    sess.save(1, _payload(False), done=False, force=True)
            if not pruned and not interrupted:
                if len(va_idx):
                    with trace.span("cart/prune", valid_rows=len(va_idx)):
                        _prune(forest, td.X_raw[va_idx], td.y[va_idx],
                               self.task)
                pruned = True
                if sess is not None:
                    sess.save(1, _payload(True), done=True, force=True)

        model = CartModel(winner_take_all=False, forest=forest, spec=td.ds.spec,
                          features=td.features, label=self.label, task=self.task,
                          classes=td.classes)
        model.training_logs = build_training_logs(
            learner="cart", num_trees=1,
            growth_engine=hp.growth_engine, engine_fallback=None,
            resilience=sess.events if sess is not None else None,
            interrupted=interrupted)
        return model


def _prune(forest: Forest, Xv: np.ndarray, yv: np.ndarray, task: Task) -> None:
    """Reduced-error pruning: convert an internal node to a leaf whenever that
    does not hurt validation accuracy / squared error."""
    t = 0
    n = int(forest.n_nodes[t])

    def valid_score() -> float:
        pr = predict_raw(forest, Xv)[:, 0]          # (Nv, out_dim)
        if task == Task.CLASSIFICATION:
            return float((pr.argmax(1) == yv).mean())
        return -float(np.mean(np.square(pr[:, 0] - yv)))

    # bottom-up: children have larger ids than parents by construction
    internal = [i for i in range(n) if forest.left_child[t, i] >= 0]
    for node in sorted(internal, reverse=True):
        before = valid_score()
        saved = forest.left_child[t, node]
        forest.left_child[t, node] = -1
        if valid_score() < before:
            forest.left_child[t, node] = saved      # revert
