"""Gradient Boosted Trees learner (Friedman 2001), YDF-default-faithful:
paper App. C.1 defaults, LOSS_INCREASE early stopping on a self-extracted
validation set (§3.3), LOCAL or BEST_FIRST_GLOBAL growth, CART/RANDOM/ONE_HOT
categorical splits, optional sparse-oblique splits, deterministic training.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import build_training_logs, trace
from repro.core.api import Learner, Task, YdfError, register_learner
from repro.core.grower import GrowthParams, grow_tree
from repro.core.hparams import GBTHparams
from repro.core.losses import make_loss
from repro.core.models import (
    GradientBoostedTreesModel,
    TrainData,
    extract_validation,
    prepare_train_data,
)
from repro.core.evaluation import Evaluation, evaluate_predictions
from repro.core.splitters import SplitterParams
from repro.core.tree import Forest, empty_forest, predict_raw


@register_learner("GRADIENT_BOOSTED_TREES")
class GradientBoostedTreesLearner(Learner):
    # hyper-parameter templates (``template="benchmark_rank1"``) are applied
    # by the Learner base BEFORE explicit overrides (§3.11)

    def default_hparams(self) -> GBTHparams:
        return GBTHparams()

    # ------------------------------------------------------------- train
    def train(self, dataset, valid=None, checkpoint=None
              ) -> GradientBoostedTreesModel:
        hp: GBTHparams = self.hparams
        rng = np.random.default_rng(self.seed)
        td = prepare_train_data(self, dataset, max_bins=hp.max_bins)

        # §3.3: extract validation from train when early stopping needs one.
        # Ranking keeps every group WHOLE on one side of the split — a torn
        # group corrupts both its lambda pairs and its NDCG.
        groups_v = None
        if valid is not None:
            train_idx = np.arange(td.ds.n_rows)
            Xv, yv, wv, groups_v = _encode_eval_set(self, td, valid)
        elif hp.early_stopping != "NONE" and hp.validation_ratio > 0:
            if self.task == Task.RANKING:
                from repro.tasks.ranking import group_aware_split
                train_idx, valid_idx = group_aware_split(
                    td.groups, hp.validation_ratio, self.seed)
            else:
                train_idx, valid_idx = extract_validation(
                    td.ds.n_rows, hp.validation_ratio, self.seed)
            Xv, yv = td.X_raw[valid_idx], td.y[valid_idx]
            wv = td.w[valid_idx]
            if td.groups is not None:
                groups_v = td.groups[valid_idx]
        else:
            train_idx = np.arange(td.ds.n_rows)
            Xv = yv = wv = None

        sub_td = _subset_td(td, train_idx)
        N = len(train_idx)
        y, w = sub_td.y, sub_td.w

        if self.task == Task.RANKING:
            # built here, not in make_loss: the loss owns the train/valid
            # group layouts, which only exist after the split above
            from repro.tasks.ranking import LambdaMARTLoss, group_layout
            loss = LambdaMARTLoss(
                y, group_layout(sub_td.groups), k=hp.ndcg_truncation,
                y_valid=yv,
                layout_valid=None if yv is None else group_layout(groups_v))
        else:
            loss = make_loss(self.task, hp.loss, td.n_classes)
        K = loss.out_dim

        max_nodes = (hp.max_num_nodes if hp.growing_strategy == "BEST_FIRST_GLOBAL"
                     else 2 ** (hp.max_depth + 1))
        oblique = hp.split_axis == "SPARSE_OBLIQUE"
        n_num = int((~td.binned.is_cat).sum())
        forest = empty_forest(hp.num_trees * K, max_nodes, 1,
                              oblique_dims=n_num if oblique else 0,
                              feature_names=td.features)
        forest.init_pred = np.zeros(K, np.float32)
        init = loss.init_pred(y, w)
        forest.init_pred[:] = init
        forest.out_dim = K
        forest.tree_class = np.arange(hp.num_trees * K, dtype=np.int32) % K

        sp = SplitterParams(
            stat_kind="gh", min_examples=hp.min_examples,
            l2=hp.l2_regularization, categorical_algorithm=hp.categorical_algorithm,
            num_candidate_ratio=(hp.num_candidate_attributes_ratio
                                 if hp.num_candidate_attributes_ratio > 0 else 1.0),
            oblique=oblique,
            oblique_num_projections_exponent=hp.sparse_oblique_num_projections_exponent,
        )
        gp = GrowthParams(max_depth=hp.max_depth, max_nodes=max_nodes,
                          growing_strategy=hp.growing_strategy, splitter=sp,
                          engine=hp.growth_engine,
                          histogram_backend=hp.histogram_backend,
                          sampling_key=self.seed & 0xFFFFFFFF)
        from repro.core.grower import resolve_engine
        engine_used, engine_fallback = resolve_engine(gp, td.binned, oblique)
        shrink, l2 = hp.shrinkage, hp.l2_regularization

        def leaf_fn(s):
            # s = [sum g, sum h_gain, sum h_true, count]; Newton step * shrinkage
            return np.array([-shrink * s[0] / (s[2] + l2 + 1e-12)], np.float32)

        pred = np.tile(init[None, :], (N, 1)).astype(np.float64)
        pred_v = (np.tile(init[None, :], (len(yv), 1)).astype(np.float64)
                  if yv is not None else None)
        best_loss, best_t, patience = np.inf, 0, hp.early_stopping_patience
        train_losses, valid_losses = [], []

        # -- checkpoint seam (DESIGN.md §11): the bit-identical-resume
        # closure is (forest slices, pred, pred_v, early-stop bookkeeping,
        # rng.bit_generator.state) snapshotted at tree boundaries. The seam
        # sits OUTSIDE grow_tree, so host-batched and device engines
        # checkpoint identically.
        from repro.train.checkpoint import (
            forest_payload, open_session, restore_forest)
        from repro.core.rf import training_data_fingerprint
        sess = open_session(checkpoint, self.train_config(),
                            training_data_fingerprint(td.X_raw, td.y))
        trees_done, stopped, interrupted = 0, False, False

        def _payload(complete: bool) -> dict:
            return {"kind": "gbt", "trees_done": trees_done,
                    "done": bool(complete),
                    "forest": forest_payload(forest, trees_done * K),
                    "pred": np.copy(pred),
                    "pred_v": None if pred_v is None else np.copy(pred_v),
                    "rng_state": rng.bit_generator.state,
                    "best_loss": float(best_loss), "best_t": int(best_t),
                    "train_losses": list(train_losses),
                    "valid_losses": list(valid_losses)}

        if sess is not None:
            state = sess.resume()
            if state is not None:
                trees_done = int(state["trees_done"])
                stopped = bool(state["done"])
                restore_forest(forest, state["forest"])
                pred[:] = state["pred"]
                if pred_v is not None and state["pred_v"] is not None:
                    pred_v[:] = state["pred_v"]
                rng.bit_generator.state = state["rng_state"]
                best_loss = state["best_loss"]
                best_t = state["best_t"]
                train_losses = list(state["train_losses"])
                valid_losses = list(state["valid_losses"])

        import contextlib
        with (sess if sess is not None else contextlib.nullcontext()):
            for it in range(trees_done, hp.num_trees):
                if stopped:
                    break
                with trace.span("gbt/grad_hess", iteration=it):
                    g, h = loss.grad_hess(pred, y, w)
                bag = w if hp.subsample >= 1.0 else w * (rng.random(N) < hp.subsample)
                for k in range(K):
                    t = it * K + k
                    stats = np.stack([
                        g[:, k] * bag,
                        (h[:, k] if hp.use_hessian_gain else np.ones(N)) * bag,
                        h[:, k] * bag,
                        bag,
                    ], axis=1).astype(np.float64)
                    with trace.span("gbt/tree", tree=t, iteration=it):
                        node_of = grow_tree(forest, t, sub_td.binned,
                                            sub_td.X_raw, stats, bag > 0,
                                            leaf_fn, gp, rng,
                                            sub_td.num_lo, sub_td.num_hi)
                    vals = forest.leaf_value[t, np.maximum(node_of, 0), 0]
                    upd = np.where(node_of >= 0, vals, 0.0)
                    if hp.subsample < 1.0:  # OOB examples still move (predict path)
                        oob = (bag <= 0)
                        if oob.any():
                            tr = predict_raw(_one_tree(forest, t), sub_td.X_raw[oob])
                            upd = upd.copy()
                            upd[oob] = tr[:, 0, 0]
                    pred[:, k] += upd
                    if pred_v is not None:
                        pv = predict_raw(_one_tree(forest, t), Xv)[:, 0, 0]
                        pred_v[:, k] += pv
                trees_done = it + 1
                train_losses.append(loss.value(pred, y, w))
                if pred_v is not None:
                    vl = loss.value(pred_v, yv, wv)
                    valid_losses.append(vl)
                    if vl < best_loss - 1e-9:
                        best_loss, best_t = vl, it + 1
                    elif hp.early_stopping == "LOSS_INCREASE" and it + 1 - best_t >= patience:
                        stopped = True
                if sess is not None:
                    complete = stopped or trees_done == hp.num_trees
                    if not complete and sess.should_stop():
                        interrupted = True
                    sess.save(trees_done, _payload(complete), done=complete,
                              force=complete or interrupted)
                    if interrupted:
                        break

        n_keep = (best_t if pred_v is not None and hp.early_stopping != "NONE"
                  and not interrupted else trees_done) * K
        forest = forest.truncated(max(min(n_keep, trees_done * K), K))
        self_eval = None
        if pred_v is not None and len(yv):
            act = loss.activation(pred_v)
            if self.task == Task.CLASSIFICATION:
                self_eval = evaluate_predictions(self.task, act, yv,
                                                 classes=td.classes,
                                                 source="validation")
            elif self.task == Task.RANKING:
                self_eval = evaluate_predictions(self.task, act, yv,
                                                 groups=groups_v,
                                                 source="validation")
            else:
                self_eval = evaluate_predictions(self.task, act, yv,
                                                 source="validation")
        # a loss that holds training-set state (LambdaMART's group layouts)
        # ships a stripped serving head instead, so pickled models stay small
        model_loss = loss.serving_head() if hasattr(loss, "serving_head") else loss
        model = GradientBoostedTreesModel(
            loss=model_loss, forest=forest, spec=td.ds.spec,
            features=td.features, label=self.label, task=self.task,
            classes=td.classes, self_evaluation=self_eval)
        if self.task == Task.RANKING:
            model.ranking_group = hp.ranking_group
        model.training_logs = build_training_logs(
            learner="gbt", num_trees=forest.n_trees // K,
            growth_engine=engine_used, engine_fallback=engine_fallback,
            resilience=sess.events if sess is not None else None,
            interrupted=interrupted,
            extra={"train_loss": train_losses, "valid_loss": valid_losses})
        return model


def _one_tree(forest: Forest, t: int) -> Forest:
    return dataclasses.replace(
        forest,
        feature=forest.feature[t:t + 1], threshold=forest.threshold[t:t + 1],
        split_bin=forest.split_bin[t:t + 1], cat_mask=forest.cat_mask[t:t + 1],
        left_child=forest.left_child[t:t + 1],
        leaf_value=forest.leaf_value[t:t + 1], n_nodes=forest.n_nodes[t:t + 1],
        obl_weights=None if forest.obl_weights is None else forest.obl_weights[t:t + 1],
        obl_features=None if forest.obl_features is None else forest.obl_features[t:t + 1],
        tree_class=None if forest.tree_class is None else forest.tree_class[t:t + 1])


def _encode_eval_set(learner, td: TrainData, valid):
    """Encode an external validation set with the TRAINING dataspec so class
    indices and imputation match (paper §3.3 external-valid path). For
    ranking the 4th return is the valid set's group ids (else None), read
    from the RAW column — the training vocabulary must not collapse unseen
    validation groups into one out-of-dictionary bucket."""
    from repro.core.models import _as_vertical, raw_matrix
    vds = _as_vertical(valid, td.ds.spec)
    Xv = raw_matrix(vds, td.features)
    if learner.task == Task.CLASSIFICATION:
        enc = vds.categorical[learner.label]
        if (enc <= 0).any():
            raise YdfError(
                f'Validation label "{learner.label}" contains values unseen in '
                "training (or missing). Solution: filter those rows.")
        yv = (enc - 1).astype(np.int32)
    else:
        yv = vds.numerical[learner.label].astype(np.float64)
    groups_v = None
    if learner.task == Task.RANKING:
        from repro.core.dataspec import VerticalDataset
        gcol = learner.hparams.ranking_group
        if isinstance(valid, VerticalDataset):
            col = np.asarray(valid.column(gcol))
        else:
            if gcol not in valid:
                raise YdfError(
                    f'Ranking validation set is missing the group column '
                    f'"{gcol}".')
            col = np.asarray(valid[gcol], dtype=object).ravel()
        groups_v = np.unique(col.astype(str),
                             return_inverse=True)[1].astype(np.int64)
    return Xv, yv, np.ones(len(yv), np.float64), groups_v


def _subset_td(td: TrainData, idx: np.ndarray) -> TrainData:
    import dataclasses as dc
    if len(idx) == td.ds.n_rows and (idx == np.arange(len(idx))).all():
        return td
    binned = dc.replace(td.binned, codes=td.binned.codes[idx])
    return dc.replace(td, binned=binned, X_raw=td.X_raw[idx], y=td.y[idx],
                      w=td.w[idx],
                      groups=None if td.groups is None else td.groups[idx])
